"""Serving with partly-persistent session state + crash recovery.

Boots the ServingEngine on a reduced gemma2 config, serves a batch of
requests with greedy decode, crashes mid-generation (dropping KV caches,
the request hashmap, and the paged-LRU metadata), recovers from the
persistent arena, and asserts the continued generations are identical.

    PYTHONPATH=src python examples/serve_recover.py
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base, registry
from repro.models.model import build
from repro.serve.engine import EngineConfig, ServingEngine


def main():
    cfg = base.reduced(registry.get("gemma2-9b"))
    model = build(cfg, compute_dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0))

    with tempfile.TemporaryDirectory() as td:
        eng = ServingEngine(
            model, params,
            EngineConfig(max_batch=4, s_max=48, max_requests=32),
            arena_path=os.path.join(td, "arena"))

        rng = np.random.default_rng(7)
        prompts = {}
        for rid in (901, 902, 903):
            p = rng.integers(1, cfg.vocab, int(rng.integers(4, 9)))
            prompts[rid] = p
            eng.add_request(rid, p.astype(np.int64))
            print(f"request {rid}: prompt {p.tolist()}")

        print("\n-- serving 4 steps --")
        for i in range(4):
            print(f"step {i}: {eng.step()}")

        expected = [eng.step() for _ in range(4)]
        print("\n-- CRASH: device caches + volatile host tables dropped --")
        eng.crash()
        dt = eng.recover()
        print(f"recovered in {dt:.2f}s: hashmap rebuilt from (KEY,VALUE) "
              f"slab, LRU from NEXT chain, KV caches re-prefilled from "
              f"the persisted token log")

        got = [eng.step() for _ in range(4)]
        assert got == expected, (got, expected)
        print("\npost-recovery generations identical to the "
              "uninterrupted run:")
        for i, toks in enumerate(got):
            print(f"step {i + 4}: {toks}")
        st = eng.arena.stats
        print(f"\narena flush stats: {st.lines} lines, {st.bytes} bytes, "
              f"{st.calls} calls")


if __name__ == "__main__":
    main()
