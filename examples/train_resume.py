"""End-to-end training driver with partly-persistent checkpointing.

Trains a ~100M-parameter llama-family model for a few hundred steps on
CPU, checkpointing through the PARTLY policy, injecting a crash at
step 120, and verifying the resumed trajectory is bit-identical to an
uninterrupted run.

    PYTHONPATH=src python examples/train_resume.py [--steps 200]
"""
import argparse
import dataclasses
import shutil
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core import policy as pol
from repro.models.model import build
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def small_llama():
    """~100M-param llama3-family config (runs on CPU)."""
    return dataclasses.replace(
        registry.get("llama3.2-3b"),
        n_layers=6, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
        d_ff=2048, vocab=32000)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--crash-at", type=int, default=120)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    args = ap.parse_args()

    cfg = small_llama()
    model = build(cfg, compute_dtype=jnp.float32)
    n_params = cfg.param_count()
    print(f"model: {n_params / 1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.global_batch} "
          f"x seq {args.seq_len}")

    d = tempfile.mkdtemp(prefix="repro_example_")
    try:
        tc = TrainerConfig(
            steps=args.steps, ckpt_every=40, ckpt_dir=d,
            policy=pol.PARTLY_PERSISTENT, global_batch=args.global_batch,
            seq_len=args.seq_len, async_ckpt=True)
        tr = Trainer(model, AdamWConfig(), tc)
        tr.init()

        # incarnation 1: run to the crash point
        tr.run(args.crash_at)
        print(f"[inc 1] step {args.crash_at - 1} "
              f"loss={tr.metrics_log[-1]['loss']:.4f}")
        print("[inc 1] CRASH (all volatile state dropped)")
        tr.crash()

        # incarnation 2: restore, reconstruct DERIVABLE state, continue
        step = tr.resume()
        rep = tr.ckpt.last_report
        print(f"[inc 2] restored step {step}; checkpoint wrote "
              f"{rep.bytes_written / 2**20:.1f} MiB, skipped "
              f"{rep.bytes_skipped_derivable} B of derivable state")
        tr.run(args.steps - step)
        crashed_final = tr.metrics_log[-1]["loss"]

        # reference: uninterrupted run
        tc2 = dataclasses.replace(tc, ckpt_every=0, ckpt_dir=d + "_ref")
        tr2 = Trainer(model, AdamWConfig(), tc2)
        tr2.init()
        tr2.run(args.steps)
        ref_final = tr2.metrics_log[-1]["loss"]

        print(f"\nfinal loss  crashed-run={crashed_final:.6f}  "
              f"uninterrupted={ref_final:.6f}  "
              f"delta={abs(crashed_final - ref_final):.2e}")
        assert abs(crashed_final - ref_final) < 1e-4, "trajectories diverged"
        print("bit-consistent resume verified: reconstruction is exact.")
    finally:
        shutil.rmtree(d, ignore_errors=True)
        shutil.rmtree(d + "_ref", ignore_errors=True)


if __name__ == "__main__":
    main()
