"""Quickstart: the paper's technique in 60 lines.

Builds each of the three partly-persistent structures, runs a workload,
crashes, reconstructs, and prints the flush savings vs fully-persistent.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.arena import open_arena
from repro.pstruct.bptree import BPTree
from repro.pstruct.dll import DoublyLinkedList
from repro.pstruct.hashmap import Hashmap

rng = np.random.default_rng(0)
N = 20000


def demo(kind):
    lines = {}
    for mode in ("full", "partly"):
        if kind == "dll":
            a = open_arena(None, DoublyLinkedList.layout(N + 64, mode))
            s = DoublyLinkedList(a, N + 64, mode)
        elif kind == "bptree":
            a = open_arena(None, BPTree.layout(N, N * 2, mode))
            s = BPTree(a, N, N * 2, mode)
        else:
            a = open_arena(None, Hashmap.layout(N + 64, mode))
            s = Hashmap(a, N + 64, mode)

        keys = rng.permutation(N).astype(np.int64)
        vals = rng.integers(0, 1 << 40, (N, 7)).astype(np.int64)
        for i in range(0, N, 1024):
            if kind == "dll":
                s.append_batch(vals[i:i + 1024])
            else:
                s.insert_batch(keys[i:i + 1024], vals[i:i + 1024])
        a.commit()
        lines[mode] = a.stats.lines

        if mode == "partly":
            # ---- crash: volatile state gone; reconstruct from essentials
            a.crash()
            a.reopen()
            s.reconstruct()
            if kind == "dll":
                assert s.count == N
            else:
                ok, got = (s.find_batch(keys))
                assert ok.all() and (got == vals).all()
    save = (1 - lines["partly"] / lines["full"]) * 100
    print(f"{kind:8s}  fully={lines['full']:8d} lines   "
          f"partly={lines['partly']:8d} lines   saved={save:.0f}%   "
          f"(crash+reconstruct verified)")


if __name__ == "__main__":
    print(f"inserting {N} entries into each structure, both modes:\n")
    for kind in ("dll", "bptree", "hashmap"):
        demo(kind)
    print("\nDon't persist all: only the essential fields hit the arena; "
          "redundancy is rebuilt on restart.")
