"""Bit rot -> scrub -> salvage: quarantine the loss, serve the rest.

Builds a mixed three-structure arena (LRU ring + B+Tree + hashmap) on
disk, crashes it, and flips ONE bit in a committed B+Tree leaf — the
media fault the integrity sidecars exist for (DESIGN.md §13).  A scrub
pass names the exact region and row, plain recovery would have
reconstructed from the rotten line, and ``recover(salvage=True)``
instead quarantines the damaged keys while the other two structures
recover bit-identically.  Part two does the same to a serving engine's
token log: the rid whose tokens rotted is refused with
``QuarantinedError`` until an explicit ``readmit`` closes it out —
corruption never silently re-enters the serving path.

    PYTHONPATH=src python examples/salvage_recovery.py
"""
import os
import tempfile

import numpy as np

from repro.core import faultinject as fi
from repro.core.arena import QuarantinedError, open_arena
from repro.core.recovery import RecoveryManager
from repro.pstruct.bptree import BPTree
from repro.pstruct.dll import DoublyLinkedList
from repro.pstruct.hashmap import Hashmap


def build(path):
    layout = {}
    layout.update(DoublyLinkedList.layout(256, "partly", name="dll"))
    layout.update(BPTree.layout(256, 1024, "partly", name="bt"))
    layout.update(Hashmap.layout(512, "partly", name="hm"))
    a = open_arena(path, layout)
    d = DoublyLinkedList(a, 256, "partly", name="dll")
    t = BPTree(a, 256, 1024, "partly", name="bt")
    h = Hashmap(a, 512, "partly", name="hm")
    rng = np.random.default_rng(0)
    key = 0
    for i in range(30):
        m = int(rng.integers(2, 7))
        vals = rng.integers(0, 1 << 30, (m, 7)).astype(np.int64)
        keys = np.arange(key, key + m, dtype=np.int64)
        key += m
        with a.epoch():
            if i % 3 == 0:
                d.append_batch(vals)
            elif i % 3 == 1:
                t.insert_batch(keys, vals)
            else:
                h.insert_batch(keys, vals)
        a.commit()
    return a, d, t, h


def salvage_mixed(td):
    a, d, t, h = build(os.path.join(td, "mixed.pm"))
    dll_order = d.order().copy()
    bt_keys = t.keys_in_order().copy()
    hm_size = int(h.size)
    leaf = int(t.leaves()[1])

    a.crash()
    fi.flip_bits(a, a.regions["bt.nodes"], leaf, byte=8, mask=0x40)
    print(f"crashed, then one bit flipped in committed leaf row {leaf} "
          f"of bt.nodes (media fault, not a torn write):")

    bad = a.scrub()
    for reg, rows in bad.items():
        print(f"  scrub: {reg} rows {rows.tolist()} fail their "
              f"line checksums")

    mgr = RecoveryManager(a)
    mgr.add("dll", "pstruct.dll", d)
    mgr.add("bt", "pstruct.bptree", t)
    mgr.add("hm", "pstruct.hashmap", h)
    rep = mgr.recover(salvage=True)
    print(f"  salvage recover in {rep.total_seconds * 1e3:.2f} ms: "
          f"quarantined={rep.quarantined} degraded={rep.degraded}")

    got = t.keys_in_order()
    lost = sorted(t.quarantined)
    assert set(got.tolist()) <= set(bt_keys.tolist())
    assert set(lost).isdisjoint(got.tolist())
    print(f"  bt: {got.size}/{bt_keys.size} keys survive, quarantined "
          f"keys {lost} are withheld (disjoint from survivors)")

    np.testing.assert_array_equal(d.order(), dll_order)
    assert int(h.size) == hm_size
    print(f"  dll ({dll_order.size} rows) and hm ({hm_size} keys) "
          f"recover bit-identical — the loss never spreads")


def salvage_engine(td):
    import jax
    import jax.numpy as jnp

    from repro.configs import base, registry
    from repro.models.model import build as build_model
    from repro.serve.engine import EngineConfig, ServingEngine

    model = build_model(base.reduced(registry.get("llama3.2-3b")),
                       compute_dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params,
                        EngineConfig(max_batch=3, s_max=16,
                                     max_requests=16),
                        arena_path=os.path.join(td, "engine"))
    eng.add_request(7, np.array([1, 2, 3], np.int64))
    eng.add_request(8, np.array([4, 5, 6, 9, 2], np.int64))
    eng.step()
    eng.crash()
    fi.flip_bits(eng.arena, eng.arena.regions["tokens"], 0,
                 byte=4, mask=0x10)           # rid 7's token-log row
    print("\nengine crashed, rid 7's token-log line rotted:")

    eng.recover(salvage=True)
    st = eng.last_recovery.stage("engine")
    print(f"  salvage recover: quarantined_rids="
          f"{st.detail['quarantined_rids']}, rid 8 serves on")
    out = eng.step()
    assert 8 in out and 7 not in out

    try:
        eng.add_request(7, np.array([1, 2, 3], np.int64))
        raise AssertionError("quarantined rid was admitted")
    except QuarantinedError as e:
        print(f"  re-admitting rid 7 refused: {e}")

    eng.readmit([7])
    assert eng.quarantined_rids == set()
    print("  explicit readmit([7]) closes it out "
          f"(journal state: {eng.journal.state_of(7)}); "
          "corruption never silently re-enters the batch")


def main():
    with tempfile.TemporaryDirectory() as td:
        salvage_mixed(td)
        salvage_engine(td)


if __name__ == "__main__":
    main()
