"""Larger-than-RAM arena: demand-paged crash recovery in ~60 lines.

Builds a paged-KV allocator whose node slab is ~10x the block-cache
budget (DESIGN.md §12), crashes it, recovers, and prints how many
blocks each recovery stage actually faulted versus the arena's total —
the point of paged regions: recovery reads the working set, not the
file.

    PYTHONPATH=src python examples/paged_arena.py
"""
import os
import tempfile
import time

from repro.serve.kvcache import PagedAllocator, PagedConfig

BLOCK_BYTES = 4096
CACHE_BLOCKS = 64
FACTOR = 10                           # arena bytes / cache capacity

rows_per_block = BLOCK_BYTES // 64    # partly-mode DLL node row: 64 B
n_pages = FACTOR * CACHE_BLOCKS * rows_per_block

with tempfile.TemporaryDirectory() as tdir:
    # snapshots seed the LRU order from the newest committed snapshot
    # (DESIGN.md §10), so the lru stage faults only the rows it replays
    # instead of walking the whole slab
    pa = PagedAllocator(PagedConfig(n_pages=n_pages, paged=True,
                                    snapshot=True,
                                    block_bytes=BLOCK_BYTES,
                                    cache_blocks=CACHE_BLOCKS),
                        path=os.path.join(tdir, "pool.bin"))
    cache = pa.arena.cache
    print(f"pool: {n_pages} pages, cache budget "
          f"{cache.capacity_bytes / 1024:.0f} KiB "
          f"({CACHE_BLOCKS} x {BLOCK_BYTES} B blocks)")

    # churn ~75% of the slab through the allocator, then free all but
    # two requests: the arena's FILE has seen most of its pages, but
    # the LIVE working set recovery must reconstruct is ~10% of it —
    # demand paging makes recovery cost track the latter
    touched = int(n_pages * 0.75)
    rid = 0
    for i in range(0, touched, 2048):
        pa.alloc(rid, min(2048, touched - i))
        rid += 1
    keep = {0, rid // 2}
    for r in range(rid):
        if r not in keep:
            pa.free_request(r)
    live = sum(len(pa.pages_of(r)) for r in keep)
    print(f"built: {rid} requests churned {touched} pages; "
          f"{live} live after frees; cache peak "
          f"{cache.peak_resident_bytes / 1024:.0f} KiB")

    pa.arena.crash()
    cache.reset_peak()                # measure recovery's own residency

    t0 = time.perf_counter()
    pa.recover()
    secs = time.perf_counter() - t0

    total_blocks = sum(r.total_blocks for r in pa.arena.regions.values()
                      if getattr(r, "is_paged", False))
    print(f"\nrecovered in {secs * 1000:.1f} ms; per-stage faults "
          f"(of {total_blocks} paged blocks total):")
    faulted = 0
    for st in pa.last_recovery.stages:
        bf = st.detail.get("block_faults")
        if bf is None:                # the reopen prologue: lazy reset
            print(f"  {st.name:<8} {st.seconds * 1000:7.2f} ms  (lazy)")
            continue
        faulted += bf
        print(f"  {st.name:<8} {st.seconds * 1000:7.2f} ms  "
              f"{bf:4d} blocks faulted")
    print(f"\nfaulted {faulted}/{total_blocks} blocks "
          f"({100 * faulted / total_blocks:.0f}% of the arena); "
          f"peak resident {cache.peak_resident_bytes / 1024:.0f} KiB "
          f"<= budget {cache.capacity_bytes / 1024:.0f} KiB "
          f"(+admit slack); spills={cache.spills}")
    pa.arena.close()
