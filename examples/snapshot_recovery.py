"""Incremental order snapshots: crash -> suffix-only replay.

Builds a paged-KV LRU ring (the DLL behind the serving allocator) on a
file-backed arena, commits a large base, commits a small suffix of
appends, then crashes.  With snapshots on (DESIGN.md §10) each epoch
flush sealed a one-line order-snapshot record, so recovery seeds the
ring from the newest committed record and local-walks ONLY the suffix —
the replayed-suffix length is printed straight from the
RecoveryReport's stage detail.  Tearing the newest record (the torn
mid-append crash image) demotes recovery to the previous record plus a
longer suffix; corrupting everything falls back to the full contraction
rank.  Recovered state is bit-identical in every case.

    PYTHONPATH=src python examples/snapshot_recovery.py
"""
import os
import tempfile

import numpy as np

from repro.core.arena import SNAP_SLOTS, open_arena, snap_record_parse
from repro.core.recovery import RecoveryManager
from repro.pstruct.dll import DoublyLinkedList

BASE, SUFFIX = 20_000, 120


def recover(arena, dll):
    mgr = RecoveryManager(arena)
    mgr.add("lru", "pstruct.dll", dll,
            regions=("lru.nodes", "lru.header", "lru.snapring",
                     "lru.snaprec"))
    report = mgr.recover()
    det = report.stage("lru").detail
    print(f"  recovered in {report.total_seconds * 1e3:.2f} ms: "
          f"chain={det['chain']} replayed={det['replayed']} "
          f"(of {det['count']} live rows)")
    return det


def newest_slot(dll):
    pv = dll.snaprec._pview()       # the PERSISTED record ring
    recs = [(snap_record_parse(pv[s]), s) for s in range(SNAP_SLOTS)]
    return max((r[1], s) for r, s in recs if r is not None)[1]


def main():
    with tempfile.TemporaryDirectory() as td:
        layout = DoublyLinkedList.layout(BASE + SUFFIX + 64, name="lru",
                                         snapshot=True)
        a = open_arena(os.path.join(td, "arena"), layout)
        d = DoublyLinkedList(a, BASE + SUFFIX + 64, name="lru",
                             snapshot=True)

        rng = np.random.default_rng(0)
        for i in range(0, BASE, 4096):
            m = min(4096, BASE - i)
            d.append_batch(rng.integers(0, 1 << 40, (m, 7))
                           .astype(np.int64))
            a.commit()     # each commit seals a snapshot record
        d.append_batch(rng.integers(0, 1 << 40, (SUFFIX, 7))
                       .astype(np.int64))
        a.commit()
        want = d.to_list()

        print(f"crash after committing {BASE} base + {SUFFIX} suffix "
              f"rows ({a.stats.snapshot_lines} snapshot lines amortized "
              f"over {a.stats.epochs} epochs):")
        a.crash()
        det = recover(a, d)
        assert det["chain"] == "snapshot" and det["replayed"] == 0
        np.testing.assert_array_equal(d.to_list(), want)

        print("\ncrash again, newest record torn mid-append "
              "(checksum rejects it -> previous record + suffix walk):")
        d.snaprec._pview()[newest_slot(d), 3:] = -777
        a.crash()
        det = recover(a, d)
        assert det["chain"] == "snapshot" and det["replayed"] == SUFFIX
        np.testing.assert_array_equal(d.to_list(), want)

        print("\ncrash again, whole snapshot ring corrupted "
              "(verification refuses it -> full contraction rank):")
        d.snaprec._pview()[:, 2:] = -777
        d.snapring._pview()[::2] = 2 ** 40
        a.crash()
        det = recover(a, d)
        assert det["chain"] in ("contract", "double")
        np.testing.assert_array_equal(d.to_list(), want)
        print("\nrecovered order bit-identical in all three scenarios")


if __name__ == "__main__":
    main()
