"""Lower + compile ONE production cell and print its roofline terms.

A minimal, readable version of launch/dryrun.py for exploring a single
(arch x shape x mesh) combination:

    PYTHONPATH=src python examples/dryrun_one_cell.py \
        --arch gemma3-27b --shape train_4k --multi-pod
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402

import jax  # noqa: E402

from repro import roofline as rl  # noqa: E402
from repro.configs import base, registry  # noqa: E402
from repro.launch.mesh import POD_SIZE, make_production_mesh  # noqa: E402
from repro.launch.specs import build_cell  # noqa: E402
from repro.models import accounting  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--shape", default="train_4k",
                    choices=list(base.SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = registry.get(args.arch)
    shape = base.SHAPES[args.shape]
    ok, why = registry.cell_supported(cfg, shape)
    if not ok:
        print(f"cell not supported: {why}")
        return

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    print(f"mesh: {dict(mesh.shape)} ({mesh.devices.size} devices)")
    cell = build_cell(cfg, shape, mesh)
    print(f"kind={cell.kind} fsdp={cell.fsdp} tokens/step={cell.n_tokens}")

    with mesh:
        compiled = (jax.jit(cell.fn, in_shardings=cell.in_shardings,
                            donate_argnums=cell.donate)
                    .lower(*cell.arg_specs).compile())

    mem = rl.memory_stats(compiled)
    print(f"\nper-device HBM: {mem['total_hbm_bytes'] / 2**30:.2f} GiB "
          f"(args {mem['argument_size_in_bytes'] / 2**30:.2f} + temp "
          f"{mem['temp_size_in_bytes'] / 2**30:.2f} - aliased "
          f"{mem['alias_size_in_bytes'] / 2**30:.2f}) "
          f"fits v5e: {mem['fits_v5e_16g']}")

    mf = accounting.model_flops(cfg, cell.n_tokens, cell.training)
    roof = rl.analyze(compiled, n_devices=mesh.devices.size,
                      pod_size=POD_SIZE if args.multi_pod else 1 << 30,
                      model_flops=mf)
    print(f"\nroofline terms (s/step/device @ TPU v5e):")
    print(f"  compute    {roof.compute_s:10.4f}   "
          f"({roof.dot_flops:.3e} dot FLOPs)")
    print(f"  memory     {roof.memory_s:10.4f}   "
          f"({roof.hbm_bytes:.3e} HBM bytes)")
    print(f"  collective {roof.collective_s:10.4f}   "
          f"({roof.coll_bytes:.3e} ICI B + {roof.coll_bytes_dcn:.3e} DCN B)")
    print(f"  dominant:  {roof.dominant};  step >= {roof.step_seconds:.4f}s")
    print(f"  MODEL_FLOPS/HLO_FLOPS = {roof.useful_flops_ratio:.3f}; "
          f"MFU at roofline = {roof.mfu:.4f}")
    print(f"  collective ops: {roof.coll_ops}")


if __name__ == "__main__":
    main()
