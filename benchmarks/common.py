"""Shared benchmark plumbing.

Scaling note (documented in EXPERIMENTS.md): the paper initializes 200M
entries and applies 100M ops on a 96-core Optane machine.  This harness
runs the same *workload shapes* scaled down (default 200k init / 100k ops)
on the CPU host.  Two metrics are reported per cell:

* exact flush accounting (lines / bytes) — medium-independent, directly
  comparable to the paper's flush-count reasoning;
* wall time with a synthetic per-line flush latency (default 250 ns,
  ~Optane clwb+fence cost) so the fully/partly *time* ratios reproduce
  the paper's regime (flush-dominated DLL, mixed B+Tree/hashmap).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.arena import open_arena
from repro.pstruct.bptree import BPTree
from repro.pstruct.dll import DoublyLinkedList
from repro.pstruct.hashmap import Hashmap

MODES = ("full", "partly")
SYNTH_LINE_NS = 250.0     # emulated clwb+fence cost per 64B line
# Ops are applied in vectorized batches (the TPU-framework adaptation of
# the paper's single-op loop).  64 keeps flush patterns close to per-op
# (inner-node / chain-pointer rewrites are not over-amortized) while
# letting numpy vectorize the traversals.
BATCH = 64


def arena_fields(a=None, **over) -> Dict:
    """Substrate triple stamped on EVERY bench row (commit protocol,
    shard count, persisted arena bytes) so rows from different
    configurations stay self-describing in the JSON artifacts.  Rows
    with no arena behind them (raw chain primitives, the ckpt restore)
    stamp ``commit_mode="none"`` and the working-set bytes instead."""
    f = {"commit_mode": "none", "n_shards": 1, "arena_bytes": 0,
         "block_bytes": 0, "cache_blocks": 0, "peak_resident_bytes": 0,
         "integrity": False, "integrity_lines": 0}
    if a is not None:
        f = {"commit_mode": a.commit_mode,
             "n_shards": int(getattr(a, "n_shards", 1)),
             "arena_bytes": int(sum(r.nbytes for r in a.regions.values())),
             "block_bytes": 0, "cache_blocks": 0, "peak_resident_bytes": 0,
             # checksum-sidecar accounting (DESIGN.md §13) rides on every
             # row so integrity-on and -off artifacts stay distinguishable
             "integrity": bool(getattr(a, "integrity", False)),
             "integrity_lines": int(a.stats.integrity_lines)}
        # paged arenas (DESIGN.md §12) additionally stamp the block-cache
        # geometry and the high-water resident footprint, so paged rows
        # carry their memory budget next to their timings
        cache = getattr(a, "cache", None)
        if cache is not None:
            f.update(block_bytes=int(cache.block_bytes),
                     cache_blocks=int(cache.cache_blocks),
                     peak_resident_bytes=int(cache.peak_resident_bytes))
    f.update(over)
    return f


@dataclasses.dataclass
class Cell:
    structure: str
    mode: str
    workload: str
    n_ops: int
    wall_s: float
    flush_s: float
    lines: int
    bytes: int
    saved_lines: int = 0   # epoch write-set dedup vs per-call accounting
    dedup_rows: int = 0    # duplicate row marks absorbed per epoch

    @property
    def flush_frac(self) -> float:
        return self.flush_s / self.wall_s if self.wall_s else 0.0


def make_structure(kind: str, mode: str, capacity: int,
                   synth_line_ns: float = SYNTH_LINE_NS,
                   integrity: Optional[bool] = None):
    if kind == "dll":
        a = open_arena(None, DoublyLinkedList.layout(capacity, mode),
                       synth_line_ns=synth_line_ns, integrity=integrity)
        return a, DoublyLinkedList(a, capacity, mode)
    if kind == "bptree":
        a = open_arena(None, BPTree.layout(max(64, capacity // 4),
                                           capacity, mode),
                       synth_line_ns=synth_line_ns, integrity=integrity)
        return a, BPTree(a, max(64, capacity // 4), capacity, mode)
    if kind == "hashmap":
        a = open_arena(None, Hashmap.layout(capacity, mode),
                       synth_line_ns=synth_line_ns, integrity=integrity)
        return a, Hashmap(a, capacity, mode)
    raise ValueError(kind)


def run_workload(kind: str, mode: str, workload: str, n_init: int,
                 n_ops: int, seed: int = 0,
                 synth_line_ns: float = SYNTH_LINE_NS) -> Cell:
    """workload: insert | delete | mixed_1_1 | mixed_2_1 | mixed_4_1."""
    rng = np.random.default_rng(seed)
    capacity = n_init + n_ops + 1024
    a, s = make_structure(kind, mode, capacity, synth_line_ns)

    keyspace = rng.permutation(capacity * 2).astype(np.int64)
    init_keys = keyspace[:n_init]
    new_keys = keyspace[n_init:n_init + n_ops]
    vals = rng.integers(0, 1 << 40, (max(n_init, n_ops), 7)).astype(np.int64)

    # ---- init (not timed) ----
    if kind == "dll":
        for i in range(0, n_init, 4096):
            s.append_batch(vals[i:min(i + 4096, n_init)])
    else:
        for i in range(0, n_init, 4096):
            s.insert_batch(init_keys[i:i + 4096], vals[i:i + 4096])
    a.commit()
    base_stats = a.stats.snapshot()

    # ---- timed ops ----
    if workload == "insert":
        ratio = (1, 0)
    elif workload == "delete":
        ratio = (0, 1)
    else:
        k = int(workload.split("_")[1])
        ratio = (k, 1)

    ins_ptr = del_ptr = 0
    t0 = time.perf_counter()
    done = 0
    while done < n_ops:
        for _ in range(ratio[0]):
            if done >= n_ops:
                break
            m = min(BATCH, n_ops - done)
            if kind == "dll":
                s.append_batch(vals[(ins_ptr % n_ops):(ins_ptr % n_ops) + m]
                               if (ins_ptr % n_ops) + m <= n_ops
                               else vals[:m])
            else:
                ks = new_keys[ins_ptr:ins_ptr + m]
                s.insert_batch(ks, vals[:len(ks)])
            ins_ptr += m
            done += m
        for _ in range(ratio[1]):
            if done >= n_ops:
                break
            m = min(BATCH, n_ops - done)
            if kind == "dll":
                s.pop_front_batch(m)
            elif kind == "bptree":
                ks = init_keys[del_ptr:del_ptr + m]
                if len(ks) == 0:
                    ks = new_keys[del_ptr - n_init:del_ptr - n_init + m]
                s.delete_batch(ks)
            else:
                ks = init_keys[del_ptr:del_ptr + m]
                if len(ks) == 0:
                    ks = new_keys[del_ptr - n_init:del_ptr - n_init + m]
                s.remove_batch(ks)
            del_ptr += m
            done += m
    wall = time.perf_counter() - t0
    d = a.stats.delta(base_stats)
    return Cell(kind, mode, workload, n_ops, wall,
                d.fence_ns * 1e-9, d.lines, d.bytes,
                saved_lines=d.saved_lines, dedup_rows=d.dedup_rows)


def fmt_table(rows: List[Dict], cols: List[str]) -> str:
    widths = [max(len(c), *(len(str(r[c])) for r in rows)) for c in cols]
    out = [" | ".join(c.ljust(w) for c, w in zip(cols, widths))]
    out.append("-|-".join("-" * w for w in widths))
    for r in rows:
        out.append(" | ".join(str(r[c]).ljust(w)
                              for c, w in zip(cols, widths)))
    return "\n".join(out)


def speedup(t_full: float, t_partly: float) -> str:
    return f"{(t_full / t_partly - 1) * 100:+.1f}%"
