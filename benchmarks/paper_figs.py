"""Paper-figure benchmarks (one function per table/figure).

fig1   — Cost of Persistence: append-only linked list, fraction of nodes
         flushed 0..100% -> near-linear execution-time growth.
fig5_6 — Insert-only workload: execution time + flush-time share for the
         three structures, fully vs partly persistent.
fig7_8 — Delete-only workload: same metrics.
fig9_11— Mixed insert:delete 1:1 / 2:1 / 4:1.
fig12  — Re-flushing the same cache line: unaligned sub-line flushes
         (8..64 B rows) vs 64 B-aligned rows.
recon  — §V-F reconstruction time vs persisted size.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.common import (MODES, SYNTH_LINE_NS, Cell, make_structure,
                               run_workload, speedup)
from repro.core.arena import open_arena
from repro.pstruct.bptree import BPTree
from repro.pstruct.dll import DoublyLinkedList
from repro.pstruct.hashmap import Hashmap


def fig1_cost_of_persistence(n: int = 60000) -> List[Dict]:
    """Append n nodes; flush only a fraction of them (paper Fig 1)."""
    rows = []
    vals = np.arange(n * 7, dtype=np.int64).reshape(n, 7)
    for frac in (0.0, 0.25, 0.5, 0.75, 1.0):
        a, d = make_structure("dll", "partly", n + 64)
        # monkey-style: append in batches, flushing only the first
        # frac-share of each batch's rows (persist_rows is the knob)
        t0 = time.perf_counter()
        for i in range(0, n, 1024):
            batch = vals[i:i + 1024]
            ids = d.append_batch(batch)   # flushes all by default
        base = time.perf_counter() - t0
        full_lines = a.stats.lines
        # re-run flushing only a fraction (drop flush calls manually)
        a2, d2 = make_structure("dll", "partly", n + 64)
        import repro.core.arena as ar
        t0 = time.perf_counter()
        for i in range(0, n, 1024):
            batch = vals[i:i + 1024]
            m = len(batch)
            keep = int(m * frac)
            ids = d2._alloc(m)
            d2.nodes.vol[ids, :7] = batch
            d2.nodes.vol[ids[:-1], 7] = ids[1:]
            d2.nodes.vol[ids[-1], 7] = -1
            if keep:
                d2.nodes.persist_rows(ids[:keep])
        dt = time.perf_counter() - t0
        rows.append({"flush_frac": frac, "wall_s": round(dt, 4),
                     "lines": a2.stats.lines,
                     "synth_flush_s": round(a2.stats.fence_ns * 1e-9, 4)})
    return rows


def _workload_fig(workload: str, n_init: int, n_ops: int) -> List[Dict]:
    rows = []
    cells: Dict[str, Dict[str, Cell]] = {}
    for kind in ("dll", "bptree", "hashmap"):
        cells[kind] = {}
        for mode in MODES:
            c = run_workload(kind, mode, workload, n_init, n_ops)
            cells[kind][mode] = c
    for kind in ("dll", "bptree", "hashmap"):
        full, partly = cells[kind]["full"], cells[kind]["partly"]
        rows.append({
            "structure": kind, "workload": workload,
            "full_s": round(full.wall_s, 4),
            "partly_s": round(partly.wall_s, 4),
            "speedup": speedup(full.wall_s, partly.wall_s),
            "full_flush%": f"{100 * full.flush_frac:.0f}%",
            "partly_flush%": f"{100 * partly.flush_frac:.0f}%",
            "full_lines": full.lines, "partly_lines": partly.lines,
            "line_save": f"{(1 - partly.lines / max(full.lines, 1)) * 100:.0f}%",
            # epoch write-set dedup (lines the pre-batching per-call
            # accounting would have charged on top of partly_lines)
            "batch_save_lines": partly.saved_lines,
            "dedup_rows": partly.dedup_rows,
        })
    return rows


def fig5_6_insert(n_init: int = 20000, n_ops: int = 50000) -> List[Dict]:
    return _workload_fig("insert", n_init, n_ops)


def fig7_8_delete(n_init: int = 60000, n_ops: int = 50000) -> List[Dict]:
    return _workload_fig("delete", n_init, n_ops)


def fig9_11_mixed(n_init: int = 30000, n_ops: int = 40000) -> List[Dict]:
    out = []
    for w in ("mixed_1_1", "mixed_2_1", "mixed_4_1"):
        out.extend(_workload_fig(w, n_init, n_ops))
    return out


def fig12_alignment(n: int = 40000) -> List[Dict]:
    """Flush the same logical stream with 8..64 B row sizes.  Sub-line rows
    re-touch the same 64 B line repeatedly — the paper's 61.3% slowdown."""
    rows = []
    for rowbytes in (8, 16, 32, 64):
        words = rowbytes // 8
        a = open_arena(None, {"r": (np.int64, (n, words))},
                       synth_line_ns=SYNTH_LINE_NS)
        r = a.regions["r"]
        t0 = time.perf_counter()
        for i in range(0, n, 1):
            r.vol[i, :] = i
            r.persist_rows(np.asarray([i]))
        dt = time.perf_counter() - t0
        rows.append({"row_bytes": rowbytes,
                     "wall_s": round(dt, 4),
                     "lines": a.stats.lines,
                     "bytes": a.stats.bytes,
                     "lines_per_64B": round(a.stats.lines * 64
                                            / max(a.stats.bytes, 1), 2)})
    base = rows[-1]["wall_s"]
    for r_ in rows:
        r_["slowdown_vs_64B"] = f"{(r_['wall_s'] / base - 1) * 100:+.1f}%"
    return rows


def reconstruction(sizes=(20000, 60000, 120000)) -> List[Dict]:
    """§V-F: rebuild time per structure vs persisted entry count."""
    rows = []
    rng = np.random.default_rng(0)
    for n in sizes:
        vals = rng.integers(0, 1 << 40, (n, 7)).astype(np.int64)
        keys = rng.permutation(n * 2)[:n].astype(np.int64)

        a, d = make_structure("dll", "partly", n + 64, synth_line_ns=0)
        for i in range(0, n, 8192):
            d.append_batch(vals[i:i + 8192])
        a.commit(); a.crash(); a.reopen()
        t0 = time.perf_counter(); d.reconstruct()
        t_dll = time.perf_counter() - t0

        a, t = make_structure("bptree", "partly", n + 64, synth_line_ns=0)
        for i in range(0, n, 8192):
            t.insert_batch(keys[i:i + 8192], vals[i:i + 8192])
        a.commit(); a.crash(); a.reopen()
        t0 = time.perf_counter(); t.reconstruct()
        t_bt = time.perf_counter() - t0

        a, h = make_structure("hashmap", "partly", n + 64, synth_line_ns=0)
        for i in range(0, n, 8192):
            h.insert_batch(keys[i:i + 8192], vals[i:i + 8192])
        a.commit(); a.crash(); a.reopen()
        t0 = time.perf_counter(); h.reconstruct()
        t_hm = time.perf_counter() - t0

        mb = n * 64 / 2 ** 20
        rows.append({"entries": n, "persisted_MiB": round(mb, 1),
                     "dll_s": round(t_dll, 4), "bptree_s": round(t_bt, 4),
                     "hashmap_s": round(t_hm, 4)})
    return rows
