"""Benchmark harness entry: ``PYTHONPATH=src python -m benchmarks.run``.

One section per paper table/figure (paper_figs), the framework-level
checkpoint-policy table (ckpt_bench), and the dry-run roofline summary
(reads results/dryrun.json if the sweep has been run).
"""
from __future__ import annotations

import argparse
import json
import os
import time

from benchmarks import ckpt_bench, paper_figs
from benchmarks.common import fmt_table


def section(title):
    print(f"\n{'=' * 72}\n{title}\n{'=' * 72}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller workloads (CI)")
    ap.add_argument("--out", default="results/bench.json")
    args = ap.parse_args()
    q = args.quick
    results = {}
    t0 = time.perf_counter()

    section("Fig 1 — Cost of Persistence (append-only DLL, flush fraction)")
    rows = paper_figs.fig1_cost_of_persistence(20000 if q else 60000)
    results["fig1"] = rows
    print(fmt_table(rows, list(rows[0])))
    print("(expect: near-linear wall-time growth in flushed lines)")

    section("Fig 5/6 — Insert-only: execution time + flush share")
    rows = paper_figs.fig5_6_insert(*((5000, 12000) if q else (20000, 50000)))
    results["fig5_6"] = rows
    print(fmt_table(rows, list(rows[0])))

    section("Fig 7/8 — Delete-only")
    rows = paper_figs.fig7_8_delete(*((15000, 12000) if q else (60000, 50000)))
    results["fig7_8"] = rows
    print(fmt_table(rows, list(rows[0])))

    section("Fig 9-11 — Mixed insert:delete (1:1, 2:1, 4:1)")
    rows = paper_figs.fig9_11_mixed(*((8000, 10000) if q else (30000, 40000)))
    results["fig9_11"] = rows
    print(fmt_table(rows, list(rows[0])))

    section("Fig 12 — Re-flushing the same cache line (alignment)")
    rows = paper_figs.fig12_alignment(8000 if q else 40000)
    results["fig12"] = rows
    print(fmt_table(rows, list(rows[0])))

    section("§V-F — Reconstruction time vs persisted size")
    rows = paper_figs.reconstruction((5000, 20000) if q
                                     else (20000, 60000, 120000))
    results["reconstruction"] = rows
    print(fmt_table(rows, list(rows[0])))

    section("Checkpoint policies on a TrainState (framework level)")
    rows = ckpt_bench.ckpt_policies()
    results["ckpt_policies"] = rows
    print(fmt_table(rows, list(rows[0])))

    section("Restore + reconstruction split")
    rows = ckpt_bench.restore_reconstruct()
    results["restore"] = rows
    print(fmt_table(rows, list(rows[0])))

    dry = "results/dryrun.json"
    if os.path.exists(dry):
        section("Dry-run roofline summary (from results/dryrun.json)")
        with open(dry) as f:
            cells = json.load(f)
        rows = []
        for r in cells:
            if r.get("status") != "ok":
                continue
            rows.append({
                "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
                "hbm_GiB": round(r["memory"]["total_hbm_bytes"] / 2**30, 2),
                "fits": "Y" if r["memory"]["fits_v5e_16g"] else "N",
                "dominant": r["terms"]["dominant"],
                "step_s": round(r["terms"]["step_s"], 3),
                "mfu": round(r["flops"]["mfu_at_roofline"], 4),
            })
        results["dryrun_summary"] = rows
        print(fmt_table(rows, list(rows[0])))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"\nall benchmarks done in {time.perf_counter() - t0:.1f}s "
          f"-> {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
