"""Framework-level persistence benchmarks (beyond-paper table).

Applies the paper's policy spectrum to a real TrainState:
fully / partly / partly+q8 / partly+drop / partly+incremental —
bytes persisted per checkpoint and save wall time.
"""
from __future__ import annotations

import shutil
import tempfile
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.ckpt.manager import CheckpointManager
from repro.configs import base, registry
from repro.core import policy as pol
from repro.models.model import build
from repro.optim.adamw import AdamWConfig, init_moments
from repro.train.state import new_state

POLICIES = [
    ("fully", pol.FULLY_PERSISTENT, False),
    ("partly", pol.PARTLY_PERSISTENT, False),
    ("partly+q8", pol.PARTLY_Q8, False),
    ("partly+drop", pol.PARTLY_DROP, False),
    ("partly+incr", pol.PARTLY_PERSISTENT, True),
]


def ckpt_policies(arch: str = "llama3.2-3b") -> List[Dict]:
    cfg = base.reduced(registry.get(arch))
    # widen the reduced config so checkpoint sizes are meaningful (~40MB)
    import dataclasses
    cfg = dataclasses.replace(cfg, d_model=512, n_layers=4, d_ff=1024,
                              vocab=8192)
    model = build(cfg, compute_dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0))
    mu, nu = init_moments(params, AdamWConfig())
    mu = jax.tree.map(lambda x: x + 0.01, mu)   # non-trivial moments
    st = new_state(params, mu, nu, seed=0)
    st = st._replace(rng=jax.random.fold_in(jax.random.PRNGKey(0), 0))

    rows = []
    for name, policy, incr in POLICIES:
        d = tempfile.mkdtemp(prefix=f"ckpt_{name.replace('+','_')}_")
        try:
            mgr = CheckpointManager(d, policy, incremental=incr)
            t0 = time.perf_counter()
            rep = mgr.save(st)
            t_first = time.perf_counter() - t0
            # second save (params unchanged): the incremental win
            t0 = time.perf_counter()
            rep2 = mgr.save(st)
            t_second = time.perf_counter() - t0
            rows.append({
                "policy": name,
                "bytes_1st": rep.bytes_written,
                "bytes_2nd": rep2.bytes_written,
                "skipped_derivable": rep.bytes_skipped_derivable,
                "save_s_1st": round(t_first, 4),
                "save_s_2nd": round(t_second, 4),
            })
        finally:
            shutil.rmtree(d, ignore_errors=True)
    base_b = rows[0]["bytes_1st"]
    for r in rows:
        r["vs_fully"] = f"{(1 - r['bytes_1st'] / base_b) * 100:.1f}% fewer"
    return rows


def restore_reconstruct(arch: str = "llama3.2-3b") -> List[Dict]:
    """Restore-time split: read-persisted vs reconstruct-derivable."""
    cfg = base.reduced(registry.get(arch))
    model = build(cfg, compute_dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0))
    mu, nu = init_moments(params, AdamWConfig())
    st = new_state(params, mu, nu, seed=0)
    st = st._replace(rng=jax.random.fold_in(jax.random.PRNGKey(0), 0))
    spec = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), st)
    rows = []
    for name, policy, _ in POLICIES[:3]:
        d = tempfile.mkdtemp(prefix="ckpt_r_")
        try:
            mgr = CheckpointManager(d, policy)
            mgr.save(st)
            t0 = time.perf_counter()
            got = mgr.restore(spec)
            rows.append({"policy": name,
                         "restore_s": round(time.perf_counter() - t0, 4),
                         "leaves": len(jax.tree.leaves(got))})
        finally:
            shutil.rmtree(d, ignore_errors=True)
    return rows
