"""recovery_bench — §V-F reconstruction-time benchmarks.

The paper's bargain is two-sided: persist fewer fields at write time
(BENCH_flush.json measures that side), pay to *recreate* them after a
crash.  This bench measures the pay side, through the unified recovery
subsystem (core/recovery.py):

* structure recovery time vs size, partly- vs fully-persistent, for all
  three paper structures — each row also carries the write-side line
  count of building the structure, so partly's write saving can be read
  against its reconstruction cost (the §V-F tradeoff curve);
* serving-engine recovery, staged (request hashmap -> LRU pages ->
  batched slab scan + grouped re-prefill), via the RecoveryReport —
  including time-to-first-token-after-crash under slot-granular early
  admission, and a serial-vs-concurrent recovery pass;
* concurrent vs serial recovery of a mixed 3-structure arena (the
  independent stages of one topological level in a thread pool) with
  the report's wall/critical-path/summed-stage triple;
* checkpoint-restore APPROXIMABLE warmup: inline vs background
  (§V-F-style warmup-time metric next to reconstruction time);
* the vectorized chain-order primitives vs the seed's scalar NEXT walk
  at >= 100k entries — a contraction-vs-doubling-vs-scalar sweep per
  size (the 10**6 point is the jump-table cache crossover that
  contraction list ranking exists to clear; the full-mode gate asserts
  the auto path beats scalar at EVERY measured size, and all three
  orders are asserted bit-identical on every chain).

Emits BENCH_recovery.json next to the repo root (CI artifact).

Run: ``PYTHONPATH=src python -m benchmarks.recovery_bench [--quick]``
``--chain-crossover`` runs ONLY the 10**6 chain point with quick-grade
repeats and fails on speedup <= 1.0 — the CI step that keeps the
crossover regression from silently returning.
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time
from typing import Any, Dict, List

import numpy as np

from benchmarks.common import arena_fields, fmt_table, make_structure
from repro.core.arena import open_arena
from repro.core.recovery import RecoveryManager, chain_method, chain_order
from repro.pstruct.bptree import BPTree
from repro.pstruct.dll import DoublyLinkedList
from repro.pstruct.hashmap import Hashmap

MODES = ("full", "partly")
STRUCTS = ("dll", "bptree", "hashmap")
RECONSTRUCTOR = {"dll": "pstruct.dll", "bptree": "pstruct.bptree",
                 "hashmap": "pstruct.hashmap"}


# ---------------------------------------------------------- structures

def _build(kind: str, mode: str, n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    a, s = make_structure(kind, mode, n + 1024, synth_line_ns=0)
    vals = rng.integers(0, 1 << 40, (4096, 7)).astype(np.int64)
    keys = rng.permutation(2 * n).astype(np.int64)
    for i in range(0, n, 4096):
        m = min(4096, n - i)
        if kind == "dll":
            s.append_batch(vals[:m])
        else:
            s.insert_batch(keys[i:i + m], vals[:m])
    a.commit()
    return a, s


def _verify(kind: str, s, n: int) -> None:
    if kind == "dll":
        assert s.count == n, (s.count, n)
    elif kind == "bptree":
        s.check_invariants()
    else:
        assert s.size == n, (s.size, n)


def structure_rows(sizes: List[int]) -> List[Dict]:
    rows = []
    for kind in STRUCTS:
        for n in sizes:
            per_mode = {}
            for mode in MODES:
                a, s = _build(kind, mode, n)
                build_lines = a.stats.lines
                a.crash()
                mgr = RecoveryManager(a)
                mgr.add(kind, RECONSTRUCTOR[kind], s)
                rep = mgr.recover()
                _verify(kind, s, n)
                row = {"structure": kind, "mode": mode, "n": n,
                       "build_lines": build_lines,
                       "recover_s": round(rep.total_seconds, 6),
                       "reopen_s": round(rep.seconds("reopen"), 6),
                       "rebuild_s": round(rep.seconds(kind), 6),
                       **arena_fields(a)}
                per_mode[mode] = row
                rows.append(row)
            # the §V-F tradeoff, read off directly: write lines saved by
            # partly vs the recovery time it costs
            full, partly = per_mode["full"], per_mode["partly"]
            saved = full["build_lines"] - partly["build_lines"]
            partly["write_lines_saved_vs_full"] = (
                f"{100 * saved / max(full['build_lines'], 1):.1f}%")
            partly["recover_cost_vs_full"] = (
                f"{partly['recover_s'] / max(full['recover_s'], 1e-9):.2f}x")
    return rows


# -------------------------------------------- concurrent vs serial

def _mixed_build(n: int, mode: str = "partly", seed: int = 0,
                 n_shards: int = 1, synth_line_ns: float = 0.0):
    """One arena holding all three structures, n entries each — the
    three rebuild stages are mutually independent (one topological
    level), so they are the concurrency unit recover(concurrency=N)
    exploits.  ``n_shards>1`` shards the substrate (DESIGN.md §7); the
    per-structure region declarations let the dependency-counter
    scheduler start each rebuild the moment ITS regions load."""
    cap = n + 1024
    layout = {}
    layout.update(DoublyLinkedList.layout(cap, mode, name="dll"))
    layout.update(BPTree.layout(max(64, cap // 4), cap, mode, name="bt"))
    layout.update(Hashmap.layout(2 * cap, mode, name="hm"))
    a = open_arena(None, layout, n_shards=n_shards,
                   synth_line_ns=synth_line_ns)
    d = DoublyLinkedList(a, cap, mode, name="dll")
    t = BPTree(a, max(64, cap // 4), cap, mode, name="bt")
    h = Hashmap(a, 2 * cap, mode, name="hm")
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, 1 << 40, (4096, 7)).astype(np.int64)
    keys = rng.permutation(4 * n).astype(np.int64)
    for i in range(0, n, 4096):
        m = min(4096, n - i)
        d.append_batch(vals[:m])
        t.insert_batch(keys[i:i + m], vals[:m])
        h.insert_batch(keys[i:i + m] + 4 * n, vals[:m])
    a.commit()
    mgr = RecoveryManager(a)
    mgr.add("dll", "pstruct.dll", d,
            regions=("dll.nodes", "dll.header"))
    mgr.add("bt", "pstruct.bptree", t,
            regions=("bt.nodes", "bt.records", "bt.header"))
    mgr.add("hm", "pstruct.hashmap", h,
            regions=("hm.entries", "hm.header"))
    return a, mgr


def concurrent_rows(sizes: List[int], concurrency: int = 0,
                    repeats: int = 7) -> List[Dict]:
    """Serial vs concurrent recovery of the mixed arena.  Reconstruction
    is pure, so the same arena can crash+recover repeatedly; best-of
    repeats with serial/concurrent passes interleaved (so cache warm-up
    and scheduler noise hit both alike) filters the jitter of a small
    shared host."""
    # pool sized to the host: oversubscribing a small machine (3 worker
    # threads on 2 cores) trades the concurrency win back for GIL and
    # scheduler thrash
    if concurrency <= 0:
        import os
        concurrency = max(2, min(3, os.cpu_count() or 2))
    rows = []
    for n in sizes:
        a, mgr = _mixed_build(n)
        best = {}
        for _ in range(repeats):
            for c in (1, concurrency):
                a.crash()
                rep = mgr.recover(concurrency=c)
                if c not in best or rep.total_seconds < best[c].total_seconds:
                    best[c] = rep
        ser, con = best[1], best[concurrency]
        rows.append({
            "n_per_structure": n, "structures": 3,
            "concurrency": concurrency, **arena_fields(a),
            "serial_wall_ms": round(ser.wall_ms, 3),
            "concurrent_wall_ms": round(con.wall_ms, 3),
            "stage_sum_ms": round(ser.total_ms, 3),
            "critical_path_ms": round(ser.critical_path_ms, 3),
            "speedup": round(ser.wall_ms / max(con.wall_ms, 1e-9), 2)})
    return rows


# ---------------------------------------------- sharded recovery sweep

def sharded_recovery_rows(sizes: List[int], repeats: int = 7
                          ) -> List[Dict]:
    """Sharded vs single-arena recovery of the mixed 3-structure arena
    at ``concurrency=4`` (DESIGN.md §7), in the repo's standard
    synthetic-PM regime (250 ns/line writes — benchmarks/common.py —
    and 250 ns per 256 B media grain on reload): the single arena pays
    the reload stall serially inside its monolithic reopen; the sharded
    arena overlaps per-shard reload stalls in the pool AND starts each
    structure's rebuild the moment its own regions land (per-region
    load stages under the dependency-counter scheduler).  The
    n_shards=1 row is the plain single Arena — the PR 3 concurrent
    path, continued.

    Without the latency model this 2-core host is rebuild-CPU-bound —
    both cores saturate either way, so sharding's block-copy loads and
    the scheduler overlap roughly cancel (within noise; the untouched
    ``concurrent_vs_serial`` rows carry that regime).  Interleaved
    best-of-``repeats``; the sharded pass's stage timeline (ready_at /
    t_start / t_end, queue wait split from run time) rides along."""
    out: List[Dict] = []
    for n in sizes:
        built = {ns: _mixed_build(n, n_shards=ns, synth_line_ns=250.0)
                 for ns in (1, 4)}
        best: Dict[int, Any] = {}
        for _ in range(repeats):
            for ns, (a, mgr) in built.items():
                a.crash()
                rep = mgr.recover(concurrency=4)
                if (ns not in best
                        or rep.total_seconds < best[ns].total_seconds):
                    best[ns] = rep
        for a, _ in built.values():
            a.close()    # release shard pools between sweep sizes
        out.append({
            "n_per_structure": n, "regime": "pm", "concurrency": 4,
            # the sharded contender's substrate; the single-arena side
            # differs only in n_shards=1
            **arena_fields(built[4][0]),
            "single_wall_ms": round(best[1].wall_ms, 3),
            "sharded_wall_ms": round(best[4].wall_ms, 3),
            "speedup": round(best[1].wall_ms
                             / max(best[4].wall_ms, 1e-9), 2),
            "sharded_stages": [
                {"name": s.name,
                 "ready_at_ms": round(s.ready_at * 1e3, 3),
                 "t_start_ms": round(s.t_start * 1e3, 3),
                 "t_end_ms": round(s.t_end * 1e3, 3),
                 "queue_wait_ms": round(s.queue_wait * 1e3, 3)}
                for s in best[4].stages]})
    return out


# ------------------------------------------------------ serving engine

def engine_report(n_requests: int, steps: int) -> Dict:
    import jax
    import jax.numpy as jnp

    from repro.configs import base, registry
    from repro.models.model import build
    from repro.serve.engine import EngineConfig, ServingEngine

    model = build(base.reduced(registry.get("llama3.2-3b")),
                  compute_dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0))
    ec = EngineConfig(max_batch=n_requests, s_max=32,
                      max_requests=4 * n_requests)
    eng = ServingEngine(model, params, ec)
    rng = np.random.default_rng(0)
    for rid in range(n_requests):
        plen = int(rng.integers(3, 9))
        eng.add_request(100 + rid,
                        rng.integers(1, model.cfg.vocab, plen).astype(np.int64))
    for _ in range(steps):
        eng.step()

    # cold pass compiles the grouped-prefill shapes; measured passes warm
    eng.crash()
    eng.recover()

    # warm serial + warm concurrent passes (reconstruction is pure, so
    # the same crash replays)
    eng.crash()
    sec = eng.recover()
    rep = eng.last_recovery
    eng.crash()
    sec_c = eng.recover(concurrency=4)
    rep_c = eng.last_recovery

    # TTFT-after-crash under early admission, measured LAST: the
    # callback's decode step appends a real token (advancing the
    # persisted lengths, hence future prefill shapes), so it must not
    # run before the warm passes above
    first: Dict[str, float] = {}

    def on_ready(slots, tlen, admitted_s):
        if "ttft_s" not in first:
            out = eng.step()           # decodes ready slots only
            first["ttft_s"] = time.perf_counter() - t0
            first["admission_s"] = admitted_s
            first["tokens"] = len(out)

    eng.crash()
    eng.on_slot_ready = on_ready
    t0 = time.perf_counter()
    eng.recover()
    eng.on_slot_ready = None
    return {"requests": n_requests, "decode_steps": steps,
            **arena_fields(eng.arena, arena_bytes=int(
                sum(r.nbytes for r in eng.arena.regions.values())
                + sum(r.nbytes
                      for r in eng.paging.arena.regions.values()))),
            "total_s": round(sec, 6),
            "concurrent_total_s": round(sec_c, 6),
            # reported as measured: pooled prefill groups pay off only
            # when the model calls leave cores idle — XLA's intra-op
            # threads already saturate small hosts, so serial can win
            # here (the honest analogue of the chain-order crossover)
            "concurrency_note": "prefill-group pooling is core-bound; "
                                "XLA saturates small hosts",
            "critical_path_ms": round(rep_c.critical_path_ms, 3),
            "ttft_after_crash_s": round(first.get("ttft_s", sec), 6),
            "first_admission_s": round(first.get("admission_s", 0.0), 6),
            "tokens_at_first_admission": int(first.get("tokens", 0)),
            "stages": {s.name: round(s.seconds, 6) for s in rep.stages},
            "prefill_groups": rep.stage("engine").detail["prefill_groups"]}


# --------------------------------------- snapshot TTFT SLO (§10)

def snapshot_component_rows(sizes: List[int], live_frac: float = 0.75,
                            repeats: int = 2) -> List[Dict]:
    """Allocator-level mechanism rows for the SLO gate: the paged-KV
    LRU at growing pool size, ~75% pages live, snapshot on vs off.  The
    lru stage is the quantity the snapshot flattens — adoption costs
    ONE vectorized verify gather over the live chain instead of the
    log-round contraction rank, so ``lru_s`` stays near-flat while the
    fallback path grows with the pool."""
    from repro.serve.kvcache import PagedAllocator, PagedConfig
    rows = []
    for snap in (True, False):
        for n_pages in sizes:
            pa = PagedAllocator(PagedConfig(n_pages=n_pages,
                                            snapshot=snap))
            live = int(n_pages * live_frac)
            rid = 0
            for i in range(0, live, 4096):
                pa.alloc(rid, min(4096, live - i))
                rid += 1
            best = None
            for _ in range(repeats):
                pa.arena.crash()
                t = pa.recover()
                if best is None or t < best[0]:
                    best = (t, pa.last_recovery)
            det = best[1].stage("lru").detail
            rows.append({"n_pages": n_pages, "live_pages": live,
                         "snapshot": snap,
                         "recover_s": round(best[0], 6),
                         "lru_s": round(best[1].seconds("lru"), 6),
                         "lru_chain": det.get("chain"),
                         "lru_replayed": det.get("replayed"),
                         **arena_fields(pa.arena)})
            pa.arena.close()
    return rows


def snapshot_slo_report(factor: int = 10, repeats: int = 8,
                        base_pages: int = 4096) -> Dict:
    """The ``--snapshot-slo`` CI gate (DESIGN.md §10): paged-KV
    TTFT-after-crash must stay within 1.2x of the small-arena baseline
    when the page pool grows ``factor``x with snapshots ON.  The pool
    capacity is what grows (EngineConfig.n_pages override); the live
    request working set is fixed, so a recovery that scales with the
    SUFFIX stays flat and one that ranks the whole pool does not.
    Snapshot-off rows ride along ungated (they carry the fallback
    growth the gate exists to keep off the admission path)."""
    import jax
    import jax.numpy as jnp

    from repro.configs import base, registry
    from repro.models.model import build
    from repro.serve.engine import EngineConfig, ServingEngine

    model = build(base.reduced(registry.get("llama3.2-3b")),
                  compute_dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0))

    def ttft_row(n_pages: int, snap: bool) -> Dict:
        ec = EngineConfig(max_batch=4, s_max=32, max_requests=16,
                          n_pages=n_pages, snapshot=snap)
        eng = ServingEngine(model, params, ec)
        rng = np.random.default_rng(0)
        for rid in range(4):
            eng.add_request(100 + rid,
                            rng.integers(1, model.cfg.vocab,
                                         24).astype(np.int64))
        for _ in range(2):
            eng.step()
        eng.crash()
        eng.recover()                # warm pass compiles prefill shapes
        # TTFT decomposed so each term is a stable best-of: time to
        # first re-admission (recovery is pure, so crash+recover
        # repeats) + one decode step on the recovered engine (fixed
        # model work, arena-size independent — measured apart so its
        # dispatch jitter is common-mode across pool sizes)
        admit = None
        for _ in range(repeats):
            first: Dict[str, float] = {}

            def on_ready(slots, tlen, admitted_s):
                first.setdefault("t", time.perf_counter() - t0)

            eng.crash()
            eng.on_slot_ready = on_ready
            t0 = time.perf_counter()
            sec = eng.recover()
            eng.on_slot_ready = None
            t = first.get("t", sec)
            admit = t if admit is None else min(admit, t)
        decode = min(_timed(eng.step) for _ in range(5))
        det = eng.last_recovery.stage("lru").detail
        row = {"n_pages": n_pages, "snapshot": snap,
               "first_admission_s": round(admit, 6),
               "first_decode_s": round(decode, 6),
               "ttft_after_crash_s": round(admit + decode, 6),
               "lru_s": round(eng.last_recovery.seconds("lru"), 6),
               "lru_chain": det.get("chain"),
               "lru_replayed": det.get("replayed"),
               **arena_fields(eng.paging.arena)}
        eng.arena.close()
        eng.paging.arena.close()
        return row

    engine_rows = [ttft_row(p, s)
                   for s in (True, False)
                   for p in (base_pages, base_pages * factor)]
    by = {(r["snapshot"], r["n_pages"]): r for r in engine_rows}
    r_on = (by[(True, base_pages * factor)]["ttft_after_crash_s"]
            / max(by[(True, base_pages)]["ttft_after_crash_s"], 1e-9))
    r_off = (by[(False, base_pages * factor)]["ttft_after_crash_s"]
             / max(by[(False, base_pages)]["ttft_after_crash_s"], 1e-9))
    return {"factor": factor, "base_pages": base_pages,
            "slo": 1.2,
            "ttft_ratio_snapshot_on": round(r_on, 3),
            "ttft_ratio_snapshot_off": round(r_off, 3),
            "engine": engine_rows,
            "component": snapshot_component_rows(
                [base_pages, base_pages * factor])}


# --------------------------------------- paged-region SLO (§12)

def _alloc_fingerprint(pa) -> tuple:
    """Full volatile state of a recovered PagedAllocator, as plain
    Python — LRU order, page ownership, free stack.  Bit-comparable
    across paged/unpaged backends."""
    return (pa.lru.order().tolist(), pa.owner.tolist(),
            sorted(pa.pages_free.tolist()))


def paged_budget_report(factor: int = 10, cache_blocks: int = 64,
                        block_bytes: int = 4096) -> Dict:
    """--paged-slo component A (DESIGN.md §12): a file-backed paged-KV
    pool whose node slab is ``factor``x the block-cache budget, built
    ~75% live, crashed, recovered demand-paged, then served.  Gated:

    * peak resident block bytes across recover + serve stay within the
      cache capacity plus 16 blocks of admit-transient slack (the
      larger-than-RAM claim, measured — not assumed);
    * the recovered state is bit-identical BOTH to the pre-crash state
      and to an UNPAGED allocator reopening the same backing file
      (paging is volatile-only: it must never change recovered bytes);
    * zero spills — the gated path runs fully block-routed."""
    import os
    from repro.serve.kvcache import PagedAllocator, PagedConfig
    rows_per_block = block_bytes // 64   # partly-mode DLL node row: 64 B
    n_pages = factor * cache_blocks * rows_per_block
    budget_bytes = (cache_blocks + 16) * block_bytes
    with tempfile.TemporaryDirectory() as tdir:
        path = os.path.join(tdir, "pool.bin")
        pa = PagedAllocator(PagedConfig(n_pages=n_pages, paged=True,
                                        block_bytes=block_bytes,
                                        cache_blocks=cache_blocks),
                            path=path)
        live = int(n_pages * 0.75)
        rid = 0
        for i in range(0, live, 2048):
            pa.alloc(rid, min(2048, live - i))
            rid += 1
        for r in range(0, rid, 3):      # fragment the pool
            pa.free_request(r)
        fp0 = _alloc_fingerprint(pa)
        pa.arena.crash()
        pa.arena.cache.reset_peak()     # phase-scoped: recover + serve
        t0 = time.perf_counter()
        pa.recover()
        recover_s = time.perf_counter() - t0
        fp_rec = _alloc_fingerprint(pa)
        rep = pa.last_recovery
        # unpaged reopen of the SAME backing file, before serving
        # mutates it
        pu = PagedAllocator(PagedConfig(n_pages=n_pages, paged=False),
                            path=path)
        pu.recover()
        fp_unpaged = _alloc_fingerprint(pu)
        pu.arena.close()
        # serve on the recovered pool: allocation churn faults and
        # dirties blocks under the same residency budget
        for k in range(5):
            pa.alloc(1_000_000 + k, 128)
        for k in range(0, 5, 2):
            pa.free_request(1_000_000 + k)
        cache = pa.arena.cache
        row = {"factor": factor, "n_pages": n_pages,
               "built_live_pages": live,
               "recover_s": round(recover_s, 6),
               "budget_bytes": int(budget_bytes),
               "capacity_bytes": int(cache.capacity_bytes),
               "faults": int(cache.faults), "hits": int(cache.hits),
               "evictions": int(cache.evictions),
               "spills": int(cache.spills),
               "over_budget": int(cache.over_budget),
               "block_faults_per_stage": {
                   s.name: s.detail.get("block_faults")
                   for s in rep.stages if s.name != "reopen"},
               "fingerprint_match_precrash": fp_rec == fp0,
               "fingerprint_match_unpaged": fp_rec == fp_unpaged,
               **arena_fields(pa.arena)}
        pa.arena.close()
    return row


def paged_slo_report(factor: int = 10, repeats: int = 8) -> Dict:
    """The ``--paged-slo`` CI gate (DESIGN.md §12).  Component A
    (``paged_budget_report``) proves the larger-than-RAM budget and
    paged-vs-unpaged bit-identity at ``factor``x the cache.  Component
    B re-measures the --snapshot-slo engine TTFT-after-crash (first
    slot re-admission + one decode) with the paged-KV substrate paged
    vs unpaged at CACHE-FITTING scale: demand paging may not tax the
    admission path by more than 1.5x when the working set fits."""
    import jax
    import jax.numpy as jnp

    from repro.configs import base, registry
    from repro.models.model import build
    from repro.serve.engine import EngineConfig, ServingEngine

    budget = paged_budget_report(factor=factor)

    model = build(base.reduced(registry.get("llama3.2-3b")),
                  compute_dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0))

    def ttft_row(paged: bool) -> Dict:
        ec = EngineConfig(max_batch=4, s_max=32, max_requests=16,
                          n_pages=4096, paged=paged)
        eng = ServingEngine(model, params, ec)
        rng = np.random.default_rng(0)
        for rid in range(4):
            eng.add_request(100 + rid,
                            rng.integers(1, model.cfg.vocab,
                                         24).astype(np.int64))
        for _ in range(2):
            eng.step()
        eng.crash()
        eng.recover()                # warm pass compiles prefill shapes
        admit = None
        for _ in range(repeats):
            first: Dict[str, float] = {}

            def on_ready(slots, tlen, admitted_s):
                first.setdefault("t", time.perf_counter() - t0)

            eng.crash()
            eng.on_slot_ready = on_ready
            t0 = time.perf_counter()
            sec = eng.recover()
            eng.on_slot_ready = None
            t = first.get("t", sec)
            admit = t if admit is None else min(admit, t)
        decode = min(_timed(eng.step) for _ in range(5))
        row = {"paged": paged, "n_pages": 4096,
               "first_admission_s": round(admit, 6),
               "first_decode_s": round(decode, 6),
               "ttft_after_crash_s": round(admit + decode, 6),
               **arena_fields(eng.paging.arena)}
        eng.arena.close()
        eng.paging.arena.close()
        return row

    engine_rows = [ttft_row(p) for p in (False, True)]
    ratio = (engine_rows[1]["ttft_after_crash_s"]
             / max(engine_rows[0]["ttft_after_crash_s"], 1e-9))
    return {"factor": factor, "slo_ttft": 1.5,
            "budget": budget,
            "ttft": engine_rows,
            "ttft_ratio_paged": round(ratio, 3)}


# ------------------------------------- request journal (DESIGN.md §11)

def journal_report(n_ops: int = 64, repeats: int = 3) -> Dict:
    """Exactly-once journal cost, both sides: the write-side overhead
    (journal ring lines per epoch, isolated in
    ``FlushStats.journal_lines``) and the recovery-side cost
    (TTFT-after-crash for the feature store, journal on vs off).  The
    line counts are deterministic, so the <=1-line-per-epoch bound and
    the journal-off data-traffic identity gate here without flake;
    the timing columns are informational."""
    from repro.serve.feature_store import FeatureConfig, FeatureStore

    rng = np.random.default_rng(0)
    ops = []
    for rid in range(n_ops):
        keys = rng.choice(256, size=8, replace=False).astype(np.int64)
        deltas = rng.integers(-9, 10, (8, 4)).astype(np.int64)
        ops.append((rid, keys, deltas))

    rows: List[Dict] = []
    for journal in (True, False):
        cfg = FeatureConfig(n_keys=256, dim=4, n_samples=8 * n_ops + 64,
                            journal=journal)
        fs = FeatureStore(cfg)
        s0 = fs.arena.stats.snapshot()
        for op in ops:
            assert fs.apply(*op)
        d = fs.arena.stats.delta(s0)
        best = float("inf")
        for _ in range(repeats):
            fs.crash()
            t0 = time.perf_counter()
            fs.recover(concurrency=2)
            best = min(best, time.perf_counter() - t0)
        rows.append({"journal": journal, "n_ops": n_ops,
                     "recover_s": round(best, 6),
                     "epochs": int(d.epochs),
                     "lines": int(d.lines),
                     "lines_per_epoch": round(d.lines / d.epochs, 3),
                     "journal_lines": int(d.journal_lines),
                     "journal_lines_per_epoch":
                         round(d.journal_lines / d.epochs, 3),
                     **arena_fields(fs.arena)})
    on, off = rows
    # the piggybacked HEAD/TAIL ride the host header line: overhead is
    # exactly <= 1 ring line per epoch, and the data ledgers match
    assert 0 < on["journal_lines"] <= on["epochs"], on
    assert off["journal_lines"] == 0, off
    assert on["lines"] == off["lines"], (on, off)
    return {"rows": rows,
            "recover_overhead_x": round(
                rows[0]["recover_s"] / max(rows[1]["recover_s"], 1e-9), 3)}


# ----------------------------------- integrity overhead (DESIGN.md §13)

def integrity_overhead_report(n_ops: int = 30000,
                              repeats: int = 7) -> Dict:
    """The ``--integrity-overhead`` CI gate: checksum sidecars ride the
    epoch drain, so their cost must stay in the noise of the flush
    itself.  Two ledgers per side (integrity on / off), best-of
    ``repeats`` with the sides interleaved:

    * deterministic: DATA lines/bytes are bit-identical across the two
      sides (``FlushStats.lines`` never counts sidecar traffic — the
      sidecar ledger is ``integrity_lines``, > 0 on, == 0 off);
    * timed: the drain's PERSISTED-line throughput (data + snapshot +
      journal + sidecar — every line the medium receives) with
      integrity on must stay >= 0.95x the integrity-off side (asserted
      by the CLI gate).  The run uses the suite's standard synthetic
      per-line flush latency (``SYNTH_LINE_NS``, same model as every
      other cell — sidecar lines are real flushes and pay it too) and
      the flush-unit drain regime (1024-row epochs, the same scale the
      builders use), so the ratio compares checksum compute against
      the flush work it actually rides with.  The sidecar adds ~1 line
      per 8 data lines on this layout; gating lines/s over the lines
      actually persisted asserts the per-line cost of the drain is
      preserved — the "don't slow the drain" claim.  The data-only
      ratio (which additionally charges integrity for its extra lines)
      is reported alongside, ungated.

    A scrub pass over the final committed arena rides along
    (informational: full-arena verify cost)."""
    rng = np.random.default_rng(0)
    vals = rng.integers(0, 1 << 40, (4096, 7)).astype(np.int64)
    keys = rng.permutation(2 * n_ops).astype(np.int64)

    def one_pass(integ: bool) -> Dict:
        a, s = make_structure("hashmap", "partly", n_ops + 1024,
                              integrity=integ)
        s0 = a.stats.snapshot()
        t0 = time.perf_counter()
        for i in range(0, n_ops, 1024):
            m = min(1024, n_ops - i)
            with a.epoch():
                s.insert_batch(keys[i:i + m], vals[:m])
        a.commit()
        wall = time.perf_counter() - t0
        d = a.stats.delta(s0)
        t0 = time.perf_counter()
        bad = a.scrub()
        scrub_s = time.perf_counter() - t0
        assert bad == {}, bad
        persisted = int(d.lines + d.snapshot_lines + d.journal_lines
                        + d.integrity_lines)
        row = {"integrity": integ, "n_ops": n_ops,
               "flush_wall_s": round(wall, 6),
               "lines": int(d.lines), "bytes": int(d.bytes),
               "integrity_lines": int(d.integrity_lines),
               "persisted_lines": persisted,
               "lines_per_s": round(persisted / max(wall, 1e-9), 1),
               "data_lines_per_s": round(d.lines / max(wall, 1e-9), 1),
               "scrub_s": round(scrub_s, 6),
               **arena_fields(a)}
        a.close()
        return row

    best: Dict[bool, Dict] = {}
    for _ in range(repeats):
        for integ in (False, True):
            r = one_pass(integ)
            if (integ not in best
                    or r["flush_wall_s"] < best[integ]["flush_wall_s"]):
                best[integ] = r
    on, off = best[True], best[False]
    # sidecar traffic must never leak into the data ledger
    assert on["lines"] == off["lines"], (on, off)
    assert on["bytes"] == off["bytes"], (on, off)
    assert on["integrity_lines"] > 0, on
    assert off["integrity_lines"] == 0, off
    return {"rows": [on, off],
            "lines_per_s_ratio": round(
                on["lines_per_s"] / max(off["lines_per_s"], 1e-9), 4),
            "data_lines_per_s_ratio": round(
                on["data_lines_per_s"]
                / max(off["data_lines_per_s"], 1e-9), 4)}


# ------------------------------------------------ ckpt warmup (§V-F)

def ckpt_report() -> Dict:
    """APPROXIMABLE warmup time next to reconstruction time: restore a
    dropped-moments checkpoint inline vs with background warmup."""
    import jax
    import jax.numpy as jnp

    from repro.ckpt.manager import CheckpointManager
    from repro.core import policy as pol
    from repro.train.state import new_state

    k = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(k, (1024, 512)),
              "b": jnp.zeros((512,))}
    mu = jax.tree.map(jnp.zeros_like, params)
    nu = jax.tree.map(jnp.zeros_like, params)
    st = new_state(params, mu, nu, seed=7)
    spec = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        st)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, pol.PARTLY_DROP)
        mgr.save(st)
        mgr.restore(spec)                        # warm the code path
        t0 = time.perf_counter()
        mgr.restore(spec)
        inline_s = time.perf_counter() - t0
        rep_in = mgr.last_recovery
        t0 = time.perf_counter()
        got = mgr.restore(spec, warmup="background")
        background_s = time.perf_counter() - t0  # state usable here
        mgr.finish_warmup(got)
        rep_bg = mgr.last_recovery
    return {"approx_leaves": rep_in.stage("rewarm_approximable").detail[
                "leaves"],
            **arena_fields(arena_bytes=int(
                sum(x.nbytes for x in jax.tree.leaves(st)))),
            "restore_inline_s": round(inline_s, 6),
            "restore_background_s": round(background_s, 6),
            "inline_rewarm_s": round(rep_in.seconds("rewarm_approximable"),
                                     6),
            "background_warmup_s": round(
                rep_bg.seconds("warmup_approximable"), 6)}


# ------------------------------------------------- chain-order speedup

def _scalar_order(nxt: np.ndarray, head: int, count: int) -> np.ndarray:
    """The seed's sequential NEXT walk (pre-refactor recovery loop)."""
    out = np.empty(count, np.int64)
    cur = head
    for i in range(count):
        out[i] = cur
        cur = int(nxt[cur])
    return out


def chain_row(n: int, repeats: int = 3) -> Dict:
    """One contraction-vs-doubling-vs-scalar sweep row.  All three
    orders must be bit-identical (asserted here, every run); `vector_s`
    / `speedup` stay the AUTO path's numbers for continuity with the
    pre-contraction JSON."""
    rng = np.random.default_rng(0)
    perm = rng.permutation(n)
    nxt = np.full(n, -1, np.int64)
    nxt[perm[:-1]] = perm[1:]
    head = int(perm[0])
    want = _scalar_order(nxt, head, n)     # warm (page in nxt)
    scalar_s = min(_timed(lambda: _scalar_order(nxt, head, n))
                   for _ in range(repeats))
    secs = {}
    for method in ("double", "contract"):
        got = chain_order(nxt, head, n, method=method)
        np.testing.assert_array_equal(got, want)   # bit-identical, warm
        secs[method] = min(
            _timed(lambda m=method: chain_order(nxt, head, n, method=m))
            for _ in range(repeats))
    auto = chain_method(n, n)
    vector_s = secs[auto]
    return {"n": n, "method": auto,
            **arena_fields(arena_bytes=int(nxt.nbytes)),
            "scalar_s": round(scalar_s, 6),
            "double_s": round(secs["double"], 6),
            "contract_s": round(secs["contract"], 6),
            "vector_s": round(vector_s, 6),
            "speedup": round(scalar_s / max(vector_s, 1e-9), 2),
            "speedup_double": round(
                scalar_s / max(secs["double"], 1e-9), 2),
            "speedup_contract": round(
                scalar_s / max(secs["contract"], 1e-9), 2)}


def device_chain_rows(sizes: List[int], k: int = 16) -> List[Dict]:
    """Device contraction path: the per-hop `gather_next` cascade vs
    the fused walk/expand kernels (kernels/chain_order.walk_segments /
    expand_segments, one in-kernel fori_loop per pallas_call).  The
    measured quantity is pallas_call ROUND TRIPS (co.KERNEL_CALLS) —
    that's the cost the fusion removes on a real accelerator; the
    interpret-mode wall rides along as a secondary signal."""
    from repro.kernels import chain_order as co
    rows = []
    for n in sizes:
        rng = np.random.default_rng(0)
        perm = rng.permutation(n)
        nxt = np.full(n, -1, np.int64)
        nxt[perm[:-1]] = perm[1:]
        head = int(perm[0])
        row: Dict[str, Any] = {"n": n, "k": k,
                               **arena_fields(arena_bytes=int(nxt.nbytes))}
        for fuse, tag in ((False, "per_hop"), (True, "fused")):
            co.KERNEL_CALLS = 0
            t0 = time.perf_counter()
            got = co.chain_order_device(nxt, head, method="contract",
                                        k=k, fuse=fuse, interpret=True)
            row[f"{tag}_s"] = round(time.perf_counter() - t0, 6)
            row[f"{tag}_pallas_calls"] = co.KERNEL_CALLS
            np.testing.assert_array_equal(got, perm)
        row["roundtrip_saving"] = round(
            row["per_hop_pallas_calls"]
            / max(row["fused_pallas_calls"], 1), 2)
        rows.append(row)
    return rows


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


# --------------------------------------------------------------- main

def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--no-engine", action="store_true")
    ap.add_argument("--chain-crossover", action="store_true",
                    help="run ONLY the 10**6 chain point (quick-grade "
                         "repeats) and fail on speedup <= 1.0 — the CI "
                         "crossover gate")
    ap.add_argument("--snapshot-slo", action="store_true",
                    help="run ONLY the incremental-order-snapshot SLO "
                         "gate: paged-KV TTFT-after-crash must stay "
                         "within 1.2x as the page pool grows 10x with "
                         "snapshots on (DESIGN.md §10); merges a "
                         "snapshot_slo section into --out")
    ap.add_argument("--paged-slo", action="store_true",
                    help="run ONLY the paged-region SLO gate: a pool "
                         "10x the block-cache budget must recover and "
                         "serve inside the cache capacity with paged-"
                         "vs-unpaged recovered state bit-identical, "
                         "and engine TTFT-after-crash must stay within "
                         "1.5x unpaged at cache-fitting scale "
                         "(DESIGN.md §12); merges a paged_slo section "
                         "into --out")
    ap.add_argument("--integrity-overhead", action="store_true",
                    help="run ONLY the checksum-sidecar overhead gate: "
                         "integrity-on epoch-drain line throughput must "
                         "stay >= 0.95x integrity-off, with the DATA "
                         "line/byte ledgers bit-identical across the "
                         "two sides (DESIGN.md §13); merges an "
                         "integrity_overhead section into --out")
    ap.add_argument("--out", default="BENCH_recovery.json")
    args = ap.parse_args()
    if args.integrity_overhead:
        rep = integrity_overhead_report()
        for r in rep["rows"]:
            print(f"integrity={'on' if r['integrity'] else 'off'}: "
                  f"{r['lines']} data lines + {r['integrity_lines']} "
                  f"sidecar lines in {r['flush_wall_s']}s "
                  f"({r['lines_per_s']} persisted lines/s), "
                  f"scrub {r['scrub_s']}s")
        print(f"integrity-on drain throughput: "
              f"{rep['lines_per_s_ratio']}x of integrity-off "
              f"(gate >= 0.95x; data-only ratio "
              f"{rep['data_lines_per_s_ratio']}x, ungated)")
        try:
            with open(args.out) as f:
                data = json.load(f)
        except (OSError, ValueError):
            data = {}
        data["integrity_overhead"] = rep
        with open(args.out, "w") as f:
            json.dump(data, f, indent=1)
        print(f"-> {args.out}")
        # vectorized splitmix rides the drain: its cost must stay in
        # the flush noise (the deterministic ledger identities are
        # asserted inside integrity_overhead_report)
        assert rep["lines_per_s_ratio"] >= 0.95, rep
        return 0
    if args.paged_slo:
        slo = paged_slo_report()
        b = slo["budget"]
        print(f"paged budget @ {b['factor']}x cache "
              f"({b['n_pages']} pages, arena {b['arena_bytes']}B vs "
              f"capacity {b['capacity_bytes']}B): peak resident "
              f"{b['peak_resident_bytes']}B (budget {b['budget_bytes']}B), "
              f"faults={b['faults']} evictions={b['evictions']} "
              f"spills={b['spills']}, recover {b['recover_s']}s, "
              f"stage faults {b['block_faults_per_stage']}")
        for r in slo["ttft"]:
            print(f"engine TTFT @ {r['n_pages']} pages "
                  f"paged={'on' if r['paged'] else 'off'}: "
                  f"{r['ttft_after_crash_s']}s "
                  f"(admission {r['first_admission_s']}s)")
        print(f"TTFT paged/unpaged at cache-fitting scale: "
              f"{slo['ttft_ratio_paged']}x (SLO {slo['slo_ttft']}x)")
        try:
            with open(args.out) as f:
                data = json.load(f)
        except (OSError, ValueError):
            data = {}
        data["paged_slo"] = slo
        with open(args.out, "w") as f:
            json.dump(data, f, indent=1)
        print(f"-> {args.out}")
        # the larger-than-RAM claim, measured: recover + serve of a
        # 10x-budget pool never holds more than the cache (+ slack)
        assert b["peak_resident_bytes"] <= b["budget_bytes"], b
        # the pool really was larger than the budget
        assert b["arena_bytes"] > b["capacity_bytes"] * 5, b
        # paging is volatile-only: recovered state bit-matches both the
        # pre-crash state and an unpaged reopen of the same file
        assert b["fingerprint_match_precrash"], b
        assert b["fingerprint_match_unpaged"], b
        # the gated path must run block-routed, not through the
        # spill fallback
        assert b["spills"] == 0, b
        # demand paging stays off the admission path when the working
        # set fits the cache
        assert slo["ttft_ratio_paged"] <= slo["slo_ttft"], slo
        return 0
    if args.snapshot_slo:
        slo = snapshot_slo_report()
        for r in slo["engine"]:
            print(f"engine TTFT @ {r['n_pages']} pages "
                  f"snapshot={'on' if r['snapshot'] else 'off'}: "
                  f"{r['ttft_after_crash_s']}s (lru {r['lru_s']}s, "
                  f"chain={r['lru_chain']})")
        for r in slo["component"]:
            print(f"lru recover @ {r['n_pages']} pages "
                  f"({r['live_pages']} live) "
                  f"snapshot={'on' if r['snapshot'] else 'off'}: "
                  f"lru {r['lru_s']}s chain={r['lru_chain']} "
                  f"replayed={r['lru_replayed']}")
        print(f"TTFT growth at {slo['factor']}x pool: snapshot on "
              f"{slo['ttft_ratio_snapshot_on']}x (SLO {slo['slo']}x), "
              f"off {slo['ttft_ratio_snapshot_off']}x")
        try:
            with open(args.out) as f:
                data = json.load(f)
        except (OSError, ValueError):
            data = {}
        data["snapshot_slo"] = slo
        with open(args.out, "w") as f:
            json.dump(data, f, indent=1)
        print(f"-> {args.out}")
        # the SLO itself: snapshots keep recovery off the admission
        # path, so a 10x pool must not move TTFT by more than 20%
        assert slo["ttft_ratio_snapshot_on"] <= slo["slo"], slo
        # and adoption must actually have happened at the big size
        assert all(r["lru_chain"] == "snapshot"
                   for r in slo["engine"] if r["snapshot"]), slo
        return 0
    if args.chain_crossover:
        c = chain_row(1_000_000, repeats=2)
        print(f"chain crossover @ {c['n']}: scalar {c['scalar_s']}s, "
              f"double {c['double_s']}s ({c['speedup_double']}x), "
              f"contract {c['contract_s']}s ({c['speedup_contract']}x) "
              f"-> auto={c['method']} {c['speedup']}x")
        # the whole point of the contraction path: the auto primitive
        # must clear the jump-table cache crossover at 10**6.  The
        # contraction margin is large (~5x on the reference host), so
        # this gate holds even on contended CI runners where the ~1.1x
        # doubling wins would flake.
        assert c["method"] == "contract", c
        assert c["speedup"] > 1.0, c
        return 0
    sizes = [2000, 8000] if args.quick else [10000, 100000]
    chain_sizes = [100000] if args.quick else [100000, 250000, 1000000]
    # concurrency pays for its thread pool only once the per-stage numpy
    # work dwarfs the GIL'd glue (~50k entries on this 2-core host), so
    # the concurrent-vs-serial sweep starts above that crossover
    conc_sizes = [50000] if args.quick else [100000, 200000]

    rows = structure_rows(sizes)
    cols = ["structure", "mode", "n", "build_lines", "recover_s",
            "rebuild_s"]
    print(fmt_table(rows, cols))
    for r in rows:
        if "write_lines_saved_vs_full" in r:
            print(f"  {r['structure']}/{r['n']}: partly saves "
                  f"{r['write_lines_saved_vs_full']} write lines, pays "
                  f"{r['recover_cost_vs_full']} recovery time")

    conc = concurrent_rows(conc_sizes)
    for c in conc:
        print(f"mixed recovery @ {c['n_per_structure']}x3: serial "
              f"{c['serial_wall_ms']}ms, concurrent "
              f"{c['concurrent_wall_ms']}ms (critical path "
              f"{c['critical_path_ms']}ms) -> {c['speedup']}x")

    sharded = sharded_recovery_rows([conc_sizes[-1]],
                                    repeats=3 if args.quick else 7)
    for r in sharded:
        print(f"sharded recovery [pm] @ {r['n_per_structure']}x3 "
              f"conc=4: single {r['single_wall_ms']}ms vs 4 shards "
              f"{r['sharded_wall_ms']}ms -> {r['speedup']}x")

    chain = [chain_row(n) for n in chain_sizes]
    for c in chain:
        print(f"chain_order @ {c['n']}: scalar {c['scalar_s']}s, "
              f"double {c['double_s']}s ({c['speedup_double']}x), "
              f"contract {c['contract_s']}s ({c['speedup_contract']}x) "
              f"-> auto={c['method']} {c['speedup']}x")

    device = device_chain_rows([2048] if args.quick else [4096])
    for r in device:
        print(f"device contraction @ {r['n']} (k={r['k']}): per-hop "
              f"{r['per_hop_pallas_calls']} pallas calls "
              f"({r['per_hop_s']}s) vs fused "
              f"{r['fused_pallas_calls']} ({r['fused_s']}s) -> "
              f"{r['roundtrip_saving']}x fewer round trips")

    engine = None
    if not args.no_engine:
        engine = engine_report(n_requests=2 if args.quick else 4,
                               steps=2 if args.quick else 4)
        print(f"engine recovery: serial {engine['total_s']}s, concurrent "
              f"{engine['concurrent_total_s']}s, TTFT after crash "
              f"{engine['ttft_after_crash_s']}s "
              f"({engine['tokens_at_first_admission']} token(s) at first "
              f"admission), stages {engine['stages']}")

    # --no-engine skips only the heavy model build; the ckpt warmup
    # metric needs just jax + a tiny TrainState, so it always runs
    ckpt = ckpt_report()
    print(f"ckpt restore: inline {ckpt['restore_inline_s']}s vs "
          f"background {ckpt['restore_background_s']}s + "
          f"{ckpt['background_warmup_s']}s warmup off-path")

    # exactly-once journal: overhead bound is a deterministic line
    # count, so its asserts (inside journal_report) gate in quick mode
    journal = journal_report(n_ops=16 if args.quick else 64)
    for r in journal["rows"]:
        print(f"feature-store recovery journal="
              f"{'on' if r['journal'] else 'off'}: {r['recover_s']}s, "
              f"{r['lines_per_epoch']} data lines/epoch + "
              f"{r['journal_lines_per_epoch']} journal lines/epoch")
    print(f"journal recovery overhead: "
          f"{journal['recover_overhead_x']}x")

    with open(args.out, "w") as f:
        json.dump({"workload": "build -> commit -> crash -> recover "
                               "(RecoveryManager, §V-F)",
                   "sizes": sizes, "rows": rows,
                   "concurrent_vs_serial": conc,
                   "sharded_recovery": sharded,
                   "chain_order": chain, "device_chain": device,
                   "engine": engine,
                   "ckpt_warmup": ckpt,
                   "journal": journal}, f, indent=1)
    print(f"-> {args.out}")
    # the auto chain primitive must beat the seed scalar walk at EVERY
    # measured size — doubling carries the 100k point and contraction
    # list ranking clears the 10**6 jump-table cache crossover the
    # pre-contraction sweep reported honestly as <1x.  Quick (CI smoke)
    # mode records without asserting: on a contended shared runner the
    # ~1.5x doubling win can measure near 1.0 and would flake the build
    # (the dedicated --chain-crossover step gates the wide-margin 10**6
    # point instead).
    if not args.quick:
        for c in chain:
            assert c["speedup"] > 1.0, c
        # concurrent recovery must not lose to serial at any measured
        # size (same flake caveat as above for quick/CI mode)
        for c in conc:
            assert c["concurrent_wall_ms"] <= c["serial_wall_ms"], c
        # sharded recovery must beat the single-arena concurrent pass in
        # the PM-latency regime (without the latency model 2-core hosts
        # are rebuild-bound, see sharded_recovery_rows)
        for r in sharded:
            assert r["sharded_wall_ms"] <= r["single_wall_ms"], r
        # the fused device walk exists to shrink kernel round trips —
        # a deterministic count, so it gates in full mode without flake
        for r in device:
            assert r["fused_pallas_calls"] < r["per_hop_pallas_calls"], r
        if engine is not None:
            assert engine["ttft_after_crash_s"] <= engine["total_s"] * 1.5, \
                engine
    # partly must never flush more write lines than fully
    for r in rows:
        if "write_lines_saved_vs_full" in r:
            assert not r["write_lines_saved_vs_full"].startswith("-"), r
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
