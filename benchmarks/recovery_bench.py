"""recovery_bench — §V-F reconstruction-time benchmarks.

The paper's bargain is two-sided: persist fewer fields at write time
(BENCH_flush.json measures that side), pay to *recreate* them after a
crash.  This bench measures the pay side, through the unified recovery
subsystem (core/recovery.py):

* structure recovery time vs size, partly- vs fully-persistent, for all
  three paper structures — each row also carries the write-side line
  count of building the structure, so partly's write saving can be read
  against its reconstruction cost (the §V-F tradeoff curve);
* serving-engine recovery, staged (request hashmap -> LRU pages ->
  batched slab scan + grouped re-prefill), via the RecoveryReport;
* the vectorized chain-order primitive vs the seed's scalar NEXT walk
  at >= 100k entries (the pointer-doubling speedup every recovery path
  now rides on).

Emits BENCH_recovery.json next to the repo root (CI artifact).

Run: ``PYTHONPATH=src python -m benchmarks.recovery_bench [--quick]``
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import fmt_table, make_structure
from repro.core.recovery import RecoveryManager, chain_order

MODES = ("full", "partly")
STRUCTS = ("dll", "bptree", "hashmap")
RECONSTRUCTOR = {"dll": "pstruct.dll", "bptree": "pstruct.bptree",
                 "hashmap": "pstruct.hashmap"}


# ---------------------------------------------------------- structures

def _build(kind: str, mode: str, n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    a, s = make_structure(kind, mode, n + 1024, synth_line_ns=0)
    vals = rng.integers(0, 1 << 40, (4096, 7)).astype(np.int64)
    keys = rng.permutation(2 * n).astype(np.int64)
    for i in range(0, n, 4096):
        m = min(4096, n - i)
        if kind == "dll":
            s.append_batch(vals[:m])
        else:
            s.insert_batch(keys[i:i + m], vals[:m])
    a.commit()
    return a, s


def _verify(kind: str, s, n: int) -> None:
    if kind == "dll":
        assert s.count == n, (s.count, n)
    elif kind == "bptree":
        s.check_invariants()
    else:
        assert s.size == n, (s.size, n)


def structure_rows(sizes: List[int]) -> List[Dict]:
    rows = []
    for kind in STRUCTS:
        for n in sizes:
            per_mode = {}
            for mode in MODES:
                a, s = _build(kind, mode, n)
                build_lines = a.stats.lines
                a.crash()
                mgr = RecoveryManager(a)
                mgr.add(kind, RECONSTRUCTOR[kind], s)
                rep = mgr.recover()
                _verify(kind, s, n)
                row = {"structure": kind, "mode": mode, "n": n,
                       "build_lines": build_lines,
                       "recover_s": round(rep.total_seconds, 6),
                       "reopen_s": round(rep.seconds("reopen"), 6),
                       "rebuild_s": round(rep.seconds(kind), 6)}
                per_mode[mode] = row
                rows.append(row)
            # the §V-F tradeoff, read off directly: write lines saved by
            # partly vs the recovery time it costs
            full, partly = per_mode["full"], per_mode["partly"]
            saved = full["build_lines"] - partly["build_lines"]
            partly["write_lines_saved_vs_full"] = (
                f"{100 * saved / max(full['build_lines'], 1):.1f}%")
            partly["recover_cost_vs_full"] = (
                f"{partly['recover_s'] / max(full['recover_s'], 1e-9):.2f}x")
    return rows


# ------------------------------------------------------ serving engine

def engine_report(n_requests: int, steps: int) -> Dict:
    import jax
    import jax.numpy as jnp

    from repro.configs import base, registry
    from repro.models.model import build
    from repro.serve.engine import EngineConfig, ServingEngine

    model = build(base.reduced(registry.get("llama3.2-3b")),
                  compute_dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0))
    ec = EngineConfig(max_batch=n_requests, s_max=32,
                      max_requests=4 * n_requests)
    eng = ServingEngine(model, params, ec)
    rng = np.random.default_rng(0)
    for rid in range(n_requests):
        plen = int(rng.integers(3, 9))
        eng.add_request(100 + rid,
                        rng.integers(1, model.cfg.vocab, plen).astype(np.int64))
    for _ in range(steps):
        eng.step()
    eng.crash()
    sec = eng.recover()
    rep = eng.last_recovery
    return {"requests": n_requests, "decode_steps": steps,
            "total_s": round(sec, 6),
            "stages": {s.name: round(s.seconds, 6) for s in rep.stages},
            "prefill_groups": rep.stage("engine").detail["prefill_groups"]}


# ------------------------------------------------- chain-order speedup

def _scalar_order(nxt: np.ndarray, head: int, count: int) -> np.ndarray:
    """The seed's sequential NEXT walk (pre-refactor recovery loop)."""
    out = np.empty(count, np.int64)
    cur = head
    for i in range(count):
        out[i] = cur
        cur = int(nxt[cur])
    return out


def chain_row(n: int, repeats: int = 3) -> Dict:
    rng = np.random.default_rng(0)
    perm = rng.permutation(n)
    nxt = np.full(n, -1, np.int64)
    nxt[perm[:-1]] = perm[1:]
    head = int(perm[0])
    want = _scalar_order(nxt, head, n)     # warm (page in nxt)
    scalar_s = min(_timed(lambda: _scalar_order(nxt, head, n))
                   for _ in range(repeats))
    chain_order(nxt, head, n)              # warm
    vector_s = min(_timed(lambda: chain_order(nxt, head, n))
                   for _ in range(repeats))
    np.testing.assert_array_equal(chain_order(nxt, head, n), want)
    return {"n": n, "scalar_s": round(scalar_s, 6),
            "vector_s": round(vector_s, 6),
            "speedup": round(scalar_s / max(vector_s, 1e-9), 2)}


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


# --------------------------------------------------------------- main

def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--no-engine", action="store_true")
    ap.add_argument("--out", default="BENCH_recovery.json")
    args = ap.parse_args()
    sizes = [2000, 8000] if args.quick else [10000, 100000]
    chain_sizes = [100000] if args.quick else [100000, 250000, 1000000]

    rows = structure_rows(sizes)
    cols = ["structure", "mode", "n", "build_lines", "recover_s",
            "rebuild_s"]
    print(fmt_table(rows, cols))
    for r in rows:
        if "write_lines_saved_vs_full" in r:
            print(f"  {r['structure']}/{r['n']}: partly saves "
                  f"{r['write_lines_saved_vs_full']} write lines, pays "
                  f"{r['recover_cost_vs_full']} recovery time")

    chain = [chain_row(n) for n in chain_sizes]
    for c in chain:
        print(f"chain_order @ {c['n']}: scalar {c['scalar_s']}s, "
              f"vectorized {c['vector_s']}s -> {c['speedup']}x")

    engine = None
    if not args.no_engine:
        engine = engine_report(n_requests=2 if args.quick else 4,
                               steps=2 if args.quick else 4)
        print(f"engine recovery: {engine['total_s']}s, "
              f"stages {engine['stages']}")

    with open(args.out, "w") as f:
        json.dump({"workload": "build -> commit -> crash -> recover "
                               "(RecoveryManager, §V-F)",
                   "sizes": sizes, "rows": rows,
                   "chain_order": chain, "engine": engine}, f, indent=1)
    print(f"-> {args.out}")
    # the vectorized primitive must beat the seed scalar walk at >=100k
    # entries (larger sizes are reported as measured — the 10**6 point
    # sits near the jump-table cache crossover on small hosts).  Quick
    # (CI smoke) mode records without asserting: on a contended shared
    # runner the ~2x win can measure near 1.0 and would flake the build.
    if not args.quick:
        assert chain[0]["n"] >= 100000 and chain[0]["speedup"] > 1.0, chain
    # partly must never flush more write lines than fully
    for r in rows:
        if "write_lines_saved_vs_full" in r:
            assert not r["write_lines_saved_vs_full"].startswith("-"), r
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
