"""flush_batching — per-call vs epoch-batched flush line accounting.

The write-set layer (repro.core.writeset, DESIGN.md §2) dedups dirty rows
and coalesces adjacent lines once per *epoch* instead of once per
``persist_rows`` call.  This micro-bench quantifies the saving on the
paper's workloads, at three batching granularities:

* ``per_call``  — one accounting call per mark (the write set's
  would-be counter).  An upper bound on pre-writeset cost: structures
  that already batched an op's dirty rows per region (B+Tree) sat at
  the per_op level, while multi-round paths (DLL delete) really did
  flush per call;
* ``per_op``    — one epoch per structure operation (the default after
  the refactor: every ``insert_batch``/``delete_batch`` is an epoch).
  This measured row is the honest pre-writeset baseline for B+Tree;
* ``per_group`` — one epoch wrapped around GROUP consecutive ops (the
  serving pattern: kvcache.alloc spans evict+append+commit).
  ``save_vs_per_op`` compares against the measured per_op row.

The ``n_shards`` sweep (DESIGN.md §7) measures FLUSH-EPOCH THROUGHPUT
of the sharded arena on the same mixed B+Tree workload: ops accumulate
marks in the epoch (untimed — structure CPU is not the flush path),
then the timed section is exactly the epoch drain + commit.  The sweep
runs in the stall-dominated regime (synthetic per-line latency at 4x
the 250 ns base so the flush stall stays above this host's timer
wakeup slack): a single arena pays the whole stall serially, N shards
pay 1/N each, overlapped in the flush pool — the medium-independent
line/dedup accounting is asserted IDENTICAL across shard counts.

Emits BENCH_flush.json next to the repo root (CI artifact).

Run: ``PYTHONPATH=src python -m benchmarks.flush_batching [--quick]``
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import arena_fields, make_structure
from repro.core.arena import open_arena
from repro.pstruct.bptree import BPTree
from repro.pstruct.dll import DoublyLinkedList

GROUP = 8  # ops fused per outer epoch in the per_group variant
SHARD_COUNTS = (1, 2, 4, 8)


def _bptree_mixed(n_init: int, n_ops: int, batch: int, group: int,
                  seed: int = 0) -> Dict:
    """Mixed 1:1 insert/delete on the partly-persistent B+Tree."""
    rng = np.random.default_rng(seed)
    capacity = n_init + n_ops + 1024
    a, t = make_structure("bptree", "partly", capacity, synth_line_ns=0)
    keyspace = rng.permutation(capacity * 2).astype(np.int64)
    init_keys = keyspace[:n_init]
    new_keys = keyspace[n_init:n_init + n_ops]
    vals = rng.integers(0, 1 << 40, (max(n_init, n_ops), 7)).astype(np.int64)
    for i in range(0, n_init, 4096):
        t.insert_batch(init_keys[i:i + 4096], vals[i:i + 4096])
    a.commit()
    base = a.stats.snapshot()

    ops = []
    done = ins = rm = 0
    while done < n_ops:
        m = min(batch, n_ops - done)
        ops.append(("ins", new_keys[ins:ins + m], vals[:m]))
        ins += m
        done += m
        if done >= n_ops:
            break
        m = min(batch, n_ops - done)
        ops.append(("del", init_keys[rm:rm + m], None))
        rm += m
        done += m

    for g in range(0, len(ops), group):
        chunk = ops[g:g + group]
        if group > 1:
            with a.epoch():
                _apply(t, chunk)
            a.commit()
        else:
            _apply(t, chunk)
            a.commit()
    d = a.stats.delta(base)
    return {"lines": d.lines, "saved_lines": d.saved_lines,
            "snapshot_lines": d.snapshot_lines,
            "dedup_rows": d.dedup_rows, "epochs": d.epochs,
            "fences": d.fences,
            "per_call_lines": d.lines + d.saved_lines,
            **arena_fields(a)}


def _apply(t, chunk) -> None:
    for op, ks, vs in chunk:
        if op == "ins":
            t.insert_batch(ks, vs)
        else:
            t.delete_batch(ks)


def _dll_delete(n_init: int, n_ops: int, batch: int, seed: int = 0) -> Dict:
    """Scattered DLL deletes: the multi-round unlink marked the same
    predecessor rows and the header once per round pre-refactor — the
    per-op epoch already dedups those."""
    rng = np.random.default_rng(seed)
    a, d = make_structure("dll", "partly", n_init + 64, synth_line_ns=0)
    vals = rng.integers(0, 1 << 40, (n_init, 7)).astype(np.int64)
    for i in range(0, n_init, 4096):
        d.append_batch(vals[i:i + 4096])
    a.commit()
    base = a.stats.snapshot()
    ids = rng.permutation(n_init)[:n_ops].astype(np.int64)
    for i in range(0, n_ops, batch):
        d.delete_batch(ids[i:i + batch])
        a.commit()
    dd = a.stats.delta(base)
    # snapshot_lines (DLL order snapshots, DESIGN.md §10) reported
    # SEPARATELY: lines/saved_lines stay bit-comparable to the
    # pre-snapshot artifacts
    return {"lines": dd.lines, "saved_lines": dd.saved_lines,
            "snapshot_lines": dd.snapshot_lines,
            "dedup_rows": dd.dedup_rows, "epochs": dd.epochs,
            "fences": dd.fences,
            "per_call_lines": dd.lines + dd.saved_lines,
            **arena_fields(a)}


def _sharded_flush(n_shards: int, n_init: int, n_ops: int, batch: int,
                   group: int, synth_ns: float, seed: int = 0,
                   commit_mode: str = "barrier",
                   synth_fence_ns: float = 0.0) -> Dict:
    """Mixed 1:1 insert/delete B+Tree on an ``n_shards`` arena; returns
    the flush-phase wall (epoch drains + commits only) and the exact
    line accounting.  ``n_shards=1`` is the plain single Arena — the
    pre-sharding baseline, spin-exact stalls and all."""
    rng = np.random.default_rng(seed)
    capacity = n_init + n_ops + 1024
    layout = BPTree.layout(max(64, capacity // 4), capacity, "partly")
    a = open_arena(None, layout, n_shards=n_shards,
                   synth_line_ns=synth_ns, commit_mode=commit_mode,
                   synth_fence_ns=synth_fence_ns)
    t = BPTree(a, max(64, capacity // 4), capacity, "partly")
    keyspace = rng.permutation(capacity * 2).astype(np.int64)
    init_keys = keyspace[:n_init]
    new_keys = keyspace[n_init:n_init + n_ops]
    vals = rng.integers(0, 1 << 40, (max(n_init, n_ops), 7)).astype(np.int64)
    for i in range(0, n_init, 4096):
        t.insert_batch(init_keys[i:i + 4096], vals[i:i + 4096])
    a.commit()
    base = a.stats.snapshot()
    ops = []
    done = ins = rm = 0
    while done < n_ops:
        m = min(batch, n_ops - done)
        ops.append(("ins", new_keys[ins:ins + m], vals[:m]))
        ins += m
        done += m
        if done >= n_ops:
            break
        m = min(batch, n_ops - done)
        ops.append(("del", init_keys[rm:rm + m], None))
        rm += m
        done += m
    flush_wall = 0.0
    for g in range(0, len(ops), group):
        # marks accumulate inside the epoch untimed (structure CPU is
        # not the flush path); the timed section is the drain + commit
        a._epoch_depth += 1
        _apply(t, ops[g:g + group])
        a._epoch_depth -= 1
        t0 = time.perf_counter()
        a.writeset.flush()
        a.commit()
        flush_wall += time.perf_counter() - t0
    d = a.stats.delta(base)
    a.close()    # release the shard pool + memmap handles per sweep point
    return {**arena_fields(a),
            "flush_wall_s": round(flush_wall, 6),
            "lines": d.lines, "saved_lines": d.saved_lines,
            "snapshot_lines": d.snapshot_lines,
            "dedup_rows": d.dedup_rows, "epochs": d.epochs,
            "fences": d.fences,
            "lines_per_s": int(d.lines / max(flush_wall, 1e-9))}


def sharded_sweep(n_init: int, n_ops: int, batch: int = 256,
                  group: int = 32, synth_ns: float = 1000.0,
                  repeats: int = 2) -> List[Dict]:
    """Flush-epoch throughput vs shard count, interleaved best-of-N (the
    noise filter every bench here uses on this shared host).

    ``synth_ns`` scales the per-line stall so stall-per-epoch lands in
    the several-ms range where this host's sleep wakeup slack (~1 ms)
    cannot mask the overlap; the line counts stay exact at any scale."""
    best: Dict[int, Dict] = {}
    for _ in range(repeats):
        for ns in SHARD_COUNTS:
            r = _sharded_flush(ns, n_init, n_ops, batch, group, synth_ns)
            if ns not in best or r["flush_wall_s"] < best[ns]["flush_wall_s"]:
                best[ns] = r
    rows = [best[ns] for ns in SHARD_COUNTS]
    base = rows[0]
    for r in rows:
        r["x_vs_1shard"] = round(base["flush_wall_s"]
                                 / max(r["flush_wall_s"], 1e-9), 2)
        # the medium-independent accounting must not depend on sharding
        assert (r["lines"], r["saved_lines"], r["dedup_rows"]) == \
            (base["lines"], base["saved_lines"], base["dedup_rows"]), rows
    return rows


def shadow_crossover(n_init: int, n_ops: int, batch: int = 64,
                     group: int = 4,
                     synth_fence_ns: float = 1_000_000.0,
                     repeats: int = 2) -> Dict:
    """Barrier vs shadow commit, n_shards=4, FENCE-dominated regime:
    small epoch groups so ordering points (3 per committed epoch in
    barrier mode — data phase, metadata phase, commit seal — vs the
    shadow mode's single generation flip) dominate the flush wall.
    The sharded arena's fence spins exact (no sleep wakeup slack), so
    the regime holds even at ms-scale ``synth_fence_ns`` — scaled, like
    the sharded sweep's line stall, until the modeled latency clears
    this host's per-epoch Python overhead; the fence COUNTS are exact
    at any scale.

    The compared rate charges BOTH modes the barrier row's line count:
    shadow writes more lines (remap entries + next-epoch collapse), so
    crediting each mode its own lines would inflate shadow's
    numerator — the honest quantity is wall time per committed
    workload."""
    best: Dict[str, Dict] = {}
    for _ in range(repeats):
        for mode in ("barrier", "shadow"):
            r = _sharded_flush(4, n_init, n_ops, batch, group,
                               synth_ns=250.0, commit_mode=mode,
                               synth_fence_ns=synth_fence_ns)
            if (mode not in best
                    or r["flush_wall_s"] < best[mode]["flush_wall_s"]):
                best[mode] = r
    bar, sh = best["barrier"], best["shadow"]
    for r in best.values():
        r["flush_lines_per_s"] = int(
            bar["lines"] / max(r["flush_wall_s"], 1e-9))
    return {"workload": "bptree mixed 1:1, n_shards=4, fence-dominated "
                        "(rate charges both modes the barrier line "
                        "count)",
            "synth_fence_ns": synth_fence_ns,
            "rows": [bar, sh],
            "speedup": round(bar["flush_wall_s"]
                             / max(sh["flush_wall_s"], 1e-9), 2)}


def paged_parity(n_init: int, n_ops: int, batch: int = 256,
                 group: int = 16, synth_ns: float = 4000.0,
                 repeats: int = 3) -> Dict:
    """The ``--paged-parity`` gate (DESIGN.md §12): the paged backend
    must not tax the flush path when the working set fits the block
    cache.  Scattered DLL deletes (the fully block-routed structure),
    same seed, paged vs unpaged: the write-set drain gathers rows
    through the block cache instead of slicing the volatile array, and
    with ZERO evictions (cache-fitting) the line/dedup/fence accounting
    must be bit-identical and flush lines/s within 5%.  Stall-dominated
    regime (``synth_ns`` per line) — scaled, like the sharded sweep's
    stall and the shadow crossover's fence, until the modeled latency
    clears this host's per-epoch Python overhead; the medium-
    independent counts stay exact at any scale."""
    def one(paged: bool) -> Dict:
        rng = np.random.default_rng(0)
        cap = n_init + 64
        layout = DoublyLinkedList.layout(cap, "partly")
        a = open_arena(None, layout, synth_line_ns=synth_ns, paged=paged,
                       block_bytes=4096,
                       cache_blocks=(cap * 64) // 4096 + 16)
        d = DoublyLinkedList(a, cap, "partly")
        vals = rng.integers(0, 1 << 40, (n_init, 7)).astype(np.int64)
        for i in range(0, n_init, 4096):
            d.append_batch(vals[i:i + 4096])
        a.commit()
        ids = rng.permutation(n_init)[:n_ops].astype(np.int64)
        base = a.stats.snapshot()
        flush_wall = 0.0
        for g in range(0, n_ops, batch * group):
            a._epoch_depth += 1
            for i in range(g, min(g + batch * group, n_ops), batch):
                d.delete_batch(ids[i:i + batch])
            a._epoch_depth -= 1
            t0 = time.perf_counter()
            a.writeset.flush()
            a.commit()
            flush_wall += time.perf_counter() - t0
        st = a.stats.delta(base)
        cache = getattr(a, "cache", None)
        row = {**arena_fields(a), "paged": paged,
               "flush_wall_s": round(flush_wall, 6),
               "lines": st.lines, "saved_lines": st.saved_lines,
               "snapshot_lines": st.snapshot_lines,
               "dedup_rows": st.dedup_rows, "epochs": st.epochs,
               "fences": st.fences,
               "evictions": int(cache.evictions) if cache else 0,
               "spills": int(cache.spills) if cache else 0,
               "lines_per_s": int(st.lines / max(flush_wall, 1e-9))}
        a.close()
        return row

    best: Dict[bool, Dict] = {}
    for _ in range(repeats):
        for paged in (False, True):
            r = one(paged)
            if (paged not in best
                    or r["flush_wall_s"] < best[paged]["flush_wall_s"]):
                best[paged] = r
    up, pg = best[False], best[True]
    return {"workload": "dll scattered deletes, stall-dominated, "
                        "working set fits the block cache",
            "synth_line_ns": synth_ns,
            "rows": [up, pg],
            "lines_per_s_ratio": round(
                pg["lines_per_s"] / max(up["lines_per_s"], 1), 3)}


def run(n_init: int = 20000, n_ops: int = 20000,
        batch: int = 64) -> List[Dict]:
    rows = []
    for label, group in (("bptree_mixed/per_op", 1),
                         (f"bptree_mixed/per_{GROUP}_ops", GROUP)):
        r = _bptree_mixed(n_init, n_ops, batch, group)
        r["grouping"] = label
        rows.append(r)
    # honest baseline for the grouped variant: the MEASURED per-op run
    # (one flush per region per op — the pre-writeset behaviour), not the
    # per-mark reconstruction, which double-counts rows a single op marks
    # from several sub-steps.
    per_op_lines = rows[0]["lines"]
    rows[1]["save_vs_per_op"] = (
        f"{100 * (per_op_lines - rows[1]['lines']) / max(per_op_lines, 1):.1f}%")
    rows[0]["save_vs_per_op"] = "0.0%"
    r = _dll_delete(n_init, min(n_ops, n_init // 2), batch)
    r["grouping"] = "dll_delete/per_op"
    # pre-refactor DLL delete_batch flushed each unlink round separately,
    # so the per-mark baseline IS its per-call behaviour.
    r["save_vs_per_op"] = (
        f"{100 * r['saved_lines'] / max(r['per_call_lines'], 1):.1f}%")
    rows.append(r)
    for r in rows:
        save = r["per_call_lines"] - r["lines"]
        r["save_vs_per_call"] = f"{100 * save / max(r['per_call_lines'], 1):.1f}%"
    return rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--shadow-crossover", action="store_true",
                    help="run ONLY the barrier-vs-shadow commit "
                         "comparison at n_shards=4 in the fence-"
                         "dominated regime; records in --quick mode, "
                         "asserts >= 1.3x otherwise — the CI gate")
    ap.add_argument("--paged-parity", action="store_true",
                    help="run ONLY the paged-vs-unpaged flush parity "
                         "gate: with the working set inside the block "
                         "cache, line accounting must be bit-identical "
                         "and paged flush lines/s within 5% "
                         "(DESIGN.md §12); merges a paged_parity "
                         "section into --out")
    ap.add_argument("--out", default="BENCH_flush.json")
    args = ap.parse_args()
    if args.paged_parity:
        pp = paged_parity(*( (4000, 4096) if args.quick
                             else (12000, 8192) ))
        for r in pp["rows"]:
            print(f"  paged={'on' if r['paged'] else 'off':>3}: wall "
                  f"{r['flush_wall_s']}s, {r['lines']} lines, "
                  f"{r['lines_per_s']} lines/s, "
                  f"evictions={r['evictions']} spills={r['spills']}")
        print(f"paged/unpaged flush throughput: "
              f"{pp['lines_per_s_ratio']}x (gate >= 0.95)")
        try:
            with open(args.out) as f:
                data = json.load(f)
        except (OSError, ValueError):
            data = {}
        data["paged_parity"] = pp
        with open(args.out, "w") as f:
            json.dump(data, f, indent=1)
        print(f"-> {args.out}")
        up, pg = pp["rows"]
        # the cache-fitting premise: no eviction, no spill on the paged
        # side, so every drain gather hits resident blocks
        assert pg["evictions"] == 0 and pg["spills"] == 0, pg
        # medium-independent accounting must not see the backend at all
        for k in ("lines", "saved_lines", "snapshot_lines", "dedup_rows",
                  "epochs", "fences"):
            assert up[k] == pg[k], (k, up, pg)
        # ... and the stall-dominated flush wall must stay within 5%
        if not args.quick:
            assert pp["lines_per_s_ratio"] >= 0.95, pp
        return 0
    if args.shadow_crossover:
        xr = shadow_crossover(4000, 8192, batch=64, group=4)
        for r in xr["rows"]:
            print(f"  {r['commit_mode']:>7}: wall {r['flush_wall_s']}s, "
                  f"{r['fences']} fences, {r['epochs']} epochs, "
                  f"{r['flush_lines_per_s']} lines/s")
        print(f"shadow crossover @ n_shards=4: {xr['speedup']}x "
              f"flush-phase throughput vs barrier")
        if not args.quick:
            assert xr["speedup"] >= 1.3, xr
        return 0
    n_init, n_ops = (4000, 4000) if args.quick else (20000, 20000)
    rows = run(n_init, n_ops)
    from benchmarks.common import fmt_table
    cols = ["grouping", "per_call_lines", "lines", "saved_lines",
            "snapshot_lines", "save_vs_per_op", "save_vs_per_call",
            "dedup_rows", "epochs", "fences"]
    print(fmt_table(rows, cols))

    # quick mode shrinks the op count, so it raises the per-line stall
    # to keep stall-per-epoch in the slack-dominating range
    synth_ns = 4000.0 if args.quick else 1000.0
    if args.quick:
        shard_rows = sharded_sweep(4000, 8192, batch=256, group=16,
                                   synth_ns=synth_ns, repeats=2)
    else:
        shard_rows = sharded_sweep(n_init, 32768, batch=256, group=32,
                                   synth_ns=synth_ns, repeats=2)
    print(fmt_table(shard_rows, ["n_shards", "flush_wall_s", "lines",
                                 "lines_per_s", "x_vs_1shard", "epochs",
                                 "fences"]))

    crossover = shadow_crossover(4000, 8192, batch=64, group=4)
    for r in crossover["rows"]:
        print(f"  {r['commit_mode']:>7}: wall {r['flush_wall_s']}s, "
              f"{r['fences']} fences, {r['epochs']} epochs, "
              f"{r['flush_lines_per_s']} lines/s")
    print(f"shadow crossover @ n_shards=4: {crossover['speedup']}x "
          f"flush-phase throughput vs barrier")

    with open(args.out, "w") as f:
        json.dump({"workload": "bptree mixed 1:1 insert/delete",
                   "n_init": n_init, "n_ops": n_ops, "rows": rows,
                   "sharded_sweep": {
                       "workload": "bptree mixed 1:1, flush-phase wall "
                                   "(epoch drain + commit), stall-"
                                   "dominated regime",
                       "synth_line_ns": synth_ns,
                       "rows": shard_rows},
                   "shadow_crossover": crossover}, f, indent=1)
    print(f"-> {args.out}")
    # epoch batching must never regress per-call accounting, and the
    # grouped B+Tree mixed workload + DLL deletes must beat it outright
    assert all(r["lines"] <= r["per_call_lines"] for r in rows), rows
    assert any(r["lines"] < r["per_call_lines"] for r in rows), rows
    # sharded flush throughput: never below the single-arena baseline
    # (the CI regression gate), and >= 1.3x at 4 shards in full mode
    x4 = next(r["x_vs_1shard"] for r in shard_rows if r["n_shards"] == 4)
    assert x4 >= 1.0, shard_rows
    if not args.quick:
        assert x4 >= 1.3, shard_rows
        # one ordering point per committed epoch instead of three: the
        # fence-dominated regime must convert that into >= 1.3x flush-
        # phase throughput (the dedicated --shadow-crossover step gates
        # this on CI; quick mode records without asserting)
        assert crossover["speedup"] >= 1.3, crossover
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
