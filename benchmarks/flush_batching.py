"""flush_batching — per-call vs epoch-batched flush line accounting.

The write-set layer (repro.core.writeset, DESIGN.md §2) dedups dirty rows
and coalesces adjacent lines once per *epoch* instead of once per
``persist_rows`` call.  This micro-bench quantifies the saving on the
paper's workloads, at three batching granularities:

* ``per_call``  — one accounting call per mark (the write set's
  would-be counter).  An upper bound on pre-writeset cost: structures
  that already batched an op's dirty rows per region (B+Tree) sat at
  the per_op level, while multi-round paths (DLL delete) really did
  flush per call;
* ``per_op``    — one epoch per structure operation (the default after
  the refactor: every ``insert_batch``/``delete_batch`` is an epoch).
  This measured row is the honest pre-writeset baseline for B+Tree;
* ``per_group`` — one epoch wrapped around GROUP consecutive ops (the
  serving pattern: kvcache.alloc spans evict+append+commit).
  ``save_vs_per_op`` compares against the measured per_op row.

Emits BENCH_flush.json next to the repo root (CI artifact).

Run: ``PYTHONPATH=src python -m benchmarks.flush_batching [--quick]``
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List

import numpy as np

from benchmarks.common import make_structure

GROUP = 8  # ops fused per outer epoch in the per_group variant


def _bptree_mixed(n_init: int, n_ops: int, batch: int, group: int,
                  seed: int = 0) -> Dict:
    """Mixed 1:1 insert/delete on the partly-persistent B+Tree."""
    rng = np.random.default_rng(seed)
    capacity = n_init + n_ops + 1024
    a, t = make_structure("bptree", "partly", capacity, synth_line_ns=0)
    keyspace = rng.permutation(capacity * 2).astype(np.int64)
    init_keys = keyspace[:n_init]
    new_keys = keyspace[n_init:n_init + n_ops]
    vals = rng.integers(0, 1 << 40, (max(n_init, n_ops), 7)).astype(np.int64)
    for i in range(0, n_init, 4096):
        t.insert_batch(init_keys[i:i + 4096], vals[i:i + 4096])
    a.commit()
    base = a.stats.snapshot()

    ops = []
    done = ins = rm = 0
    while done < n_ops:
        m = min(batch, n_ops - done)
        ops.append(("ins", new_keys[ins:ins + m], vals[:m]))
        ins += m
        done += m
        if done >= n_ops:
            break
        m = min(batch, n_ops - done)
        ops.append(("del", init_keys[rm:rm + m], None))
        rm += m
        done += m

    for g in range(0, len(ops), group):
        chunk = ops[g:g + group]
        if group > 1:
            with a.epoch():
                _apply(t, chunk)
            a.commit()
        else:
            _apply(t, chunk)
            a.commit()
    d = a.stats.delta(base)
    return {"lines": d.lines, "saved_lines": d.saved_lines,
            "dedup_rows": d.dedup_rows, "epochs": d.epochs,
            "per_call_lines": d.lines + d.saved_lines}


def _apply(t, chunk) -> None:
    for op, ks, vs in chunk:
        if op == "ins":
            t.insert_batch(ks, vs)
        else:
            t.delete_batch(ks)


def _dll_delete(n_init: int, n_ops: int, batch: int, seed: int = 0) -> Dict:
    """Scattered DLL deletes: the multi-round unlink marked the same
    predecessor rows and the header once per round pre-refactor — the
    per-op epoch already dedups those."""
    rng = np.random.default_rng(seed)
    a, d = make_structure("dll", "partly", n_init + 64, synth_line_ns=0)
    vals = rng.integers(0, 1 << 40, (n_init, 7)).astype(np.int64)
    for i in range(0, n_init, 4096):
        d.append_batch(vals[i:i + 4096])
    a.commit()
    base = a.stats.snapshot()
    ids = rng.permutation(n_init)[:n_ops].astype(np.int64)
    for i in range(0, n_ops, batch):
        d.delete_batch(ids[i:i + batch])
        a.commit()
    dd = a.stats.delta(base)
    return {"lines": dd.lines, "saved_lines": dd.saved_lines,
            "dedup_rows": dd.dedup_rows, "epochs": dd.epochs,
            "per_call_lines": dd.lines + dd.saved_lines}


def run(n_init: int = 20000, n_ops: int = 20000,
        batch: int = 64) -> List[Dict]:
    rows = []
    for label, group in (("bptree_mixed/per_op", 1),
                         (f"bptree_mixed/per_{GROUP}_ops", GROUP)):
        r = _bptree_mixed(n_init, n_ops, batch, group)
        r["grouping"] = label
        rows.append(r)
    # honest baseline for the grouped variant: the MEASURED per-op run
    # (one flush per region per op — the pre-writeset behaviour), not the
    # per-mark reconstruction, which double-counts rows a single op marks
    # from several sub-steps.
    per_op_lines = rows[0]["lines"]
    rows[1]["save_vs_per_op"] = (
        f"{100 * (per_op_lines - rows[1]['lines']) / max(per_op_lines, 1):.1f}%")
    rows[0]["save_vs_per_op"] = "0.0%"
    r = _dll_delete(n_init, min(n_ops, n_init // 2), batch)
    r["grouping"] = "dll_delete/per_op"
    # pre-refactor DLL delete_batch flushed each unlink round separately,
    # so the per-mark baseline IS its per-call behaviour.
    r["save_vs_per_op"] = (
        f"{100 * r['saved_lines'] / max(r['per_call_lines'], 1):.1f}%")
    rows.append(r)
    for r in rows:
        save = r["per_call_lines"] - r["lines"]
        r["save_vs_per_call"] = f"{100 * save / max(r['per_call_lines'], 1):.1f}%"
    return rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_flush.json")
    args = ap.parse_args()
    n_init, n_ops = (4000, 4000) if args.quick else (20000, 20000)
    rows = run(n_init, n_ops)
    from benchmarks.common import fmt_table
    cols = ["grouping", "per_call_lines", "lines", "saved_lines",
            "save_vs_per_op", "save_vs_per_call", "dedup_rows", "epochs"]
    print(fmt_table(rows, cols))
    with open(args.out, "w") as f:
        json.dump({"workload": "bptree mixed 1:1 insert/delete",
                   "n_init": n_init, "n_ops": n_ops, "rows": rows}, f,
                  indent=1)
    print(f"-> {args.out}")
    # epoch batching must never regress per-call accounting, and the
    # grouped B+Tree mixed workload + DLL deletes must beat it outright
    assert all(r["lines"] <= r["per_call_lines"] for r in rows), rows
    assert any(r["lines"] < r["per_call_lines"] for r in rows), rows
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
