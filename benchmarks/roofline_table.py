"""Render the §Roofline tables from results/dryrun.json (+ baseline).

    PYTHONPATH=src python -m benchmarks.roofline_table [--append]
"""
from __future__ import annotations

import argparse
import json
import os

from benchmarks.common import fmt_table


def load(path):
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def rows_for(cells, mesh):
    rows = []
    for r in cells:
        if r.get("mesh") != mesh:
            continue
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "hbm_GiB": "--", "fits": "--",
                         "compute_s": "--", "memory_s": "--",
                         "collective_s": "--",
                         "dominant": r.get("status", "?")[:30],
                         "mfu": "--", "useful": "--"})
            continue
        t, fl, m = r["terms"], r["flops"], r["memory"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "hbm_GiB": round(m["total_hbm_bytes"] / 2**30, 2),
            "fits": "Y" if m["fits_v5e_16g"] else "N",
            "compute_s": round(t["compute_s"], 3),
            "memory_s": round(t["memory_s"], 3),
            "collective_s": round(t["collective_s"], 3),
            "dominant": t["dominant"],
            "mfu": round(fl["mfu_at_roofline"], 4),
            "useful": round(fl["useful_ratio"], 3),
        })
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--append", action="store_true",
                    help="append tables to EXPERIMENTS.md")
    args = ap.parse_args()
    cur = load("results/dryrun.json")
    base = load("results/dryrun_baseline.json")

    out = []
    for mesh, title in (("16x16", "single-pod 16x16 (256 chips)"),
                        ("2x16x16", "multi-pod 2x16x16 (512 chips)")):
        rows = rows_for(cur, mesh)
        if rows:
            out.append(f"\n### Optimized — {title}\n")
            out.append("```")
            out.append(fmt_table(rows, list(rows[0])))
            out.append("```")
    if base:
        rows = rows_for(base, "16x16")
        if rows:
            out.append("\n### Baseline (pre-§Perf) — single-pod 16x16\n")
            out.append("```")
            out.append(fmt_table(rows, list(rows[0])))
            out.append("```")
    text = "\n".join(out)
    print(text)
    if args.append:
        with open("EXPERIMENTS.md", "a") as f:
            f.write("\n" + text + "\n")


if __name__ == "__main__":
    main()
