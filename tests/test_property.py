"""Property-based tests (hypothesis): random op sequences against pure
python reference models, with a crash+reconstruct inserted at an arbitrary
point.  The system invariant under test is the paper's central claim:

    reconstruct(persist(partly)) == live state == reconstruct(persist(full))

and flush accounting: lines(partly) <= lines(full) for the same op trace.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.arena import open_arena
from repro.pstruct.bptree import BPTree
from repro.pstruct.dll import DoublyLinkedList
from repro.pstruct.hashmap import Hashmap

SETTINGS = dict(max_examples=40, deadline=None,
                suppress_health_check=[HealthCheck.too_slow])


# ---------------------------------------------------------------- hashmap

hm_ops = st.lists(
    st.tuples(st.sampled_from(["insert", "remove", "crash"]),
              st.lists(st.integers(0, 200), min_size=1, max_size=20)),
    min_size=1, max_size=24)


@given(ops=hm_ops)
@settings(**SETTINGS)
def test_hashmap_matches_dict(ops):
    ref = {}
    lines = {}
    for mode in ("partly", "full"):
        a = open_arena(None, Hashmap.layout(1024, mode))
        h = Hashmap(a, 1024, mode)
        ref = {}
        for op, keys in ops:
            k = np.asarray(keys, np.int64)
            if op == "insert":
                v = np.stack([np.arange(7, dtype=np.int64) + kk for kk in k])
                h.insert_batch(k, v)
                for kk, vv in zip(k.tolist(), v):
                    ref[kk] = vv
            elif op == "remove":
                h.remove_batch(k)
                for kk in k.tolist():
                    ref.pop(kk, None)
            else:
                a.commit()
                a.crash()
                a.reopen()
                h.reconstruct()
            assert h.check_against(ref)
        lines[mode] = a.stats.lines
    assert lines["partly"] <= lines["full"]


# ---------------------------------------------------------------- bptree

bt_ops = st.lists(
    st.tuples(st.sampled_from(["insert", "delete", "crash"]),
              st.lists(st.integers(0, 400), min_size=1, max_size=30)),
    min_size=1, max_size=20)


@given(ops=bt_ops)
@settings(**SETTINGS)
def test_bptree_matches_dict(ops):
    for mode in ("partly", "full"):
        a = open_arena(None, BPTree.layout(1024, 4096, mode))
        t = BPTree(a, 1024, 4096, mode)
        ref = {}
        for op, keys in ops:
            k = np.asarray(keys, np.int64)
            if op == "insert":
                v = np.stack([np.arange(7, dtype=np.int64) * kk for kk in k])
                t.insert_batch(k, v)
                # batch dedup keeps last occurrence
                for kk, vv in zip(k.tolist(), v):
                    ref[kk] = vv
            elif op == "delete":
                t.delete_batch(k)
                for kk in k.tolist():
                    ref.pop(kk, None)
            else:
                a.commit()
                a.crash()
                a.reopen()
                t.reconstruct()
            t.check_invariants()
            if ref:
                rk = np.fromiter(ref.keys(), np.int64, len(ref))
                ok, vals = t.find_batch(rk)
                assert ok.all()
                want = np.stack([ref[int(x)] for x in rk])
                assert (vals == want).all()
            gone = np.asarray([x for x in range(0, 401, 37)
                               if x not in ref], np.int64)
            if gone.size:
                ok, _ = t.find_batch(gone)
                assert not ok.any()


# ---------------------------------------------------------------- dll

dll_ops = st.lists(
    st.tuples(st.sampled_from(["append", "pop", "crash"]),
              st.integers(1, 12)),
    min_size=1, max_size=24)


@given(ops=dll_ops)
@settings(**SETTINGS)
def test_dll_matches_list(ops):
    a = open_arena(None, DoublyLinkedList.layout(1024, "partly"))
    d = DoublyLinkedList(a, 1024, "partly")
    ref = []          # list of data rows in order
    ctr = 0
    for op, n in ops:
        if op == "append":
            vals = np.arange(n * 7, dtype=np.int64).reshape(n, 7) + ctr
            ctr += n * 7
            d.append_batch(vals)
            ref.extend(vals.tolist())
        elif op == "pop":
            m = min(n, len(ref))
            if m:
                d.pop_front_batch(m)
                ref = ref[m:]
        else:
            a.commit()
            a.crash()
            a.reopen()
            d.reconstruct()
        assert d.count == len(ref)
        if ref:
            order = d.to_list()
            assert d.data[order].tolist() == ref
            # prev chain is the exact mirror of next
            assert d.prev[order[0]] == -1
            assert (d.prev[order[1:]] == order[:-1]).all()


# ---------------------------------------------------------------- arena

@given(rows=st.lists(st.integers(0, 63), min_size=1, max_size=40),
       rowbytes_pow=st.integers(3, 7))
@settings(max_examples=30, deadline=None)
def test_arena_line_accounting(rows, rowbytes_pow):
    """Distinct-line accounting: flushing R unique rows of 2^k bytes costs
    exactly the number of distinct 64B lines those rows touch."""
    rowlen = 2 ** rowbytes_pow  # bytes per row (8..128)
    words = rowlen // 8
    a = open_arena(None, {"r": (np.int64, (64, words))})
    r = a.regions["r"]
    r.persist_rows(np.asarray(rows, np.int64))
    uniq = np.unique(rows)
    base = r.offset
    starts = (base + uniq * rowlen) // 64
    ends = (base + (uniq + 1) * rowlen - 1) // 64
    expect = len(set(int(x) for lo, hi in zip(starts, ends)
                     for x in range(lo, hi + 1)))
    assert a.stats.lines == expect
    assert a.stats.bytes == len(uniq) * rowlen
