"""Property-based tests (hypothesis): random op sequences against pure
python reference models, with a crash+reconstruct inserted at an arbitrary
point.  The system invariant under test is the paper's central claim:

    reconstruct(persist(partly)) == live state == reconstruct(persist(full))

and flush accounting: lines(partly) <= lines(full) for the same op trace;
plus the recovery-subsystem property: an interleaved multi-structure
workload crashed at a RANDOM point recovers — serially or concurrently —
to exactly the committed prefix of the op sequence.
"""
import numpy as np
import pytest

# hypothesis is in requirements.txt and present in CI; local dev sandboxes
# without it skip this file rather than fail collection (the only
# intentionally skippable tier-1 file — everything here is re-covered
# deterministically by the fuzz sweeps in tests/test_async_recovery.py)
hypothesis = pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed (CI installs requirements.txt)")
from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.arena import open_arena
from repro.core.recovery import RecoveryManager
from repro.pstruct.bptree import BPTree
from repro.pstruct.dll import DoublyLinkedList
from repro.pstruct.hashmap import Hashmap

SETTINGS = dict(max_examples=40, deadline=None,
                suppress_health_check=[HealthCheck.too_slow])


# ---------------------------------------------------------------- hashmap

hm_ops = st.lists(
    st.tuples(st.sampled_from(["insert", "remove", "crash"]),
              st.lists(st.integers(0, 200), min_size=1, max_size=20)),
    min_size=1, max_size=24)


@given(ops=hm_ops)
@settings(**SETTINGS)
def test_hashmap_matches_dict(ops):
    ref = {}
    lines = {}
    for mode in ("partly", "full"):
        a = open_arena(None, Hashmap.layout(1024, mode))
        h = Hashmap(a, 1024, mode)
        ref = {}
        for op, keys in ops:
            k = np.asarray(keys, np.int64)
            if op == "insert":
                v = np.stack([np.arange(7, dtype=np.int64) + kk for kk in k])
                h.insert_batch(k, v)
                for kk, vv in zip(k.tolist(), v):
                    ref[kk] = vv
            elif op == "remove":
                h.remove_batch(k)
                for kk in k.tolist():
                    ref.pop(kk, None)
            else:
                a.commit()
                a.crash()
                a.reopen()
                h.reconstruct()
            assert h.check_against(ref)
        lines[mode] = a.stats.lines
    assert lines["partly"] <= lines["full"]


# ---------------------------------------------------------------- bptree

bt_ops = st.lists(
    st.tuples(st.sampled_from(["insert", "delete", "crash"]),
              st.lists(st.integers(0, 400), min_size=1, max_size=30)),
    min_size=1, max_size=20)


@given(ops=bt_ops)
@settings(**SETTINGS)
def test_bptree_matches_dict(ops):
    for mode in ("partly", "full"):
        a = open_arena(None, BPTree.layout(1024, 4096, mode))
        t = BPTree(a, 1024, 4096, mode)
        ref = {}
        for op, keys in ops:
            k = np.asarray(keys, np.int64)
            if op == "insert":
                v = np.stack([np.arange(7, dtype=np.int64) * kk for kk in k])
                t.insert_batch(k, v)
                # batch dedup keeps last occurrence
                for kk, vv in zip(k.tolist(), v):
                    ref[kk] = vv
            elif op == "delete":
                t.delete_batch(k)
                for kk in k.tolist():
                    ref.pop(kk, None)
            else:
                a.commit()
                a.crash()
                a.reopen()
                t.reconstruct()
            t.check_invariants()
            if ref:
                rk = np.fromiter(ref.keys(), np.int64, len(ref))
                ok, vals = t.find_batch(rk)
                assert ok.all()
                want = np.stack([ref[int(x)] for x in rk])
                assert (vals == want).all()
            gone = np.asarray([x for x in range(0, 401, 37)
                               if x not in ref], np.int64)
            if gone.size:
                ok, _ = t.find_batch(gone)
                assert not ok.any()


# ---------------------------------------------------------------- dll

dll_ops = st.lists(
    st.tuples(st.sampled_from(["append", "pop", "crash"]),
              st.integers(1, 12)),
    min_size=1, max_size=24)


@given(ops=dll_ops)
@settings(**SETTINGS)
def test_dll_matches_list(ops):
    a = open_arena(None, DoublyLinkedList.layout(1024, "partly"))
    d = DoublyLinkedList(a, 1024, "partly")
    ref = []          # list of data rows in order
    ctr = 0
    for op, n in ops:
        if op == "append":
            vals = np.arange(n * 7, dtype=np.int64).reshape(n, 7) + ctr
            ctr += n * 7
            d.append_batch(vals)
            ref.extend(vals.tolist())
        elif op == "pop":
            m = min(n, len(ref))
            if m:
                d.pop_front_batch(m)
                ref = ref[m:]
        else:
            a.commit()
            a.crash()
            a.reopen()
            d.reconstruct()
        assert d.count == len(ref)
        if ref:
            order = d.to_list()
            assert d.data[order].tolist() == ref
            # prev chain is the exact mirror of next
            assert d.prev[order[0]] == -1
            assert (d.prev[order[1:]] == order[:-1]).all()


# ------------------------------------------- interleaved crash point

mixed_ops = st.lists(
    st.tuples(st.sampled_from(["dll", "bt", "hm"]), st.integers(1, 6)),
    min_size=2, max_size=12)


@given(ops=mixed_ops, frac=st.floats(0.0, 1.0),
       concurrency=st.sampled_from([1, 4]))
@settings(**SETTINGS)
def test_interleaved_crash_point_recovers_committed_prefix(
        ops, frac, concurrency):
    """Random interleaved DLL/B+Tree/Hashmap ops over fresh keys, one
    commit per op; a crash lands inside the op AFTER a randomly chosen
    boundary (power loss: nothing of the torn epoch flushed).  Recovery
    through the manager — at the drawn concurrency — must rebuild
    exactly the committed prefix for all three structures."""
    layout = {}
    layout.update(DoublyLinkedList.layout(128, "partly", name="dll"))
    layout.update(BPTree.layout(128, 512, "partly", name="bt"))
    layout.update(Hashmap.layout(256, "partly", name="hm"))
    a = open_arena(None, layout)
    d = DoublyLinkedList(a, 128, "partly", name="dll")
    t = BPTree(a, 128, 512, "partly", name="bt")
    h = Hashmap(a, 256, "partly", name="hm")

    boundary = min(int(frac * len(ops)), len(ops) - 1)
    key = 0
    dll_ref, bt_ref, hm_ref = [], {}, {}
    crashed_mid_op = False
    for i, (kind, m) in enumerate(ops):
        vals = (np.arange(m * 7, dtype=np.int64).reshape(m, 7)
                + 1000 * key)
        keys = np.arange(key, key + m, dtype=np.int64)
        key += m
        if i <= boundary:
            if kind == "dll":
                d.append_batch(vals)
                dll_ref.extend(vals.tolist())
            elif kind == "bt":
                t.insert_batch(keys, vals)
                bt_ref.update(zip(keys.tolist(), vals))
            else:
                h.insert_batch(keys, vals)
                hm_ref.update(zip(keys.tolist(), vals))
            a.commit()
        else:
            # the torn op: applied but never flushed nor committed
            with a.epoch():
                if kind == "dll":
                    d.append_batch(vals)
                elif kind == "bt":
                    t.insert_batch(keys, vals)
                else:
                    h.insert_batch(keys, vals)
                a.crash()
            crashed_mid_op = True
            break
    if not crashed_mid_op:
        a.crash()

    mgr = RecoveryManager(a)
    mgr.add("dll", "pstruct.dll", d)
    mgr.add("bt", "pstruct.bptree", t)
    mgr.add("hm", "pstruct.hashmap", h)
    report = mgr.recover(concurrency=concurrency)
    assert report.valid
    assert report.generation == boundary + 1

    # committed prefix, exactly
    assert d.count == len(dll_ref)
    if dll_ref:
        order = d.to_list()
        assert d.data[order].tolist() == dll_ref
    t.check_invariants()
    if bt_ref:
        ks = np.fromiter(bt_ref.keys(), np.int64, len(bt_ref))
        ok, got = t.find_batch(ks)
        assert ok.all()
        assert (got == np.stack([bt_ref[int(k)] for k in ks])).all()
    assert h.size == len(hm_ref)
    if hm_ref:
        ks = np.fromiter(hm_ref.keys(), np.int64, len(hm_ref))
        ok, got = h.find_batch(ks)
        assert ok.all()
        assert (got == np.stack([hm_ref[int(k)] for k in ks])).all()
    # torn keys must NOT surface (power-loss flavor: nothing flushed)
    if crashed_mid_op:
        torn = np.arange(key - ops[boundary + 1][1], key, dtype=np.int64)
        if ops[boundary + 1][0] == "bt":
            ok, _ = t.find_batch(torn)
            assert not ok.any()
        elif ops[boundary + 1][0] == "hm":
            ok, _ = h.find_batch(torn)
            assert not ok.any()


# ------------------------------------ chain list ranking (DESIGN.md §8)

def _random_chain(n, n_live, seed):
    rng = np.random.default_rng(seed)
    live = rng.permutation(n)[:n_live]
    nxt = np.full(n, -1, np.int64)
    nxt[live[:-1]] = live[1:]
    return nxt, live


def _scalar_order(nxt, head, count):
    out = np.empty(count, np.int64)
    cur = head
    for i in range(count):
        out[i] = cur
        cur = int(nxt[cur])
    return out


@given(n=st.integers(2, 400), frac=st.floats(0.05, 1.0),
       k=st.integers(2, 96), seed=st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_chain_ranking_strategies_equivalent(n, frac, k, seed):
    """The §8 equivalence: contraction list ranking == pointer doubling
    == the seed's scalar walk, on random chains, for every sampling
    stride — order (explicit and derived count), lengths, and walk."""
    from repro.core.recovery import chain_lengths, chain_order, chain_walk
    n_live = max(1, int(n * frac))
    nxt, live = _random_chain(n, n_live, seed)
    head = int(live[0])
    want = _scalar_order(nxt, head, n_live)
    for method in ("double", "contract"):
        got = chain_order(nxt, head, n_live, method=method, k=k)
        np.testing.assert_array_equal(got, want)
        got = chain_order(nxt, head, method=method, k=k)   # derived count
        np.testing.assert_array_equal(got, want)
        heads = np.asarray([head, live[n_live // 2], -1, n + 3], np.int64)
        np.testing.assert_array_equal(
            chain_lengths(nxt, heads, method=method, k=k),
            [n_live, n_live - n_live // 2, 0, 0])
    np.testing.assert_array_equal(
        chain_walk(nxt, np.asarray([head, -1]), method="contract", k=k),
        chain_walk(nxt, np.asarray([head, -1]), method="double"))


@given(n=st.integers(4, 40), frac=st.floats(0.3, 1.0),
       k=st.sampled_from([2, 4, 8]), B=st.sampled_from([4, 8]),
       seed=st.integers(0, 999))
@settings(max_examples=6, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_chain_ranking_device_matches_host_with_and_without_packing(
        n, frac, k, B, seed):
    """Device contraction == host primitive, on the flat layout AND the
    sharded shard-major packed layout (global pointer values steered
    through the closed-form packed-position translate).  Few examples:
    interpret-mode Pallas rounds are slow, and the deterministic
    test_kernels.py sweep already pins the edge cases."""
    from repro.core.recovery import chain_order as chain_order_np
    from repro.kernels import chain_order as CO
    n_live = max(1, int(n * frac))
    nxt, live = _random_chain(n, n_live, seed)
    head = int(live[0])
    want = chain_order_np(nxt, head)
    got = CO.chain_order_device(nxt, head, method="contract", k=k,
                                interpret=True)
    np.testing.assert_array_equal(got, want)
    # shard-major packed layout (DESIGN.md §7), N=3 shards
    N = 3
    shard_of = (np.arange(n) // B) % N
    segments = np.zeros(N + 1, np.int64)
    packed = np.empty(n, np.int64)
    off = 0
    for s in range(N):
        g = np.nonzero(shard_of == s)[0]
        packed[off:off + g.size] = nxt[g]
        segments[s] = off
        off += g.size
    segments[N] = off
    got = CO.chain_order_device(packed, head, segments=segments,
                                seg_rows=B, method="contract", k=k,
                                interpret=True)
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------- arena

@given(rows=st.lists(st.integers(0, 63), min_size=1, max_size=40),
       rowbytes_pow=st.integers(3, 7))
@settings(max_examples=30, deadline=None)
def test_arena_line_accounting(rows, rowbytes_pow):
    """Distinct-line accounting: flushing R unique rows of 2^k bytes costs
    exactly the number of distinct 64B lines those rows touch."""
    rowlen = 2 ** rowbytes_pow  # bytes per row (8..128)
    words = rowlen // 8
    a = open_arena(None, {"r": (np.int64, (64, words))})
    r = a.regions["r"]
    r.persist_rows(np.asarray(rows, np.int64))
    uniq = np.unique(rows)
    base = r.offset
    starts = (base + uniq * rowlen) // 64
    ends = (base + (uniq + 1) * rowlen - 1) // 64
    expect = len(set(int(x) for lo, hi in zip(starts, ends)
                     for x in range(lo, hi + 1)))
    assert a.stats.lines == expect
    assert a.stats.bytes == len(uniq) * rowlen


# ------------------------------ request journal (DESIGN.md §11)

from repro.serve.journal import (OP_ADMIT, OP_APPLY,  # noqa: E402
                                 OP_COMPLETE, ST_DONE, ST_RETRY,
                                 DuplicateRequestError, RequestJournal)

_JR_OPS = {"admit": OP_ADMIT, "complete": OP_COMPLETE, "apply": OP_APPLY}

jr_ops = st.lists(
    st.tuples(st.sampled_from(["admit", "complete", "apply",
                               "crash", "torn"]),
              st.integers(0, 15)),
    min_size=1, max_size=24)


def _jr_expected_error(vol, kind, rid):
    """The journal's admission state machine, as a pure reference."""
    if kind in ("admit", "apply"):
        return DuplicateRequestError if rid in vol else None
    if rid not in vol:
        return KeyError
    return DuplicateRequestError if vol[rid] == ST_DONE else None


def _jr_recover(a, j):
    a.reopen()
    mgr = RecoveryManager(a)
    mgr.add("journal", "serve.journal", j,
            regions=("jr.jrnl", "jr.jrnlheader"))
    mgr.recover()
    return dict(j.classify()), j.head, j.tail


@given(ops=jr_ops)
@settings(**SETTINGS)
def test_journal_random_interleaving_matches_reference(ops):
    """Random admit/complete/apply ops interleaved with power-loss and
    torn-flush crashes, one commit per op.  After every recovery the
    journal's classification must equal the committed prefix of the
    reference state machine (prefix consistency), and recovering twice
    must be bit-identical to recovering once (replay idempotence)."""
    a = open_arena(None, RequestJournal.layout(64, name="jr",
                                               standalone=True))
    j = RequestJournal(a, 64, name="jr")
    vol = {}                   # reference rid -> state, live volatile view
    committed = {}             # reference at the last committed epoch
    for kind, rid in ops:
        if kind in _JR_OPS:
            with a.epoch():
                err = _jr_expected_error(vol, kind, rid)
                if err is not None:
                    with pytest.raises(err):
                        j.log(_JR_OPS[kind], rid)
                else:
                    j.log(_JR_OPS[kind], rid)
                    vol[rid] = ST_DONE if kind != "admit" else (
                        ST_DONE if rid in vol else ST_RETRY)
                    if kind == "complete":
                        vol[rid] = ST_DONE
                a.commit()
            committed = dict(vol)
        elif kind == "crash":
            a.crash()
            got1 = _jr_recover(a, j)
            got2 = _jr_recover(a, j)       # idempotent
            assert got1 == got2
            assert got1[0] == committed
            vol = dict(committed)
        else:                              # torn: crash inside the epoch
            err = _jr_expected_error(vol, "admit", rid)
            with a.epoch():
                if err is None:
                    j.log(OP_ADMIT, rid)
                a.writeset.flush(include_meta=False)
                a.crash()
            got1 = _jr_recover(a, j)
            got2 = _jr_recover(a, j)
            assert got1 == got2
            # the torn entry is behind the committed HEAD: invisible
            assert got1[0] == committed
            vol = dict(committed)
        assert dict(j.classify()) == vol
    # final crash: whatever committed last is what recovery must yield
    a.crash()
    cls, head, tail = _jr_recover(a, j)
    assert cls == committed
    assert {r for r, s_ in cls.items() if s_ == ST_RETRY} == \
        j.must_retry()
