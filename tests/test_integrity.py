"""Integrity-checked arenas: media-fault injection, scrub, and salvage
recovery (DESIGN.md §13).

Invariant families:

* checksum unification: the snapshot record checksum, the journal batch
  checksum, and the integrity sidecar all speak ONE vectorized mixer
  (``core.arena.mix_checksums``);
* detection: a single flipped bit or stuck-at line in any COMMITTED
  data row is caught by ``Arena.scrub()`` (and by the paged fault path
  before a corrupt block is admitted), across both commit modes, 1 and
  4 shards, paged and resident — with zero false positives on clean
  arenas at every commit point (scrub under live traffic);
* corruption x crash double failure: a crash (power-loss or torn
  flavor) composed with a media fault must end detected-or-harmless —
  either scrub names the corruption, or recovery lands bit-identically
  to an uncorrupted twin;
* typed media losses: shard truncation/removal -> ``ShardLossError``
  at fresh open; scribbled header/manifest magic -> ``ManifestError``;
* salvage: ``recover(salvage=True)`` quarantines what corruption
  proves untrustworthy and recovers every other structure of a mixed
  arena; the serving layers refuse exactly the quarantined keys
  (``QuarantinedError``) until readmitted.
"""
import os

import numpy as np
import pytest

from repro.core import faultinject as fi
from repro.core.arena import (LINE, CorruptLineError, IntegrityError,
                              ManifestError, QuarantinedError,
                              ShardLossError, mix_checksums, open_arena,
                              sidecar_checksums, snap_checksum)
from repro.core.recovery import RecoveryManager
from repro.pstruct.bptree import BPTree
from repro.pstruct.dll import DoublyLinkedList
from repro.pstruct.hashmap import H_FRESH, KEY_NULL, Hashmap
from repro.serve.journal import _batch_cksum

N_SHARDS = int(os.environ.get("REPRO_N_SHARDS", "1"))
COMMIT_MODE = os.environ.get("REPRO_COMMIT_MODE", "barrier")

GRID = [("barrier", 1), ("barrier", 4), ("shadow", 1), ("shadow", 4)]


# ---------------------------------------------------------------- helpers


def _mixed(path, mode="partly", commit_mode=None, n_shards=None, **kw):
    layout = {}
    layout.update(DoublyLinkedList.layout(256, mode, name="dll"))
    layout.update(BPTree.layout(256, 1024, mode, name="bt"))
    layout.update(Hashmap.layout(512, mode, name="hm"))
    a = open_arena(path, layout,
                   n_shards=N_SHARDS if n_shards is None else n_shards,
                   commit_mode=commit_mode or COMMIT_MODE, **kw)
    return (a, DoublyLinkedList(a, 256, mode, name="dll"),
            BPTree(a, 256, 1024, mode, name="bt"),
            Hashmap(a, 512, mode, name="hm"))


def _script(n_ops, seed=0):
    rng = np.random.default_rng(seed)
    ops, key = [], 0
    for i in range(n_ops):
        m = int(rng.integers(2, 7))
        vals = rng.integers(0, 1 << 30, (m, 7)).astype(np.int64)
        keys = np.arange(key, key + m, dtype=np.int64)
        key += m
        ops.append(("dll" if i % 3 == 0 else ("bt" if i % 3 == 1 else "hm"),
                    keys, vals))
    return ops


def _apply(d, t, h, op):
    kind, keys, vals = op
    if kind == "dll":
        d.append_batch(vals)
    elif kind == "bt":
        t.insert_batch(keys, vals)
    else:
        h.insert_batch(keys, vals)


def _run(a, d, t, h, ops):
    for op in ops:
        with a.epoch():
            _apply(d, t, h, op)
        a.commit()


def _manager(a, d, t, h):
    mgr = RecoveryManager(a)
    mgr.add("dll", "pstruct.dll", d)
    mgr.add("bt", "pstruct.bptree", t)
    mgr.add("hm", "pstruct.hashmap", h)
    return mgr


def _fingerprint(d, t, h):
    """Full logical state of all three structures.  Region-byte
    comparison would be too strong: a flip in a never-flushed row is
    undetectable by design (the 0 sidecar sentinel) and lingers as
    dead-space garbage — harmless means the LOGICAL state matches."""
    fp = {"dll.values": np.asarray(d.to_list()).copy(),
          "bt.keys": t.keys_in_order().copy()}
    fresh = int(h.header.vol[0, H_FRESH])
    ks = np.asarray(h.keys[:fresh])
    vs = np.asarray(h.values[:fresh])
    live = ks != KEY_NULL
    o = np.argsort(ks[live], kind="stable")
    fp["hm.keys"] = ks[live][o].copy()
    fp["hm.values"] = np.asarray(vs)[live][o].copy()
    return fp


# --------------------------------------------- checksum unification


def test_checksum_helpers_agree():
    rng = np.random.default_rng(7)
    rows = rng.integers(-(1 << 60), 1 << 60, (32, 8)).astype(np.int64)
    # the journal batch checksum IS the shared mixer over words 0..6
    np.testing.assert_array_equal(_batch_cksum(rows),
                                  mix_checksums(rows[:, :7]))
    # the scalar snapshot checksum is its row-wise special case
    for r in rows[:4]:
        assert snap_checksum(r) == int(mix_checksums(r[None, :7])[0])
    # the sidecar vectorization agrees with the per-line mixer
    arr = rng.integers(-(1 << 60), 1 << 60, (16, 16)).astype(np.int64)
    sc = sidecar_checksums(arr, 2)          # 128 B rows = 2 lines
    assert sc.shape == (16, 2)
    for i in range(4):
        for c in range(2):
            want = int(mix_checksums(arr[i, c * 8:(c + 1) * 8][None])[0])
            got = int(sc[i, c])
            assert got == want or (want == 0 and got == 1)


def test_checksum_zero_is_reserved_sentinel():
    # a computed 0 must nudge away from the never-written sentinel
    z = np.zeros((4, 8), np.int64)
    assert (sidecar_checksums(z, 1) != 0).all()


# ----------------------------------------------------- detection


@pytest.mark.parametrize("commit_mode,n_shards", GRID)
@pytest.mark.parametrize("paged", [False, True])
def test_scrub_detects_flip_and_stuck_line(tmp_path, commit_mode,
                                           n_shards, paged):
    kw = dict(paged=True, block_bytes=256, cache_blocks=8) if paged else {}
    a, d, t, h = _mixed(str(tmp_path / "a.pm"), commit_mode=commit_mode,
                        n_shards=n_shards, **kw)
    _run(a, d, t, h, _script(12, seed=1))
    row = int(d.order()[2])
    a.crash()
    off = fi.flip_bits(a, a.regions["dll.nodes"], row, byte=8, mask=0x01)
    a.reopen()
    bad = a.scrub()
    assert list(bad) == ["dll.nodes"] and row in bad["dll.nodes"].tolist()
    fi.flip_bits(a, a.regions["dll.nodes"], row, byte=8, mask=0x01)  # undo
    assert a.scrub() == {}, "flip_bits is not an involution"
    # stuck-at line on a hashmap entry row
    hrow = 2
    fi.stuck_line(a, a.regions["hm.entries"], hrow, line=0, value=0xAB)
    bad = a.scrub()
    assert list(bad) == ["hm.entries"] and hrow in bad["hm.entries"].tolist()
    with pytest.raises(CorruptLineError):
        a.scrub(raise_on_error=True)
    assert off >= 0


@pytest.mark.parametrize("commit_mode", ["barrier", "shadow"])
def test_paged_fault_path_verifies_blocks(tmp_path, commit_mode):
    a, d, t, h = _mixed(str(tmp_path / "a.pm"), commit_mode=commit_mode,
                        n_shards=1, paged=True, block_bytes=256,
                        cache_blocks=4)
    _run(a, d, t, h, _script(12, seed=2))
    row = int(d.order()[1])
    a.crash()
    fi.flip_bits(a, a.regions["dll.nodes"], row, byte=8, mask=0x04)
    a.reopen()
    # a demand fault that assembles the corrupt row's block must refuse
    # to admit it
    with pytest.raises(CorruptLineError) as ei:
        a.regions["dll.nodes"].read_rows(np.array([row], np.int64))
    assert ei.value.region == "dll.nodes"
    assert row in np.asarray(ei.value.rows).tolist()


def test_integrity_off_layout_and_bytes_are_identical(tmp_path):
    """Integrity-off arenas lay out exactly the pre-integrity image:
    same region offsets, no sidecars, and bit-identical committed bytes
    for the same traffic (the sidecar is a pure suffix)."""
    ops = _script(10, seed=3)
    arenas = {}
    for integ in (False, True):
        a, d, t, h = _mixed(str(tmp_path / f"i{int(integ)}.pm"),
                            commit_mode="barrier", n_shards=1,
                            integrity=integ)
        _run(a, d, t, h, ops)
        arenas[integ] = a
    offs_off = {n: r.offset for n, r in arenas[False].regions.items()}
    offs_on = {n: r.offset for n, r in arenas[True].regions.items()
               if not n.endswith(".integ")}
    assert offs_off == offs_on
    assert not any(n.endswith(".integ") for n in arenas[False].regions)
    assert any(n.endswith(".integ") for n in arenas[True].regions)
    for n, r in arenas[False].regions.items():
        np.testing.assert_array_equal(
            np.asarray(arenas[True]._pimage(arenas[True].regions[n])),
            np.asarray(arenas[False]._pimage(r)), err_msg=n)
    assert arenas[False].stats.integrity_lines == 0
    assert arenas[True].stats.integrity_lines > 0
    assert arenas[True].stats.lines == arenas[False].stats.lines


# --------------------------------------- scrub under live traffic


@pytest.mark.parametrize("commit_mode,n_shards", GRID)
def test_scrub_under_traffic_no_false_positives(tmp_path, commit_mode,
                                                n_shards):
    """Data and sidecar always move in the same flush phase/bank, so a
    scrub between ANY two commits — and after any crash point — must
    come back clean."""
    a, d, t, h = _mixed(str(tmp_path / "a.pm"), commit_mode=commit_mode,
                        n_shards=n_shards)
    for i, op in enumerate(_script(10, seed=4)):
        with a.epoch():
            _apply(d, t, h, op)
        a.commit()
        assert a.scrub() == {}, f"false positive after commit {i}"
    # crash + recover, scrub stays clean
    a.crash()
    _manager(a, d, t, h).recover()
    assert a.scrub() == {}


def test_mid_scrub_crash_is_harmless(tmp_path):
    """Scrub is pure reads: crashing between per-region verify calls
    leaves nothing behind — recovery and a full re-scrub behave exactly
    as if the interrupted scrub never ran."""
    a, d, t, h = _mixed(str(tmp_path / "a.pm"), commit_mode="barrier",
                        n_shards=1)
    _run(a, d, t, h, _script(8, seed=5))
    covered = [n for n, r in a.regions.items() if r._integ is not None]
    assert len(covered) >= 2
    for n in covered[: len(covered) // 2]:     # half a scrub...
        assert a.verify_region(n).size == 0
    a.crash()                                  # ...then power loss
    rep = _manager(a, d, t, h).recover()
    assert rep.valid
    assert a.scrub() == {}


# -------------------------- corruption x crash double failure sweep


@pytest.mark.parametrize("commit_mode,n_shards", GRID)
@pytest.mark.parametrize("torn", [False, True])
def test_corruption_crash_double_failure(tmp_path, commit_mode, n_shards,
                                         torn):
    """Satellite sweep: compose a crash (power-loss or torn data-phase
    flavor) with a one-byte media fault in a data region and require
    DETECTED-OR-BIT-IDENTICAL — either scrub names the corruption, or
    the fault landed in dead bytes and recovery matches an uncorrupted
    twin bit-for-bit."""
    ops = _script(8, seed=6)
    targets = [("dll.nodes", 1), ("bt.nodes", 0), ("hm.entries", 0),
               ("dll.nodes", 200), ("hm.entries", 400)]  # dead tails too
    stage_of = {"dll.nodes": "dll", "bt.nodes": "bt", "hm.entries": "hm"}

    def _crash(a, d, t, h, boundary):
        _run(a, d, t, h, ops[: boundary + 1])
        if boundary + 1 < len(ops):
            with a.epoch():
                _apply(d, t, h, ops[boundary + 1])
                if torn:
                    a.writeset.flush(include_meta=False)
                a.crash()
        else:
            a.crash()

    for boundary in (3, len(ops) - 1):
        # twin A: same crash, no corruption
        a, d, t, h = _mixed(str(tmp_path / f"tw{boundary}.pm"),
                            commit_mode=commit_mode, n_shards=n_shards)
        _crash(a, d, t, h, boundary)
        _manager(a, d, t, h).recover()
        ref = _fingerprint(d, t, h)
        for j, (reg, row) in enumerate(targets):
            b, d2, t2, h2 = _mixed(
                str(tmp_path / f"b{boundary}.{j}.pm"),
                commit_mode=commit_mode, n_shards=n_shards)
            _crash(b, d2, t2, h2, boundary)
            fi.flip_bits(b, b.regions[reg], row, byte=3, mask=0x80)
            rep = _manager(b, d2, t2, h2).recover(salvage=True)
            named = set(rep.quarantined) | set(rep.degraded)
            if named:
                # DETECTED: only the struck structure may be named
                assert named == {stage_of[reg]}, (reg, row, named)
                bad = b.scrub()
                assert reg in bad and row in bad[reg].tolist(), \
                    (reg, row, bad)
                continue
            got = _fingerprint(d2, t2, h2)     # or HARMLESS
            assert set(got) == set(ref)
            for k in ref:
                np.testing.assert_array_equal(got[k], ref[k], err_msg=k)
            assert b.scrub() == {}             # dead-row flip: unseen


# ----------------------------------------------- typed media losses


def test_shard_loss_errors(tmp_path):
    path = str(tmp_path / "s.pm")
    a, d, t, h = _mixed(path, commit_mode="barrier", n_shards=4)
    _run(a, d, t, h, _script(8, seed=7))
    layout = {}
    layout.update(DoublyLinkedList.layout(256, "partly", name="dll"))
    layout.update(BPTree.layout(256, 1024, "partly", name="bt"))
    layout.update(Hashmap.layout(512, "partly", name="hm"))
    del a, d, t, h
    fi.truncate_shard(path, shard=2, nbytes=64)
    with pytest.raises(ShardLossError):
        open_arena(path, layout, n_shards=4, commit_mode="barrier")
    fi.remove_shard(path, shard=2)
    with pytest.raises(ShardLossError):
        open_arena(path, layout, n_shards=4, commit_mode="barrier")


@pytest.mark.parametrize("n_shards", [1, 4])
def test_manifest_errors(tmp_path, n_shards):
    a, d, t, h = _mixed(str(tmp_path / "m.pm"), commit_mode="barrier",
                        n_shards=n_shards)
    _run(a, d, t, h, _script(6, seed=8))
    a.crash()
    if n_shards > 1:
        fi.corrupt_manifest(a)
    else:
        fi.corrupt_header(a)
    with pytest.raises(ManifestError):
        a.verify_header()
    # garbage magic is fatal even in salvage: with no trustworthy
    # generation there is no committed prefix to salvage toward
    with pytest.raises(ManifestError):
        _manager(a, d, t, h).recover(salvage=True)
    assert issubclass(ManifestError, IntegrityError)
    assert issubclass(CorruptLineError, IntegrityError)
    assert issubclass(ShardLossError, IntegrityError)


# --------------------------------------------------------- salvage


@pytest.mark.parametrize("commit_mode,n_shards", GRID)
@pytest.mark.parametrize("victim", ["dll", "bt", "hm"])
def test_mixed_salvage_recovers_the_rest(tmp_path, commit_mode, n_shards,
                                         victim):
    """Acceptance: one corrupted slab of a mixed three-structure arena
    quarantines/degrades ONLY its own stage; the other two recover to
    their exact pre-crash state, and the report names the loss."""
    a, d, t, h = _mixed(str(tmp_path / "a.pm"), commit_mode=commit_mode,
                        n_shards=n_shards)
    _run(a, d, t, h, _script(30, seed=9))
    dll_order = d.order().copy()
    bt_keys = t.keys_in_order().copy()
    bt_leaves = t.leaves().copy()
    hm_size = int(h.size)
    a.crash()
    reg = {"dll": "dll.nodes", "bt": "bt.nodes", "hm": "hm.entries"}[victim]
    row = {"dll": int(dll_order[1]),
           "bt": int(bt_leaves[1]) if bt_leaves.size > 1
           else int(bt_leaves[0]),
           "hm": 3}[victim]
    fi.flip_bits(a, a.regions[reg], row, byte=8, mask=0x40)
    rep = _manager(a, d, t, h).recover(salvage=True)
    st = {s.name: s for s in rep.stages}
    assert st[victim].quarantined or st[victim].degraded, \
        st[victim].as_dict()
    assert victim in set(rep.quarantined) | set(rep.degraded)
    for other in ("dll", "bt", "hm"):
        if other == victim:
            continue
        assert other not in rep.quarantined
        assert other not in rep.degraded
    if victim != "dll":
        np.testing.assert_array_equal(d.order(), dll_order)
    if victim != "bt":
        np.testing.assert_array_equal(t.keys_in_order(), bt_keys)
    if victim != "hm":
        assert int(h.size) == hm_size
    # victim-specific salvage shape
    if victim == "dll":
        got = d.order()
        assert got.size < dll_order.size
        np.testing.assert_array_equal(got, dll_order[: got.size])
    elif victim == "bt":
        got = t.keys_in_order()
        assert set(got.tolist()) <= set(bt_keys.tolist())
        assert set(t.quarantined).isdisjoint(got.tolist())
    else:
        assert h.quarantined, "hashmap salvage named no keys"


def test_full_mode_tree_quarantines_wholesale(tmp_path):
    a, d, t, h = _mixed(str(tmp_path / "a.pm"), mode="full",
                        commit_mode="barrier", n_shards=1)
    _run(a, d, t, h, _script(30, seed=10))
    dll_order = d.order().copy()
    leaf = int(t.leaves()[0])
    a.crash()
    fi.flip_bits(a, a.regions["bt.nodes"], leaf, byte=8, mask=0x40)
    rep = _manager(a, d, t, h).recover(salvage=True)
    assert rep.quarantined == ["bt"]
    np.testing.assert_array_equal(d.order(), dll_order)


def test_salvage_off_still_aborts_nothing_silently(tmp_path):
    """Without salvage the corrupt stage keeps its pre-integrity
    behavior (possibly recovering garbage the scrub then names) — but
    nothing is EVER silent: on a paged arena the verifying fault path
    raises mid-recovery, on a resident one the scrub names the row."""
    a, d, t, h = _mixed(str(tmp_path / "a.pm"), commit_mode="barrier",
                        n_shards=1)
    _run(a, d, t, h, _script(12, seed=11))
    row = int(d.order()[1])
    a.crash()
    fi.flip_bits(a, a.regions["dll.nodes"], row, byte=8, mask=0x40)
    try:
        _manager(a, d, t, h).recover()       # plain recovery: no verify
    except CorruptLineError as e:            # paged fault path verifies
        assert e.region == "dll.nodes" and row in e.rows.tolist()
        return
    bad = a.scrub()                          # ...but scrub detects
    assert "dll.nodes" in bad and row in bad["dll.nodes"].tolist()


def test_quarantined_dependents_skip(tmp_path):
    """A stage whose dependency quarantined self-skips with a degraded
    report instead of reconstructing from untrusted inputs."""
    a, d, t, h = _mixed(str(tmp_path / "a.pm"), mode="full",
                        commit_mode="barrier", n_shards=1)
    _run(a, d, t, h, _script(12, seed=12))
    leaf = int(t.leaves()[0])
    a.crash()
    fi.flip_bits(a, a.regions["bt.nodes"], leaf, byte=8, mask=0x40)
    mgr = RecoveryManager(a)
    mgr.add("bt", "pstruct.bptree", t)
    mgr.add("dll", "pstruct.dll", d, depends=("bt",))
    rep = mgr.recover(salvage=True)
    st = {s.name: s for s in rep.stages}
    assert st["bt"].quarantined
    assert st["dll"].degraded
    assert st["dll"].detail.get("skipped") == "quarantined dependency"
    assert rep.quarantined == ["bt"] and rep.degraded == ["dll"]


# ------------------------------------------------- serving quarantine


def test_feature_store_refuses_only_quarantined_keys(tmp_path):
    from repro.serve.feature_store import FeatureConfig, FeatureStore
    fs = FeatureStore(FeatureConfig(n_keys=64, dim=3, n_samples=256,
                                    commit_mode=COMMIT_MODE,
                                    n_shards=N_SHARDS),
                      str(tmp_path / "fs.pm"))
    rng = np.random.default_rng(13)
    for rid in range(8):
        fs.apply(rid, np.array([rid * 3, rid * 3 + 1], np.int64),
                 rng.integers(0, 100, (2, 3)))
    keep = fs.lookup(np.array([3], np.int64)).copy()
    slot = int(fs.table._find_slots(np.array([0], np.int64))[0])
    fs.crash()
    fi.flip_bits(fs.arena, fs.arena.regions["emb.entries"], slot,
                 byte=16, mask=0x20)          # a VALUE word: key readable
    rep = fs.recover(salvage=True)
    assert {s.name: s for s in rep.stages}["emb"].degraded
    assert 0 in fs.quarantined_keys
    with pytest.raises(QuarantinedError):
        fs.lookup(np.array([0], np.int64))
    with pytest.raises(QuarantinedError):
        fs.apply(99, np.array([0], np.int64), np.zeros((1, 3), np.int64))
    np.testing.assert_array_equal(fs.lookup(np.array([3], np.int64)),
                                  keep)
    fs.readmit([0])
    fs.lookup(np.array([0], np.int64))       # fresh start, no raise


def test_feature_store_record_loss_names_keys_by_shortfall(tmp_path):
    from repro.serve.feature_store import FeatureConfig, FeatureStore
    fs = FeatureStore(FeatureConfig(n_keys=64, dim=3, n_samples=256),
                      str(tmp_path / "fs.pm"))
    rng = np.random.default_rng(14)
    for rid in range(8):
        fs.apply(rid, np.array([rid * 3, rid * 3 + 1], np.int64),
                 rng.integers(0, 100, (2, 3)))
    fs.crash()
    fi.flip_bits(fs.arena, fs.arena.regions["sx.records"], 4,
                 byte=24, mask=0x08)
    rep = fs.recover(salvage=True)
    st = {s.name: s for s in rep.stages}
    assert st["samples"].degraded or st["samples"].quarantined
    assert fs.quarantined_keys, "record loss named no keys"
    det = st["store"].detail
    assert det.get("skipped") or det.get("missing_samples", 0) > 0


def test_engine_rejects_only_quarantined_rids(tmp_path):
    import jax
    import jax.numpy as jnp

    from repro.configs import base, registry
    from repro.models.model import build
    from repro.serve.engine import EngineConfig, ServingEngine
    model = build(base.reduced(registry.get("llama3.2-3b")),
                  compute_dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params,
                        EngineConfig(max_batch=3, s_max=16,
                                     max_requests=16,
                                     commit_mode=COMMIT_MODE),
                        arena_path=str(tmp_path / "a"))
    eng.add_request(7, np.array([1, 2, 3], np.int64))
    eng.add_request(8, np.array([4, 5, 6, 9, 2], np.int64))
    eng.step()
    eng.crash()
    fi.flip_bits(eng.arena, eng.arena.regions["tokens"], 0,
                 byte=4, mask=0x10)          # rid 7's token-log row
    eng.recover(salvage=True)
    assert eng.quarantined_rids == {7}
    st = eng.last_recovery.stage("engine")
    assert st.degraded and st.detail["quarantined_rids"] == [7]
    out = eng.step()                          # rid 8 serves on
    assert 8 in out and 7 not in out
    with pytest.raises(QuarantinedError):
        eng.add_request(7, np.array([1, 2, 3], np.int64))
    eng.add_request(9, np.array([2, 2], np.int64))   # others admit fine
    eng.readmit([7])
    assert eng.quarantined_rids == set()
    if eng.journal is not None:
        # the abandoned rid's exactly-once accounting is closed
        assert eng.journal.state_of(7) == "completed"
