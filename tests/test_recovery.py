"""Unified recovery subsystem tests (core/recovery.py, DESIGN.md §6).

* chain primitives: chain_order/chain_lengths/chain_walk vs scalar-walk
  oracles, stale-count bounding, cycle detection;
* RecoveryManager: dependency ordering, validity check, staged timing;
* torn-epoch recovery: a mixed DLL/B+Tree/Hashmap workload sharing one
  arena is crashed at EVERY epoch boundary (extends test_writeset.py's
  single-structure crash test to all structures via the manager) —
  power-loss mid-epoch must recover the last committed generation
  byte-exactly for every structure; a crash at the data/metadata barrier
  must recover it for the count-bounded structures (DLL, Hashmap) and a
  valid superset state for the in-place-rewriting B+Tree.
"""
import os

import numpy as np
import pytest

from repro.core import reconstruct
from repro.core.arena import open_arena
from repro.core.recovery import (NULL, RecoveryManager, RecoveryReport,
                                 chain_lengths, chain_order, chain_walk)
from repro.pstruct.bptree import BPTree
from repro.pstruct.dll import DoublyLinkedList
from repro.pstruct.hashmap import Hashmap

MODES = ("partly", "full")


# ------------------------------------------------------- chain primitives


def _scalar_order(nxt, head, count):
    """The seed's sequential NEXT walk — oracle and bench baseline."""
    out = np.empty(count, np.int64)
    cur = head
    for i in range(count):
        out[i] = cur
        cur = int(nxt[cur])
    return out


def _random_chain(n, n_live, seed=0):
    rng = np.random.default_rng(seed)
    live = rng.permutation(n)[:n_live]
    nxt = np.full(n, NULL, np.int64)
    nxt[live[:-1]] = live[1:]
    return nxt, live


@pytest.mark.parametrize("n,n_live", [(16, 16), (300, 211), (4096, 1000)])
def test_chain_order_matches_scalar_walk(n, n_live):
    nxt, live = _random_chain(n, n_live, seed=n)
    head = int(live[0])
    want = _scalar_order(nxt, head, n_live)
    np.testing.assert_array_equal(chain_order(nxt, head, n_live), want)
    # count=None derives the length by pointer doubling
    np.testing.assert_array_equal(chain_order(nxt, head), want)
    np.testing.assert_array_equal(want, live)


def test_chain_order_stale_count_bounds_walk():
    """A committed count smaller than the volatile chain length walks only
    the committed prefix — the torn-epoch recovery guarantee."""
    nxt, live = _random_chain(64, 40, seed=9)
    got = chain_order(nxt, int(live[0]), 25)
    np.testing.assert_array_equal(got, live[:25])


def test_chain_lengths_multi_head():
    nxt, live = _random_chain(128, 70, seed=3)
    heads = np.array([live[0], live[10], live[69], NULL], np.int64)
    got = chain_lengths(nxt, heads)
    np.testing.assert_array_equal(got, [70, 60, 1, 0])


def test_chain_lengths_oob_head_is_empty_chain():
    """Heads outside [0, n) terminate like NULL — the module-wide OOB
    contract (a bucket head flushed past the fresh-water mark)."""
    nxt = np.full(8, NULL, np.int64)
    got = chain_lengths(nxt, np.array([0, 8, 100, NULL], np.int64))
    np.testing.assert_array_equal(got, [1, 0, 0, 0])


def test_chain_order_overlong_count_raises():
    """An explicit count past the chain end must fail loudly, not wrap
    NULL around as a numpy negative index."""
    nxt, live = _random_chain(32, 10, seed=4)
    with pytest.raises(ValueError, match="count exceeds"):
        chain_order(nxt, int(live[0]), 11)


def test_chain_lengths_detects_cycle():
    nxt = np.array([1, 2, 3, 1], np.int64)   # 1 -> 2 -> 3 -> 1
    with pytest.raises(RuntimeError, match="cycle"):
        chain_lengths(nxt, np.array([0]))


def test_chain_walk_materializes_all_chains():
    # two disjoint chains of different lengths + an empty head
    nxt = np.full(16, NULL, np.int64)
    nxt[[2, 5]] = [5, 7]              # 2 -> 5 -> 7
    nxt[3] = 9                        # 3 -> 9
    members = chain_walk(nxt, np.array([2, 3, NULL], np.int64))
    assert members.shape == (3, 3)
    np.testing.assert_array_equal(members[0], [2, 5, 7])
    np.testing.assert_array_equal(members[1], [3, 9, NULL])
    np.testing.assert_array_equal(members[2], [NULL, NULL, NULL])


# -------------------------------------- contraction list ranking (§8)


@pytest.mark.parametrize("method", ["double", "contract"])
@pytest.mark.parametrize("k", [2, 3, 7, 32, 1000])
def test_chain_order_method_parity(method, k):
    """contraction == doubling == scalar walk, any sampling stride —
    including k larger than the whole table (spine = heads only)."""
    nxt, live = _random_chain(300, 211, seed=k)
    head = int(live[0])
    want = _scalar_order(nxt, head, 211)
    np.testing.assert_array_equal(
        chain_order(nxt, head, 211, method=method, k=k), want)
    np.testing.assert_array_equal(
        chain_order(nxt, head, method=method, k=k), want)
    np.testing.assert_array_equal(
        chain_lengths(nxt, np.array([head, NULL]), method=method, k=k),
        [211, 0])


@pytest.mark.parametrize("method", ["double", "contract"])
def test_mid_chain_cycle_detected(method):
    """A cycle reachable only MID-chain (the head itself is not on it)
    must raise in both strategies: 0 -> 1 -> 2 -> 3 -> 1."""
    nxt = np.array([1, 2, 3, 1], np.int64)
    with pytest.raises(RuntimeError, match="cycle"):
        chain_order(nxt, 0, method=method)
    with pytest.raises(RuntimeError, match="cycle"):
        chain_lengths(nxt, np.array([0]), method=method)
    with pytest.raises(RuntimeError, match="cycle"):
        chain_walk(nxt, np.array([0]), method=method)


def test_mid_chain_spine_free_cycle_poisons_contract():
    """A mid-chain cycle that contains NO spine node (all its ids are
    off the k-stride) can't surface as a contracted-chain cycle — the
    local walk must poison the stuck segment instead of spinning, and
    the poisoned weight must still read as "cycle"."""
    nxt = np.full(64, NULL, np.int64)
    nxt[0] = 33                       # head 0 (spine) into the cycle:
    nxt[33], nxt[34], nxt[35] = 34, 35, 33   # 33/34/35 are all % 32 != 0
    with pytest.raises(RuntimeError, match="cycle"):
        chain_order(nxt, 0, method="contract", k=32)
    with pytest.raises(RuntimeError, match="cycle"):
        chain_lengths(nxt, np.array([0]), method="contract", k=32)


@pytest.mark.parametrize("method", ["double", "contract"])
def test_mid_chain_cycle_beyond_committed_count_recovers_prefix(method):
    """Torn-epoch shape: the committed prefix is a valid chain; a torn
    NEXT beyond it loops back.  An explicit committed count must bound
    the walk to the prefix WITHOUT tripping cycle detection — the
    stale-count recovery guarantee, preserved by the contraction path
    (only segments whose start lands inside [0, count) are expanded)."""
    nxt = np.array([1, 2, 3, 4, 2, NULL], np.int64)   # 4 -> 2 re-enters
    for count in (1, 2, 3):
        np.testing.assert_array_equal(
            chain_order(nxt, 0, count, method=method, k=2),
            [0, 1, 2][:count])


@pytest.mark.parametrize("k", [2, 4, 32])
def test_chain_walk_contract_matches_level_sync(k):
    rng = np.random.default_rng(k)
    nxt = np.full(400, NULL, np.int64)
    heads = []
    free = rng.permutation(400)
    at = 0
    for ln in (1, 7, 40, 113):        # four disjoint chains
        ids = free[at:at + ln]
        at += ln
        nxt[ids[:-1]] = ids[1:]
        heads.append(int(ids[0]))
    heads.append(NULL)
    heads.append(999)                 # OOB head: empty row
    want = chain_walk(nxt, np.asarray(heads), method="double")
    got = chain_walk(nxt, np.asarray(heads), method="contract", k=k)
    np.testing.assert_array_equal(got, want)


def test_chain_walk_auto_escalates_only_on_long_chains():
    """chain_walk "auto" on a big table must not pay contraction's
    O(n) passes for short chains (the hashmap-unlink hot path) but
    must still rank a proven-long chain correctly after escalating."""
    from repro.core.recovery import CONTRACT_MIN_N, _WALK_ESCALATE_ROUNDS
    n = CONTRACT_MIN_N
    nxt = np.full(n, NULL, np.int64)
    rng = np.random.default_rng(0)
    ids = rng.permutation(n)[:_WALK_ESCALATE_ROUNDS * 3]
    nxt[ids[:-1]] = ids[1:]          # one long chain, escalates
    short = np.asarray([int(ids[-1]), NULL])   # plus a length-1 chain
    long_heads = np.asarray([int(ids[0])])
    want = chain_walk(nxt, long_heads, method="contract")
    got = chain_walk(nxt, long_heads, method="auto")
    np.testing.assert_array_equal(got, want)
    # short chains resolve within the escalation budget (level-sync)
    np.testing.assert_array_equal(
        chain_walk(nxt, short, method="auto"),
        [[int(ids[-1])], [NULL]])
    with pytest.raises(ValueError, match="unknown chain method"):
        chain_walk(nxt, short, method="levelsync")


def test_chain_method_heuristic_and_override():
    from repro.core.recovery import (CONTRACT_MIN_COUNT, CONTRACT_MIN_N,
                                     chain_method)
    assert chain_method(CONTRACT_MIN_N - 1) == "double"
    assert chain_method(CONTRACT_MIN_N) == "contract"
    # tiny explicit counts stay on the doubling tables
    assert chain_method(CONTRACT_MIN_N, CONTRACT_MIN_COUNT - 1) == "double"
    assert chain_method(16, method="contract") == "contract"
    with pytest.raises(ValueError, match="unknown chain method"):
        chain_method(16, method="scalar")


# ------------------------------------------------------- RecoveryManager


def test_manager_orders_by_dependency_and_times_stages(rng):
    a = open_arena(None, DoublyLinkedList.layout(64, "partly"))
    d = DoublyLinkedList(a, 64, "partly")
    d.append_batch(rng.integers(0, 9, (10, 7)))
    a.commit()
    a.crash()

    ran = []

    @reconstruct.register("test.probe")
    def _probe(tag):
        ran.append(tag)
        return {"tag": tag}

    mgr = RecoveryManager(a)
    # registered out of order: declared dependencies must win
    mgr.add("late", "test.probe", "late", depends=("dll", "early"))
    mgr.add("early", "test.probe", "early")
    mgr.add("dll", "pstruct.dll", d, depends=("early",))
    assert mgr.order() == ["early", "dll", "late"]
    report = mgr.recover()
    assert ran == ["early", "late"]
    assert d.count == 10
    # staged report: reopen + one stage per recoverable, all timed
    assert [s.name for s in report.stages] == ["reopen", "early", "dll",
                                               "late"]
    assert all(s.seconds >= 0 for s in report.stages)
    assert report.stage("dll").detail["count"] == 10
    assert report.valid and report.generation == 1


def test_manager_reports_committed_generation_across_processes(tmp_path,
                                                               rng):
    """The report's generation comes from the persisted header, so a
    recovery in a fresh process (in-memory counter back at 0) still
    names the committed generation it restored."""
    path = str(tmp_path / "arena")
    a = open_arena(path, DoublyLinkedList.layout(32, "partly"))
    d = DoublyLinkedList(a, 32, "partly")
    for _ in range(3):
        d.append_batch(rng.integers(0, 9, (2, 7)))
        a.commit()
    a.close()
    a2 = open_arena(path, DoublyLinkedList.layout(32, "partly"))
    d2 = DoublyLinkedList(a2, 32, "partly")
    mgr = RecoveryManager(a2)
    mgr.add("dll", "pstruct.dll", d2)
    report = mgr.recover()
    assert report.valid and report.generation == 3
    assert a2.generation == 3              # reopen re-anchors the counter
    assert d2.count == 6


def test_manager_rejects_unknown_and_cyclic_dependencies():
    mgr = RecoveryManager()
    with pytest.raises(KeyError):
        mgr.add("x", "no.such.reconstructor", None)
    mgr.add("a", "rng", 0, depends=("b",))
    with pytest.raises(KeyError):
        mgr.order()                       # b unregistered
    mgr.add("b", "rng", 0, depends=("a",))
    with pytest.raises(ValueError, match="cycle"):
        mgr.order()


def test_manager_reports_uncommitted_arena_invalid(rng):
    a = open_arena(None, DoublyLinkedList.layout(32, "partly"))
    d = DoublyLinkedList(a, 32, "partly")
    d.append_batch(rng.integers(0, 9, (4, 7)))
    a.crash()                              # commit() never ran
    mgr = RecoveryManager(a)
    mgr.add("dll", "pstruct.dll", d)
    report = mgr.recover()
    # epoch flushes are durable (the structure recovers), but the
    # arena-level validity flag — checked once, by the manager — records
    # that no commit sealed them
    assert not report.valid
    assert d.count == 4


# --------------------------------------------------- torn-epoch recovery


def _mixed_arena(mode):
    # REPRO_N_SHARDS reruns the torn-epoch sweep on a sharded substrate
    # (the CI matrix axis, DESIGN.md §7)
    layout = {}
    layout.update(DoublyLinkedList.layout(256, mode, name="dll"))
    layout.update(BPTree.layout(256, 1024, mode, name="bt"))
    layout.update(Hashmap.layout(512, mode, name="hm"))
    a = open_arena(None, layout,
                   n_shards=int(os.environ.get("REPRO_N_SHARDS", "1")))
    return (a, DoublyLinkedList(a, 256, mode, name="dll"),
            BPTree(a, 256, 1024, mode, name="bt"),
            Hashmap(a, 512, mode, name="hm"))


def _script(n_ops, seed=0):
    """Mixed append/insert workload over fresh keys (torn-epoch-safe ops:
    nothing rewrites committed persistent rows destructively)."""
    rng = np.random.default_rng(seed)
    ops = []
    key = 0
    for i in range(n_ops):
        m = int(rng.integers(2, 7))
        vals = rng.integers(0, 1 << 30, (m, 7)).astype(np.int64)
        keys = np.arange(key, key + m, dtype=np.int64)
        key += m
        ops.append(("dll" if i % 3 == 0 else ("bt" if i % 3 == 1 else "hm"),
                    keys, vals))
    return ops


def _apply(d, t, h, op):
    kind, keys, vals = op
    if kind == "dll":
        d.append_batch(vals)
    elif kind == "bt":
        t.insert_batch(keys, vals)
    else:
        h.insert_batch(keys, vals)


def _state(d, t, h, bt_keys, hm_keys):
    order = d.to_list()
    ok_b, got_b = t.find_batch(np.asarray(bt_keys, np.int64)) \
        if bt_keys else (np.ones(0, bool), np.zeros((0, 7), np.int64))
    ok_h, got_h = h.find_batch(np.asarray(hm_keys, np.int64)) \
        if hm_keys else (np.ones(0, bool), np.zeros((0, 7), np.int64))
    return {"dll_order": order.copy(), "dll_data": d.data[order].copy(),
            "bt_count": t.header.vol[0, 3], "bt_ok": ok_b.copy(),
            "bt_vals": got_b.copy(), "hm_size": h.size,
            "hm_ok": ok_h.copy(), "hm_vals": got_h.copy()}


def _recover_all(a, d, t, h):
    mgr = RecoveryManager(a)
    mgr.add("dll", "pstruct.dll", d)
    mgr.add("bt", "pstruct.bptree", t)
    mgr.add("hm", "pstruct.hashmap", h)
    return mgr.recover()


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("torn", [False, True])
def test_crash_at_every_epoch_boundary_recovers_committed_state(mode, torn):
    """Replay a 12-op mixed workload; for every boundary b, crash during
    op b+1 — either before anything flushed (torn=False: power loss
    mid-epoch) or after the data half flushed but not the metadata half
    (torn=True) — recover via the manager, and compare against the state
    captured at boundary b."""
    ops = _script(12)
    n = len(ops)
    for boundary in range(n):
        a, d, t, h = _mixed_arena(mode)
        bt_keys, hm_keys = [], []
        snap = None
        for i in range(boundary + 1):
            _apply(d, t, h, ops[i])
            kind, keys, _ = ops[i]
            (bt_keys if kind == "bt" else hm_keys if kind == "hm"
             else []).extend(keys.tolist())
            a.commit()
        snap = _state(d, t, h, bt_keys, hm_keys)
        gen0 = a.generation
        # crash inside the NEXT op's epoch
        if boundary + 1 < n:
            with a.epoch():
                _apply(d, t, h, ops[boundary + 1])
                if torn:
                    a.writeset.flush(include_meta=False)
                a.crash()
        else:
            a.crash()
        report = _recover_all(a, d, t, h)
        assert report.valid and a.generation == gen0
        got = _state(d, t, h, bt_keys, hm_keys)
        # DLL + Hashmap: the committed COUNT / fresh-water mark bounds
        # the recovered state in both crash flavors — byte-exact last
        # committed generation even when the torn op touched them.
        np.testing.assert_array_equal(got["dll_order"], snap["dll_order"])
        np.testing.assert_array_equal(got["dll_data"], snap["dll_data"])
        assert got["hm_size"] == snap["hm_size"]
        assert got["hm_ok"].all() and snap["hm_ok"].all()
        np.testing.assert_array_equal(got["hm_vals"], snap["hm_vals"])
        bt_torn = (torn and boundary + 1 < n
                   and ops[boundary + 1][0] == "bt")
        if bt_torn:
            # the torn epoch's data half rewrote committed leaf rows in
            # place — the documented B+Tree asymmetry: keys still found
            # must carry committed values, strict equality is not owed
            found = got["bt_ok"]
            np.testing.assert_array_equal(got["bt_vals"][found],
                                          snap["bt_vals"][found])
        else:
            t.check_invariants()
            assert got["bt_ok"].all()
            np.testing.assert_array_equal(got["bt_vals"], snap["bt_vals"])
            assert got["bt_count"] == snap["bt_count"]


def test_torn_bptree_leaf_rewrite_is_visible_but_durable(rng):
    """Documents the asymmetry the boundary sweep allows for: a B+Tree
    insert rewrites committed leaf rows in place, so the data half of a
    torn epoch IS reachable after recovery — committed keys stay durable
    with committed values, but the torn keys surface and the committed
    COUNT goes stale (which is why check_invariants is not owed here,
    unlike the count-bounded DLL/Hashmap)."""
    a, d, t, h = _mixed_arena("partly")
    keys = np.arange(40, dtype=np.int64)
    vals = rng.integers(0, 9, (40, 7)).astype(np.int64)
    t.insert_batch(keys, vals)
    a.commit()
    torn_keys = np.arange(40, 45, dtype=np.int64)
    with a.epoch():
        t.insert_batch(torn_keys,
                       rng.integers(0, 9, (5, 7)).astype(np.int64))
        a.writeset.flush(include_meta=False)
        a.crash()
    _recover_all(a, d, t, h)
    ok, got = t.find_batch(keys)
    assert ok.all()
    np.testing.assert_array_equal(got, vals)
    ok_torn, _ = t.find_batch(torn_keys)
    assert ok_torn.all()                       # torn rewrite surfaced
    assert int(t.header.vol[0, 3]) == 40       # committed COUNT is stale


# ------------------------------------------------ serving recovery report


def test_engine_recovery_report_has_dependency_ordered_stages(tmp_path):
    import jax.numpy as jnp

    from repro.configs import base, registry
    from repro.models.model import build
    from repro.serve.engine import EngineConfig, ServingEngine
    import jax

    model = build(base.reduced(registry.get("llama3.2-3b")),
                  compute_dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, EngineConfig(max_batch=2, s_max=16,
                                                    max_requests=16),
                        arena_path=str(tmp_path / "a"))
    eng.add_request(7, np.array([1, 2, 3], np.int64))
    eng.add_request(8, np.array([4, 5, 6], np.int64))   # same prompt length
    eng.step()
    eng.crash()
    dt = eng.recover()
    assert dt >= 0
    rep = eng.last_recovery
    names = [s.name for s in rep.stages]
    expect = {"reopen", "req_table", "lru", "pages", "engine"}
    if eng.journal is not None:          # REPRO_JOURNAL-dependent stage
        expect.add("journal")
    assert set(names) == expect
    # reopen is the prologue; the engine stage depends on everything else
    assert names[0] == "reopen" and names[-1] == "engine"
    assert rep.stage("engine").detail["requests"] == 2
    # equal-length prompts re-prefill as ONE batched group
    assert rep.stage("engine").detail["prefill_groups"] == 1
    assert rep.total_seconds >= rep.seconds("engine")


def test_paged_allocator_recovery_report(tmp_path):
    from repro.serve.kvcache import PagedAllocator, PagedConfig
    pa = PagedAllocator(PagedConfig(n_pages=16, page_tokens=4),
                        path=str(tmp_path / "pg"))
    pa.alloc(1, 5)
    pa.arena.commit()
    pa.arena.crash()
    sec = pa.recover()
    assert sec >= 0
    rep = pa.last_recovery
    assert [s.name for s in rep.stages] == ["reopen", "lru", "pages"]
    assert rep.stage("pages").detail["pages_live"] == 5
    assert rep.stage("pages").detail["pages_free"] == 11
