"""Unit tests for the three partly-persistent structures (paper §IV).

Every test runs BOTH modes and asserts:
  * functional equivalence with a pure-python reference,
  * crash + reconstruct restores exactly the live state (§V-G),
  * partly persists strictly fewer flush lines than fully (§V-B..D).
"""
import numpy as np
import pytest

from repro.core.arena import open_arena
from repro.pstruct.bptree import BPTree
from repro.pstruct.dll import DoublyLinkedList, order_from_next
from repro.pstruct.hashmap import Hashmap

MODES = ("partly", "full")


# ---------------------------------------------------------------- DLL


def make_dll(mode, cap=512):
    a = open_arena(None, DoublyLinkedList.layout(cap, mode))
    return a, DoublyLinkedList(a, cap, mode)


@pytest.mark.parametrize("mode", MODES)
def test_dll_append_pop_delete(mode, rng):
    a, d = make_dll(mode)
    ids1 = d.append_batch(rng.integers(0, 99, (20, 7)))
    assert d.count == 20 and d.head == ids1[0] and d.tail == ids1[-1]
    popped = d.pop_front_batch(5)
    assert (popped == ids1[:5]).all() and d.count == 15
    d.delete_batch(ids1[10:12])
    assert d.count == 13
    order = d.to_list()
    want = [i for i in ids1.tolist() if i not in
            set(ids1[:5].tolist()) | set(ids1[10:12].tolist())]
    assert order.tolist() == want
    # slot reuse after free
    ids2 = d.append_batch(rng.integers(0, 99, (6, 7)))
    assert set(ids2.tolist()) & (set(popped.tolist())
                                 | set(ids1[10:12].tolist()))


@pytest.mark.parametrize("mode", MODES)
def test_dll_crash_reconstruct(mode, rng):
    a, d = make_dll(mode)
    ids = d.append_batch(rng.integers(0, 99, (50, 7)))
    d.pop_front_batch(7)
    d.delete_batch(ids[20:30])
    order0, prev0, tail0 = d.to_list().copy(), d.prev.copy(), d.tail
    data0 = d.data.copy()
    a.commit()
    a.crash()
    assert (d.nodes.vol == 0).all()          # volatile state really gone
    a.reopen()
    d.reconstruct()
    order1 = d.to_list()
    live = np.zeros(d.capacity, bool)
    live[order1] = True
    assert (order1 == order0).all()
    assert (d.prev[live] == prev0[live]).all()
    assert d.tail == tail0
    assert (d.data[order1] == data0[order0]).all()


def test_dll_partly_flushes_fewer_lines(rng):
    vals = rng.integers(0, 99, (200, 7))
    lines = {}
    for mode in MODES:
        a, d = make_dll(mode, cap=256)
        d.append_batch(vals)
        lines[mode] = a.stats.lines
    # partly: 1 line/node; fully: 2 lines/node (prev on the 2nd line)
    assert lines["partly"] < lines["full"]
    assert lines["full"] >= 2 * (lines["partly"] - 2)


def test_order_from_next_matches_walk(rng):
    n = 64
    perm = rng.permutation(n)
    nxt = np.full(n, -1, np.int64)
    nxt[perm[:-1]] = perm[1:]
    got = order_from_next(nxt, int(perm[0]), n)
    assert (got == perm).all()


# ---------------------------------------------------------------- B+Tree


def make_bt(mode, cap_nodes=2048, cap_recs=8192):
    a = open_arena(None, BPTree.layout(cap_nodes, cap_recs, mode))
    return a, BPTree(a, cap_nodes, cap_recs, mode)


@pytest.mark.parametrize("mode", MODES)
def test_bptree_insert_find_delete(mode, rng):
    a, t = make_bt(mode)
    keys = rng.permutation(2000).astype(np.int64)
    vals = rng.integers(0, 1 << 40, (2000, 7)).astype(np.int64)
    for i in range(0, 2000, 137):
        t.insert_batch(keys[i:i + 137], vals[i:i + 137])
    t.check_invariants()
    ok, got = t.find_batch(keys)
    assert ok.all() and (got == vals).all()
    # update-in-place
    t.insert_batch(keys[:10], vals[:10] + 1)
    _, got = t.find_batch(keys[:10])
    assert (got == vals[:10] + 1).all()
    # delete
    rm = t.delete_batch(keys[:500])
    assert rm.all()
    t.check_invariants()
    ok, _ = t.find_batch(keys[:500])
    assert not ok.any()
    ok, got = t.find_batch(keys[500:])
    assert ok.all() and (got == vals[500:]).all()


@pytest.mark.parametrize("mode", MODES)
def test_bptree_crash_reconstruct(mode, rng):
    a, t = make_bt(mode)
    keys = rng.permutation(3000).astype(np.int64)
    vals = rng.integers(0, 1 << 40, (3000, 7)).astype(np.int64)
    t.insert_batch(keys, vals)
    t.delete_batch(keys[:777])
    a.commit()
    a.crash()
    a.reopen()
    t.reconstruct()
    t.check_invariants()
    ok, got = t.find_batch(keys[777:])
    assert ok.all() and (got == vals[777:]).all()
    ok, _ = t.find_batch(keys[:777])
    assert not ok.any()
    # structure is writable after reconstruction (free lists correct)
    t.insert_batch(keys[:777], vals[:777])
    t.check_invariants()
    ok, _ = t.find_batch(keys)
    assert ok.all()


def test_bptree_partly_flushes_fewer_lines(rng):
    keys = rng.permutation(4000).astype(np.int64)
    vals = rng.integers(0, 9, (4000, 7)).astype(np.int64)
    lines = {}
    for mode in MODES:
        a, t = make_bt(mode, 4096, 8192)
        for i in range(0, 4000, 100):
            t.insert_batch(keys[i:i + 100], vals[i:i + 100])
        lines[mode] = a.stats.lines
    assert lines["partly"] < lines["full"]


# ---------------------------------------------------------------- Hashmap


def make_hm(mode, cap=4096):
    a = open_arena(None, Hashmap.layout(cap, mode))
    return a, Hashmap(a, cap, mode)


@pytest.mark.parametrize("mode", MODES)
def test_hashmap_insert_find_remove(mode, rng):
    a, h = make_hm(mode)
    keys = rng.choice(10 ** 6, 3000, replace=False).astype(np.int64)
    vals = rng.integers(0, 1 << 40, (3000, 7)).astype(np.int64)
    h.insert_batch(keys, vals)
    assert h.size == 3000
    ok, got = h.find_batch(keys)
    assert ok.all() and (got == vals).all()
    # update
    h.insert_batch(keys[:50], vals[:50] * 2)
    _, got = h.find_batch(keys[:50])
    assert (got == vals[:50] * 2).all()
    # absent keys
    ok, _ = h.find_batch(keys[:10] + 10 ** 7)
    assert not ok.any()
    # remove
    rm = h.remove_batch(keys[:1000])
    assert rm.all() and h.size == 2000
    ok, _ = h.find_batch(keys[:1000])
    assert not ok.any()


@pytest.mark.parametrize("mode", MODES)
def test_hashmap_crash_reconstruct(mode, rng):
    a, h = make_hm(mode)
    keys = rng.choice(10 ** 6, 2500, replace=False).astype(np.int64)
    vals = rng.integers(0, 1 << 40, (2500, 7)).astype(np.int64)
    h.insert_batch(keys, vals)
    h.remove_batch(keys[:500])
    ref = {int(k): vals[i] for i, k in enumerate(keys) if i >= 500}
    a.commit()
    a.crash()
    a.reopen()
    h.reconstruct()
    assert h.check_against(ref)
    # writable post-reconstruction
    h.insert_batch(keys[:500], vals[:500])
    ok, got = h.find_batch(keys)
    assert ok.all() and (got == vals).all()


def test_hashmap_partly_flushes_fewer_lines(rng):
    keys = rng.choice(10 ** 6, 3000, replace=False).astype(np.int64)
    vals = rng.integers(0, 9, (3000, 7)).astype(np.int64)
    lines = {}
    for mode in MODES:
        a, h = make_hm(mode)
        h.insert_batch(keys, vals)
        h.remove_batch(keys[:500])
        lines[mode] = a.stats.lines
    assert lines["partly"] < lines["full"]


# ------------------------------------------------- corruption (paper §V-G)


@pytest.mark.parametrize("mode", ["partly"])
def test_corruption_before_flush_not_persisted(mode, rng):
    """The paper's §V-G experiment: volatile corruption injected before a
    flush must not reach persistent state; recovery restores the last
    committed state exactly."""
    a, d = make_dll(mode)
    ids = d.append_batch(rng.integers(0, 99, (30, 7)))
    a.commit()
    order0, data0 = d.to_list().copy(), d.data.copy()
    # corrupt volatile structure WITHOUT flushing: next points to itself
    d.nodes.vol[ids[5], 7] = ids[5]
    d.prev[ids[3]] = ids[3]
    a.crash()
    a.reopen()
    d.reconstruct()
    order1 = d.to_list()
    assert (order1 == order0).all()
    assert (d.data[order1] == data0[order1]).all()
