"""Per-architecture smoke tests: REDUCED same-family configs, one
forward/train step + one prefill/decode step on CPU, asserting shapes and
finiteness (assignment requirement (f))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base, registry
from repro.models.model import build
from repro.optim.adamw import AdamWConfig, init_moments, update
from repro.optim.schedule import WarmupCosine

ARCHS = list(registry.ARCHS)


def make_batch(cfg, b, s, key=0):
    k = jax.random.PRNGKey(key)
    toks = jax.random.randint(k, (b, s), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    if cfg.family == "audio":
        batch["frames"] = 0.02 * jax.random.normal(
            k, (b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["context"] = 0.02 * jax.random.normal(
            k, (b, cfg.context_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch):
    cfg = base.reduced(registry.get(arch))
    model = build(cfg, compute_dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 16)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    # loss near ln(vocab) at random init (sanity of scale)
    assert 0.5 * np.log(cfg.vocab) < float(loss) < 2.5 * np.log(cfg.vocab)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                      for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gn)) and float(gn) > 0
    # one optimizer step moves the loss
    opt = AdamWConfig()
    mu, nu = init_moments(params, opt)
    p2, *_ = update(params, grads, mu, nu, jnp.zeros((), jnp.int32),
                    WarmupCosine()(jnp.ones(())), opt)
    loss2 = model.loss(p2, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_prefill_decode(arch):
    cfg = base.reduced(registry.get(arch))
    model = build(cfg, compute_dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 12)
    logits, cache = model.prefill(params, batch, s_max=16)
    assert logits.shape == (2, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits)).all()
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for step in range(2):
        logits, cache = model.decode_step(params, cache, tok,
                                          jnp.asarray(12 + step, jnp.int32))
        assert logits.shape == (2, cfg.vocab_padded)
        assert np.isfinite(np.asarray(logits)).all()
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch):
    """Incremental decoding must agree with full-prefill logits."""
    cfg = base.reduced(registry.get(arch))
    model = build(cfg, compute_dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 12)
    full, _ = model.prefill(params, batch, s_max=16)
    b11 = dict(batch)
    b11["tokens"] = batch["tokens"][:, :11]
    _, kv = model.prefill(params, b11, s_max=16)
    inc, _ = model.decode_step(params, kv, batch["tokens"][:, 11],
                               jnp.asarray(11, jnp.int32))
    # MoE: prefill routes groups under a capacity bound (tokens can be
    # dropped); single-token decode never drops => inherent small diff.
    tol = 0.08 if cfg.moe is not None else 1e-4
    np.testing.assert_allclose(np.asarray(inc), np.asarray(full),
                               atol=tol, rtol=tol)


def test_exact_configs_match_assignment():
    spec = {
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
    }
    for name, (L, d, h, kv, dff, vocab) in spec.items():
        c = registry.get(name)
        assert c.n_layers == L and c.d_model == d, name
        assert c.n_heads == h and c.n_kv_heads == kv, name
        assert c.vocab == vocab, name
        if c.moe is not None and c.moe.expert_d_ff:
            assert c.moe.expert_d_ff == dff, name
        else:
            assert c.d_ff == dff, name
    assert registry.get("dbrx-132b").moe.n_experts == 16
    assert registry.get("dbrx-132b").moe.top_k == 4
    assert registry.get("llama4-maverick-400b-a17b").moe.n_experts == 128
    assert registry.get("llama4-maverick-400b-a17b").moe.top_k == 1
    assert registry.get("hymba-1.5b").ssm.state_dim == 16


def test_cell_support_rules():
    cells = registry.all_cells()
    assert len(cells) == 40
    skipped = [(c.name, s.name) for c, s in cells
               if not registry.cell_supported(c, s)[0]]
    # exactly the 8 full-attention archs skip long_500k
    assert len(skipped) == 8
    assert all(s == "long_500k" for _, s in skipped)
    assert ("hymba-1.5b", "long_500k") not in skipped
    assert ("xlstm-1.3b", "long_500k") not in skipped
