"""Checkpoint manager: policies, commit protocol, reconstruction,
quantized persist, incremental skip, elastic restore spec."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.manager import CheckpointManager
from repro.ckpt.manifest import CheckpointCatalog
from repro.core import policy as pol
from repro.train.state import TrainState, new_state


def tiny_state(seed=0):
    k = jax.random.PRNGKey(seed)
    params = {"w": jax.random.normal(k, (32, 16)),
              "b": jnp.zeros((16,))}
    mu = jax.tree.map(jnp.zeros_like, params)
    nu = jax.tree.map(jnp.zeros_like, params)
    st = new_state(params, mu, nu, seed=7)
    # keep the DERIVABLE-rng invariant: rng == fold_in(PRNGKey(seed), step)
    return st._replace(step=jnp.asarray(42, jnp.int32),
                       rng=jax.random.fold_in(jax.random.PRNGKey(7), 42))


def state_spec(st):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), st)


def test_policy_classification():
    st = tiny_state()
    plans = {p.path: p for p in pol.plan(st.as_dict(), pol.PARTLY_PERSISTENT)}
    assert plans["params/w"].kind == pol.Kind.ESSENTIAL
    assert plans["mu/w"].kind == pol.Kind.APPROXIMABLE
    assert plans["rng"].kind == pol.Kind.DERIVABLE
    assert not plans["rng"].persisted
    assert plans["params/w"].persisted


def test_partly_persists_fewer_bytes():
    st = tiny_state().as_dict()
    full = pol.persisted_bytes(st, pol.FULLY_PERSISTENT)
    partly = pol.persisted_bytes(st, pol.PARTLY_PERSISTENT)
    drop = pol.persisted_bytes(st, pol.PARTLY_DROP)
    q8 = pol.persisted_bytes(st, pol.PARTLY_Q8)
    assert drop < q8 < partly < full


@pytest.mark.parametrize("policy", [pol.FULLY_PERSISTENT,
                                    pol.PARTLY_PERSISTENT])
def test_save_restore_bitexact(tmp_path, policy):
    st = tiny_state()
    mgr = CheckpointManager(str(tmp_path), policy)
    rep = mgr.save(st)
    assert rep.step == 42 and rep.bytes_written > 0
    got = mgr.restore(state_spec(st))
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_reconstructs_rng(tmp_path):
    """rng is DERIVABLE: never written, rebuilt as fold_in(seed, step)."""
    st = tiny_state()
    st = st._replace(rng=jax.random.fold_in(jax.random.PRNGKey(7), 42))
    mgr = CheckpointManager(str(tmp_path), pol.PARTLY_PERSISTENT)
    mgr.save(st)
    with open(os.path.join(str(tmp_path), "manifest.json")) as f:
        manifest = json.load(f)
    assert "rng" not in manifest["leaves"]
    got = mgr.restore(state_spec(st))
    np.testing.assert_array_equal(np.asarray(got.rng), np.asarray(st.rng))


def test_quantized_moments_bounded_error(tmp_path):
    st = tiny_state()
    st = st._replace(mu=jax.tree.map(
        lambda x: jax.random.normal(jax.random.PRNGKey(1), x.shape),
        st.mu))
    mgr = CheckpointManager(str(tmp_path), pol.PARTLY_Q8)
    rep = mgr.save(st)
    assert rep.quantized
    got = mgr.restore(state_spec(st))
    # params bit-exact, moments within int8 blockwise error
    np.testing.assert_array_equal(np.asarray(got.params["w"]),
                                  np.asarray(st.params["w"]))
    err = np.max(np.abs(np.asarray(got.mu["w"]) - np.asarray(st.mu["w"])))
    amax = np.max(np.abs(np.asarray(st.mu["w"])))
    assert err <= amax / 127 * 1.01


def test_drop_policy_rewarns_moments(tmp_path):
    st = tiny_state()
    st = st._replace(nu=jax.tree.map(lambda x: x + 3.0, st.nu))
    mgr = CheckpointManager(str(tmp_path), pol.PARTLY_DROP)
    mgr.save(st)
    got = mgr.restore(state_spec(st))
    assert float(jnp.sum(jnp.abs(got.nu["w"]))) == 0.0


def test_manifest_last_commit(tmp_path):
    """A crash before the manifest rename leaves the PREVIOUS checkpoint
    fully valid (the paper's flag-bit ordering)."""
    st = tiny_state()
    mgr = CheckpointManager(str(tmp_path), pol.PARTLY_PERSISTENT)
    mgr.save(st)
    st2 = st._replace(step=jnp.asarray(43, jnp.int32),
                      params=jax.tree.map(lambda x: x + 1, st.params))
    # simulate crash mid-write: leaf tmp files written, manifest NOT renamed
    sd = st2.as_dict()
    from repro.ckpt.manager import _leaf_file
    for pth, leaf in jax.tree_util.tree_flatten_with_path(sd)[0]:
        pstr = pol.path_str(pth)
        if pstr.startswith("params"):
            fp = os.path.join(str(tmp_path), _leaf_file(pstr) + ".tmp")
            with open(fp, "wb") as f:
                np.savez(f, x=np.asarray(leaf))
    got = mgr.restore(state_spec(st))
    assert int(got.step) == 42  # previous checkpoint intact
    np.testing.assert_array_equal(np.asarray(got.params["w"]),
                                  np.asarray(st.params["w"]))


def test_incremental_skips_unchanged(tmp_path):
    st = tiny_state()
    mgr = CheckpointManager(str(tmp_path), pol.PARTLY_PERSISTENT,
                            incremental=True)
    r1 = mgr.save(st)
    assert r1.bytes_skipped_unchanged == 0
    st2 = st._replace(step=jnp.asarray(43, jnp.int32))  # params unchanged
    r2 = mgr.save(st2)
    assert r2.bytes_skipped_unchanged > 0
    assert r2.bytes_written < r1.bytes_written
    got = mgr.restore(state_spec(st2))
    np.testing.assert_array_equal(np.asarray(got.params["w"]),
                                  np.asarray(st.params["w"]))
    assert int(got.step) == 43


def test_async_save_equivalent(tmp_path):
    st = tiny_state()
    mgr = CheckpointManager(str(tmp_path), pol.PARTLY_PERSISTENT)
    mgr.save(st, blocking=False)
    mgr.wait()
    got = mgr.restore(state_spec(st))
    np.testing.assert_array_equal(np.asarray(got.params["w"]),
                                  np.asarray(st.params["w"]))


def test_catalog_roundtrip(tmp_path):
    path = str(tmp_path / "cat.arena")
    cat = CheckpointCatalog(path)
    for s in (10, 20, 30):
        cat.record(s, s // 10, 1000 * s, 5)
    assert cat.latest()[0] == 30
    assert cat.steps().tolist() == [10, 20, 30]
    # crash + reopen: inner nodes rebuilt from leaves
    cat.arena.crash()
    cat2 = CheckpointCatalog(path)
    assert cat2.steps().tolist() == [10, 20, 30]
    assert cat2.latest()[0] == 30


def test_elastic_restore_reshards(tmp_path):
    """A checkpoint saved without shardings restores under a target-mesh
    sharding spec (the elastic-scaling path: restore onto a different
    mesh = same code, different NamedShardings)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    st = tiny_state()
    mgr = CheckpointManager(str(tmp_path), pol.PARTLY_PERSISTENT)
    mgr.save(st)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), state_spec(st))
    got = mgr.restore(state_spec(st), shardings=sh)
    np.testing.assert_array_equal(np.asarray(got.params["w"]),
                                  np.asarray(st.params["w"]))
    assert got.params["w"].sharding.mesh.shape == {"data": 1, "model": 1}
