"""Structural validation of every dry-run cell WITHOUT compiling.

Uses AbstractMesh (no device initialization) to build all 40+ (arch x
shape x mesh) cells and asserts:
* arg_specs and in_shardings are congruent pytrees,
* every sharded dim divides its mesh-axis product,
* decode cells lower serve_step-shaped inputs, train cells TrainState.

This catches the whole class of sharding-tree bugs the 512-device
dry-run would hit, in seconds.
"""
import jax
import numpy as np
import pytest
from jax.sharding import AbstractMesh, NamedSharding

from repro.configs import base, registry
from repro.launch import specs as S


@pytest.fixture(autouse=True)
def _reset_sharding_hooks():
    """build_cell sets module-level sharding hooks (MoE dispatch,
    activation/seq-parallel constraints, LOWP reduces) against the
    AbstractMesh; reset them so later numeric tests trace clean."""
    yield
    from repro.dist import mesh as dmesh
    from repro.models import layers as L
    from repro.models import moe
    moe.set_sharding(None, None)
    dmesh.set_activation_sharding(None)
    dmesh.set_seq_parallel(None, None, None)
    dmesh.set_fsdp_axes("data")
    L.LOWP_ROW_REDUCE["on"] = False


def make_abstract_mesh(multi_pod: bool):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    try:
        return AbstractMesh(shape, axes)
    except TypeError:
        pass
    try:
        return AbstractMesh(axis_sizes=shape, axis_names=axes)
    except TypeError:
        # older signature: one tuple of (name, size) pairs
        return AbstractMesh(tuple(zip(axes, shape)))


def _axis_prod(mesh, spec_entry):
    axes = spec_entry if isinstance(spec_entry, tuple) else (spec_entry,)
    n = 1
    for a in axes:
        n *= dict(mesh.shape)[a]
    return n


CELLS = [(c.name, s.name, mp)
         for c, s in registry.all_cells()
         if registry.cell_supported(c, s)[0]
         for mp in (False, True)]


@pytest.mark.parametrize("arch,shape,multi", CELLS)
def test_cell_spec_congruence(arch, shape, multi):
    cfg = registry.get(arch)
    sh = base.SHAPES[shape]
    mesh = make_abstract_mesh(multi)
    cell = S.build_cell(cfg, sh, mesh)
    assert cell.kind == {"train": "train", "prefill": "prefill",
                         "decode": "decode"}[sh.kind]
    specs_leaves = jax.tree.leaves(cell.arg_specs)
    shard_leaves = jax.tree.leaves(
        cell.in_shardings,
        is_leaf=lambda x: isinstance(x, NamedSharding))
    assert len(specs_leaves) == len(shard_leaves), \
        "arg_specs / in_shardings tree mismatch"
    # congruent structure (raises on mismatch)
    jax.tree.map(lambda a, b: None, cell.arg_specs, cell.in_shardings,
                 is_leaf=lambda x: isinstance(x, NamedSharding))
    for spec, shard in zip(specs_leaves, shard_leaves):
        for dim, entry in enumerate(shard.spec):
            if entry is None:
                continue
            n = _axis_prod(mesh, entry)
            assert spec.shape[dim] % n == 0, \
                (arch, shape, spec.shape, shard.spec, dim)


def test_all_40_cells_enumerated():
    cells = registry.all_cells()
    assert len(cells) == 40
    runnable = [1 for c, s in cells if registry.cell_supported(c, s)[0]]
    assert len(runnable) == 32  # 8 long_500k skips
