"""Paged regions & block cache (core/paging.py, DESIGN.md §12).

Invariant families:

* region selection: only data regions bigger than one block page fault
  through the cache — headers, order snapshots, and journal rings stay
  resident; paging is strictly volatile-side, so a paged arena's
  persistent files are BYTE-identical to an unpaged arena's for the
  same op trace (both commit modes, sharded and single);
* LRU discipline: clean blocks evict at the budget, dirty blocks are
  pinned until the write-set drain (the epoch flush IS the write-back
  path) — an all-pinned cache goes over budget rather than drop the
  only copy of unflushed rows;
* crash contract: a crashed paged region reads ZEROS (never stale
  committed bytes) until reopen/load re-authorizes faulting;
* eviction + write-back under crash sweeps: forced post-commit drops
  (``drop_clean``) and organic tiny-cache eviction never change what
  recovery reconstructs — fingerprints match the unpaged reference at
  every epoch boundary, in both commit modes;
* the spill fallback (full-``.vol`` consumers) is correct, counted,
  and exits paged mode until the next load;
* recovery reports per-stage ``block_faults`` on paged arenas.
"""
import numpy as np
import pytest

from repro.core.arena import open_arena
from repro.core.paging import BlockCache, PagedRegion, PagedShardedRegion
from repro.core.recovery import RecoveryManager
from repro.pstruct.dll import DoublyLinkedList

MODES = ("barrier", "shadow")


def _paged_kw(cache_blocks=4, block_bytes=512):
    return dict(paged=True, block_bytes=block_bytes,
                cache_blocks=cache_blocks)


# --------------------------------------------------- region selection


def test_eligibility_and_roundtrip():
    a = open_arena(None, {"r": (np.int64, (64, 8)),
                          "r.header": (np.int64, (1, 8)),
                          "r.snapring": (np.int64, (64, 8)),
                          "jr.jrnl": (np.int64, (64, 8)),
                          "tiny": (np.int64, (4, 8))}, **_paged_kw())
    r = a.regions["r"]
    assert isinstance(r, PagedRegion) and r.is_paged
    # headers / snapshots / journal rings / sub-block regions stay
    # resident no matter their size
    for name in ("r.header", "r.snapring", "jr.jrnl", "tiny"):
        assert not getattr(a.regions[name], "is_paged", False), name
    data = np.arange(64 * 8, dtype=np.int64).reshape(64, 8)
    r.write_rows(np.arange(64), data)
    np.testing.assert_array_equal(r.read_rows(np.arange(64)), data)
    assert r.read_one(13, 5) == data[13, 5]
    np.testing.assert_array_equal(r.read_at(np.array([3, 60]), 2),
                                  data[[3, 60], 2])
    np.testing.assert_array_equal(r.read_col(1), data[:, 1])
    assert a.cache.faults == r.total_blocks   # 64 rows / 8 per block
    assert a.cache.hits > 0


def test_scattered_reads_cross_blocks():
    a = open_arena(None, {"r": (np.int64, (200, 8))}, **_paged_kw(64))
    r = a.regions["r"]
    data = np.random.default_rng(0).integers(0, 99, (200, 8))
    r.write_rows(np.arange(200), data)
    rng = np.random.default_rng(1)
    for _ in range(5):
        rows = rng.integers(0, 200, 37)
        np.testing.assert_array_equal(r.read_rows(rows), data[rows])
        np.testing.assert_array_equal(r.read_at(rows, slice(2, 5)),
                                      data[rows, 2:5])
    assert r.read_rows(np.empty(0, np.int64)).shape == (0, 8)


# ------------------------------------------------- pinning & eviction


def test_dirty_blocks_pinned_until_flush():
    a = open_arena(None, {"r": (np.int64, (64, 8))},
                   **_paged_kw(cache_blocks=1))
    r = a.regions["r"]
    cache = a.cache
    r.write_rows(np.array([0]), np.arange(8))    # block 0 dirty
    r.write_rows(np.array([8]), np.arange(8))    # block 1 dirty
    # both dirty -> neither evictable -> cache rides over budget
    assert cache.over_budget >= 1
    assert cache.resident_bytes > cache.capacity_bytes
    assert r._block_pinned(0) and r._block_pinned(1)
    with a.epoch():
        r.mark_rows(np.array([0, 8]))
    # drained -> unpinned -> free drops
    assert not r._block_pinned(0) and not r._block_pinned(1)
    dropped = cache.drop_clean()
    assert dropped == 2 and cache.resident_bytes == 0
    # refault reads back the flushed values
    np.testing.assert_array_equal(r.read_rows(np.array([0, 8])),
                                  np.broadcast_to(np.arange(8), (2, 8)))


def test_clean_blocks_evict_at_budget():
    a = open_arena(None, {"r": (np.int64, (64, 8))},
                   **_paged_kw(cache_blocks=2))
    r = a.regions["r"]
    data = np.random.default_rng(2).integers(0, 99, (64, 8))
    r.write_rows(np.arange(64), data)
    with a.epoch():
        r.mark_rows(np.arange(64))
    a.commit()
    a.cache.drop_clean()
    base = a.cache.evictions
    over0 = a.cache.over_budget   # the pinned bulk write above rode
    for bid in range(r.total_blocks):           # sequential sweep
        r.read_one(bid * r._block_rows, 0)
    assert a.cache.evictions > base
    assert a.cache.resident_bytes <= a.cache.capacity_bytes
    assert a.cache.over_budget == over0   # clean sweep never over-rides
    np.testing.assert_array_equal(r.read_rows(np.arange(64)), data)


# ------------------------------------------------------ crash contract


def test_crashed_region_reads_zeros_until_reopen(tmp_path):
    a = open_arena(str(tmp_path / "a"), {"r": (np.int64, (64, 8))},
                   **_paged_kw())
    r = a.regions["r"]
    data = np.random.default_rng(3).integers(1, 99, (64, 8))
    r.write_rows(np.arange(64), data)
    with a.epoch():
        r.mark_rows(np.arange(64))
    a.commit()
    a.crash()
    # volatile state is GONE: reads must NOT resurrect committed bytes
    assert (r.read_rows(np.arange(64)) == 0).all()
    assert (r.vol == 0).all()                   # spill path also zeros
    a.reopen()
    np.testing.assert_array_equal(r.read_rows(np.arange(64)), data)


# ------------------------------------------------------ spill fallback


def test_spill_fallback_roundtrip(tmp_path):
    a = open_arena(str(tmp_path / "a"), {"r": (np.int64, (64, 8))},
                   **_paged_kw())
    r = a.regions["r"]
    data = np.random.default_rng(4).integers(0, 99, (64, 8))
    r.write_rows(np.arange(32), data[:32])      # dirty resident rows
    full = r.vol                                # full-array consumer
    assert a.cache.spills == 1
    assert not r.paged_active
    np.testing.assert_array_equal(full[:32], data[:32])
    # post-spill the region behaves like an unpaged one until reload
    r.vol[32:] = data[32:]
    with a.epoch():
        r.mark_rows(np.arange(64))
    a.commit()
    a.crash()
    a.reopen()                                  # load() re-enters paging
    assert r.paged_active
    np.testing.assert_array_equal(r.read_rows(np.arange(64)), data)


# ------------------------------- paged/unpaged parity & byte identity


def _dll_trace(a, d, n_epochs, crash_tail=False):
    """Deterministic append/delete trace, one commit per epoch; with
    ``crash_tail`` adds uncommitted work that a crash must discard."""
    rng = np.random.default_rng(7)
    live = []
    for e in range(n_epochs):
        ids = d.append_batch(rng.integers(0, 99, (7, 7)))
        live.extend(int(i) for i in ids)
        if e % 2 and len(live) > 6:
            dead = [live.pop(0) for _ in range(3)]
            d.delete_batch(np.asarray(dead, np.int64))
        a.commit()
    if crash_tail:
        d.append_batch(rng.integers(0, 99, (3, 7)))


def _dll_fingerprint(d):
    order = d.to_list()
    return order.copy(), d.data[order].copy()


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("n_shards", [1, 3])
def test_persistent_files_bit_identical_paged_vs_unpaged(
        tmp_path, mode, n_shards):
    """Paging is volatile-only: the same op trace must land the same
    bytes in every backing file (shards + manifest), either mode."""
    blobs = {}
    for paged in (False, True):
        root = tmp_path / f"paged{int(paged)}"
        root.mkdir()
        ap = str(root / "a")
        a = open_arena(ap, DoublyLinkedList.layout(256, "partly"),
                       n_shards=n_shards, commit_mode=mode,
                       **(_paged_kw() if paged else {"paged": False}))
        d = DoublyLinkedList(a, 256, "partly")
        _dll_trace(a, d, 6)
        files = {p.name: p.read_bytes() for p in sorted(root.iterdir())
                 if not p.name.endswith(".layout")}
        blobs[paged] = files
    assert blobs[False].keys() == blobs[True].keys()
    for name in blobs[False]:
        assert blobs[False][name] == blobs[True][name], \
            f"{name} diverged under paging"


@pytest.mark.parametrize("mode", MODES)
def test_evict_then_crash_sweep_every_epoch_boundary(tmp_path, mode):
    """At every epoch boundary: commit, force-drop every clean block,
    run an uncommitted tail, crash.  Recovery must reconstruct the
    boundary's committed state bit-identically to an unpaged reference
    crashed at the same point."""
    for k in range(1, 6):
        fps = {}
        for paged in (False, True):
            ap = str(tmp_path / f"{mode}.{k}.{int(paged)}")
            a = open_arena(ap, DoublyLinkedList.layout(96, "partly"),
                           commit_mode=mode,
                           **(_paged_kw(cache_blocks=3) if paged
                              else {"paged": False}))
            d = DoublyLinkedList(a, 96, "partly")
            _dll_trace(a, d, k, crash_tail=True)
            if paged:
                assert a.cache.drop_clean() > 0
            a.crash()
            a.reopen()
            d.reconstruct()
            fps[paged] = _dll_fingerprint(d)
        np.testing.assert_array_equal(fps[False][0], fps[True][0])
        np.testing.assert_array_equal(fps[False][1], fps[True][1])


@pytest.mark.parametrize("mode", MODES)
def test_organic_eviction_crash_recovery(tmp_path, mode):
    """A cache far smaller than the working set evicts continuously
    during the trace (no forced drops); recovery is still exact."""
    ap = str(tmp_path / "a")
    a = open_arena(ap, DoublyLinkedList.layout(96, "partly"),
                   commit_mode=mode, **_paged_kw(cache_blocks=2))
    d = DoublyLinkedList(a, 96, "partly")
    _dll_trace(a, d, 8, crash_tail=True)
    assert a.cache.evictions > 0, "cache never evicted — not exercised"
    fp0 = None
    a.crash()
    a.reopen()
    d.reconstruct()
    fp0 = _dll_fingerprint(d)
    # unpaged reference
    a2 = open_arena(str(tmp_path / "b"),
                    DoublyLinkedList.layout(96, "partly"),
                    commit_mode=mode, paged=False)
    d2 = DoublyLinkedList(a2, 96, "partly")
    _dll_trace(a2, d2, 8, crash_tail=True)
    a2.crash()
    a2.reopen()
    d2.reconstruct()
    fp1 = _dll_fingerprint(d2)
    np.testing.assert_array_equal(fp0[0], fp1[0])
    np.testing.assert_array_equal(fp0[1], fp1[1])


# ----------------------------------------------------- sharded paging


@pytest.mark.parametrize("router", [("seg", 8), ("hash",), ("range",)])
def test_sharded_paged_roundtrip(router):
    a = open_arena(None, {"r": (np.int64, (103, 8), router),
                          "r.header": (np.int64, (1, 8))},
                   n_shards=3, **_paged_kw())
    r = a.regions["r"]
    assert isinstance(r, PagedShardedRegion)
    data = np.random.default_rng(5).integers(0, 99, (103, 8))
    r.write_rows(np.arange(103), data)
    a.regions["r.header"].vol[0, 0] = 42
    with a.epoch():
        r.mark_rows(np.arange(103))
        a.regions["r.header"].mark_rows(np.array([0]))
    a.commit()
    a.crash()
    assert (r.read_rows(np.arange(103)) == 0).all()
    a.reopen()
    np.testing.assert_array_equal(r.read_rows(np.arange(103)), data)
    assert a.regions["r.header"].vol[0, 0] == 42


# ------------------------------------------------- recovery reporting


def test_recovery_report_carries_block_faults(tmp_path):
    a = open_arena(str(tmp_path / "a"),
                   DoublyLinkedList.layout(96, "partly"), **_paged_kw())
    d = DoublyLinkedList(a, 96, "partly")
    _dll_trace(a, d, 4)
    a.crash()
    rep = RecoveryManager(a).add("dll", "pstruct.dll", d).recover()
    st = {s.name: s.detail for s in rep.stages}
    assert "block_faults" in st["dll"]
    # lazy load: the reconstructor faults blocks, the reset doesn't
    assert st["dll"]["block_faults"] > 0
    assert a.cache.faults >= st["dll"]["block_faults"]
    np.testing.assert_array_equal(*(_dll_fingerprint(d)[0],
                                    d.to_list()))


def test_cache_counters_consistent():
    c = BlockCache(block_bytes=512, cache_blocks=2)
    assert c.capacity_bytes == 1024
    c.reset_peak()
    assert c.peak_resident_bytes == c.resident_bytes == 0
