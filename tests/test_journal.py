"""Request journal unit tests (serve/journal.py, DESIGN.md §11) plus
the feature store's replay reconstructor (serve/feature_store.py).

Covered here:

* append / classify roundtrip across crash+recover, both commit modes
  and shard counts (CI env axes);
* the admission state machine: duplicate ADMIT/APPLY refused,
  COMPLETE without ADMIT refused, appends outside an epoch refused,
  ring-full refused until ``retire_completed`` frees slots;
* crash-window visibility: a torn (data-phase-only) append recovers as
  never-admitted in barrier mode and a pre-flip crash does the same in
  shadow mode — the entry bytes may be durable, the committed HEAD is
  not past them;
* the sealing rule: a wrapped append may destroy a RETIRED entry's
  slot without committing; recovery must skip the seq-mismatched slot
  and an orphaned COMPLETE must still classify its rid as completed;
* journal-off identity: with REPRO_JOURNAL=0 (or journal=False) the
  feature store lays out NO journal regions, every shared region keeps
  its offset, and the flushed line/byte counts are bit-identical to
  the journal-on run minus exactly the ring lines — the overhead bound
  (<= 1 journal line per epoch) the CI matrix asserts.
"""
import os

import numpy as np
import pytest

from repro.core.arena import journal_enabled, open_arena
from repro.core.recovery import RecoveryManager
from repro.serve.feature_store import FeatureConfig, FeatureStore
from repro.serve.journal import (JR_MAGIC, OP_ADMIT, OP_APPLY, OP_COMPLETE,
                                 ST_DONE, ST_NEVER, ST_RETRY,
                                 DuplicateRequestError, RequestJournal,
                                 args_digest, snap_checksum)

N_SHARDS = int(os.environ.get("REPRO_N_SHARDS", "1"))
COMMIT_MODE = os.environ.get("REPRO_COMMIT_MODE", "barrier")


def _jr(cap=64, commit_mode=None):
    """Standalone journal (own .jrnlheader line) on a fresh arena."""
    a = open_arena(None, RequestJournal.layout(cap, name="jr",
                                               standalone=True),
                   n_shards=N_SHARDS,
                   commit_mode=commit_mode or COMMIT_MODE)
    return a, RequestJournal(a, cap, name="jr")


def _recover(a, j):
    a.reopen()
    mgr = RecoveryManager(a)
    mgr.add("journal", "serve.journal", j,
            regions=("jr.jrnl", "jr.jrnlheader"))
    rep = mgr.recover()
    assert rep.valid
    return rep.stage("journal").detail


# ------------------------------------------------------------ state machine


def test_roundtrip_classify_across_crash():
    a, j = _jr()
    with a.epoch():
        j.log(OP_ADMIT, 1, digest=args_digest([1, 2, 3]))
        j.log(OP_ADMIT, 2)
        a.commit()
    with a.epoch():
        j.log(OP_COMPLETE, 1)
        j.log(OP_APPLY, 3)
        a.commit()
    a.crash()
    detail = _recover(a, j)
    assert detail["entries"] == 4 and detail["skipped"] == 0
    assert j.classify() == {1: ST_DONE, 2: ST_RETRY, 3: ST_DONE}
    assert j.must_retry() == {2}
    assert j.state_of(99) == ST_NEVER


def test_duplicate_admission_raises():
    a, j = _jr()
    with a.epoch():
        j.log(OP_ADMIT, 5)
        a.commit()
    with a.epoch():
        with pytest.raises(DuplicateRequestError):
            j.log(OP_ADMIT, 5)
        with pytest.raises(DuplicateRequestError):
            j.log(OP_APPLY, 5)
        j.log(OP_COMPLETE, 5)
        # completed is STILL a known rid inside the dedup window
        with pytest.raises(DuplicateRequestError):
            j.log(OP_ADMIT, 5)
        with pytest.raises(DuplicateRequestError):
            j.log(OP_COMPLETE, 5)
        a.commit()


def test_complete_without_admit_raises():
    a, j = _jr()
    with a.epoch():
        with pytest.raises(KeyError):
            j.log(OP_COMPLETE, 7)
        a.commit()


def test_log_outside_epoch_refused():
    a, j = _jr()
    with pytest.raises(AssertionError):
        j.log(OP_ADMIT, 1)


def test_unknown_op_refused():
    a, j = _jr()
    with a.epoch():
        with pytest.raises(ValueError):
            j.log(0, 1)
        a.commit()


# ------------------------------------------------------------ crash windows


def test_torn_append_recovers_as_never_admitted():
    """Data-phase-only flush: the ring line may be durable but the
    committed HEAD is not past it — the op must classify never-admitted
    (this is the exactly-once crash window, both commit modes)."""
    a, j = _jr()
    with a.epoch():
        j.log(OP_ADMIT, 1)
        a.commit()
    with a.epoch():
        j.log(OP_ADMIT, 2)
        a.writeset.flush(include_meta=False)
        a.crash()
    detail = _recover(a, j)
    assert detail["window"] == 1
    assert j.state_of(1) == ST_RETRY
    assert j.state_of(2) == ST_NEVER
    # the retry is not a duplicate
    with a.epoch():
        j.log(OP_ADMIT, 2)
        a.commit()
    assert j.state_of(2) == ST_RETRY


def test_uncommitted_epoch_recovers_clean():
    a, j = _jr()
    with a.epoch():
        j.log(OP_ADMIT, 1)
        a.commit()
    with a.epoch():
        j.log(OP_ADMIT, 2)
        j.log(OP_COMPLETE, 1)
        a.crash()
    _recover(a, j)
    assert j.classify() == {1: ST_RETRY}


def test_recover_twice_is_idempotent():
    a, j = _jr()
    with a.epoch():
        j.log(OP_ADMIT, 1)
        j.log(OP_APPLY, 2)
        a.commit()
    a.crash()
    d1 = _recover(a, j)
    c1, h1, t1 = dict(j.classify()), j.head, j.tail
    d2 = _recover(a, j)
    assert (d1, c1, h1, t1) == (d2, dict(j.classify()), j.head, j.tail)


# ------------------------------------------------- ring wrap + sealing rule


def test_ring_full_then_retire_and_wrap():
    a, j = _jr(cap=4)
    for rid in range(4):
        with a.epoch():
            j.log(OP_APPLY, rid)
            a.commit()
    with a.epoch():
        with pytest.raises(MemoryError):
            j.log(OP_ADMIT, 4)
        a.commit()
    assert j.space() == 0
    assert j.retire_completed() == 4
    assert j.space() == 4
    with a.epoch():
        j.log(OP_ADMIT, 5)       # seq 4 -> wraps onto slot 0
        a.commit()
    with a.epoch():              # torn second lap append
        j.log(OP_ADMIT, 6)
        a.writeset.flush(include_meta=False)
        a.crash()
    _recover(a, j)
    assert j.state_of(5) == ST_RETRY
    assert j.state_of(6) == ST_NEVER
    assert j.state_of(0) == ST_NEVER     # retired: out of the window


def test_retire_inside_epoch_refused():
    a, j = _jr()
    with a.epoch():
        j.log(OP_APPLY, 1)
        with pytest.raises(AssertionError):
            j.retire_completed()
        a.commit()


def test_sealing_rule_skips_destroyed_retired_slot():
    """A wrapped TORN append destroys slot 0's retired first-lap entry
    while the committed window still spans it (ADMIT retired, its
    COMPLETE not yet).  Recovery must skip the seq-mismatched slot and
    the orphaned COMPLETE must still classify rid 0 as completed."""
    a, j = _jr(cap=4)
    with a.epoch():
        j.log(OP_ADMIT, 0)       # seq 0 -> slot 0
        j.log(OP_ADMIT, 1)       # seq 1
        a.commit()
    with a.epoch():
        j.log(OP_COMPLETE, 0)    # seq 2
        j.log(OP_COMPLETE, 1)    # seq 3
        a.commit()
    j.retire_completed()         # volatile TAIL -> 4; committed TAIL
    assert j.tail == 4           # still 0 until the next log's line
    with a.epoch():
        j.log(OP_ADMIT, 5)       # seq 4 -> slot 0, overwrites rid 0's ADMIT
        a.writeset.flush(include_meta=False)
        a.crash()
    detail = _recover(a, j)
    # committed window is still [0, 4); slot 0 holds the torn lap-2 bytes
    assert detail["window"] == 4
    assert detail["skipped"] == 1
    assert j.state_of(0) == ST_DONE      # orphaned COMPLETE suffices
    assert j.state_of(1) == ST_DONE
    assert j.state_of(5) == ST_NEVER


def test_checksum_rejects_corrupt_entry():
    a, j = _jr()
    with a.epoch():
        j.log(OP_ADMIT, 1)
        j.log(OP_ADMIT, 2)
        a.commit()
    # flip one digest word of entry 0 directly in "persistent memory"
    row = np.array(j.ring.vol[0])
    assert row[0] == JR_MAGIC and row[7] == snap_checksum(row)
    row[4] ^= 1
    j.ring.vol[0] = row
    j.ring.persist_rows(np.array([0]))
    a.crash()
    detail = _recover(a, j)
    assert detail["skipped"] == 1
    assert j.state_of(1) == ST_NEVER
    assert j.state_of(2) == ST_RETRY


def test_args_digest_is_order_and_length_sensitive():
    assert args_digest([1, 2, 3]) == args_digest(np.array([1, 2, 3]))
    assert args_digest([1, 2, 3]) != args_digest([3, 2, 1])
    assert args_digest([]) != args_digest([0])
    assert args_digest([0]) != args_digest([0, 0])


# -------------------------------------------------- journal-off identity


def _fs_workload(fs, n_ops=6, seed=3):
    rng = np.random.default_rng(seed)
    for rid in range(n_ops):
        keys = rng.choice(fs.cfg.n_keys, size=4, replace=False)
        deltas = rng.integers(-9, 10, (4, fs.cfg.dim))
        assert fs.apply(rid, keys, deltas)


def test_journal_off_layout_and_traffic_identical():
    """REPRO_JOURNAL=0 layouts must be bit-identical to the pre-journal
    engine: no .jrnl regions, shared regions at unchanged offsets, and
    the journal's entire flush overhead isolated in
    ``FlushStats.journal_lines`` (<= 1 line per epoch)."""
    cfg_kw = dict(n_keys=32, dim=3, n_samples=256, n_shards=N_SHARDS,
                  commit_mode=COMMIT_MODE)
    on = FeatureStore(FeatureConfig(journal=True, **cfg_kw))
    off = FeatureStore(FeatureConfig(journal=False, **cfg_kw))
    assert on.journal is not None and off.journal is None
    assert not [n for n in off.arena.regions if ".jrnl" in n]
    for name, r_off in off.arena.regions.items():
        r_on = on.arena.regions[name]
        assert r_on.shape == r_off.shape
        # integrity sidecars are appended AFTER every declared region
        # (DESIGN.md §13), so the journal regions legitimately shift
        # them; every declared region must sit at an unchanged offset
        if hasattr(r_on, "offset") and not name.endswith(".integ"):
            assert r_on.offset == r_off.offset, name
    s_on, s_off = on.arena.stats.snapshot(), off.arena.stats.snapshot()
    _fs_workload(on)
    _fs_workload(off)
    d_on = on.arena.stats.delta(s_on)
    d_off = off.arena.stats.delta(s_off)
    assert d_off.journal_lines == 0
    assert 0 < d_on.journal_lines <= d_on.epochs
    # journal traffic lives ONLY in journal_lines: the data-line/byte
    # ledgers are bit-identical to the journal-off run
    assert d_on.lines == d_off.lines and d_on.bytes == d_off.bytes
    # and the effects are identical either way
    probe = np.arange(32)
    np.testing.assert_array_equal(on.lookup(probe), off.lookup(probe))


def test_journal_env_default(monkeypatch):
    assert journal_enabled(True) and not journal_enabled(False)
    monkeypatch.setenv("REPRO_JOURNAL", "0")
    assert not journal_enabled(None)
    assert journal_enabled(True)      # explicit flag beats the env
    monkeypatch.setenv("REPRO_JOURNAL", "1")
    assert journal_enabled(None)
    monkeypatch.delenv("REPRO_JOURNAL")
    assert journal_enabled(None)      # default on
