"""Write-set / epoch-flush layer tests (DESIGN.md §2).

* double-dirty rows within one epoch account exactly one flush;
* data-before-metadata ordering inside the epoch: a crash after the data
  flush but before the metadata (header) flush recovers the previous
  committed state;
* DLL / B+Tree / Hashmap recover identically through the write-set path
  (crash mid-stream, reconstruct, compare with a pure-python reference);
* the Pallas pack_flush gather path is bit-identical to the numpy path;
* the checkpoint manager's DigestWriteSet skips clean leaves.
"""
import numpy as np
import pytest

from repro.core.arena import open_arena
from repro.core.writeset import DigestWriteSet
from repro.pstruct.bptree import BPTree
from repro.pstruct.dll import DoublyLinkedList
from repro.pstruct.hashmap import Hashmap

MODES = ("partly", "full")


# ------------------------------------------------------------- accounting


def test_double_dirty_one_epoch_accounts_one_flush():
    a = open_arena(None, {"r": (np.int64, (64, 8))})  # 64 B rows
    r = a.regions["r"]
    with a.epoch():
        r.vol[3] = 1
        r.mark_rows(np.array([3]))
        r.vol[3] = 2
        r.mark_rows(np.array([3]))      # same row again
        r.vol[4] = 9
        r.mark_rows(np.array([3, 4]))   # and again, plus a neighbour
    assert a.stats.lines == 2           # rows 3 and 4, one line each
    assert a.stats.epochs == 1
    assert a.stats.dedup_rows == 2      # three marks of row 3 -> one flush
    assert a.stats.saved_lines == 2     # per-call would have charged 4
    assert (r._pview()[3] == 2).all()   # latest value won
    assert (r._pview()[4] == 9).all()


def test_unaligned_rows_coalesce_once_across_epoch():
    # 16 B rows: 4 rows/line.  Marked one at a time in two separate calls
    # per row, per-call accounting charges a line per mark; the epoch
    # charges each distinct line once.
    a = open_arena(None, {"r": (np.int64, (64, 2))})
    r = a.regions["r"]
    with a.epoch():
        for i in range(8):
            r.vol[i] = i
            r.mark_rows(np.array([i]))
    assert a.stats.lines == 2           # 8 x 16 B = 2 lines
    assert a.stats.saved_lines == 8 - 2


def test_mark_outside_epoch_degrades_to_per_call():
    a = open_arena(None, {"r": (np.int64, (64, 2))})
    r = a.regions["r"]
    for i in range(4):
        r.mark_rows(np.array([i]))      # no epoch: immediate per-call flush
    assert a.stats.lines == 4           # one (shared) line charged 4x
    assert a.stats.epochs == 0


def test_epoch_nesting_flushes_once_at_outermost():
    a = open_arena(None, {"r": (np.int64, (64, 8))})
    r = a.regions["r"]
    with a.epoch():
        with a.epoch():
            r.mark_rows(np.array([1]))
        assert a.stats.lines == 0       # inner exit does not flush
        r.mark_rows(np.array([1]))
    assert a.stats.lines == 1
    assert a.stats.epochs == 1


# ------------------------------------------------- crash-ordering (§IV-C3)


def test_crash_between_data_flush_and_meta_flush_recovers_prior_state(rng):
    a = open_arena(None, DoublyLinkedList.layout(256, "partly"))
    d = DoublyLinkedList(a, 256, "partly")
    d.append_batch(rng.integers(0, 99, (20, 7)))
    a.commit()
    order0, data0 = d.to_list().copy(), d.data.copy()
    gen0 = a.generation
    # one more append whose epoch is cut at the data/metadata barrier:
    # node rows reach PM, the header row does not (power loss mid-epoch).
    with a.epoch():
        d.append_batch(rng.integers(0, 99, (10, 7)))
        a.writeset.flush(include_meta=False)
        assert not a.writeset             # remaining meta marks are lost
        a.crash()
    a.reopen()
    d.reconstruct()
    # prior generation intact: old header -> old chain, byte-exact
    assert a.generation == gen0
    assert (d.to_list() == order0).all()
    assert (d.data[order0] == data0[order0]).all()


def test_crash_inside_epoch_discards_marks_without_corrupting_pm():
    """crash() during an epoch must NOT let the unwinding epoch flush
    zeroed volatile rows over committed persistent data."""
    a = open_arena(None, {"r": (np.int64, (16, 8))})
    r = a.regions["r"]
    r.vol[3] = 7
    r.persist_rows(np.array([3]))
    a.commit()
    with a.epoch():
        r.vol[3] = 9
        r.mark_rows(np.array([3]))
        a.crash()                   # power loss: pending marks die too
    a.reopen()
    assert int(r.vol[3, 0]) == 7    # committed value survived


def test_commit_inside_epoch_flushes_pending_before_flag(rng):
    a = open_arena(None, DoublyLinkedList.layout(64, "partly"))
    d = DoublyLinkedList(a, 64, "partly")
    with a.epoch():
        d.append_batch(rng.integers(0, 9, (5, 7)))
        a.commit()                        # must drain the write set first
        assert not a.writeset
    a.crash()
    a.reopen()
    d.reconstruct()
    assert d.count == 5


# ------------------------------------- recovery equivalence post-refactor


@pytest.mark.parametrize("mode", MODES)
def test_dll_recovers_identically_via_writeset(mode, rng):
    a = open_arena(None, DoublyLinkedList.layout(512, mode))
    d = DoublyLinkedList(a, 512, mode)
    ids = d.append_batch(rng.integers(0, 99, (60, 7)))
    d.pop_front_batch(9)
    d.delete_batch(ids[20:35])
    order0, data0, tail0 = d.to_list().copy(), d.data.copy(), d.tail
    a.commit()
    a.crash()
    a.reopen()
    d.reconstruct()
    order1 = d.to_list()
    assert (order1 == order0).all()
    assert (d.data[order1] == data0[order0]).all()
    assert d.tail == tail0


@pytest.mark.parametrize("mode", MODES)
def test_bptree_recovers_identically_via_writeset(mode, rng):
    a = open_arena(None, BPTree.layout(1024, 4096, mode))
    t = BPTree(a, 1024, 4096, mode)
    keys = rng.permutation(1500).astype(np.int64)
    vals = rng.integers(0, 1 << 40, (1500, 7)).astype(np.int64)
    ref = {}
    for i in range(0, 1500, 97):
        t.insert_batch(keys[i:i + 97], vals[i:i + 97])
        for k, v in zip(keys[i:i + 97].tolist(), vals[i:i + 97]):
            ref[k] = v
    t.delete_batch(keys[:400])
    for k in keys[:400].tolist():
        ref.pop(k)
    a.commit()
    a.crash()
    a.reopen()
    t.reconstruct()
    t.check_invariants()
    rk = np.fromiter(ref.keys(), np.int64, len(ref))
    ok, got = t.find_batch(rk)
    assert ok.all()
    assert (got == np.stack([ref[int(k)] for k in rk])).all()
    ok, _ = t.find_batch(keys[:400])
    assert not ok.any()


@pytest.mark.parametrize("mode", MODES)
def test_hashmap_recovers_identically_via_writeset(mode, rng):
    a = open_arena(None, Hashmap.layout(2048, mode))
    h = Hashmap(a, 2048, mode)
    keys = rng.choice(10 ** 6, 1200, replace=False).astype(np.int64)
    vals = rng.integers(0, 1 << 40, (1200, 7)).astype(np.int64)
    h.insert_batch(keys, vals)
    h.remove_batch(keys[:300])
    ref = {int(k): vals[i] for i, k in enumerate(keys) if i >= 300}
    a.commit()
    a.crash()
    a.reopen()
    h.reconstruct()
    assert h.check_against(ref)


def test_partly_still_flushes_fewer_lines_than_fully(rng):
    """The paper's central inequality survives the epoch refactor."""
    keys = rng.permutation(2000).astype(np.int64)
    vals = rng.integers(0, 9, (2000, 7)).astype(np.int64)
    lines = {}
    for mode in MODES:
        a = open_arena(None, BPTree.layout(2048, 4096, mode))
        t = BPTree(a, 2048, 4096, mode)
        for i in range(0, 2000, 64):
            t.insert_batch(keys[i:i + 64], vals[i:i + 64])
        t.delete_batch(keys[:500])
        lines[mode] = a.stats.lines
    assert lines["partly"] < lines["full"]


# ------------------------------------------------------- pack-kernel path


def test_pack_flush_kernel_path_matches_numpy_path():
    rng = np.random.default_rng(7)
    rows = rng.choice(128, 40, replace=False).astype(np.int64)
    data = rng.integers(0, 1 << 62, (128, 8)).astype(np.int64)
    out = {}
    for thresh in (0, 1):   # 0 = numpy gather, 1 = Pallas pack_rows
        a = open_arena(None, {"r": (np.int64, (128, 8))},
                       pack_flush_rows=thresh)
        r = a.regions["r"]
        r.vol[:] = data
        with a.epoch():
            r.mark_rows(rows)
        out[thresh] = np.array(r._pview())
    np.testing.assert_array_equal(out[0], out[1])
    np.testing.assert_array_equal(out[1][rows], data[rows])


# -------------------------------------------------------- DigestWriteSet


def test_digest_writeset_skips_clean_leaves():
    ws = DigestWriteSet()
    assert ws.dirty("a", "d1")              # first sight: dirty
    assert not ws.dirty("a", "d1")          # unchanged: clean
    assert ws.dirty("a", "d2")              # content changed
    assert ws.dirty("a", "d2", present=False)  # file missing: rewrite
    assert ws.written == 3 and ws.skipped == 1


def test_kvcache_alloc_is_single_epoch():
    from repro.serve.kvcache import PagedAllocator, PagedConfig
    pa = PagedAllocator(PagedConfig(n_pages=32, page_tokens=4))
    base = pa.arena.stats.snapshot()
    pa.alloc(7, 4)
    d = pa.arena.stats.delta(base)
    assert d.epochs == 1                    # evict+append+commit fused
