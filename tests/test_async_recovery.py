"""Concurrent staged recovery + crash-point fuzzing (DESIGN.md §6,
"Concurrent recovery & admission").

Four invariant families:

* crash-point fuzzing: a mixed DLL/B+Tree/Hashmap arena is crashed at
  EVERY epoch boundary (power-loss and torn data/metadata flavors) and
  recovered with both serial and concurrent managers — the last
  committed generation must survive either way;
* double failure: recovery itself is interrupted (a second crash fires
  right after the k-th stage completes, for every k, while sibling
  stages may still be running in pool threads) and then recovery runs
  again — reconstructors are pure and recovery writes nothing
  persistent, so recover-crash-recover must land on the committed
  state bit-exactly;
* determinism: recover(concurrency=4) and recover(concurrency=1)
  produce bit-identical arenas + volatile redundancy and equivalent
  RecoveryReports (modulo timing fields);
* early admission: the serving engine's slot-readiness bitmap admits
  each prefill group as it lands (decode serves ready slots while
  other slots are still recovering), and ckpt background warmup takes
  APPROXIMABLE re-warming off the restore critical path without
  changing the restored state.
"""
import os
import threading

import numpy as np
import pytest

from repro.core.arena import open_arena
from repro.core.recovery import RecoveryManager
from repro.pstruct.bptree import BPTree
from repro.pstruct.dll import DoublyLinkedList
from repro.pstruct.hashmap import Hashmap

MODES = ("partly", "full")

# CI matrix axes (DESIGN.md §7, §9): the whole crash/recovery fuzz
# suite reruns on a sharded substrate with REPRO_N_SHARDS=4 and under
# the shadow commit protocol with REPRO_COMMIT_MODE=shadow — every
# invariant here is independent of both the shard count and the
# commit-ordering protocol.
N_SHARDS = int(os.environ.get("REPRO_N_SHARDS", "1"))
COMMIT_MODE = os.environ.get("REPRO_COMMIT_MODE", "barrier")


# ---------------------------------------------------------------- helpers


def _mixed_arena(mode, commit_mode=None):
    layout = {}
    layout.update(DoublyLinkedList.layout(256, mode, name="dll"))
    layout.update(BPTree.layout(256, 1024, mode, name="bt"))
    layout.update(Hashmap.layout(512, mode, name="hm"))
    a = open_arena(None, layout, n_shards=N_SHARDS,
                   commit_mode=commit_mode or COMMIT_MODE)
    return (a, DoublyLinkedList(a, 256, mode, name="dll"),
            BPTree(a, 256, 1024, mode, name="bt"),
            Hashmap(a, 512, mode, name="hm"))


def _pmem_image(a) -> np.ndarray:
    """Every persistent byte of the arena, shard files concatenated."""
    if hasattr(a, "shards"):
        return np.concatenate([np.asarray(sh._mm) for sh in a.shards]
                              + [np.asarray(a._man)])
    return np.asarray(a._mm).copy()


def _script(n_ops, seed=0):
    """Mixed append/insert workload over fresh keys (torn-epoch-safe —
    nothing rewrites committed persistent rows destructively except the
    B+Tree, whose documented asymmetry the sweep accounts for)."""
    rng = np.random.default_rng(seed)
    ops, key = [], 0
    for i in range(n_ops):
        m = int(rng.integers(2, 7))
        vals = rng.integers(0, 1 << 30, (m, 7)).astype(np.int64)
        keys = np.arange(key, key + m, dtype=np.int64)
        key += m
        ops.append(("dll" if i % 3 == 0 else ("bt" if i % 3 == 1 else "hm"),
                    keys, vals))
    return ops


def _apply(d, t, h, op):
    kind, keys, vals = op
    if kind == "dll":
        d.append_batch(vals)
    elif kind == "bt":
        t.insert_batch(keys, vals)
    else:
        h.insert_batch(keys, vals)


def _manager(a, d, t, h):
    mgr = RecoveryManager(a)
    mgr.add("dll", "pstruct.dll", d)
    mgr.add("bt", "pstruct.bptree", t)
    mgr.add("hm", "pstruct.hashmap", h)
    return mgr


def _fingerprint(a, d, t, h):
    """Everything recovery is supposed to rebuild, bit-exactly: region
    volatile copies + every piece of volatile redundancy."""
    fp = {f"region:{name}": r.vol.copy() for name, r in a.regions.items()}
    fp["dll.prev"] = d.prev.copy()
    fp["dll.free"] = np.sort(np.asarray(d._free, np.int64))
    fp["dll.order"] = d.order().copy()
    fp["hm.n_buckets"] = h.n_buckets
    fp["hm.buckets"] = h.buckets.copy()
    fp["hm.chain"] = h.chain.copy()
    fp["hm.hashes"] = h.hashes.copy()
    fp["bt.leaf_prev"] = t.leaf_prev.copy()
    fp["bt.free_nodes"] = np.sort(np.asarray(t._free_nodes, np.int64))
    fp["bt.free_recs"] = np.sort(np.asarray(t._free_recs, np.int64))
    return fp


def _assert_fp_equal(got, want):
    assert got.keys() == want.keys()
    for k in want:
        np.testing.assert_array_equal(got[k], want[k], err_msg=k)


def _strip_timing(report):
    """Report equivalence view: everything but the timing fields."""
    out = []
    for st in report.stages:
        detail = {k: v for k, v in st.detail.items()
                  if not k.endswith("_s") and k not in ("seconds",)}
        out.append((st.name, detail))
    return {"valid": report.valid, "generation": report.generation,
            "stages": out}


# ----------------------------------------------- boundary-sweep fuzzing


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("torn", [False, True])
@pytest.mark.parametrize("concurrency", [1, 4])
def test_crash_fuzz_every_boundary(mode, torn, concurrency):
    """For every epoch boundary b, crash inside op b+1 (power loss
    mid-epoch, or torn: data half flushed but not metadata), recover
    with the given concurrency, and require the committed generation's
    fingerprint for the count-bounded structures (B+Tree rows follow
    the documented in-place asymmetry, asserted via find_batch)."""
    ops = _script(8, seed=3)
    n = len(ops)
    for boundary in range(n):
        a, d, t, h = _mixed_arena(mode)
        bt_keys = []
        for i in range(boundary + 1):
            _apply(d, t, h, ops[i])
            if ops[i][0] == "bt":
                bt_keys.extend(ops[i][1].tolist())
            a.commit()
        dll_order = d.to_list().copy()
        dll_data = d.data[dll_order].copy()
        hm_size = h.size
        bt_vals = t.find_batch(np.asarray(bt_keys, np.int64))[1].copy() \
            if bt_keys else None
        gen0 = a.generation
        if boundary + 1 < n:
            with a.epoch():
                _apply(d, t, h, ops[boundary + 1])
                if torn:
                    a.writeset.flush(include_meta=False)
                a.crash()
        else:
            a.crash()
        report = _manager(a, d, t, h).recover(concurrency=concurrency)
        assert report.valid and report.generation == gen0
        np.testing.assert_array_equal(d.to_list(), dll_order)
        np.testing.assert_array_equal(d.data[dll_order], dll_data)
        assert h.size == hm_size
        if bt_keys:
            ok, got = t.find_batch(np.asarray(bt_keys, np.int64))
            assert ok.all()
            np.testing.assert_array_equal(got, bt_vals)


# ------------------------------------- commit-mode cross-equality


@pytest.mark.parametrize("torn", [False, True])
def test_commit_modes_recover_identical_logical_state(torn):
    """DESIGN.md §9: the shadow commit changes WHERE uncommitted bytes
    live, never what recovery rebuilds.  Crash at every epoch boundary
    (power-loss and torn flavors) under both commit modes and require
    the recovered structure state — order, data, committed lookups — to
    be bit-identical.  Raw region bytes legitimately differ (a torn
    barrier flush lands in home rows, a torn shadow flush sits in a
    never-selected mirror bank), so equality is asserted on the
    structure view, which is what the consistency argument is about."""
    ops = _script(6, seed=5)
    n = len(ops)
    for boundary in range(n):
        state = {}
        for cm in ("barrier", "shadow"):
            a, d, t, h = _mixed_arena("partly", commit_mode=cm)
            keys = {"bt": [], "hm": []}
            for i in range(boundary + 1):
                _apply(d, t, h, ops[i])
                if ops[i][0] in keys:
                    keys[ops[i][0]].extend(ops[i][1].tolist())
                a.commit()
            gen0 = a.generation
            if boundary + 1 < n:
                with a.epoch():
                    _apply(d, t, h, ops[boundary + 1])
                    if torn:
                        a.writeset.flush(include_meta=False)
                    a.crash()
            else:
                a.crash()
            rep = _manager(a, d, t, h).recover(concurrency=2)
            assert rep.valid and rep.generation == gen0
            order = d.to_list()
            st = {"dll.order": order.copy(),
                  "dll.data": d.data[order].copy(),
                  "hm.size": np.int64(h.size)}
            for kind, struct_ in (("bt", t), ("hm", h)):
                if keys[kind]:
                    ok, vals = struct_.find_batch(
                        np.asarray(keys[kind], np.int64))
                    assert ok.all(), f"{cm}: committed {kind} key lost"
                    st[f"{kind}.vals"] = vals.copy()
            state[cm] = st
            # the shadow protocol has no torn-rewrite asymmetry: keys of
            # the crashed epoch are gone, not half-surfaced (the barrier
            # B+Tree may expose them — its in-place leaf rewrite)
            if cm == "shadow" and boundary + 1 < n \
                    and ops[boundary + 1][0] == "bt":
                ok, _ = t.find_batch(ops[boundary + 1][1])
                assert not ok.any()
        assert state["barrier"].keys() == state["shadow"].keys()
        for k in state["barrier"]:
            np.testing.assert_array_equal(
                state["shadow"][k], state["barrier"][k],
                err_msg=f"boundary={boundary}: {k}")


# --------------------------------------------- double-failure fuzzing


@pytest.mark.parametrize("torn", [False, True])
@pytest.mark.parametrize("concurrency", [1, 4])
@pytest.mark.parametrize("crash_after_stage", [0, 1, 2, 3])
def test_double_failure_mid_stage(torn, concurrency, crash_after_stage):
    """Recovery is itself crashed: a listener injects arena.crash() the
    moment the k-th stage report lands (stage 0 = reopen) — under
    concurrency>1 sibling stages of the same level are mid-flight in
    other threads when the rug is pulled.  The interrupted pass may
    raise or produce garbage volatile state; it must never touch
    persistent bytes, so a second, uninterrupted recovery lands on the
    committed fingerprint."""
    a, d, t, h = _mixed_arena("partly")
    for op in _script(6, seed=11):
        _apply(d, t, h, op)
        a.commit()
    # the first failure: crash mid-op (optionally torn)
    with a.epoch():
        _apply(d, t, h, _script(1, seed=99)[0])
        if torn:
            a.writeset.flush(include_meta=False)
        a.crash()
    # reference: what one uninterrupted recovery of this image rebuilds
    pmem0 = _pmem_image(a)
    _manager(a, d, t, h).recover()
    np.testing.assert_array_equal(_pmem_image(a), pmem0)   # recovery persists nothing
    want = _fingerprint(a, d, t, h)

    # the fuzzed run: recover again, crashing mid-recovery after stage k
    a.crash()
    seen = []

    def bomb(st):
        seen.append(st.name)
        if len(seen) == crash_after_stage + 1:
            a.crash()

    try:
        _manager(a, d, t, h).recover(concurrency=concurrency,
                                     on_stage=bomb)
    except Exception:
        pass          # garbage volatile state may fail loudly — allowed
    np.testing.assert_array_equal(_pmem_image(a), pmem0)   # still nothing persisted
    report = _manager(a, d, t, h).recover(concurrency=concurrency)
    assert report.valid
    _assert_fp_equal(_fingerprint(a, d, t, h), want)
    np.testing.assert_array_equal(_pmem_image(a), pmem0)


# ------------------------------------------------- report truthfulness


def test_report_valid_true_only_after_commit(rng):
    a, d, t, h = _mixed_arena("partly")
    d.append_batch(rng.integers(0, 9, (4, 7)))
    a.crash()                                  # commit() never ran
    rep = _manager(a, d, t, h).recover(concurrency=4)
    assert not rep.valid
    d.append_batch(rng.integers(0, 9, (4, 7)))
    a.commit()
    a.crash()
    rep = _manager(a, d, t, h).recover(concurrency=4)
    assert rep.valid and rep.generation == 1


def test_report_valid_false_after_invalidate(rng):
    a, d, t, h = _mixed_arena("partly")
    d.append_batch(rng.integers(0, 9, (4, 7)))
    a.commit()
    a.invalidate()
    a.crash()
    rep = _manager(a, d, t, h).recover()
    assert not rep.valid


# ------------------------------------------------------- determinism


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("seed", [0, 7])
def test_concurrent_recovery_bit_identical_to_serial(mode, seed):
    """recover(concurrency=4) == recover(concurrency=1): bit-identical
    arenas + volatile redundancy, equivalent reports modulo timing."""
    a, d, t, h = _mixed_arena(mode)
    for op in _script(9, seed=seed):
        _apply(d, t, h, op)
        a.commit()
    a.crash()
    rep1 = _manager(a, d, t, h).recover(concurrency=1)
    fp1 = _fingerprint(a, d, t, h)
    a.crash()
    rep4 = _manager(a, d, t, h).recover(concurrency=4)
    fp4 = _fingerprint(a, d, t, h)
    _assert_fp_equal(fp4, fp1)
    assert _strip_timing(rep4) == _strip_timing(rep1)
    assert rep4.concurrency == 4 and rep1.concurrency == 1


# -------------------------------------------------- callbacks + timing


def test_stage_callbacks_fire_once_per_stage_any_thread(rng):
    a, d, t, h = _mixed_arena("partly")
    for op in _script(5, seed=2):
        _apply(d, t, h, op)
        a.commit()
    a.crash()
    mgr = _manager(a, d, t, h)
    from_listener, from_on_stage = [], []
    mgr.add_listener(lambda st: from_listener.append(st.name))
    rep = mgr.recover(concurrency=4,
                      on_stage=lambda st: from_on_stage.append(st.name))
    # every stage (incl. reopen) lands exactly once in each callback;
    # completion order is the pool's business, the SET is the contract
    assert sorted(from_listener) == sorted(s.name for s in rep.stages)
    assert sorted(from_on_stage) == sorted(from_listener)
    # the report itself stays in deterministic level-major order
    assert [s.name for s in rep.stages] == ["reopen", "dll", "bt", "hm"]


def test_report_carries_wall_critical_path_and_sum(rng):
    a, d, t, h = _mixed_arena("partly")
    for op in _script(5, seed=4):
        _apply(d, t, h, op)
        a.commit()
    a.crash()
    rep = _manager(a, d, t, h).recover(concurrency=2)
    # three stages on one level: critical path = reopen + slowest stage
    assert rep.critical_path_ms <= rep.total_ms + 1e-6
    assert rep.critical_path_ms <= rep.wall_ms + 0.5  # measurement slack
    assert rep.wall_ms > 0 and rep.total_ms > 0
    d_dict = rep.as_dict()
    for key in ("wall_ms", "critical_path_ms", "total_ms", "concurrency"):
        assert key in d_dict
    for st in rep.stages:
        assert st.t_end >= st.t_start >= 0.0


def test_critical_path_follows_dependency_chain():
    """A linear dependency chain's critical path is the full stage sum;
    adding an independent stage leaves the chain's path dominant."""
    from repro.core import reconstruct

    if "test.sleepy" not in reconstruct.names():
        @reconstruct.register("test.sleepy")
        def _sleepy(secs):
            import time as _t
            _t.sleep(secs)
            return {}

    mgr = RecoveryManager()
    mgr.add("a", "test.sleepy", 0.02)
    mgr.add("b", "test.sleepy", 0.02, depends=("a",))
    mgr.add("lone", "test.sleepy", 0.001)
    rep = mgr.recover(reopen=False, concurrency=4)
    assert rep.critical_path_seconds >= 0.04 - 1e-3
    assert rep.critical_path_seconds <= rep.total_seconds + 1e-3
    assert [lvl for lvl in mgr.levels()] == [["a", "lone"], ["b"]]


def test_concurrent_scheduler_runs_each_stage_exactly_once():
    """Regression: a stage future can complete before its done-callback
    attaches, running finished() INLINE in the submitting thread — mid
    initial-submission-loop that can drop a LATER stage's dependency
    counter to zero and submit it early, and the loop's own
    remaining==0 check then submitted it AGAIN.  The duplicate
    completion double-decremented its dependents' counters, so a stage
    could start before a sibling dependency finished (observed as the
    engine stage racing the journal replay).  Instant stages maximize
    the inline-callback window; every stage must run exactly once and
    only after its declared dependencies."""
    from repro.core import reconstruct

    if "test.counted" not in reconstruct.names():
        @reconstruct.register("test.counted")
        def _counted(state):
            key, deps, runs, done, lock = state
            with lock:
                missing = [d for d in deps if d not in done]
                assert not missing, f"{key} ran before {missing}"
                runs[key] = runs.get(key, 0) + 1
                done.add(key)
            return {}

    for _ in range(60):
        runs: dict = {}
        done: set = set()
        lock = threading.Lock()

        def st(key, *deps):
            return (key, deps, runs, done, lock)

        mgr = RecoveryManager()
        mgr.add("a", "test.counted", st("a"))
        mgr.add("b", "test.counted", st("b", "a"), depends=("a",))
        mgr.add("c", "test.counted", st("c"))
        mgr.add("d", "test.counted", st("d", "b", "c"),
                depends=("b", "c"))
        mgr.recover(reopen=False, concurrency=2)
        assert runs == {"a": 1, "b": 1, "c": 1, "d": 1}


# ------------------------------------------- engine early admission


@pytest.mark.parametrize("concurrency", [1, 4])
def test_engine_admits_slots_per_prefill_group(tmp_path, concurrency):
    import jax
    import jax.numpy as jnp

    from repro.configs import base, registry
    from repro.models.model import build
    from repro.serve.engine import EngineConfig, ServingEngine

    model = build(base.reduced(registry.get("llama3.2-3b")),
                  compute_dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params,
                        EngineConfig(max_batch=3, s_max=16,
                                     max_requests=16),
                        arena_path=str(tmp_path / "a"))
    eng.add_request(7, np.array([1, 2, 3], np.int64))       # plen 3
    eng.add_request(8, np.array([4, 5, 6, 9, 2], np.int64))  # plen 5
    eng.step()
    eng.crash()
    assert not eng.slot_ready.any()
    events = []
    lock = threading.Lock()

    def on_ready(slots, tlen, admitted_s):
        with lock:
            events.append((sorted(int(s) for s in slots), tlen,
                           eng.slot_ready.copy()))

    eng.on_slot_ready = on_ready
    eng.recover(concurrency=concurrency)
    eng.on_slot_ready = None
    # two distinct prompt lengths -> two admission events
    assert len(events) == 2
    assert {e[1] for e in events} == {4, 6}    # tlen = plen + 1 step
    for slots, _tlen, bitmap in events:
        assert bitmap[slots].all()             # admitted when it fired
    # the unoccupied slot was admitted by the scan, before any prefill
    assert all(e[2][2] for e in events)
    assert eng.slot_ready.all()
    rep = eng.last_recovery
    det = rep.stage("engine").detail
    assert det["prefill_groups"] == 2
    assert 0 < det["first_admission_s"] <= det["last_admission_s"]


def test_engine_step_and_seating_respect_readiness(tmp_path):
    import jax
    import jax.numpy as jnp

    from repro.configs import base, registry
    from repro.models.model import build
    from repro.serve.engine import EngineConfig, ServingEngine

    model = build(base.reduced(registry.get("llama3.2-3b")),
                  compute_dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params,
                        EngineConfig(max_batch=2, s_max=16,
                                     max_requests=16),
                        arena_path=str(tmp_path / "a"))
    eng.add_request(7, np.array([1, 2, 3], np.int64))
    eng.add_request(8, np.array([4, 5, 6, 9], np.int64))
    eng.step()
    eng.crash()
    stepped = []

    def on_ready(slots, tlen, admitted_s):
        if not stepped:
            # mid-recovery: only the admitted group's slot decodes; the
            # other active slot is skipped, and with every slot busy a
            # new request cannot be seated yet
            out = eng.step()
            stepped.append((sorted(out), eng.slot_ready.copy()))
            with pytest.raises(RuntimeError, match="no free slots"):
                eng.add_request(99, np.array([1], np.int64))

    eng.on_slot_ready = on_ready
    eng.recover()               # serial: callbacks run between groups
    eng.on_slot_ready = None
    assert len(stepped) == 1
    first_rids, bitmap = stepped[0]
    assert len(first_rids) == 1 and int(bitmap.sum()) == 1
    # fully recovered: both slots serve again
    out = eng.step()
    assert sorted(out) == [7, 8]


# --------------------------------------------- ckpt background warmup


def _tiny_train_state():
    import jax
    import jax.numpy as jnp

    from repro.train.state import new_state

    k = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(k, (32, 16)), "b": jnp.zeros((16,))}
    mu = jax.tree.map(jnp.zeros_like, params)
    nu = jax.tree.map(jnp.zeros_like, params)
    return new_state(params, mu, nu, seed=7)


def test_ckpt_background_warmup_matches_inline(tmp_path):
    import jax

    from repro.ckpt.manager import CheckpointManager
    from repro.core import policy as pol

    st = _tiny_train_state()
    spec = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), st)
    mgr = CheckpointManager(str(tmp_path), pol.PARTLY_DROP)
    mgr.save(st)
    inline = mgr.restore(spec)
    bg = mgr.restore(spec, warmup="background")
    bg = mgr.finish_warmup(bg)
    for a, b in zip(jax.tree.leaves(inline), jax.tree.leaves(bg)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ckpt_background_warmup_reports_stage(tmp_path):
    import jax

    from repro.ckpt.manager import CheckpointManager
    from repro.core import policy as pol

    st = _tiny_train_state()
    spec = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), st)
    mgr = CheckpointManager(str(tmp_path), pol.PARTLY_DROP)
    mgr.save(st)
    got = mgr.restore(spec, warmup="background")
    mgr.wait_warmup()
    rep = mgr.last_recovery
    warm = rep.stage("warmup_approximable")
    assert warm is not None and warm.detail["background"]
    assert warm.detail["leaves"] == 4          # mu/nu x {w, b}
    assert warm.seconds >= 0
    # the placeholder state is already usable (host zeros for moments)
    assert float(np.sum(np.abs(np.asarray(got.mu["w"])))) == 0.0
    mgr.finish_warmup(got)


def test_ckpt_unclaimed_warmup_refuses_next_restore(tmp_path):
    """Splicing restore B's warm leaves into restore A's state would be
    silent corruption — the manager refuses the second restore until
    the first warmup is claimed."""
    import jax

    from repro.ckpt.manager import CheckpointManager
    from repro.core import policy as pol

    st = _tiny_train_state()
    spec = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), st)
    mgr = CheckpointManager(str(tmp_path), pol.PARTLY_DROP)
    mgr.save(st)
    got = mgr.restore(spec, warmup="background")
    with pytest.raises(RuntimeError, match="unclaimed background warmup"):
        mgr.restore(spec)
    got = mgr.finish_warmup(got)          # claim it
    mgr.restore(spec)                     # now fine
    assert got.step is not None


def test_ckpt_warmup_thread_failure_surfaces(tmp_path, monkeypatch):
    """A failure inside the warmup thread must re-raise at the join
    point, not die silently in a daemon thread."""
    import jax

    from repro.ckpt import manager as M
    from repro.core import policy as pol

    st = _tiny_train_state()
    spec = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), st)
    mgr = M.CheckpointManager(str(tmp_path), pol.PARTLY_DROP)
    mgr.save(st)

    real = M.jnp.asarray

    def boom(x, *a, **k):
        # fail only in the warmup worker — restore's main-thread
        # device placement stays real
        if threading.current_thread() is not threading.main_thread():
            raise ValueError("synthetic warmup failure")
        return real(x, *a, **k)

    monkeypatch.setattr(M.jnp, "asarray", boom)
    got = mgr.restore(spec, warmup="background")
    with pytest.raises(ValueError, match="synthetic warmup"):
        mgr.finish_warmup(got)
    monkeypatch.undo()
    # the error is consumed; the manager is reusable afterwards
    mgr.restore(spec)
    assert got.step is not None


# ------------------- duplicate-admission oracle (DESIGN.md §11)
#
# The request journal's exactly-once contract, fuzzed the same way the
# structures are: crash at EVERY epoch boundary (power-loss and torn),
# recover, then replay the ENTIRE workload through the journal's
# duplicate check — completed requests must be refused, interrupted
# ones must retry, and the final effect-set must equal a twin run that
# never crashed.  Swept over both commit modes and shard counts
# regardless of the ambient CI axes.

from repro.serve.feature_store import FeatureConfig, FeatureStore  # noqa: E402
from repro.serve.journal import (ST_DONE, ST_NEVER,  # noqa: E402
                                 ST_RETRY, DuplicateRequestError)

FS_GRID = [("barrier", 1), ("barrier", 4), ("shadow", 1), ("shadow", 4)]


def _fs_cfg(commit_mode, n_shards):
    return FeatureConfig(n_keys=64, dim=3, n_samples=512,
                         commit_mode=commit_mode, n_shards=n_shards,
                         journal=True)


def _fs_script(n_ops, seed=0):
    rng = np.random.default_rng(seed)
    ops = []
    for rid in range(n_ops):
        m = int(rng.integers(1, 6))
        keys = rng.choice(64, size=m, replace=False).astype(np.int64)
        deltas = rng.integers(-9, 10, (m, 3)).astype(np.int64)
        ops.append((rid, keys, deltas))
    return ops


def _fs_effects(fs):
    return {"vectors": fs.lookup(np.arange(fs.cfg.n_keys)).copy(),
            "counts": fs.counts.copy(),
            "next_sample": fs.next_sample,
            "classify": dict(fs.journal.classify())}


def _fs_assert_effects(fs, want):
    got = _fs_effects(fs)
    assert got["classify"] == want["classify"]
    assert got["next_sample"] == want["next_sample"]
    np.testing.assert_array_equal(got["counts"], want["counts"])
    np.testing.assert_array_equal(got["vectors"], want["vectors"])


def _fs_twin(commit_mode, n_shards, ops):
    """Uninterrupted twin run: the expected effect-set, plus the journal
    overhead bound (<= 1 extra flushed line per epoch)."""
    fs = FeatureStore(_fs_cfg(commit_mode, n_shards))
    s0 = fs.arena.stats.snapshot()
    for op in ops:
        assert fs.apply(*op)
    d = fs.arena.stats.delta(s0)
    assert 0 < d.journal_lines <= d.epochs
    return _fs_effects(fs)


@pytest.mark.parametrize("commit_mode,n_shards", FS_GRID)
@pytest.mark.parametrize("torn", [False, True])
def test_journal_exactly_once_every_boundary(commit_mode, n_shards, torn):
    ops = _fs_script(6, seed=13)
    want = _fs_twin(commit_mode, n_shards, ops)
    last = len(ops) if not torn else len(ops) - 1
    for boundary in range(last + 1):
        fs = FeatureStore(_fs_cfg(commit_mode, n_shards))
        for op in ops[:boundary]:
            assert fs.apply(*op)
        if torn and boundary < len(ops):
            # crash inside op `boundary`: data phase durable, commit not
            assert fs.apply(*ops[boundary], _torn_crash=True) is False
        else:
            fs.crash()                       # power loss between epochs
        rep = fs.recover(concurrency=2)
        # a report is valid only once a generation has committed; at
        # boundary 0 the image is legitimately pre-first-commit
        assert rep.valid == (boundary > 0)
        # classification: exactly the committed prefix is completed; the
        # crashed op left no committed trace
        assert fs.journal.classify() == \
            {rid: ST_DONE for rid, _, _ in ops[:boundary]}
        if boundary < len(ops):
            assert fs.journal.state_of(ops[boundary][0]) == ST_NEVER
        # the oracle: replay the WHOLE workload; completed requests are
        # refused, the rest apply exactly once
        for i, op in enumerate(ops):
            assert fs.apply(*op) == (i >= boundary), (boundary, i)
        _fs_assert_effects(fs, want)


@pytest.mark.parametrize("commit_mode,n_shards", [("barrier", 1),
                                                  ("shadow", 4)])
@pytest.mark.parametrize("crash_after_stage", [0, 1, 2, 3, 4])
def test_journal_oracle_survives_double_failure(commit_mode, n_shards,
                                                crash_after_stage):
    """Crash the journal's own recovery after every stage (reopen, emb,
    samples, journal, store — possibly while siblings run in pool
    threads), recover again, and the replay oracle must still land on
    the twin effect-set with zero duplicate admissions."""
    ops = _fs_script(5, seed=21)
    want = _fs_twin(commit_mode, n_shards, ops)
    fs = FeatureStore(_fs_cfg(commit_mode, n_shards))
    for op in ops[:3]:
        assert fs.apply(*op)
    assert fs.apply(*ops[3], _torn_crash=True) is False
    seen = []

    def bomb(st):
        seen.append(st.name)
        if len(seen) == crash_after_stage + 1:
            fs.arena.crash()

    try:
        fs.recover(concurrency=2, on_stage=bomb)
    except Exception:
        pass      # garbage volatile state may fail loudly — allowed
    rep = fs.recover(concurrency=2)
    assert rep.valid
    for i, op in enumerate(ops):
        assert fs.apply(*op) == (i >= 3)
    _fs_assert_effects(fs, want)


def test_engine_journal_refuses_duplicate_admission(tmp_path):
    """Engine-level exactly-once: after crash+recover the journal
    classifies a finished request completed and an in-flight one
    must-retry; re-admitting EITHER raises, the freed slot seats a
    fresh rid, and decode resumes."""
    import jax
    import jax.numpy as jnp

    from repro.configs import base, registry
    from repro.models.model import build
    from repro.serve.engine import EngineConfig, ServingEngine

    model = build(base.reduced(registry.get("llama3.2-3b")),
                  compute_dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params,
                        EngineConfig(max_batch=2, s_max=16,
                                     max_requests=16, journal=True),
                        arena_path=str(tmp_path / "a"))
    eng.add_request(7, np.array([1, 2, 3, 4], np.int64))
    eng.add_request(8, np.array([5, 6], np.int64))
    for _ in range(2):
        eng.step()
    assert eng.finish_request(7) == 6          # 4 prompt + 2 decoded
    with pytest.raises(KeyError):
        eng.finish_request(7)                  # already finished
    eng.crash()
    eng.recover(concurrency=2)
    rep = eng.last_recovery
    assert rep.valid
    assert rep.stage("journal").detail["must_retry"] == 1
    assert eng.journal.state_of(7) == ST_DONE
    assert eng.journal.state_of(8) == ST_RETRY
    for rid in (7, 8):
        with pytest.raises(DuplicateRequestError):
            eng.add_request(rid, np.array([9], np.int64))
    eng.add_request(9, np.array([9, 9], np.int64))  # freed slot reused
    out = eng.step()
    assert sorted(out) == [8, 9]
