"""Integration: trainer crash/resume bit-consistency; serving engine
crash/recover determinism; paged allocator; data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base, registry
from repro.core import policy as pol
from repro.data.pipeline import Pipeline
from repro.models.model import build
from repro.optim.adamw import AdamWConfig
from repro.serve.engine import EngineConfig, ServingEngine
from repro.serve.kvcache import PagedAllocator, PagedConfig
from repro.train.trainer import Trainer, TrainerConfig


@pytest.fixture(scope="module")
def small_model():
    cfg = base.reduced(registry.get("llama3.2-3b"))
    return build(cfg, compute_dtype=jnp.float32)


def test_trainer_crash_resume_bit_consistent(tmp_path, small_model):
    tc = TrainerConfig(steps=8, ckpt_every=4, ckpt_dir=str(tmp_path / "a"),
                       policy=pol.PARTLY_PERSISTENT, global_batch=4,
                       seq_len=32, async_ckpt=False)
    tr = Trainer(small_model, AdamWConfig(), tc)
    tr.init()
    tr.run(6)
    tr.crash()
    step = tr.resume()
    assert step == 4
    tr.run(2)
    crash_losses = {m["step"]: m["loss"] for m in tr.metrics_log}

    tc2 = TrainerConfig(steps=8, ckpt_every=0, ckpt_dir=str(tmp_path / "b"),
                        policy=pol.PARTLY_PERSISTENT, global_batch=4,
                        seq_len=32)
    tr2 = Trainer(small_model, AdamWConfig(), tc2)
    tr2.init()
    tr2.run(6)
    ref = {m["step"]: m["loss"] for m in tr2.metrics_log}
    for s in (4, 5):
        assert abs(crash_losses[s] - ref[s]) < 1e-5, s


def test_trainer_drop_policy_resumes_with_divergence(tmp_path, small_model):
    """partly+drop restores params exactly but re-warms moments — the
    documented approximation; training continues finitely."""
    tc = TrainerConfig(steps=6, ckpt_every=3, ckpt_dir=str(tmp_path),
                       policy=pol.PARTLY_DROP, global_batch=4, seq_len=32,
                       async_ckpt=False)
    tr = Trainer(small_model, AdamWConfig(), tc)
    tr.init()
    tr.run(4)
    tr.crash()
    assert tr.resume() == 3
    assert float(jnp.sum(jnp.abs(jax.tree.leaves(tr.state.mu)[0]))) == 0.0
    tr.run(2)
    assert np.isfinite(tr.metrics_log[-1]["loss"])


def test_pipeline_determinism_and_cursor():
    cfg = registry.get("llama3.2-3b")
    p1 = Pipeline(cfg, 4, 16, seed=3)
    b_a = p1.batch_at(5)
    p2 = Pipeline(cfg, 4, 16, seed=3)
    p2.reconstruct_cursor(3, 5)
    b_b = p2.batch_at(5)
    np.testing.assert_array_equal(b_a["tokens"], b_b["tokens"])
    # tokens in range, labels shifted
    assert b_a["tokens"].max() < cfg.vocab
    b_c = Pipeline(cfg, 4, 16, seed=4).batch_at(5)
    assert (b_a["tokens"] != b_c["tokens"]).any()


def test_serving_crash_recover_determinism(tmp_path, small_model):
    """Tokens generated after crash+recover must equal the same steps of
    an uninterrupted twin run (greedy decode is deterministic)."""
    params = small_model.init_params(jax.random.PRNGKey(0))
    ec = EngineConfig(max_batch=2, s_max=24, max_requests=16)

    def fresh(name):
        eng = ServingEngine(small_model, params, ec,
                            arena_path=str(tmp_path / name))
        eng.add_request(101, np.array([1, 2, 3, 4], np.int64))
        eng.add_request(202, np.array([9, 8, 7], np.int64))
        return eng

    twin = fresh("twin")
    for _ in range(6):
        twin.step()
    ref = [twin.step() for _ in range(3)]

    eng = fresh("arena")
    for _ in range(6):
        eng.step()
    eng.crash()
    dt = eng.recover()
    assert dt >= 0
    got = [eng.step() for _ in range(3)]
    assert ref == got


def test_paged_allocator_lru_and_recover(tmp_path):
    pa = PagedAllocator(PagedConfig(n_pages=16, page_tokens=4),
                        path=str(tmp_path / "pg"))
    pa.alloc(1, 6)
    pa.alloc(2, 6)
    assert len(pa.pages_free) == 4
    # exhaustion triggers LRU eviction of request 1's oldest pages
    pa.alloc(3, 8)
    assert (pa.owner == 3).sum() == 8
    owner_before = pa.owner.copy()
    free_before = sorted(pa.pages_free)
    pa.arena.commit()
    pa.arena.crash()
    sec = pa.recover()
    assert sec >= 0
    np.testing.assert_array_equal(pa.owner, owner_before)
    assert sorted(pa.pages_free) == free_before
    pa.free_request(3)
    assert (pa.owner == 3).sum() == 0


def test_sample_index_recover(tmp_path):
    from repro.data.index import SampleIndex
    idx = SampleIndex(str(tmp_path / "idx"), 4096)
    ids = np.arange(1000, dtype=np.int64)
    idx.add(ids, ids % 7, ids * 64, np.full(1000, 64, np.int64))
    idx.arena.crash()
    sec = idx.recover()
    assert sec >= 0
    ok, shard, off, ln = idx.lookup(ids[::13])
    assert ok.all()
    np.testing.assert_array_equal(shard, (ids[::13]) % 7)
    np.testing.assert_array_equal(off, ids[::13] * 64)
