"""Sharded persistent arenas (core/arena.py ShardedArena, DESIGN.md §7).

Invariant families:

* routers partition rows exactly; every router round-trips through
  epoch-mark -> commit -> crash -> reopen (serial and pooled);
* the aggregate line/dedup accounting of a sharded arena is IDENTICAL
  to the single arena's for the same op trace (the medium-independent
  metric must not depend on how the substrate is partitioned);
* shard-count invariance: recovering the same op trace under
  n_shards in {1, 3, 4} yields bit-identical structure fingerprints;
* manifest-last commit protocol: a crash in the inter-shard commit
  window (after shard k of N committed, before the manifest) recovers
  the last generation ALL shards agree on — swept over every k;
* the data-before-metadata barrier is GLOBAL across shards: a torn
  flush never exposes a header on one shard ahead of another shard's
  data;
* the dependency-counter scheduler starts a stage the moment its own
  deps land (no level barrier), reports ready_at / queue_wait, and
  splits a sharded arena's reopen into per-region load stages;
* the serving engine stripes its token slab across shards and re-admits
  traffic per (shard, prompt-length) group.
"""
import os

import numpy as np
import pytest

from repro.core import reconstruct
from repro.core.arena import (Arena, ShardedArena, open_arena, route_rows,
                              router_block)
from repro.core.recovery import RecoveryManager
from repro.pstruct.bptree import BPTree
from repro.pstruct.dll import DoublyLinkedList
from repro.pstruct.hashmap import Hashmap

ROUTERS = (("seg", 4), ("seg", 64), ("hash",), ("hash", 8), ("range",),
           ("shard", 2), None)


# ------------------------------------------------------------- routers


@pytest.mark.parametrize("router", ROUTERS)
@pytest.mark.parametrize("n_shards", [2, 3, 4])
def test_router_partitions_rows_exactly(router, n_shards):
    shard_of = route_rows(router, 103, n_shards)
    assert shard_of.shape == (103,)
    assert ((shard_of >= 0) & (shard_of < n_shards)).all()
    # block-granular routers are constant within each block
    blk = router_block(router)
    if blk:
        for b in range(103 // blk):
            assert len(set(shard_of[b * blk:(b + 1) * blk])) == 1


@pytest.mark.parametrize("router", ROUTERS)
def test_roundtrip_epoch_commit_crash_reopen(router):
    a = open_arena(None, {"r": (np.int64, (103, 8), router),
                          "r.header": (np.int64, (1, 8))}, n_shards=3)
    r, hdr = a.regions["r"], a.regions["r.header"]
    data = np.random.default_rng(0).integers(0, 99, (103, 8))
    r.vol[:] = data
    hdr.vol[0, 0] = 42
    with a.epoch():
        r.mark_rows(np.arange(103))
        hdr.mark_rows(np.array([0]))
    a.commit()
    a.crash()
    assert (r.vol == 0).all()
    a.reopen()
    np.testing.assert_array_equal(r.vol, data)
    assert hdr.vol[0, 0] == 42
    assert a.header_valid() and a.header_generation() == 1
    # pooled reopen is bit-identical
    a.crash()
    a.reopen(concurrency=3)
    np.testing.assert_array_equal(r.vol, data)


def test_local_global_maps_are_bijective():
    a = open_arena(None, {"r": (np.int64, (257, 8), ("hash", 4))},
                   n_shards=4)
    r = a.regions["r"]
    seen = np.zeros(257, bool)
    for s, sl in enumerate(r.slices):
        if sl is None:
            continue
        assert (r.shard_of[sl._gidx] == s).all()
        assert (r.local_of[sl._gidx] == np.arange(sl._gidx.size)).all()
        assert not seen[sl._gidx].any()
        seen[sl._gidx] = True
    assert seen.all()


# --------------------------------------------------------- accounting


def test_aggregate_accounting_matches_single_arena():
    """Same op trace, same exact line/dedup numbers — sharding changes
    WHERE bytes land, never how many lines the medium is charged."""
    stats = {}
    for ns in (1, 4):
        rng = np.random.default_rng(11)        # identical trace per config
        a = open_arena(None, BPTree.layout(256, 1024), n_shards=ns)
        t = BPTree(a, 256, 1024)
        keys = rng.permutation(500).astype(np.int64)
        vals = rng.integers(0, 1 << 30, (500, 7)).astype(np.int64)
        for i in range(0, 500, 97):
            t.insert_batch(keys[i:i + 97], vals[i:i + 97])
        t.delete_batch(keys[:100])
        a.commit()
        s = a.stats
        stats[ns] = (s.lines, s.bytes, s.saved_lines, s.dedup_rows,
                     s.epochs)
    assert stats[1] == stats[4], stats


def test_per_shard_stats_sum_to_aggregate(rng):
    a = open_arena(None, DoublyLinkedList.layout(256), n_shards=3)
    d = DoublyLinkedList(a, 256)
    # 200 rows = 4 segment blocks of 64 -> shards 0, 1, 2, 0
    d.append_batch(rng.integers(0, 9, (200, 7)))
    a.commit()
    agg = a.stats
    per = a.shard_stats()
    assert agg.lines == sum(s.lines for s in per)
    assert agg.bytes == sum(s.bytes for s in per)
    assert all(s.lines > 0 for s in per)   # every shard took flushes


# ------------------------------------------- shard-count invariance


def _mixed(n_shards, mode="partly", commit_mode="barrier"):
    layout = {}
    layout.update(DoublyLinkedList.layout(256, mode, name="dll"))
    layout.update(BPTree.layout(256, 1024, mode, name="bt"))
    layout.update(Hashmap.layout(512, mode, name="hm"))
    a = open_arena(None, layout, n_shards=n_shards,
                   commit_mode=commit_mode)
    return (a, DoublyLinkedList(a, 256, mode, name="dll"),
            BPTree(a, 256, 1024, mode, name="bt"),
            Hashmap(a, 512, mode, name="hm"))


def _trace(a, d, t, h, n_ops=9, seed=7):
    rng = np.random.default_rng(seed)
    key = 0
    for i in range(n_ops):
        m = int(rng.integers(2, 7))
        vals = rng.integers(0, 1 << 30, (m, 7)).astype(np.int64)
        keys = np.arange(key, key + m, dtype=np.int64)
        key += m
        if i % 3 == 0:
            d.append_batch(vals)
        elif i % 3 == 1:
            t.insert_batch(keys, vals)
        else:
            h.insert_batch(keys, vals)
        a.commit()


def _recover(a, d, t, h, concurrency=1):
    mgr = RecoveryManager(a)
    mgr.add("dll", "pstruct.dll", d, regions=("dll.nodes", "dll.header"))
    mgr.add("bt", "pstruct.bptree", t,
            regions=("bt.nodes", "bt.records", "bt.header"))
    mgr.add("hm", "pstruct.hashmap", h,
            regions=("hm.entries", "hm.header"))
    return mgr.recover(concurrency=concurrency)


def _fingerprint(a, d, t, h):
    fp = {f"region:{nm}": r.vol.copy() for nm, r in a.regions.items()}
    fp["dll.prev"] = d.prev.copy()
    fp["dll.order"] = d.order().copy()
    fp["dll.free"] = np.sort(np.asarray(d._free, np.int64))
    fp["hm.n_buckets"] = h.n_buckets
    fp["hm.buckets"] = h.buckets.copy()
    fp["hm.chain"] = h.chain.copy()
    fp["bt.leaf_prev"] = t.leaf_prev.copy()
    fp["bt.free_nodes"] = np.sort(np.asarray(t._free_nodes, np.int64))
    return fp


@pytest.mark.parametrize("mode", ["partly", "full"])
def test_shard_count_invariant_fingerprints(mode):
    """Recovering the same committed op trace under n_shards in
    {1, 3, 4} yields bit-identical structure fingerprints — the shard
    substrate must be invisible above the region API."""
    fps = {}
    for ns in (1, 3, 4):
        a, d, t, h = _mixed(ns, mode)
        _trace(a, d, t, h)
        a.crash()
        rep = _recover(a, d, t, h, concurrency=2 if ns > 1 else 1)
        assert rep.valid and rep.generation == 9
        fps[ns] = _fingerprint(a, d, t, h)
    for ns in (3, 4):
        assert fps[ns].keys() == fps[1].keys()
        for k in fps[1]:
            np.testing.assert_array_equal(fps[ns][k], fps[1][k],
                                          err_msg=f"n_shards={ns}: {k}")


# --------------------------------------- inter-shard commit window


@pytest.mark.parametrize("crash_after_shard", [0, 1, 2, 3])
def test_intershard_commit_window_recovers_agreed_generation(
        crash_after_shard):
    """The crash-point fuzzer's new sweep axis: power fails AFTER shard
    k of 4 committed generation g+1 but BEFORE the manifest.  The
    manifest still names g — the generation all shards agree on — and
    recovery must land exactly where a plain flushed-but-uncommitted
    crash lands (the epoch data is durable either way; only the
    generation seal differs)."""
    def build():
        a, d, t, h = _mixed(4)
        _trace(a, d, t, h, n_ops=6)
        # one more op whose COMMIT is the thing that fails
        d.append_batch(np.ones((3, 7), np.int64))
        return a, d, t, h

    # reference: epoch flushed (epoch close), commit never ran
    a0, d0, t0, h0 = build()
    gen0 = a0.header_generation()
    a0.crash()
    _recover(a0, d0, t0, h0)
    want = _fingerprint(a0, d0, t0, h0)

    a, d, t, h = build()
    a.commit(_crash_after_shard=crash_after_shard)   # powers off mid-commit
    rep = _recover(a, d, t, h)
    # shards 0..k sit at gen+1; the manifest — written LAST — still
    # seals the generation every shard reached
    assert rep.generation == gen0 == 6
    assert rep.valid
    got = _fingerprint(a, d, t, h)
    assert got.keys() == want.keys()
    for k in want:
        np.testing.assert_array_equal(got[k], want[k], err_msg=k)
    # the substrate is not wedged: the next commit seals a new
    # generation on every shard and the manifest
    a.commit()
    assert a.header_generation() == 7 and a.header_valid()


@pytest.mark.parametrize(
    "commit_mode,crash_after_shard",
    # the -1 window (post-seal / pre-flip) exists only in shadow mode,
    # so the grid enumerates valid (mode, window) pairs instead of a
    # full product with a perpetual skip for barrier/-1
    [("barrier", k) for k in range(4)]
    + [("shadow", k) for k in (-1, 0, 1, 2, 3)])
def test_commit_window_sweep_both_modes(commit_mode, crash_after_shard):
    """The inter-shard commit-window sweep, rerun under both commit
    protocols.  ``crash_after_shard=k>=0`` powers off after shard k's
    header flipped but before the manifest; ``-1`` is shadow-only — the
    torn-flip window's leading edge, after every shard SEALED its
    target bank but before any header flip.  Either way the manifest
    names the generation all shards agree on and recovery lands where a
    flushed-but-uncommitted crash lands."""
    def build():
        a, d, t, h = _mixed(4, commit_mode=commit_mode)
        _trace(a, d, t, h, n_ops=6)
        d.append_batch(np.ones((3, 7), np.int64))
        return a, d, t, h

    a0, d0, t0, h0 = build()
    gen0 = a0.header_generation()
    a0.crash()
    _recover(a0, d0, t0, h0)
    want = _fingerprint(a0, d0, t0, h0)

    a, d, t, h = build()
    a.commit(_crash_after_shard=crash_after_shard)
    rep = _recover(a, d, t, h)
    assert rep.valid and rep.generation == gen0 == 6
    got = _fingerprint(a, d, t, h)
    assert got.keys() == want.keys()
    for k in want:
        np.testing.assert_array_equal(got[k], want[k], err_msg=k)
    # not wedged: the next commit seals gen 7 everywhere
    a.commit()
    assert a.header_generation() == 7 and a.header_valid()


@pytest.mark.parametrize("n_shards", [1, 4])
def test_shadow_gc_crash_is_idempotent(n_shards):
    """Double failure inside shadow-bank reclamation: the fold of the
    committed bank's rows back into their home slots is interrupted
    mid-region (limit=1), power fails, recovery reruns — twice in a
    row.  The fold only ever writes committed values over dead bytes,
    so the committed fingerprint must never move and the substrate must
    still commit afterwards."""
    a, d, t, h = _mixed(n_shards, commit_mode="shadow")
    _trace(a, d, t, h, n_ops=6)
    a.crash()
    _recover(a, d, t, h)
    want = _fingerprint(a, d, t, h)
    for _ in range(2):
        for sh in (a.shards if hasattr(a, "shards") else [a]):
            sh._shadow_collapse(limit=1)   # partial fold ...
        a.crash()                          # ... then power loss
        rep = _recover(a, d, t, h)
        assert rep.valid and rep.generation == 6
        got = _fingerprint(a, d, t, h)
        for k in want:
            np.testing.assert_array_equal(got[k], want[k], err_msg=k)
    d.append_batch(np.ones((2, 7), np.int64))
    a.commit()
    assert a.header_generation() == 7 and a.header_valid()


def test_single_arena_sealed_unflipped_discards_epoch():
    """Plain-Arena flavor of the torn-flip window: the commit sequence
    runs through collapse + drain + seal, then crashes before the
    generation flip.  The sealed target bank is orphaned — recovery
    reads the committed bank and the epoch vanishes whole."""
    def build():
        a, d, t, h = _mixed(1, commit_mode="shadow")
        _trace(a, d, t, h, n_ops=4)
        d.append_batch(np.ones((3, 7), np.int64))  # drained on close
        return a, d, t, h

    # reference: same epoch drained, commit never started
    a, d, t, h = build()
    a.crash()
    _recover(a, d, t, h)
    want = _fingerprint(a, d, t, h)
    # fuzzed: run commit's sub-steps up to the seal, crash pre-flip
    a2, d2, t2, h2 = build()
    a2._shadow_collapse()
    a2.writeset.flush()
    a2._shadow_seal()
    a2.crash()
    rep = _recover(a2, d2, t2, h2)
    assert rep.valid and rep.generation == 4
    got = _fingerprint(a2, d2, t2, h2)
    for k in want:
        np.testing.assert_array_equal(got[k], want[k], err_msg=k)
    d2.append_batch(np.ones((2, 7), np.int64))
    a2.commit()
    assert a2.header_generation() == 5 and a2.header_valid()


def test_manifest_is_written_last_on_disk(tmp_path):
    path = str(tmp_path / "arena")
    a = open_arena(path, DoublyLinkedList.layout(128), n_shards=3)
    d = DoublyLinkedList(a, 128)
    d.append_batch(np.arange(21, dtype=np.int64).reshape(3, 7))
    a.commit()
    for k in range(3):
        assert os.path.exists(f"{path}.s{k}")
    assert os.path.exists(path + ".manifest")
    a.close()
    # fresh-process open: committed generation + data come back
    a2 = open_arena(path, DoublyLinkedList.layout(128), n_shards=3)
    d2 = DoublyLinkedList(a2, 128)
    rep = RecoveryManager(a2).add("dll", "pstruct.dll", d2).recover()
    assert rep.valid and rep.generation == 1
    assert d2.count == 3


def test_reopening_with_wrong_shard_count_fails_loudly(tmp_path):
    """The manifest records n_shards precisely so a mis-configured
    fresh-process open cannot silently map the wrong number of backing
    files and 'recover' garbage."""
    path = str(tmp_path / "arena")
    a = open_arena(path, DoublyLinkedList.layout(128), n_shards=2)
    a.commit()
    a.close()
    with pytest.raises(ValueError, match="2 shards, opened with 4"):
        open_arena(path, DoublyLinkedList.layout(128), n_shards=4)


def test_shard_header_ahead_of_manifest_is_still_valid():
    """Shards ahead of the manifest (gen+1 committed, manifest at gen)
    are torn territory the structures bound away — validity only
    requires every shard to have REACHED the manifest generation."""
    a, d, t, h = _mixed(2)
    _trace(a, d, t, h, n_ops=4)
    a.commit(_crash_after_shard=0)
    assert a.header_valid()
    # but a shard BEHIND the manifest is corruption
    a.shards[1].generation = 0
    a.shards[1]._write_header(valid=True)
    assert not a.header_valid()


# ----------------------------- global data-before-metadata barrier


def test_data_before_metadata_barrier_is_global():
    """Data region pinned to shard 1, header pinned to shard 0: a torn
    flush (include_meta=False) must persist shard 1's data and drop
    shard 0's header mark — the barrier orders PHASES across all
    shards, not per shard."""
    a = open_arena(None, {"r": (np.int64, (64, 8), ("shard", 1)),
                          "r.header": (np.int64, (1, 8), ("shard", 0))},
                   n_shards=2)
    r, hdr = a.regions["r"], a.regions["r.header"]
    with a.epoch():
        r.vol[5] = 7
        r.mark_rows(np.array([5]))
        hdr.vol[0, 0] = 99
        hdr.mark_rows(np.array([0]))
        a.writeset.flush(include_meta=False)
        assert not a.writeset
        a.crash()
    a.reopen()
    assert r.vol[5, 0] == 7          # data half landed (shard 1)
    assert hdr.vol[0, 0] == 0        # metadata half was dropped (shard 0)


# --------------------------- dependency-counter scheduler + ready_at


def test_scheduler_has_no_level_barrier():
    """A fast chain must race ahead of a slow sibling: `child` depends
    only on `fast`, so under the counter scheduler it starts while
    `slow` (same level as `fast`) is still running — the level-barrier
    implementation would have gated it on slow's end."""
    if "test.sleepy" not in reconstruct.names():
        @reconstruct.register("test.sleepy")
        def _sleepy(secs):
            import time as _t
            _t.sleep(secs)
            return {}

    mgr = RecoveryManager()
    mgr.add("slow", "test.sleepy", 0.25)
    mgr.add("fast", "test.sleepy", 0.01)
    mgr.add("child", "test.sleepy", 0.01, depends=("fast",))
    rep = mgr.recover(reopen=False, concurrency=3)
    slow, child = rep.stage("slow"), rep.stage("child")
    assert child.t_start < slow.t_end - 0.05
    assert child.ready_at >= rep.stage("fast").t_end - 1e-6
    assert [s.name for s in rep.stages] == ["slow", "fast", "child"]


def test_stage_reports_expose_ready_at_and_queue_wait(rng):
    a, d, t, h = _mixed(3)
    _trace(a, d, t, h, n_ops=5)
    a.crash()
    rep = _recover(a, d, t, h, concurrency=2)
    names = [s.name for s in rep.stages]
    # sharded arena + declared regions => per-region load stages for
    # the BULK regions (>= 64 KiB; smaller ones load in the reopen
    # prologue), biggest first, between reopen and the rebuilds
    assert names[0] == "reopen"
    loads = [n for n in names if n.startswith("load:")]
    assert set(loads) == {"load:bt.nodes", "load:bt.records"}
    assert names[-3:] == ["dll", "bt", "hm"]
    for s in rep.stages:
        assert s.t_start >= s.ready_at >= 0.0
        dd = s.as_dict()
        assert "ready_at" in dd and "queue_wait" in dd
        assert dd["queue_wait"] >= 0.0


def test_same_named_regions_across_arenas_all_reload(rng):
    """Two sharded arenas in one manager, both holding a region named
    'dll.nodes' big enough to become a load stage: the stage must reload
    BOTH arenas' regions (neither may be left zeroed by the reopen
    exclusion)."""
    arenas, dlls = [], []
    for k in range(2):
        a = open_arena(None, DoublyLinkedList.layout(2048), n_shards=2)
        d = DoublyLinkedList(a, 2048)
        d.append_batch(rng.integers(1, 9, (64 * (k + 1), 7)))
        a.commit()
        arenas.append(a)
        dlls.append(d)
    for a in arenas:
        a.crash()
    mgr = RecoveryManager(*arenas)
    mgr.add("d0", "pstruct.dll", dlls[0],
            regions=("dll.nodes", "dll.header"))
    mgr.add("d1", "pstruct.dll", dlls[1],
            regions=("dll.nodes", "dll.header"))
    rep = mgr.recover(concurrency=2)
    assert "load:dll.nodes" in [s.name for s in rep.stages]
    assert dlls[0].count == 64 and dlls[1].count == 128
    assert (dlls[0].data[dlls[0].to_list()] != 0).all()
    assert (dlls[1].data[dlls[1].to_list()] != 0).all()


def test_serial_and_concurrent_sharded_recovery_bit_identical():
    a, d, t, h = _mixed(4)
    _trace(a, d, t, h)
    a.crash()
    _recover(a, d, t, h, concurrency=1)
    fp1 = _fingerprint(a, d, t, h)
    a.crash()
    _recover(a, d, t, h, concurrency=4)
    fp4 = _fingerprint(a, d, t, h)
    for k in fp1:
        np.testing.assert_array_equal(fp4[k], fp1[k], err_msg=k)


# ------------------------------------------------- serving engine


def test_engine_stripes_tokens_and_admits_per_shard_group(tmp_path):
    import jax
    import jax.numpy as jnp

    from repro.configs import base, registry
    from repro.models.model import build
    from repro.serve.engine import EngineConfig, ServingEngine

    model = build(base.reduced(registry.get("llama3.2-3b")),
                  compute_dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params,
                        EngineConfig(max_batch=2, s_max=16,
                                     max_requests=16, n_shards=2),
                        arena_path=str(tmp_path / "a"))
    assert isinstance(eng.arena, ShardedArena)
    # slot-per-shard striping of the token slab
    np.testing.assert_array_equal(
        eng.arena.region_shards("tokens", np.array([0, 1])), [0, 1])
    eng.add_request(7, np.array([1, 2, 3], np.int64))
    eng.add_request(8, np.array([4, 5, 6], np.int64))   # same prompt len
    out0 = dict(eng.step())
    eng.crash()
    eng.recover()
    det = eng.last_recovery.stage("engine").detail
    # same length, DIFFERENT token-log shards: admission goes per
    # shard-group, so two groups (a single arena would batch them once)
    assert det["prefill_groups"] == 2
    assert det["shard_groups"] == 2
    # greedy decode stays bit-checkable across the sharded substrate
    assert sorted(out0) == [7, 8]
    out1 = dict(eng.step())
    assert sorted(out1) == [7, 8]


def test_single_shard_sharded_arena_matches_plain(rng):
    """ShardedArena(n_shards=1) behaves like the plain Arena (the
    open_arena fast path) for the same trace — belt and braces for the
    degenerate configuration."""
    a1 = open_arena(None, DoublyLinkedList.layout(128), n_shards=1)
    assert isinstance(a1, Arena)
    sh = ShardedArena(None, n_shards=1)
    for name, spec in DoublyLinkedList.layout(128).items():
        sh.region(name, spec[0], spec[1],
                  router=spec[2] if len(spec) > 2 else None)
    sh.finalize()
    d1 = DoublyLinkedList(a1, 128)
    d2 = DoublyLinkedList(sh, 128)
    vals = rng.integers(0, 9, (20, 7))
    d1.append_batch(vals)
    d2.append_batch(vals)
    a1.commit()
    sh.commit()
    assert a1.stats.lines == sh.stats.lines
    a1.crash(), sh.crash()
    a1.reopen(), sh.reopen()
    d1.reconstruct(), d2.reconstruct()
    np.testing.assert_array_equal(d1.to_list(), d2.to_list())
