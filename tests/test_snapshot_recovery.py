"""Incremental order snapshots (DESIGN.md §10): torn-snapshot-record
sweep, suffix-only replay, env gating, accounting isolation, and the
device-side verify.

The torn-record sweep is the crash-point fuzzer's snapshot axis: power
fails mid-snapshot-append at every epoch boundary, under both commit
protocols, and — via the REPRO_N_SHARDS env axis the CI matrix drives —
on a sharded substrate.  Recovery must refuse the torn snapshot
(verify-always adoption) and land on EXACTLY the state a full
contraction rebuild recovers.
"""
import os

import numpy as np
import pytest

from repro.core.arena import (SNAP_SLOTS, open_arena, snap_record_pack,
                              snap_record_parse, snapshot_enabled)
from repro.core.recovery import ChainSnapshot, RecoveryManager, chain_order
from repro.pstruct.dll import DoublyLinkedList, _reconstruct_dll
from repro.pstruct.hashmap import Hashmap, _reconstruct_hashmap

N_SHARDS = int(os.environ.get("REPRO_N_SHARDS", "1"))
MODES = ["barrier", "shadow"]


# ----------------------------------------------------------- helpers

def _build(commit_mode, n_shards=N_SHARDS, snapshot=True):
    layout = {}
    layout.update(DoublyLinkedList.layout(256, name="dll",
                                          snapshot=snapshot))
    layout.update(Hashmap.layout(512, name="hm", snapshot=snapshot))
    a = open_arena(None, layout, n_shards=n_shards,
                   commit_mode=commit_mode)
    return (a, DoublyLinkedList(a, 256, name="dll", snapshot=snapshot),
            Hashmap(a, 512, name="hm", snapshot=snapshot))


def _script(n_ops, seed=0):
    """Mixed append/insert/delete workload: every op is one epoch +
    commit, so every boundary seals a snapshot record."""
    rng = np.random.default_rng(seed)
    ops = []
    key = 0
    for i in range(n_ops):
        m = int(rng.integers(2, 7))
        vals = rng.integers(0, 1 << 30, (m, 7)).astype(np.int64)
        keys = np.arange(key, key + m, dtype=np.int64)
        key += m
        ops.append(("dll" if i % 3 == 0 else ("hm" if i % 3 == 1
                                              else "dll_del"),
                    keys, vals))
    return ops


def _apply(d, h, op, dll_ids):
    kind, keys, vals = op
    if kind == "dll":
        dll_ids.extend(d.append_batch(vals).tolist())
    elif kind == "hm":
        h.insert_batch(keys, vals)
    elif kind == "dll_del" and len(dll_ids) >= 2:
        doomed = np.asarray(dll_ids[::7][:2], np.int64)
        d.delete_batch(doomed)
        for x in doomed.tolist():
            dll_ids.remove(x)
    else:
        dll_ids.extend(d.append_batch(vals).tolist())


def _state(d, h, hm_keys):
    order = d.to_list()
    if hm_keys:
        ok, got = h.find_batch(np.asarray(hm_keys, np.int64))
    else:
        ok, got = np.ones(0, bool), np.zeros((0, 7), np.int64)
    return {"order": order.copy(), "data": d.data[order].copy(),
            "hm_size": h.size, "hm_ok": ok.copy(), "hm_vals": got.copy()}


def _reload(a, d, h):
    a.reopen()
    d.header.load(); d.nodes.load()
    h.header.load(); h.entries.load()
    if d.snapshot:
        d.snapring.load(); d.snaprec.load()
    if h.snapshot:
        h.snapbkt.load(); h.snapchain.load(); h.snaprec.load()


def _assert_state(d, h, hm_keys, want):
    got = _state(d, h, hm_keys)
    np.testing.assert_array_equal(got["order"], want["order"])
    np.testing.assert_array_equal(got["data"], want["data"])
    assert got["hm_size"] == want["hm_size"]
    assert got["hm_ok"].all() == want["hm_ok"].all()
    np.testing.assert_array_equal(got["hm_vals"], want["hm_vals"])


# ----------------------------------- torn-snapshot-record crash sweep

@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("tear", ["record", "all"])
def test_torn_snapshot_record_sweep(mode, tear):
    """Crash mid-snapshot-append at EVERY epoch boundary: the newest
    record line lands garbled ("record") or the whole record ring plus
    half the mirror lands garbled ("all").  Verify-always adoption must
    refuse anything inconsistent and recover bit-identical state — via
    an older record + suffix replay, or the full contraction/rebuild
    fallback."""
    ops = _script(12)
    for boundary in range(len(ops)):
        a, d, h = _build(mode)
        hm_keys, dll_ids = [], []
        for i in range(boundary + 1):
            _apply(d, h, ops[i], dll_ids)
            if ops[i][0] == "hm":
                hm_keys.extend(ops[i][1].tolist())
            a.commit()
        want = _state(d, h, hm_keys)
        a.crash()
        _reload(a, d, h)
        # garble snapshot bytes as loaded — the mid-append torn image
        newest = max((r for s in range(SNAP_SLOTS)
                      if (r := snap_record_parse(d.snaprec.vol[s]))
                      is not None), key=lambda r: r[1], default=None)
        if tear == "record":
            if newest is not None:
                d.snaprec.vol[newest[1] % SNAP_SLOTS, 3:] = -777
                h.snaprec.vol[newest[1] % SNAP_SLOTS, 3:] = -777
        else:
            d.snaprec.vol[:, 2:] = -777
            h.snaprec.vol[:, 2:] = -777
            d.snapring.vol[::2] = 2 ** 40
            h.snapchain.vol[::2] = 2 ** 40
        det_d = _reconstruct_dll(d)
        det_h = _reconstruct_hashmap(h)
        if tear == "all":
            assert det_d["chain"] in ("double", "contract")
            assert det_h["chain"] == "rebuild"
        _assert_state(d, h, hm_keys, want)


# ------------------------------------------------- suffix-only replay

@pytest.mark.parametrize("mode", MODES)
def test_suffix_replay_length_matches_delta(mode):
    """Tear only the newest record: recovery seeds from the previous
    record and replays exactly the rows committed after it."""
    a, d, h = _build(mode)
    d.append_batch(np.arange(280).reshape(40, 7).astype(np.int64))
    a.commit()
    k = np.arange(50, dtype=np.int64)
    h.insert_batch(k, np.tile(k[:, None], (1, 7)))
    a.commit()
    d.append_batch(np.ones((9, 7), np.int64))          # suffix: 9 nodes
    a.commit()
    h.insert_batch(k + 100, np.zeros((50, 7), np.int64))  # suffix: 50
    a.commit()
    want = _state(d, h, k.tolist() + (k + 100).tolist())
    a.crash()
    _reload(a, d, h)
    for reg in (d.snaprec, h.snaprec):
        newest = max((r for s in range(SNAP_SLOTS)
                      if (r := snap_record_parse(reg.vol[s])) is not None),
                     key=lambda r: r[1])
        reg.vol[newest[1] % SNAP_SLOTS, 3:] = -777
    det_d = _reconstruct_dll(d)
    det_h = _reconstruct_hashmap(h)
    assert det_d["chain"] == "snapshot" and det_d["replayed"] == 9
    assert det_h["chain"] == "snapshot" and det_h["replayed"] == 50
    _assert_state(d, h, k.tolist() + (k + 100).tolist(), want)


def test_clean_recovery_adopts_without_replay():
    a, d, h = _build("barrier")
    d.append_batch(np.arange(70).reshape(10, 7).astype(np.int64))
    k = np.arange(30, dtype=np.int64)
    h.insert_batch(k, np.tile(k[:, None], (1, 7)))
    a.commit()
    a.crash()
    _reload(a, d, h)
    det_d = _reconstruct_dll(d)
    det_h = _reconstruct_hashmap(h)
    assert det_d == {"mode": "partly", "count": 10, "chain": "snapshot",
                     "replayed": 0}
    assert det_h["chain"] == "snapshot" and det_h["replayed"] == 0


def test_persisted_record_tear_survives_restart():
    """Tear the record at the PERSISTED layer (no reliance on the
    volatile load path) and reconstruct through fresh objects — the
    cross-process shape of the fuzzer."""
    a, d, h = _build("barrier", n_shards=1)
    d.append_batch(np.arange(70).reshape(10, 7).astype(np.int64))
    a.commit()
    d.append_batch(np.ones((5, 7), np.int64))
    a.commit()
    want_order = d.to_list().copy()
    newest = max((r for s in range(SNAP_SLOTS)
                  if (r := snap_record_parse(d.snaprec.vol[s])) is not None),
                 key=lambda r: r[1])
    d.snaprec._pview()[newest[1] % SNAP_SLOTS, 4:] = -777
    a.crash()
    _reload(a, d, h)
    det = _reconstruct_dll(d)
    assert det["chain"] == "snapshot" and det["replayed"] == 5
    np.testing.assert_array_equal(d.to_list(), want_order)


# ------------------------------------------- gating + layout parity

def test_env_gate_and_layout_parity(monkeypatch):
    monkeypatch.setenv("REPRO_SNAPSHOT", "0")
    assert not snapshot_enabled(None)
    assert snapshot_enabled(True)          # explicit flag wins
    off = DoublyLinkedList.layout(64, name="x")
    assert not any(".snap" in n for n in off)
    off_hm = Hashmap.layout(64, name="x")
    assert not any(".snap" in n for n in off_hm)
    monkeypatch.setenv("REPRO_SNAPSHOT", "1")
    assert snapshot_enabled(None)
    assert not snapshot_enabled(False)
    on = DoublyLinkedList.layout(64, name="x")
    assert {n for n in on} - {n for n in off} == {"x.snapring", "x.snaprec"}


def test_snapshot_off_recovery_identical_states():
    """The REPRO_SNAPSHOT=0 rerun axis: recovered structure state must
    be identical with snapshots on and off (the snapshot is pure
    derivable redundancy)."""
    states = {}
    for snap in (True, False):
        a, d, h = _build("barrier", snapshot=snap)
        hm_keys, dll_ids = [], []
        for op in _script(8):
            _apply(d, h, op, dll_ids)
            if op[0] == "hm":
                hm_keys.extend(op[1].tolist())
            a.commit()
        a.crash()
        _reload(a, d, h)
        _reconstruct_dll(d)
        _reconstruct_hashmap(h)
        states[snap] = _state(d, h, hm_keys)
    np.testing.assert_array_equal(states[True]["order"],
                                  states[False]["order"])
    np.testing.assert_array_equal(states[True]["data"],
                                  states[False]["data"])
    np.testing.assert_array_equal(states[True]["hm_vals"],
                                  states[False]["hm_vals"])
    assert states[True]["hm_size"] == states[False]["hm_size"]


# ------------------------------------------------ accounting isolation

def test_snapshot_lines_accounted_separately():
    """snapshot_lines is a separate counter: data lines / bytes / dedup
    savings are bit-comparable between snapshot-on and snapshot-off runs
    of the same workload."""
    stats = {}
    for snap in (True, False):
        a, d, h = _build("barrier", n_shards=N_SHARDS, snapshot=snap)
        hm_keys, dll_ids = [], []
        for op in _script(10, seed=3):
            _apply(d, h, op, dll_ids)
            a.commit()
        stats[snap] = a.stats
    on, off = stats[True], stats[False]
    assert on.snapshot_lines > 0
    assert off.snapshot_lines == 0
    assert on.lines == off.lines
    assert on.bytes == off.bytes
    assert on.saved_lines == off.saved_lines
    assert on.calls == off.calls


# --------------------------------------------- manager stage details

def test_manager_stage_detail_reports_chain():
    a, d, h = _build("barrier")
    d.append_batch(np.arange(70).reshape(10, 7).astype(np.int64))
    k = np.arange(20, dtype=np.int64)
    h.insert_batch(k, np.tile(k[:, None], (1, 7)))
    a.commit()
    a.crash()
    mgr = RecoveryManager(a)
    mgr.add("dll", "pstruct.dll", d)
    mgr.add("hm", "pstruct.hashmap", h)
    report = mgr.recover()
    details = {s.name: s.detail for s in report.stages}
    assert details["dll"]["chain"] == "snapshot"
    assert details["dll"]["replayed"] == 0
    assert details["hm"]["chain"] == "snapshot"
    assert details["hm"]["replayed"] == 0


# ------------------------------------------------- host + device seed

def test_chain_order_snapshot_seed_host():
    n = 300
    perm = np.random.default_rng(1).permutation(n)[:120]
    nxt = np.full(n, -1, np.int64)
    nxt[perm[:-1]] = perm[1:]
    head = int(perm[0])
    s = ChainSnapshot(perm)
    got = chain_order(nxt, head, 120, snapshot=s)
    np.testing.assert_array_equal(got, perm)
    assert s.outcome == "snapshot"
    bad = perm.copy()
    bad[5] = bad[6]
    s2 = ChainSnapshot(bad)
    got2 = chain_order(nxt, head, 120, snapshot=s2)
    np.testing.assert_array_equal(got2, perm)
    assert s2.outcome != "snapshot" and s2.replayed == 120


def test_chain_order_snapshot_seed_device():
    from repro.kernels import chain_order as co
    n = 600
    perm = np.random.default_rng(2).permutation(n)[:200]
    nxt = np.full(n, -1, np.int64)
    nxt[perm[:-1]] = perm[1:]
    head = int(perm[0])
    calls0 = co.KERNEL_CALLS
    s = ChainSnapshot(perm)
    got = co.chain_order_device(nxt, head, snapshot=s)
    np.testing.assert_array_equal(got, perm)
    assert s.outcome == "snapshot"
    assert co.KERNEL_CALLS - calls0 == 1     # one verify gather, no rank
    # a strict prefix must NOT be adopted (chain continues past it)
    s2 = ChainSnapshot(perm[:50])
    got2 = co.chain_order_device(nxt, head, snapshot=s2)
    np.testing.assert_array_equal(got2, perm)
    assert s2.outcome != "snapshot" and s2.replayed == 200


def test_record_checksum_rejects_bitflips():
    rec = snap_record_pack(3, 7, 10, 20, 30)
    assert snap_record_parse(rec) == (3, 7, 10, 20, 30, 0)
    for w in range(8):
        bad = rec.copy()
        bad[w] ^= 1 << 17
        assert snap_record_parse(bad) is None
