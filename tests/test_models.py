"""Model-layer unit tests: attention variants, RoPE, ring cache, loss
chunking, MoE dispatch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.models import layers as L
from repro.models import moe as M

KEY = jax.random.PRNGKey(0)


def naive_attention(q, k, v, causal=True, window=0, softcap=0.0):
    """O(S^2) reference: q (B,S,K,G,D); k,v (B,S,K,D)."""
    b, s, nk, g, d = q.shape
    qf = q.astype(jnp.float32) / jnp.sqrt(d)
    s_ = jnp.einsum("bqkgd,bjkd->bkgqj", qf, k.astype(jnp.float32))
    if softcap:
        s_ = softcap * jnp.tanh(s_ / softcap)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    s_ = jnp.where(mask[None, None, None], s_, -1e30)
    p = jax.nn.softmax(s_, -1)
    out = jnp.einsum("bkgqj,bjkd->bkgqd", p, v.astype(jnp.float32))
    return out.transpose(0, 3, 1, 2, 4)


def rand_qkv(b=2, s=64, nk=2, g=2, d=16):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, nk, g, d))
    k = jax.random.normal(ks[1], (b, s, nk, d))
    v = jax.random.normal(ks[2], (b, s, nk, d))
    return q, k, v


@pytest.mark.parametrize("qb,kb", [(64, 64), (16, 16), (32, 8), (16, 64)])
def test_blockwise_attention_matches_naive(qb, kb):
    q, k, v = rand_qkv()
    got = L.blockwise_attention(q, k, v, causal=True, q_block=qb,
                                kv_block=kb)
    want = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [8, 16, 40])
def test_sliding_window_matches_naive(window):
    q, k, v = rand_qkv()
    got = L.blockwise_attention(q, k, v, causal=True, window=window,
                                q_block=16, kv_block=16)
    want = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_softcap_matches_naive():
    q, k, v = rand_qkv()
    got = L.blockwise_attention(q, k, v, causal=True, softcap=30.0,
                                q_block=16, kv_block=16)
    want = naive_attention(q, k, v, causal=True, softcap=30.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_bidirectional_attention():
    q, k, v = rand_qkv()
    got = L.blockwise_attention(q, k, v, causal=False, q_block=16,
                                kv_block=16)
    want = naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_decode_attention_matches_last_row():
    """decode at position s-1 == last row of full attention."""
    q, k, v = rand_qkv(s=32)
    full = naive_attention(q, k, v, causal=True)
    kv_pos = jnp.arange(32)
    got = L.decode_attention(q[:, -1:], k, v, kv_pos,
                             jnp.asarray(31))
    np.testing.assert_allclose(np.asarray(got[:, 0]),
                               np.asarray(full[:, -1]), atol=2e-5, rtol=2e-5)


def test_ring_buffer_positions():
    cap = 8
    for pos in [0, 3, 7, 8, 13, 100]:
        slots = np.asarray(L.ring_slot_positions(jnp.asarray(pos), cap))
        for w, p in enumerate(slots):
            if p >= 0:
                assert p % cap == w and p <= pos
                assert p + cap > pos  # the newest value for that slot


def test_rope_rotation_invariant():
    """RoPE preserves norms and relative-position inner products."""
    x = jax.random.normal(KEY, (1, 8, 2, 16))
    pos = jnp.arange(8)
    r = L.apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(r), axis=-1),
                               rtol=1e-5)
    # q.k after rope depends only on relative distance
    q = jnp.ones((1, 8, 1, 16))
    k = jnp.ones((1, 8, 1, 16))
    qr = L.apply_rope(q, pos, 10000.0)
    kr = L.apply_rope(k, pos, 10000.0)
    dots = np.einsum("bqhd,bkhd->qk", np.asarray(qr), np.asarray(kr))
    d1 = np.diag(dots, k=1)
    np.testing.assert_allclose(d1, d1[0] * np.ones_like(d1), rtol=1e-5)


def test_moe_capacity_and_combine():
    cfg = MoEConfig(n_experts=4, top_k=2, capacity_factor=8.0,
                    router_group=16)
    d, g, b = 8, 16, 2
    ks = jax.random.split(KEY, 5)
    p = M.MoEParams(
        router=jax.random.normal(ks[0], (d, 4)),
        w_gate=0.1 * jax.random.normal(ks[1], (4, d, 16)),
        w_up=0.1 * jax.random.normal(ks[2], (4, d, 16)),
        w_down=0.1 * jax.random.normal(ks[3], (4, 16, d)),
    )
    x = jax.random.normal(ks[4], (b, g, d))
    y = M.moe_ffn(x, p, cfg, "silu")
    assert y.shape == x.shape
    # with huge capacity, no token dropped: output == dense mixture ref
    logits = jnp.einsum("bgd,de->bge", x, p.router)
    top_w, top_e = jax.lax.top_k(logits, 2)
    top_w = jax.nn.softmax(top_w, -1)
    def ffn_e(xv, e):
        h = jax.nn.silu(xv @ p.w_gate[e]) * (xv @ p.w_up[e])
        return h @ p.w_down[e]
    want = np.zeros((b, g, d), np.float32)
    for bi in range(b):
        for gi in range(g):
            for kk in range(2):
                e = int(top_e[bi, gi, kk])
                want[bi, gi] += float(top_w[bi, gi, kk]) * np.asarray(
                    ffn_e(x[bi, gi], e))
    np.testing.assert_allclose(np.asarray(y), want, atol=1e-4, rtol=1e-4)


def test_moe_capacity_drops_overflow():
    """top_k tokens beyond expert capacity are dropped (contribute 0)."""
    cfg = MoEConfig(n_experts=2, top_k=1, capacity_factor=0.5,
                    router_group=8)
    d = 4
    # router forces every token to expert 0; capacity = 8*1*0.5/2 = 2
    p = M.MoEParams(
        router=jnp.stack([jnp.ones(d), -jnp.ones(d)], 1),
        w_gate=jnp.ones((2, d, 8)), w_up=jnp.ones((2, d, 8)),
        w_down=jnp.ones((2, 8, d)),
    )
    x = jnp.abs(jax.random.normal(KEY, (1, 8, d))) + 0.1
    y = M.moe_ffn(x, p, cfg, "silu")
    contributed = (np.abs(np.asarray(y[0])) > 1e-9).any(1)
    assert contributed.sum() == 2  # exactly `capacity` tokens got output


def test_loss_chunking_equivalence():
    from repro.configs import base, registry
    from repro.models.model import build
    cfg = base.reduced(registry.get("llama3.2-3b"))
    m1 = build(cfg, compute_dtype=jnp.float32, loss_chunk=4)
    m2 = build(cfg, compute_dtype=jnp.float32, loss_chunk=1 << 20)
    params = m1.init_params(KEY)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    l1 = m1.loss(params, batch)
    l2 = m2.loss(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_slstm_custom_vjp_matches_autodiff():
    """The sLSTM custom VJP (one post-scan recurrent-weight contraction,
    §Perf) must match plain autodiff of the per-step cell."""
    from repro.models import xlstm as X
    B, S, H, Dh = 2, 10, 3, 8
    ks = jax.random.split(KEY, 2)
    pre = 0.5 * jax.random.normal(ks[0], (B, S, 4, H, Dh))
    r = 0.3 * jax.random.normal(ks[1], (4, H, Dh, Dh))
    st0 = X.slstm_init_state(B, H, Dh)

    def ref_scan(pre, r):
        def body(st, pre_t):
            st2 = X._slstm_cell(st, pre_t, r)
            return st2, st2.h
        sf, hs = jax.lax.scan(body, st0, pre.swapaxes(0, 1))
        return hs.swapaxes(0, 1), sf

    def loss_ref(pre, r):
        hs, sf = ref_scan(pre, r)
        return jnp.sum(jnp.sin(hs)) + jnp.sum(sf.c * 0.3)

    def loss_new(pre, r):
        hs, sf = X.slstm_scan(pre, r, st0)
        return jnp.sum(jnp.sin(hs)) + jnp.sum(sf.c * 0.3)

    l1 = loss_ref(pre, r)
    l2 = loss_new(pre, r)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    g1 = jax.grad(loss_ref, (0, 1))(pre, r)
    g2 = jax.grad(loss_new, (0, 1))(pre, r)
    for a, b in zip(g1, g2):
        rel = float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(a)) + 1e-9))
        assert rel < 5e-3, rel  # drec stacked bf16 => small quantization
