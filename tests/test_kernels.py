"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Sweeps shapes/dtypes per kernel and asserts allclose against ref.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (chain_order, hash_probe, ops, pack_flush,
                           quant_pack, ref)

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------- pack

@pytest.mark.parametrize("n,d", [(8, 128), (64, 256), (33, 384), (128, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_pack_rows_sweep(n, d, dtype):
    src = (jax.random.normal(KEY, (n, d)) * 10).astype(dtype)
    idx = jnp.asarray(
        np.random.default_rng(1).choice(n + 1, size=min(n, 16)) - 1,
        jnp.int32)  # includes -1 sentinels
    got = pack_flush.pack_rows(src, idx, interpret=True)
    want = ref.pack_rows_ref(src, idx)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n,d", [(16, 128), (64, 512), (40, 896)])
def test_scatter_rows_roundtrip(n, d):
    src = jax.random.normal(KEY, (n, d))
    m = n // 2
    idx = jnp.asarray(np.random.default_rng(2).choice(n, m, replace=False),
                      jnp.int32)
    packed = pack_flush.pack_rows(src, idx, block_d=128, interpret=True)
    dst = jnp.zeros((n, d))
    got = pack_flush.scatter_rows(dst, packed, idx, block_d=128,
                                  interpret=True)
    want = ref.scatter_rows_ref(dst, packed, idx)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # scatter(pack(x)) restores exactly the selected rows
    np.testing.assert_array_equal(np.asarray(got[idx]), np.asarray(src[idx]))


def test_pack_unaligned_width_via_ops():
    """ops.pack_rows pads non-128-multiple widths (the Fig-12 alignment
    path) and unpads the result."""
    src = jax.random.normal(KEY, (32, 300))
    idx = jnp.array([3, 1, -1, 31], jnp.int32)
    got = ops.pack_rows(src, idx)
    want = ref.pack_rows_ref(src, idx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


# ------------------------------------------------------------ quantize

@pytest.mark.parametrize("n,d", [(8, 256), (64, 512), (16, 2048)])
@pytest.mark.parametrize("scale", [1e-3, 1.0, 1e3])
def test_quantize_blockwise_sweep(n, d, scale):
    x = jax.random.normal(KEY, (n, d)) * scale
    q, s = quant_pack.quantize_blockwise(x, interpret=True)
    qr, sr = ref.quantize_blockwise_ref(x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    # dequant error bound: |x - dq| <= scale_per_group (1/127 of absmax)
    dq = quant_pack.dequantize_blockwise(q, s, interpret=True)
    err = np.abs(np.asarray(x) - np.asarray(dq))
    bound = np.repeat(np.asarray(s), quant_pack.GROUP, axis=1) * 0.5001
    assert (err <= bound + 1e-9).all()


def test_quantize_leaf_any_shape():
    for shape in [(7,), (3, 5), (2, 3, 4, 5), ()]:
        x = jax.random.normal(KEY, shape) * 3
        q, s = ops.quantize_leaf(x)
        back = ops.dequantize_leaf(q, s, x.shape, x.dtype)
        assert back.shape == x.shape
        np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                                   atol=0.05 * max(1.0, float(jnp.max(jnp.abs(x)) if x.size else 0.0)))


# ---------------------------------------------------------- hash probe

def test_hash_probe_matches_ref():
    nb = 64
    rng = np.random.default_rng(3)
    table = np.full((nb, hash_probe.BUCKET), -1, np.int32)
    keys = rng.choice(100000, 500, replace=False).astype(np.int32)
    # place each key in its hash bucket (first free lane)
    for k in keys:
        b = int(np.asarray(ops.hash32(jnp.asarray([k]))[0]) % nb)
        lane = int(np.argmax(table[b] == -1))
        table[b, lane] = k
    tbl = jnp.asarray(table)
    queries = jnp.asarray(np.concatenate([keys[:64],
                                          keys[:32] + 1000000]), jnp.int32)
    h = ops.hash32(queries)
    bids = (h % jnp.uint32(nb)).astype(jnp.int32)
    got = hash_probe.probe(tbl, queries, bids, interpret=True)
    want = ref.probe_ref(tbl, queries, bids)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # present keys found, absent -> -1
    assert (np.asarray(got[:64]) >= 0).all()
    assert (np.asarray(got[64:]) == -1).all()


def test_hash_lookup_end_to_end():
    nb = 32
    keys = jnp.arange(100, 150, dtype=jnp.int32)
    table = np.full((nb, hash_probe.BUCKET), -1, np.int32)
    for k in np.asarray(keys):
        b = int(np.asarray(ops.hash32(jnp.asarray([k]))[0]) % nb)
        table[b, np.argmax(table[b] == -1)] = k
    got = ops.hash_lookup(jnp.asarray(table),
                          jnp.array([100, 149, 999], jnp.int32))
    g = np.asarray(got)
    assert g[0] >= 0 and g[1] >= 0 and g[2] == -1


# ----------------------------------------------------- chain order (§V-F)

@pytest.mark.parametrize("n", [8, 61, 256])
def test_jump_double_matches_ref(n):
    rng = np.random.default_rng(5)
    perm = rng.permutation(n)
    nxt = np.full(n, -1, np.int32)
    nxt[perm[:-1]] = perm[1:]
    jump = jnp.asarray(nxt)
    cnt = jnp.ones(n, jnp.int32)
    for _ in range(3):   # stays an oracle match through several rounds
        gj, gc = chain_order.jump_double(jump, cnt, interpret=True)
        wj, wc = ref.jump_double_ref(jump, cnt)
        np.testing.assert_array_equal(np.asarray(gj), np.asarray(wj))
        np.testing.assert_array_equal(np.asarray(gc), np.asarray(wc))
        jump, cnt = gj, gc


def test_chain_order_device_matches_numpy_primitive():
    from repro.core.recovery import chain_order as chain_order_np
    rng = np.random.default_rng(6)
    n = 128
    perm = rng.permutation(n)
    live = perm[:97]                       # chain covers a strict subset
    nxt = np.full(n, -1, np.int64)
    nxt[live[:-1]] = live[1:]
    head = int(live[0])
    got = chain_order.chain_order_device(nxt, head, interpret=True)
    want = chain_order_np(nxt, head)
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(got, live)


def test_chain_order_device_detects_cycle():
    nxt = np.array([1, 2, 0, -1], np.int64)
    with pytest.raises(RuntimeError, match="cycle"):
        chain_order.chain_order_device(nxt, 0, interpret=True)


def test_chain_order_device_treats_oob_pointer_as_terminator():
    """Torn-epoch contract parity with the numpy primitive: a pointer
    flushed past the committed fresh-water mark ends the chain."""
    from repro.core.recovery import chain_order as chain_order_np
    nxt = np.array([1, 8, -1, -1], np.int64)     # 8 is out of range (n=4)
    got = chain_order.chain_order_device(nxt, 0, interpret=True)
    np.testing.assert_array_equal(got, [0, 1])
    np.testing.assert_array_equal(got, chain_order_np(nxt, 0))


@pytest.mark.parametrize("n,B,N", [(203, 8, 3), (256, 64, 4), (40, 16, 4)])
def test_chain_order_device_segments_matches_global(n, B, N):
    """The sharded-arena path (DESIGN.md §7): the NEXT column arrives as
    per-shard views concatenated shard-major (`segments` offsets), with
    pointer values still global — the kernel's steering translate must
    reproduce the global-array order exactly."""
    from repro.core.recovery import chain_order as chain_order_np
    rng = np.random.default_rng(n)
    perm = rng.permutation(n)
    nxt = np.full(n, -1, np.int64)
    nxt[perm[:-1]] = perm[1:]
    head = int(perm[0])
    shard_of = (np.arange(n) // B) % N
    segments = np.zeros(N + 1, np.int64)
    packed = np.empty(n, np.int64)
    off = 0
    for s in range(N):
        gidx = np.nonzero(shard_of == s)[0]
        packed[off:off + gidx.size] = nxt[gidx]
        segments[s] = off
        off += gidx.size
    segments[N] = off
    # the closed-form translate IS the packing
    pp = chain_order.packed_positions(np.arange(n, dtype=np.int64), B,
                                      segments)
    np.testing.assert_array_equal(packed[pp], nxt)
    got = chain_order.chain_order_device(packed, head, segments=segments,
                                         seg_rows=B, interpret=True)
    np.testing.assert_array_equal(got, chain_order_np(nxt, head))


def test_chain_order_device_segments_from_sharded_dll():
    """End to end: a sharded arena's per-shard persistent NEXT views,
    concatenated WITHOUT any host re-gather, recover the DLL order the
    host primitive computes from the global volatile array."""
    from repro.core.arena import open_arena
    from repro.pstruct import dll as DL

    a = open_arena(None, DL.DoublyLinkedList.layout(256), n_shards=4)
    d = DL.DoublyLinkedList(a, 256)
    rng = np.random.default_rng(3)
    ids = d.append_batch(rng.integers(0, 9, (180, 7)).astype(np.int64))
    d.delete_batch(ids[30:60])
    a.commit()
    region = a.regions["dll.nodes"]
    packed = np.concatenate([
        sl._pview()[:, DL.DATA_WORDS] for sl in region.slices
        if sl is not None])
    segments = np.cumsum([0] + [0 if sl is None else sl.shape[0]
                                for sl in region.slices])
    got = chain_order.chain_order_device(
        packed, d.head, segments=segments, seg_rows=DL.SHARD_SEG,
        interpret=True)
    np.testing.assert_array_equal(got, d.to_list())
    # the contraction path must agree bit-for-bit on the SAME packed
    # layout (acceptance: sharded packed layout included), fused
    # walk/expand kernels and the per-hop cascade alike
    for fuse in (False, True):
        got_c = chain_order.chain_order_device(
            packed, d.head, segments=segments, seg_rows=DL.SHARD_SEG,
            method="contract", k=16, fuse=fuse, interpret=True)
        np.testing.assert_array_equal(got_c, d.to_list())


# ------------------------- contraction list ranking, device (§8)


@pytest.mark.parametrize("fuse", [False, True])
@pytest.mark.parametrize("k", [4, 32])
def test_chain_order_device_contract_matches_host(k, fuse):
    from repro.core.recovery import chain_order as chain_order_np
    rng = np.random.default_rng(7)
    n = 96
    perm = rng.permutation(n)
    live = perm[:71]
    nxt = np.full(n, -1, np.int64)
    nxt[live[:-1]] = live[1:]
    head = int(live[0])
    got = chain_order.chain_order_device(nxt, head, method="contract",
                                         k=k, fuse=fuse, interpret=True)
    np.testing.assert_array_equal(got, chain_order_np(nxt, head))
    np.testing.assert_array_equal(got, live)


def test_contract_fused_saves_round_trips():
    """The fused walk/expand kernels must resolve the same order in
    strictly fewer pallas_call round trips than the per-hop cascade —
    the deterministic quantity the fusion exists to shrink."""
    rng = np.random.default_rng(11)
    n = 512
    perm = rng.permutation(n)
    nxt = np.full(n, -1, np.int64)
    nxt[perm[:-1]] = perm[1:]
    calls = {}
    for fuse in (False, True):
        chain_order.KERNEL_CALLS = 0
        got = chain_order.chain_order_device(
            nxt, int(perm[0]), method="contract", k=8, fuse=fuse,
            interpret=True)
        np.testing.assert_array_equal(got, perm)
        calls[fuse] = chain_order.KERNEL_CALLS
    assert calls[True] < calls[False], calls


@pytest.mark.parametrize("method", ["double", "contract"])
def test_chain_order_device_mid_chain_cycle(method):
    """A cycle reachable only MID-chain (head not on it) raises on both
    device strategies: 0 -> 1 -> 2 -> 3 -> 1."""
    nxt = np.array([1, 2, 3, 1], np.int64)
    with pytest.raises(RuntimeError, match="cycle"):
        chain_order.chain_order_device(nxt, 0, method=method, k=2,
                                       interpret=True)


@pytest.mark.parametrize("fuse", [False, True])
def test_chain_order_device_contract_spine_free_cycle(fuse):
    """A mid-chain cycle containing no sampled spine node: the device
    local walk must poison the stuck segment (not spin) and still
    surface "cycle"."""
    nxt = np.full(16, -1, np.int64)
    nxt[0] = 9
    nxt[9], nxt[10], nxt[11] = 10, 11, 9     # 9/10/11 all % 8 != 0
    with pytest.raises(RuntimeError, match="cycle"):
        chain_order.chain_order_device(nxt, 0, method="contract", k=8,
                                       fuse=fuse, interpret=True)


@pytest.mark.parametrize("fuse", [False, True])
def test_chain_order_device_contract_oob_and_empty(fuse):
    from repro.core.recovery import chain_order as chain_order_np
    nxt = np.array([1, 8, -1, -1], np.int64)     # 8 OOB terminates
    got = chain_order.chain_order_device(nxt, 0, method="contract", k=2,
                                         fuse=fuse, interpret=True)
    np.testing.assert_array_equal(got, chain_order_np(nxt, 0))
    assert chain_order.chain_order_device(
        nxt, -1, method="contract", k=2, fuse=fuse,
        interpret=True).size == 0
    assert chain_order.chain_order_device(
        nxt, 99, method="contract", k=2, fuse=fuse,
        interpret=True).size == 0


# --------------------------------------- chain primitive edge cases


def test_chain_empty_chain_everywhere():
    """NULL head / empty table: every primitive returns empty, never
    indexes."""
    from repro.core import recovery as R
    nxt = np.full(4, -1, np.int64)
    assert R.chain_order(nxt, R.NULL).size == 0
    assert R.chain_order(nxt, R.NULL, 0).size == 0
    assert chain_order.chain_order_device(nxt, -1, interpret=True).size == 0
    empty = np.empty(0, np.int64)
    assert R.chain_lengths(empty, empty).size == 0
    assert R.chain_walk(nxt, empty).shape == (0, 0)


def test_chain_single_node():
    from repro.core import recovery as R
    nxt = np.array([-1], np.int64)
    np.testing.assert_array_equal(R.chain_order(nxt, 0), [0])
    np.testing.assert_array_equal(R.chain_order(nxt, 0, 1), [0])
    np.testing.assert_array_equal(
        chain_order.chain_order_device(nxt, 0, interpret=True), [0])
    np.testing.assert_array_equal(R.chain_lengths(nxt, np.array([0])), [1])
    np.testing.assert_array_equal(R.chain_walk(nxt, np.array([0])),
                                  [[0]])


def test_chain_self_loop_guard():
    """A self-loop (nxt[i] == i, the smallest cycle) must fail loudly in
    every primitive, host and device."""
    from repro.core import recovery as R
    nxt = np.array([-1, 1, -1], np.int64)        # node 1 points at itself
    with pytest.raises(RuntimeError, match="cycle"):
        R.chain_order(nxt, 1)
    with pytest.raises(RuntimeError, match="cycle"):
        R.chain_lengths(nxt, np.array([1]))
    with pytest.raises(RuntimeError, match="cycle"):
        R.chain_walk(nxt, np.array([1]))
    with pytest.raises(RuntimeError, match="cycle"):
        chain_order.chain_order_device(nxt, 1, interpret=True)


@pytest.mark.parametrize("bad", [2 ** 31 - 1, 2 ** 31, 2 ** 31 + 5,
                                 2 ** 32 + 3, -(2 ** 31)])
def test_chain_int32_overflow_adjacent_pointers_terminate(bad):
    """Torn 64-bit pointers adjacent to the int32 boundary must behave
    as terminators, not wrap through the int32 working arrays into
    valid-looking node ids (2**32+3 would alias node 3)."""
    from repro.core import recovery as R
    nxt = np.array([1, 2, bad, -1, -1], np.int64)   # 0 -> 1 -> 2 -> X
    np.testing.assert_array_equal(R.chain_order(nxt, 0), [0, 1, 2])
    np.testing.assert_array_equal(
        chain_order.chain_order_device(nxt, 0, interpret=True), [0, 1, 2])
    np.testing.assert_array_equal(R.chain_lengths(nxt, np.array([0])), [3])
    np.testing.assert_array_equal(
        R.chain_walk(nxt, np.array([0], np.int64))[0], [0, 1, 2])
    # an overflow-adjacent HEAD is an already-terminated chain
    assert R.chain_lengths(nxt, np.array([bad]))[0] == 0


def test_chain_order_oob_head_is_empty():
    """Heads outside [0, n): the DLL header's HEAD field flushed by a
    torn epoch into uncommitted territory — empty chain, not a fault,
    in all four primitives (host + device)."""
    from repro.core import recovery as R
    nxt = np.array([1, -1], np.int64)
    for head in (5, 2 ** 31, 2 ** 40):
        assert R.chain_walk(nxt, np.array([head], np.int64))[0].size \
            == R.chain_lengths(nxt, np.array([head]))[0] == 0
        assert R.chain_order(nxt, head).size == 0
        assert chain_order.chain_order_device(
            nxt, head, interpret=True).size == 0


# ------------------------------------------------------- flash attention

@pytest.mark.parametrize("h,sq,skv,d,bq,bk,causal", [
    (2, 256, 256, 64, 128, 128, True),
    (3, 128, 128, 128, 64, 32, True),
    (1, 256, 512, 64, 128, 128, False),
    (4, 64, 64, 32, 64, 64, True),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(h, sq, skv, d, bq, bk, causal, dtype):
    from repro.kernels.flash_attention import flash_attention
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (h, sq, d)).astype(dtype)
    k = jax.random.normal(ks[1], (h, skv, d)).astype(dtype)
    v = jax.random.normal(ks[2], (h, skv, d)).astype(dtype)
    got = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_matches_model_blockwise():
    """The Pallas kernel and the model's XLA blockwise path agree."""
    from repro.kernels.flash_attention import flash_attention
    from repro.models import layers as L
    b, s, nk, g, dh = 1, 128, 2, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, nk, g, dh))
    k = jax.random.normal(ks[1], (b, s, nk, dh))
    v = jax.random.normal(ks[2], (b, s, nk, dh))
    want = L.blockwise_attention(q, k, v, causal=True, q_block=64,
                                 kv_block=64)
    # kernel layout: fold (B,K,G) into H; repeat K/V per query group
    qh = q.transpose(0, 2, 3, 1, 4).reshape(b * nk * g, s, dh)
    kh = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1
                    ).reshape(b * nk * g, s, dh)
    vh = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1
                    ).reshape(b * nk * g, s, dh)
    got = flash_attention(qh, kh, vh, causal=True, block_q=64, block_k=64)
    got = got.reshape(b, nk, g, s, dh).transpose(0, 3, 1, 2, 4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)
