"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the
single real CPU device; only launch/dryrun.py requests 512 placeholders."""
import os

import numpy as np
import pytest

os.environ.setdefault("JAX_PLATFORMS", "cpu")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
