"""Distribution-layer tests that need no compilation: sharding
divisibility for every (arch x fsdp) cell, pytree congruence of spec
trees, and the HLO roofline analyzer on a fixture."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import roofline as rl
from repro.configs import base, registry
from repro.dist import mesh as dmesh
from repro.models import backbone as B

AXIS_SIZE = {"data": 16, "model": 16, "pod": 2, None: 1}


def _check_divisible(spec_tree, shape_tree, where):
    specs = jax.tree.leaves(spec_tree, is_leaf=lambda x: isinstance(x, P))
    shapes = [s.shape for s in jax.tree.leaves(shape_tree)]
    assert len(specs) == len(shapes), where
    for spec, shape in zip(specs, shapes):
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = int(np.prod([AXIS_SIZE[a] for a in axes]))
            assert shape[dim] % n == 0, (where, spec, shape, dim)


@pytest.mark.parametrize("arch", list(registry.ARCHS))
@pytest.mark.parametrize("fsdp", [False, True])
def test_param_shardings_divide(arch, fsdp):
    cfg = registry.get(arch)
    specs = B.param_specs(cfg)
    pspecs = dmesh.param_pspecs(cfg, fsdp)
    # congruent trees
    jax.tree.map(lambda a, b: None, specs, pspecs,
                 is_leaf=lambda x: isinstance(x, P))
    _check_divisible(pspecs, specs, (arch, fsdp))


@pytest.mark.parametrize("arch", list(registry.ARCHS))
def test_cache_shardings_divide(arch):
    cfg = registry.get(arch)
    mesh_like = type("M", (), {"axis_names": ("data", "model"),
                               "shape": {"data": 16, "model": 16}})()
    for shape in base.ALL_SHAPES:
        if not registry.cell_supported(cfg, shape)[0]:
            continue
        if not shape.is_decode:
            continue
        cspecs = B.cache_specs(cfg, shape.global_batch, shape.seq_len)
        pspecs = dmesh.cache_pspecs(cfg, mesh_like, shape.global_batch)
        jax.tree.map(lambda a, b: None, cspecs, pspecs,
                     is_leaf=lambda x: isinstance(x, P))
        _check_divisible(pspecs, cspecs, (arch, shape.name))


def test_fsdp_threshold():
    assert not dmesh.use_fsdp(registry.get("hymba-1.5b"))
    assert dmesh.use_fsdp(registry.get("gemma3-27b"))
    assert dmesh.use_fsdp(registry.get("llama4-maverick-400b-a17b"))


# ------------------------------------------------------------- analyzer

HLO_FIXTURE = """\
HloModule jit_f, entry_computation_layout={()->f32[8,128]{1,0}}

%wide.body (param: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %param = (s32[], f32[8,128]) parameter(0)
  %gte.0 = s32[] get-tuple-element(%param), index=0
  %gte.1 = f32[8,128]{1,0} get-tuple-element(%param), index=1
  %w = f32[128,128]{1,0} constant({...})
  %dot.1 = f32[8,128]{1,0} dot(%gte.1, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,128]{1,0} all-reduce(%dot.1), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%sum
  %one = s32[] constant(1)
  %next = s32[] add(%gte.0, %one)
  ROOT %tuple.1 = (s32[], f32[8,128]) tuple(%next, %ar)
}

%wide.cond (param.1: (s32[], f32[8,128])) -> pred[] {
  %param.1 = (s32[], f32[8,128]) parameter(0)
  %gte.2 = s32[] get-tuple-element(%param.1), index=0
  %bound = s32[] constant(6)
  ROOT %lt = pred[] compare(%gte.2, %bound), direction=LT
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main.1_spmd () -> f32[8,128] {
  %c0 = s32[] constant(0)
  %x0 = f32[8,128]{1,0} constant({...})
  %t0 = (s32[], f32[8,128]) tuple(%c0, %x0)
  %while.1 = (s32[], f32[8,128]) while(%t0), condition=%wide.cond, body=%wide.body, backend_config={"known_trip_count":{"n":"6"}}
  ROOT %out = f32[8,128]{1,0} get-tuple-element(%while.1), index=1
}
"""


def test_analyzer_trip_count_multiplication():
    an = rl.HloAnalyzer(HLO_FIXTURE, n_devices=8)
    c = an.entry()
    # 6 iterations x (2 * 8 * 128 * 128) dot flops
    assert c.dot_flops == 6 * 2 * 8 * 128 * 128
    # all-reduce payload: 8*128*4 bytes, weight 2, x6 trips
    assert c.coll_bytes == 6 * 2 * 8 * 128 * 4
    assert c.coll_ops == {"all-reduce": 6.0}


def test_analyzer_trip_count_from_condition():
    # strip backend_config: falls back to the condition constant
    fixture = HLO_FIXTURE.replace(
        ', backend_config={"known_trip_count":{"n":"6"}}', "")
    an = rl.HloAnalyzer(fixture, n_devices=8)
    c = an.entry()
    assert c.dot_flops == 6 * 2 * 8 * 128 * 128


def test_analyzer_pod_spanning_groups():
    # replica_groups=[2,4]<=[8]: rows of 4 consecutive ids; with pod_size 4
    # no group crosses a pod; with pod_size 2 every group does.
    an_intra = rl.HloAnalyzer(HLO_FIXTURE, n_devices=8, pod_size=4)
    c = an_intra.entry()
    assert c.coll_bytes > 0 and c.coll_bytes_dcn == 0
    an_cross = rl.HloAnalyzer(HLO_FIXTURE, n_devices=8, pod_size=2)
    c2 = an_cross.entry()
    assert c2.coll_bytes == 0 and c2.coll_bytes_dcn > 0


def test_shape_bytes_tuple_and_layout():
    assert rl._shape_bytes("f32[8,128]{1,0}") == 8 * 128 * 4
    assert rl._shape_bytes("(s32[], f32[2,2]{1,0}, bf16[4]{0})") == \
        4 + 16 + 8
    assert rl._shape_bytes("pred[10]") == 10


def test_roofline_terms_math():
    r = rl.Roofline(
        compute_s=2.0, memory_s=1.0, collective_s=0.5,
        dot_flops=2.0 * rl.PEAK_FLOPS, hbm_bytes=rl.HBM_BW,
        coll_bytes=0.5 * rl.ICI_BW, coll_bytes_dcn=0, coll_ops={},
        raw_cost_flops=0, raw_cost_bytes=0,
        model_flops=2.0 * rl.PEAK_FLOPS * 256, n_devices=256)
    assert r.dominant == "compute"
    assert r.step_seconds == 2.0
    assert abs(r.useful_flops_ratio - 1.0) < 1e-9
    assert abs(r.mfu - 1.0) < 1e-9
