"""Manual AdamW with controllable moment dtype.

Implemented directly (not optax) so the persistence layer has full control
over the moment representation: f32 (default), bf16 (halves HBM for the
400B llama4 budget — DESIGN.md §5), and — on the persist path only —
the int8 block-quantized form produced by kernels/quant_pack.

Decoupled weight decay, bias-corrected, eps outside sqrt.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "float32"     # float32 | bfloat16
    max_grad_norm: float = 1.0


def init_moments(params: PyTree, cfg: AdamWConfig) -> Tuple[PyTree, PyTree]:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return jax.tree.map(zeros, params), jax.tree.map(zeros, params)


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(params: PyTree, grads: PyTree, mu: PyTree, nu: PyTree,
           step: jax.Array, lr: jax.Array, cfg: AdamWConfig
           ) -> Tuple[PyTree, PyTree, PyTree, jax.Array]:
    """Returns (new_params, new_mu, new_nu, grad_norm)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.max_grad_norm / (gnorm + 1e-12)) \
        if cfg.max_grad_norm else 1.0
    t = (step + 1).astype(jnp.float32)
    c1 = 1.0 - cfg.b1 ** t
    c2 = 1.0 - cfg.b2 ** t
    mdt = jnp.dtype(cfg.moment_dtype)

    def leaf(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / c1
        vhat = v32 / c2
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (upd + cfg.weight_decay * p32)
        return p_new.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    out = jax.tree.map(leaf, params, grads, mu, nu)
    new_p = jax.tree.map(lambda t3: t3[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t3: t3[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t3: t3[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_p, new_m, new_v, gnorm
