"""LR schedules — pure functions of step (DERIVABLE: never checkpointed)."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class WarmupCosine:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    final_frac: float = 0.1

    def __call__(self, step):
        s = jnp.asarray(step, jnp.float32)
        warm = self.peak_lr * s / max(self.warmup_steps, 1)
        prog = jnp.clip((s - self.warmup_steps)
                        / max(self.total_steps - self.warmup_steps, 1), 0, 1)
        cos = self.final_frac + (1 - self.final_frac) * 0.5 * (
            1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < self.warmup_steps, warm, self.peak_lr * cos)
