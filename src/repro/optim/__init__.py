from repro.optim.adamw import AdamWConfig, init_moments, update  # noqa: F401
from repro.optim.schedule import WarmupCosine  # noqa: F401
