from repro.data.pipeline import Pipeline  # noqa: F401
from repro.data.index import SampleIndex  # noqa: F401
