"""Sample index: the framework's live B+Tree use-case.

Maps sample id -> (shard, offset, length) for a sharded corpus.  Partly
persistent per the paper: only leaf nodes hit storage; inner levels are
rebuilt on open.  Used by the data pipeline for deterministic resume of
*file-backed* corpora (the synthetic pipeline derives everything, but the
index is exercised by tests/examples as the manifest-style workload).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core.arena import Arena, open_arena
from repro.core.recovery import RecoveryManager, RecoveryReport
from repro.pstruct.bptree import BPTree


class SampleIndex:
    def __init__(self, path: Optional[str], capacity: int,
                 mode: str = "partly"):
        cap_nodes = max(64, int(capacity / 8))
        self.arena = open_arena(
            path, BPTree.layout(cap_nodes, capacity, mode, name="idx"))
        self.tree = BPTree(self.arena, cap_nodes, capacity, mode, name="idx")
        self.last_recovery: Optional[RecoveryReport] = None

    def add(self, sample_ids: np.ndarray, shards: np.ndarray,
            offsets: np.ndarray, lengths: np.ndarray) -> None:
        vals = np.zeros((len(sample_ids), 7), np.int64)
        vals[:, 0] = shards
        vals[:, 1] = offsets
        vals[:, 2] = lengths
        self.tree.insert_batch(sample_ids, vals)
        self.arena.commit()

    def lookup(self, sample_ids: np.ndarray
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        ok, vals = self.tree.find_batch(sample_ids)
        return ok, vals[:, 0], vals[:, 1], vals[:, 2]

    def recover(self) -> float:
        """Reconstruct after crash via the unified recovery manager;
        returns seconds (paper §V-F metric; the staged RecoveryReport
        lands in ``last_recovery``)."""
        mgr = RecoveryManager(self.arena)
        mgr.add("index", "pstruct.bptree", self.tree)
        report = mgr.recover()
        self.last_recovery = report
        return report.total_seconds
