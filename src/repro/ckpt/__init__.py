from repro.ckpt.manager import CheckpointManager, SaveReport  # noqa: F401
from repro.ckpt.manifest import CheckpointCatalog  # noqa: F401
