"""Checkpoint manager: persistence policies applied to TrainState.

The paper's discipline, end to end:

* plan: classify every leaf (core.policy) — ESSENTIAL / DERIVABLE /
  APPROXIMABLE — and compute the flush plan (bytes to persist).
* flush: device->host gather of persisted leaves, optional int8
  block-quantization of APPROXIMABLE leaves (kernels.quant_pack), one file
  per leaf shard, written by a background thread (async checkpointing —
  compute/persist overlap).
* commit protocol: leaf files are fully written and fsync'd BEFORE the
  manifest is atomically renamed into place (manifest-last = the paper's
  flag bit; a crash mid-write leaves the previous checkpoint valid).
* restore: read manifest, load+dequantize persisted leaves, RECONSTRUCT
  every DERIVABLE leaf (rng, pipeline cursor, schedule) via
  core.reconstruct, re-warm dropped moments, and device_put with the
  *target* mesh's shardings — restoring onto a different mesh (elastic
  scaling) is the same code path.  ``restore(warmup="background")``
  takes APPROXIMABLE re-warming off the restore critical path: the
  returned state carries cheap host placeholders for dropped moments
  while a background thread materializes the device arrays;
  ``finish_warmup(state)`` joins and swaps them in, and the warmup time
  lands in the RecoveryReport as its own §V-F-style stage
  ("warmup_approximable") next to the reconstruction times.
* incremental mode (beyond paper): leaves whose content digest is unchanged
  since the previous checkpoint are skipped ("don't persist what didn't
  change") — frozen embeddings/stubs cost zero bytes per step.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policy as pol
from repro.core import reconstruct as rec
from repro.core.recovery import RecoveryReport
from repro.core.writeset import DigestWriteSet
from repro.kernels import ops as kops
from repro.train.state import TrainState

PyTree = Any


@dataclasses.dataclass
class SaveReport:
    step: int
    bytes_written: int
    bytes_skipped_derivable: int
    bytes_skipped_unchanged: int
    n_leaves_written: int
    seconds: float
    quantized: bool


def _leaf_file(path_str: str) -> str:
    h = hashlib.md5(path_str.encode()).hexdigest()[:16]
    return f"leaf_{h}.npz"


class CheckpointManager:
    def __init__(self, directory: str, policy: pol.PersistPolicy,
                 incremental: bool = False, use_pack_kernel: bool = False):
        self.dir = directory
        self.policy = policy
        self.incremental = incremental
        self.use_pack_kernel = use_pack_kernel
        os.makedirs(directory, exist_ok=True)
        self._writer: Optional[threading.Thread] = None
        # Leaf-granularity write set: digests decide which leaves are
        # dirty this epoch ("don't persist what didn't change") — same
        # discipline as the arena's row write set (DESIGN.md §2).
        self._writeset = DigestWriteSet()
        self.last_report: Optional[SaveReport] = None
        # restore() reports through the same per-stage format as every
        # other recovery path (core.recovery.RecoveryReport)
        self.last_recovery: Optional[RecoveryReport] = None
        # background APPROXIMABLE warmup (restore(warmup="background"))
        self._warmer: Optional[threading.Thread] = None
        self._warm_result: Dict[int, Any] = {}
        self._warm_error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, state: TrainState, blocking: bool = True) -> SaveReport:
        t0 = time.perf_counter()
        self.wait()
        sd = state.as_dict()
        plans = pol.plan(sd, self.policy)
        leaves = {pol.path_str(p): l for p, l in
                  jax.tree_util.tree_flatten_with_path(sd)[0]}

        to_write: Dict[str, Tuple[np.ndarray, dict]] = {}
        bytes_written = 0
        bytes_skipped_deriv = 0
        bytes_skipped_unchanged = 0
        quantized_any = False
        manifest: Dict[str, Any] = {"step": int(jax.device_get(state.step)),
                                    "policy": self.policy.name,
                                    "approx": self.policy.approx,
                                    "leaves": {}}

        for p in plans:
            leaf = leaves[p.path]
            raw_bytes = int(np.prod(p.shape or (1,))) * np.dtype(p.dtype).itemsize
            if not p.persisted:
                bytes_skipped_deriv += raw_bytes
                continue
            entry = {"shape": list(p.shape), "dtype": str(np.dtype(p.dtype)),
                     "kind": p.kind.value, "file": _leaf_file(p.path),
                     "quantized": False}
            if p.quantized and np.issubdtype(np.dtype(p.dtype), np.floating):
                q, s = kops.quantize_leaf(leaf)
                host = {"q": np.asarray(q), "s": np.asarray(s)}
                entry["quantized"] = True
                quantized_any = True
                nbytes = host["q"].nbytes + host["s"].nbytes
            else:
                host = {"x": np.asarray(jax.device_get(leaf))}
                nbytes = host["x"].nbytes
            digest = hashlib.md5(
                b"".join(v.tobytes() for v in host.values())).hexdigest()
            entry["digest"] = digest
            if self.incremental:
                present = os.path.exists(
                    os.path.join(self.dir, entry["file"]))
                if not self._writeset.dirty(p.path, digest, present):
                    bytes_skipped_unchanged += nbytes
                    manifest["leaves"][p.path] = entry
                    continue
            else:
                self._writeset.note(p.path, digest)
            to_write[p.path] = (host, entry)
            manifest["leaves"][p.path] = entry
            bytes_written += nbytes

        def write():
            for path, (host, entry) in to_write.items():
                fp = os.path.join(self.dir, entry["file"])
                with open(fp + ".tmp", "wb") as f:
                    np.savez(f, **host)
                    f.flush()
                    os.fsync(f.fileno())
                os.replace(fp + ".tmp", fp)
            # manifest-last commit (the paper's flag bit)
            mtmp = os.path.join(self.dir, "manifest.json.tmp")
            with open(mtmp, "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            os.replace(mtmp, os.path.join(self.dir, "manifest.json"))

        if blocking:
            write()
        else:
            self._writer = threading.Thread(target=write, daemon=True)
            self._writer.start()

        report = SaveReport(
            step=manifest["step"], bytes_written=bytes_written,
            bytes_skipped_derivable=bytes_skipped_deriv,
            bytes_skipped_unchanged=bytes_skipped_unchanged,
            n_leaves_written=len(to_write),
            seconds=time.perf_counter() - t0, quantized=quantized_any)
        self.last_report = report
        return report

    def wait(self) -> None:
        if self._writer is not None:
            self._writer.join()
            self._writer = None

    # --------------------------------------------------------------- restore
    def valid(self) -> bool:
        return os.path.exists(os.path.join(self.dir, "manifest.json"))

    def restore(self, state_spec: TrainState,
                shardings: Optional[PyTree] = None,
                warmup: str = "inline") -> TrainState:
        """state_spec: a TrainState of ShapeDtypeStructs (or arrays) giving
        the target structure; shardings: matching NamedSharding pytree (or
        None for single-device).  DERIVABLE leaves are reconstructed, not
        read.

        warmup: "inline" re-warms APPROXIMABLE leaves on the restore
        critical path (the seed behavior); "background" returns host
        placeholders for them immediately and materializes the device
        arrays in a background thread — call ``finish_warmup(state)`` to
        join and swap them in.  The warmup stage is timed into the
        report either way (detail ``background=True`` marks the
        off-critical-path variant)."""
        assert warmup in ("inline", "background")
        self.wait()
        self.wait_warmup()
        if self._warm_result:
            # splicing THIS restore's indices into a state produced by a
            # previous one would corrupt it silently — refuse loudly
            raise RuntimeError(
                "unclaimed background warmup from a previous restore — "
                "call finish_warmup(state) on that state first")
        t_all = time.perf_counter()
        report = RecoveryReport()
        t0 = time.perf_counter()
        with open(os.path.join(self.dir, "manifest.json")) as f:
            manifest = json.load(f)
        step = manifest["step"]
        report.add("manifest", time.perf_counter() - t0, step=step)
        report.generation = step
        sd = state_spec._asdict()
        flat, treedef = jax.tree_util.tree_flatten_with_path(sd)
        sflat = jax.tree.leaves(shardings) if shardings is not None \
            else [None] * len(flat)
        seed = None
        # first pass: essential scalars we need for reconstruction
        for pth, spec in flat:
            if pol.path_str(pth) == "data_seed":
                ent = manifest["leaves"].get("data_seed")
                if ent is not None:
                    seed = int(self._load_leaf(ent, (), np.int32))
        if seed is None:
            seed = 0

        out = []
        times = {"load_persisted": 0.0, "reconstruct_derivable": 0.0,
                 "rewarm_approximable": 0.0, "device_put": 0.0}
        counts = {k: 0 for k in times}
        deferred: Dict[int, Tuple[Tuple[int, ...], Any, Any]] = {}
        for i, ((pth, spec), shard) in enumerate(zip(flat, sflat)):
            pstr = pol.path_str(pth)
            kind = pol.classify(pth, self.policy.rules)
            ent = manifest["leaves"].get(pstr)
            shape = tuple(getattr(spec, "shape", ()))
            dtype = getattr(spec, "dtype", np.float32)
            t0 = time.perf_counter()
            if ent is not None:
                arr = self._load_leaf(ent, shape, dtype)
                stage = "load_persisted"
            elif kind == pol.Kind.DERIVABLE:
                arr = self._reconstruct_leaf(pstr, seed, step, shape, dtype)
                stage = "reconstruct_derivable"
            elif kind == pol.Kind.APPROXIMABLE:
                # drop policy: re-warm from zeros (bias correction restarts
                # cleanly because update() corrects with the global step)
                arr = np.zeros(shape, dtype)
                stage = "rewarm_approximable"
                if warmup == "background":
                    # hand back the host placeholder now; the device
                    # array materializes off the critical path
                    deferred[i] = (shape, dtype, shard)
                    times[stage] += time.perf_counter() - t0
                    counts[stage] += 1
                    out.append(arr)
                    continue
            else:
                raise KeyError(f"essential leaf {pstr} missing from checkpoint")
            times[stage] += time.perf_counter() - t0
            counts[stage] += 1
            t0 = time.perf_counter()
            if shard is not None:
                arr = jax.device_put(arr, shard)
            else:
                arr = jnp.asarray(arr)
            times["device_put"] += time.perf_counter() - t0
            counts["device_put"] += 1
            out.append(arr)
        for stage, secs in times.items():
            report.add(stage, secs, leaves=counts[stage],
                       background=(stage == "rewarm_approximable"
                                   and warmup == "background"))
        report.total_seconds = time.perf_counter() - t_all
        self.last_recovery = report
        if deferred:
            self._start_warmup(report, deferred, t_all)
        sd_new = jax.tree.unflatten(treedef, out)
        return TrainState(**sd_new)

    # ------------------------------------------- background warmup stage
    def _start_warmup(self, report: RecoveryReport,
                      deferred: Dict[int, Tuple], t_anchor: float) -> None:
        self._warm_result = {}
        self._warm_error = None

        def warm():
            try:
                t0 = time.perf_counter()
                warmed: Dict[int, Any] = {}
                for idx, (shape, dtype, shard) in deferred.items():
                    arr = np.zeros(shape, dtype)
                    warmed[idx] = (jax.device_put(arr, shard)
                                   if shard is not None
                                   else jnp.asarray(arr))
                secs = time.perf_counter() - t0
                st = report.add("warmup_approximable", secs,
                                leaves=len(warmed), background=True)
                st.t_start = t0 - t_anchor
                st.t_end = st.t_start + secs
                self._warm_result = warmed
            except BaseException as e:   # surfaced by wait_warmup()
                self._warm_error = e

        self._warmer = threading.Thread(target=warm, daemon=True)
        self._warmer.start()

    def wait_warmup(self) -> None:
        """Join the background warmup thread; a failure inside it (a
        device_put OOM, a sharding mismatch) re-raises HERE rather than
        dying silently in the daemon thread."""
        if self._warmer is not None:
            self._warmer.join()
            self._warmer = None
        err, self._warm_error = self._warm_error, None
        if err is not None:
            raise err

    def finish_warmup(self, state: TrainState) -> TrainState:
        """Join the background warmup thread and swap the warmed device
        arrays into the restored state (leaf order matches restore's
        flatten order).  A no-op for inline restores."""
        self.wait_warmup()
        if not self._warm_result:
            return state
        leaves, treedef = jax.tree_util.tree_flatten(state.as_dict())
        for idx, arr in self._warm_result.items():
            leaves[idx] = arr
        self._warm_result = {}
        return TrainState(**jax.tree_util.tree_unflatten(treedef, leaves))

    def _load_leaf(self, entry: dict, shape, dtype) -> np.ndarray:
        with np.load(os.path.join(self.dir, entry["file"])) as z:
            if entry.get("quantized"):
                q, s = z["q"], z["s"]
                return np.asarray(kops.dequantize_leaf(
                    jnp.asarray(q), jnp.asarray(s), tuple(entry["shape"]),
                    np.dtype(entry["dtype"])))
            return z["x"].reshape(shape).astype(dtype, copy=False)

    def _reconstruct_leaf(self, pstr: str, seed: int, step: int, shape,
                          dtype) -> np.ndarray:
        if pstr == "rng":
            key, _ = rec.run("rng", seed, step)
            return np.asarray(key)
        # unknown derivable leaves default to zeros (caches, cursors held
        # host-side are rebuilt by their owners)
        return np.zeros(shape, dtype)
