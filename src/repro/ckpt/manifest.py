"""Checkpoint catalog: a partly-persistent B+Tree over checkpoint history.

Maps step -> (generation, bytes, n_leaves) across a training run — the
framework-level manifest workload for the paper's B+Tree (leaves persisted,
inner levels rebuilt on open).  Survives crashes with the same commit
protocol as the checkpoints it catalogs.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import numpy as np

from repro.core.arena import open_arena
from repro.pstruct.bptree import BPTree


class CheckpointCatalog:
    def __init__(self, path: Optional[str], capacity: int = 4096,
                 mode: str = "partly"):
        cap_nodes = max(64, capacity // 4)
        exists = path is not None and os.path.exists(path)
        self.arena = open_arena(
            path, BPTree.layout(cap_nodes, capacity, mode, name="cat"))
        self.tree = BPTree(self.arena, cap_nodes, capacity, mode, name="cat")
        if exists and self.arena.header_valid():
            self.tree.reconstruct()

    def record(self, step: int, generation: int, nbytes: int,
               n_leaves: int) -> None:
        vals = np.zeros((1, 7), np.int64)
        vals[0, :3] = [generation, nbytes, n_leaves]
        self.tree.insert_batch(np.array([step], np.int64), vals)
        self.arena.commit()

    def latest(self) -> Optional[Tuple[int, int, int, int]]:
        hv = self.tree.header.vol[0]
        if hv[3] == 0:  # H_COUNT
            return None
        # walk to the right-most leaf via descent on +inf
        ok, vals = self.tree.find_batch(np.array([self._max_key()], np.int64))
        key = self._max_key()
        return (key, int(vals[0, 0]), int(vals[0, 1]), int(vals[0, 2]))

    def _max_key(self) -> int:
        import repro.pstruct.bptree as bt
        cur = int(self.tree.header.vol[0, bt.H_FIRST_LEAF])
        last = None
        while cur != bt.NULL:
            row = self.tree.nodes.vol[cur]
            nk = int(row[bt.C_NK])
            if nk:
                last = int(row[bt.K0 + nk - 1])
            cur = int(row[bt.C_NEXT])
        return last

    def steps(self) -> np.ndarray:
        import repro.pstruct.bptree as bt
        out = []
        cur = int(self.tree.header.vol[0, bt.H_FIRST_LEAF])
        while cur != bt.NULL:
            row = self.tree.nodes.vol[cur]
            nk = int(row[bt.C_NK])
            out.extend(row[bt.K0:bt.K0 + nk].tolist())
            cur = int(row[bt.C_NEXT])
        return np.asarray(out, np.int64)
