"""Checkpoint catalog: a partly-persistent B+Tree over checkpoint history.

Maps step -> (generation, bytes, n_leaves) across a training run — the
framework-level manifest workload for the paper's B+Tree (leaves persisted,
inner levels rebuilt on open).  Survives crashes with the same commit
protocol as the checkpoints it catalogs; the open-after-crash rebuild
routes through core.recovery.RecoveryManager, and the history queries ride
the tree's vectorized chain-order traversals (BPTree.keys_in_order /
max_key) instead of scalar NEXT walks.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import numpy as np

from repro.core.arena import open_arena
from repro.core.recovery import RecoveryManager, RecoveryReport
from repro.pstruct.bptree import BPTree


class CheckpointCatalog:
    def __init__(self, path: Optional[str], capacity: int = 4096,
                 mode: str = "partly"):
        cap_nodes = max(64, capacity // 4)
        exists = path is not None and os.path.exists(path)
        self.arena = open_arena(
            path, BPTree.layout(cap_nodes, capacity, mode, name="cat"))
        self.tree = BPTree(self.arena, cap_nodes, capacity, mode, name="cat")
        self.last_recovery: Optional[RecoveryReport] = None
        if exists and self.arena.header_valid():
            mgr = RecoveryManager(self.arena)
            mgr.add("catalog", "pstruct.bptree", self.tree)
            self.last_recovery = mgr.recover()

    def record(self, step: int, generation: int, nbytes: int,
               n_leaves: int) -> None:
        vals = np.zeros((1, 7), np.int64)
        vals[0, :3] = [generation, nbytes, n_leaves]
        self.tree.insert_batch(np.array([step], np.int64), vals)
        self.arena.commit()

    def latest(self) -> Optional[Tuple[int, int, int, int]]:
        key = self.tree.max_key()
        if key is None:
            return None
        ok, vals = self.tree.find_batch(np.array([key], np.int64))
        return (key, int(vals[0, 0]), int(vals[0, 1]), int(vals[0, 2]))

    def steps(self) -> np.ndarray:
        """All recorded steps in order (vectorized leaf-chain gather)."""
        return self.tree.keys_in_order()
