"""The paper's primary contribution: partly-persistent state management —
field classification, flush planning/accounting, persistent arena with
commit protocol, and the reconstruction engine."""
from repro.core.arena import LINE, Arena, FlushStats, open_arena  # noqa: F401
from repro.core.writeset import DigestWriteSet, WriteSet  # noqa: F401
from repro.core.policy import (  # noqa: F401
    FULLY_PERSISTENT,
    Kind,
    PARTLY_DROP,
    PARTLY_PERSISTENT,
    PARTLY_Q8,
    PersistPolicy,
    classify,
    persisted_bytes,
    plan,
)
from repro.core import reconstruct  # noqa: F401
