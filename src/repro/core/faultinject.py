"""Media-fault injection harness (DESIGN.md §13).

The integrity layer's whole claim — every single-line corruption in
committed territory is *detected or harmless* — is only as strong as
the injector behind the sweep.  These helpers corrupt the COMMITTED
LOGICAL IMAGE of a row, not merely some bytes at its home offset:
under ``commit_mode="shadow"`` a committed row may live in the
authoritative remap bank's mirror rather than its home slot, so the
injector parses the PERSISTENT bank state (generation parity, sealed
entry counts, remap entries) exactly the way post-crash recovery does,
and lands the fault where recovery will actually read.  Injecting at
the home slot of a bank-remapped row would corrupt dead bytes and
prove nothing.

Faults by taxonomy (core.arena error types):

* ``flip_bits`` / ``stuck_line``   -> ``CorruptLineError`` territory:
  in-place byte rot inside a committed row's line(s), visible to
  ``Arena.scrub()`` and the paged fault path;
* ``truncate_shard`` / ``remove_shard`` -> ``ShardLossError``
  territory: whole-file media loss, detected at fresh-process open
  (use BETWEEN arena generations — the helpers operate on the backing
  files, never through a live mapping);
* ``corrupt_header`` / ``corrupt_manifest`` -> ``ManifestError``
  territory: scribbled commit-pointer magic, detected by
  ``verify_header()`` in the recovery prologue.

Everything returns enough to assert precision (which bytes changed),
and ``flip_bits`` is an involution — inject twice to undo.
"""
from __future__ import annotations

import os
from typing import Tuple

import numpy as np

from repro.core.arena import LINE, Arena, ShardedArena

__all__ = [
    "flip_bits", "stuck_line", "truncate_shard", "remove_shard",
    "corrupt_header", "corrupt_manifest", "committed_row_offset",
]


def committed_row_offset(arena, region, row: int) -> Tuple[Arena, int, int]:
    """(owning plain arena, byte offset of the row's committed image in
    that arena's mapping, rowbytes).  Resolves sharded regions to the
    owning shard and shadow-remapped rows to the authoritative bank's
    mirror slot by parsing persistent state only — valid before or
    after a crash, in either commit mode."""
    if isinstance(region, str):
        region = arena.regions[region]
    if isinstance(arena, ShardedArena):
        s = int(region.shard_of[row])
        return committed_row_offset(arena.shards[s], region.slices[s],
                                    int(region.local_of[row]))
    base = region.offset
    if arena.commit_mode == "shadow":
        bank = arena.header_generation() % 2
        cnt = int(arena._shadow_meta_view()[bank])
        if cnt:
            ents = np.array(arena._shadow_entries(bank)[:cnt])
            rid = arena._region_ids[region.name]
            if bool(((ents[:, 0] == rid) & (ents[:, 1] == row)).any()):
                base = region._shadow_off[bank]
    return arena, base + row * region.rowbytes, region.rowbytes


def flip_bits(arena, region, row: int, byte: int = 0,
              mask: int = 0x01) -> int:
    """XOR ``mask`` into one byte of the committed image of
    ``(region, row)`` — the single-bit-rot injection.  Returns the
    absolute byte offset that changed (inject again to undo)."""
    a, off, rb = committed_row_offset(arena, region, row)
    assert 0 <= byte < rb
    a._mm[off + byte] ^= np.uint8(mask)
    if isinstance(a._mm, np.memmap):
        a._mm.flush()
    return off + byte


def stuck_line(arena, region, row: int, line: int = 0,
               value: int = 0xFF) -> Tuple[int, int]:
    """Overwrite one 64 B line of the committed row image with a
    stuck-at pattern (a failed-cell fault).  Clamped to the row so the
    injection stays a SINGLE-row corruption; returns the [lo, hi) byte
    range overwritten."""
    a, off, rb = committed_row_offset(arena, region, row)
    lo = off + line * LINE
    hi = min(off + rb, lo + LINE)
    assert lo < hi, "line index beyond the row"
    a._mm[lo:hi] = np.uint8(value)
    if isinstance(a._mm, np.memmap):
        a._mm.flush()
    return lo, hi


def _shard_path(arena, shard: int) -> str:
    if isinstance(arena, str):
        return f"{arena}.s{shard}"
    assert arena.path is not None, "file faults need a file-backed arena"
    if isinstance(arena, ShardedArena):
        return arena.shards[shard].path
    return arena.path


def truncate_shard(arena, shard: int = 0, nbytes: int = 0) -> str:
    """Truncate a shard's backing file to ``nbytes`` — partial media
    loss.  File-level: use between process generations (after a crash,
    before the fresh open that raises ``ShardLossError``)."""
    path = _shard_path(arena, shard)
    with open(path, "r+b") as f:
        f.truncate(nbytes)
    return path


def remove_shard(arena, shard: int = 0) -> str:
    """Delete a shard's backing file outright — total media loss of
    one shard.  File-level, like ``truncate_shard``."""
    path = _shard_path(arena, shard)
    os.remove(path)
    return path


def corrupt_header(arena, shard: int = 0) -> None:
    """Scribble a commit header's magic word (plain arena, or one shard
    of a sharded one) — ``verify_header()`` raises ``ManifestError``."""
    a = arena.shards[shard] if isinstance(arena, ShardedArena) else arena
    a._mm[:4] = np.frombuffer(b"ROT!", np.uint8)
    if isinstance(a._mm, np.memmap):
        a._mm.flush()


def corrupt_manifest(arena) -> None:
    """Scribble a sharded arena's manifest magic — the cross-shard
    commit pointer itself is the corrupted medium."""
    assert isinstance(arena, ShardedArena)
    arena._man[:4] = np.frombuffer(b"ROT!", np.uint8)
    if isinstance(arena._man, np.memmap):
        arena._man.flush()
