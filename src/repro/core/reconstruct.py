"""Reconstruction engine: registry of per-structure rebuild functions.

The restore path walks the state spec; every DERIVABLE leaf/subsystem names
a reconstructor which rebuilds it from essential state — the generalization
of the paper's three per-structure reconstruction algorithms (§IV-*3).
Reconstructors must be *pure* given (essential_state, static config): same
inputs => identical rebuilt state, which the crash tests assert.

Registrants (each module registers at import time; RecoveryManager in
core/recovery.py consumes the registry by name, in dependency order):

* trainer-state leaves below ("rng", "schedule", "pipeline_cursor");
* "pstruct.dll" / "pstruct.bptree" / "pstruct.hashmap" — the three
  paper structures' rebuild logic (pstruct/*.py), taking the structure
  object with its regions already loaded from persistent memory;
* "serve.paged_alloc" / "serve.engine" — the paged-KV allocator's page
  metadata and the serving engine's batched slab-scan + re-prefill
  (serve/kvcache.py, serve/engine.py);
* "serve.journal" / "serve.feature_store" — the request journal's rid
  index replayed from the committed descriptor window, and the feature
  store's hot rows + apply counters replayed from the committed sample
  log (serve/journal.py, serve/feature_store.py, DESIGN.md §11).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Tuple

_REGISTRY: Dict[str, Callable[..., Any]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get(name: str) -> Callable[..., Any]:
    return _REGISTRY[name]


def names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def run(name: str, *args, **kw):
    """Run a reconstructor, returning (result, seconds) for §V-F style
    reconstruction-time reporting."""
    t0 = time.perf_counter()
    out = _REGISTRY[name](*args, **kw)
    return out, time.perf_counter() - t0


# -- built-in trainer-state reconstructors ---------------------------------

@register("rng")
def rebuild_rng(seed: int, step: int):
    import jax
    key = jax.random.PRNGKey(seed)
    return jax.random.fold_in(key, step)


@register("schedule")
def rebuild_schedule(step: int, schedule_fn):
    # LR schedules are pure functions of step; their "state" is just memo
    return schedule_fn(step)


@register("pipeline_cursor")
def rebuild_pipeline_cursor(seed: int, step: int, global_batch: int):
    # deterministic pipeline: cursor is a pure function of (seed, step)
    return {"seed": seed, "next_index": step * global_batch}
