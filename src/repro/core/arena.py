"""Persistent arena: the framework's "persistent memory".

The paper operates data structures in volatile memory and treats each
explicit flush as a checkpoint of the *essential* fields into persistent
memory (Optane, mmap'd with MAP_SYNC).  Our TPU-cluster analogue (DESIGN.md
§2) is a host-side file-backed arena:

* every region has a VOLATILE numpy array (the working copy — the "DRAM/HBM"
  side) and a PERSISTENT np.memmap view of a backing file;
* ``persist_rows`` / ``persist_range`` copy selected rows from volatile to
  persistent and account the cost in *flush units* — 64-byte "cache lines"
  by default, with adjacent dirty lines coalesced, exactly mirroring the
  paper's clwb accounting (§V-E: unaligned/partial-line flushes re-fetch
  whole lines, so cost is counted in whole lines touched);
* a commit protocol orders data before metadata: ``commit()`` flushes the
  backing file and only then sets the header's valid flag (the paper's
  "flag bit" + NVTree-style manifest-last ordering);
* ``reopen()`` simulates the post-crash restart: all volatile state is
  discarded and regions are reloaded from the file;
* structures mark dirty rows (``Region.mark_rows``) into the arena's
  write set inside an ``Arena.epoch()``; the epoch exit flushes once —
  rows deduplicated, lines coalesced across the whole operation, data
  regions before header regions (core/writeset.py, DESIGN.md §2).

Byte/line counters are exact and medium-independent; wall-clock cost on this
CPU host is the real memcpy+write cost, which scales linearly in flushed
bytes (reproducing Fig 1's linearity).  An optional synthetic per-line
latency models Optane-like flush stalls for experiments that want the
paper's regime explicitly.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import struct
import time
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.writeset import WriteSet

LINE = 64                 # flush granularity (bytes) — paper's cache line
MEDIA_GRAIN = 256         # DCPMM internal granularity (§IV-D bucket sizing)

_MAGIC = b"RPRA"
_HDR_FMT = "<4sQQ?7x"     # magic, n_regions, generation, valid flag


@dataclass
class FlushStats:
    lines: int = 0
    bytes: int = 0
    calls: int = 0
    fence_ns: int = 0      # synthetic latency accumulated (if enabled)
    # epoch-flush (write-set) counters — DESIGN.md §2
    epochs: int = 0        # batched epoch flushes performed
    marks: int = 0         # mark_rows calls absorbed by the write set
    dedup_rows: int = 0    # row marks dropped as duplicates within an epoch
    saved_lines: int = 0   # lines one accounting call PER MARK would have
                           # charged minus lines the epoch flush charged

    def snapshot(self) -> "FlushStats":
        return dataclasses.replace(self)

    def delta(self, since: "FlushStats") -> "FlushStats":
        return FlushStats(*(getattr(self, f.name) - getattr(since, f.name)
                            for f in dataclasses.fields(self)))


class Region:
    """A named, row-structured persistent region."""

    def __init__(self, arena: "Arena", name: str, dtype, shape: Tuple[int, ...],
                 offset: int, meta: Optional[bool] = None):
        self.arena = arena
        self.name = name
        self.dtype = np.dtype(dtype)
        self.shape = tuple(shape)
        self.offset = offset
        # Metadata regions (structure headers) flush AFTER data regions
        # within an epoch — data-before-metadata ordering (DESIGN.md §2).
        self.meta = name.endswith("header") if meta is None else meta
        self.rowbytes = int(self.dtype.itemsize * np.prod(shape[1:], dtype=np.int64)) \
            if len(shape) > 1 else self.dtype.itemsize
        self.nbytes = self.rowbytes * shape[0]
        # Volatile working copy.
        self.vol = np.zeros(self.shape, self.dtype)

    # -- persistence ------------------------------------------------------
    def _pview(self) -> np.ndarray:
        mm = self.arena._mm
        flat = np.frombuffer(mm, dtype=np.uint8,
                             count=self.nbytes, offset=self.offset)
        return flat.view(self.dtype).reshape(self.shape)

    def persist_rows(self, rows: np.ndarray) -> None:
        """Flush the given row indices (volatile -> persistent) NOW, with
        per-call line accounting.  Structure code should prefer
        ``mark_rows`` so flushes batch per epoch."""
        rows = np.asarray(rows, np.int64)
        if rows.size == 0:
            return
        rows = np.unique(rows)
        pv = self._pview()
        pv[rows] = self.vol[rows]
        self.arena._account_rows(self.offset, self.rowbytes, rows)

    def mark_rows(self, rows: np.ndarray) -> None:
        """Add rows to the arena's write set (flushed once, deduplicated,
        when the enclosing epoch closes).  Outside any epoch this
        degrades to an immediate ``persist_rows`` — per-op call sites
        behave identically either way."""
        if self.arena._epoch_depth > 0:
            self.arena.writeset.mark(self, np.asarray(rows, np.int64))
        else:
            self.persist_rows(rows)

    def mark_range(self, lo: int, hi: int) -> None:
        if hi > lo:
            self.mark_rows(np.arange(lo, hi, dtype=np.int64))

    def persist_range(self, lo: int, hi: int) -> None:
        if hi <= lo:
            return
        pv = self._pview()
        pv[lo:hi] = self.vol[lo:hi]
        self.arena._account_range(self.offset + lo * self.rowbytes,
                                  (hi - lo) * self.rowbytes)

    def persist_all(self) -> None:
        self.persist_range(0, self.shape[0])

    def load(self) -> None:
        """Reload volatile copy from persistent memory (post-crash)."""
        self.vol = np.array(self._pview())


class Arena:
    """File-backed persistent arena with flush accounting."""

    def __init__(self, path: Optional[str], synth_line_ns: float = 0.0,
                 pack_flush_rows: int = 0):
        self.path = path
        self.regions: Dict[str, Region] = {}
        self.stats = FlushStats()
        self.synth_line_ns = synth_line_ns
        # >0: epoch flushes of at least this many rows gather through the
        # Pallas pack_flush kernel (tile-aligned staging buffer).
        self.pack_flush_rows = pack_flush_rows
        self.writeset = WriteSet(self)
        self._epoch_depth = 0
        self._layout_final = False
        self._mm: Optional[np.memmap] = None
        self._cursor = 4096  # header page
        self._meta: Dict[str, dict] = {}
        self.generation = 0

    # -- epochs -----------------------------------------------------------
    @contextlib.contextmanager
    def epoch(self):
        """One logical operation: ``mark_rows`` calls inside the block
        accumulate in the write set; the outermost epoch exit flushes
        them once (rows deduplicated, lines coalesced across the op,
        data regions before metadata regions)."""
        self._epoch_depth += 1
        try:
            yield self
        finally:
            self._epoch_depth -= 1
            if self._epoch_depth == 0:
                self.writeset.flush()

    # -- layout -----------------------------------------------------------
    def region(self, name: str, dtype, shape: Tuple[int, ...],
               meta: Optional[bool] = None) -> Region:
        assert not self._layout_final, "layout already finalized"
        assert name not in self.regions
        # Row-align every region to LINE so a row flush never straddles an
        # unrelated region (paper: __attribute__((aligned(64)))).
        self._cursor = _align(self._cursor, LINE)
        r = Region(self, name, dtype, shape, self._cursor, meta=meta)
        self._cursor += _align(r.nbytes, LINE)
        self.regions[name] = r
        self._meta[name] = {"dtype": np.dtype(dtype).str,
                            "shape": list(shape), "offset": r.offset}
        return r

    def finalize(self) -> None:
        assert not self._layout_final
        self._layout_final = True
        total = _align(self._cursor, 4096)
        if self.path is None:
            self._mm = np.zeros(total, np.uint8)  # in-memory (tests)
        else:
            create = not os.path.exists(self.path)
            if create:
                with open(self.path, "wb") as f:
                    f.truncate(total)
            self._mm = np.memmap(self.path, dtype=np.uint8, mode="r+",
                                 shape=(total,))
            if create:
                self._write_header(valid=False)
        # sidecar layout description (tiny, metadata-only)
        if self.path is not None:
            with open(self.path + ".layout", "w") as f:
                json.dump(self._meta, f)

    # -- header / commit protocol -----------------------------------------
    def _write_header(self, valid: bool) -> None:
        hdr = struct.pack(_HDR_FMT, _MAGIC, len(self.regions),
                          self.generation, valid)
        self._mm[: len(hdr)] = np.frombuffer(hdr, np.uint8)

    def header_valid(self) -> bool:
        raw = bytes(self._mm[: struct.calcsize(_HDR_FMT)])
        magic, _, gen, valid = struct.unpack(_HDR_FMT, raw)
        return magic == _MAGIC and bool(valid)

    def header_generation(self) -> int:
        """Committed generation as persisted in the header — unlike the
        in-memory ``generation`` counter, this survives a fresh-process
        reopen."""
        raw = bytes(self._mm[: struct.calcsize(_HDR_FMT)])
        magic, _, gen, _ = struct.unpack(_HDR_FMT, raw)
        return int(gen) if magic == _MAGIC else 0

    def commit(self) -> None:
        """Data-before-metadata ordering: drain the write set, flush file
        contents, then set the valid flag (the paper's initialization
        flag bit).  Inside an epoch this flushes the pending marks first,
        so a commit never orders the flag ahead of its data."""
        self.writeset.flush()
        if isinstance(self._mm, np.memmap):
            self._mm.flush()
        self.generation += 1
        self._write_header(valid=True)
        if isinstance(self._mm, np.memmap):
            self._mm.flush()
        self.stats.calls += 1

    def invalidate(self) -> None:
        self._write_header(valid=False)

    # -- crash simulation ---------------------------------------------------
    def crash(self) -> None:
        """Discard all volatile state (keep the backing file).  Pending
        write-set marks die with the volatile state — power loss loses
        un-flushed rows; it must never flush zeroed volatile copies over
        committed data when a wrapping epoch unwinds."""
        self.writeset.discard()
        for r in self.regions.values():
            r.vol = np.zeros(r.shape, r.dtype)

    def reopen(self) -> None:
        """Reload every region's volatile copy from persistent memory,
        and re-anchor the in-memory generation counter to the committed
        one (a fresh process starts at 0 otherwise)."""
        for r in self.regions.values():
            r.load()
        self.generation = max(self.generation, self.header_generation())

    # -- accounting ---------------------------------------------------------
    def _account_range(self, byte_off: int, nbytes: int) -> None:
        lo = (byte_off // LINE) * LINE
        hi = _align(byte_off + nbytes, LINE)
        lines = (hi - lo) // LINE
        self.stats.lines += lines
        self.stats.bytes += nbytes
        self.stats.calls += 1
        self._synth(lines)

    @staticmethod
    def _rows_line_count(base: int, rowbytes: int, rows: np.ndarray) -> int:
        """Distinct 64 B lines touched by flushing `rows` (sorted unique)."""
        if rowbytes % LINE == 0 and base % LINE == 0:
            # aligned rows: rows * rowbytes/LINE lines, coalescing irrelevant
            return int(rows.size) * (rowbytes // LINE)
        # exact distinct-line count over sorted row intervals (adjacent
        # rows may share a line — the Fig-12 unaligned-flush effect)
        starts = (base + rows * rowbytes) // LINE
        ends = (base + (rows + 1) * rowbytes - 1) // LINE
        starts = np.maximum(starts,
                            np.concatenate(([-1], ends[:-1])) + 1)
        return int(np.sum(np.maximum(0, ends - starts + 1)))

    def _account_rows(self, base: int, rowbytes: int, rows: np.ndarray) -> None:
        lines = self._rows_line_count(base, rowbytes, rows)
        self.stats.lines += lines
        self.stats.bytes += int(rows.size) * rowbytes
        self.stats.calls += 1
        self._synth(lines)

    def _synth(self, lines: int) -> None:
        if self.synth_line_ns:
            ns = int(lines * self.synth_line_ns)
            self.stats.fence_ns += ns
            t0 = time.perf_counter_ns()
            while time.perf_counter_ns() - t0 < ns:
                pass

    def close(self) -> None:
        if isinstance(self._mm, np.memmap):
            self._mm.flush()
        self._mm = None


def _align(x: int, a: int) -> int:
    return ((x + a - 1) // a) * a


def open_arena(path: Optional[str], layout: Dict[str, Tuple], **kw) -> Arena:
    """Create/open an arena with the given {name: (dtype, shape)} layout."""
    a = Arena(path, **kw)
    for name, (dtype, shape) in layout.items():
        a.region(name, dtype, shape)
    a.finalize()
    return a
