"""Persistent arena: the framework's "persistent memory".

The paper operates data structures in volatile memory and treats each
explicit flush as a checkpoint of the *essential* fields into persistent
memory (Optane, mmap'd with MAP_SYNC).  Our TPU-cluster analogue (DESIGN.md
§2) is a host-side file-backed arena:

* every region has a VOLATILE numpy array (the working copy — the "DRAM/HBM"
  side) and a PERSISTENT np.memmap view of a backing file;
* ``persist_rows`` / ``persist_range`` copy selected rows from volatile to
  persistent and account the cost in *flush units* — 64-byte "cache lines"
  by default, with adjacent dirty lines coalesced, exactly mirroring the
  paper's clwb accounting (§V-E: unaligned/partial-line flushes re-fetch
  whole lines, so cost is counted in whole lines touched);
* a commit protocol orders data before metadata: ``commit()`` flushes the
  backing file and only then sets the header's valid flag (the paper's
  "flag bit" + NVTree-style manifest-last ordering);
* ``reopen()`` simulates the post-crash restart: all volatile state is
  discarded and regions are reloaded from the file;
* structures mark dirty rows (``Region.mark_rows``) into the arena's
  write set inside an ``Arena.epoch()``; the epoch exit flushes once —
  rows deduplicated, lines coalesced across the whole operation, data
  regions before header regions (core/writeset.py, DESIGN.md §2).

Byte/line counters are exact and medium-independent; wall-clock cost on this
CPU host is the real memcpy+write cost, which scales linearly in flushed
bytes (reproducing Fig 1's linearity).  An optional synthetic per-line
latency models Optane-like flush stalls for experiments that want the
paper's regime explicitly.

``ShardedArena`` (DESIGN.md §7) partitions the substrate into N
independent shards — each a full ``Arena`` with its own backing file,
write set, flush stats, and data-before-metadata commit header — behind
the SAME region/epoch/commit API, so every structure runs unchanged on
any shard count.  A ``ShardedRegion`` keeps ONE full-shape volatile
array (structures index it with global row ids exactly as before) while
its persistent bytes are split across shards by a pure row->shard
router (block-cyclic segments, hashed rows, or contiguous ranges).  A
tiny manifest commits LAST: the cross-shard generation is the one ALL
shards agree on, so a crash between shard commits recovers the previous
manifest generation.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import struct
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.writeset import ShardedWriteSet, WriteSet

LINE = 64                 # flush granularity (bytes) — paper's cache line
MEDIA_GRAIN = 256         # DCPMM internal granularity (§IV-D bucket sizing)

_MAGIC = b"RPRA"
_HDR_FMT = "<4sQQ?7x"     # magic, n_regions, generation, valid flag
_MAN_MAGIC = b"RPRM"
_MAN_FMT = "<4sQQ?7x"     # magic, n_shards, generation, valid flag


# ======================================================================
# Integrity taxonomy (DESIGN.md §13)
# ======================================================================


class IntegrityError(RuntimeError):
    """Base of the media-fault taxonomy: persistent bytes failed a trust
    check that power loss alone cannot produce (checksum mismatch, shard
    file gone, manifest/header magic garbage)."""


class CorruptLineError(IntegrityError):
    """Committed persistent line(s) fail their sidecar checksum."""

    def __init__(self, region: str, rows, detail: str = ""):
        self.region = region
        self.rows = np.atleast_1d(np.asarray(rows, np.int64))
        msg = (f"corrupt line(s) in region {region!r}, "
               f"rows {self.rows[:8].tolist()}"
               + (f" (+{self.rows.size - 8} more)"
                  if self.rows.size > 8 else ""))
        super().__init__(msg + (f": {detail}" if detail else ""))


class ShardLossError(IntegrityError):
    """A shard backing file is missing, truncated, or behind the
    committed manifest generation — whole-device loss, not a torn
    commit (torn commits leave shards AHEAD of the manifest)."""


class ManifestError(IntegrityError):
    """The arena commit header or the sharded manifest — the trust
    anchors everything else hangs off — carry garbage magic/fields."""


class QuarantinedError(RuntimeError):
    """A request touched keys salvage recovery quarantined: refusing is
    the contract — serving reconstructed garbage is not (DESIGN.md §13)."""


@dataclass
class FlushStats:
    lines: int = 0
    bytes: int = 0
    calls: int = 0
    fence_ns: int = 0      # synthetic latency accumulated (if enabled)
    fences: int = 0        # ordering points paid (barrier phases + commit
                           # seals in barrier mode; ONE flip in shadow mode)
    # epoch-flush (write-set) counters — DESIGN.md §2
    epochs: int = 0        # batched epoch flushes performed
    marks: int = 0         # mark_rows calls absorbed by the write set
    dedup_rows: int = 0    # row marks dropped as duplicates within an epoch
    saved_lines: int = 0   # lines one accounting call PER MARK would have
                           # charged minus lines the epoch flush charged
    snapshot_lines: int = 0  # order-snapshot lines (DESIGN.md §10) — kept
                             # OUT of `lines`/`saved_lines` so partly-vs-
                             # full accounting stays comparable across PRs
    journal_lines: int = 0   # request-journal ring lines (DESIGN.md §11) —
                             # same separation: journal-off data accounting
                             # is bit-identical to journal-on
    integrity_lines: int = 0  # checksum-sidecar lines (DESIGN.md §13) —
                              # integrity-off accounting stays bit-identical

    def snapshot(self) -> "FlushStats":
        return dataclasses.replace(self)

    def delta(self, since: "FlushStats") -> "FlushStats":
        return FlushStats(*(getattr(self, f.name) - getattr(since, f.name)
                            for f in dataclasses.fields(self)))


class _RowAccess:
    """Row accessors shared by Region and ShardedRegion.

    Structures read/write volatile rows through these instead of direct
    ``.vol`` fancy indexing; here they are thin views over the
    full-shape volatile array (zero behavior change), while the paged
    variants (core/paging.py, DESIGN.md §12) override them to route
    through the per-arena block cache without ever materializing the
    full array.  ``col`` may be an int or a slice over the trailing
    dimension."""

    is_paged = False

    def read_rows(self, rows: np.ndarray) -> np.ndarray:
        return self.vol[np.asarray(rows, np.int64)]

    def read_at(self, rows: np.ndarray, col) -> np.ndarray:
        return self.vol[np.asarray(rows, np.int64), col]

    def read_one(self, row: int, col: int) -> int:
        return int(self.vol[row, col])

    def read_col(self, col) -> np.ndarray:
        return self.vol[:, col]

    def write_rows(self, rows: np.ndarray, vals) -> None:
        self.vol[np.asarray(rows, np.int64)] = vals

    def write_at(self, rows: np.ndarray, col, vals) -> None:
        self.vol[np.asarray(rows, np.int64), col] = vals

    # -- paging hooks (no-ops on resident regions) ------------------------
    def _note_flushed(self, rows: np.ndarray) -> None:
        """Rows just copied volatile->persistent through the write-set:
        a paged region clears their dirty bits (unpinning clean blocks
        for eviction); resident regions need no bookkeeping."""

    def _note_persisted(self, rows: np.ndarray) -> None:
        """Rows just written home by a DIRECT (epoch-less) persist call
        — the paged override additionally keeps shadow-masked rows
        dirty, since a refault would overlay the stale mirror."""

    def _note_persisted_range(self, lo: int, hi: int) -> None:
        pass


class Region(_RowAccess):
    """A named, row-structured persistent region."""

    def __init__(self, arena: "Arena", name: str, dtype, shape: Tuple[int, ...],
                 offset: int, meta: Optional[bool] = None):
        self.arena = arena
        self.name = name
        self.dtype = np.dtype(dtype)
        self.shape = tuple(shape)
        self.offset = offset
        # Order-snapshot regions (DESIGN.md §10) are derivable-redundancy
        # mirrors: their flush lines are accounted separately
        # (FlushStats.snapshot_lines) and they ride the metadata phase so
        # a torn data-phase crash never leaves half a snapshot behind the
        # committed header.
        self.snap = ".snap" in name
        # Request-journal regions (DESIGN.md §11): the append ring is a
        # data-phase region (entries become visible only through the
        # committed head counter on a metadata line) whose lines are
        # accounted in FlushStats.journal_lines.
        self.jrnl = ".jrnl" in name
        # Integrity-sidecar regions (DESIGN.md §13): per-line checksums
        # of a data region, written by the SAME drain that moves the
        # data rows (never marked by structures), accounted in
        # FlushStats.integrity_lines.
        self.integ = name.endswith(".integ")
        self._integ: Optional["Region"] = None   # my sidecar, if covered
        # Metadata regions (structure headers) flush AFTER data regions
        # within an epoch — data-before-metadata ordering (DESIGN.md §2).
        self.meta = (name.endswith("header") or self.snap) \
            if meta is None else meta
        self.rowbytes = int(self.dtype.itemsize * np.prod(shape[1:], dtype=np.int64)) \
            if len(shape) > 1 else self.dtype.itemsize
        self.nbytes = self.rowbytes * shape[0]
        self._init_vol()

    def _init_vol(self) -> None:
        # Volatile working copy.  PagedRegion overrides this with a
        # demand-faulted block pool (DESIGN.md §12).
        self.vol = np.zeros(self.shape, self.dtype)

    def _crash_reset(self) -> None:
        """Discard volatile state on a simulated power loss."""
        self.vol = np.zeros(self.shape, self.dtype)

    # -- persistence ------------------------------------------------------
    def _pview(self) -> np.ndarray:
        mm = self.arena._mm
        flat = np.frombuffer(mm, dtype=np.uint8,
                             count=self.nbytes, offset=self.offset)
        return flat.view(self.dtype).reshape(self.shape)

    def _gather(self, rows: np.ndarray) -> np.ndarray:
        """Volatile source rows for a flush — overridden by shard slices,
        whose volatile state lives in the parent ShardedRegion."""
        return self.vol[rows]

    def _gather_range(self, lo: int, hi: int) -> np.ndarray:
        return self.vol[lo:hi]

    def _pack_source(self, rows: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(full volatile array, row ids into it) for the pack_flush
        kernel gather path."""
        return self.vol, rows

    def persist_rows(self, rows: np.ndarray) -> None:
        """Flush the given row indices (volatile -> persistent) NOW, with
        per-call line accounting.  Structure code should prefer
        ``mark_rows`` so flushes batch per epoch."""
        rows = np.asarray(rows, np.int64)
        if rows.size == 0:
            return
        rows = np.unique(rows)
        pv = self._pview()
        pv[rows] = self._gather(rows)
        self.arena._account_rows(self.offset, self.rowbytes, rows,
                                 snap=self.snap, jrnl=self.jrnl,
                                 integ=self.integ)
        self._note_persisted(rows)
        self.arena._integrity_home(self, rows)

    def mark_rows(self, rows: np.ndarray, fresh: bool = False) -> None:
        """Add rows to the arena's write set (flushed once, deduplicated,
        when the enclosing epoch closes).  Outside any epoch this
        degrades to an immediate ``persist_rows`` — per-op call sites
        behave identically either way.  ``fresh=True`` asserts the rows
        were never reachable from any committed generation (fresh-range
        allocations above the committed high-water mark), so a shadow
        drain may write them home in place instead of through the
        remap; barrier mode ignores the hint."""
        if self.arena._epoch_depth > 0:
            self.arena.writeset.mark(self, np.asarray(rows, np.int64),
                                     fresh=fresh)
        else:
            self.persist_rows(rows)

    def mark_range(self, lo: int, hi: int, fresh: bool = False) -> None:
        if hi > lo:
            self.mark_rows(np.arange(lo, hi, dtype=np.int64), fresh=fresh)

    def persist_range(self, lo: int, hi: int) -> None:
        if hi <= lo:
            return
        pv = self._pview()
        pv[lo:hi] = self._gather_range(lo, hi)
        self.arena._account_range(self.offset + lo * self.rowbytes,
                                  (hi - lo) * self.rowbytes,
                                  snap=self.snap, jrnl=self.jrnl,
                                  integ=self.integ)
        self._note_persisted_range(lo, hi)
        self.arena._integrity_home(self, np.arange(lo, hi, dtype=np.int64))

    def persist_all(self) -> None:
        self.persist_range(0, self.shape[0])

    def load(self) -> None:
        """Reload volatile copy from persistent memory (post-crash).
        Pays the synthetic media read latency when the arena models one
        — the recovery-side mirror of the flush stall."""
        self.vol = np.array(self._pview())
        self.arena._shadow_overlay(self)
        self.arena.synth_read(self.nbytes)


class Arena:
    """File-backed persistent arena with flush accounting."""

    def __init__(self, path: Optional[str], synth_line_ns: float = 0.0,
                 pack_flush_rows: int = 0, commit_mode: str = "barrier",
                 synth_fence_ns: float = 0.0, paged: Optional[bool] = None,
                 block_bytes: int = 4096, cache_blocks: int = 1024,
                 integrity: Optional[bool] = None):
        assert commit_mode in ("barrier", "shadow")
        self.path = path
        self.regions: Dict[str, Region] = {}
        self.stats = FlushStats()
        # Integrity sidecars (DESIGN.md §13): finalize() appends a
        # per-line checksum region per covered data region, written by
        # the epoch drain itself.  Integrity-off layouts and accounting
        # are bit-identical to the pre-integrity substrate.
        self.integrity = integrity_enabled(integrity)
        # Paged-region backend (DESIGN.md §12): eligible data regions
        # fault fixed-size blocks through a per-arena LRU cache instead
        # of materializing a full-shape volatile array.  Strictly
        # volatile-side — persistent layouts are bit-identical either way.
        self.paged = paged_enabled(paged)
        self.block_bytes = int(block_bytes)
        self.cache_blocks = int(cache_blocks)
        self.cache = None
        if self.paged:
            from repro.core.paging import BlockCache
            self.cache = BlockCache(self.block_bytes, self.cache_blocks)
        self.synth_line_ns = synth_line_ns
        self.commit_mode = commit_mode
        self.synth_fence_ns = synth_fence_ns
        # >0: epoch flushes of at least this many rows gather through the
        # Pallas pack_flush kernel (tile-aligned staging buffer).
        self.pack_flush_rows = pack_flush_rows
        # Sharded parents set this: synthetic flush stalls then sleep
        # (GIL-released — stalls of sibling shards overlap in the flush
        # pool) instead of spinning.  A lone arena always spins: exact,
        # and nothing could overlap with it anyway.
        self.synth_sleep = False
        self._defer = False
        self._defer_ns = 0
        # concurrent per-region load stages may synth_read the same
        # shard from several scheduler threads; the fence accumulator is
        # the one counter they share
        self._fence_lock = threading.Lock()
        self.writeset = WriteSet(self)
        self._epoch_depth = 0
        self._layout_final = False
        # order-snapshot providers (DESIGN.md §10): callables returning
        # [(region, rows), ...] drained by the write set ONLY inside a
        # commit (never by mid-epoch flushes), so snapshot bytes always
        # ride the commit protocol of whichever mode is active
        self._snap_providers: List = []
        self._mm: Optional[np.memmap] = None
        self._cursor = 4096  # header page
        self._meta: Dict[str, dict] = {}
        self.generation = 0
        # shadow-commit state (DESIGN.md §9) — all volatile; the
        # persistent side (one meta line, two remap-entry banks, and a
        # per-region mirror per bank) is laid out by finalize() after
        # the last region
        self._region_ids: Dict[str, int] = {}
        self._shadow_meta_off = 0
        self._shadow_ent_off = [0, 0]
        self._shadow_cap = 0
        self._shadow_masks = ({}, {})   # bank -> {region name: bool mask}
        self._shadow_counts = [0, 0]
        self._shadow_collapsed = [True, True]
        self._shadow_auth_bank = 0

    # -- epochs -----------------------------------------------------------
    @contextlib.contextmanager
    def epoch(self):
        """One logical operation: ``mark_rows`` calls inside the block
        accumulate in the write set; the outermost epoch exit flushes
        them once (rows deduplicated, lines coalesced across the op,
        data regions before metadata regions)."""
        self._epoch_depth += 1
        try:
            yield self
        finally:
            self._epoch_depth -= 1
            if self._epoch_depth == 0:
                self.writeset.flush()

    # -- layout -----------------------------------------------------------
    def region(self, name: str, dtype, shape: Tuple[int, ...],
               meta: Optional[bool] = None, router=None,
               _cls=Region, **_slice_kw) -> Region:
        """``router`` (a row->shard routing spec) is accepted for layout
        compatibility with ShardedArena and ignored here: a single arena
        IS one shard."""
        assert not self._layout_final, "layout already finalized"
        assert name not in self.regions
        # Row-align every region to LINE so a row flush never straddles an
        # unrelated region (paper: __attribute__((aligned(64)))).
        self._cursor = _align(self._cursor, LINE)
        cls = _cls
        if cls is Region and self.cache is not None and _paged_eligible(
                name, meta, dtype, shape, self.block_bytes):
            from repro.core.paging import PagedRegion
            cls = PagedRegion
        r = cls(self, name, dtype, shape, self._cursor, meta=meta,
                **_slice_kw)
        self._cursor += _align(r.nbytes, LINE)
        self.regions[name] = r
        self._region_ids[name] = len(self._region_ids)
        self._meta[name] = {"dtype": np.dtype(dtype).str,
                            "shape": list(shape), "offset": r.offset}
        return r

    def region_shards(self, name: str, rows: np.ndarray) -> np.ndarray:
        """Shard id of each row of region `name` — all zeros for a plain
        arena (callers group work per shard without caring which arena
        flavor they hold)."""
        return np.zeros(len(np.atleast_1d(rows)), np.int64)

    def finalize(self) -> None:
        assert not self._layout_final
        if self.integrity:
            self._integrity_layout()
        self._layout_final = True
        if self.commit_mode == "shadow":
            self._shadow_layout()
        total = _align(self._cursor, 4096)
        if self.path is None:
            self._mm = np.zeros(total, np.uint8)  # in-memory (tests)
        else:
            create = not os.path.exists(self.path)
            if create:
                with open(self.path, "wb") as f:
                    f.truncate(total)
            elif os.path.getsize(self.path) < total:
                # an existing-but-short backing file is media loss, not
                # a layout bug.  np.memmap in r+ mode would silently
                # re-extend it with zeros — zeros that also wipe the
                # integrity sidecars back to the never-written sentinel,
                # making the loss invisible to scrub — so the size check
                # must happen BEFORE mapping.
                raise ShardLossError(
                    f"backing file {self.path!r} truncated: "
                    f"{os.path.getsize(self.path)} < {total} bytes")
            try:
                self._mm = np.memmap(self.path, dtype=np.uint8, mode="r+",
                                     shape=(total,))
            except (ValueError, OSError) as e:
                raise ShardLossError(
                    f"backing file {self.path!r} unmappable at "
                    f"{total} bytes: {e}") from e
            if create:
                self._write_header(valid=False)
        # sidecar layout description (tiny, metadata-only)
        if self.path is not None:
            with open(self.path + ".layout", "w") as f:
                json.dump(self._meta, f)

    # -- order snapshots (DESIGN.md §10) -----------------------------------
    def add_snapshot_provider(self, fn) -> None:
        """Register an order-snapshot provider: a callable returning
        ``[(region, rows), ...]`` of snapshot-region rows to persist.
        Drained by the write set exactly once per commit, inside the
        active commit protocol."""
        self._snap_providers.append(fn)

    # -- integrity sidecars (DESIGN.md §13) --------------------------------
    def _integrity_layout(self) -> None:
        """Append one checksum sidecar per covered data region: int64
        rows of shape (rows, chunks) where each word checksums one 64 B
        line of the source row (whole row when rows are sub-line).
        Appending AFTER every declared region keeps integrity-on
        layouts a pure suffix of integrity-off ones — existing region
        offsets never move."""
        for name, r in list(self.regions.items()):
            if r.meta or r.snap or r.jrnl or r.integ or r.rowbytes % 8:
                continue
            if getattr(r, "_parent", None) is not None:
                continue            # shard slices: the parent covers them
            sc = self.region(name + ".integ", np.int64,
                             (r.shape[0], _integ_chunks(r.rowbytes)),
                             meta=False)
            r._integ = sc

    def _integrity_home(self, region, rows: np.ndarray,
                        data: Optional[np.ndarray] = None) -> None:
        """Recompute + persist `rows`' sidecar checksums IN PLACE — the
        companion of every home write of the data rows themselves
        (barrier drains, fresh shadow rows, direct persists), so data
        and checksums always move in the same flush phase and a torn
        crash can never split them.  ``data``, when the caller already
        gathered the rows (the epoch drain always has), skips a second
        gather."""
        sc = region._integ
        if sc is None or rows.size == 0:
            return
        if data is None:
            data = region._gather(rows)
        ck = sidecar_checksums(data, sc.shape[1])
        sc.write_rows(rows, ck)
        sc._pview()[rows] = ck
        self._account_rows(sc.offset, sc.rowbytes, rows, integ=True)

    def verify_header(self) -> None:
        """Raise ManifestError when the commit header's magic is neither
        ours nor the all-zero never-committed state — field corruption
        power loss cannot produce (the header is one atomic line)."""
        raw = bytes(self._mm[:4])
        if raw not in (_MAGIC, b"\x00\x00\x00\x00"):
            raise ManifestError(
                f"arena {self.path!r} header magic {raw!r} corrupt")

    def _pimage(self, region) -> np.ndarray:
        """The COMMITTED persistent image of a region: home bytes plus
        the authoritative shadow bank's overlay.  A pure read — scrub
        and salvage never write persistent state."""
        img = np.array(region._pview())
        if self.commit_mode == "shadow":
            mask = self._shadow_masks[self._shadow_auth_bank].get(
                region.name)
            if mask is not None and mask.any():
                rows = np.nonzero(mask)[0]
                img[rows] = self._shadow_mirror(
                    region, self._shadow_auth_bank)[rows]
        return img

    def verify_region(self, region) -> np.ndarray:
        """Row indices of `region` whose committed persistent bytes fail
        their sidecar checksums (empty = clean).  Reads the persistent
        image only — in-flight volatile writes and pending epoch marks
        are invisible to it, and rows whose lines were never flushed
        carry the 0 \"no checksum\" sentinel and are skipped — so scrub
        under traffic cannot false-positive (DESIGN.md §13)."""
        if isinstance(region, str):
            region = self.regions[region]
        sc = region._integ
        if sc is None:
            return np.empty(0, np.int64)
        ck = sidecar_checksums(self._pimage(region), sc.shape[1])
        ref = self._pimage(sc)
        bad = (ref != 0) & (ck != ref)
        self.synth_read(region.nbytes + sc.nbytes)
        return np.nonzero(bad.any(axis=1))[0]

    def scrub(self, raise_on_error: bool = False
              ) -> Dict[str, np.ndarray]:
        """Verify every covered region against its sidecar; returns
        {region name: bad rows} for the regions that fail (empty dict =
        media clean).  Read-only and crash-safe at any instant."""
        bad: Dict[str, np.ndarray] = {}
        for name, r in self.regions.items():
            if r._integ is None:
                continue
            rows = self.verify_region(r)
            if rows.size:
                bad[name] = rows
        if bad and raise_on_error:
            name, rows = next(iter(bad.items()))
            raise CorruptLineError(name, rows,
                                   detail=f"scrub: {len(bad)} region(s)")
        return bad

    # -- header / commit protocol -----------------------------------------
    def _write_header(self, valid: bool) -> None:
        hdr = struct.pack(_HDR_FMT, _MAGIC, len(self.regions),
                          self.generation, valid)
        self._mm[: len(hdr)] = np.frombuffer(hdr, np.uint8)

    def header_valid(self) -> bool:
        raw = bytes(self._mm[: struct.calcsize(_HDR_FMT)])
        magic, _, gen, valid = struct.unpack(_HDR_FMT, raw)
        return magic == _MAGIC and bool(valid)

    def header_generation(self) -> int:
        """Committed generation as persisted in the header — unlike the
        in-memory ``generation`` counter, this survives a fresh-process
        reopen."""
        raw = bytes(self._mm[: struct.calcsize(_HDR_FMT)])
        magic, _, gen, _ = struct.unpack(_HDR_FMT, raw)
        return int(gen) if magic == _MAGIC else 0

    def commit(self) -> None:
        """Data-before-metadata ordering: drain the write set, flush file
        contents, then set the valid flag (the paper's initialization
        flag bit).  Inside an epoch this flushes the pending marks first,
        so a commit never orders the flag ahead of its data.  In shadow
        mode the whole protocol collapses to ONE ordering point — see
        ``_commit_shadow``."""
        if self.commit_mode == "shadow":
            self._commit_shadow()
            return
        self.writeset.flush()
        if isinstance(self._mm, np.memmap):
            self._mm.flush()
        self._fence()
        self.generation += 1
        self._write_header(valid=True)
        if isinstance(self._mm, np.memmap):
            self._mm.flush()
        self.stats.calls += 1

    def invalidate(self) -> None:
        self._write_header(valid=False)

    def _fence(self) -> None:
        """One ordering point (sfence + drain of outstanding flushes):
        counted per mode so the barrier-vs-shadow comparison is visible
        in the stats artifact, and paid synthetically when
        ``synth_fence_ns`` models the stall."""
        self.stats.fences += 1
        if self.synth_fence_ns:
            self._stall(int(self.synth_fence_ns))

    # -- shadow commit protocol (DESIGN.md §9) ------------------------------
    def _shadow_layout(self) -> None:
        """Persistent shadow areas, appended after the last region: one
        meta line holding each remap bank's sealed entry count, two
        remap-entry banks (one per generation parity: the epoch
        targeting generation T writes bank T%2, so a torn flip never
        touches the committed bank), and a per-region mirror per bank
        whose slot index IS the row index — duplicate rewrites of a row
        are idempotent by construction, and the remap entry is just
        (region id, row)."""
        cur = _align(self._cursor, LINE)
        self._shadow_meta_off = cur
        cur += LINE
        self._shadow_cap = max(1, sum(r.shape[0]
                                      for r in self.regions.values()))
        for b in (0, 1):
            self._shadow_ent_off[b] = cur
            cur += _align(self._shadow_cap * 16, LINE)
        for r in self.regions.values():
            r._shadow_off = {}
            for b in (0, 1):
                r._shadow_off[b] = cur
                cur += _align(r.nbytes, LINE)
        self._cursor = cur

    def _shadow_target_bank(self) -> int:
        return (self.generation + 1) % 2

    def _shadow_mirror(self, region: "Region", bank: int) -> np.ndarray:
        flat = np.frombuffer(self._mm, dtype=np.uint8, count=region.nbytes,
                             offset=region._shadow_off[bank])
        return flat.view(region.dtype).reshape(region.shape)

    def _shadow_entries(self, bank: int) -> np.ndarray:
        flat = np.frombuffer(self._mm, dtype=np.uint8,
                             count=self._shadow_cap * 16,
                             offset=self._shadow_ent_off[bank])
        return flat.view(np.int64).reshape(self._shadow_cap, 2)

    def _shadow_meta_view(self) -> np.ndarray:
        flat = np.frombuffer(self._mm, dtype=np.uint8, count=LINE,
                             offset=self._shadow_meta_off)
        return flat.view(np.int64)

    def _shadow_write(self, region: "Region", rows: np.ndarray) -> None:
        """Route a rewrite through the remap: the new row versions land
        in the target bank's mirror (slot = row) and first-touch rows
        append a (region, row) remap entry.  Committed home rows are
        never rewritten before the flip, so the drain needs no ordering
        against the metadata that references them."""
        b = self._shadow_target_bank()
        mask = self._shadow_masks[b].get(region.name)
        if mask is None:
            mask = self._shadow_masks[b][region.name] = \
                np.zeros(region.shape[0], bool)
        new = rows[~mask[rows]]
        mask[rows] = True
        self._shadow_mirror(region, b)[rows] = region._gather(rows)
        self._account_rows(region._shadow_off[b], region.rowbytes, rows,
                           snap=region.snap, jrnl=region.jrnl,
                           integ=region.integ)
        if new.size:
            cnt = self._shadow_counts[b]
            ents = self._shadow_entries(b)
            ents[cnt:cnt + new.size, 0] = self._region_ids[region.name]
            ents[cnt:cnt + new.size, 1] = new
            self._account_range(self._shadow_ent_off[b] + cnt * 16,
                                int(new.size) * 16, snap=region.snap,
                                jrnl=region.jrnl, integ=region.integ)
            self._shadow_counts[b] = cnt + int(new.size)
        # The rows' volatile values are now captured persistently in the
        # target-bank mirror, which a paged refault overlays — so their
        # dirty bits may clear (clean blocks become evictable).
        region._note_flushed(rows)
        # cascade: the rows' checksums route through the SAME bank, so a
        # discarded target bank drops data and checksums together
        sc = region._integ
        if sc is not None:
            sc.write_rows(rows, sidecar_checksums(region._gather(rows),
                                                  sc.shape[1]))
            self._shadow_write(sc, rows)

    def _shadow_collapse(self, limit: Optional[int] = None) -> bool:
        """Fold the committed bank's shadow rows into their home slots —
        the stale-row reclamation, deferred into the next drain instead
        of blocking the commit that created them.  The copy is
        value-identical to what recovery would overlay, so a crash at
        ANY instant during it (the double-failure window) changes
        nothing the committed generation can observe.  ``limit`` bounds
        the number of regions folded (crash-injection hook); returns
        whether the bank fully collapsed."""
        b = self.generation % 2
        if self._shadow_collapsed[b]:
            return True
        done = True
        for i, name in enumerate(sorted(self._shadow_masks[b])):
            if limit is not None and i >= limit:
                done = False
                break
            rows = np.nonzero(self._shadow_masks[b][name])[0]
            if rows.size == 0:
                continue
            region = self.regions[name]
            region._pview()[rows] = self._shadow_mirror(region, b)[rows]
            self._account_rows(region.offset, region.rowbytes, rows,
                               snap=region.snap, jrnl=region.jrnl,
                               integ=region.integ)
        if done:
            self._shadow_collapsed[b] = True
        return done

    def _shadow_seal(self) -> None:
        """Persist the target bank's entry count.  Safe before the
        flip: the bank is dead weight until the generation pointer
        selects it, and the committed bank's count slot is untouched."""
        b = self._shadow_target_bank()
        self._shadow_meta_view()[b] = self._shadow_counts[b]
        self._account_range(self._shadow_meta_off + b * 8, 8)

    def _shadow_retire(self) -> None:
        """Post-flip bookkeeping: the previous bank's entries are dead
        (their rows were folded home before the flip); the newly
        committed bank awaits its fold at the next drain."""
        live = self.generation % 2
        dead = 1 - live
        self._shadow_masks[dead].clear()
        self._shadow_counts[dead] = 0
        self._shadow_collapsed[dead] = True
        self._shadow_collapsed[live] = self._shadow_counts[live] == 0
        self._shadow_auth_bank = live

    def _commit_shadow(self) -> None:
        """Shadow commit: fold the previous epoch's shadow rows home,
        drain the write set straight through — fresh rows in place,
        rewrites into the target bank — seal the target bank's count,
        then pay the ONE ordering point and flip the generation
        pointer.  The flip atomically reassigns bank authority; a torn
        flip leaves the committed bank (untouched since its own seal)
        authoritative, and the orphaned target bank is discarded by
        never being selected."""
        self._shadow_collapse()
        self.writeset.flush()
        self._shadow_seal()
        if isinstance(self._mm, np.memmap):
            self._mm.flush()
        self._fence()                      # the single ordering point
        self.generation += 1
        self._write_header(valid=True)
        if isinstance(self._mm, np.memmap):
            self._mm.flush()
        self.stats.calls += 1
        self._shadow_retire()

    def _shadow_discard(self) -> None:
        """Volatile shadow bookkeeping dies with a crash; ``reopen``
        re-parses it from the committed bank."""
        for m in self._shadow_masks:
            m.clear()
        self._shadow_counts = [0, 0]
        self._shadow_collapsed = [True, True]

    def _shadow_parse(self, authority_gen: Optional[int] = None) -> None:
        """Post-crash: rebuild the volatile remap masks from the bank
        the COMMITTED generation pointer selects — for a shard that is
        the manifest generation, which may trail the shard's own header
        if the flip tore between shards.  Entries in the other bank (a
        torn flip's orphans) are never selected and are overwritten
        when that bank is next targeted."""
        if self.commit_mode != "shadow":
            return
        gen = self.header_generation() if authority_gen is None \
            else authority_gen
        b = gen % 2
        cnt = int(self._shadow_meta_view()[b])
        ents = np.array(self._shadow_entries(b)[:cnt])
        masks: Dict[str, np.ndarray] = {}
        names = list(self.regions)
        for rid in (np.unique(ents[:, 0]) if cnt else ()):
            name = names[int(rid)]
            mask = np.zeros(self.regions[name].shape[0], bool)
            mask[ents[ents[:, 0] == rid, 1]] = True
            masks[name] = mask
        self._shadow_masks = (masks, {}) if b == 0 else ({}, masks)
        self._shadow_counts = [cnt, 0] if b == 0 else [0, cnt]
        self._shadow_collapsed = [True, True]
        self._shadow_collapsed[b] = cnt == 0
        self._shadow_auth_bank = b
        # re-anchor bank targeting to the COMMITTED generation: a shard
        # whose header flipped ahead of a torn manifest write must aim
        # its next drain at the bank the manifest's parity dooms, not
        # keep writing into the bank recovery just selected
        self.generation = gen

    def _shadow_overlay(self, region: "Region",
                        vol: Optional[np.ndarray] = None,
                        gidx: Optional[np.ndarray] = None) -> None:
        """Apply the authoritative bank's shadow rows over a freshly
        loaded volatile copy — recovery-side only, and VOLATILE-only:
        recovery persists nothing (the fold happens lazily at the next
        drain, preserving reconstructor purity)."""
        if self.commit_mode != "shadow":
            return
        mask = self._shadow_masks[self._shadow_auth_bank].get(region.name)
        if mask is None:
            return
        rows = np.nonzero(mask)[0]
        if rows.size == 0:
            return
        m = self._shadow_mirror(region, self._shadow_auth_bank)
        if vol is None:
            region.vol[rows] = m[rows]
        else:
            vol[gidx[rows]] = m[rows]
        self.synth_read(int(rows.size) * region.rowbytes)

    # -- crash simulation ---------------------------------------------------
    def crash(self) -> None:
        """Discard all volatile state (keep the backing file).  Pending
        write-set marks die with the volatile state — power loss loses
        un-flushed rows; it must never flush zeroed volatile copies over
        committed data when a wrapping epoch unwinds."""
        self.writeset.discard()
        self._shadow_discard()
        for r in self.regions.values():
            r._crash_reset()

    def reopen(self) -> None:
        """Reload every region's volatile copy from persistent memory,
        and re-anchor the in-memory generation counter to the committed
        one (a fresh process starts at 0 otherwise).  Shadow mode first
        re-parses the committed bank's remap so each load overlays the
        flipped-in row versions."""
        self._shadow_parse()
        for r in self.regions.values():
            r.load()
        self.generation = max(self.generation, self.header_generation())

    # -- accounting ---------------------------------------------------------
    def _account_range(self, byte_off: int, nbytes: int,
                       snap: bool = False, jrnl: bool = False,
                       integ: bool = False) -> None:
        lo = (byte_off // LINE) * LINE
        hi = _align(byte_off + nbytes, LINE)
        lines = (hi - lo) // LINE
        if snap:
            # snapshot overhead is real media traffic (it pays the synth
            # stall) but lands in its own counter so data-line accounting
            # stays bit-comparable to snapshot-off runs
            self.stats.snapshot_lines += lines
            self._synth(lines)
            return
        if jrnl:
            # journal rings get the same treatment (DESIGN.md §11)
            self.stats.journal_lines += lines
            self._synth(lines)
            return
        if integ:
            # checksum sidecars too (DESIGN.md §13)
            self.stats.integrity_lines += lines
            self._synth(lines)
            return
        self.stats.lines += lines
        self.stats.bytes += nbytes
        self.stats.calls += 1
        self._synth(lines)

    @staticmethod
    def _rows_line_count(base: int, rowbytes: int, rows: np.ndarray) -> int:
        """Distinct 64 B lines touched by flushing `rows` (sorted unique)."""
        if rowbytes % LINE == 0 and base % LINE == 0:
            # aligned rows: rows * rowbytes/LINE lines, coalescing irrelevant
            return int(rows.size) * (rowbytes // LINE)
        if rowbytes and LINE % rowbytes == 0 and base % LINE == 0:
            # sub-line rows that tile lines exactly (the checksum
            # sidecars: 8/16/32 B rows) — sorted-unique rows sharing a
            # line are adjacent, so distinct lines = breaks + 1
            per = LINE // rowbytes
            if rows.size == 0:
                return 0
            return int(np.count_nonzero(np.diff(rows // per))) + 1
        # exact distinct-line count over sorted row intervals (adjacent
        # rows may share a line — the Fig-12 unaligned-flush effect)
        starts = (base + rows * rowbytes) // LINE
        ends = (base + (rows + 1) * rowbytes - 1) // LINE
        starts = np.maximum(starts,
                            np.concatenate(([-1], ends[:-1])) + 1)
        return int(np.sum(np.maximum(0, ends - starts + 1)))

    def _account_rows(self, base: int, rowbytes: int, rows: np.ndarray,
                      snap: bool = False, jrnl: bool = False,
                      integ: bool = False) -> None:
        lines = self._rows_line_count(base, rowbytes, rows)
        if snap:
            self.stats.snapshot_lines += lines
            self._synth(lines)
            return
        if jrnl:
            self.stats.journal_lines += lines
            self._synth(lines)
            return
        if integ:
            self.stats.integrity_lines += lines
            self._synth(lines)
            return
        self.stats.lines += lines
        self.stats.bytes += int(rows.size) * rowbytes
        self.stats.calls += 1
        self._synth(lines)

    def _synth(self, lines: int) -> None:
        if self.synth_line_ns:
            self._stall(int(lines * self.synth_line_ns))

    def synth_read(self, nbytes: int) -> None:
        """Synthetic media READ latency for a reload of `nbytes` —
        the §V-F mirror of the write-side flush stall, at DCPMM media
        granularity (256 B grains).  Zero-cost unless the arena was
        opened with ``synth_line_ns`` (the same knob as the write side:
        one medium, one latency model)."""
        if self.synth_line_ns:
            grains = (nbytes + MEDIA_GRAIN - 1) // MEDIA_GRAIN
            self._stall(int(grains * self.synth_line_ns))

    @contextlib.contextmanager
    def stall_scope(self):
        """Aggregate synthetic stalls issued inside the block into ONE
        stall paid at exit — a flush that touches several regions fences
        once per drain, not once per region.  The accounting
        (``fence_ns``) is unchanged; only the pay-out coalesces, which
        is what keeps the sleep-based stall's timer slack from being
        charged per region."""
        self._defer_ns = 0
        self._defer = True
        try:
            yield
        finally:
            self._defer = False
            ns, self._defer_ns = self._defer_ns, 0
            if ns:
                self._pay(ns)

    def _stall(self, ns: int) -> None:
        with self._fence_lock:
            self.stats.fence_ns += ns
        if self._defer:
            self._defer_ns += ns
            return
        self._pay(ns)

    def _pay(self, ns: int) -> None:
        if self.synth_sleep and ns >= 200_000:
            # big stalls sleep so concurrent shard flushes/reloads
            # overlap them; sub-200µs stalls stay on the exact spin (the
            # host timer's wakeup slack would swamp them)
            time.sleep(ns * 1e-9)
            return
        t0 = time.perf_counter_ns()
        while time.perf_counter_ns() - t0 < ns:
            pass

    def close(self) -> None:
        if isinstance(self._mm, np.memmap):
            self._mm.flush()
        self._mm = None


def _align(x: int, a: int) -> int:
    return ((x + a - 1) // a) * a


# ======================================================================
# Sharded arenas (DESIGN.md §7)
# ======================================================================


def _splitmix64(x: np.ndarray) -> np.ndarray:
    # 0-d arrays route through numpy's *scalar* ufunc paths, which WARN
    # on the intended uint64 wraparound; compute 1-D (a view) and
    # restore the shape so >=1-d callers pay nothing
    x = np.asarray(x).astype(np.uint64, copy=False)
    shape = x.shape
    x = x.reshape(-1)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return (x ^ (x >> np.uint64(31))).reshape(shape)


# ======================================================================
# Incremental order snapshots — record format (DESIGN.md §10)
# ======================================================================

SNAP_MAGIC = 0x50414E53          # "SNAP" little-endian
SNAP_SLOTS = 4                   # record-ring slots; one 64 B line each
SNAP_WORDS = 8                   # int64 words per record (= one line)


def snapshot_enabled(flag: Optional[bool] = None) -> bool:
    """Resolve a structure's ``snapshot=`` ctor arg: an explicit flag
    wins; ``None`` defers to the ``REPRO_SNAPSHOT`` env axis (default
    on).  Snapshot-off layouts and accounting are bit-identical to the
    pre-snapshot substrate."""
    if flag is not None:
        return bool(flag)
    return os.environ.get("REPRO_SNAPSHOT", "1") != "0"


def journal_enabled(flag: Optional[bool] = None) -> bool:
    """Resolve a structure's ``journal=`` ctor arg: an explicit flag
    wins; ``None`` defers to the ``REPRO_JOURNAL`` env axis (default
    on).  Journal-off layouts and accounting are bit-identical to the
    pre-journal substrate (DESIGN.md §11)."""
    if flag is not None:
        return bool(flag)
    return os.environ.get("REPRO_JOURNAL", "1") != "0"


def paged_enabled(flag: Optional[bool] = None) -> bool:
    """Resolve an arena's ``paged=`` ctor arg: an explicit flag wins;
    ``None`` defers to the ``REPRO_PAGED`` env axis (default OFF — the
    resident volatile array is the baseline).  Paging is strictly
    volatile-side, so persistent layouts are bit-identical either way
    (DESIGN.md §12)."""
    if flag is not None:
        return bool(flag)
    return os.environ.get("REPRO_PAGED", "0") != "0"


def integrity_enabled(flag: Optional[bool] = None) -> bool:
    """Resolve an arena's ``integrity=`` ctor arg: an explicit flag
    wins; ``None`` defers to the ``REPRO_INTEGRITY`` env axis (default
    ON).  Integrity-off layouts and flush accounting are bit-identical
    to the pre-integrity substrate (DESIGN.md §13)."""
    if flag is not None:
        return bool(flag)
    return os.environ.get("REPRO_INTEGRITY", "1") != "0"


def _paged_eligible(name: str, meta: Optional[bool], dtype, shape,
                    block_bytes: int) -> bool:
    """Data regions bigger than one block page; headers, order
    snapshots, and journal rings stay resident — they are tiny, hot on
    every epoch, and recovery reads them in full anyway.  Computed from
    the layout spec BEFORE construction so an ineligible huge region is
    never allocated twice."""
    snap = ".snap" in name
    jrnl = ".jrnl" in name
    integ = name.endswith(".integ")
    m = (name.endswith("header") or snap) if meta is None else meta
    rowbytes = int(np.dtype(dtype).itemsize *
                   np.prod(shape[1:], dtype=np.int64)) \
        if len(shape) > 1 else np.dtype(dtype).itemsize
    return (not (m or snap or jrnl or integ)
            and rowbytes * shape[0] > block_bytes)


_POS_KEYS: Dict[int, np.ndarray] = {}


def _pos_keys(n: int) -> np.ndarray:
    """``n`` distinct odd 64-bit multipliers, one per word position —
    splitmix64 of the position, forced odd so each per-word multiply is
    a bijection mod 2**64."""
    k = _POS_KEYS.get(n)
    if k is None:
        k = _splitmix64(np.arange(1, n + 1, dtype=np.uint64)) \
            | np.uint64(1)
        _POS_KEYS[n] = k
    return k


def mix_checksums(words: np.ndarray) -> np.ndarray:
    """THE checksum of the substrate (DESIGN.md §10/§11/§13): each word
    multiplied by a distinct odd position key (a bijection mod 2**64,
    so any change to any word changes its term), xor-folded over the
    trailing axis, splitmix64-finalized for avalanche.  ``(..., k)``
    integer words -> ``(...)`` int64.  One vectorized helper serves
    snapshot records, journal slots, and the integrity sidecar — a torn
    or bit-rotted line fails it with overwhelming probability, and the
    per-position keys catch the word swaps plain xor would miss.  The
    multilinear shape keeps the hot path at one multiply per word: this
    runs inside every epoch drain, where its cost is bounded against
    the flush itself (the --integrity-overhead gate)."""
    w = np.asarray(words)
    # int64 -> uint64 is a bit-reinterpretation: view when contiguous
    # (the drain's gathered rows always are) instead of copying
    if w.dtype == np.int64 and w.flags.c_contiguous:
        w = w.view(np.uint64)
    elif w.dtype != np.uint64:
        w = w.astype(np.uint64)
    shape = w.shape[:-1]
    w = np.atleast_2d(w)          # 1-D input: keep off scalar ufunc paths
    k = _pos_keys(w.shape[-1])
    # unrolled fold: ufunc .reduce over a short trailing axis is the
    # slowest op on the drain's hot path, and k is <= 8 for every
    # caller (one line = 8 words)
    acc = w[..., 0] * k[0]
    for j in range(1, w.shape[-1]):
        acc = acc ^ (w[..., j] * k[j])
    return _splitmix64(acc).astype(np.int64).reshape(shape)


def _integ_chunks(rowbytes: int) -> int:
    """Checksum words per sidecar row: one per 64 B line of the source
    row, or one for the whole row when rows are sub-line."""
    return rowbytes // LINE if rowbytes % LINE == 0 and rowbytes else 1


def sidecar_checksums(arr: np.ndarray, chunks: int) -> np.ndarray:
    """Per-line checksums of gathered rows: ``(m, ...)`` rows of any
    8-byte-divisible dtype -> ``(m, chunks)`` int64, one word per 64 B
    line (per whole row for sub-line rows).  0 is reserved as the
    sidecar's \"never checksummed\" sentinel, so a computed 0 nudges
    to 1."""
    m = arr.shape[0]
    w = np.ascontiguousarray(arr).reshape(m, -1).view(np.uint64)
    ck = mix_checksums(w.reshape(m, chunks, -1))
    ck[ck == 0] = 1
    return ck


def snap_checksum(rec: np.ndarray) -> int:
    """Mix-then-xor checksum over the first 7 words of a snapshot
    record.  A torn 64 B record line (the only partial-write unit the
    substrate can produce) fails this with overwhelming probability, so
    recovery can reject it without any ordering requirement between the
    record and the ring rows it describes."""
    return int(mix_checksums(np.asarray(rec, np.int64)[:7]))


def snap_record_pack(gen: int, seq: int, a: int, b: int, c: int,
                     d: int = 0) -> np.ndarray:
    """Sealed snapshot record: ``[magic, gen, seq, a, b, c, d, cksum]``
    — exactly one flush line.  ``gen`` is the generation the enclosing
    commit is sealing; ``seq`` picks the record-ring slot (seq %
    SNAP_SLOTS) so a torn append can only damage the slot it targets,
    never the previously sealed records."""
    rec = np.array([SNAP_MAGIC, gen, seq, a, b, c, d, 0], np.int64)
    rec[7] = snap_checksum(rec)
    return rec


def snap_record_parse(rec: np.ndarray) -> Optional[Tuple[int, ...]]:
    """``(gen, seq, a, b, c, d)`` if the record line is intact, else
    ``None`` (torn append, never-written slot, or foreign bytes)."""
    rec = np.asarray(rec, np.int64).ravel()
    if rec.size != SNAP_WORDS or int(rec[0]) != SNAP_MAGIC:
        return None
    if int(rec[7]) != snap_checksum(rec):
        return None
    return tuple(int(x) for x in rec[1:7])


def route_rows(router, n_rows: int, n_shards: int, rr_hint: int = 0
               ) -> np.ndarray:
    """Pure row->shard map for one region.  Routers are functions of the
    ROW INDEX only (never of row contents): reading a row back after a
    crash must not require the row to know where it lives.

    * ``("seg", B)``   — block-cyclic: segment ``row // B`` on shard
      ``(row // B) % n_shards`` (DLL segments, B+Tree leaf ranges);
    * ``("hash", B)``  — splitmix64(row // B) % n_shards (hashmap slab
      segments — the paper's bucket-hash scatter, decoupled from insert
      order; B defaults to 64 rows, so routing stays segment-granular
      and loads take the ~4 KiB block-copy fast path);
    * ``("range",)``   — contiguous equal split;
    * ``("shard", k)`` — pin the whole region to shard k;
    * ``None``         — small regions (headers) pin to shard
      ``rr_hint % n_shards`` (round-robin by creation order, so distinct
      structures' headers spread across shards); larger ones default to
      ~4 KiB block-cyclic segments.
    """
    rows = np.arange(n_rows, dtype=np.int64)
    if n_shards == 1:
        return np.zeros(n_rows, np.int32)
    router = normalize_router(router, n_rows, n_shards, rr_hint)
    kind = router[0]
    if kind == "seg":
        return ((rows // int(router[1])) % n_shards).astype(np.int32)
    if kind == "hash":
        blk = int(router[1]) if len(router) > 1 else 64
        return (_splitmix64(rows // blk) %
                np.uint64(n_shards)).astype(np.int32)
    if kind == "range":
        return np.minimum(rows * n_shards // max(n_rows, 1),
                          n_shards - 1).astype(np.int32)
    if kind == "shard":
        return np.full(n_rows, int(router[1]) % n_shards, np.int32)
    raise ValueError(f"unknown router {router!r}")


def normalize_router(router, n_rows: int, n_shards: int,
                     rr_hint: int = 0):
    """Resolve the ``None`` default to a concrete router — the ONE place
    the defaulting policy lives (route_rows and ShardedRegion both
    consume it)."""
    if router is not None:
        return router
    if n_rows <= 4 * n_shards:
        return ("shard", rr_hint)
    return ("seg", 64)


def router_block(router) -> int:
    """Segment size of a block-granular router (seg/hash), else 0 — the
    load fast path keys off this."""
    if router is None:
        return 0
    if router[0] == "seg":
        return int(router[1])
    if router[0] == "hash":
        return int(router[1]) if len(router) > 1 else 64
    return 0


class _ShardSlice(Region):
    """Per-shard persistent slice of a ShardedRegion.

    Local rows pack the parent's assigned global rows in ascending
    global order; all volatile state lives ONLY in the parent's
    full-shape array — a slice is pure persistence plumbing, so a crash
    has exactly one volatile image to discard."""

    def __init__(self, arena, name, dtype, shape, offset, meta=None,
                 parent=None, gidx=None, arena_index=0):
        super().__init__(arena, name, dtype, shape, offset, meta=meta)
        self.vol = None                 # no independent volatile copy
        self._parent = parent
        self._gidx = gidx               # local row -> global row
        self.arena_index = arena_index  # which shard holds this slice

    def _gather(self, rows: np.ndarray) -> np.ndarray:
        return self._parent._vol_rows(self._gidx[rows])

    def _gather_range(self, lo: int, hi: int) -> np.ndarray:
        return self._parent._vol_rows(self._gidx[lo:hi])

    def write_rows(self, rows: np.ndarray, vals) -> None:
        # sidecar cascades write slice-local rows; the one volatile copy
        # lives in the parent, global-indexed
        self._parent.write_rows(self._gidx[np.asarray(rows, np.int64)],
                                vals)

    def _pack_source(self, rows: np.ndarray):
        return self._parent._pack_source_global(self._gidx[rows])

    def _note_flushed(self, rows: np.ndarray) -> None:
        self._parent._note_flushed_global(self._gidx[rows])

    def _note_persisted(self, rows: np.ndarray) -> None:
        self._parent._note_persisted_global(self._gidx[rows])

    def _note_persisted_range(self, lo: int, hi: int) -> None:
        self._parent._note_persisted_global(self._gidx[lo:hi])

    def _crash_reset(self) -> None:
        pass                            # no volatile state of its own

    def load(self) -> None:
        self._parent.vol[self._gidx] = self._pview()
        self.arena._shadow_overlay(self, vol=self._parent.vol,
                                   gidx=self._gidx)


class ShardedRegion(_RowAccess):
    """Facade with the exact Region API structures use (``vol`` /
    ``mark_rows`` / ``mark_range`` / ``persist_*`` / ``load``), backed
    by per-shard slices.  Marks and flushes partition by the router;
    per-shard line/dedup accounting lands in each shard's FlushStats and
    rolls up through ``ShardedArena.stats``."""

    def __init__(self, arena: "ShardedArena", name: str, dtype,
                 shape: Tuple[int, ...], meta: Optional[bool] = None,
                 router=None, rr_hint: int = 0):
        self.arena = arena
        self.name = name
        self.dtype = np.dtype(dtype)
        self.shape = tuple(shape)
        self.snap = ".snap" in name
        self.jrnl = ".jrnl" in name
        self.integ = name.endswith(".integ")
        self._integ: Optional["ShardedRegion"] = None
        self.meta = (name.endswith("header") or self.snap) \
            if meta is None else meta
        self.rowbytes = int(self.dtype.itemsize *
                            np.prod(shape[1:], dtype=np.int64)) \
            if len(shape) > 1 else self.dtype.itemsize
        self.nbytes = self.rowbytes * shape[0]
        self._init_vol()
        n = self.shape[0]
        self.router = router = normalize_router(router, n, arena.n_shards,
                                                rr_hint)
        self.shard_of = route_rows(router, n, arena.n_shards, rr_hint)
        self.local_of = np.zeros(n, np.int64)
        # block-granular routers (seg/hash) load through a block-level
        # copy: per-shard FULL-block ids over the (nb, B, ...) view
        self._blk = router_block(router)
        nb = (n // self._blk) if self._blk else 0
        self._blocks: List[Optional[np.ndarray]] = []
        self.slices: List[Optional[_ShardSlice]] = []
        for s, shard in enumerate(arena.shards):
            gidx = np.nonzero(self.shard_of == s)[0]
            self.local_of[gidx] = np.arange(gidx.size)
            self._blocks.append(
                np.nonzero(self.shard_of[:nb * self._blk:self._blk] == s)[0]
                if self._blk else None)
            if gidx.size == 0:
                self.slices.append(None)
                continue
            sl = shard.region(name, dtype, (int(gidx.size),) + self.shape[1:],
                              meta=self.meta, _cls=_ShardSlice,
                              parent=self, gidx=gidx, arena_index=s)
            self.slices.append(sl)

    def _init_vol(self) -> None:
        self.vol = np.zeros(self.shape, self.dtype)

    def _crash_reset(self) -> None:
        # the volatile buffer is a LONG-LIVED arena: zero in place so
        # the post-crash reload writes warm pages
        self.vol.fill(0)

    # -- slice plumbing: slices hold no volatile state, so their gathers
    # and paging notes route through the parent with GLOBAL row ids ------
    def _vol_rows(self, grows: np.ndarray) -> np.ndarray:
        return self.vol[grows]

    def _pack_source_global(self, grows: np.ndarray):
        return self.vol, grows

    def _note_flushed_global(self, grows: np.ndarray) -> None:
        pass

    def _note_persisted_global(self, grows: np.ndarray) -> None:
        pass

    # -- shard partitioning ------------------------------------------------
    def _split(self, rows: np.ndarray):
        """Yield (slice, local_rows) per shard holding any of `rows`."""
        shards = self.shard_of[rows]
        for s in np.unique(shards):
            sel = rows[shards == s]
            yield self.slices[s], self.local_of[sel]

    # -- Region API --------------------------------------------------------
    def mark_rows(self, rows: np.ndarray, fresh: bool = False) -> None:
        rows = np.asarray(rows, np.int64)
        if rows.size == 0:
            return
        if self.arena._epoch_depth > 0:
            # buffered globally; the row->shard split happens once per
            # epoch at flush (ShardedWriteSet.mark documents why)
            self.arena.writeset.mark(self, rows, fresh=fresh)
        else:
            self.persist_rows(rows)

    def mark_range(self, lo: int, hi: int, fresh: bool = False) -> None:
        if hi > lo:
            self.mark_rows(np.arange(lo, hi, dtype=np.int64), fresh=fresh)

    def persist_rows(self, rows: np.ndarray) -> None:
        rows = np.asarray(rows, np.int64)
        if rows.size == 0:
            return
        for sl, local in self._split(np.unique(rows)):
            sl.persist_rows(local)

    def persist_range(self, lo: int, hi: int) -> None:
        if hi > lo:
            self.persist_rows(np.arange(lo, hi, dtype=np.int64))

    def persist_all(self) -> None:
        self.persist_range(0, self.shape[0])

    def load(self, concurrency: int = 1) -> None:
        """Reload all shards' rows.  ``concurrency>1`` fans the per-shard
        block copies out on the arena's shard pool: post-crash loads
        write cold pages, and page faults parallelize even where pure
        memcpy bandwidth would not."""
        if concurrency > 1 and self.arena.n_shards > 1:
            list(self.arena.pool().map(self.load_shard,
                                       range(self.arena.n_shards)))
        else:
            for s in range(self.arena.n_shards):
                self.load_shard(s)

    def load_shard(self, s: int) -> None:
        """Reload this region's shard-s rows into the shared volatile
        array.  Block-granular routers (seg/hash) copy whole segments
        through a (blocks, B, ...) view — ~5x the throughput of a
        row-wise scatter, and a C-level copy that releases the GIL, so
        the pooled sharded reopen is actually parallel."""
        sl = self.slices[s]
        if sl is None:
            return
        pv = sl._pview()
        if self._blk:
            B = self._blk
            nb = self.shape[0] // B            # full blocks
            bs = self._blocks[s]
            nfull = bs.size
            if nfull:
                self.vol[:nb * B].reshape((nb, B) + self.shape[1:])[bs] = \
                    pv[:nfull * B].reshape((nfull, B) + self.shape[1:])
            if pv.shape[0] > nfull * B:        # global tail block is ours
                self.vol[nb * B:] = pv[nfull * B:]
        else:
            self.vol[sl._gidx] = pv
        sl.arena._shadow_overlay(sl, vol=self.vol, gidx=sl._gidx)
        # per-shard media read stall — sleeps in the shard pool, so N
        # shards' reload stalls overlap instead of summing
        sl.arena.synth_read(sl.nbytes)


class ShardedArena:
    """N independent arena shards behind the single-arena API, plus a
    manifest that makes the cross-shard generation atomic.

    Commit protocol (manifest-last, the NVTree ordering lifted one
    level):  1. drain every shard's write set — ALL shards' data
    regions, then all shards' metadata regions (the data-before-metadata
    barrier is global, not per shard);  2. commit each shard (flush
    file, bump its header generation, set its valid flag);  3. write the
    manifest.  A crash between shard commits leaves the manifest at the
    previous generation — exactly the generation every shard agrees on,
    which is what recovery reports.
    """

    def __init__(self, path: Optional[str], n_shards: int = 2,
                 synth_line_ns: float = 0.0, pack_flush_rows: int = 0,
                 commit_mode: str = "barrier", synth_fence_ns: float = 0.0,
                 paged: Optional[bool] = None, block_bytes: int = 4096,
                 cache_blocks: int = 1024,
                 integrity: Optional[bool] = None):
        assert n_shards >= 1
        assert commit_mode in ("barrier", "shadow")
        self.path = path
        self.n_shards = int(n_shards)
        # sidecars are declared at the SHARDED level (same router as
        # their source region, so a row's checksum lives on the row's
        # shard); shard sub-arenas must not re-derive their own
        self.integrity = integrity_enabled(integrity)
        # shard sub-arenas are pure persistence backends — the ONE block
        # cache (like the one volatile image it replaces) lives at the
        # sharded level, so shards are always opened unpaged
        self.shards = [Arena(f"{path}.s{k}" if path else None,
                             synth_line_ns, pack_flush_rows,
                             commit_mode=commit_mode, paged=False,
                             integrity=False)
                       for k in range(self.n_shards)]
        self.paged = paged_enabled(paged)
        self.block_bytes = int(block_bytes)
        self.cache_blocks = int(cache_blocks)
        self.cache = None
        if self.paged:
            from repro.core.paging import BlockCache
            self.cache = BlockCache(self.block_bytes, self.cache_blocks)
        for sh in self.shards:
            sh.synth_sleep = True
        self.synth_line_ns = synth_line_ns
        self.pack_flush_rows = pack_flush_rows
        self.commit_mode = commit_mode
        # the fence is a GLOBAL ordering point, so its synthetic stall
        # lives at the sharded level, never per shard
        self.synth_fence_ns = synth_fence_ns
        self.regions: Dict[str, ShardedRegion] = {}
        self.writeset = ShardedWriteSet(self)
        self.generation = 0
        self._epoch_depth = 0
        self._layout_final = False
        self._snap_providers: List = []
        self._local_stats = FlushStats()
        self._man: Optional[np.ndarray] = None
        self._rr = 0
        self._pool: Optional[ThreadPoolExecutor] = None

    # -- stats -------------------------------------------------------------
    @property
    def stats(self) -> FlushStats:
        """Aggregate of every shard's per-shard accounting (plus the
        manifest-level commit calls) — same FlushStats shape callers
        snapshot()/delta() on a plain arena."""
        out = self._local_stats.snapshot()
        for sh in self.shards:
            for f in dataclasses.fields(FlushStats):
                setattr(out, f.name,
                        getattr(out, f.name) + getattr(sh.stats, f.name))
        return out

    def shard_stats(self) -> List[FlushStats]:
        return [sh.stats.snapshot() for sh in self.shards]

    # -- epochs ------------------------------------------------------------
    @contextlib.contextmanager
    def epoch(self):
        self._epoch_depth += 1
        try:
            yield self
        finally:
            self._epoch_depth -= 1
            if self._epoch_depth == 0:
                self.writeset.flush()

    # -- layout ------------------------------------------------------------
    def region(self, name: str, dtype, shape: Tuple[int, ...],
               meta: Optional[bool] = None, router=None) -> ShardedRegion:
        assert not self._layout_final, "layout already finalized"
        assert name not in self.regions
        cls = ShardedRegion
        if self.cache is not None and _paged_eligible(
                name, meta, dtype, shape, self.block_bytes):
            from repro.core.paging import PagedShardedRegion
            cls = PagedShardedRegion
        r = cls(self, name, dtype, shape, meta=meta,
                router=router, rr_hint=self._rr)
        self._rr += 1
        self.regions[name] = r
        return r

    def region_shards(self, name: str, rows: np.ndarray) -> np.ndarray:
        return self.regions[name].shard_of[
            np.asarray(np.atleast_1d(rows), np.int64)].astype(np.int64)

    def finalize(self) -> None:
        assert not self._layout_final
        if self.integrity:
            self._integrity_layout()
        self._layout_final = True
        for sh in self.shards:
            sh.finalize()
        if self.path is None:
            self._man = np.zeros(64, np.uint8)
        else:
            mp = self.path + ".manifest"
            create = not os.path.exists(mp)
            if create:
                with open(mp, "wb") as f:
                    f.truncate(64)
            self._man = np.memmap(mp, dtype=np.uint8, mode="r+",
                                  shape=(64,))
            if create:
                self._write_manifest(valid=False)
            else:
                # the manifest records the shard count precisely so a
                # mis-configured reopen fails loudly instead of mapping
                # the wrong number of backing files
                raw = bytes(self._man[: struct.calcsize(_MAN_FMT)])
                magic, man_shards, man_gen, man_valid = \
                    struct.unpack(_MAN_FMT, raw)
                if magic == _MAN_MAGIC and man_shards != self.n_shards:
                    raise ValueError(
                        f"arena at {self.path!r} was committed with "
                        f"{man_shards} shards, opened with "
                        f"{self.n_shards}")
                if magic == _MAN_MAGIC and man_valid and man_gen > 0:
                    # a valid manifest promises every shard reached at
                    # least its generation (shards can only be AHEAD
                    # across a torn commit).  A shard behind it — or
                    # zeroed because the file vanished and was recreated
                    # above — is media loss, not power loss.
                    for k, sh in enumerate(self.shards):
                        if not (sh.header_valid()
                                and sh.header_generation() >= man_gen):
                            raise ShardLossError(
                                f"shard {k} ({sh.path!r}) lost or behind "
                                f"manifest generation {man_gen}")

    def _integrity_layout(self) -> None:
        """Sharded sidecars: one per covered region, SAME router as the
        source — a row and its checksum always commit through the same
        shard's header, so the cross-shard atomicity argument (manifest-
        last) covers them as a pair."""
        for name, r in list(self.regions.items()):
            if r.meta or r.snap or r.jrnl or r.integ or r.rowbytes % 8:
                continue
            sc = self.region(name + ".integ", np.int64,
                             (r.shape[0], _integ_chunks(r.rowbytes)),
                             meta=False, router=r.router)
            r._integ = sc
            for s in range(self.n_shards):
                if r.slices[s] is not None:
                    r.slices[s]._integ = sc.slices[s]

    def verify_header(self) -> None:
        """ManifestError on garbage manifest magic; delegate per-shard
        header checks to each shard."""
        raw = bytes(self._man[:4])
        if raw not in (_MAN_MAGIC, b"\x00\x00\x00\x00"):
            raise ManifestError(
                f"arena {self.path!r} manifest magic {raw!r} corrupt")
        for sh in self.shards:
            sh.verify_header()

    def _pimage(self, region: "ShardedRegion") -> np.ndarray:
        """Committed persistent image assembled across shards (home
        bytes + each shard's authoritative bank overlay) — pure read."""
        img = np.zeros(region.shape, region.dtype)
        for sl in region.slices:
            if sl is None:
                continue
            img[sl._gidx] = sl._pview()
            sh = sl.arena
            if sh.commit_mode == "shadow":
                mask = sh._shadow_masks[sh._shadow_auth_bank].get(sl.name)
                if mask is not None and mask.any():
                    rows = np.nonzero(mask)[0]
                    img[sl._gidx[rows]] = sh._shadow_mirror(
                        sl, sh._shadow_auth_bank)[rows]
        return img

    def verify_region(self, region) -> np.ndarray:
        if isinstance(region, str):
            region = self.regions[region]
        sc = region._integ
        if sc is None:
            return np.empty(0, np.int64)
        ck = sidecar_checksums(self._pimage(region), sc.shape[1])
        ref = self._pimage(sc)
        bad = (ref != 0) & (ck != ref)
        for sh in self.shards:
            sh.synth_read((region.nbytes + sc.nbytes) // self.n_shards)
        return np.nonzero(bad.any(axis=1))[0]

    def scrub(self, raise_on_error: bool = False
              ) -> Dict[str, np.ndarray]:
        bad: Dict[str, np.ndarray] = {}
        for name, r in self.regions.items():
            if r._integ is None:
                continue
            rows = self.verify_region(r)
            if rows.size:
                bad[name] = rows
        if bad and raise_on_error:
            name, rows = next(iter(bad.items()))
            raise CorruptLineError(name, rows,
                                   detail=f"scrub: {len(bad)} region(s)")
        return bad

    # -- order snapshots (DESIGN.md §10) -----------------------------------
    def add_snapshot_provider(self, fn) -> None:
        self._snap_providers.append(fn)

    # -- manifest / commit protocol ----------------------------------------
    def _write_manifest(self, valid: bool) -> None:
        man = struct.pack(_MAN_FMT, _MAN_MAGIC, self.n_shards,
                          self.generation, valid)
        self._man[: len(man)] = np.frombuffer(man, np.uint8)
        if isinstance(self._man, np.memmap):
            self._man.flush()

    def header_generation(self) -> int:
        raw = bytes(self._man[: struct.calcsize(_MAN_FMT)])
        magic, _, gen, _ = struct.unpack(_MAN_FMT, raw)
        return int(gen) if magic == _MAN_MAGIC else 0

    def header_valid(self) -> bool:
        raw = bytes(self._man[: struct.calcsize(_MAN_FMT)])
        magic, _, gen, valid = struct.unpack(_MAN_FMT, raw)
        if magic != _MAN_MAGIC or not valid:
            return False
        # the manifest seals generation `gen`; every shard must have
        # reached at least that far (shards ahead are torn territory the
        # structures' count-bounded recovery already handles)
        return all(sh.header_valid() and sh.header_generation() >= gen
                   for sh in self.shards)

    def _fence(self) -> None:
        """The global ordering point — one per barrier phase plus one
        per commit seal in barrier mode, exactly ONE per shadow commit."""
        self._local_stats.fences += 1
        if self.synth_fence_ns:
            ns = int(self.synth_fence_ns)
            self._local_stats.fence_ns += ns
            t0 = time.perf_counter_ns()
            while time.perf_counter_ns() - t0 < ns:
                pass

    def commit(self, _crash_after_shard: Optional[int] = None) -> None:
        """Drain write sets (global data-before-metadata in barrier
        mode), commit each shard, manifest LAST.  ``_crash_after_shard=k``
        is the crash-injection hook for the inter-shard commit window:
        shards 0..k commit, then power fails before the manifest — the
        fuzzer's sweep point (tests/test_sharded_arena.py).

        Shadow mode: fold every shard's previous bank home and drain the
        write set in one pooled phase (no cross-shard barrier), seal
        each shard's target bank, pay the SINGLE ordering point, then
        flip every shard's header and write the manifest last — the
        existing cross-shard atomicity protocol carries over unchanged.
        ``_crash_after_shard=-1`` crashes after the seals but before any
        flip (the torn-flip window's leading edge)."""
        if self.commit_mode == "shadow":
            if self.n_shards > 1:
                list(self.pool().map(lambda sh: sh._shadow_collapse(),
                                     self.shards))
            else:
                self.shards[0]._shadow_collapse()
            self.writeset.flush()
            for sh in self.shards:
                sh._shadow_seal()
                if isinstance(sh._mm, np.memmap):
                    sh._mm.flush()
            if _crash_after_shard is not None and _crash_after_shard < 0:
                self.crash()
                return
            self._fence()                  # the single ordering point
        else:
            self.writeset.flush()
            self._fence()
        tgt = self.generation + 1
        for k, sh in enumerate(self.shards):
            if isinstance(sh._mm, np.memmap):
                sh._mm.flush()
            sh.generation = tgt
            sh._write_header(valid=True)
            if isinstance(sh._mm, np.memmap):
                sh._mm.flush()
            if _crash_after_shard is not None and k == _crash_after_shard:
                self.crash()
                return
        self.generation = tgt
        self._write_manifest(valid=True)
        self._local_stats.calls += 1
        if self.commit_mode == "shadow":
            for sh in self.shards:
                sh._shadow_retire()

    def invalidate(self) -> None:
        self._write_manifest(valid=False)

    # -- crash simulation ---------------------------------------------------
    def crash(self) -> None:
        """Discard every shard's pending marks and the one volatile image
        per region (slices carry none).  The volatile buffer is a
        LONG-LIVED arena: it zeroes in place instead of reallocating, so
        the post-crash reload writes warm pages — allocator churn and
        page faults stay out of the recovery-critical path."""
        self.writeset.discard()
        for sh in self.shards:
            sh._shadow_discard()
        for r in self.regions.values():
            r._crash_reset()

    def reopen(self, concurrency: int = 1,
               exclude: Tuple[str, ...] = ()) -> None:
        """Reload every region's volatile copy from the shard files —
        per shard, in the flush pool when ``concurrency>1`` (the loads
        are big GIL-releasing copies, so N shards reopen in parallel) —
        then re-anchor the generation to the manifest's.  ``exclude``
        names regions the caller will load itself (RecoveryManager's
        per-region load stages)."""
        # shadow bank authority is the MANIFEST generation: a shard whose
        # header flipped ahead of a torn manifest write must still
        # overlay the manifest generation's bank (intact by parity, and
        # value-identical to its own already-folded home rows)
        man_gen = self.header_generation()
        for sh in self.shards:
            sh._shadow_parse(authority_gen=man_gen)
        regions = [r for n, r in self.regions.items() if n not in exclude]
        # paged regions reload lazily: one cheap block-pool reset, and
        # the post-crash working set faults in on demand
        for r in regions:
            if r.is_paged:
                r.load()
        regions = [r for r in regions if not r.is_paged]

        def load_shard(s: int) -> None:
            # one aggregated media stall per shard, not one per region
            with self.shards[s].stall_scope():
                for r in regions:
                    r.load_shard(s)

        if concurrency > 1 and self.n_shards > 1:
            list(self.pool().map(load_shard, range(self.n_shards)))
        else:
            for s in range(self.n_shards):
                load_shard(s)
        self.generation = max(self.generation, self.header_generation())

    # -- pool ---------------------------------------------------------------
    def pool(self) -> ThreadPoolExecutor:
        """Shared shard-flush/reopen pool.  Sized to the shard count, not
        the core count: flush stalls sleep (I/O-like), so more waiters
        than cores still overlap."""
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=self.n_shards,
                thread_name_prefix="arena-shard")
        return self._pool

    def close(self) -> None:
        for sh in self.shards:
            sh.close()
        if isinstance(self._man, np.memmap):
            self._man.flush()
        self._man = None
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None


def open_arena(path: Optional[str], layout: Dict[str, Tuple],
               n_shards: int = 1, **kw):
    """Create/open an arena with the given layout.  Layout values are
    ``(dtype, shape)`` or ``(dtype, shape, router)`` — the router steers
    rows across shards when ``n_shards > 1`` (route_rows documents the
    specs).  ``n_shards=1`` returns the plain single Arena: byte- and
    accounting-identical to the pre-sharding path."""
    a = Arena(path, **kw) if n_shards == 1 else \
        ShardedArena(path, n_shards=n_shards, **kw)
    for name, spec in layout.items():
        dtype, shape = spec[0], spec[1]
        router = spec[2] if len(spec) > 2 else None
        a.region(name, dtype, shape, router=router)
    a.finalize()
    return a
