"""Unified recovery subsystem (paper §V-F): vectorized chain primitives +
a dependency-ordered RecoveryManager that times every rebuild stage.

The paper's bargain is two-sided: persist fewer fields at write time, pay
to *recreate* them after a crash.  The write side batches through one
layer (core/writeset.py); this module is its mirror for the read side —
every crash-recovery path (pstruct structures, the serving engine, the
paged-KV allocator, the checkpoint manager) routes through it:

* ``chain_order`` / ``chain_lengths`` / ``chain_walk`` — shared vectorized
  pointer-jumping primitives (NumPy; Pallas variants live in
  ``kernels/chain_order.py``).  They replace the per-structure scalar
  ``while cur != NULL`` walks: recovery of a million-entry structure
  runs at hardware speed, not at Python-loop speed.  Two strategies sit
  behind one ``method=`` switch (DESIGN.md §8): pointer DOUBLING
  (binary-lifting tables, O(N log N), unbeatable while the tables fit
  in cache) and contraction-based LIST RANKING (sample every k-th row
  as a spine node, local-walk each spine segment, rank the ~N/k
  contracted chain with the same doubling tables, expand — O(N) gathers
  plus an O(N/k·log(N/k)) in-cache rank, which is what keeps the 10**6+
  chains of the north-star serving workload off the jump-table cache
  cliff).  ``method="auto"`` picks doubling below ``CONTRACT_MIN_N``
  and contraction at or above it.
* ``RecoveryManager`` — structures register their *pure* reconstructors
  (``core/reconstruct.py`` registry) under a name with declared
  dependencies (e.g. the serving engine depends on the request hashmap
  and the LRU page list).  ``recover()`` reopens the arenas once, does
  the generation/validity check once, runs the reconstructors in
  topological order, and times each stage into a ``RecoveryReport`` —
  the §V-F reconstruction-time metric, measured per stage.

``recover(concurrency=N)`` schedules stages by per-stage DEPENDENCY
COUNTERS in one thread pool: every stage starts the moment ITS OWN
declared dependencies land — not when its whole topological level does
(the level barrier the first concurrent implementation used; DESIGN.md
§7 has the scheduler diagram).  Recovery wall time approaches the
critical path over the dependency DAG instead of the serial stage sum
(the report carries all three — ``wall_ms`` / ``critical_path_ms`` /
``total_ms`` — and each StageReport carries ``ready_at``, the moment
its dependencies were satisfied, so queue wait and run time read
separately off the report).  Stage-completion callbacks
(``recover(on_stage=...)`` or ``add_listener``) fire the moment a stage
lands, which is how the serving engine admits traffic per slot before
the full report exists (DESIGN.md §6, "Concurrent recovery &
admission").  Sharded arenas reopen their shards in a pool of the same
width before any stage runs.

Reconstructors must be pure given the loaded persistent state: same
bytes => identical rebuilt volatile redundancy, which the torn-epoch
crash tests assert at every epoch boundary (tests/test_recovery.py)
and the crash-point fuzzer re-asserts through recover-crash-recover
double failures (tests/test_async_recovery.py) — purity is exactly
what makes a crash *during* recovery harmless.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import reconstruct
from repro.core.arena import IntegrityError

NULL = -1

__all__ = [
    "NULL", "chain_order", "chain_lengths", "chain_walk", "jump_tables",
    "chain_method", "ChainSnapshot", "CONTRACT_K", "CONTRACT_MIN_N",
    "CONTRACT_MIN_COUNT",
    "StageReport", "RecoveryReport", "Recoverable", "RecoveryManager",
]

# ----------------------------------------------------------------------
# Method selection (DESIGN.md §8).  Doubling's working set is its
# (bits, n) jump tables — past the cache it loses even to the scalar
# walk (the BENCH_recovery.json crossover this module used to report
# honestly at 10**6).  Contraction's working set is the ~n/k contracted
# chain; its full-array passes are O(n) total gathers, so it scales
# through the crossover.  The threshold is the measured flip point on
# the reference host (contraction wins from ~10**5 up; doubling keeps a
# small edge below, where its tables still fit and its fixed costs are
# lower), and CONTRACT_MIN_COUNT keeps tiny explicit-count walks — a
# handful of table levels — on the doubling path.
CONTRACT_K = 32              # spine sampling stride (id % k == 0)
CONTRACT_MIN_N = 1 << 17     # auto: contract at/above this table size
CONTRACT_MIN_COUNT = 32      # auto: explicit counts below stay doubling
_CONTRACT_WALK_HEADS = 64    # chain_walk: contract only for few heads
_WALK_ESCALATE_ROUNDS = 128  # chain_walk auto: level-sync rounds before
                             # escalating to contraction (chains proven
                             # longer than this pay the restart; short
                             # ones — the hashmap unlink — never do)


def chain_method(n: int, count: Optional[int] = None,
                 method: str = "auto") -> str:
    """Resolve a chain-primitive ``method=`` argument to "double" or
    "contract" (the auto heuristic, exported so recovery reports can
    name the path a rebuild actually took)."""
    if method != "auto":
        if method not in ("double", "contract"):
            raise ValueError(f"unknown chain method {method!r}")
        return method
    if n >= CONTRACT_MIN_N and (count is None or count >= CONTRACT_MIN_COUNT):
        return "contract"
    return "double"


# ======================================================================
# Vectorized chain primitives (pointer doubling / binary lifting)
# ======================================================================

def jump_tables(nxt: np.ndarray, bits: int) -> np.ndarray:
    """(bits, n) binary-lifting tables: ``jump[k][i]`` = node 2**k hops
    after i along ``nxt`` (NULL-absorbing).  A pointer outside [0, n) is
    a terminator, like NULL — recovery slices ``nxt`` at the committed
    fresh-water mark, so a link flushed by a torn epoch into uncommitted
    territory ends the chain instead of faulting.

    Tables are int32: node ids are region row indices (< 2**31), and
    halving the table bytes keeps the doubling gathers in cache — the
    difference between beating and losing to the scalar walk at 10**6
    entries (see BENCH_recovery.json)."""
    n = nxt.shape[0]
    jump = np.empty((bits, n), np.int32)
    jump[0] = np.where((nxt >= 0) & (nxt < n), nxt, NULL)
    for k in range(1, bits):
        prev_j = jump[k - 1]
        safe = np.where(prev_j >= 0, prev_j, 0)
        jump[k] = np.where(prev_j >= 0, prev_j[safe], NULL)
    return jump


def _sanitize32(nxt: np.ndarray) -> np.ndarray:
    """OOB pointers -> NULL, narrowed to int32 AFTER the 64-bit range
    check (a torn 2**32+3 must terminate, not alias node 3).  int32
    halves the bytes every random gather touches."""
    n = nxt.shape[0]
    return np.where((nxt >= 0) & (nxt < n), nxt, NULL).astype(np.int32)


def _absorb(jump: np.ndarray, cnt: np.ndarray,
            heads: np.ndarray) -> np.ndarray:
    """Pointer-doubling absorb: after r rounds ``jump[i]`` = node
    min(2**r, L(i)) hops after i (NULL once the chain ran out) and
    ``cnt[i]`` = the counts of those nodes summed, so 2**rounds > n
    rounds yield exact chain totals.  Seeding ``cnt`` with ones counts
    nodes (chain_lengths); seeding it with segment weights sums a
    contracted chain's hop counts (the list-ranking rank step).  Raises
    on a cycle reachable from ``heads`` (it never absorbs).  Pure:
    every round rebinds, the caller's arrays are never written."""
    n = jump.shape[0]
    for _ in range(max(1, int(n).bit_length())):   # 2**rounds > n
        live = jump >= 0
        if not live.any():
            break
        safe = np.where(live, jump, 0)
        cnt = cnt + np.where(live, cnt[safe], 0)
        jump = np.where(live, jump[safe], NULL)
    if (jump[heads] >= 0).any():
        raise RuntimeError("cycle in chain")
    return cnt[heads]


def chain_lengths(nxt: np.ndarray, heads: np.ndarray, *,
                  method: str = "auto",
                  k: Optional[int] = None) -> np.ndarray:
    """Length of the NULL-terminated chain starting at each head.

    Doubling: the `_absorb` invariant over the full array, O(n log n)
    work, fully vectorized — the parallel analogue of the seed's
    sequential ``_chain_len`` walk.  Contraction: local-walk the ~n/k
    spine segments (every head is promoted to a spine node), then
    `_absorb` the contracted chain seeded with segment weights —
    O(n) gathers + an in-cache rank.  Both raise on cycles (a cycle
    never absorbs into NULL, so its count exceeds n)."""
    heads = np.asarray(heads, np.int64)
    n = nxt.shape[0]
    if n == 0 or heads.size == 0:
        return np.zeros(heads.shape, np.int64)
    out = np.zeros(heads.shape, np.int64)
    # heads outside [0, n) are terminated chains (length 0), per the
    # module-wide OOB-pointer contract
    ok = (heads >= 0) & (heads < n)
    if chain_method(n, None, method) == "contract":
        nxt32 = _sanitize32(np.asarray(nxt))
        spine, spine_pos, cnext, w = _contract(nxt32, heads[ok],
                                               k or CONTRACT_K)
        lens = _absorb(cnext, w, spine_pos[heads[ok]])
        if (lens > n).any():
            # a poisoned (spine-free-cycle) segment on some head's chain
            raise RuntimeError("cycle in chain")
        out[ok] = lens
        return out
    # int32 working arrays for the same cache reasons as jump_tables
    jump = _sanitize32(np.asarray(nxt))
    out[ok] = _absorb(jump, np.ones(n, np.int32), heads[ok])
    return out


class ChainSnapshot:
    """A candidate node order seeded from a committed incremental order
    snapshot (DESIGN.md §10), handed to ``chain_order(snapshot=...)``.

    The candidate is NEVER trusted: adoption requires one O(count)
    vectorized verification pass against the committed NEXT chain —
    ``cand[0] == head`` and ``nxt[cand[i]] == cand[i+1]`` for every
    position.  NEXT is a function of the node id, so a candidate that
    verifies is *mathematically* the chain_order output: bit-identical
    recovery whether the snapshot was used or not, in every torn-write
    scenario the crash fuzzer can produce.  Any mismatch (torn snapshot
    record, stale ring rows, crash inside the commit window) silently
    falls back to the full contraction/doubling rank.

    ``outcome`` is filled by chain_order — "snapshot" on adoption, else
    the fallback method name ("contract"/"double") — and ``replayed``
    is the suffix length the seed had to local-walk (set by the
    structure that built the candidate; reset to the full count on
    fallback), which is what RecoveryManager stage details report."""

    def __init__(self, candidate: np.ndarray, replayed: int = 0):
        self.candidate = np.asarray(candidate, np.int64).ravel()
        self.replayed = int(replayed)
        self.outcome: Optional[str] = None


def _snapshot_verify(nxt: np.ndarray, head: int, count: Optional[int],
                     cand: np.ndarray) -> bool:
    """True iff `cand` IS chain_order(nxt, head, count) — one pass of
    O(count) vectorized gathers, no scalar loop."""
    if count is None or cand.size != count:
        return False
    n = nxt.shape[0]
    if int(cand[0]) != int(head):
        return False
    if ((cand < 0) | (cand >= n)).any():
        return False
    if count > 1 and not np.array_equal(
            np.asarray(nxt)[cand[:-1]], cand[1:]):
        return False
    return True


def chain_order(nxt: np.ndarray, head: int, count: Optional[int] = None,
                *, method: str = "auto", k: Optional[int] = None,
                snapshot: Optional[ChainSnapshot] = None) -> np.ndarray:
    """node-at-position for positions 0..count-1.

    ``count=None`` derives the length first (one lifting descent off the
    doubling tables, or the contracted rank — cycle-detected either
    way); recovery paths that persist an explicit count (the DLL header)
    pass it instead — a stale-but-committed count then bounds the walk
    to the committed prefix, which is exactly the torn-epoch recovery
    guarantee.

    ``method`` — "double" (binary lifting, O(N log N) fully vectorized),
    "contract" (sample/contract/rank/expand list ranking, O(N) gathers +
    an O(N/k log(N/k)) in-cache rank), or "auto" (`chain_method`).

    A head outside [0, n) — NULL, or a HEAD field flushed by a torn
    epoch past the committed fresh-water mark — is a terminated chain:
    empty order, per the module-wide OOB-pointer contract."""
    n = nxt.shape[0]
    if head < 0 or head >= n:
        return np.empty(0, np.int64)
    if count == 0:
        return np.empty(0, np.int64)
    if snapshot is not None:
        if _snapshot_verify(nxt, head, count, snapshot.candidate):
            snapshot.outcome = "snapshot"
            return snapshot.candidate.copy()
        # verification failed: the snapshot lied about the committed
        # chain — fall back to the full rank and report it
        snapshot.outcome = chain_method(n, count, method)
        snapshot.replayed = int(count or 0)
    if chain_method(n, count, method) == "contract":
        return _order_contract(np.asarray(nxt), head, count,
                               k or CONTRACT_K)
    if count is None:
        # tables deep enough to absorb any valid chain; the length
        # derivation below and the position walk share this ONE build
        bits = max(1, int(n).bit_length())       # 2**bits > n
    else:
        bits = max(1, int(np.ceil(np.log2(max(count, 2)))))
    jump = jump_tables(np.asarray(nxt, np.int64), bits)
    if count is None:
        # read the length off the tables: descend from the top bit,
        # taking every jump that does not absorb — the hop count is the
        # tail position
        cur, tail_pos = head, 0
        for b in reversed(range(bits)):
            nb = int(jump[b][cur])
            if nb != NULL:
                tail_pos += 1 << b
                cur = nb
        count = tail_pos + 1
        if count > n:
            raise RuntimeError("cycle in chain")
    # int32 throughout the position walk (row ids < 2**31): mixed-dtype
    # masked gathers cost ~3x at 10**6 entries.  Only the low
    # (count-1).bit_length() table levels can set a position bit, so the
    # walk skips the deeper levels a count=None derivation built.
    pos = np.arange(count, dtype=np.int32)
    cur = np.full(count, head, np.int32)
    dead = np.zeros(count, bool)   # absorbed into NULL: count overran
    for b in range(min(bits, int(count - 1).bit_length())):
        m = ((pos >> b) & 1 == 1) & ~dead
        if m.any():
            cur[m] = jump[b][cur[m]]
            dead |= cur == NULL
    if dead.any():
        # an explicit count larger than the chain: fail loudly instead
        # of letting NULL wrap around as a numpy negative index
        raise ValueError("count exceeds chain length")
    return cur.astype(np.int64)


def chain_walk(nxt: np.ndarray, heads: np.ndarray, *,
               method: str = "auto",
               k: Optional[int] = None) -> np.ndarray:
    """Materialize many chains at once: (H, Lmax) member matrix, row h =
    nodes of the chain starting at heads[h] in order, NULL-padded.

    Level-synchronous by default — one vectorized round per chain
    *position*, all chains advanced together (the batched-probe idiom
    from hashmap._find_slots), so rounds = max chain length, not total
    nodes.  That is the right shape for many short chains (the hashmap's
    bucket unlink); for a FEW chains over a huge table (rounds = chain
    length, each round a tiny gather) the contraction path ranks all
    chains off one shared contraction instead.  "auto" ESCALATES rather
    than guesses — chain length isn't knowable up front, and routing a
    short-chain unlink on a big table to contraction's O(n) passes
    would regress the serving hot path — so it walks level-sync and
    restarts on the contraction path only once the chains have proven
    longer than _WALK_ESCALATE_ROUNDS (the discarded rounds are a few
    tiny gathers; the escalated case saves full-chain-length rounds)."""
    heads = np.asarray(heads, np.int64)
    n = nxt.shape[0]
    if method != "auto":
        method = chain_method(n, None, method)   # validates the string
    if method == "contract":
        return _walk_contract(np.asarray(nxt), heads, k or CONTRACT_K)
    escalate = (method == "auto" and n >= CONTRACT_MIN_N
                and 0 < heads.size <= _CONTRACT_WALK_HEADS)
    cols: List[np.ndarray] = []
    cur = np.where((heads >= 0) & (heads < n), heads, NULL)
    while (cur != NULL).any():
        if escalate and len(cols) >= _WALK_ESCALATE_ROUNDS:
            return _walk_contract(np.asarray(nxt), heads, k or CONTRACT_K)
        cols.append(cur.copy())
        safe = np.where(cur != NULL, cur, 0)
        cur = np.where(cur != NULL, nxt[safe], NULL)
        cur = np.where((cur >= 0) & (cur < n), cur, NULL)
        if len(cols) > n:
            raise RuntimeError("cycle in chain")
    if not cols:
        return np.empty((heads.shape[0], 0), np.int64)
    return np.stack(cols, axis=1)


# ======================================================================
# Contraction-based list ranking (sample / contract / rank / expand)
# ======================================================================

def _contract(nxt32: np.ndarray, extra_heads: np.ndarray, k: int
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Sample + local-walk steps of the list ranking (DESIGN.md §8).

    Spine nodes are every row with ``id % k == 0`` plus every in-range
    head (deterministic — no RNG in a recovery path, and the device
    variant can test membership with arithmetic alone).  Each spine
    node's SEGMENT is itself plus the non-spine nodes after it, up to
    the next spine node or the chain end; the local walk advances all
    segments together, retiring lanes as they arrive (compacted each
    round, so total gather work is O(n) — the sum of segment lengths —
    not rounds x lanes).

    Returns ``(spine, spine_pos, cnext, w)``: spine row ids, the (n,)
    id -> spine-index map (NULL off-spine), the contracted next pointer
    (spine-index space, NULL-terminated) and the segment weights
    (nodes per segment).  A cycle that contains a spine node shows up
    as a cycle in ``cnext`` (the rank step detects it); a spine-FREE
    cycle would spin the local walk forever, so after n rounds the
    stuck lanes are closed with a POISON weight of n+1 — any length
    summed through them exceeds n, which is exactly the condition the
    callers already treat as "cycle in chain".  Walks that never need
    the poisoned segment (an explicit committed count that stops short
    of torn territory) stay unaffected, matching the doubling path."""
    n = nxt32.shape[0]
    spine = np.arange(0, n, k, dtype=np.int64)
    extra = extra_heads[(extra_heads >= 0) & (extra_heads < n)]
    extra = np.unique(extra[extra % k != 0])
    if extra.size:
        spine = np.concatenate([spine, extra])
    S = spine.size
    spine_pos = np.full(n, NULL, np.int32)
    spine_pos[spine] = np.arange(S, dtype=np.int32)
    cnext = np.full(S, NULL, np.int32)
    w = np.ones(S, np.int64)
    lanes = np.arange(S)
    cur = nxt32[spine]
    for _ in range(n + 1):       # a legit segment closes within n hops
        if not lanes.size:
            break
        alive = cur >= 0
        sp = np.full(lanes.size, NULL, np.int32)
        sp[alive] = spine_pos[cur[alive]]
        arrived = sp >= 0
        if arrived.any():
            cnext[lanes[arrived]] = sp[arrived]
        keep = alive & ~arrived
        lanes = lanes[keep]
        cur = cur[keep]
        if lanes.size:
            w[lanes] += 1
            cur = nxt32[cur]
    if lanes.size:               # spine-free cycle: poison, don't raise
        w[lanes] = n + 1
    return spine, spine_pos, cnext, w


def _rank_expand(nxt32: np.ndarray, spine: np.ndarray, cjump: np.ndarray,
                 w: np.ndarray, hpos: int, count: int) -> np.ndarray:
    """Rank + expand steps: order of the chain starting at spine index
    ``hpos``, positions 0..count-1.

    Rank: ``cjump`` — the EXISTING binary-lifting tables, built ONCE by
    the caller over the contracted chain (a (bits, S) working set that
    stays in cache, shared across heads in the multi-head walk) — walks
    spine-at-contracted-position exactly like chain_order's position
    walk; the exclusive cumsum of segment weights turns contracted
    positions into global start positions.  Expand: re-walk only the
    segments whose start lands inside [0, count) — emitting straight
    into the output, so total work is count gathers + count scatters."""
    S = cjump.shape[1]
    cap = min(count, S)
    pos = np.arange(cap, dtype=np.int32)
    curq = np.full(cap, hpos, np.int32)
    dead = np.zeros(cap, bool)
    for b in range(min(cjump.shape[0], int(cap - 1).bit_length())):
        m = ((pos >> b) & 1 == 1) & ~dead
        if m.any():
            curq[m] = cjump[b][curq[m]]
            dead |= curq == NULL
    wq = np.where(dead, 0, w[np.where(dead, 0, curq)])
    g = np.concatenate([[0], np.cumsum(wq)[:-1]])   # global start of q
    use = ~dead & (g < count)
    starts = g[use]
    take = np.minimum(wq[use], count - starts)
    if int(take.sum()) != count:
        # the contracted chain ran out before covering count positions —
        # same contract as the doubling walk's dead check
        raise ValueError("count exceeds chain length")
    out = np.empty(count, np.int64)
    cur = spine[curq[use]].astype(np.int32)
    posn = starts.copy()
    rem = take.copy()
    while cur.size:
        out[posn] = cur
        rem -= 1
        kp = rem > 0
        cur = nxt32[cur[kp]]
        posn = posn[kp] + 1
        rem = rem[kp]
    return out


def _order_contract(nxt: np.ndarray, head: int, count: Optional[int],
                    k: int) -> np.ndarray:
    """chain_order via contraction: the full sample / contract / rank /
    expand pipeline for one head (head already validated in-range)."""
    n = nxt.shape[0]
    nxt32 = _sanitize32(nxt)
    spine, spine_pos, cnext, w = _contract(
        nxt32, np.asarray([head], np.int64), k)
    hpos = int(spine_pos[head])
    if count is None:
        count = int(_absorb(cnext, w, np.asarray([hpos]))[0])
        if count > n:
            raise RuntimeError("cycle in chain")
    cjump = _contract_tables(cnext, min(count, spine.shape[0]))
    return _rank_expand(nxt32, spine, cjump, w, hpos, count)


def _contract_tables(cnext: np.ndarray, cap: int) -> np.ndarray:
    """Binary-lifting tables over the contracted chain, deep enough for
    a position walk of ``cap`` contracted positions."""
    bits = max(1, int(np.ceil(np.log2(max(cap, 2)))))
    return jump_tables(cnext.astype(np.int64), bits)


def _walk_contract(nxt: np.ndarray, heads: np.ndarray,
                   k: int) -> np.ndarray:
    """chain_walk via ONE shared contraction: every head is a spine
    node, so each chain's rank+expand reads the same contracted tables
    (built once, deep enough for the longest chain); the per-head
    Python loop runs over the FEW heads this path is selected for,
    each iteration fully vectorized."""
    n = nxt.shape[0]
    nxt32 = _sanitize32(nxt)
    spine, spine_pos, cnext, w = _contract(nxt32, heads, k)
    ok = (heads >= 0) & (heads < n)
    lens = np.zeros(heads.shape, np.int64)
    lens[ok] = _absorb(cnext, w, spine_pos[heads[ok]])
    if (lens > n).any():
        raise RuntimeError("cycle in chain")
    lmax = int(lens.max()) if lens.size else 0
    out = np.full((heads.shape[0], lmax), NULL, np.int64)
    if lmax:
        cjump = _contract_tables(cnext, min(lmax, spine.shape[0]))
        for h in range(heads.shape[0]):
            if lens[h]:
                out[h, :lens[h]] = _rank_expand(
                    nxt32, spine, cjump, w,
                    int(spine_pos[heads[h]]), int(lens[h]))
    return out


# ======================================================================
# Recovery reports
# ======================================================================

@dataclass
class StageReport:
    """One timed rebuild stage (§V-F reconstruction-time row).

    ``t_start`` / ``t_end`` are wall-clock offsets (seconds) from the
    start of the recovery pass, so a concurrent recovery's timeline can
    be read off the report: overlapping [t_start, t_end) intervals are
    stages that ran in parallel.  ``ready_at`` is the offset at which
    the stage's declared dependencies were all satisfied — the moment
    the dependency-counter scheduler queued it — so
    ``t_start - ready_at`` is pure queue wait (pool contention), split
    from run time in BENCH_recovery.json."""
    name: str
    seconds: float
    detail: Dict[str, Any] = field(default_factory=dict)
    t_start: float = 0.0
    t_end: float = 0.0
    ready_at: float = 0.0
    # Salvage-mode outcome (DESIGN.md §13): ``quarantined`` — the stage
    # tripped on media corruption and its structure is untrusted;
    # ``degraded`` — the stage ran on partial inputs (a dependency was
    # quarantined) or salvaged around corrupt rows itself.
    quarantined: bool = False
    degraded: bool = False

    @property
    def queue_wait(self) -> float:
        return max(0.0, self.t_start - self.ready_at)

    def as_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "seconds": self.seconds,
                "t_start": self.t_start, "t_end": self.t_end,
                "ready_at": self.ready_at, "queue_wait": self.queue_wait,
                "quarantined": self.quarantined, "degraded": self.degraded,
                **self.detail}


@dataclass
class RecoveryReport:
    """Per-stage timing + validity of one recovery pass.  Produced by
    RecoveryManager and by ckpt.CheckpointManager.restore — the one
    report format every recovery path shares.

    Three times tell the concurrency story:

    * ``total_ms``         — summed per-stage seconds (serial work);
    * ``critical_path_ms`` — longest dependency chain (the floor any
      concurrency can reach);
    * ``wall_ms``          — what this pass actually took.

    ``total_seconds`` remains the wall-clock duration of the pass
    (``wall_ms / 1000``) for existing call sites."""
    valid: bool = True
    generation: int = 0
    total_seconds: float = 0.0
    concurrency: int = 1
    critical_path_seconds: float = 0.0
    stages: List[StageReport] = field(default_factory=list)
    # salvage mode (DESIGN.md §13): stage names that tripped on media
    # corruption / ran degraded on partial inputs during this pass
    quarantined: List[str] = field(default_factory=list)
    degraded: List[str] = field(default_factory=list)

    @property
    def wall_ms(self) -> float:
        return self.total_seconds * 1e3

    @property
    def total_ms(self) -> float:
        return sum(s.seconds for s in self.stages) * 1e3

    @property
    def critical_path_ms(self) -> float:
        return self.critical_path_seconds * 1e3

    def add(self, name: str, seconds: float, **detail: Any) -> "StageReport":
        st = StageReport(name, seconds, dict(detail))
        self.stages.append(st)
        return st

    def stage(self, name: str) -> Optional[StageReport]:
        for st in self.stages:
            if st.name == name:
                return st
        return None

    def seconds(self, name: str) -> float:
        st = self.stage(name)
        return st.seconds if st is not None else 0.0

    def as_dict(self) -> Dict[str, Any]:
        return {"valid": self.valid, "generation": self.generation,
                "total_seconds": self.total_seconds,
                "concurrency": self.concurrency,
                "wall_ms": self.wall_ms, "total_ms": self.total_ms,
                "critical_path_ms": self.critical_path_ms,
                "quarantined": list(self.quarantined),
                "degraded": list(self.degraded),
                "stages": [s.as_dict() for s in self.stages]}


# ======================================================================
# RecoveryManager
# ======================================================================

@dataclass(frozen=True)
class Recoverable:
    name: str
    reconstructor: str          # name in the core.reconstruct registry
    target: Any                 # object handed to the reconstructor
    depends: Tuple[str, ...] = ()
    # Arena regions the reconstructor reads (beyond what its `depends`
    # already rebuilt).  On a SHARDED arena, declared regions become
    # per-region load stages in the dependency-counter scheduler: this
    # stage starts the moment ITS regions are loaded, overlapping the
    # other regions' shard loads with its rebuild (DESIGN.md §7).
    # None = unknown (conservative: waits for every load); () = reads no
    # regions directly (only its dependencies' outputs).
    regions: Optional[Tuple[str, ...]] = None


class RecoveryManager:
    """Dependency-ordered, timed crash recovery.

    Usage::

        mgr = RecoveryManager(engine.arena, paging.arena)
        mgr.add("req_table", "pstruct.hashmap", engine.table)
        mgr.add("lru", "pstruct.dll", paging.lru)
        mgr.add("pages", "serve.paged_alloc", paging, depends=("lru",))
        mgr.add("engine", "serve.engine", engine,
                depends=("req_table", "pages"))
        report = mgr.recover()

    ``recover()`` reopens every arena once (the generation/validity check
    happens here, not in each structure), then runs the registered pure
    reconstructors in topological order, timing each into the report.
    ``recover(concurrency=N)`` runs the independent stages of each
    topological level in a thread pool of N workers; the report's stage
    list stays in deterministic (level-major, registration) order no
    matter which thread finished first, so serial and concurrent passes
    produce equivalent reports modulo timing fields.
    """

    def __init__(self, *arenas: Any):
        # dedupe by identity: callers pass each structure's arena and
        # several structures often share one (e.g. the engine's table
        # and allocator) — a duplicate would reopen it twice and count
        # its block-fault deltas twice
        seen: set = set()
        self.arenas = []
        for a in arenas:
            if a is not None and id(a) not in seen:
                seen.add(id(a))
                self.arenas.append(a)
        self._items: Dict[str, Recoverable] = {}
        self._listeners: List[Callable[[StageReport], None]] = []

    # ------------------------------------------------------------- setup
    def add(self, name: str, reconstructor: str, target: Any,
            depends: Sequence[str] = (),
            regions: Optional[Sequence[str]] = None) -> "RecoveryManager":
        if name in self._items:
            raise ValueError(f"recoverable {name!r} already registered")
        if reconstructor not in reconstruct.names():
            raise KeyError(f"unknown reconstructor {reconstructor!r}")
        self._items[name] = Recoverable(
            name, reconstructor, target, tuple(depends),
            tuple(regions) if regions is not None else None)
        return self

    def add_listener(self, fn: Callable[[StageReport], None]
                     ) -> "RecoveryManager":
        """Register a stage-completion callback: ``fn(stage_report)`` is
        invoked the moment each stage (including "reopen") lands — from
        the completing worker thread under ``recover(concurrency>1)``,
        serialized by the manager's lock either way."""
        self._listeners.append(fn)
        return self

    def levels(self) -> List[List[str]]:
        """Topological *levels* over declared dependencies: level k holds
        every item whose dependencies all sit in levels < k, stable in
        registration order within a level.  Items of one level are
        mutually independent — the unit of stage concurrency."""
        items = self._items
        for it in items.values():
            for dep in it.depends:
                if dep not in items:
                    raise KeyError(
                        f"recoverable {it.name!r} depends on unregistered "
                        f"{dep!r}")
        done: set = set()
        out: List[List[str]] = []
        pending = list(items)
        while pending:
            ready = [n for n in pending
                     if all(d in done for d in items[n].depends)]
            if not ready:
                raise ValueError(f"dependency cycle among {pending}")
            out.append(ready)
            done.update(ready)
            pending = [n for n in pending if n not in done]
        return out

    def order(self) -> List[str]:
        """Topological order over declared dependencies, stable in
        registration order among ready items (levels, flattened)."""
        return [n for level in self.levels() for n in level]

    # ----------------------------------------------------------- recover
    def recover(self, reopen: bool = True, concurrency: int = 1,
                on_stage: Optional[Callable[[StageReport], None]] = None,
                salvage: bool = False) -> RecoveryReport:
        """``salvage=True`` (DESIGN.md §13) turns media corruption from
        a recovery abort into degraded-mode recovery: a stage that trips
        on an ``IntegrityError`` is QUARANTINED (reported, not raised),
        its transitive dependents are skipped as DEGRADED, and every
        structure off the corrupt dependency chain still rebuilds.
        Reconstructors see ``arena._salvage == True`` for the duration
        and may verify their own regions / drop provably-corrupt rows,
        reporting ``degraded`` / ``quarantined`` through their detail
        dict.  Default recovery stays trusting — detection is scrub's
        and the paged fault path's job, not the hot recovery path's."""
        t_all = time.perf_counter()
        report = RecoveryReport(concurrency=max(1, int(concurrency)))
        lock = threading.Lock()
        listeners = list(self._listeners)
        if on_stage is not None:
            listeners.append(on_stage)

        def emit(st: StageReport) -> None:
            with lock:
                for fn in listeners:
                    fn(st)

        order = self.order()            # validates deps / detects cycles
        items = self._items

        # Sharded arenas: regions a stage DECLARES become per-region
        # load stages, so its rebuild starts the moment its own regions
        # land instead of barriering on the whole reopen (DESIGN.md §7).
        # region name -> every sharded arena's region of that name (two
        # arenas MAY carry same-named regions; the load stage reloads
        # them all, and each arena's reopen excludes exactly the names
        # it contributed)
        split: Dict[str, List[Any]] = {}
        if reopen and any(it.regions for it in items.values()):
            declared = {r for it in items.values() for r in it.regions or ()}
            for a in self.arenas:
                if getattr(a, "n_shards", 1) > 1:
                    for rname, r in a.regions.items():
                        # small regions (headers) load in the prologue:
                        # a sub-ms load isn't worth a scheduler slot,
                        # and a header queued behind bulk loads would
                        # gate its structure's rebuild on THEIR finish
                        if rname in declared and r.nbytes >= 1 << 16:
                            split.setdefault(rname, []).append(r)
        # biggest loads first: a large region usually feeds the longest
        # rebuild, so its load must clear the pool earliest for that
        # rebuild's start time — the quantity the wall clock follows —
        # to beat the serial-reopen baseline
        load_order = sorted(
            split, key=lambda r: (-max(x.nbytes for x in split[r]), r))
        load_names = [f"load:{r}" for r in load_order]

        reopen_secs = 0.0
        if reopen and self.arenas:
            t0 = time.perf_counter()
            valids = []
            for a in self.arenas:
                if getattr(a, "n_shards", 1) > 1:
                    # pooled shard reload of whatever the load stages
                    # below don't cover (GIL-releasing block copies)
                    a.reopen(concurrency=report.concurrency,
                             exclude=tuple(
                                 n for n, rs in split.items()
                                 if any(r.arena is a for r in rs)))
                else:
                    a.reopen()
                # garbage header/manifest magic is media corruption no
                # power loss can produce — fail typed (ManifestError)
                # before trusting the generation it claims, salvage or
                # not (with no trustworthy generation there is no
                # committed prefix to salvage toward)
                if hasattr(a, "verify_header"):
                    a.verify_header()
                valids.append(bool(a.header_valid()))
            reopen_secs = time.perf_counter() - t0
            st = report.add("reopen", reopen_secs,
                            arenas=len(self.arenas), valid=valids,
                            shards=[getattr(a, "n_shards", 1)
                                    for a in self.arenas],
                            # which commit protocol the recovered bytes
                            # came through: "shadow" means reopen also
                            # selected the committed remap bank and
                            # discarded orphans from any torn flip (§9)
                            modes=[getattr(a, "commit_mode", "barrier")
                                   for a in self.arenas])
            st.t_start, st.t_end = 0.0, reopen_secs
            report.valid = all(valids)
            # the committed (persisted) generation — survives recovery in
            # a fresh process, unlike the in-memory commit counter
            report.generation = max(a.header_generation()
                                    for a in self.arenas)
            emit(st)

        results: Dict[str, StageReport] = {}
        ready_at: Dict[str, float] = {n: reopen_secs for n in load_names}
        # a stage's load prerequisites: its declared regions' load
        # stages; an undeclared (regions=None) stage conservatively
        # waits for every load
        load_deps = {
            n: (load_names if items[n].regions is None
                else [f"load:{r}" for r in items[n].regions if r in split])
            for n in order}
        for n in order:
            if not items[n].depends and not load_deps[n]:
                ready_at[n] = reopen_secs

        # paged arenas (DESIGN.md §12): per-stage block-fault deltas make
        # demand-paged recovery visible — load: stages of paged regions
        # are free resets, and the faults attribute to whichever
        # reconstructor actually touched the blocks.  Under concurrent
        # recovery simultaneous stages share the counters, so per-stage
        # attribution is approximate (the TOTAL across stages is exact).
        caches = [a.cache for a in self.arenas
                  if getattr(a, "cache", None) is not None]

        def _cache_faults() -> int:
            return sum(c.faults for c in caches)

        # salvage bookkeeping: stages whose output is untrusted (they
        # tripped on corruption, or ran downstream of one that did).
        # Mutated inside run_stage BEFORE its future resolves, so both
        # schedulers see a dependency's taint before any dependent runs.
        tainted: set = set()
        if salvage:
            for a in self.arenas:
                a._salvage = True
                for sh in getattr(a, "shards", ()):
                    sh._salvage = True

        def run_stage(name: str) -> StageReport:
            t0 = time.perf_counter()
            faults0 = _cache_faults() if caches else 0
            bad_deps = sorted(d for d in depends_of.get(name, ())
                              if d in tainted)
            if salvage and bad_deps:
                # skipped, not failed: the stage itself is healthy but
                # its inputs are quarantined — running it would serve
                # reconstructed garbage
                tainted.add(name)
                st = StageReport(name, 0.0,
                                 {"skipped": "quarantined dependency",
                                  "tainted_deps": bad_deps},
                                 t_start=t0 - t_all,
                                 t_end=time.perf_counter() - t_all,
                                 ready_at=ready_at.get(name, reopen_secs),
                                 degraded=True)
                emit(st)
                return st
            try:
                if name.startswith("load:"):
                    regions = split[name[5:]]
                    for region in regions:
                        region.load(concurrency=report.concurrency)
                    secs = time.perf_counter() - t0
                    detail = {"rows": sum(int(r.shape[0]) for r in regions),
                              "shards": int(regions[0].arena.n_shards)}
                else:
                    it = items[name]
                    out, secs = reconstruct.run(it.reconstructor, it.target)
                    detail = dict(out) if isinstance(out, dict) else {}
                    detail.setdefault("reconstructor", it.reconstructor)
            except IntegrityError as e:
                if not salvage:
                    raise
                tainted.add(name)
                t1 = time.perf_counter()
                st = StageReport(name, t1 - t0,
                                 {"error": type(e).__name__,
                                  "message": str(e)},
                                 t_start=t0 - t_all, t_end=t1 - t_all,
                                 ready_at=ready_at.get(name, reopen_secs),
                                 quarantined=True)
                emit(st)
                return st
            # a reconstructor may partially salvage on its own: it drops
            # corrupt rows, keeps the rest, and reports through detail
            quarantined = bool(detail.pop("quarantined", False))
            degraded = bool(detail.pop("degraded", False))
            if quarantined:
                tainted.add(name)
            if caches:
                detail["block_faults"] = _cache_faults() - faults0
            t1 = time.perf_counter()
            st = StageReport(name, secs, detail,
                             t_start=t0 - t_all, t_end=t1 - t_all,
                             ready_at=ready_at.get(name, reopen_secs),
                             quarantined=quarantined, degraded=degraded)
            emit(st)
            return st

        full_order = load_names + order
        depends_of = {n: [] for n in load_names}
        depends_of.update({n: list(items[n].depends) + load_deps[n]
                           for n in order})
        try:
            if report.concurrency == 1:
                # serial: topological order; a stage is "ready" the moment
                # its last dependency finished
                for name in full_order:
                    st = run_stage(name)
                    results[name] = st
                    for m in full_order:
                        if name in depends_of[m]:
                            ready_at[m] = max(ready_at.get(m, 0.0), st.t_end)
            else:
                self._run_counters(full_order, depends_of, run_stage,
                                   results, ready_at, report.concurrency,
                                   t_all)
        finally:
            if salvage:
                for a in self.arenas:
                    a._salvage = False
                    for sh in getattr(a, "shards", ()):
                        sh._salvage = False
        # deterministic report order — loads first, then level-major
        # stages — whatever the completion order was
        report.stages.extend(results[n] for n in full_order
                             if n in results)
        report.quarantined = [s.name for s in report.stages
                              if s.quarantined]
        report.degraded = [s.name for s in report.stages if s.degraded]
        report.total_seconds = time.perf_counter() - t_all
        report.critical_path_seconds = reopen_secs + self._critical_path(
            full_order, depends_of,
            {s.name: s.seconds for s in report.stages})
        return report

    def _run_counters(self, order: List[str], depends_of: Dict[str, List[str]],
                      run_stage, results, ready_at,
                      concurrency: int, t_all: float) -> None:
        """Dependency-counter scheduler: one pool for the whole DAG
        (region-load stages included); a stage is submitted the instant
        its own dependency counter hits zero — no level barrier, so a
        fast chain races ahead of a slow sibling (DESIGN.md §7).
        Dependents of a failed stage are never scheduled; the earliest
        failure (in deterministic topological order) re-raises once
        in-flight stages drain."""
        remaining = {n: len(depends_of[n]) for n in order}
        dependents: Dict[str, List[str]] = {n: [] for n in order}
        for n in order:
            for d in depends_of[n]:
                dependents[d].append(n)
        errors: Dict[str, BaseException] = {}
        # RLock: a future that finishes before its done-callback attaches
        # runs the callback INLINE in the submitting thread, which may
        # already hold the scheduler lock
        done_cv = threading.Condition(threading.RLock())
        outstanding = [0]
        # an inline callback can also fire MID-submission-loop: it runs
        # finished() for the stage just submitted, which may drop a
        # LATER loop stage's counter to zero and submit it before the
        # loop reaches it — the loop's own remaining==0 check would then
        # submit it AGAIN, and the duplicate completion double-decrements
        # its dependents (a stage could start before a sibling dep
        # finished).  `submitted` makes submission idempotent.
        submitted: set = set()

        with ThreadPoolExecutor(max_workers=concurrency) as ex:
            def submit(name: str) -> None:
                if name in submitted:
                    return
                submitted.add(name)
                outstanding[0] += 1
                fut = ex.submit(run_stage, name)
                fut.add_done_callback(
                    lambda f, n=name: finished(n, f))

            def finished(name: str, fut) -> None:
                with done_cv:
                    try:
                        results[name] = fut.result()
                    except BaseException as e:   # noqa: BLE001
                        errors[name] = e
                    now = time.perf_counter() - t_all
                    if name not in errors:
                        for m in dependents[name]:
                            remaining[m] -= 1
                            ready_at[m] = max(ready_at.get(m, 0.0), now)
                            if remaining[m] == 0:
                                submit(m)
                    outstanding[0] -= 1
                    done_cv.notify_all()

            with done_cv:
                for n in order:
                    if remaining[n] == 0:
                        submit(n)
                while outstanding[0] > 0:
                    done_cv.wait()
        if errors:
            raise errors[min(errors, key=order.index)]

    def _critical_path(self, order: List[str],
                       depends_of: Dict[str, List[str]],
                       secs: Dict[str, float]) -> float:
        """Longest dependency-chain sum of stage times — the wall-time
        floor of an infinitely concurrent recovery, region-load stages
        included (excludes the reopen prologue, which is inherently
        serial and added by the caller)."""
        memo: Dict[str, float] = {}
        for name in order:               # deps resolve before dependents
            memo[name] = secs.get(name, 0.0) + max(
                (memo[d] for d in depends_of[name]), default=0.0)
        return max(memo.values(), default=0.0)
