"""Unified recovery subsystem (paper §V-F): vectorized chain primitives +
a dependency-ordered RecoveryManager that times every rebuild stage.

The paper's bargain is two-sided: persist fewer fields at write time, pay
to *recreate* them after a crash.  The write side batches through one
layer (core/writeset.py); this module is its mirror for the read side —
every crash-recovery path (pstruct structures, the serving engine, the
paged-KV allocator, the checkpoint manager) routes through it:

* ``chain_order`` / ``chain_lengths`` / ``chain_walk`` — shared vectorized
  pointer-jumping primitives (NumPy pointer-doubling; a Pallas variant
  lives in ``kernels/chain_order.py``).  They replace the per-structure
  scalar ``while cur != NULL`` walks: recovery of a million-entry
  structure runs at hardware speed, not at Python-loop speed.
* ``RecoveryManager`` — structures register their *pure* reconstructors
  (``core/reconstruct.py`` registry) under a name with declared
  dependencies (e.g. the serving engine depends on the request hashmap
  and the LRU page list).  ``recover()`` reopens the arenas once, does
  the generation/validity check once, runs the reconstructors in
  topological order, and times each stage into a ``RecoveryReport`` —
  the §V-F reconstruction-time metric, measured per stage.

``recover(concurrency=N)`` runs independent stages of the same
topological level in a thread pool: recovery wall time approaches the
critical path over the dependency DAG instead of the serial stage sum
(the report carries all three — ``wall_ms`` / ``critical_path_ms`` /
``total_ms``).  Stage-completion callbacks (``recover(on_stage=...)``
or ``add_listener``) fire the moment a stage lands, which is how the
serving engine admits traffic per slot before the full report exists
(DESIGN.md §6, "Concurrent recovery & admission").

Reconstructors must be pure given the loaded persistent state: same
bytes => identical rebuilt volatile redundancy, which the torn-epoch
crash tests assert at every epoch boundary (tests/test_recovery.py)
and the crash-point fuzzer re-asserts through recover-crash-recover
double failures (tests/test_async_recovery.py) — purity is exactly
what makes a crash *during* recovery harmless.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import reconstruct

NULL = -1

__all__ = [
    "NULL", "chain_order", "chain_lengths", "chain_walk", "jump_tables",
    "StageReport", "RecoveryReport", "Recoverable", "RecoveryManager",
]


# ======================================================================
# Vectorized chain primitives (pointer doubling / binary lifting)
# ======================================================================

def jump_tables(nxt: np.ndarray, bits: int) -> np.ndarray:
    """(bits, n) binary-lifting tables: ``jump[k][i]`` = node 2**k hops
    after i along ``nxt`` (NULL-absorbing).  A pointer outside [0, n) is
    a terminator, like NULL — recovery slices ``nxt`` at the committed
    fresh-water mark, so a link flushed by a torn epoch into uncommitted
    territory ends the chain instead of faulting.

    Tables are int32: node ids are region row indices (< 2**31), and
    halving the table bytes keeps the doubling gathers in cache — the
    difference between beating and losing to the scalar walk at 10**6
    entries (see BENCH_recovery.json)."""
    n = nxt.shape[0]
    jump = np.empty((bits, n), np.int32)
    jump[0] = np.where((nxt >= 0) & (nxt < n), nxt, NULL)
    for k in range(1, bits):
        prev_j = jump[k - 1]
        safe = np.where(prev_j >= 0, prev_j, 0)
        jump[k] = np.where(prev_j >= 0, prev_j[safe], NULL)
    return jump


def chain_lengths(nxt: np.ndarray, heads: np.ndarray) -> np.ndarray:
    """Length of the NULL-terminated chain starting at each head.

    Pointer doubling keeps the invariant (after k rounds):
    ``jump[i]`` = node min(2**k, L(i)) hops after i (NULL once the chain
    ran out), ``cnt[i]`` = min(2**k, L(i)), where L(i) counts the nodes
    from i to the NULL terminator.  O(n log n) work, fully vectorized —
    the parallel analogue of the seed's sequential ``_chain_len`` walk.
    Raises on cycles (a cycle never absorbs into NULL, so its count
    exceeds n)."""
    heads = np.asarray(heads, np.int64)
    n = nxt.shape[0]
    if n == 0 or heads.size == 0:
        return np.zeros(heads.shape, np.int64)
    # out-of-range pointers terminate (see jump_tables); int32 working
    # arrays for the same cache reasons as jump_tables
    jump = np.where((nxt >= 0) & (nxt < n), nxt, NULL).astype(np.int32)
    cnt = np.ones(n, np.int32)
    for _ in range(max(1, int(n).bit_length())):   # 2**rounds > n
        live = jump >= 0
        if not live.any():
            break
        safe = np.where(live, jump, 0)
        cnt = cnt + np.where(live, cnt[safe], 0)
        jump = np.where(live, jump[safe], NULL)
    # heads outside [0, n) are terminated chains (length 0), per the
    # module-wide OOB-pointer contract
    ok = (heads >= 0) & (heads < n)
    if (jump[heads[ok]] >= 0).any():
        raise RuntimeError("cycle in chain")
    out = np.zeros(heads.shape, np.int64)
    out[ok] = cnt[heads[ok]]
    return out


def chain_order(nxt: np.ndarray, head: int,
                count: Optional[int] = None) -> np.ndarray:
    """node-at-position for positions 0..count-1 via binary lifting.

    ``count=None`` derives the length from the same jump tables the
    position walk uses (one lifting descent from the top bit — no second
    doubling pass — with cycle detection); recovery paths that persist
    an explicit count (the DLL header) pass it instead — a
    stale-but-committed count then bounds the walk to the committed
    prefix, which is exactly the torn-epoch recovery guarantee.
    O(N log N) work, fully vectorized.

    A head outside [0, n) — NULL, or a HEAD field flushed by a torn
    epoch past the committed fresh-water mark — is a terminated chain:
    empty order, per the module-wide OOB-pointer contract."""
    n = nxt.shape[0]
    if head < 0 or head >= n:
        return np.empty(0, np.int64)
    if count is None:
        # build tables deep enough to absorb any valid chain, then read
        # the length off them: descend from the top bit, taking every
        # jump that does not absorb — the hop count is the tail position
        bits = max(1, int(n).bit_length())       # 2**bits > n
        jump = jump_tables(np.asarray(nxt, np.int64), bits)
        cur, tail_pos = head, 0
        for k in reversed(range(bits)):
            nk = int(jump[k][cur])
            if nk != NULL:
                tail_pos += 1 << k
                cur = nk
        count = tail_pos + 1
        if count > n:
            raise RuntimeError("cycle in chain")
    else:
        if count == 0:
            return np.empty(0, np.int64)
        bits = max(1, int(np.ceil(np.log2(max(count, 2)))))
        jump = jump_tables(np.asarray(nxt, np.int64), bits)
    # int32 throughout the position walk (row ids < 2**31): mixed-dtype
    # masked gathers cost ~3x at 10**6 entries
    pos = np.arange(count, dtype=np.int32)
    cur = np.full(count, head, np.int32)
    dead = np.zeros(count, bool)   # absorbed into NULL: count overran
    for k in range(bits):
        m = ((pos >> k) & 1 == 1) & ~dead
        if m.any():
            cur[m] = jump[k][cur[m]]
            dead |= cur == NULL
    if dead.any():
        # an explicit count larger than the chain: fail loudly instead
        # of letting NULL wrap around as a numpy negative index
        raise ValueError("count exceeds chain length")
    return cur.astype(np.int64)


def chain_walk(nxt: np.ndarray, heads: np.ndarray) -> np.ndarray:
    """Materialize many chains at once: (H, Lmax) member matrix, row h =
    nodes of the chain starting at heads[h] in order, NULL-padded.

    Level-synchronous — one vectorized round per chain *position*, all
    chains advanced together (the batched-probe idiom from
    hashmap._find_slots), so rounds = max chain length, not total
    nodes."""
    heads = np.asarray(heads, np.int64)
    n = nxt.shape[0]
    cols: List[np.ndarray] = []
    cur = np.where((heads >= 0) & (heads < n), heads, NULL)
    while (cur != NULL).any():
        cols.append(cur.copy())
        safe = np.where(cur != NULL, cur, 0)
        cur = np.where(cur != NULL, nxt[safe], NULL)
        cur = np.where((cur >= 0) & (cur < n), cur, NULL)
        if len(cols) > n:
            raise RuntimeError("cycle in chain")
    if not cols:
        return np.empty((heads.shape[0], 0), np.int64)
    return np.stack(cols, axis=1)


# ======================================================================
# Recovery reports
# ======================================================================

@dataclass
class StageReport:
    """One timed rebuild stage (§V-F reconstruction-time row).

    ``t_start`` / ``t_end`` are wall-clock offsets (seconds) from the
    start of the recovery pass, so a concurrent recovery's timeline can
    be read off the report: overlapping [t_start, t_end) intervals are
    stages that ran in parallel."""
    name: str
    seconds: float
    detail: Dict[str, Any] = field(default_factory=dict)
    t_start: float = 0.0
    t_end: float = 0.0

    def as_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "seconds": self.seconds,
                "t_start": self.t_start, "t_end": self.t_end,
                **self.detail}


@dataclass
class RecoveryReport:
    """Per-stage timing + validity of one recovery pass.  Produced by
    RecoveryManager and by ckpt.CheckpointManager.restore — the one
    report format every recovery path shares.

    Three times tell the concurrency story:

    * ``total_ms``         — summed per-stage seconds (serial work);
    * ``critical_path_ms`` — longest dependency chain (the floor any
      concurrency can reach);
    * ``wall_ms``          — what this pass actually took.

    ``total_seconds`` remains the wall-clock duration of the pass
    (``wall_ms / 1000``) for existing call sites."""
    valid: bool = True
    generation: int = 0
    total_seconds: float = 0.0
    concurrency: int = 1
    critical_path_seconds: float = 0.0
    stages: List[StageReport] = field(default_factory=list)

    @property
    def wall_ms(self) -> float:
        return self.total_seconds * 1e3

    @property
    def total_ms(self) -> float:
        return sum(s.seconds for s in self.stages) * 1e3

    @property
    def critical_path_ms(self) -> float:
        return self.critical_path_seconds * 1e3

    def add(self, name: str, seconds: float, **detail: Any) -> "StageReport":
        st = StageReport(name, seconds, dict(detail))
        self.stages.append(st)
        return st

    def stage(self, name: str) -> Optional[StageReport]:
        for st in self.stages:
            if st.name == name:
                return st
        return None

    def seconds(self, name: str) -> float:
        st = self.stage(name)
        return st.seconds if st is not None else 0.0

    def as_dict(self) -> Dict[str, Any]:
        return {"valid": self.valid, "generation": self.generation,
                "total_seconds": self.total_seconds,
                "concurrency": self.concurrency,
                "wall_ms": self.wall_ms, "total_ms": self.total_ms,
                "critical_path_ms": self.critical_path_ms,
                "stages": [s.as_dict() for s in self.stages]}


# ======================================================================
# RecoveryManager
# ======================================================================

@dataclass(frozen=True)
class Recoverable:
    name: str
    reconstructor: str          # name in the core.reconstruct registry
    target: Any                 # object handed to the reconstructor
    depends: Tuple[str, ...] = ()


class RecoveryManager:
    """Dependency-ordered, timed crash recovery.

    Usage::

        mgr = RecoveryManager(engine.arena, paging.arena)
        mgr.add("req_table", "pstruct.hashmap", engine.table)
        mgr.add("lru", "pstruct.dll", paging.lru)
        mgr.add("pages", "serve.paged_alloc", paging, depends=("lru",))
        mgr.add("engine", "serve.engine", engine,
                depends=("req_table", "pages"))
        report = mgr.recover()

    ``recover()`` reopens every arena once (the generation/validity check
    happens here, not in each structure), then runs the registered pure
    reconstructors in topological order, timing each into the report.
    ``recover(concurrency=N)`` runs the independent stages of each
    topological level in a thread pool of N workers; the report's stage
    list stays in deterministic (level-major, registration) order no
    matter which thread finished first, so serial and concurrent passes
    produce equivalent reports modulo timing fields.
    """

    def __init__(self, *arenas: Any):
        self.arenas = [a for a in arenas if a is not None]
        self._items: Dict[str, Recoverable] = {}
        self._listeners: List[Callable[[StageReport], None]] = []

    # ------------------------------------------------------------- setup
    def add(self, name: str, reconstructor: str, target: Any,
            depends: Sequence[str] = ()) -> "RecoveryManager":
        if name in self._items:
            raise ValueError(f"recoverable {name!r} already registered")
        if reconstructor not in reconstruct.names():
            raise KeyError(f"unknown reconstructor {reconstructor!r}")
        self._items[name] = Recoverable(name, reconstructor, target,
                                        tuple(depends))
        return self

    def add_listener(self, fn: Callable[[StageReport], None]
                     ) -> "RecoveryManager":
        """Register a stage-completion callback: ``fn(stage_report)`` is
        invoked the moment each stage (including "reopen") lands — from
        the completing worker thread under ``recover(concurrency>1)``,
        serialized by the manager's lock either way."""
        self._listeners.append(fn)
        return self

    def levels(self) -> List[List[str]]:
        """Topological *levels* over declared dependencies: level k holds
        every item whose dependencies all sit in levels < k, stable in
        registration order within a level.  Items of one level are
        mutually independent — the unit of stage concurrency."""
        items = self._items
        for it in items.values():
            for dep in it.depends:
                if dep not in items:
                    raise KeyError(
                        f"recoverable {it.name!r} depends on unregistered "
                        f"{dep!r}")
        done: set = set()
        out: List[List[str]] = []
        pending = list(items)
        while pending:
            ready = [n for n in pending
                     if all(d in done for d in items[n].depends)]
            if not ready:
                raise ValueError(f"dependency cycle among {pending}")
            out.append(ready)
            done.update(ready)
            pending = [n for n in pending if n not in done]
        return out

    def order(self) -> List[str]:
        """Topological order over declared dependencies, stable in
        registration order among ready items (levels, flattened)."""
        return [n for level in self.levels() for n in level]

    # ----------------------------------------------------------- recover
    def recover(self, reopen: bool = True, concurrency: int = 1,
                on_stage: Optional[Callable[[StageReport], None]] = None
                ) -> RecoveryReport:
        t_all = time.perf_counter()
        report = RecoveryReport(concurrency=max(1, int(concurrency)))
        lock = threading.Lock()
        listeners = list(self._listeners)
        if on_stage is not None:
            listeners.append(on_stage)

        def emit(st: StageReport) -> None:
            with lock:
                for fn in listeners:
                    fn(st)

        reopen_secs = 0.0
        if reopen and self.arenas:
            t0 = time.perf_counter()
            valids = []
            for a in self.arenas:
                a.reopen()
                valids.append(bool(a.header_valid()))
            reopen_secs = time.perf_counter() - t0
            st = report.add("reopen", reopen_secs,
                            arenas=len(self.arenas), valid=valids)
            st.t_start, st.t_end = 0.0, reopen_secs
            report.valid = all(valids)
            # the committed (persisted) generation — survives recovery in
            # a fresh process, unlike the in-memory commit counter
            report.generation = max(a.header_generation()
                                    for a in self.arenas)
            emit(st)

        def run_stage(name: str) -> StageReport:
            it = self._items[name]
            t0 = time.perf_counter()
            out, secs = reconstruct.run(it.reconstructor, it.target)
            t1 = time.perf_counter()
            detail = dict(out) if isinstance(out, dict) else {}
            detail.setdefault("reconstructor", it.reconstructor)
            st = StageReport(name, secs, detail,
                             t_start=t0 - t_all, t_end=t1 - t_all)
            emit(st)
            return st

        for level in self.levels():
            if report.concurrency > 1 and len(level) > 1:
                # independent stages of one level: fan out, then barrier —
                # the next level's dependencies are all of this one
                with ThreadPoolExecutor(
                        max_workers=min(report.concurrency,
                                        len(level))) as ex:
                    futs = [ex.submit(run_stage, n) for n in level]
                # .result() re-raises the first stage failure; report
                # order is submission (registration) order, not
                # completion order — determinism over luck
                report.stages.extend(f.result() for f in futs)
            else:
                report.stages.extend(run_stage(n) for n in level)
        report.total_seconds = time.perf_counter() - t_all
        report.critical_path_seconds = reopen_secs + self._critical_path(
            {s.name: s.seconds for s in report.stages})
        return report

    def _critical_path(self, secs: Dict[str, float]) -> float:
        """Longest dependency-chain sum of stage times — the wall-time
        floor of an infinitely concurrent recovery (excludes reopen,
        which is inherently serial and added by the caller)."""
        memo: Dict[str, float] = {}
        for name in self.order():        # deps resolve before dependents
            it = self._items[name]
            memo[name] = secs.get(name, 0.0) + max(
                (memo[d] for d in it.depends), default=0.0)
        return max(memo.values(), default=0.0)
