"""Paged regions: larger-than-RAM arenas behind the Region API.

DESIGN.md §12.  A ``ShardedRegion``/``Region`` materializes one
full-shape volatile array, capping arena capacity at host RAM and
forcing ``load()`` to read 100% of the persistent bytes after a crash.
The paged backend replaces that array with a pool of fixed-size row
blocks (default 4 KiB — the same granularity as the sharded
block-copy load fast path) faulted in on demand through a per-arena
LRU ``BlockCache``:

* a FAULT assembles the block from its authoritative persistent bytes:
  the home slot overlaid with BOTH shadow banks (committed authority
  first, then the in-flight target bank — newer wins), so a refaulted
  block is always bit-identical to the volatile view it replaces;
* a CLEAN block is therefore pure cache: eviction is a free drop;
* a DIRTY block (unflushed ``write_*`` rows) is PINNED — it holds the
  only copy of those rows, and mid-epoch home write-back would tear
  the committed generation's data-before-metadata invariant.  Dirty
  blocks write back exclusively through the existing write-set drain
  (``_note_flushed``) or the shadow remap, i.e. the epoch flush IS the
  write-back path, so commit semantics are unchanged in both modes;
* recovery's ``load:`` stages become lazy block-pool resets; the
  reconstructors fault exactly the blocks they touch, so recovery cost
  tracks the working set, not the arena size (the OID/node-cache
  indirection the ROADMAP item names).

Consumers that still grab the full ``.vol`` array trigger a one-shot
SPILL: the region materializes (home + overlays + dirty resident rows)
and leaves paged mode until the next ``load()``/crash.  Counted in
``BlockCache.spills`` — correctness fallback, not a fast path.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Dict, Optional

import numpy as np

from repro.core.arena import (CorruptLineError, Region, ShardedRegion,
                              sidecar_checksums)


class BlockCache:
    """Per-arena LRU over (region, block id) with dirty-block pinning.

    ``cache_blocks * block_bytes`` is the residency budget; admission
    past it evicts clean unpinned blocks from the LRU end.  When every
    resident block is pinned the cache stays over budget (counted in
    ``over_budget``) rather than evict un-written-back state.  All
    block operations run under one reentrant lock — concurrent
    recovery stages fault safely, and the only lock ordering is
    cache.lock -> arena fence lock (never the reverse)."""

    def __init__(self, block_bytes: int = 4096, cache_blocks: int = 1024):
        self.block_bytes = int(block_bytes)
        self.cache_blocks = int(cache_blocks)
        self.capacity_bytes = self.block_bytes * self.cache_blocks
        self.lock = threading.RLock()
        self._lru: "OrderedDict" = OrderedDict()  # (name, bid) -> region
        self.faults = 0
        self.hits = 0
        self.evictions = 0
        self.spills = 0
        self.over_budget = 0
        self.resident_bytes = 0
        self.peak_resident_bytes = 0

    # All methods assume self.lock is held by the calling accessor.
    def hit(self, region, bid: int) -> None:
        self.hits += 1
        self._lru.move_to_end((region.name, bid))

    def admit(self, region, bid: int, nbytes: int) -> None:
        self.faults += 1
        key = (region.name, bid)
        self._lru[key] = region
        self.resident_bytes += nbytes
        # peak includes the admit-then-evict transient — that memory
        # really coexists, and the SLO slack covers it
        if self.resident_bytes > self.peak_resident_bytes:
            self.peak_resident_bytes = self.resident_bytes
        self._evict_to_budget(protect=key)

    def forget(self, region, bid: int, nbytes: int) -> None:
        self._lru.pop((region.name, bid), None)
        self.resident_bytes -= nbytes

    def _evict_to_budget(self, protect=None) -> None:
        # `protect` is the block being admitted right now: its caller
        # holds a reference and is about to read/write it, so it must
        # survive its own admission even while still clean
        while self.resident_bytes > self.capacity_bytes:
            victim = None
            for (name, bid), region in self._lru.items():
                if (name, bid) == protect:
                    continue
                if not region._block_pinned(bid):
                    victim = (region, bid)
                    break
            if victim is None:
                self.over_budget += 1
                return
            victim[0]._drop_block(victim[1])
            self.evictions += 1

    def drop_clean(self) -> int:
        """Evict EVERY clean unpinned block (memory-pressure hook; the
        crash-sweep tests use it to force post-flush refaults).
        Returns the number of blocks dropped."""
        with self.lock:
            victims = [(region, bid)
                       for (name, bid), region in self._lru.items()
                       if not region._block_pinned(bid)]
            for region, bid in victims:
                region._drop_block(bid)
                self.evictions += 1
            return len(victims)

    def reset_peak(self) -> None:
        """Re-anchor the peak to current residency — phase-scoped peak
        measurement (the --paged-slo gate resets between build and
        recover)."""
        with self.lock:
            self.peak_resident_bytes = self.resident_bytes


class _BlockPool:
    """Demand-faulted block pool shared by PagedRegion and
    PagedShardedRegion.  Subclasses provide ``_assemble(lo, hi)`` (the
    authoritative fault read) and ``_masked_rows(rows)`` (which rows a
    shadow bank currently remaps)."""

    is_paged = True

    def _init_vol(self) -> None:
        self._cache: BlockCache = self.arena.cache
        self._block_rows = max(1, self._cache.block_bytes //
                               max(self.rowbytes, 1))
        self._n_blocks = -(-self.shape[0] // self._block_rows)
        self._resident: Dict[int, np.ndarray] = {}
        # one dirty bit per ROW (1 B/row bookkeeping — 1/64 of the 64 B
        # row data, like the DLL's volatile PREV redundancy): dirty-row
        # marking and the drain's unpin are single vectorized scatters
        # instead of per-block mask loops.  Invariant: a set bit's block
        # is resident (writes fault it in; eviction refuses pinned
        # blocks), so dropping a block never orphans dirty bits.
        self._dirty_rows = np.zeros(self.shape[0], bool)
        self._spill: Optional[np.ndarray] = None
        # crash() disarms faulting: volatile state is GONE, and reads
        # must see zeros (the unpaged contract) until reopen/load
        # re-authorizes reading the persistent bytes
        self._armed = True

    # -- pool state --------------------------------------------------------
    @property
    def paged_active(self) -> bool:
        """False once a full-``.vol`` consumer forced a spill."""
        return self._spill is None

    @property
    def total_blocks(self) -> int:
        return self._n_blocks

    @property
    def vol(self):
        # full-array access: correctness fallback for unconverted
        # consumers — materializes once and leaves paged mode
        if self._spill is None:
            self._materialize_spill()
        return self._spill

    @vol.setter
    def vol(self, value) -> None:
        self._spill = value

    def _reset_blocks(self, armed: bool = True) -> None:
        with self._cache.lock:
            self._drop_all()
            self._spill = None
            self._armed = armed

    def _drop_all(self) -> None:
        for bid in list(self._resident):
            self._cache.forget(self, bid, self._resident[bid].nbytes)
        self._resident.clear()
        self._dirty_rows[:] = False

    def _block_pinned(self, bid: int) -> bool:
        lo = bid * self._block_rows
        return bool(self._dirty_rows[lo:lo + self._block_rows].any())

    def _drop_block(self, bid: int) -> None:
        blk = self._resident.pop(bid, None)
        if blk is None:
            return
        self._cache.forget(self, bid, blk.nbytes)

    def _get_block(self, bid: int) -> np.ndarray:
        blk = self._resident.get(bid)
        if blk is not None:
            self._cache.hit(self, bid)
            return blk
        lo = bid * self._block_rows
        hi = min(lo + self._block_rows, self.shape[0])
        blk = (self._assemble(lo, hi) if self._armed
               else np.zeros((hi - lo,) + self.shape[1:], self.dtype))
        if self._armed:
            # fault-path verification (DESIGN.md §13): a corrupt block
            # is rejected BEFORE admission, so no consumer ever reads
            # silently-rotted bytes through the cache
            self._verify_block(blk, lo, hi)
        self._resident[bid] = blk
        self._cache.admit(self, bid, blk.nbytes)
        return blk

    def _verify_block(self, blk: np.ndarray, lo: int, hi: int) -> None:
        """Check an assembled block against its sidecar checksums.  The
        reference is assembled EXACTLY like the data (home + authority
        bank + in-flight target bank, newer wins): data rows and their
        sidecar lines always move in the same flush phase and bank, so
        every flushed row has a matching reference and never-flushed
        rows carry the 0 sentinel and are skipped."""
        sc = self._integ
        if sc is None:
            return
        ref = self._integ_ref(lo, hi)
        live = ref != 0
        if not live.any():
            return
        ck = sidecar_checksums(blk, sc.shape[1])
        bad = live & (ck != ref)
        if bad.any():
            rows = lo + np.nonzero(bad.any(axis=1))[0]
            raise CorruptLineError(self.name, rows,
                                   detail="paged fault verification")

    def _blk_loop(self, rows: np.ndarray):
        """Group `rows` by block; yield (bid, block, local rows within
        the block, positions into `rows`) per touched block."""
        bids = rows // self._block_rows
        order = np.argsort(bids, kind="stable")
        sbids = bids[order]
        srows = rows[order]
        cuts = np.nonzero(np.diff(sbids))[0] + 1
        starts = np.concatenate(([0], cuts))
        ends = np.concatenate((cuts, [sbids.size]))
        for a, b in zip(starts, ends):
            bid = int(sbids[a])
            yield (bid, self._get_block(bid),
                   srows[a:b] - bid * self._block_rows, order[a:b])

    def _empty_at(self, col, n: int) -> np.ndarray:
        probe = np.empty((0,) + self.shape[1:], self.dtype)[:, col]
        return np.empty((n,) + probe.shape[1:], self.dtype)

    def _flat_gather(self, rows: np.ndarray):
        """(stacked, flat) such that ``stacked[flat]`` is the rows' data.
        One fancy index over a concatenation of the touched blocks
        instead of a per-block Python loop — the write-set drain gathers
        thousands of scattered rows per epoch, and per-block loop
        overhead would tax the flush path the --paged-parity gate
        bounds.  Assumes the cache lock is held."""
        bids = rows // self._block_rows
        ub, inv = np.unique(bids, return_inverse=True)
        blocks = [self._get_block(int(b)) for b in ub]
        if len(blocks) == 1:
            return blocks[0], rows - ub[0] * self._block_rows
        offs = np.zeros(len(blocks), np.int64)
        np.cumsum([b.shape[0] for b in blocks[:-1]], out=offs[1:])
        stacked = np.concatenate(blocks)
        return stacked, offs[inv] + (rows - ub[inv] * self._block_rows)

    # -- row accessors (the _RowAccess API, block-routed) ------------------
    def read_rows(self, rows: np.ndarray) -> np.ndarray:
        rows = np.asarray(rows, np.int64)
        if self._spill is not None:
            return self._spill[rows]
        if rows.size == 0:
            return np.empty((0,) + self.shape[1:], self.dtype)
        with self._cache.lock:
            stacked, flat = self._flat_gather(rows)
            return stacked[flat]

    def read_at(self, rows: np.ndarray, col) -> np.ndarray:
        rows = np.asarray(rows, np.int64)
        if self._spill is not None:
            return self._spill[rows, col]
        if rows.size == 0:
            return self._empty_at(col, 0)
        with self._cache.lock:
            stacked, flat = self._flat_gather(rows)
            return stacked[flat, col]

    def read_one(self, row: int, col: int) -> int:
        if self._spill is not None:
            return int(self._spill[row, col])
        with self._cache.lock:
            bid, off = divmod(int(row), self._block_rows)
            return int(self._get_block(bid)[off, col])

    def read_col(self, col) -> np.ndarray:
        # whole-column read: faults every block THROUGH the cache, so
        # residency stays bounded — the full-recovery fallback path
        if self._spill is not None:
            return self._spill[:, col]
        return self.read_at(np.arange(self.shape[0], dtype=np.int64), col)

    def write_rows(self, rows: np.ndarray, vals) -> None:
        rows = np.asarray(rows, np.int64)
        if rows.size == 0:
            return
        if self._spill is not None:
            self._spill[rows] = vals
            return
        v = np.broadcast_to(np.asarray(vals, self.dtype),
                            (rows.size,) + self.shape[1:])
        with self._cache.lock:
            # dirty bits BEFORE the block loop: each admission inside
            # the loop may evict, and an already-written block of THIS
            # call must be pinned by then or its writes vanish
            self._dirty_rows[rows] = True
            for bid, blk, local, pos in self._blk_loop(rows):
                blk[local] = v[pos]

    def write_at(self, rows: np.ndarray, col, vals) -> None:
        rows = np.asarray(rows, np.int64)
        if rows.size == 0:
            return
        if self._spill is not None:
            self._spill[rows, col] = vals
            return
        shape = self._empty_at(col, rows.size).shape
        v = np.broadcast_to(np.asarray(vals, self.dtype), shape)
        with self._cache.lock:
            self._dirty_rows[rows] = True     # pin before any admission
            for bid, blk, local, pos in self._blk_loop(rows):
                blk[local, col] = v[pos]

    # -- write-back bookkeeping --------------------------------------------
    def _note_flushed(self, rows: np.ndarray) -> None:
        """Rows persisted by the write-set drain (home write in barrier
        mode, target-bank mirror in shadow mode — both refault-visible):
        clear their dirty bits so their blocks become evictable."""
        if self._spill is not None:
            return
        rows = np.asarray(rows, np.int64)
        if rows.size == 0:
            return
        with self._cache.lock:
            self._dirty_rows[rows] = False

    def _set_dirty(self, rows: np.ndarray) -> None:
        with self._cache.lock:
            self._dirty_rows[rows] = True

    def _note_persisted(self, rows: np.ndarray) -> None:
        """Direct (epoch-less) persist wrote these rows home — as
        durable as a flush EXCEPT where a shadow bank still remaps the
        row: a refault would overlay the stale mirror over the newer
        home bytes, so those rows stay dirty (their blocks pinned)."""
        if self._spill is not None:
            return
        rows = np.asarray(rows, np.int64)
        if rows.size == 0:
            return
        with self._cache.lock:
            masked = self._masked_rows(rows)
            if masked.any():
                self._set_dirty(rows[masked])
            self._note_flushed(rows[~masked])

    def _note_persisted_range(self, lo: int, hi: int) -> None:
        self._note_persisted(np.arange(lo, hi, dtype=np.int64))

    # -- flush-source gathers ----------------------------------------------
    def _gather(self, rows: np.ndarray) -> np.ndarray:
        return self.read_rows(rows)

    def _gather_range(self, lo: int, hi: int) -> np.ndarray:
        return self.read_rows(np.arange(lo, hi, dtype=np.int64))

    def _pack_source(self, rows: np.ndarray):
        g = self.read_rows(rows)
        return g, np.arange(rows.size, dtype=np.int64)

    # -- spill fallback ----------------------------------------------------
    def _materialize_spill(self) -> None:
        with self._cache.lock:
            if self._spill is not None:
                return
            full = (self._assemble(0, self.shape[0]) if self._armed
                    else np.zeros(self.shape, self.dtype))
            # clean resident blocks are value-equal to the assembly;
            # only dirty rows hold newer (unflushed) state
            for r in np.nonzero(self._dirty_rows)[0]:
                bid, off = divmod(int(r), self._block_rows)
                full[r] = self._resident[bid][off]
            self._cache.spills += 1
            self._drop_all()
            self._spill = full


class PagedRegion(_BlockPool, Region):
    """Single-arena paged region: blocks assemble from this arena's
    home slots + its two shadow banks."""

    def _masked_rows(self, rows: np.ndarray) -> np.ndarray:
        out = np.zeros(rows.size, bool)
        a = self.arena
        if a.commit_mode != "shadow":
            return out
        for bank in (0, 1):
            mask = a._shadow_masks[bank].get(self.name)
            if mask is not None:
                out |= mask[rows]
        return out

    def _assemble(self, lo: int, hi: int) -> np.ndarray:
        blk = np.array(self._pview()[lo:hi])
        a = self.arena
        if a.commit_mode == "shadow":
            auth = a._shadow_auth_bank
            for bank in (auth, 1 - auth):   # target bank last: newer wins
                mask = a._shadow_masks[bank].get(self.name)
                if mask is not None:
                    hit = np.nonzero(mask[lo:hi])[0]
                    if hit.size:
                        blk[hit] = a._shadow_mirror(self, bank)[lo + hit]
        a.synth_read(blk.nbytes)
        return blk

    def _integ_ref(self, lo: int, hi: int) -> np.ndarray:
        """Sidecar checksums for rows [lo, hi), assembled with the same
        overlay order as the data block itself (sidecars are never
        paged, so this is a plain persistent read)."""
        sc = self._integ
        ref = np.array(sc._pview()[lo:hi])
        a = self.arena
        if a.commit_mode == "shadow":
            auth = a._shadow_auth_bank
            for bank in (auth, 1 - auth):
                mask = a._shadow_masks[bank].get(sc.name)
                if mask is not None:
                    hit = np.nonzero(mask[lo:hi])[0]
                    if hit.size:
                        ref[hit] = a._shadow_mirror(sc, bank)[lo + hit]
        return ref

    def load(self) -> None:
        """Lazy reload: drop every block.  The post-crash working set
        faults back in on demand — recovery reads what it touches."""
        self._reset_blocks()

    def _crash_reset(self) -> None:
        self._reset_blocks(armed=False)


class PagedShardedRegion(_BlockPool, ShardedRegion):
    """Sharded paged region: ONE block pool at the sharded level (the
    cache replaces the one full-shape volatile image); each fault
    gathers its rows from the owning shards' slices and applies each
    shard's own bank overlays with LOCAL row masks."""

    def _masked_rows(self, rows: np.ndarray) -> np.ndarray:
        out = np.zeros(rows.size, bool)
        sh = self.shard_of[rows]
        for s in np.unique(sh):
            shard = self.arena.shards[s]
            if shard.commit_mode != "shadow":
                continue
            pos = np.nonzero(sh == s)[0]
            lr = self.local_of[rows[pos]]
            for bank in (0, 1):
                mask = shard._shadow_masks[bank].get(self.name)
                if mask is not None:
                    out[pos] |= mask[lr]
        return out

    def _assemble(self, lo: int, hi: int) -> np.ndarray:
        blk = np.empty((hi - lo,) + self.shape[1:], self.dtype)
        grows = np.arange(lo, hi, dtype=np.int64)
        sh = self.shard_of[grows]
        for s in np.unique(sh):
            pos = np.nonzero(sh == s)[0]
            sl = self.slices[s]
            lr = self.local_of[grows[pos]]
            sub = sl._pview()[lr]
            shard = self.arena.shards[s]
            if shard.commit_mode == "shadow":
                auth = shard._shadow_auth_bank
                for bank in (auth, 1 - auth):
                    mask = shard._shadow_masks[bank].get(self.name)
                    if mask is not None:
                        hit = np.nonzero(mask[lr])[0]
                        if hit.size:
                            sub[hit] = shard._shadow_mirror(sl, bank)[lr[hit]]
            blk[pos] = sub
            shard.synth_read(int(pos.size) * self.rowbytes)
        return blk

    def _integ_ref(self, lo: int, hi: int) -> np.ndarray:
        sc = self._integ
        ref = np.empty((hi - lo,) + sc.shape[1:], sc.dtype)
        grows = np.arange(lo, hi, dtype=np.int64)
        sh = sc.shard_of[grows]
        for s in np.unique(sh):
            pos = np.nonzero(sh == s)[0]
            sl = sc.slices[s]
            lr = sc.local_of[grows[pos]]
            sub = np.array(sl._pview()[lr])
            shard = self.arena.shards[s]
            if shard.commit_mode == "shadow":
                auth = shard._shadow_auth_bank
                for bank in (auth, 1 - auth):
                    mask = shard._shadow_masks[bank].get(sc.name)
                    if mask is not None:
                        hit = np.nonzero(mask[lr])[0]
                        if hit.size:
                            sub[hit] = shard._shadow_mirror(sl, bank)[lr[hit]]
            ref[pos] = sub
        return ref

    # slice gathers / notes route here with GLOBAL row ids
    def _vol_rows(self, grows: np.ndarray) -> np.ndarray:
        return self.read_rows(grows)

    def _pack_source_global(self, grows: np.ndarray):
        g = self.read_rows(grows)
        return g, np.arange(grows.size, dtype=np.int64)

    def _note_flushed_global(self, grows: np.ndarray) -> None:
        self._note_flushed(grows)

    def _note_persisted_global(self, grows: np.ndarray) -> None:
        self._note_persisted(grows)

    def load(self, concurrency: int = 1) -> None:
        self._reset_blocks()

    def load_shard(self, s: int) -> None:
        # reload == discard volatile and defer to faults; idempotent
        # across the per-shard loop callers drive
        self._reset_blocks()

    def _crash_reset(self) -> None:
        self._reset_blocks(armed=False)
