"""Write-set / epoch-flush layer (paper §V-E, MOD-style minimal ordering).

Structures no longer flush rows as they touch them.  Instead each logical
operation opens an *epoch* (``Arena.epoch()``); every mutation marks its
dirty rows into the arena's :class:`WriteSet`; when the outermost epoch
closes (or ``Arena.commit`` runs) the write set flushes ONCE:

* rows marked several times within the epoch are deduplicated;
* adjacent dirty rows coalesce into distinct 64 B lines exactly once
  across the whole operation — not once per ``persist_rows`` call;
* data regions flush before metadata (header) regions, extending the
  arena's data-before-metadata commit ordering into the epoch itself: a
  crash mid-epoch leaves the previous header state reachable;
* large row gathers can route through the Pallas ``pack_flush`` kernel
  (tile-aligned staging buffer) when the arena enables it.

Accounting: :class:`~repro.core.arena.FlushStats` gains per-epoch dedup
counters.  ``saved_lines`` is the difference between what per-call
accounting *would* have charged (one distinct-line count per mark, the
pre-refactor behaviour) and what the batched epoch flush actually
charged — the paper's redundant-flush overhead, measured directly.

``DigestWriteSet`` is the file-granularity sibling used by
``ckpt/manager.py``: leaves whose content digest is unchanged since the
last flush are dropped from the write set ("don't persist what didn't
change"), unifying the checkpoint manager's incremental mode with the
row-granularity tracker here.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

__all__ = ["WriteSet", "DigestWriteSet"]


class WriteSet:
    """Per-arena dirty-row tracker with epoch-batched flushing."""

    def __init__(self, arena):
        self.arena = arena
        # region name -> list of (unique row arrays, per-call line cost)
        self._pending: Dict[str, List[Tuple[np.ndarray, int]]] = {}

    # ------------------------------------------------------------- mark
    def mark(self, region, rows: np.ndarray) -> None:
        """Record dirty rows of `region`; flushed at epoch close."""
        rows = np.unique(np.asarray(rows, np.int64))
        if rows.size == 0:
            return
        would = self.arena._rows_line_count(region.offset, region.rowbytes,
                                            rows)
        self._pending.setdefault(region.name, []).append((rows, would))
        self.arena.stats.marks += 1

    def __bool__(self) -> bool:
        return bool(self._pending)

    def discard(self) -> None:
        """Drop all pending marks without flushing (crash simulation)."""
        self._pending.clear()

    # ------------------------------------------------------------ flush
    def flush(self, include_meta: bool = True) -> None:
        """Flush all pending marks: dedup rows, account distinct lines
        once, copy volatile -> persistent.  Data regions first, then
        metadata regions (headers); ``include_meta=False`` flushes only
        the data half and DROPS the metadata marks — the crash-injection
        point used by recovery tests."""
        if not self._pending:
            return
        arena = self.arena
        names = list(self._pending)
        names.sort(key=lambda n: (arena.regions[n].meta, arena.regions[n].offset))
        flushed_any = False
        for name in names:
            region = arena.regions[name]
            if region.meta and not include_meta:
                continue
            marks = self._pending.pop(name)
            rows = np.unique(np.concatenate([r for r, _ in marks]))
            would_lines = sum(w for _, w in marks)
            marked_rows = sum(r.size for r, _ in marks)
            self._copy_rows(region, rows)
            before = arena.stats.lines
            arena._account_rows(region.offset, region.rowbytes, rows)
            actual = arena.stats.lines - before
            arena.stats.saved_lines += max(0, would_lines - actual)
            arena.stats.dedup_rows += marked_rows - rows.size
            flushed_any = True
        if not include_meta:
            self._pending.clear()   # crash point: metadata marks are lost
        if flushed_any:
            arena.stats.epochs += 1

    def _copy_rows(self, region, rows: np.ndarray) -> None:
        pv = region._pview()
        if (self.arena.pack_flush_rows
                and rows.size >= self.arena.pack_flush_rows):
            pv[rows] = _pack_gather(region.vol, rows)
        else:
            pv[rows] = region.vol[rows]


def _pack_gather(vol: np.ndarray, rows: np.ndarray) -> np.ndarray:
    """Gather dirty rows through the Pallas pack kernel (tile-aligned
    staging buffer — the §V-E flush-unit path).  Rows are bit-cast to
    uint32 words so 64-bit payloads survive jax's default 32-bit mode.
    Falls back to a numpy gather if the kernel stack is unavailable."""
    try:
        import jax.numpy as jnp
        from repro.kernels import ops as kops
    except Exception:                                 # pragma: no cover
        return vol[rows]
    words = vol.reshape(vol.shape[0], -1).view(np.uint32)
    packed = kops.pack_rows(jnp.asarray(words), jnp.asarray(rows, jnp.int32))
    return np.ascontiguousarray(np.asarray(packed)).view(vol.dtype).reshape(
        (rows.size,) + vol.shape[1:])


class DigestWriteSet:
    """Content-digest dirty tracking for file-per-leaf persistence.

    ``dirty(key, digest, present)`` returns True when the leaf must be
    rewritten (digest changed, or the backing file is missing) and
    records the new digest; unchanged leaves are counted as deduplicated
    writes, mirroring ``WriteSet``'s row dedup at file granularity."""

    def __init__(self):
        self._digests: Dict[str, str] = {}
        self.skipped = 0
        self.written = 0

    def dirty(self, key: str, digest: str, present: bool = True) -> bool:
        clean = present and self._digests.get(key) == digest
        self._digests[key] = digest
        if clean:
            self.skipped += 1
            return False
        self.written += 1
        return True

    def note(self, key: str, digest: str) -> None:
        """Record a write that happens regardless of digest (callers not
        running in incremental mode), keeping the counters truthful."""
        self._digests[key] = digest
        self.written += 1
