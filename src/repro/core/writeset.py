"""Write-set / epoch-flush layer (paper §V-E, MOD-style minimal ordering).

Structures no longer flush rows as they touch them.  Instead each logical
operation opens an *epoch* (``Arena.epoch()``); every mutation marks its
dirty rows into the arena's :class:`WriteSet`; when the outermost epoch
closes (or ``Arena.commit`` runs) the write set flushes ONCE:

* rows marked several times within the epoch are deduplicated;
* adjacent dirty rows coalesce into distinct 64 B lines exactly once
  across the whole operation — not once per ``persist_rows`` call;
* data regions flush before metadata (header) regions, extending the
  arena's data-before-metadata commit ordering into the epoch itself: a
  crash mid-epoch leaves the previous header state reachable;
* large row gathers can route through the Pallas ``pack_flush`` kernel
  (tile-aligned staging buffer) when the arena enables it.

Accounting: :class:`~repro.core.arena.FlushStats` gains per-epoch dedup
counters.  ``saved_lines`` is the difference between what per-call
accounting *would* have charged (one distinct-line count per mark, the
pre-refactor behaviour) and what the batched epoch flush actually
charged — the paper's redundant-flush overhead, measured directly.

``DigestWriteSet`` is the file-granularity sibling used by
``ckpt/manager.py``: leaves whose content digest is unchanged since the
last flush are dropped from the write set ("don't persist what didn't
change"), unifying the checkpoint manager's incremental mode with the
row-granularity tracker here.

``ShardedWriteSet`` coordinates one WriteSet per arena shard
(DESIGN.md §7): an epoch close flushes every shard's DATA regions in
the shard pool, barriers, then flushes every shard's METADATA regions —
the data-before-metadata ordering is global across shards, so a
structure whose header landed on shard 0 can never expose rows that a
slower shard 3 hadn't flushed yet.  Per-shard line/dedup accounting
stays in each shard's FlushStats and rolls up through
``ShardedArena.stats``.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

__all__ = ["WriteSet", "ShardedWriteSet", "DigestWriteSet"]


class WriteSet:
    """Per-arena dirty-row tracker with epoch-batched flushing."""

    def __init__(self, arena):
        self.arena = arena
        # region name -> list of (unique rows, per-call line cost, fresh)
        self._pending: Dict[str, List[Tuple[np.ndarray, int, bool]]] = {}

    # ------------------------------------------------------------- mark
    def mark(self, region, rows: np.ndarray, fresh: bool = False) -> None:
        """Record dirty rows of `region`; flushed at epoch close.
        ``fresh`` rows were never committed-reachable, so a shadow-mode
        drain writes them home in place (barrier mode ignores it)."""
        rows = np.unique(np.asarray(rows, np.int64))
        if rows.size == 0:
            return
        if getattr(region, "snap", False) or getattr(region, "jrnl", False):
            # snapshot and journal regions stay out of the mark/saved/
            # dedup ledger — their lines land in FlushStats.snapshot_lines
            # / journal_lines at drain
            self._pending.setdefault(region.name, []).append((rows, 0,
                                                              fresh))
            return
        would = self.arena._rows_line_count(region.offset, region.rowbytes,
                                            rows)
        self._pending.setdefault(region.name, []).append((rows, would,
                                                          fresh))
        self.arena.stats.marks += 1

    def __bool__(self) -> bool:
        return bool(self._pending)

    def discard(self) -> None:
        """Drop all pending marks without flushing (crash simulation)."""
        self._pending.clear()

    # ------------------------------------------------------------ flush
    def flush(self, include_meta: bool = True) -> None:
        """Flush all pending marks: dedup rows, account distinct lines
        once, copy volatile -> persistent.  Data regions first, then
        metadata regions (headers); ``include_meta=False`` flushes only
        the data half and DROPS the metadata marks — the crash-injection
        point used by recovery tests.  Shadow mode drains everything in
        ONE unordered phase (fresh rows home, rewrites into the target
        bank); ``include_meta=False`` then simply means "crash before
        the flip" — nothing drained is reachable until commit."""
        self._drain_snapshots()
        if not self._pending:
            return
        if self.arena.commit_mode == "shadow":
            flushed = self._flush_shadow()
            self._pending.clear()
            if flushed:
                self.arena.stats.epochs += 1
            return
        flushed = self.flush_phase(meta=False)
        if include_meta:
            flushed = self.flush_phase(meta=True) or flushed
        else:
            self._pending.clear()   # crash point: metadata marks are lost
        if flushed:
            self.arena.stats.epochs += 1

    def _drain_snapshots(self) -> None:
        """Ask each registered order-snapshot provider for its dirty
        snapshot rows at EVERY flush, so a mid-commit crash leaves
        byte-identical snapshot regions to a flushed-but-uncommitted
        crash (the inter-shard commit-window invariant).  Providers are
        idempotent — a flush with nothing newly dirty emits nothing —
        and a record sealed at a non-commit flush names a generation
        that may never commit; recovery's ``gen <= committed`` guard
        plus verify-always adoption makes that harmless (DESIGN.md
        §10)."""
        arena = self.arena
        if not arena._snap_providers:
            return
        for prov in arena._snap_providers:
            for region, rows in prov():
                self.mark(region, rows)

    def flush_phase(self, meta: bool) -> bool:
        """Flush only the data half (``meta=False``) or only the
        metadata half (``meta=True``) of the pending marks, leaving the
        other half pending.  The two-phase split is what lets
        ShardedWriteSet barrier ALL shards' data ahead of ANY shard's
        metadata.  Returns whether anything flushed; the caller owns the
        ``epochs`` counter."""
        arena = self.arena
        names = [n for n in self._pending if arena.regions[n].meta == meta]
        names.sort(key=lambda n: arena.regions[n].offset)
        flushed_any = False
        with arena.stall_scope():
            flushed_any = self._flush_names(names, arena)
        if flushed_any:
            arena._fence()      # one ordering point per barrier phase
        return flushed_any

    def _flush_names(self, names, arena) -> bool:
        flushed_any = False
        for name in names:
            region = arena.regions[name]
            marks = self._pending.pop(name)
            rows = np.unique(np.concatenate([r for r, _, _ in marks]))
            would_lines = sum(w for _, w, _ in marks)
            marked_rows = sum(r.size for r, _, _ in marks)
            g = self._copy_rows(region, rows)
            # the drain IS where checksums ride the write set: data rows
            # and their sidecar lines move in the same phase, same fence
            arena._integrity_home(region, rows, data=g)
            if region.snap or region.jrnl:
                arena._account_rows(region.offset, region.rowbytes, rows,
                                    snap=region.snap, jrnl=region.jrnl)
                flushed_any = True
                continue
            before = arena.stats.lines
            arena._account_rows(region.offset, region.rowbytes, rows)
            actual = arena.stats.lines - before
            arena.stats.saved_lines += max(0, would_lines - actual)
            arena.stats.dedup_rows += marked_rows - rows.size
            flushed_any = True
        return flushed_any

    def _flush_shadow(self) -> bool:
        """Single-phase shadow drain: every region together, no
        data-before-metadata ordering — fresh rows go home in place
        (unreachable until the flip), every other row routes through the
        arena's remap (arena._shadow_write).  The committed bank's
        leftovers fold home first (reclamation deferred from the prior
        commit into this drain)."""
        arena = self.arena
        names = sorted(self._pending,
                       key=lambda n: arena.regions[n].offset)
        flushed_any = False
        with arena.stall_scope():
            arena._shadow_collapse()
            for name in names:
                region = arena.regions[name]
                marks = self._pending.pop(name)
                rew = [r for r, _, f in marks if not f]
                frs = [r for r, _, f in marks if f]
                rew = np.unique(np.concatenate(rew)) if rew \
                    else np.empty(0, np.int64)
                fr = np.unique(np.concatenate(frs)) if frs \
                    else np.empty(0, np.int64)
                # a row marked both ways is conservatively a rewrite
                fr = np.setdiff1d(fr, rew, assume_unique=True)
                would_lines = sum(w for _, w, _ in marks)
                marked_rows = sum(r.size for r, _, _ in marks)
                before = arena.stats.lines
                if fr.size:
                    g = self._copy_rows(region, fr)
                    arena._account_rows(region.offset, region.rowbytes, fr,
                                        snap=region.snap, jrnl=region.jrnl)
                    # fresh rows flush home, so their checksums do too;
                    # rewrites cascade inside _shadow_write (same bank)
                    arena._integrity_home(region, fr, data=g)
                if rew.size:
                    arena._shadow_write(region, rew)
                if region.snap or region.jrnl:
                    flushed_any = True
                    continue
                actual = arena.stats.lines - before
                arena.stats.saved_lines += max(0, would_lines - actual)
                arena.stats.dedup_rows += \
                    marked_rows - int(fr.size) - int(rew.size)
                flushed_any = True
        return flushed_any

    def _copy_rows(self, region, rows: np.ndarray) -> np.ndarray:
        pv = region._pview()
        if (self.arena.pack_flush_rows
                and rows.size >= self.arena.pack_flush_rows):
            vol, vrows = region._pack_source(rows)
            g = _pack_gather(vol, vrows)
        else:
            g = region._gather(rows)
        pv[rows] = g
        # the epoch drain IS the dirty-block write-back path: the rows
        # are home now, so a paged region may unpin their blocks
        region._note_flushed(rows)
        # returned so the integrity sidecar reuses the gather
        return g


class ShardedWriteSet:
    """Cross-shard epoch coordinator.

    Marks are buffered GLOBALLY per region — one cheap append per
    ``mark_rows`` call, exactly like the single-arena tracker — and the
    row->shard split happens ONCE per epoch at flush time, not once per
    mark (a B+Tree batch marks dozens of row sets per op; splitting
    each of them per shard would multiply the bookkeeping by the shard
    count).  The flush fans per-shard copy+account work out on the
    arena's shard pool in two phases: every shard's DATA regions land
    before ANY shard's metadata — the data-before-metadata barrier is
    global, so a header on shard 0 can never expose rows a slower shard
    3 hadn't flushed."""

    def __init__(self, arena):
        self.arena = arena
        # region name -> [rewrite row arrays, would_lines, marked,
        #                 fresh row arrays]
        self._pending: Dict[str, list] = {}

    def mark(self, region, rows: np.ndarray, fresh: bool = False) -> None:
        rows = np.unique(np.asarray(rows, np.int64))
        if rows.size == 0:
            return
        # the per-call counterfactual (what one accounting call per mark
        # would have charged) is computed on the GLOBAL rows with the
        # ONE shared counting rule — identical to the single-arena
        # bookkeeping, O(1) for line-aligned rows.  (For rows that are
        # line-aligned — every current region — the flushed-lines total
        # is shard-count-invariant too; sub-line rows split across
        # shards legitimately charge a shared line once PER FILE.)
        if getattr(region, "snap", False) or getattr(region, "jrnl", False):
            ent = self._pending.get(region.name)
            if ent is None:
                ent = self._pending[region.name] = [[], 0, 0, []]
            (ent[3] if fresh else ent[0]).append(rows)
            return
        from repro.core.arena import Arena
        would = Arena._rows_line_count(0, region.rowbytes, rows)
        ent = self._pending.get(region.name)
        if ent is None:
            ent = self._pending[region.name] = [[], 0, 0, []]
        (ent[3] if fresh else ent[0]).append(rows)
        ent[1] += would
        ent[2] += rows.size
        self.arena._local_stats.marks += 1

    def __bool__(self) -> bool:
        return bool(self._pending)

    def discard(self) -> None:
        self._pending.clear()

    def _drain_snapshots(self) -> None:
        arena = self.arena
        if not arena._snap_providers:
            return
        for prov in arena._snap_providers:
            for region, rows in prov():
                self.mark(region, rows)

    def flush(self, include_meta: bool = True) -> None:
        self._drain_snapshots()
        if not self._pending:
            return
        arena = self.arena
        if arena.commit_mode == "shadow":
            flushed = self._flush_shadow()
            self._pending.clear()
            if flushed:
                arena._local_stats.epochs += 1
            return
        flushed = self._flush_phase(meta=False)
        if include_meta:
            flushed = self._flush_phase(meta=True) or flushed
        else:
            self._pending.clear()   # crash point: metadata marks are lost
        if flushed:
            arena._local_stats.epochs += 1

    def flush_phase(self, meta: bool) -> bool:
        return self._flush_phase(meta)

    def _flush_phase(self, meta: bool) -> bool:
        arena = self.arena
        names = [n for n in self._pending
                 if arena.regions[n].meta == meta]
        names.sort(key=lambda n: n)
        if not names:
            return False
        # split each region's deduplicated rows per shard ONCE, then fan
        # the copy + per-shard line accounting out on the shard pool
        work: Dict[int, list] = {}      # shard -> [(slice, local rows)]
        region_rows = []
        for name in names:
            region = arena.regions[name]
            arrs, would, marked, fresh_arrs = self._pending.pop(name)
            arrs = arrs + fresh_arrs    # barrier mode: the hint is moot
            rows = np.unique(np.concatenate(arrs)) if len(arrs) > 1 \
                else arrs[0]
            if not (region.snap or region.jrnl):
                # snap/jrnl lines stay off the ledger
                region_rows.append((region, rows, would, marked))
            for sl, local in region._split(rows):
                work.setdefault(sl.arena_index, []).append((sl, local))

        actual = {}                     # shard -> lines flushed there

        def flush_shard(s: int) -> None:
            shard = arena.shards[s]
            before = shard.stats.lines
            with shard.stall_scope():
                for sl, local in work[s]:
                    g = self._copy_rows(sl, local)
                    shard._account_rows(sl.offset, sl.rowbytes, local,
                                        snap=sl.snap, jrnl=sl.jrnl)
                    # per-shard sidecar write: a row's checksum shares
                    # its shard (same router), phase, and fence
                    shard._integrity_home(sl, local, data=g)
            actual[s] = shard.stats.lines - before

        shards = sorted(work)
        if len(shards) > 1:
            list(arena.pool().map(flush_shard, shards))
        else:
            flush_shard(shards[0])
        # region-level dedup/saved accounting against the global
        # counterfactual (rolls up through ShardedArena.stats)
        total_actual = sum(actual.values())
        would_total = sum(w for _, _, w, _ in region_rows)
        arena._local_stats.saved_lines += max(0, would_total - total_actual)
        arena._local_stats.dedup_rows += sum(
            m - r.size for _, r, _, m in region_rows)
        arena._fence()          # the global cross-shard ordering point
        return True

    def _flush_shadow(self) -> bool:
        """Pooled SINGLE-phase shadow drain: no cross-shard barrier and
        no data/metadata split — every shard folds its committed bank's
        leftovers home, writes fresh rows in place, and routes rewrites
        through its own remap bank, all concurrently.  Nothing drained
        here is reachable until the commit's generation flip, which is
        the one ordering point the whole epoch pays."""
        arena = self.arena
        names = sorted(self._pending)
        if not names:
            return False
        work: Dict[int, list] = {}  # shard -> [(slice, local, fresh)]
        region_rows = []
        for name in names:
            region = arena.regions[name]
            arrs, would, marked, fresh_arrs = self._pending.pop(name)
            rew = np.unique(np.concatenate(arrs)) if arrs \
                else np.empty(0, np.int64)
            fr = np.unique(np.concatenate(fresh_arrs)) if fresh_arrs \
                else np.empty(0, np.int64)
            # a row marked both ways is conservatively a rewrite
            fr = np.setdiff1d(fr, rew, assume_unique=True)
            if not (region.snap or region.jrnl):
                # snap/jrnl lines stay off the ledger
                region_rows.append((would, marked,
                                    int(fr.size + rew.size)))
            for sl, local in region._split(rew):
                work.setdefault(sl.arena_index, []).append(
                    (sl, np.sort(local), False))
            for sl, local in region._split(fr):
                work.setdefault(sl.arena_index, []).append(
                    (sl, np.sort(local), True))

        actual = {}                     # shard -> lines flushed there

        def flush_shard(s: int) -> None:
            shard = arena.shards[s]
            before = shard.stats.lines
            with shard.stall_scope():
                shard._shadow_collapse()
                for sl, local, fresh in work.get(s, ()):
                    if fresh:
                        g = self._copy_rows(sl, local)
                        shard._account_rows(sl.offset, sl.rowbytes, local,
                                            snap=sl.snap, jrnl=sl.jrnl)
                        shard._integrity_home(sl, local, data=g)
                    else:
                        shard._shadow_write(sl, local)
            actual[s] = shard.stats.lines - before

        shards = sorted(work)
        if len(shards) > 1:
            list(arena.pool().map(flush_shard, shards))
        elif shards:
            flush_shard(shards[0])
        total_actual = sum(actual.values())
        would_total = sum(w for w, _, _ in region_rows)
        arena._local_stats.saved_lines += max(0, would_total - total_actual)
        arena._local_stats.dedup_rows += sum(
            m - n for _, m, n in region_rows)
        return True

    def _copy_rows(self, sl, rows: np.ndarray) -> np.ndarray:
        pv = sl._pview()
        if (self.arena.pack_flush_rows
                and rows.size >= self.arena.pack_flush_rows):
            vol, vrows = sl._pack_source(rows)
            g = _pack_gather(vol, vrows)
        else:
            g = sl._gather(rows)
        pv[rows] = g
        # write-back point for paged parents (slice forwards globally)
        sl._note_flushed(rows)
        return g


def _pack_gather(vol: np.ndarray, rows: np.ndarray) -> np.ndarray:
    """Gather dirty rows through the Pallas pack kernel (tile-aligned
    staging buffer — the §V-E flush-unit path).  Rows are bit-cast to
    uint32 words so 64-bit payloads survive jax's default 32-bit mode.
    Falls back to a numpy gather if the kernel stack is unavailable."""
    try:
        import jax.numpy as jnp
        from repro.kernels import ops as kops
    except Exception:                                 # pragma: no cover
        return vol[rows]
    words = vol.reshape(vol.shape[0], -1).view(np.uint32)
    packed = kops.pack_rows(jnp.asarray(words), jnp.asarray(rows, jnp.int32))
    return np.ascontiguousarray(np.asarray(packed)).view(vol.dtype).reshape(
        (rows.size,) + vol.shape[1:])


class DigestWriteSet:
    """Content-digest dirty tracking for file-per-leaf persistence.

    ``dirty(key, digest, present)`` returns True when the leaf must be
    rewritten (digest changed, or the backing file is missing) and
    records the new digest; unchanged leaves are counted as deduplicated
    writes, mirroring ``WriteSet``'s row dedup at file granularity."""

    def __init__(self):
        self._digests: Dict[str, str] = {}
        self.skipped = 0
        self.written = 0

    def dirty(self, key: str, digest: str, present: bool = True) -> bool:
        clean = present and self._digests.get(key) == digest
        self._digests[key] = digest
        if clean:
            self.skipped += 1
            return False
        self.written += 1
        return True

    def note(self, key: str, digest: str) -> None:
        """Record a write that happens regardless of digest (callers not
        running in incremental mode), keeping the counters truthful."""
        self._digests[key] = digest
        self.written += 1
