"""Persistence policy: the paper's essential/redundant field classification
lifted to training/serving state pytrees.

Every leaf of a state pytree is classified as:

* ESSENTIAL    — must be persisted; the minimal recovery set (params, step,
                 data-order seed, live request payloads).
* DERIVABLE    — never persisted; reconstructed exactly on restore (RNG
                 state from seed+step, LR schedule internals, data-pipeline
                 cursor, B+Tree inner nodes, hashmap buckets, DLL prev/LRU,
                 KV paging tables, compiled/layout caches).
* APPROXIMABLE — not exactly derivable but tolerably reconstructible
                 (Adam moments).  Handling is explicit per policy:
                 "persist" (bit-exact, fully-persistent semantics),
                 "quantize8" (8-bit block-quantized persist — 4x fewer
                 bytes, bounded restore error; beyond-paper),
                 "drop" (re-warm from zeros; documented divergence).

The `partly` policy with approx="persist" is the *faithful* reproduction:
exactly the paper's contract — only truly-redundant fields are skipped.
"""
from __future__ import annotations

import dataclasses
import enum
import fnmatch
import re
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


class Kind(enum.Enum):
    ESSENTIAL = "essential"
    DERIVABLE = "derivable"
    APPROXIMABLE = "approximable"


# Path-suffix rules (matched against "/".join(path keys)).
DEFAULT_RULES: Tuple[Tuple[str, Kind], ...] = (
    ("params/*", Kind.ESSENTIAL),
    ("step", Kind.ESSENTIAL),
    ("data_seed", Kind.ESSENTIAL),
    ("mu/*", Kind.APPROXIMABLE),
    ("nu/*", Kind.APPROXIMABLE),
    ("rng", Kind.DERIVABLE),
    ("schedule/*", Kind.DERIVABLE),
    ("pipeline/*", Kind.DERIVABLE),
    ("cache/*", Kind.DERIVABLE),
    ("paging/*", Kind.DERIVABLE),
)


def path_str(path) -> str:
    parts = []
    for k in path:
        parts.append(str(getattr(k, "key", getattr(k, "name", k))))
    return "/".join(parts)


def classify(path, rules=DEFAULT_RULES) -> Kind:
    p = path_str(path)
    for pat, kind in rules:
        if fnmatch.fnmatch(p, pat) or fnmatch.fnmatch(p, pat + "/*") or \
                fnmatch.fnmatch(p, "*/" + pat):
            return kind
    return Kind.ESSENTIAL  # unknown leaves default to safe


@dataclasses.dataclass(frozen=True)
class PersistPolicy:
    """What gets written at a checkpoint."""
    name: str                      # "full" | "partly"
    approx: str = "persist"        # persist | quantize8 | drop
    rules: Tuple[Tuple[str, Kind], ...] = DEFAULT_RULES

    def persisted_kinds(self) -> Tuple[Kind, ...]:
        if self.name == "full":
            return (Kind.ESSENTIAL, Kind.DERIVABLE, Kind.APPROXIMABLE)
        if self.approx == "drop":
            return (Kind.ESSENTIAL,)
        return (Kind.ESSENTIAL, Kind.APPROXIMABLE)


FULLY_PERSISTENT = PersistPolicy("full")
PARTLY_PERSISTENT = PersistPolicy("partly", approx="persist")
PARTLY_Q8 = PersistPolicy("partly", approx="quantize8")
PARTLY_DROP = PersistPolicy("partly", approx="drop")


@dataclasses.dataclass
class LeafPlan:
    path: str
    kind: Kind
    shape: Tuple[int, ...]
    dtype: Any
    nbytes: int
    persisted: bool
    quantized: bool


def plan(state: Any, policy: PersistPolicy) -> List[LeafPlan]:
    """Per-leaf persistence plan + byte accounting (the Fig-1 'how many
    lines will this flush' estimate, ahead of time)."""
    out: List[LeafPlan] = []
    kinds = policy.persisted_kinds()

    def visit(path, leaf):
        kind = classify(path, policy.rules)
        quant = (policy.name == "partly" and policy.approx == "quantize8"
                 and kind == Kind.APPROXIMABLE)
        persisted = kind in kinds
        shape = tuple(getattr(leaf, "shape", ()))
        dtype = getattr(leaf, "dtype", np.dtype("float32"))
        raw = int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize \
            if shape else np.dtype(dtype).itemsize
        nbytes = raw
        if quant:
            # int8 payload + f32 scale per 256-block
            n = int(np.prod(shape, dtype=np.int64)) if shape else 1
            nbytes = n + 4 * ((n + 255) // 256)
        out.append(LeafPlan(path_str(path), kind, shape, dtype,
                            nbytes if persisted else 0, persisted, quant))

    jax.tree_util.tree_map_with_path(visit, state)
    return out


def persisted_bytes(state: Any, policy: PersistPolicy) -> int:
    return sum(p.nbytes for p in plan(state, policy))
