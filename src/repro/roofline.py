"""Roofline analysis from compiled (post-SPMD) HLO.

Why a custom analyzer: ``compiled.cost_analysis()`` counts every ``while``
body ONCE — a 12-superblock layer scan is undercounted 12x (verified
empirically on this backend; see EXPERIMENTS.md §Method).  Since the whole
stack is scanned (layers, loss chunks, MoE groups, KV blocks), honest
roofline terms require multiplying loop-body costs by trip counts.  This
module parses the optimized HLO text, resolves ``while`` trip counts from
their condition computations, and walks the call graph with multiplicity.

Reported terms per (arch x shape x mesh), all **seconds per step, per
device** on the target TPU v5e:

  compute    = dot_flops                / PEAK_FLOPS      (197e12 bf16)
  memory     = hbm_bytes                / HBM_BW          (819e9 B/s)
  collective = sum(w_op * tensor_bytes) / ICI_BW          (50e9 B/s/link)

Cost-model conventions (documented for the §Roofline tables):

* dot_flops: 2 * |result| * |contraction| per dot, x loop multiplicity.
  Elementwise/reduce flops are excluded (<5% for these models and not
  MXU-bound); ``convolution`` ops are flagged if present.
* hbm_bytes: per instruction, operand + result bytes (fusion call-site
  shapes — fusion internals live in registers/VMEM, matching TPU HBM
  traffic).  dynamic-slice / dynamic-update-slice count only the slice
  moved (XLA aliases the big buffer in place).  gather/scatter count the
  gathered/updated rows, not the whole table.  reshape/bitcast/tuple/gte
  are free; collective operands are counted in the collective term only.
* collective_bytes: per op, the largest tensor shape on the line (the
  full rotated payload) with weight 2 for all-reduce (ring reduce +
  broadcast phases), 1 for all-gather / reduce-scatter / all-to-all /
  collective-permute.  Ring factor (n-1)/n is approximated as 1.
* The "pod" axis of the multi-pod mesh maps to the slower inter-pod
  links; ops whose replica groups span pods are charged at DCN_BW.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

# ----- TPU v5e hardware constants (per chip) -----
PEAK_FLOPS = 197e12          # bf16 MXU
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link (intra-pod ring)
DCN_BW = 6.25e9              # B/s per chip inter-pod (50 Gb/s NIC share)
HBM_PER_CHIP = 16 * 2**30    # 16 GiB

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"
    r"(\(.*?\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s+"
    r"([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s+\(")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*?(\d+)')

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "reshape", "after-all", "partition-id",
             "replica-id", "iota", "rng-bit-generator",
             # On TPU these fuse into producers/consumers; standalone
             # appearances in CPU-backend HLO are bf16-emulation artifacts.
             "convert", "broadcast"}


def _shape_bytes(type_str: str) -> int:
    """Total bytes of all array shapes in an HLO type string (handles
    tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape(type_str: str) -> Tuple[str, Tuple[int, ...]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "f32", ()
    dt, dims = m.groups()
    shape = tuple(int(d) for d in dims.split(",")) if dims else ()
    return dt, shape


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str          # everything after the opening '('


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        s = line.rstrip()
        if not s or s.lstrip().startswith("//"):
            continue
        if not s.startswith(" ") and s.endswith("{"):
            m = _COMP_HDR_RE.match(s.replace("ENTRY ", "", 1).strip()
                                   if s.startswith("ENTRY")
                                   else s.strip())
            if m:
                cur = Computation(m.group(1), [])
                comps[cur.name] = cur
                continue
        if s.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(s)
        if m:
            name, type_str, opcode, rest = m.groups()
            cur.instrs.append(Instr(name, type_str, opcode, rest))
    return comps


def _trip_count(cond: Computation) -> int:
    """Trip count of a jax-lowered while: condition compares the counter
    against an s32 constant (possibly inside a wrapped fusion).  Take the
    largest s32 constant in the condition computation."""
    best = 0
    for ins in cond.instrs:
        if ins.opcode == "constant" and ins.type_str.strip().startswith("s32"):
            m = re.match(r"([\-\d]+)\)", ins.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best if best > 0 else 1


def _attr(rest: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", rest)
    return m.group(1) if m else None


@dataclasses.dataclass
class HloCosts:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0          # ICI-charged collective payload
    coll_bytes_dcn: float = 0.0      # inter-pod-charged payload
    coll_ops: Dict[str, float] = dataclasses.field(default_factory=dict)
    has_convolution: bool = False

    def add(self, o: "HloCosts", mult: float) -> None:
        self.dot_flops += o.dot_flops * mult
        self.hbm_bytes += o.hbm_bytes * mult
        self.coll_bytes += o.coll_bytes * mult
        self.coll_bytes_dcn += o.coll_bytes_dcn * mult
        for k, v in o.coll_ops.items():
            self.coll_ops[k] = self.coll_ops.get(k, 0.0) + v * mult
        self.has_convolution |= o.has_convolution


def _spans_pods(rest: str, n_devices: int, pod_size: int) -> bool:
    """True if the op's replica groups cross a pod boundary.  Devices are
    laid out pod-major (mesh axis order ("pod","data","model")), so a group
    crosses pods iff it contains ids from different `id // pod_size`."""
    if pod_size >= n_devices:
        return False
    m = re.search(r"replica_groups=\{([^}]*)\}", rest)
    if m:
        for grp in re.findall(r"\{([\d,]+)\}", "{" + m.group(1) + "}"):
            ids = [int(x) for x in grp.split(",")]
            if len({i // pod_size for i in ids}) > 1:
                return True
        return False
    # iota form: replica_groups=[G,S]<=[perm or dims]T(...)
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[([^\]]*)\]"
                  r"(?:T\(([\d,]+)\))?", rest)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            ids = ids.transpose(perm)
        ids = ids.reshape(g, s)
        for row in ids:
            if len({int(i) // pod_size for i in row}) > 1:
                return True
    return False


class HloAnalyzer:
    def __init__(self, text: str, n_devices: int, pod_size: int = 1 << 30):
        self.comps = parse_hlo(text)
        self.n_devices = n_devices
        self.pod_size = pod_size
        self._shape_of: Dict[str, str] = {}
        self._instr_of: Dict[str, Instr] = {}
        for c in self.comps.values():
            for ins in c.instrs:
                self._shape_of[ins.name] = ins.type_str
                self._instr_of[ins.name] = ins
        self._memo: Dict[str, HloCosts] = {}

    # -- per-instruction costs -------------------------------------------
    def _operands(self, ins: Instr) -> List[str]:
        # operand list = %names before the first "), " attr break
        head = ins.rest.split("),")[0]
        return re.findall(r"%([\w.\-]+)", head)

    def _operand_bytes(self, ins: Instr) -> int:
        return sum(_shape_bytes(self._shape_of.get(o, ""))
                   for o in self._operands(ins))

    def _fusion_bytes(self, ins: Instr) -> int:
        """HBM traffic of a fusion: bytes actually read from each external
        operand + result written.  A fused-computation parameter consumed
        ONLY through dynamic-slice/gather is read at slice granularity
        (this is how scan xs are consumed — charging the full stacked
        tensor per iteration would overcount by the trip count)."""
        tgt = _attr(ins.rest, "calls")
        comp = self.comps.get(tgt) if tgt else None
        result = _shape_bytes(ins.type_str)
        if comp is None:
            return result + self._operand_bytes(ins)
        params: Dict[str, str] = {}
        uses: Dict[str, List[Instr]] = {}
        # bitcast/reshape/copy chains are aliases of their source; whole-
        # buffer `convert` is treated as transparent too — the CPU fusion
        # emitter wraps in-place stack updates as convert(buf) -> DUS ->
        # convert(buf) per loop iteration, a backend artifact the TPU
        # emitter does not produce (normalized out of the traffic model).
        alias: Dict[str, str] = {}
        for i2 in comp.instrs:
            if i2.opcode == "parameter":
                params[i2.name] = i2.type_str
                uses[i2.name] = []
        for i2 in comp.instrs:
            if i2.opcode == "parameter":
                continue
            ops_ = self._operands(i2)
            if i2.opcode in ("bitcast", "reshape", "copy", "convert") \
                    and ops_:
                src = alias.get(ops_[0], ops_[0])
                if src in params:
                    alias[i2.name] = src
                    continue
            for o in ops_:
                root = alias.get(o, o)
                if root in uses:
                    uses[root].append(i2)
        def _op0_is(u: Instr, pname: str) -> bool:
            ops_ = self._operands(u)
            return bool(ops_) and alias.get(ops_[0], ops_[0]) == pname

        read = 0
        in_place = 0   # bytes written in place through a DUS root
        for pname, ptype in params.items():
            us = uses[pname]
            if not us:
                continue
            if all(u.opcode in ("dynamic-slice", "gather")
                   and _op0_is(u, pname) for u in us):
                read += sum(_shape_bytes(u.type_str) for u in us)
            elif all(u.opcode == "dynamic-update-slice"
                     and _op0_is(u, pname) for u in us):
                # scan-residual stacking: the big buffer is aliased in
                # place; traffic = the update slices only (read-modify
                # -write of the touched region).
                for u in us:
                    ops_ = self._operands(u)
                    upd = _shape_bytes(self._shape_of.get(ops_[1], "")) \
                        if len(ops_) > 1 else 0
                    in_place += 2 * upd
                if _shape_bytes(ptype) == result:
                    result = 0     # root writes in place, not a full copy
            else:
                read += _shape_bytes(ptype)
        return read + in_place + result

    def _is_bf16_upcast(self, ins: Instr) -> bool:
        """True when every operand of a collective is an f32 tensor
        produced by converting bf16 (directly or via a convert-only
        fusion)."""
        ops_ = self._operands(ins)
        if not ops_:
            return False
        found = False
        for o in ops_:
            src_ins = self._instr_of.get(o)
            if src_ins is None or not src_ins.type_str.startswith("f32"):
                return False
            if src_ins.opcode == "convert":
                in0 = self._instr_of.get(
                    (self._operands(src_ins) or [""])[0])
                if in0 is None or not in0.type_str.startswith("bf16"):
                    return False
                found = True
            elif src_ins.opcode == "fusion":
                # artifact signature: the fused computation's root is a
                # convert-to-f32 whose input is bf16 (the true payload)
                tgt = _attr(src_ins.rest, "calls")
                comp = self.comps.get(tgt)
                if comp is None or not comp.instrs:
                    return False
                root = comp.instrs[-1]
                if root.opcode != "convert" \
                        or not root.type_str.startswith("f32"):
                    return False
                rops = self._operands(root)
                shapes = {i2.name: i2.type_str for i2 in comp.instrs}
                if not rops or not shapes.get(rops[0], "").startswith(
                        "bf16"):
                    return False
                found = True
            else:
                return False
        return found

    def _consumers_are_bf16_converts(self, comp: Computation,
                                     ins: Instr) -> bool:
        """True when every consumer of a collective's f32 result (through
        one level of get-tuple-element) immediately converts it to bf16 —
        i.e. nothing uses the f32 value, so on the TPU target the
        collective itself runs at bf16 width (the f32 stop-over is the
        CPU DotThunk upcast around bf16 dots)."""
        if not ins.type_str.lstrip("(").startswith("f32"):
            return False
        names = {ins.name}
        consumers: List[Instr] = []
        for i2 in comp.instrs:
            if i2 is ins:
                continue
            ops_ = self._operands(i2)
            if any(o in names for o in ops_):
                if i2.opcode == "get-tuple-element":
                    names.add(i2.name)
                else:
                    consumers.append(i2)
        if not consumers:
            return False
        for c in consumers:
            if c.opcode == "convert" and c.type_str.startswith("bf16"):
                continue
            if c.opcode == "fusion":
                tgt = _attr(c.rest, "calls")
                fc = self.comps.get(tgt)
                if fc and fc.instrs and fc.instrs[-1].opcode == "convert" \
                        and fc.instrs[-1].type_str.startswith("bf16"):
                    continue
            return False
        return True

    def _dot_flops(self, ins: Instr) -> float:
        out_elems = 1
        _, oshape = _first_shape(ins.type_str)
        for d in oshape:
            out_elems *= d
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
        ops = self._operands(ins)
        contraction = 1
        if m and ops:
            lhs_t = self._shape_of.get(ops[0], "")
            _, lshape = _first_shape(lhs_t)
            dims = [int(x) for x in m.group(1).split(",") if x]
            for d in dims:
                if d < len(lshape):
                    contraction *= lshape[d]
        return 2.0 * out_elems * contraction

    # -- computation walk --------------------------------------------------
    def costs(self, comp_name: str) -> HloCosts:
        if comp_name in self._memo:
            return self._memo[comp_name]
        total = HloCosts()
        comp = self.comps.get(comp_name)
        if comp is None:
            self._memo[comp_name] = total
            return total
        self._memo[comp_name] = total  # break cycles defensively
        for ins in comp.instrs:
            op = ins.opcode
            if op in _FREE_OPS:
                continue
            if op == "while":
                body = _attr(ins.rest, "body")
                cond = _attr(ins.rest, "condition")
                m = _TRIP_RE.search(ins.rest)
                if m:
                    trips = int(m.group(1))
                elif cond in self.comps:
                    trips = _trip_count(self.comps[cond])
                else:
                    trips = 1
                if body:
                    total.add(self.costs(body), trips)
                continue
            if op in ("call", "conditional"):
                tgt = _attr(ins.rest, "to_apply") or _attr(ins.rest,
                                                           "true_computation")
                if tgt:
                    total.add(self.costs(tgt), 1.0)
                continue
            base = op[:-6] if op.endswith("-start") else op
            if base in COLLECTIVES:
                payload = max(_shape_bytes(ins.type_str),
                              self._operand_bytes(ins))
                if self._is_bf16_upcast(ins) or \
                        self._consumers_are_bf16_converts(comp, ins):
                    # CPU-backend artifact: DotThunk cannot execute bf16
                    # dots, so XLA upcasts bf16 values to f32 around the
                    # collective (producer- or consumer-side).  On the
                    # TPU target the dot is native bf16 and the
                    # collective moves bf16 — charge the true width.
                    payload //= 2
                w = 2.0 if base == "all-reduce" else 1.0
                if _spans_pods(ins.rest, self.n_devices, self.pod_size):
                    total.coll_bytes_dcn += w * payload
                else:
                    total.coll_bytes += w * payload
                total.coll_ops[base] = total.coll_ops.get(base, 0) + 1
                continue
            if op.endswith("-done"):
                continue
            if op == "dot":
                total.dot_flops += self._dot_flops(ins)
                total.hbm_bytes += (_shape_bytes(ins.type_str)
                                    + self._operand_bytes(ins))
                continue
            if op == "convolution":
                total.has_convolution = True
            if op in ("dynamic-slice", "dynamic-update-slice"):
                # in-place: only the moved slice counts
                ops_ = self._operands(ins)
                if op == "dynamic-update-slice" and len(ops_) >= 2:
                    upd = _shape_bytes(self._shape_of.get(ops_[1], ""))
                    total.hbm_bytes += 2 * upd
                else:
                    total.hbm_bytes += 2 * _shape_bytes(ins.type_str)
                continue
            if op == "gather":
                total.hbm_bytes += 2 * _shape_bytes(ins.type_str)
                continue
            if op == "scatter":
                ops_ = self._operands(ins)
                upd = _shape_bytes(self._shape_of.get(ops_[-1], "")) \
                    if ops_ else 0
                total.hbm_bytes += 2 * upd + _shape_bytes(ins.type_str) // 8
                continue
            if op == "fusion":
                total.hbm_bytes += self._fusion_bytes(ins)
                continue
            # generic op: operands + result
            total.hbm_bytes += (_shape_bytes(ins.type_str)
                                + self._operand_bytes(ins))
        return total

    def entry(self) -> HloCosts:
        for name, comp in self.comps.items():
            if "main" in name:
                return self.costs(name)
        # fallback: the largest computation
        name = max(self.comps, key=lambda n: len(self.comps[n].instrs))
        return self.costs(name)


# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dot_flops: float             # per device, per step
    hbm_bytes: float
    coll_bytes: float
    coll_bytes_dcn: float
    coll_ops: Dict[str, float]
    raw_cost_flops: float        # cost_analysis() (loop-undercounted)
    raw_cost_bytes: float
    model_flops: float           # 6*N*D (train) / 2*N*D (inference), global
    n_devices: int
    per_device_hbm: Optional[int] = None

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_seconds(self) -> float:
        """No-overlap upper bound: max term (perfect overlap) is the
        roofline; we report max() as the achievable step time."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        hlo_global = self.dot_flops * self.n_devices
        return self.model_flops / hlo_global if hlo_global else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs utilization at the roofline step time."""
        denom = self.step_seconds * self.n_devices * PEAK_FLOPS
        return self.model_flops / denom if denom else 0.0


def analyze(compiled, *, n_devices: int, pod_size: int = 1 << 30,
            model_flops: float = 0.0) -> Roofline:
    text = compiled.as_text()
    an = HloAnalyzer(text, n_devices, pod_size)
    c = an.entry()
    ca = {}
    try:
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):   # older jax: one dict per device
            ca = ca[0] if ca else {}
    except Exception:
        pass
    return Roofline(
        compute_s=c.dot_flops / PEAK_FLOPS,
        memory_s=c.hbm_bytes / HBM_BW,
        collective_s=c.coll_bytes / ICI_BW + c.coll_bytes_dcn / DCN_BW,
        dot_flops=c.dot_flops,
        hbm_bytes=c.hbm_bytes,
        coll_bytes=c.coll_bytes,
        coll_bytes_dcn=c.coll_bytes_dcn,
        coll_ops=c.coll_ops,
        raw_cost_flops=float(ca.get("flops", 0.0)),
        raw_cost_bytes=float(ca.get("bytes accessed", 0.0)),
        model_flops=model_flops,
        n_devices=n_devices,
    )


def memory_stats(compiled) -> Dict[str, int]:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        out[k] = int(getattr(ma, k, 0))
    out["total_hbm_bytes"] = (out["argument_size_in_bytes"]
                              + out["output_size_in_bytes"]
                              + out["temp_size_in_bytes"]
                              - out["alias_size_in_bytes"])
    out["fits_v5e_16g"] = out["total_hbm_bytes"] <= HBM_PER_CHIP
    return out
