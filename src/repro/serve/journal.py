"""Persistent request journal: detectable, exactly-once op semantics
(DESIGN.md §11, "Practical Detectability" blueprint from PAPERS.md).

The partly-persistent structures guarantee the *data* survives a crash;
this journal makes the *operations* detectable: every admission /
completion appends one sealed 64 B descriptor line to a persistent
append ring, and recovery replays the committed window to classify
every request as completed / must-retry / never-admitted — so the
serving path can refuse duplicate admissions and retry exactly the
requests whose effects never committed.

Partly-persistent split:

* ESSENTIAL — the ring entries (``{name}.jrnl``, one 64 B line each:
  ``[magic, seq, rid, op, digest, info, gen, cksum]``) and the HEAD /
  TAIL counters.
* DERIVABLE — the rid -> seq index (``_admit`` / ``_complete`` dicts),
  rebuilt by the registered ``serve.journal`` reconstructor.

MOD-style minimal ordering: the journal adds NO ordering points of its
own.  Entries are marked ``fresh`` into the enclosing epoch's write set
(every append targets a slot outside the committed live window — the
sealing rule — so the shadow drain homes them in place and the barrier
drain can never tear a committed entry), and visibility follows the
SAME convention as every structure header: the persisted HEAD/TAIL
counters ride a metadata line.  When the journal is hosted by a
structure whose header line is already marked every epoch (the request
hashmap marks header row 0 on every insert/remove), HEAD/TAIL piggyback
on that row's unused words — the structure's committed size and the
journal's committed head then share ONE 64 B line, so they can never
diverge across any crash point, and the journal's flush overhead is
exactly the one ring line per epoch counted in
``FlushStats.journal_lines``.

Crash-window argument (both commit modes): an entry is visible iff its
seq is under the committed HEAD.  Barrier mode — the ring line flushes
in the data phase, HEAD in the metadata phase; a torn (data-only) crash
leaves the entry bytes behind an unmoved HEAD, invisible.  Shadow mode
— the fresh ring line homes in place during the unordered drain, but
the header rewrite sits in the uncommitted mirror bank until the
generation flip; a pre-flip crash recovers the old header, same result.
A wrap append may overwrite a slot still inside a stale committed
window, but only RETIRED entries' slots are ever reused (``log``
refuses when head - tail >= capacity and ``retire_completed`` only
advances TAIL over completed pairs), so recovery skips the
seq-mismatched slot and the orphaned COMPLETE of the overwritten pair
still classifies its rid as completed.
"""
from __future__ import annotations

from typing import Dict, Optional, Set

import numpy as np

from repro.core import reconstruct as rec
from repro.core.arena import _splitmix64, mix_checksums, snap_checksum

JR_MAGIC = 0x4C4E524A            # "JRNL" little-endian
JR_WORDS = 8                     # int64 words per entry = one 64 B line

OP_ADMIT = 1                     # request admitted; effects pending
OP_COMPLETE = 2                  # request's effects fully applied
OP_APPLY = 3                     # single-epoch admit+complete fusion

ST_NEVER = "never-admitted"
ST_RETRY = "must-retry"
ST_DONE = "completed"

# piggyback base: the request hashmap's header row uses words 0-3
# (H_FLAG/H_SIZE/H_FRESH/H_BUCKETS); the journal takes words 4-5
HOST_HEADER_BASE = 4


class DuplicateRequestError(RuntimeError):
    """An already-journaled request id was admitted again."""


def args_digest(arr) -> int:
    """Order-sensitive splitmix64 fold of an int array — the per-op args
    fingerprint stored in the entry's digest word (recovery-side
    consumers can cross-check a retry carries the same payload)."""
    a = np.asarray(arr).astype(np.int64, copy=False).ravel().astype(np.uint64)
    x = np.uint64(0x9E3779B97F4A7C15)
    if a.size:
        mixed = _splitmix64(a + np.arange(1, a.size + 1, dtype=np.uint64))
        x = np.bitwise_xor.reduce(mixed)
    return int(_splitmix64(np.array([x ^ np.uint64(a.size)],
                                    np.uint64))[0].astype(np.int64))


class RequestJournal:
    """Partly-persistent append ring of per-request op descriptors.

    ``header``/``header_base``: the metadata row carrying the persisted
    HEAD/TAIL words.  Pass the host structure's header region to
    piggyback (words ``header_base``, ``header_base+1`` must be unused
    by the host); omit it for a standalone journal, which lays out its
    own ``{name}.jrnlheader`` line.
    """

    def __init__(self, arena, capacity: int, name: str = "jr",
                 header=None, header_base: int = HOST_HEADER_BASE):
        self.arena = arena
        self.capacity = int(capacity)
        self.name = name
        self.ring = arena.regions.get(f"{name}.jrnl") or arena.region(
            f"{name}.jrnl", np.int64, (self.capacity, JR_WORDS),
            router=("seg", 8))
        if header is None:
            header = arena.regions.get(f"{name}.jrnlheader") or arena.region(
                f"{name}.jrnlheader", np.int64, (1, 8))
            header_base = 0
        self.header = header
        self._hb = int(header_base)
        assert 0 <= self._hb <= 6
        # volatile redundancy (rebuilt by the serve.journal reconstructor)
        self.head = 0                       # next seq to append
        self.tail = 0                       # oldest live seq
        self._admit: Dict[int, int] = {}    # rid -> ADMIT/APPLY seq
        self._complete: Dict[int, int] = {} # rid -> COMPLETE/APPLY seq
        self._retired: Set[int] = set()     # seqs retired, tail not yet past

    @staticmethod
    def layout(capacity: int, name: str = "jr", standalone: bool = False):
        """Arena layout fragment.  Hosted journals (header piggyback)
        need only the ring; ``standalone=True`` adds the dedicated
        header line."""
        out = {f"{name}.jrnl": (np.int64, (int(capacity), JR_WORDS),
                                ("seg", 8))}
        if standalone:
            out[f"{name}.jrnlheader"] = (np.int64, (1, 8))
        return out

    # ------------------------------------------------------------- write
    def log(self, op: int, rid: int, digest: int = 0, info: int = 0) -> int:
        """Append one op descriptor inside the CURRENT epoch (the entry
        commits — or not — atomically with the host structure's own rows
        for this op).  Raises DuplicateRequestError on re-admission of a
        known rid; the dedup window is the ring capacity (retired rids
        fall out of it)."""
        assert self.arena._epoch_depth > 0, \
            "journal writes must ride an epoch"
        rid = int(rid)
        if op in (OP_ADMIT, OP_APPLY):
            st = self.state_of(rid)
            if st != ST_NEVER:
                raise DuplicateRequestError(
                    f"request {rid} already journaled as {st}")
        elif op == OP_COMPLETE:
            if rid not in self._admit:
                raise KeyError(f"request {rid} was never admitted")
            if rid in self._complete:
                raise DuplicateRequestError(
                    f"request {rid} already completed")
        else:
            raise ValueError(f"unknown journal op {op!r}")
        if self.head - self.tail >= self.capacity:
            raise MemoryError(
                "journal ring full — retire_completed() first")
        seq = self.head
        slot = seq % self.capacity
        row = np.array([JR_MAGIC, seq, rid, int(op), int(digest),
                        int(info), self.arena.generation + 1, 0], np.int64)
        row[7] = snap_checksum(row)
        self.ring.vol[slot] = row
        # sealing rule: the slot is outside the committed live window
        # (only retired slots are ever reused), hence fresh
        self.ring.mark_rows(np.array([slot]), fresh=True)
        hv = self.header.vol[0]
        hv[self._hb] = seq + 1
        hv[self._hb + 1] = self.tail
        self.header.mark_rows(np.array([0]))
        self.head = seq + 1
        if op == OP_ADMIT:
            self._admit[rid] = seq
        elif op == OP_COMPLETE:
            self._complete[rid] = seq
        else:                               # OP_APPLY
            self._admit[rid] = seq
            self._complete[rid] = seq
        return seq

    def retire_completed(self) -> int:
        """Drop completed rids from the volatile index and advance TAIL
        over the contiguous retired prefix, freeing their ring slots for
        reuse.  Volatile-only — the advanced TAIL persists with the next
        ``log``'s header line.  Must run OUTSIDE any epoch (a retire
        concurrent with an append could reuse a slot the same epoch's
        crash window still needs)."""
        assert self.arena._epoch_depth == 0, \
            "retire_completed must run outside epochs"
        n = 0
        for r in list(self._complete):
            self._retired.add(self._complete.pop(r))
            adm = self._admit.pop(r, None)
            if adm is not None:
                self._retired.add(adm)
            n += 1
        while self.tail < self.head and self.tail in self._retired:
            self._retired.discard(self.tail)
            self.tail += 1
        return n

    # -------------------------------------------------------------- read
    def state_of(self, rid: int) -> str:
        rid = int(rid)
        if rid in self._complete:
            return ST_DONE
        if rid in self._admit:
            return ST_RETRY
        return ST_NEVER

    def admitted(self, rid: int) -> bool:
        rid = int(rid)
        return rid in self._admit or rid in self._complete

    def classify(self) -> Dict[int, str]:
        """rid -> state for every request in the live window."""
        out = {r: ST_DONE for r in self._complete}
        for r in self._admit:
            out.setdefault(r, ST_RETRY)
        return out

    def must_retry(self) -> Set[int]:
        """Rids admitted but never completed — the replay set."""
        return {r for r in self._admit if r not in self._complete}

    def space(self) -> int:
        return self.capacity - (self.head - self.tail)


def _batch_cksum(rows: np.ndarray) -> np.ndarray:
    """Vectorized snap_checksum over (n, 8) entry rows — the shared
    ``mix_checksums`` mixer (DESIGN.md §13) over the first 7 words, so
    journal slots, snapshot records, and integrity sidecars all speak
    one checksum."""
    return mix_checksums(np.asarray(rows, np.int64)[:, :7])


@rec.register("serve.journal")
def _reconstruct_journal(j: RequestJournal) -> dict:
    """Pure rebuild of the volatile rid index from the committed window
    [TAIL, HEAD).  A window slot is accepted iff its magic, stored seq,
    and checksum all match; a mismatch is a retired entry's slot
    destroyed by an uncommitted later lap (the sealing rule — only
    retired slots are ever reused), so skipping it cannot change any
    live rid's classification (an orphaned COMPLETE still marks its rid
    completed)."""
    hv = j.header.vol[0]
    head, tail = int(hv[j._hb]), int(hv[j._hb + 1])
    j._admit, j._complete, j._retired = {}, {}, set()
    if not (0 <= tail <= head and head - tail <= j.capacity):
        # unreachable from any committed image (HEAD/TAIL share one
        # flushed line); garbage header words recover as empty
        j.head = j.tail = 0
        return {"window": 0, "entries": 0, "skipped": 0,
                "invalid_header": True}
    j.head, j.tail = head, tail
    detail = {"window": head - tail}
    seqs = np.arange(tail, head, dtype=np.int64)
    if seqs.size == 0:
        detail.update(entries=0, skipped=0, completed=0, must_retry=0)
        return detail
    rows = np.asarray(j.ring.vol[seqs % j.capacity], np.int64)
    valid = ((rows[:, 0] == JR_MAGIC) & (rows[:, 1] == seqs)
             & (rows[:, 7] == _batch_cksum(rows)))
    for seq, rid, op in zip(seqs[valid].tolist(),
                            rows[valid, 2].tolist(),
                            rows[valid, 3].tolist()):
        if op == OP_ADMIT:
            j._admit[rid] = seq
        elif op == OP_COMPLETE:
            j._complete[rid] = seq
        elif op == OP_APPLY:
            j._admit[rid] = seq
            j._complete[rid] = seq
    cls = j.classify()
    detail.update(entries=int(valid.sum()), skipped=int((~valid).sum()),
                  completed=sum(1 for s in cls.values() if s == ST_DONE),
                  must_retry=sum(1 for s in cls.values() if s == ST_RETRY))
    return detail
