"""Partly-persistent embedding/feature store with exactly-once request
semantics (the ROADMAP recommender workload; DESIGN.md §11).

A recommender-style serving path beyond the LLM KV-cache: requests
carry per-key embedding deltas (gradient-style updates).  The paper's
state split, applied per structure:

* ESSENTIAL — the embedding hashmap ``emb`` (key -> per-key apply
  counters; keys + NEXT chains persisted by the hashmap itself), the
  sample log (the B+Tree ``sx``: sample id -> (emb key, delta) — tree
  records ARE the log), and the request journal ring.
* DERIVABLE — the dense hot rows (``vectors``, one fixed-point
  accumulator row per hashmap slab slot) and the ``next_sample``
  cursor: both rebuilt by replaying the committed sample log.  Delta
  accumulation commutes, so the replay is one ``np.add.at`` scatter —
  order-free and vectorized.

Exactly-once: every ``apply`` journals one fused OP_APPLY descriptor in
the SAME epoch as its table/tree mutations.  After a crash, recovery
classifies each request off the committed journal window; a retry of a
completed request is refused (``apply`` returns False), a request whose
epoch never committed left no trace anywhere (the descriptor, the
samples, and the count bumps commit atomically) and retries cleanly.
This store is the first consumer the journal's guarantee is asserted
against — the duplicate-admission oracle in tests/test_async_recovery.py
crashes at every epoch boundary and replays the full workload, and the
twin uninterrupted run's effect-set must match exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import reconstruct as rec
from repro.core.arena import (CorruptLineError, QuarantinedError,
                              journal_enabled, open_arena)
from repro.core.recovery import RecoveryManager
from repro.pstruct.bptree import BPTree
from repro.pstruct.hashmap import KEY_NULL, Hashmap
from repro.pstruct.hashmap import H_FRESH as HM_FRESH
from repro.serve.journal import (OP_APPLY, ST_NEVER, RequestJournal,
                                 args_digest)

# the emb header line, word by word: the hashmap owns 0-3
# (H_FLAG/H_SIZE/H_FRESH/H_BUCKETS), the piggybacked journal takes 4-5
# (HEAD/TAIL), and the store's committed sample cursor rides word 6 —
# table size, journal head, and log cursor commit in ONE 64 B line, so
# no crash point can ever let them diverge.  The cursor must live here
# and not be derived from table values or tree keys: torn (data-phase)
# crashes leave in-place row rewrites visible-but-durable in both
# structures, and only metadata lines are crash-ordered.
FS_CURSOR = 6


@dataclasses.dataclass
class FeatureConfig:
    n_keys: int = 256             # embedding-table capacity (slab slots)
    dim: int = 4                  # delta words per key (<= 6: the tree
                                  # record packs (key, delta) in 7 words)
    n_samples: int = 1024         # sample-log capacity
    mode: str = "partly"
    n_shards: int = 1
    commit_mode: str = "barrier"
    chain_method: str = "auto"
    snapshot: Optional[bool] = None
    journal: Optional[bool] = None


class FeatureStore:
    def __init__(self, cfg: FeatureConfig, path: Optional[str] = None):
        assert 1 <= cfg.dim <= 6
        self.cfg = cfg
        node_cap = max(64, cfg.n_samples // 4)
        layout = dict(Hashmap.layout(cfg.n_keys, cfg.mode, name="emb",
                                     snapshot=cfg.snapshot))
        layout.update(BPTree.layout(node_cap, cfg.n_samples, cfg.mode,
                                    name="sx"))
        jr_cap = 2 * cfg.n_samples
        if journal_enabled(cfg.journal):
            layout.update(RequestJournal.layout(jr_cap, name="emb"))
        self.arena = open_arena(path, layout, n_shards=cfg.n_shards,
                                commit_mode=cfg.commit_mode)
        self.table = Hashmap(self.arena, cfg.n_keys, cfg.mode, name="emb",
                             chain_method=cfg.chain_method,
                             snapshot=cfg.snapshot)
        self.tree = BPTree(self.arena, node_cap, cfg.n_samples, cfg.mode,
                           name="sx", chain_method=cfg.chain_method)
        # HEAD/TAIL piggyback on the emb header line, which apply()
        # marks every epoch through insert_batch — same one-ring-line
        # overhead argument as the engine journal (DESIGN.md §11)
        self.journal = RequestJournal(
            self.arena, jr_cap, name="emb", header=self.table.header) \
            if journal_enabled(cfg.journal) else None
        # DERIVABLE hot rows + per-key apply counters, indexed by
        # hashmap slab slot; both replayed from the committed sample log
        self.vectors = np.zeros((cfg.n_keys, cfg.dim), np.int64)
        self.counts = np.zeros(cfg.n_keys, np.int64)
        self.next_sample = 0
        self.last_recovery = None
        # keys whose state was lost to media corruption in the last
        # salvage recovery: lookup/apply refuse them until readmit()
        self.quarantined_keys: set = set()

    # ------------------------------------------------------------- write
    def apply(self, rid: int, keys, deltas, _torn_crash: bool = False
              ) -> bool:
        """Apply one request's embedding deltas, exactly once.  Returns
        False (no effects) when the journal has already seen ``rid`` —
        the crash-retry path replays its whole workload and completed
        requests are refused here.  One atomic epoch: per-key counter
        bumps in the table, the request's samples appended to the log,
        and the fused OP_APPLY descriptor.  ``_torn_crash`` is the
        crash-injection hook: flush the data phase, then lose power
        before the commit (tests/test_async_recovery.py)."""
        rid = int(rid)
        keys = np.asarray(keys, np.int64)
        deltas = np.asarray(deltas, np.int64).reshape(len(keys),
                                                      self.cfg.dim)
        assert len(np.unique(keys)) == len(keys), \
            "apply expects unique keys per request"
        self._refuse_quarantined(keys)
        if self.journal is not None and \
                self.journal.state_of(rid) != ST_NEVER:
            return False
        if self.next_sample + len(keys) > self.cfg.n_samples:
            raise MemoryError("sample log full")
        sids = np.arange(self.next_sample, self.next_sample + len(keys),
                         dtype=np.int64)
        # value rows are written from VOLATILE truth, never
        # read-modify-write of the table copy: a torn crash can leave an
        # uncommitted in-place value rewrite durable, and incrementing
        # that on retry would double-count
        slots0 = self.table._find_slots(keys)
        pre = np.where(slots0 >= 0,
                       self.counts[np.clip(slots0, 0, None)], 0)
        with self.arena.epoch():
            # per-key value row: word 0 = applied-sample count, word 1 =
            # last sample id.  ALWAYS rewritten for every touched key,
            # so the emb.header line is marked every apply epoch (the
            # journal's piggyback ride).
            vals = np.zeros((len(keys), 7), np.int64)
            vals[:, 0] = pre + 1
            vals[:, 1] = sids
            self.table.insert_batch(keys, vals)
            self.table.header.vol[0, FS_CURSOR] = \
                self.next_sample + len(keys)
            recs = np.zeros((len(keys), 7), np.int64)
            recs[:, 0] = keys
            recs[:, 1:1 + self.cfg.dim] = deltas
            self.tree.insert_batch(sids, recs)
            if self.journal is not None:
                self.journal.log(
                    OP_APPLY, rid,
                    digest=args_digest(np.concatenate([keys,
                                                       deltas.ravel()])),
                    info=len(keys))
            if _torn_crash:
                self.arena.writeset.flush(include_meta=False)
                self.crash()
                return False
            self.arena.commit()
        slots = self.table._find_slots(keys)
        np.add.at(self.vectors, slots, deltas)
        self.counts[slots] = pre + 1
        self.next_sample += len(keys)
        return True

    def _refuse_quarantined(self, keys) -> None:
        if not self.quarantined_keys:
            return
        bad = sorted(int(k) for k in np.atleast_1d(keys)
                     if int(k) in self.quarantined_keys)
        if bad:
            raise QuarantinedError(
                f"keys {bad} were lost to media corruption in the last "
                "salvage recovery; readmit() them to start fresh")

    def readmit(self, keys) -> None:
        """Lift the quarantine on ``keys``: the caller accepts that the
        lost history is gone and wants the keys writable again (their
        accumulators restart from the salvaged committed state)."""
        self.quarantined_keys -= {int(k) for k in np.atleast_1d(keys)}

    # -------------------------------------------------------------- read
    def lookup(self, keys) -> np.ndarray:
        """Dense embedding rows for ``keys`` (zeros for absent keys).
        Raises QuarantinedError if any key's state was lost to media
        corruption in the last salvage recovery."""
        keys = np.asarray(keys, np.int64)
        self._refuse_quarantined(keys)
        slots = self.table._find_slots(keys)
        out = np.zeros((len(keys), self.cfg.dim), np.int64)
        ok = slots >= 0
        out[ok] = self.vectors[slots[ok]]
        return out

    # ---------------------------------------------------------- recovery
    def crash(self) -> None:
        self.vectors = np.zeros_like(self.vectors)
        self.counts = np.zeros_like(self.counts)
        self.next_sample = 0
        self.arena.crash()

    def recover(self, concurrency: int = 1, on_stage=None,
                salvage: bool = False):
        mgr = RecoveryManager(self.arena)
        emb_regions = tuple(n for n in self.arena.regions
                            if n.startswith("emb.")
                            and not n.endswith(".jrnl")
                            and not n.endswith(".integ"))
        sx_regions = tuple(n for n in self.arena.regions
                           if n.startswith("sx.")
                           and not n.endswith(".integ"))
        mgr.add("emb", "pstruct.hashmap", self.table, regions=emb_regions)
        mgr.add("samples", "pstruct.bptree", self.tree, regions=sx_regions)
        deps = ("emb", "samples")
        if self.journal is not None:
            mgr.add("journal", "serve.journal", self.journal,
                    regions=("emb.jrnl", "emb.header"))
            deps += ("journal",)
        mgr.add("store", "serve.feature_store", self, depends=deps,
                regions=())
        report = mgr.recover(concurrency=concurrency, on_stage=on_stage,
                             salvage=salvage)
        self.last_recovery = report
        if salvage:
            # belt and braces: even if the store stage was skipped
            # (quarantined dependency), table-level losses still gate
            self.quarantined_keys |= {
                int(k) for k in getattr(self.table, "quarantined", ())}
        return report


@rec.register("serve.feature_store")
def _reconstruct_feature_store(fs: FeatureStore) -> dict:
    """Pure rebuild of the hot rows: replay the committed sample log
    (tree records) into the slot-indexed accumulators with one
    ``np.add.at`` scatter — commutative deltas make the replay
    order-free.  The committed cursor comes from the header line's
    FS_CURSOR word, NOT from ``tree.max_key()`` or table values: a torn
    (data-phase-only) crash leaves in-place row rewrites
    visible-but-durable in both slabs
    (test_torn_bptree_leaf_rewrite_is_visible_but_durable), so only the
    crash-ordered metadata line can say where the committed prefix
    ends.  Torn tree records beyond the cursor are ignored here and
    overwritten in place when the request retries (tree inserts are
    insert-or-update).  Within the committed prefix, holes or unknown
    keys ARE corruption: fail loudly (detectability over silent
    drift)."""
    cfg = fs.cfg
    salvage = bool(getattr(fs.arena, "_salvage", False))
    fs.quarantined_keys = ({int(k) for k in
                            getattr(fs.table, "quarantined", ())}
                           if salvage else set())
    fs.vectors = np.zeros((cfg.n_keys, cfg.dim), np.int64)
    fs.counts = np.zeros(cfg.n_keys, np.int64)
    fs.next_sample = int(fs.table.header.vol[0, FS_CURSOR])
    if not 0 <= fs.next_sample <= cfg.n_samples:
        if salvage:
            raise CorruptLineError(
                "emb.header", np.array([0], np.int64),
                detail=f"committed sample cursor {fs.next_sample} "
                       "out of range")
        raise RuntimeError(
            f"committed sample cursor {fs.next_sample} out of range")
    replayed = missing = 0
    if fs.next_sample:
        sids = np.arange(fs.next_sample, dtype=np.int64)
        ok, recs = fs.tree.find_batch(sids)
        if not ok.all():
            if not salvage:
                raise RuntimeError(
                    f"sample log has holes: {int((~ok).sum())} "
                    "missing ids")
            # salvage: quarantined/lost log records replay as holes —
            # the per-key count cross-check below names the losers
            missing = int((~ok).sum())
            recs = recs[ok]
        keys = recs[:, 0]
        slots = fs.table._find_slots(keys)
        if (slots < 0).any():
            if not salvage:
                raise RuntimeError(
                    "sample log names keys absent from the committed "
                    "table")
            # the table lost these keys (row quarantined): their log
            # records survive and name them precisely
            fs.quarantined_keys.update(int(k) for k in keys[slots < 0])
            keep = slots >= 0
            recs, slots = recs[keep], slots[keep]
        np.add.at(fs.vectors, slots, recs[:, 1:1 + cfg.dim])
        np.add.at(fs.counts, slots, 1)
        replayed = int(slots.size) if salvage else int(sids.size)
    if salvage:
        # cross-check: the table's committed per-key apply counters vs
        # the replayed ones — any key whose samples were lost (the log
        # record was corrupt, so the key inside it is unreadable) shows
        # up as a counter shortfall and quarantines BY NAME here
        fresh = int(fs.table.header.vol[0, HM_FRESH])
        tk = np.asarray(fs.table.keys[:fresh], np.int64)
        tv = np.asarray(fs.table.values[:fresh], np.int64)
        bad = (tk != KEY_NULL) & (tv[:, 0] != fs.counts[:fresh])
        fs.quarantined_keys.update(int(k) for k in tk[bad])
    detail = {"samples": replayed, "keys": int(fs.table.size)}
    if fs.journal is not None:
        cls = fs.journal.classify()
        detail["journal_completed"] = sum(
            1 for s in cls.values() if s == "completed")
    if salvage and (fs.quarantined_keys or missing):
        detail.update(degraded=True, missing_samples=missing,
                      quarantined_keys=sorted(fs.quarantined_keys))
    return detail
