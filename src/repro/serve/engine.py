"""Serving engine: batched decode with partly-persistent session state.

State classification (the paper's contract, applied to serving):
* ESSENTIAL  — request table (Hashmap: rid -> slot/lengths) and the token
  log (prompt + generated tokens per slot), both arena-backed;
* DERIVABLE  — everything on device: KV caches / recurrent states are
  rebuilt by re-prefilling the persisted token log after a crash; the
  paged-LRU metadata reconstructs from its persistent NEXT chain
  (kvcache.PagedAllocator).

The decode path runs a jit'd `decode_step` over fixed batch slots
(slot-contiguous caches; the paged allocator manages page *metadata* —
documented simplification, DESIGN.md §3).  Greedy sampling keeps recovery
bit-checkable: tokens generated after recovery must equal an uninterrupted
run, which tests/test_serving.py asserts.

Early traffic admission (DESIGN.md §6): the engine holds a per-slot
readiness bitmap (`slot_ready`).  A crash clears it; recovery re-admits
each slot the moment its grouped re-prefill lands — `step()` decodes
ready slots and skips the rest, and `add_request` only seats new work on
ready slots — so serving resumes at the first admitted group instead of
barriering on the full RecoveryReport.  `on_slot_ready` callbacks fire
per admitted group (slots, prompt length, seconds since recovery start).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import reconstruct as rec
from repro.core.arena import (CorruptLineError, QuarantinedError,
                              journal_enabled, open_arena)
from repro.core.recovery import RecoveryManager, RecoveryReport
from repro.pstruct.dll import _salvage_bad_rows
from repro.models.model import Model
from repro.pstruct.hashmap import H_FRESH as HM_FRESH
from repro.pstruct.hashmap import Hashmap
from repro.serve.journal import (OP_ADMIT, OP_COMPLETE, ST_NEVER,
                                 DuplicateRequestError, RequestJournal,
                                 args_digest)
from repro.serve.kvcache import PagedAllocator, PagedConfig

# request-table value row: (slot, prompt_len, total_len, active, 0, 0, 0)
V_SLOT, V_PLEN, V_TLEN, V_ACTIVE = range(4)


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 4
    s_max: int = 128
    max_requests: int = 64
    mode: str = "partly"          # persistence mode for host structures
    page_tokens: int = 16
    # Shard count of the host persistence substrate (DESIGN.md §7): the
    # token-log slab stripes slot-per-shard, the request hashmap's slab
    # hashes across shards, and the paged-KV metadata arena shards too —
    # recovery re-admits traffic per (shard, prompt-length) group.
    n_shards: int = 1
    # Commit protocol of the host persistence substrate: "barrier" pays
    # the two-phase data/metadata ordering each epoch; "shadow" routes
    # rewrites through shadow banks and pays ONE flip (DESIGN.md §9)
    commit_mode: str = "barrier"
    # Chain-ranking strategy for every recovery NEXT walk (request-table
    # unlinks, LRU ring scan): doubling vs contraction list ranking
    # (core.recovery.chain_method, DESIGN.md §8)
    chain_method: str = "auto"
    # Incremental order snapshots (DESIGN.md §10) for the request
    # hashmap and the paged-KV LRU: None defers to REPRO_SNAPSHOT,
    # True/False overrides.  Gates TTFT-after-crash — recovery replays
    # only the suffix of rows younger than the newest committed
    # snapshot instead of ranking the whole chain.
    snapshot: Optional[bool] = None
    # Page-pool capacity override (None = the max_batch * s_max /
    # page_tokens working-set minimum).  Capacity planning headroom —
    # and the axis the --snapshot-slo bench grows 10x to show recovery
    # cost tracking the LIVE suffix, not the pool size.
    n_pages: Optional[int] = None
    # Persistent request journal (DESIGN.md §11): one sealed descriptor
    # line per admission/completion rides each epoch's flush, so
    # recovery classifies every request completed / must-retry /
    # never-admitted and refuses duplicate admissions.  None defers to
    # REPRO_JOURNAL, True/False overrides; journal-off layouts are
    # bit-identical to the pre-journal engine.
    journal: Optional[bool] = None
    # Paged regions (DESIGN.md §12): None defers to the REPRO_PAGED env
    # gate (default off).  With paging on, large data regions (the
    # token-log slab, the LRU node slab) keep only a block-cache-bounded
    # volatile working set, and recovery faults blocks on demand.
    paged: Optional[bool] = None
    block_bytes: int = 4096
    cache_blocks: int = 1024


class ServingEngine:
    def __init__(self, model: Model, params, cfg: EngineConfig,
                 arena_path: Optional[str] = None):
        self.model = model
        self.params = params
        self.cfg = cfg
        layout = dict(Hashmap.layout(cfg.max_requests, cfg.mode, name="req",
                                     snapshot=cfg.snapshot))
        # token-log rows stripe slot-per-shard: re-prefill after a crash
        # reads each slot's prompt from its own shard file
        layout["tokens"] = (np.int32, (cfg.max_batch, cfg.s_max),
                            ("seg", 1))
        # journal ring appended LAST: journal-off layouts keep every
        # shared region at its pre-journal offset (bit-identical)
        jr_cap = 4 * cfg.max_requests
        if journal_enabled(cfg.journal):
            layout.update(RequestJournal.layout(jr_cap, name="req"))
        self.arena = open_arena(arena_path, layout, n_shards=cfg.n_shards,
                                commit_mode=cfg.commit_mode,
                                paged=cfg.paged, block_bytes=cfg.block_bytes,
                                cache_blocks=cfg.cache_blocks)
        self.table = Hashmap(self.arena, cfg.max_requests, cfg.mode,
                             name="req", chain_method=cfg.chain_method,
                             snapshot=cfg.snapshot)
        # HEAD/TAIL piggyback on the request hashmap's header line
        # (words 4-5, unused by the hashmap), which every admission /
        # completion epoch already marks — journal overhead is exactly
        # the one ring line per epoch (FlushStats.journal_lines)
        self.journal = RequestJournal(
            self.arena, jr_cap, name="req", header=self.table.header) \
            if journal_enabled(cfg.journal) else None
        self.tok_region = self.arena.regions["tokens"]
        self.paging = PagedAllocator(PagedConfig(
            n_pages=max(cfg.n_pages or 0,
                        cfg.max_batch * (cfg.s_max // cfg.page_tokens)),
            page_tokens=cfg.page_tokens, mode=cfg.mode,
            n_shards=cfg.n_shards, commit_mode=cfg.commit_mode,
            chain_method=cfg.chain_method, snapshot=cfg.snapshot,
            paged=cfg.paged, block_bytes=cfg.block_bytes,
            cache_blocks=cfg.cache_blocks))
        # device state (DERIVABLE)
        self.cache = model.init_cache(cfg.max_batch, cfg.s_max)
        self.pos = np.zeros(cfg.max_batch, np.int64)       # per-slot length
        self.slot_rid = np.full(cfg.max_batch, -1, np.int64)
        # slot-granular admission: all ready in steady state; a crash
        # clears the bitmap and recovery re-admits per prefill group
        self.slot_ready = np.ones(cfg.max_batch, bool)
        self.on_slot_ready: Optional[Callable[[np.ndarray, int, float],
                                              None]] = None
        self._cache_lock = threading.Lock()
        # admission events serialize (like manager stage listeners), so
        # check-then-act callbacks stay race-free under pooled prefill;
        # distinct from _cache_lock so a callback may decode (step())
        self._admit_lock = threading.Lock()
        self._recover_concurrency = 1
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(lambda p, b: model.prefill(
            p, b, s_max=cfg.s_max))
        self.last_recovery: Optional[RecoveryReport] = None
        # rids lost to media corruption in the last salvage recovery:
        # admission refuses them (QuarantinedError) until readmit()
        self.quarantined_rids: set = set()

    # ------------------------------------------------------------------
    def _free_slot(self) -> int:
        for i in range(self.cfg.max_batch):
            if self.slot_rid[i] < 0 and self.slot_ready[i]:
                return i
        raise RuntimeError("no free slots")

    def add_request(self, rid: int, prompt: np.ndarray) -> int:
        if int(rid) in self.quarantined_rids:
            raise QuarantinedError(
                f"request {rid} was lost to media corruption in the last "
                "salvage recovery; readmit() it explicitly to resubmit")
        if self.journal is not None:
            st = self.journal.state_of(rid)
            if st != ST_NEVER:
                raise DuplicateRequestError(
                    f"request {rid} already journaled as {st}")
        slot = self._free_slot()
        plen = len(prompt)
        # ESSENTIAL: token log row + request-table entry (+ journal
        # admission descriptor), one epoch — all or none of it commits
        with self.arena.epoch():
            self.tok_region.write_at(np.asarray([slot], np.int64),
                                     slice(0, plen),
                                     np.asarray(prompt)[None])
            self.tok_region.mark_range(slot, slot + 1)
            val = np.zeros((1, 7), np.int64)
            val[0, :4] = [slot, plen, plen, 1]
            self.table.insert_batch(np.array([rid], np.int64), val)
            self.paging.alloc(rid, -(-plen // self.cfg.page_tokens))
            if self.journal is not None:
                self.journal.log(OP_ADMIT, rid,
                                 digest=args_digest(prompt), info=slot)
            self.arena.commit()
        # DERIVABLE: device prefill into the slot
        self._prefill_slot(slot, prompt)
        self.slot_rid[slot] = rid
        self.pos[slot] = plen
        return slot

    def _prefill_slot(self, slot: int, tokens: np.ndarray) -> None:
        self._prefill_slots(np.asarray([slot], np.int64),
                            np.asarray(tokens)[None])

    def _prefill_slots(self, slots: np.ndarray, tokens: np.ndarray) -> None:
        """Prefill a group of slots sharing one prompt length with a
        single batched model call (tokens: (g, plen)), then scatter the
        (g, ...) cache rows into their slots with one indexed device
        update per cache leaf — the grouped re-prefill unit of the
        batched recovery path."""
        g = len(slots)
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
        if self.model.cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (g, self.model.cfg.encoder_seq, self.model.cfg.d_model),
                self.model.compute_dtype)
        if self.model.cfg.family == "vlm":
            batch["context"] = jnp.zeros(
                (g, self.model.cfg.context_seq, self.model.cfg.d_model),
                self.model.compute_dtype)
        _, kv = self._prefill(self.params, batch)
        idx = jnp.asarray(slots, jnp.int32)
        # the model call above runs lock-free (groups prefill in
        # parallel under recover(concurrency>1)); the read-modify-write
        # scatter of the shared cache tree serializes
        with self._cache_lock:
            self.cache = _map_slot(
                self.cache, kv,
                lambda full, grp, ax: _scatter_batch(
                    full, grp.astype(full.dtype), idx, ax))

    def step(self) -> Dict[int, int]:
        """One greedy decode step for every active slot.  Returns
        {rid: token}.  Per-slot positions differ, so slots run their own
        decode_step (jit'd once; static shapes).

        The whole step is one persistence epoch: every slot's token-log
        row and table entry flush once at the closing commit, not once
        per slot."""
        out: Dict[int, int] = {}
        with self.arena.epoch():
            for slot in range(self.cfg.max_batch):
                rid = int(self.slot_rid[slot])
                if rid < 0 or not self.slot_ready[slot]:
                    continue
                p = int(self.pos[slot])
                if p >= self.cfg.s_max:
                    continue
                last_tok = int(self.tok_region.read_one(slot, p - 1))
                logits = self._decode_slot(slot, last_tok, p)
                tok = int(np.asarray(jnp.argmax(logits)))
                # ESSENTIAL: append the generated token + bump lengths
                self.tok_region.write_at(np.asarray([slot], np.int64),
                                         p, tok)
                self.tok_region.mark_range(slot, slot + 1)
                val = np.zeros((1, 7), np.int64)
                val[0, :4] = [slot, 0, 0, 1]
                ok, cur = self.table.find_batch(np.array([rid], np.int64))
                cur[0, V_TLEN] += 1
                self.table.insert_batch(np.array([rid], np.int64), cur)
                self.pos[slot] = p + 1
                out[rid] = tok
            self.arena.commit()
        return out

    def finish_request(self, rid: int) -> int:
        """Retire a completed request: journal the completion and
        tombstone its table entry in ONE epoch (the COMPLETE descriptor
        and the table removal share the req.header flush line, so they
        commit atomically), then release its pages and slot.  Returns
        the final token count."""
        rid = int(rid)
        ok, val = self.table.find_batch(np.array([rid], np.int64))
        if not ok[0] or int(val[0, V_ACTIVE]) != 1:
            raise KeyError(f"request {rid} is not active")
        slot, tlen = int(val[0, V_SLOT]), int(val[0, V_TLEN])
        with self.arena.epoch():
            if self.journal is not None:
                toks = np.asarray(self.tok_region.read_at(
                    np.asarray([slot], np.int64),
                    slice(0, tlen))[0], np.int64)
                self.journal.log(OP_COMPLETE, rid,
                                 digest=args_digest(toks), info=tlen)
            self.table.remove_batch(np.array([rid], np.int64))
            self.arena.commit()
        self.paging.free_request(rid)
        self.slot_rid[slot] = -1
        self.pos[slot] = 0
        return tlen

    def _decode_slot(self, slot: int, token: int, p: int):
        # extract the slot's cache, run decode at B=1, re-seat it.  A
        # ready slot is never a re-prefill target, so the extracted rows
        # cannot change underneath the decode — but the re-seat is a
        # read-modify-write of the SHARED cache tree, which must not
        # lose a sibling prefill group's scatter during early-admission
        # decoding (step() inside an on_slot_ready callback while
        # recovery is still prefilling other slots)
        one = _map_slot(
            self.cache, self.cache,
            lambda full, _, ax: jax.lax.dynamic_slice_in_dim(
                full, slot, 1, axis=ax))
        logits, one2 = self._decode(self.params, one,
                                    jnp.asarray([token], jnp.int32),
                                    jnp.asarray(p, jnp.int32))
        # the cache updates ONLY here, inside the lock — returning it for
        # reassignment at the call site would re-introduce the lost-update
        # window this lock closes
        with self._cache_lock:
            self.cache = _map_slot(
                self.cache, one2,
                lambda full, o, ax: jax.lax.dynamic_update_slice_in_dim(
                    full, o.astype(full.dtype), slot, axis=ax))
        return logits[0]

    # ------------------------------------------------------------------
    def crash(self) -> None:
        """Drop ALL device + volatile host state.  No slot is ready to
        serve until recovery re-admits it."""
        self.cache = None
        self.pos = None
        self.slot_rid = None
        self.slot_ready = np.zeros(self.cfg.max_batch, bool)
        self.arena.crash()

    def readmit(self, rids) -> None:
        """Abandon quarantined ``rids``: lift the admission gate and —
        when journaling — close each rid's exactly-once accounting with
        a COMPLETE descriptor (its effects are unrecoverable, so the
        retry obligation is formally discharged; a resubmission is a
        NEW request under a new rid, per the journal's dedup window)."""
        rids = {int(r) for r in np.atleast_1d(rids)}
        self.quarantined_rids -= rids
        if self.journal is None:
            return
        stale = [r for r in sorted(rids)
                 if r in self.journal._admit
                 and r not in self.journal._complete]
        if stale:
            with self.arena.epoch():
                for r in stale:
                    self.journal.log(OP_COMPLETE, r, info=-1)
                self.arena.commit()

    def recover(self, concurrency: int = 1,
                on_stage=None, salvage: bool = False) -> float:
        """Paper-style recovery through the unified manager: reopen the
        arenas once, then reconstruct in dependency order — request
        hashmap + LRU chain (independent: one topological level), page
        tables, engine slots (batched slab scan + grouped re-prefill).
        ``concurrency>1`` runs independent stages AND the engine's
        prefill groups in thread pools, and slots are re-admitted
        (``slot_ready``) group by group as their prefill lands.
        ``salvage=True`` rides the manager's salvage mode (DESIGN.md
        §13): corrupted stages quarantine instead of aborting, and rids
        whose table entry or token-log row was lost land in
        ``quarantined_rids`` — admission refuses exactly those until
        ``readmit()``.  Returns seconds; the staged RecoveryReport
        lands in ``last_recovery``."""
        self._recover_concurrency = max(1, int(concurrency))
        # .jrnl rings load with the journal stage, .integ sidecars with
        # the arena-level verify paths — neither belongs to the table's
        # own load stage
        req_regions = tuple(n for n in self.arena.regions
                            if n.startswith("req.")
                            and not n.endswith(".jrnl")
                            and not n.endswith(".integ"))
        mgr = RecoveryManager(self.arena, self.paging.arena)
        mgr.add("req_table", "pstruct.hashmap", self.table,
                regions=req_regions)
        lru_regions = ("lru.nodes", "lru.header")
        if self.paging.lru.snapshot:
            lru_regions += ("lru.snapring", "lru.snaprec")
        mgr.add("lru", "pstruct.dll", self.paging.lru, regions=lru_regions)
        mgr.add("pages", "serve.paged_alloc", self.paging,
                depends=("lru",), regions=("lru.nodes",))
        eng_deps = ("req_table", "pages")
        if self.journal is not None:
            # replay the committed journal window, then cross-check the
            # classification against the recovered table in the engine
            # stage (detectable exactly-once semantics, DESIGN.md §11)
            mgr.add("journal", "serve.journal", self.journal,
                    regions=("req.jrnl", "req.header"))
            eng_deps += ("journal",)
        mgr.add("engine", "serve.engine", self, depends=eng_deps,
                regions=req_regions + ("tokens",))
        report = mgr.recover(concurrency=concurrency, on_stage=on_stage,
                             salvage=salvage)
        self.last_recovery = report
        self.quarantined_rids = {
            int(k) for k in getattr(self.table, "quarantined", ())}
        return report.total_seconds


@rec.register("serve.engine")
def _reconstruct_engine(eng: "ServingEngine") -> dict:
    """Pure rebuild of the engine's DERIVABLE state from the recovered
    request table: one vectorized scan over the dense entry slab (no
    per-entry Python loop), then grouped re-prefill — slots sharing a
    (token-log shard, prompt length) pair share a single batched prefill
    call.  Each group's slots are re-admitted (``slot_ready``) the
    moment its prefill lands, and ``on_slot_ready`` fires with the
    admission offset — empty slots admit right after the scan, so new
    requests need not wait for old ones to re-prefill.  On a sharded
    arena admission goes per SHARD-GROUP (DESIGN.md §7): each group
    reads only its own shard's token rows, so groups stream out of
    independent shard files instead of queueing behind one; on
    ``n_shards=1`` the grouping degenerates to the per-length grouping
    exactly.  Groups run in a thread pool when the engine is recovering
    with ``concurrency>1`` (model calls parallel, cache scatter
    serialized by the cache lock)."""
    cfg = eng.cfg
    t0 = time.perf_counter()
    eng.cache = eng.model.init_cache(cfg.max_batch, cfg.s_max)
    eng.pos = np.zeros(cfg.max_batch, np.int64)
    eng.slot_rid = np.full(cfg.max_batch, -1, np.int64)
    fresh = int(eng.table.header.vol[0, HM_FRESH])
    keys = eng.table.keys[:fresh]
    vals = eng.table.values[:fresh]
    # valid rids are non-negative; KEY_NULL tombstones are negative too,
    # so one sign check covers both
    live = (keys >= 0) & (vals[:, V_ACTIVE] == 1)
    salvage = bool(getattr(eng.arena, "_salvage", False))
    lost_tok = 0
    if salvage:
        # token-log salvage: a corrupt slot row loses its request's
        # prompt — the table entry is intact, so the rid quarantines by
        # name and its slot frees for new work
        bad_slots = _salvage_bad_rows(eng.arena, eng.tok_region)
        if bad_slots.size:
            hit = live & np.isin(vals[:, V_SLOT], bad_slots)
            eng.table.quarantined.update(int(k) for k in keys[hit])
            live = live & ~hit
            lost_tok = int(hit.sum())
    lost = set(getattr(eng.table, "quarantined", ()))
    if eng.journal is not None:
        # the journal's must-retry set and the table's live set are two
        # independent persisted records of the same fact; the shared
        # req.header flush line makes divergence impossible in any
        # committed image, so a mismatch here is corruption — fail
        # loudly instead of double-admitting (DESIGN.md §11)
        retry = eng.journal.must_retry()
        table_live = {int(k) for k in keys[live]}
        if salvage and lost:
            # rids cut out by salvage are EXPECTED to diverge: the
            # journal still remembers admissions the table lost
            retry = retry - lost
            table_live = table_live - lost
        if retry != table_live:
            msg = ("journal/table divergence after recovery: journal "
                   f"must-retry={sorted(retry)} vs table live="
                   f"{sorted(table_live)}")
            if salvage:
                # residual divergence IS corruption — quarantine the
                # engine stage rather than abort the whole recovery
                raise CorruptLineError("req.jrnl", np.empty(0, np.int64),
                                       detail=msg)
            raise RuntimeError(msg)
    slots = vals[live, V_SLOT]
    tlens = vals[live, V_TLEN]
    eng.slot_rid[slots] = keys[live]
    eng.pos[slots] = tlens
    # admit everything the scan proved empty; occupied slots stay gated
    # until their group's prefill lands
    ready = np.ones(cfg.max_batch, bool)
    ready[slots] = False
    eng.slot_ready = ready
    shards = eng.arena.region_shards("tokens", slots)
    groups = sorted({(int(s), int(tl)) for s, tl in zip(shards, tlens)})

    def prefill_group(key: Tuple[int, int]) -> float:
        shard, tl = key
        sel = slots[(shards == shard) & (tlens == tl)]
        eng._prefill_slots(sel, np.asarray(
            eng.tok_region.read_at(sel, slice(0, tl)), np.int32))
        with eng._admit_lock:
            eng.slot_ready[sel] = True
            admitted = time.perf_counter() - t0
            cb = eng.on_slot_ready
            if cb is not None:
                cb(sel, int(tl), admitted)
        return admitted

    conc = max(1, int(eng._recover_concurrency))
    if conc > 1 and len(groups) > 1:
        with ThreadPoolExecutor(
                max_workers=min(conc, len(groups))) as ex:
            admissions = list(ex.map(prefill_group, groups))
    else:
        admissions = [prefill_group(g) for g in groups]
    out = {"requests": int(live.sum()),
           "prefill_groups": len(groups),
           "shard_groups": int(np.unique(shards).size) if slots.size
           else 0,
           "first_admission_s": round(min(admissions), 6)
           if admissions else 0.0,
           "last_admission_s": round(max(admissions), 6)
           if admissions else 0.0}
    if lost:
        out.update(degraded=True, quarantined_rids=sorted(lost),
                   lost_token_rows=lost_tok)
    return out


def _scatter_batch(full, grp, idx, ax):
    """full.at[slots].set(rows) along the structural batch axis."""
    if ax == 0:
        return full.at[idx].set(grp)
    return full.at[:, idx].set(grp)


def _map_slot(full_tree, other_tree, fn):
    """Apply fn(full_leaf, other_leaf, batch_axis) over a cache pytree.
    The batch axis is structural, not shape-inferred: leaves under the
    stacked "blocks" subtree carry a leading superblock dim (batch at axis
    1); leaves under "rem" have batch at axis 0."""
    out = dict(full_tree)
    if "blocks" in full_tree:
        out["blocks"] = jax.tree.map(lambda f, o: fn(f, o, 1),
                                     full_tree["blocks"],
                                     other_tree["blocks"])
    if "rem" in full_tree:
        out["rem"] = jax.tree.map(lambda f, o: fn(f, o, 0),
                                  full_tree["rem"], other_tree["rem"])
    return out
