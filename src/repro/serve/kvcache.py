"""Paged KV-cache allocator — the framework's live DLL use-case.

Device tensors hold the actual KV pages; this module manages the *page
metadata* host-side, exactly the shape of state the paper targets:

* page table (request -> page list) + request payloads: ESSENTIAL
  (persisted through the arena; 64 B rows);
* the free list and the LRU eviction order: a DoublyLinkedList whose NEXT
  chain is persistent and whose PREV/tail/order-ring are volatile
  redundancy, reconstructed after a crash (paper §IV-C);
* the KV page *contents* on device: DERIVABLE — re-prefilled from the
  persisted request payloads on recovery (serving never checkpoints HBM).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import reconstruct as rec
from repro.core.arena import Arena, open_arena
from repro.core.recovery import RecoveryManager, RecoveryReport
from repro.pstruct.dll import NULL, DoublyLinkedList


@dataclasses.dataclass
class PagedConfig:
    n_pages: int = 1024
    page_tokens: int = 64
    mode: str = "partly"
    n_shards: int = 1      # shard count of the page-metadata arena
    commit_mode: str = "barrier"   # "barrier" | "shadow" (DESIGN.md §9)
    # chain-ranking strategy for the LRU ring scan after a crash (the
    # DLL reconstructor's NEXT walk): "auto" flips from pointer doubling
    # to contraction list ranking once the page pool crosses the
    # jump-table cache crossover (core.recovery.chain_method, §8)
    chain_method: str = "auto"
    # incremental order snapshots (DESIGN.md §10): None defers to the
    # REPRO_SNAPSHOT env gate; True/False overrides it.  With snapshots
    # on, recovery seeds the LRU order from the newest committed
    # snapshot and replays only the suffix — TTFT-after-crash stays flat
    # as the page pool grows.
    snapshot: Optional[bool] = None
    # paged regions (DESIGN.md §12): None defers to the REPRO_PAGED env
    # gate (default off).  With paging on, the node slab's volatile side
    # is an LRU block cache of `cache_blocks` x `block_bytes`, and
    # recovery faults only the blocks it touches.
    paged: Optional[bool] = None
    block_bytes: int = 4096
    cache_blocks: int = 1024


class PagedAllocator:
    """LRU page pool.  data row of the DLL node = (page_id, owner_request,
    first_token, n_tokens, 0, 0, 0).

    With ``n_shards > 1`` the LRU's node slab stripes across arena
    shards (the DLL's segment router), so page-metadata flushes from an
    allocation burst fan out over independent backing files
    (DESIGN.md §7)."""

    def __init__(self, cfg: PagedConfig, path: Optional[str] = None):
        self.cfg = cfg
        layout = DoublyLinkedList.layout(cfg.n_pages, cfg.mode, name="lru",
                                         snapshot=cfg.snapshot)
        self.arena = open_arena(path, layout, n_shards=cfg.n_shards,
                                commit_mode=cfg.commit_mode,
                                paged=cfg.paged,
                                block_bytes=cfg.block_bytes,
                                cache_blocks=cfg.cache_blocks)
        self.lru = DoublyLinkedList(self.arena, cfg.n_pages, cfg.mode,
                                    name="lru",
                                    chain_method=cfg.chain_method,
                                    snapshot=cfg.snapshot)
        self.page_of_node: Dict[int, int] = {}
        # free pages as a numpy stack (top = end): recovery rebuilds it
        # with one nonzero() instead of materializing an O(n_pages)
        # Python list on the TTFT-after-crash path
        self.pages_free: np.ndarray = np.arange(cfg.n_pages,
                                                dtype=np.int64)
        self.owner: np.ndarray = np.full(cfg.n_pages, -1, np.int64)
        self.last_recovery: Optional[RecoveryReport] = None

    def alloc(self, request_id: int, n: int) -> np.ndarray:
        """Allocate n pages to a request (LRU-evicting if exhausted).

        Eviction, append, and commit share one epoch: LRU rows touched by
        both the pop and the append flush once, and the header row —
        previously flushed by each sub-op — flushes once per alloc."""
        with self.arena.epoch():
            if len(self.pages_free) < n:
                self._evict(n - len(self.pages_free))
            top = len(self.pages_free) - n
            pages = self.pages_free[top:][::-1].copy()
            self.pages_free = self.pages_free[:top]
            vals = np.zeros((n, 7), np.int64)
            vals[:, 0] = pages
            vals[:, 1] = request_id
            ids = self.lru.append_batch(vals)
            for nd, pg in zip(ids.tolist(), pages.tolist()):
                self.page_of_node[nd] = pg
            self.owner[pages] = request_id
            self.arena.commit()
        return pages

    def free_request(self, request_id: int) -> None:
        pages = np.nonzero(self.owner == request_id)[0]
        if pages.size == 0:
            return
        # find their DLL nodes
        nodes = [nd for nd, pg in self.page_of_node.items()
                 if self.owner[pg] == request_id]
        with self.arena.epoch():
            self.lru.delete_batch(np.asarray(nodes, np.int64))
            for nd in nodes:
                self.page_of_node.pop(nd, None)
            self.owner[pages] = -1
            self.pages_free = np.concatenate([self.pages_free, pages])
            self.arena.commit()

    def _evict(self, n: int) -> np.ndarray:
        nodes = self.lru.pop_front_batch(n)
        pages = np.asarray([self.page_of_node.pop(int(nd)) for nd in nodes],
                           np.int64)
        self.owner[pages] = -1
        self.pages_free = np.concatenate([self.pages_free, pages])
        return pages

    def pages_of(self, request_id: int) -> np.ndarray:
        return np.nonzero(self.owner == request_id)[0]

    # ------------- crash recovery -------------
    def recover(self, concurrency: int = 1, on_stage=None) -> float:
        """Rebuild all volatile metadata from the persistent NEXT chain +
        node payloads (paper §IV-C3), through the unified recovery
        manager: LRU chain first, page tables second (a strict dependency
        chain, so ``concurrency`` only matters when this allocator's
        stages share a manager with other recoverables — the serving
        engine's recover() composes them that way).  Stage-completion
        callbacks pass through to the manager.  Returns seconds (the
        full RecoveryReport lands in ``last_recovery``)."""
        mgr = RecoveryManager(self.arena)
        lru_regions = ("lru.nodes", "lru.header")
        if self.lru.snapshot:
            lru_regions += ("lru.snapring", "lru.snaprec")
        mgr.add("lru", "pstruct.dll", self.lru, regions=lru_regions)
        mgr.add("pages", "serve.paged_alloc", self, depends=("lru",),
                regions=("lru.nodes",))
        report = mgr.recover(concurrency=concurrency, on_stage=on_stage)
        self.last_recovery = report
        return report.total_seconds


@rec.register("serve.paged_alloc")
def _reconstruct_paged_alloc(pa: PagedAllocator) -> dict:
    """Pure rebuild of owner/page_of_node/pages_free from the
    reconstructed LRU — one vectorized pass over the node payloads
    instead of the per-node Python loop + `p not in used` scan."""
    order = pa.lru.order()          # materialized by the DLL reconstructor
    vals = pa.lru.data_rows(order)  # block-routed gather (no .data spill)
    pages = vals[:, 0]
    pa.page_of_node = dict(zip(order.tolist(), pages.tolist()))
    pa.owner = np.full(pa.cfg.n_pages, -1, np.int64)
    pa.owner[pages] = vals[:, 1]
    # boolean scatter, not np.isin: isin sorts both sides, an O(N log N)
    # constant that lands on the TTFT-after-crash path at large pools
    free = np.ones(pa.cfg.n_pages, bool)
    free[pages] = False
    pa.pages_free = np.nonzero(free)[0].astype(np.int64)
    return {"pages_live": int(pages.size),
            "pages_free": int(pa.cfg.n_pages - pages.size)}
