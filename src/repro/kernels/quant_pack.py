"""quant_pack — fused blockwise int8 quantize + pack Pallas kernel.

Beyond-paper persistence path for APPROXIMABLE leaves (Adam moments):
persist 1 byte/elem + one f32 scale per 256-element group instead of 4
bytes/elem — a ~3.9x reduction in flushed bytes (EXPERIMENTS.md §Perf).
Also usable as the in-memory moment representation (8-bit Adam) for the
llama4-400b memory budget (DESIGN.md §5).

Tiling: grid over (N / bn, D / G) with G = group = 256.  Each (bn, G)
block computes a per-row absmax -> scale column (bn, 1) and the quantized
payload (bn, G).  All dims are multiples of (8, 128) so blocks sit on
natural TPU tile boundaries.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

GROUP = 256


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)            # (bn, G)
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    q_ref[...] = q
    s_ref[...] = scale.astype(jnp.float32)


def quantize_blockwise(x: jax.Array, *, block_n: int = 64,
                       interpret: bool = True):
    """x: (N, D) float -> (q (N, D) int8, scales (N, D // GROUP) f32).

    D must be a multiple of GROUP; N a multiple of 8 (ops.py pads).
    """
    n, d = x.shape
    assert d % GROUP == 0 and n % 8 == 0, (n, d)
    bn = min(block_n, n)
    while n % bn:
        bn //= 2
    grid = (n // bn, d // GROUP)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bn, GROUP), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bn, GROUP), lambda i, j: (i, j)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.int8),
            jax.ShapeDtypeStruct((n, d // GROUP), jnp.float32),
        ],
        interpret=interpret,
    )(x)


def _dequant_kernel(q_ref, s_ref, x_ref):
    q = q_ref[...].astype(jnp.float32)
    x_ref[...] = q * s_ref[...]


def dequantize_blockwise(q: jax.Array, scales: jax.Array, *,
                         block_n: int = 64, dtype=jnp.float32,
                         interpret: bool = True) -> jax.Array:
    n, d = q.shape
    assert d % GROUP == 0 and scales.shape == (n, d // GROUP)
    bn = min(block_n, n)
    while n % bn:
        bn //= 2
    grid = (n // bn, d // GROUP)
    out = pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, GROUP), lambda i, j: (i, j)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bn, GROUP), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
    )(q, scales)
    return out.astype(dtype)
