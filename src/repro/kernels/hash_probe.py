"""hash_probe — batched bucketized hash-table probe Pallas kernel.

Device-side analogue of the paper's hashmap FIND/INSERT chain walk, used by
the serving engine for batched request/session lookups and embedding-dedup.
TPU adaptation (DESIGN.md §2): pointer-chasing chains don't vectorize, so
the device table is *bucketized* — each bucket is a 128-wide lane row that
is compared in one VPU op.  hash -> bucket id is computed in the ops.py
wrapper; the scalar-prefetched bucket ids steer the BlockSpec index_map
(same dynamic-gather pattern as pack_flush).

Kernel: for query q with bucket b = bucket_of(q):
    slot  = first lane j with keys[b, j] == q   (or -1)
Returns the global slot id b * BUCKET + j so callers can gather values.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BUCKET = 128  # lanes


def _probe_kernel(bid_ref, q_ref, keys_ref, out_ref):
    i = pl.program_id(0)
    q = q_ref[...]                        # (1, 1)
    row = keys_ref[...]                   # (1, BUCKET)
    hit = row == q
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, BUCKET), 1)
    slot = jnp.min(jnp.where(hit, lane, BUCKET), axis=1, keepdims=True)
    found = slot < BUCKET
    gslot = bid_ref[i] * BUCKET + slot
    out_ref[...] = jnp.where(found, gslot, -1).astype(jnp.int32)


def probe(keys_table: jax.Array, queries: jax.Array, bucket_ids: jax.Array,
          *, interpret: bool = True) -> jax.Array:
    """keys_table: (n_buckets, BUCKET) int32/int64-as-2xi32 packed keys;
    queries: (Q,) same dtype; bucket_ids: (Q,) int32.
    Returns (Q,) int32 global slot ids (-1 = absent)."""
    nb, bw = keys_table.shape
    assert bw == BUCKET
    q = queries.shape[0]
    grid = (q,)
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, bid_ref: (i, 0)),
            pl.BlockSpec((1, BUCKET), lambda i, bid_ref: (bid_ref[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, bid_ref: (i, 0)),
    )
    out = pl.pallas_call(
        _probe_kernel,
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((q, 1), jnp.int32),
        interpret=interpret,
    )(bucket_ids, queries[:, None], keys_table)
    return out[:, 0]
