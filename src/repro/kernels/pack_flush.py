"""pack_flush — selective-field gather/pack Pallas kernel.

THE paper hot spot, TPU-adapted: checkpointing persists only the essential
rows/fields of device-resident state.  The flush path gathers the dirty row
set into a contiguous, tile-aligned staging buffer (which is then DMA'd to
host and written by the async checkpoint writer).  This is the cache-line
analogue from §V-E: the staging buffer is laid out in (8, 128) VMEM tiles,
so a flush unit never straddles tiles — packing *unaligned* field slices
would re-read tiles exactly like unaligned clwb re-fetches lines (we expose
that contrast in benchmarks/fig12_alignment).

Kernel shape: out[i, :] = src[idx[i], :] for i < n_valid (rows whose
idx == -1 are zero-filled).  The row index list is scalar-prefetched
(pltpu.PrefetchScalarGridSpec) so BlockSpec index_maps can steer the input
block choice — the idiomatic TPU dynamic-gather pattern.

scatter_unpack (restore path) is the exact inverse.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128
SUB = 8  # f32 sublane


def _gather_kernel(idx_ref, src_ref, out_ref):
    """One grid step packs one output row-block from a dynamic source row.

    grid = (n_out, D // bd); blocks: src (1, bd) selected by idx, out (1, bd).
    """
    i = pl.program_id(0)
    valid = idx_ref[i] >= 0
    row = src_ref[...]
    out_ref[...] = jnp.where(valid, row, jnp.zeros_like(row))


def pack_rows(src: jax.Array, idx: jax.Array, *, block_d: int = 512,
              interpret: bool = True) -> jax.Array:
    """Gather rows of `src` (N, D) at `idx` (M,) into a packed (M, D) buffer.

    idx entries of -1 produce zero rows.  D must be a multiple of 128; the
    wrapper in ops.py pads as needed.
    """
    n, d = src.shape
    m = idx.shape[0]
    bd = min(block_d, d)
    assert d % bd == 0 and bd % LANE == 0, (d, bd)

    grid = (m, d // bd)
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bd),
                         lambda i, j, idx_ref: (jnp.maximum(idx_ref[i], 0), j)),
        ],
        out_specs=pl.BlockSpec((1, bd), lambda i, j, idx_ref: (i, j)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((m, d), src.dtype),
        interpret=interpret,
    )(idx, src)


def _scatter_kernel(inv_ref, packed_ref, dst_ref, out_ref):
    """Inverse of pack: for dst row r, out[r] = packed[inv[r]] if a packed
    row maps here (inv[r] >= 0) else dst[r].

    grid = (n, D // bd).  Every output block is written exactly once, so no
    aliasing is needed; the packed input block is steered dynamically by
    the scalar-prefetched inverse map.
    """
    r = pl.program_id(0)
    valid = inv_ref[r] >= 0
    out_ref[...] = jnp.where(valid, packed_ref[...], dst_ref[...])


def scatter_rows(dst: jax.Array, packed: jax.Array, idx: jax.Array, *,
                 block_d: int = 512, interpret: bool = True) -> jax.Array:
    """Functional dst.at[idx[i]].set(packed[i]) for idx[i] >= 0 (restore).

    The (N,) inverse map (dst row -> packed row or -1) is computed with one
    jnp scatter in the wrapper; the kernel then writes every dst row block
    exactly once.
    """
    n, d = dst.shape
    m = idx.shape[0]
    bd = min(block_d, d)
    assert d % bd == 0 and bd % LANE == 0

    valid = idx >= 0
    oob = jnp.where(valid, idx, n)  # invalid rows -> out of bounds, dropped
    inv = jnp.full((n,), -1, jnp.int32).at[oob].set(
        jnp.arange(m, dtype=jnp.int32), mode="drop")

    grid = (n, d // bd)
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bd),
                         lambda r, j, inv_ref: (jnp.maximum(inv_ref[r], 0), j)),
            pl.BlockSpec((1, bd), lambda r, j, inv_ref: (r, j)),
        ],
        out_specs=pl.BlockSpec((1, bd), lambda r, j, inv_ref: (r, j)),
    )
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((n, d), dst.dtype),
        interpret=interpret,
    )(inv, packed, dst)
