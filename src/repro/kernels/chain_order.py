"""chain_order — chain-reconstruction Pallas kernels (doubling +
contraction list ranking).

Device-side variant of the recovery layer's shared chain primitives
(core/recovery.py).  Two paths behind ``chain_order_device(method=)``:

* DOUBLING — one `jump_double` call advances every node's jump pointer
  by its own current distance (jump' = jump[jump], NULL-absorbing) and
  accumulates the hop count, so log2(N) rounds resolve the order/length
  of a NULL-terminated chain — the §V-F reconstruction walk at hardware
  speed instead of Python-loop speed.
* CONTRACTION (DESIGN.md §8) — sample every k-th row as a spine node
  (deterministic ``id % k == 0``, so membership is arithmetic — no
  lookup table on device), local-walk the spine segments with
  `gather_next` rounds (total gathers O(N): lanes retire as segments
  close), rank the ~N/k contracted chain with the SAME `jump_double`
  tables — now an in-cache working set — and expand ranks back through
  a second pass of `gather_next` rounds.  This is what keeps 10**6+
  chain recovery off the jump-table cache cliff; ``method="auto"``
  defers to the shared `core.recovery.chain_method` heuristic.

TPU adaptation (same dynamic-gather pattern as pack_flush/hash_probe):
pointer chasing doesn't vectorize as lane ops, so the per-node gathers
``jump[jump[i]]`` / ``nxt[cur[i]]`` are steered by the
*scalar-prefetched* pointer array in the BlockSpec index_map; the kernel
bodies only mask the NULL-absorbed lanes.

Sharded arenas (DESIGN.md §7) add a ``segments`` offset argument: a
sharded region's NEXT column arrives as N per-shard views concatenated
shard-major (what a recovery DMA reads straight out of the shard files,
no host re-gather), while pointer VALUES stay global row ids.  With the
block-cyclic segment router the packed position of global id g is
closed-form — ``packed_positions`` — so the doubling rounds steer their
gathers through the per-shard segments directly: pass
``segments=<shard row offsets>, seg_rows=<router segment size>`` and
the primitives accept the packed layout, returning global ids.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.recovery import CONTRACT_K, ChainSnapshot, chain_method

NULL = -1

# pallas_call round-trips issued by this module (interpret or compiled):
# the contraction fusion's whole point is shrinking this, so benchmarks
# snapshot it around a run instead of guessing from wall time
KERNEL_CALLS = 0


def packed_positions(ids, seg_rows: int, segments):
    """Position of each global row id in a shard-major packed array.

    ``segments`` — (n_shards + 1,) row offsets of each shard's span in
    the packed array (``segments[s]`` = rows held by shards < s); shard
    of a global id under the block-cyclic router is
    ``(id // seg_rows) % n_shards`` and its local rank is
    ``(id // (seg_rows * n_shards)) * seg_rows + id % seg_rows`` —
    exact even when the last block is partial, because earlier blocks of
    a shard are always full.  Works on numpy and jax arrays alike.
    Negative ids (NULL) map to NULL."""
    n_shards = len(segments) - 1
    seg = ids // seg_rows
    shard = seg % n_shards
    local = (ids // (seg_rows * n_shards)) * seg_rows + ids % seg_rows
    if isinstance(ids, np.ndarray):
        base = np.asarray(segments)[np.maximum(shard, 0)]
        return np.where(ids >= 0, base + local, NULL)
    base = jnp.asarray(segments)[jnp.maximum(shard, 0)]
    return jnp.where(ids >= 0, base + local, NULL)


def _double_kernel(jmp_ref, jump_at_ref, cnt_at_ref, cnt_ref,
                   jump_out, cnt_out):
    """One doubling round for node i = program_id(0).

    jump_at/cnt_at blocks are steered to row jump[i] (clamped to 0 when
    absorbed); cnt block is row i.  Invariant maintained: after k rounds
    jump[i] = node min(2^k, L(i)) hops after i, cnt[i] = min(2^k, L(i)).
    """
    i = pl.program_id(0)
    live = jmp_ref[i] >= 0
    jump_out[...] = jnp.where(live, jump_at_ref[...], NULL)
    cnt_out[...] = cnt_ref[...] + jnp.where(live, cnt_at_ref[...], 0)


def jump_double(jump: jax.Array, cnt: jax.Array, *,
                segments: Optional[np.ndarray] = None,
                seg_rows: int = 0,
                interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """jump, cnt: (N,) int32.  Returns (jump', cnt') after one doubling
    round: jump'[i] = jump[jump[i]] (NULL absorbing), cnt'[i] = cnt[i] +
    cnt[jump[i]] for live lanes.  Out-of-range pointers terminate like
    NULL (the shared torn-epoch contract of core.recovery.jump_tables):
    sanitized here, so every round's output is in-range-or-NULL.

    With ``segments``/``seg_rows`` the arrays are shard-major packed
    (per-shard views of a sharded region, concatenated) while pointer
    VALUES are global ids: the steering array handed to the scalar
    prefetcher is the pointers' packed POSITION (closed-form translate),
    so each gather lands inside the right shard's segment."""
    n = jump.shape[0]
    jump = jnp.where((jump >= 0) & (jump < n), jump, NULL)
    if segments is not None:
        steer = packed_positions(jump, seg_rows, segments).astype(jnp.int32)
    else:
        steer = jump
    grid = (n,)
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1),
                         lambda i, p_ref: (jnp.maximum(p_ref[i], 0), 0)),
            pl.BlockSpec((1, 1),
                         lambda i, p_ref: (jnp.maximum(p_ref[i], 0), 0)),
            pl.BlockSpec((1, 1), lambda i, p_ref: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i, p_ref: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, p_ref: (i, 0)),
        ],
    )
    global KERNEL_CALLS
    KERNEL_CALLS += 1
    j2, c2 = pl.pallas_call(
        _double_kernel,
        grid_spec=spec,
        out_shape=(jax.ShapeDtypeStruct((n, 1), jnp.int32),
                   jax.ShapeDtypeStruct((n, 1), jnp.int32)),
        interpret=interpret,
    )(steer, jump[:, None], cnt[:, None], cnt[:, None])
    return j2[:, 0], c2[:, 0]


def _gather_kernel(steer_ref, val_at_ref, out):
    """One chain hop for lane i = program_id(0): the val block is
    steered to row steer[i] (clamped to 0 when the lane is retired);
    the body only masks retired lanes to NULL."""
    i = pl.program_id(0)
    live = steer_ref[i] >= 0
    out[...] = jnp.where(live, val_at_ref[...], NULL)


def gather_next(nxt: jax.Array, ids, *,
                segments: Optional[np.ndarray] = None,
                seg_rows: int = 0,
                interpret: bool = True) -> jax.Array:
    """One contraction hop for a batch of lanes: out[i] = nxt[ids[i]]
    (NULL lanes stay NULL; out-of-range ids terminate, the shared
    torn-epoch contract).  ``nxt`` is the sanitized (n,) int32 pointer
    column — shard-major packed when ``segments``/``seg_rows`` are
    given, in which case the scalar-prefetched steering is the ids'
    packed POSITION while ids and gathered values stay global.  This is
    the kernel the contraction local-walk and expand rounds ride: the
    same prefetch-steered dynamic gather as `jump_double`, minus the
    count lane."""
    n = nxt.shape[0]
    if isinstance(ids, np.ndarray):
        # range-check at the caller's full width BEFORE the int32
        # narrowing: a torn 2**32+3 must terminate, not alias node 3
        # (jnp.asarray would truncate it silently under 32-bit jax)
        ids = np.where((ids >= 0) & (ids < n), ids, NULL).astype(np.int32)
    ids = jnp.asarray(ids, jnp.int32)
    ids = jnp.where((ids >= 0) & (ids < n), ids, NULL)
    if segments is not None:
        steer = packed_positions(ids, seg_rows, segments).astype(jnp.int32)
    else:
        steer = ids
    grid = (ids.shape[0],)
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1),
                         lambda i, p_ref: (jnp.maximum(p_ref[i], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, p_ref: (i, 0)),
    )
    global KERNEL_CALLS
    KERNEL_CALLS += 1
    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((ids.shape[0], 1), jnp.int32),
        interpret=interpret,
    )(steer, nxt[:, None])
    return out[:, 0]


def walk_segments(nxt: jax.Array, starts, *, k: int, head: int,
                  n_mult: int, promoted: bool,
                  segments: Optional[np.ndarray] = None,
                  seg_rows: int = 0, budget: int = 64,
                  interpret: bool = True
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Walk every lane's chain segment toward its next spine node in ONE
    ``pallas_call``: an in-kernel ``fori_loop`` takes up to ``budget``
    hops per lane (lanes freeze the step they arrive at a spine node or
    the chain ends), replacing the one-host-roundtrip-per-hop
    `gather_next` cascade of the contraction local walk.  The whole
    (sanitized) pointer column rides in as a single block and each hop
    is a dynamic in-kernel load — spine membership stays the arithmetic
    ``id % k == 0`` test (plus the promoted head), so no lookup table
    crosses the host boundary either.

    Returns ``(cur, sp, w)`` per lane: final global id (NULL once the
    chain ended), arrival spine index (NULL if still walking or the
    chain ended), and hops taken this call.  A lane with ``cur >= 0``
    and ``sp == NULL`` ran out of budget — feed ``cur`` back in to
    continue (weights accumulate at the caller).

    ``segments``/``seg_rows``: shard-major packed layout; the packed
    position of each hop's global pointer is the same closed form as
    `packed_positions`, evaluated in-kernel."""
    n = nxt.shape[0]
    starts = jnp.asarray(starts, jnp.int32)
    if segments is not None:
        segs = jnp.asarray(np.asarray(segments), jnp.int32)
        n_shards = len(segments) - 1
    else:
        segs = jnp.zeros(1, jnp.int32)
        n_shards = 1
    sr = max(int(seg_rows), 1)
    kk, hd, nm = int(k), int(head), int(n_mult)

    def kern(start_ref, seg_ref, nxt_ref, cur_out, sp_out, w_out):
        i = pl.program_id(0)

        def pos(c):
            if n_shards == 1:
                return c
            shard = (c // sr) % n_shards
            local = (c // (sr * n_shards)) * sr + c % sr
            return seg_ref[shard] + local

        def spidx(c):
            sp = jnp.where(c % kk == 0, c // kk, NULL)
            if promoted:
                sp = jnp.where(c == hd, nm, sp)
            return sp

        def hop(_, st):
            cur, w, sp, done = st
            nv = pl.load(nxt_ref,
                         (pl.ds(pos(jnp.maximum(cur, 0)), 1),
                          slice(None)))[0, 0]
            live = jnp.logical_not(done)
            cur2 = jnp.where(live, nv, cur)
            w2 = jnp.where(live, w + 1, w)
            spv = spidx(cur2)
            arrived = live & (cur2 >= 0) & (spv >= 0)
            sp2 = jnp.where(arrived, spv, sp)
            done2 = done | (live & ((cur2 < 0) | arrived))
            return cur2, w2, sp2, done2

        g = start_ref[i]
        cur, w, sp, _ = jax.lax.fori_loop(
            0, budget, hop,
            (g, jnp.int32(0), jnp.int32(NULL), g < 0))
        cur_out[...] = jnp.full((1, 1), cur, jnp.int32)
        sp_out[...] = jnp.full((1, 1), sp, jnp.int32)
        w_out[...] = jnp.full((1, 1), w, jnp.int32)

    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(starts.shape[0],),
        in_specs=[pl.BlockSpec((n, 1), lambda i, s_ref, g_ref: (0, 0))],
        out_specs=[pl.BlockSpec((1, 1), lambda i, s_ref, g_ref: (i, 0)),
                   pl.BlockSpec((1, 1), lambda i, s_ref, g_ref: (i, 0)),
                   pl.BlockSpec((1, 1), lambda i, s_ref, g_ref: (i, 0))],
    )
    global KERNEL_CALLS
    KERNEL_CALLS += 1
    c2, sp, w = pl.pallas_call(
        kern,
        grid_spec=spec,
        out_shape=(jax.ShapeDtypeStruct((starts.shape[0], 1), jnp.int32),
                   jax.ShapeDtypeStruct((starts.shape[0], 1), jnp.int32),
                   jax.ShapeDtypeStruct((starts.shape[0], 1), jnp.int32)),
        interpret=interpret,
    )(starts, segs, nxt[:, None])
    return c2[:, 0], sp[:, 0], w[:, 0]


def expand_segments(nxt: jax.Array, starts, posn, rem, count: int, *,
                    segments: Optional[np.ndarray] = None,
                    seg_rows: int = 0,
                    interpret: bool = True) -> np.ndarray:
    """Emit every node of the used contraction segments into the final
    order array in ONE ``pallas_call``: lane i walks ``rem[i]`` hops
    from ``starts[i]``, storing each visited global id at
    ``out[posn[i] + t]`` — the whole (count,) order block persists
    across the sequential grid (every step maps block (0, 0)), so the
    lanes' disjoint runs land in a single kernel instead of one
    host-roundtripped gather per hop.  Retired steps re-store the
    lane's own first slot with its own first value, so no mask is
    needed and no other lane's run is disturbed."""
    n = nxt.shape[0]
    starts = jnp.asarray(starts, jnp.int32)
    posn = jnp.asarray(posn, jnp.int32)
    rem_np = np.asarray(rem, np.int64)
    remj = jnp.asarray(rem_np, jnp.int32)
    L = int(starts.shape[0])
    max_rem = int(rem_np.max()) if L else 0
    if segments is not None:
        segs = jnp.asarray(np.asarray(segments), jnp.int32)
        n_shards = len(segments) - 1
    else:
        segs = jnp.zeros(1, jnp.int32)
        n_shards = 1
    sr = max(int(seg_rows), 1)

    def kern(start_ref, pos_ref, rem_ref, seg_ref, nxt_ref, out_ref):
        i = pl.program_id(0)

        def pos(c):
            if n_shards == 1:
                return c
            shard = (c // sr) % n_shards
            local = (c // (sr * n_shards)) * sr + c % sr
            return seg_ref[shard] + local

        g0 = start_ref[i]
        p0 = pos_ref[i]
        r = rem_ref[i]

        def hop(t, st):
            cur, p = st
            live = t < r
            pl.store(out_ref,
                     (pl.ds(jnp.where(live, p, p0), 1), slice(None)),
                     jnp.full((1, 1), jnp.where(live, cur, g0),
                              jnp.int32))
            nv = pl.load(nxt_ref,
                         (pl.ds(pos(jnp.maximum(cur, 0)), 1),
                          slice(None)))[0, 0]
            return jnp.where(t + 1 < r, nv, cur), p + 1

        jax.lax.fori_loop(0, max_rem, hop, (g0, p0))

    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(L,),
        in_specs=[pl.BlockSpec((n, 1), lambda i, *_: (0, 0))],
        out_specs=pl.BlockSpec((count, 1), lambda i, *_: (0, 0)),
    )
    global KERNEL_CALLS
    KERNEL_CALLS += 1
    out = pl.pallas_call(
        kern,
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((count, 1), jnp.int32),
        interpret=interpret,
    )(starts, posn, remj, segs, nxt[:, None])
    return np.asarray(out[:, 0], np.int64)


def chain_tables_device(nxt: np.ndarray, bits: int, *,
                        segments: Optional[np.ndarray] = None,
                        seg_rows: int = 0,
                        interpret: bool = True
                        ) -> Tuple[List[np.ndarray], np.ndarray]:
    """Binary-lifting tables via the kernel: returns ([jump^(2^k) for
    k < bits], counts) with counts[i] = min(2^bits, chain length from i).

    ``segments``/``seg_rows``: `nxt` is shard-major packed (see module
    docstring); tables then hold GLOBAL ids at PACKED positions."""
    # sanitize at full width BEFORE the int32 narrowing: a torn 64-bit
    # pointer like 2**32+3 would otherwise wrap to a valid-looking 3
    # instead of terminating the chain (the module-wide OOB contract)
    nxt = np.asarray(nxt)
    n = nxt.shape[0]
    jump = jnp.asarray(np.where((nxt >= 0) & (nxt < n), nxt, NULL),
                       jnp.int32)
    cnt = jnp.ones(nxt.shape[0], jnp.int32)
    tables = [np.asarray(jump, np.int64)]
    for _ in range(bits - 1):
        jump, cnt = jump_double(jump, cnt, segments=segments,
                                seg_rows=seg_rows, interpret=interpret)
        tables.append(np.asarray(jump, np.int64))
    # one more round so counts saturate past 2^(bits-1)-long chains
    _, cnt = jump_double(jump, cnt, segments=segments, seg_rows=seg_rows,
                         interpret=interpret)
    return tables, np.asarray(cnt, np.int64)


def _snapshot_verify_device(nxt: np.ndarray, head: int, cand: np.ndarray,
                            segments, seg_rows: int,
                            interpret: bool) -> bool:
    """Verify an order-snapshot candidate (DESIGN.md §10) with ONE
    `gather_next` round: succ[i] = nxt[cand[i]] must equal cand[i+1]
    for every internal link and NULL at the last element (the chain
    must END there — that completeness check replaces the host
    primitive's explicit count comparison, so the device path needs no
    O(N) table build to adopt a snapshot).  NEXT is a function of node
    id, so a candidate that passes IS the chain order from `head` —
    duplicates would force nxt[cand[-1]] to be both NULL and a live
    successor."""
    n = np.asarray(nxt).shape[0]
    if cand.size == 0 or cand[0] != head:
        return False
    if ((cand < 0) | (cand >= n)).any():
        return False
    sane = np.where((np.asarray(nxt) >= 0) & (np.asarray(nxt) < n),
                    np.asarray(nxt), NULL)
    succ = np.asarray(gather_next(jnp.asarray(sane, jnp.int32), cand,
                                  segments=segments, seg_rows=seg_rows,
                                  interpret=interpret), np.int64)
    if succ[-1] != NULL:
        return False                 # chain continues past the candidate
    return bool(np.array_equal(succ[:-1], cand[1:]))


def chain_order_device(nxt: np.ndarray, head: int, *,
                       segments: Optional[np.ndarray] = None,
                       seg_rows: int = 0,
                       method: str = "auto",
                       k: int = 0,
                       fuse: bool = True,
                       snapshot: Optional[ChainSnapshot] = None,
                       interpret: bool = True) -> np.ndarray:
    """Full device-built chain order.  ``method`` — "double" (the
    doubling rounds run in the Pallas kernel; the final node-at-position
    extraction is a cheap O(count log count) gather off the returned
    tables), "contract" (the contraction list ranking: `gather_next`
    local-walk rounds, `jump_double` rank over the ~n/k contracted
    chain, `gather_next` expand rounds), or "auto" — the SAME heuristic
    as the host primitive (`core.recovery.chain_method`), so host and
    device flip strategies at the same size.  A head outside [0, n) is
    a terminated chain (empty order) — the same OOB contract as the
    host primitive.

    ``segments``/``seg_rows`` accept the shard-major packed NEXT column
    of a sharded region (the per-shard persistent views, concatenated —
    no host re-gather); `head` and the returned order are global ids
    either way, on both methods (the contraction rank runs in
    spine-index space, which is layout-free).

    ``snapshot``: an order-snapshot candidate (core.recovery
    .ChainSnapshot, DESIGN.md §10).  Verified with one `gather_next`
    round; on success the candidate is returned directly (outcome
    "snapshot") and the ranking is skipped entirely — on mismatch the
    full device ranking runs (outcome = the ranking method, replayed =
    full chain length), the same contract as the host primitive."""
    n = nxt.shape[0]
    if head < 0 or head >= n:
        return np.empty(0, np.int64)
    if snapshot is not None:
        cand = np.asarray(snapshot.candidate, np.int64).ravel()
        if _snapshot_verify_device(nxt, head, cand, segments, seg_rows,
                                   interpret):
            snapshot.outcome = "snapshot"
            return cand.copy()
        snapshot.outcome = chain_method(n, None, method)
        order = chain_order_device(nxt, head, segments=segments,
                                   seg_rows=seg_rows, method=method, k=k,
                                   fuse=fuse, interpret=interpret)
        snapshot.replayed = int(order.size)
        return order
    if chain_method(n, None, method) == "contract":
        return _order_device_contract(nxt, head, k or CONTRACT_K,
                                      segments, seg_rows, interpret,
                                      fuse=fuse)

    def pos_of(ids):
        if segments is None:
            return ids
        return packed_positions(ids, seg_rows, segments)

    bits = max(1, int(n).bit_length())
    tables, cnt = chain_tables_device(nxt, bits, segments=segments,
                                      seg_rows=seg_rows,
                                      interpret=interpret)
    count = int(cnt[pos_of(np.asarray([head], np.int64))[0]])
    if count > n:
        raise RuntimeError("cycle in chain")
    pos = np.arange(count)
    cur = np.full(count, head, np.int64)
    for b in range(len(tables)):
        m = (pos >> b) & 1 == 1
        if m.any():
            cur[m] = tables[b][pos_of(cur[m])]
    return cur


def _order_device_contract(nxt: np.ndarray, head: int, k: int,
                           segments: Optional[np.ndarray],
                           seg_rows: int,
                           interpret: bool,
                           fuse: bool = True) -> np.ndarray:
    """Contraction list ranking with every chain hop in a Pallas
    kernel; the host orchestrates lane bookkeeping between rounds, the
    established chain_tables_device split.

    ``fuse=True`` (default) runs the local walk through `walk_segments`
    — one ``pallas_call`` covers up to ``budget`` hops for every lane,
    so the typical segment (~k hops) resolves in a single round trip
    instead of one per hop; ``fuse=False`` keeps the per-hop
    `gather_next` cascade (the recovery_bench baseline rows).

    Spine membership is pure arithmetic (``id % k == 0``, plus the one
    promoted head), so the local walk needs no spine-position table:
    the contracted index of global id g is ``g // k`` for sampled rows
    and ``ceil(n/k)`` for the promoted head."""
    # sanitize at 64-bit BEFORE the int32 narrowing (module-wide OOB
    # contract, same as chain_tables_device)
    nxt = np.asarray(nxt)
    n = nxt.shape[0]
    jnxt = jnp.asarray(np.where((nxt >= 0) & (nxt < n), nxt, NULL),
                       jnp.int32)
    n_mult = (n + k - 1) // k            # sampled spine rows
    promoted = head % k != 0
    spine = np.arange(0, n, k, dtype=np.int64)
    if promoted:
        spine = np.concatenate([spine, [head]])
    S = spine.size

    def spine_idx(ids):                  # global id -> spine index
        out = np.where(ids % k == 0, ids // k, NULL)
        if promoted:
            out = np.where(ids == head, n_mult, out)
        return out.astype(np.int64)

    cnext = np.full(S, NULL, np.int64)
    if fuse:
        # ---- local walk, fused: one walk_segments call covers up to
        # `budget` hops for every live lane; lanes that exhaust the
        # budget (segment longer than budget) feed their cursor back in
        # and weights accumulate — typically ONE round trip total
        w = np.zeros(S, np.int64)
        lanes = np.arange(S)
        cur = spine
        budget = max(2 * k, 64)
        hops = 0
        while lanes.size and hops <= n:
            c2, sp, wd = walk_segments(
                jnxt, cur, k=k, head=head, n_mult=n_mult,
                promoted=promoted, segments=segments, seg_rows=seg_rows,
                budget=budget, interpret=interpret)
            c2 = np.asarray(c2, np.int64)
            sp = np.asarray(sp, np.int64)
            w[lanes] += np.asarray(wd, np.int64)
            arrived = sp >= 0
            if arrived.any():
                cnext[lanes[arrived]] = sp[arrived]
            alive = (c2 >= 0) & ~arrived
            lanes = lanes[alive]
            cur = c2[alive]
            hops += budget
        if lanes.size:                   # spine-free cycle: poison
            w[lanes] = n + 1
        w = np.maximum(w, 1)
    else:
        # ---- local walk, per-hop baseline: one gather_next round per
        # segment hop, lanes retired (and compacted away) as they reach
        # the next spine node
        w = np.ones(S, np.int64)
        lanes = np.arange(S)
        cur = np.asarray(gather_next(jnxt, spine, segments=segments,
                                     seg_rows=seg_rows,
                                     interpret=interpret), np.int64)
        for _ in range(n + 1):
            if not lanes.size:
                break
            sp = np.where(cur >= 0, spine_idx(np.maximum(cur, 0)), NULL)
            arrived = sp >= 0
            if arrived.any():
                cnext[lanes[arrived]] = sp[arrived]
            keep = (cur >= 0) & ~arrived
            lanes = lanes[keep]
            if lanes.size:
                w[lanes] += 1
                cur = np.asarray(gather_next(jnxt, cur[keep],
                                             segments=segments,
                                             seg_rows=seg_rows,
                                             interpret=interpret),
                                 np.int64)
        if lanes.size:                   # spine-free cycle: poison
            w[lanes] = n + 1

    # ---- rank the contracted chain with the existing doubling tables
    # (spine-index space: dense, layout-free, in-cache) — weights seed
    # the count lane, so counts come out as global hop totals
    hpos = n_mult if promoted else head // k
    bits = max(1, int(S).bit_length())
    jq = jnp.asarray(cnext, jnp.int32)
    cw = jnp.asarray(np.minimum(w, n + 1), jnp.int32)
    tables = [np.asarray(jq, np.int64)]
    for _ in range(bits):
        jq, cw = jump_double(jq, cw, interpret=interpret)
        tables.append(np.asarray(jq, np.int64))
    if int(np.asarray(jq)[hpos]) != NULL:
        raise RuntimeError("cycle in chain")   # cycle through spine nodes
    count = int(np.asarray(cw)[hpos])
    if count > n:
        raise RuntimeError("cycle in chain")   # poisoned spine-free cycle
    # contracted position walk off the tables (host, like the doubling
    # path's extraction), then exclusive-cumsum weights -> global starts
    cap = min(count, S)
    posq = np.arange(cap)
    curq = np.full(cap, hpos, np.int64)
    dead = np.zeros(cap, bool)
    for b in range(len(tables)):
        m = ((posq >> b) & 1 == 1) & ~dead
        if m.any():
            curq[m] = tables[b][curq[m]]
            dead |= curq == NULL
    wq = np.where(dead, 0, w[np.where(dead, 0, curq)])
    g = np.concatenate([[0], np.cumsum(wq)[:-1]])
    use = ~dead & (g < count)

    # ---- expand: re-walk only the used segments, emitting into out
    cur = spine[curq[use]]
    posn = g[use]
    rem = np.minimum(wq[use], count - posn)
    if fuse:
        # all runs land in one emitting pallas_call (the same fusion as
        # the local walk, plus in-kernel stores at each lane's offsets)
        if cur.size == 0:
            return np.empty(count, np.int64)
        return expand_segments(jnxt, cur, posn, rem, count,
                               segments=segments, seg_rows=seg_rows,
                               interpret=interpret)
    out = np.empty(count, np.int64)
    while cur.size:
        out[posn] = cur
        rem -= 1
        kp = rem > 0
        if not kp.any():
            break
        cur = np.asarray(gather_next(jnxt, cur[kp], segments=segments,
                                     seg_rows=seg_rows,
                                     interpret=interpret), np.int64)
        posn = posn[kp] + 1
        rem = rem[kp]
    return out
