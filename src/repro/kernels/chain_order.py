"""chain_order — pointer-doubling chain reconstruction Pallas kernel.

Device-side variant of the recovery layer's shared chain primitive
(core/recovery.py): one `jump_double` call advances every node's jump
pointer by its own current distance (jump' = jump[jump], NULL-absorbing)
and accumulates the hop count, so log2(N) rounds resolve the order/length
of a NULL-terminated chain — the §V-F reconstruction walk at hardware
speed instead of Python-loop speed.

TPU adaptation (same dynamic-gather pattern as pack_flush/hash_probe):
pointer chasing doesn't vectorize as lane ops, so the per-node gather
``jump[jump[i]]`` is steered by the *scalar-prefetched* jump array in the
BlockSpec index_map; the kernel body only masks the NULL-absorbed lanes.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NULL = -1


def _double_kernel(jmp_ref, jump_at_ref, cnt_at_ref, cnt_ref,
                   jump_out, cnt_out):
    """One doubling round for node i = program_id(0).

    jump_at/cnt_at blocks are steered to row jump[i] (clamped to 0 when
    absorbed); cnt block is row i.  Invariant maintained: after k rounds
    jump[i] = node min(2^k, L(i)) hops after i, cnt[i] = min(2^k, L(i)).
    """
    i = pl.program_id(0)
    live = jmp_ref[i] >= 0
    jump_out[...] = jnp.where(live, jump_at_ref[...], NULL)
    cnt_out[...] = cnt_ref[...] + jnp.where(live, cnt_at_ref[...], 0)


def jump_double(jump: jax.Array, cnt: jax.Array, *,
                interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """jump, cnt: (N,) int32.  Returns (jump', cnt') after one doubling
    round: jump'[i] = jump[jump[i]] (NULL absorbing), cnt'[i] = cnt[i] +
    cnt[jump[i]] for live lanes.  Out-of-range pointers terminate like
    NULL (the shared torn-epoch contract of core.recovery.jump_tables):
    sanitized here, so every round's output is in-range-or-NULL."""
    n = jump.shape[0]
    jump = jnp.where((jump >= 0) & (jump < n), jump, NULL)
    grid = (n,)
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1),
                         lambda i, j_ref: (jnp.maximum(j_ref[i], 0), 0)),
            pl.BlockSpec((1, 1),
                         lambda i, j_ref: (jnp.maximum(j_ref[i], 0), 0)),
            pl.BlockSpec((1, 1), lambda i, j_ref: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i, j_ref: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j_ref: (i, 0)),
        ],
    )
    j2, c2 = pl.pallas_call(
        _double_kernel,
        grid_spec=spec,
        out_shape=(jax.ShapeDtypeStruct((n, 1), jnp.int32),
                   jax.ShapeDtypeStruct((n, 1), jnp.int32)),
        interpret=interpret,
    )(jump, jump[:, None], cnt[:, None], cnt[:, None])
    return j2[:, 0], c2[:, 0]


def chain_tables_device(nxt: np.ndarray, bits: int, *,
                        interpret: bool = True
                        ) -> Tuple[List[np.ndarray], np.ndarray]:
    """Binary-lifting tables via the kernel: returns ([jump^(2^k) for
    k < bits], counts) with counts[i] = min(2^bits, chain length from i)."""
    # sanitize at full width BEFORE the int32 narrowing: a torn 64-bit
    # pointer like 2**32+3 would otherwise wrap to a valid-looking 3
    # instead of terminating the chain (the module-wide OOB contract)
    nxt = np.asarray(nxt)
    n = nxt.shape[0]
    jump = jnp.asarray(np.where((nxt >= 0) & (nxt < n), nxt, NULL),
                       jnp.int32)
    cnt = jnp.ones(nxt.shape[0], jnp.int32)
    tables = [np.asarray(jump, np.int64)]
    for _ in range(bits - 1):
        jump, cnt = jump_double(jump, cnt, interpret=interpret)
        tables.append(np.asarray(jump, np.int64))
    # one more round so counts saturate past 2^(bits-1)-long chains
    _, cnt = jump_double(jump, cnt, interpret=interpret)
    return tables, np.asarray(cnt, np.int64)


def chain_order_device(nxt: np.ndarray, head: int, *,
                       interpret: bool = True) -> np.ndarray:
    """Full device-built chain order: the doubling rounds run in the
    Pallas kernel; the final node-at-position extraction is a cheap
    O(count log count) gather off the returned tables.  A head outside
    [0, n) is a terminated chain (empty order) — the same OOB contract
    as the host primitive."""
    n = nxt.shape[0]
    if head < 0 or head >= n:
        return np.empty(0, np.int64)
    bits = max(1, int(n).bit_length())
    tables, cnt = chain_tables_device(nxt, bits, interpret=interpret)
    count = int(cnt[head])
    if count > n:
        raise RuntimeError("cycle in chain")
    pos = np.arange(count)
    cur = np.full(count, head, np.int64)
    for k in range(len(tables)):
        m = (pos >> k) & 1 == 1
        if m.any():
            cur[m] = tables[k][cur[m]]
    return cur
