"""chain_order — pointer-doubling chain reconstruction Pallas kernel.

Device-side variant of the recovery layer's shared chain primitive
(core/recovery.py): one `jump_double` call advances every node's jump
pointer by its own current distance (jump' = jump[jump], NULL-absorbing)
and accumulates the hop count, so log2(N) rounds resolve the order/length
of a NULL-terminated chain — the §V-F reconstruction walk at hardware
speed instead of Python-loop speed.

TPU adaptation (same dynamic-gather pattern as pack_flush/hash_probe):
pointer chasing doesn't vectorize as lane ops, so the per-node gather
``jump[jump[i]]`` is steered by the *scalar-prefetched* jump array in the
BlockSpec index_map; the kernel body only masks the NULL-absorbed lanes.

Sharded arenas (DESIGN.md §7) add a ``segments`` offset argument: a
sharded region's NEXT column arrives as N per-shard views concatenated
shard-major (what a recovery DMA reads straight out of the shard files,
no host re-gather), while pointer VALUES stay global row ids.  With the
block-cyclic segment router the packed position of global id g is
closed-form — ``packed_positions`` — so the doubling rounds steer their
gathers through the per-shard segments directly: pass
``segments=<shard row offsets>, seg_rows=<router segment size>`` and
the primitives accept the packed layout, returning global ids.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NULL = -1


def packed_positions(ids, seg_rows: int, segments):
    """Position of each global row id in a shard-major packed array.

    ``segments`` — (n_shards + 1,) row offsets of each shard's span in
    the packed array (``segments[s]`` = rows held by shards < s); shard
    of a global id under the block-cyclic router is
    ``(id // seg_rows) % n_shards`` and its local rank is
    ``(id // (seg_rows * n_shards)) * seg_rows + id % seg_rows`` —
    exact even when the last block is partial, because earlier blocks of
    a shard are always full.  Works on numpy and jax arrays alike.
    Negative ids (NULL) map to NULL."""
    n_shards = len(segments) - 1
    seg = ids // seg_rows
    shard = seg % n_shards
    local = (ids // (seg_rows * n_shards)) * seg_rows + ids % seg_rows
    if isinstance(ids, np.ndarray):
        base = np.asarray(segments)[np.maximum(shard, 0)]
        return np.where(ids >= 0, base + local, NULL)
    base = jnp.asarray(segments)[jnp.maximum(shard, 0)]
    return jnp.where(ids >= 0, base + local, NULL)


def _double_kernel(jmp_ref, jump_at_ref, cnt_at_ref, cnt_ref,
                   jump_out, cnt_out):
    """One doubling round for node i = program_id(0).

    jump_at/cnt_at blocks are steered to row jump[i] (clamped to 0 when
    absorbed); cnt block is row i.  Invariant maintained: after k rounds
    jump[i] = node min(2^k, L(i)) hops after i, cnt[i] = min(2^k, L(i)).
    """
    i = pl.program_id(0)
    live = jmp_ref[i] >= 0
    jump_out[...] = jnp.where(live, jump_at_ref[...], NULL)
    cnt_out[...] = cnt_ref[...] + jnp.where(live, cnt_at_ref[...], 0)


def jump_double(jump: jax.Array, cnt: jax.Array, *,
                segments: Optional[np.ndarray] = None,
                seg_rows: int = 0,
                interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """jump, cnt: (N,) int32.  Returns (jump', cnt') after one doubling
    round: jump'[i] = jump[jump[i]] (NULL absorbing), cnt'[i] = cnt[i] +
    cnt[jump[i]] for live lanes.  Out-of-range pointers terminate like
    NULL (the shared torn-epoch contract of core.recovery.jump_tables):
    sanitized here, so every round's output is in-range-or-NULL.

    With ``segments``/``seg_rows`` the arrays are shard-major packed
    (per-shard views of a sharded region, concatenated) while pointer
    VALUES are global ids: the steering array handed to the scalar
    prefetcher is the pointers' packed POSITION (closed-form translate),
    so each gather lands inside the right shard's segment."""
    n = jump.shape[0]
    jump = jnp.where((jump >= 0) & (jump < n), jump, NULL)
    if segments is not None:
        steer = packed_positions(jump, seg_rows, segments).astype(jnp.int32)
    else:
        steer = jump
    grid = (n,)
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1),
                         lambda i, p_ref: (jnp.maximum(p_ref[i], 0), 0)),
            pl.BlockSpec((1, 1),
                         lambda i, p_ref: (jnp.maximum(p_ref[i], 0), 0)),
            pl.BlockSpec((1, 1), lambda i, p_ref: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i, p_ref: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, p_ref: (i, 0)),
        ],
    )
    j2, c2 = pl.pallas_call(
        _double_kernel,
        grid_spec=spec,
        out_shape=(jax.ShapeDtypeStruct((n, 1), jnp.int32),
                   jax.ShapeDtypeStruct((n, 1), jnp.int32)),
        interpret=interpret,
    )(steer, jump[:, None], cnt[:, None], cnt[:, None])
    return j2[:, 0], c2[:, 0]


def chain_tables_device(nxt: np.ndarray, bits: int, *,
                        segments: Optional[np.ndarray] = None,
                        seg_rows: int = 0,
                        interpret: bool = True
                        ) -> Tuple[List[np.ndarray], np.ndarray]:
    """Binary-lifting tables via the kernel: returns ([jump^(2^k) for
    k < bits], counts) with counts[i] = min(2^bits, chain length from i).

    ``segments``/``seg_rows``: `nxt` is shard-major packed (see module
    docstring); tables then hold GLOBAL ids at PACKED positions."""
    # sanitize at full width BEFORE the int32 narrowing: a torn 64-bit
    # pointer like 2**32+3 would otherwise wrap to a valid-looking 3
    # instead of terminating the chain (the module-wide OOB contract)
    nxt = np.asarray(nxt)
    n = nxt.shape[0]
    jump = jnp.asarray(np.where((nxt >= 0) & (nxt < n), nxt, NULL),
                       jnp.int32)
    cnt = jnp.ones(nxt.shape[0], jnp.int32)
    tables = [np.asarray(jump, np.int64)]
    for _ in range(bits - 1):
        jump, cnt = jump_double(jump, cnt, segments=segments,
                                seg_rows=seg_rows, interpret=interpret)
        tables.append(np.asarray(jump, np.int64))
    # one more round so counts saturate past 2^(bits-1)-long chains
    _, cnt = jump_double(jump, cnt, segments=segments, seg_rows=seg_rows,
                         interpret=interpret)
    return tables, np.asarray(cnt, np.int64)


def chain_order_device(nxt: np.ndarray, head: int, *,
                       segments: Optional[np.ndarray] = None,
                       seg_rows: int = 0,
                       interpret: bool = True) -> np.ndarray:
    """Full device-built chain order: the doubling rounds run in the
    Pallas kernel; the final node-at-position extraction is a cheap
    O(count log count) gather off the returned tables.  A head outside
    [0, n) is a terminated chain (empty order) — the same OOB contract
    as the host primitive.

    ``segments``/``seg_rows`` accept the shard-major packed NEXT column
    of a sharded region (the per-shard persistent views, concatenated —
    no host re-gather); `head` and the returned order are global ids
    either way."""
    n = nxt.shape[0]
    if head < 0 or head >= n:
        return np.empty(0, np.int64)

    def pos_of(ids):
        if segments is None:
            return ids
        return packed_positions(ids, seg_rows, segments)

    bits = max(1, int(n).bit_length())
    tables, cnt = chain_tables_device(nxt, bits, segments=segments,
                                      seg_rows=seg_rows,
                                      interpret=interpret)
    count = int(cnt[pos_of(np.asarray([head], np.int64))[0]])
    if count > n:
        raise RuntimeError("cycle in chain")
    pos = np.arange(count)
    cur = np.full(count, head, np.int64)
    for k in range(len(tables)):
        m = (pos >> k) & 1 == 1
        if m.any():
            cur[m] = tables[k][pos_of(cur[m])]
    return cur
