"""flash_attention — blockwise online-softmax attention Pallas kernel.

The §Roofline analysis shows XLA-materialized attention dominates the
memory term of every 4k-train / 32k-prefill cell: the (Sq, Skv) score
tensor round-trips HBM several times per layer.  This kernel is the
TPU-native fix — the splash-attention pattern with the score block living
entirely in VMEM:

* grid = (B*K*G, Sq/bq, Skv/bk); the KV axis is the MINOR (fastest) grid
  dim, so the (m, l, acc) accumulators for one q-block stay resident in
  VMEM scratch across the KV sweep (TPU grid order guarantees sequential
  minor-axis execution).
* causal masking via block-level iota compare; fully-masked blocks are
  skipped by the index-map returning the same block (the compiler still
  executes them, but the mask zeroes contributions — the static
  triangular schedule of the XLA path is traded for grid regularity).
* accumulation f32; q/k/v bf16 or f32; out dtype = q dtype.

HBM traffic per layer becomes q + k + v + o (+ tiny m/l), matching the
roofline model's "kernel-adjusted" memory term.  Validated in
interpret mode against ref.flash_attention_ref on shape/dtype sweeps
(tests/test_kernels.py); TPU compilation path is pl.pallas_call with the
same BlockSpecs.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, sq: int, skv: int, bq: int, bk: int,
                  scale: float):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0].astype(jnp.float32)                  # (bk, d)
    s = q @ k.T                                       # (bq, bk)
    if causal:
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(qpos >= kpos, s, NEG_INF)
    m_prev = m_ref[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.where(s > 0.5 * NEG_INF, jnp.exp(s - m_new), 0.0)
    scale_prev = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * scale_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * scale_prev + p @ v
    m_ref[...] = m_new

    @pl.when(kj == (skv // bk) - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128, scale=None,
                    interpret: bool = True) -> jax.Array:
    """q: (H, Sq, D); k, v: (H, Skv, D) — call via vmap/reshape for batch.

    Returns (H, Sq, D) in q's dtype.  Sq % block_q == Skv % block_k == 0.
    """
    h, sq, d = q.shape
    skv = k.shape[1]
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    grid = (h, sq // bq, skv // bk)
    kernel = functools.partial(_flash_kernel, causal=causal, sq=sq,
                               skv=skv, bq=bq, bk=bk, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda hh, qi, kj: (hh, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda hh, qi, kj: (hh, kj, 0)),
            pl.BlockSpec((1, bk, d), lambda hh, qi, kj: (hh, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda hh, qi, kj: (hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max m
            pltpu.VMEM((bq, 1), jnp.float32),   # running sum l
            pltpu.VMEM((bq, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
