"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.quant_pack import GROUP
from repro.kernels.hash_probe import BUCKET


def pack_rows_ref(src: jax.Array, idx: jax.Array) -> jax.Array:
    valid = idx >= 0
    safe = jnp.where(valid, idx, 0)
    rows = src[safe]
    return jnp.where(valid[:, None], rows, jnp.zeros_like(rows))


def scatter_rows_ref(dst: jax.Array, packed: jax.Array,
                     idx: jax.Array) -> jax.Array:
    n = dst.shape[0]
    valid = idx >= 0
    oob = jnp.where(valid, idx, n)
    return dst.at[oob].set(packed, mode="drop")


def quantize_blockwise_ref(x: jax.Array):
    n, d = x.shape
    g = x.astype(jnp.float32).reshape(n, d // GROUP, GROUP)
    absmax = jnp.max(jnp.abs(g), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.reshape(n, d), scale[..., 0].astype(jnp.float32)


def dequantize_blockwise_ref(q: jax.Array, scales: jax.Array,
                             dtype=jnp.float32) -> jax.Array:
    n, d = q.shape
    g = q.reshape(n, d // GROUP, GROUP).astype(jnp.float32)
    out = g * scales[..., None]
    return out.reshape(n, d).astype(dtype)


def probe_ref(keys_table: jax.Array, queries: jax.Array,
              bucket_ids: jax.Array) -> jax.Array:
    rows = keys_table[bucket_ids]                      # (Q, BUCKET)
    hit = rows == queries[:, None]
    lane = jnp.argmax(hit, axis=1)
    found = hit.any(axis=1)
    return jnp.where(found, bucket_ids * BUCKET + lane, -1).astype(jnp.int32)


def jump_double_ref(jump: jax.Array, cnt: jax.Array):
    """Oracle for chain_order.jump_double: one pointer-doubling round
    (out-of-range pointers terminate like NULL)."""
    live = (jump >= 0) & (jump < jump.shape[0])
    safe = jnp.where(live, jump, 0)
    return (jnp.where(live, jump[safe], -1),
            cnt + jnp.where(live, cnt[safe], 0))


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, scale=None) -> jax.Array:
    """O(S^2) oracle for flash_attention.  q: (H, Sq, D); k,v: (H, Skv, D)."""
    h, sq, d = q.shape
    skv = k.shape[1]
    scale = scale if scale is not None else d ** -0.5
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        qpos = jnp.arange(sq)[:, None]
        kpos = jnp.arange(skv)[None, :]
        s = jnp.where(qpos >= kpos, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("hqk,hkd->hqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
