"""jit'd public wrappers around the Pallas kernels.

Handles: lane padding (last dim to 128/256 multiples), flattening arbitrary
pytree leaves to (N, D) row form, backend selection (compiled on TPU,
interpret elsewhere), and the leaf-level quantized-persist API used by the
checkpoint manager.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import pack_flush, quant_pack, hash_probe
from repro.kernels.quant_pack import GROUP


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------- pack / scatter ----------------

@functools.partial(jax.jit, static_argnames=("block_d",))
def pack_rows(src: jax.Array, idx: jax.Array, block_d: int = 512) -> jax.Array:
    """Gather dirty rows into a contiguous flush buffer (tile-aligned)."""
    d0 = src.shape[1]
    srcp = _pad_to(src, 128, 1)
    bd = min(block_d, srcp.shape[1])
    while srcp.shape[1] % bd:
        bd //= 2
    out = pack_flush.pack_rows(srcp, idx, block_d=bd, interpret=_interpret())
    return out[:, :d0]


@functools.partial(jax.jit, static_argnames=("block_d",))
def scatter_rows(dst: jax.Array, packed: jax.Array, idx: jax.Array,
                 block_d: int = 512) -> jax.Array:
    d0 = dst.shape[1]
    dstp = _pad_to(dst, 128, 1)
    packedp = _pad_to(packed, 128, 1)
    bd = min(block_d, dstp.shape[1])
    while dstp.shape[1] % bd:
        bd //= 2
    out = pack_flush.scatter_rows(dstp, packedp, idx, block_d=bd,
                                  interpret=_interpret())
    return out[:, :d0]


# ---------------- quantize / dequantize ----------------

def _as_rows(x: jax.Array) -> Tuple[jax.Array, Tuple[int, ...], int]:
    """Flatten any leaf to (N, GROUP*k) rows, padding the tail."""
    flat = x.reshape(-1)
    n_el = flat.shape[0]
    width = GROUP * max(1, min(16, (n_el + GROUP - 1) // GROUP))
    rows = -(-n_el // width)
    rows8 = -(-rows // 8) * 8
    padded = jnp.zeros((rows8 * width,), flat.dtype).at[:n_el].set(flat)
    return padded.reshape(rows8, width), x.shape, n_el


@jax.jit
def quantize_leaf(x: jax.Array):
    """Any-shaped float leaf -> (q int8 rows, scales, meta) for persist."""
    rows, shape, n_el = _as_rows(x)
    q, s = quant_pack.quantize_blockwise(rows, interpret=_interpret())
    return q, s


def dequantize_leaf(q: jax.Array, s: jax.Array, shape, dtype) -> jax.Array:
    rows = quant_pack.dequantize_blockwise(q, s, interpret=_interpret())
    n_el = int(np.prod(shape)) if shape else 1
    return rows.reshape(-1)[:n_el].reshape(shape).astype(dtype)


# ---------------- hash probe ----------------

@jax.jit
def hash_lookup(keys_table: jax.Array, queries: jax.Array) -> jax.Array:
    """keys_table: (n_buckets, 128) int32; queries (Q,) int32.
    Returns global slot ids (-1 absent)."""
    nb = keys_table.shape[0]
    h = hash32(queries)
    bid = (h % jnp.uint32(nb)).astype(jnp.int32)
    return hash_probe.probe(keys_table, queries, bid, interpret=_interpret())


def hash32(x: jax.Array) -> jax.Array:
    u = x.astype(jnp.uint32)
    u = (u ^ (u >> 16)) * jnp.uint32(0x7FEB352D)
    u = (u ^ (u >> 15)) * jnp.uint32(0x846CA68B)
    return u ^ (u >> 16)
