"""Sharding policy for the production mesh (DESIGN.md §5).

One module owns every axis-name decision:

* mesh construction (re-exported from the original ``launch/mesh.py``
  helpers, kept importable from both paths);
* parameter PartitionSpecs (model parallel + optional FSDP/ZeRO-3);
* batch / KV-cache PartitionSpecs for the dry-run cells;
* module-level *hooks* — activation sharding and sequence-parallel
  constraints — set per-cell by ``launch/specs.build_cell`` and consumed
  inside the traced model code via ``with_sharding_constraint``.

The production mesh is (data=16, model=16), optionally with a leading
pod=2 axis (512 chips).  PartitionSpec choices are made by divisibility
against those axis sizes, so every emitted spec shards evenly; dims that
do not divide stay replicated rather than erroring.

All hooks are no-ops until set, so single-device smoke tests trace the
exact same model code with zero constraints.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import (  # noqa: F401  (re-exported)
    MULTI_POD,
    POD_SIZE,
    SINGLE_POD,
    make_host_mesh,
    make_production_mesh,
)

PyTree = Any
Axes = Union[str, Tuple[str, ...]]

# Production axis sizes (v5e pod slice).  param_pspecs has no mesh in
# hand — divisibility is decided against these constants, which match
# both assigned meshes (the pod axis only ever appears in FSDP axes).
AXIS_SIZE: Dict[str, int] = {"data": 16, "model": 16, "pod": 2}
MODEL_AXIS = "model"

# Archs above this parameter count get ZeRO-3 (FSDP) sharding of the f32
# master params + moments by default; below it, replicated masters keep
# the param all-gathers off the critical path.
FSDP_THRESHOLD = 5_000_000_000


def _axes_tuple(axes: Axes) -> Tuple[str, ...]:
    return axes if isinstance(axes, tuple) else (axes,)


def _axes_size(axes: Axes) -> int:
    n = 1
    for a in _axes_tuple(axes):
        n *= AXIS_SIZE[a]
    return n


# ---------------------------------------------------------------------------
# FSDP policy
# ---------------------------------------------------------------------------

_FSDP: Dict[str, Axes] = {"axes": "data"}


def use_fsdp(cfg) -> bool:
    """ZeRO-3 by parameter count (>5B ⇒ shard masters/moments)."""
    return cfg.param_count() > FSDP_THRESHOLD


def set_fsdp_axes(axes: Axes) -> None:
    """Axes the FSDP dim shards over ("data" or ("pod", "data"))."""
    _FSDP["axes"] = axes


def fsdp_axes() -> Axes:
    return _FSDP["axes"]


# ---------------------------------------------------------------------------
# Data-parallel helpers
# ---------------------------------------------------------------------------


def dp_axes(mesh) -> Axes:
    """The batch-sharding axes of a mesh (pod folds into data-parallel)."""
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def _dp_divides(mesh, batch: int) -> bool:
    sizes = dict(mesh.shape)
    n = 1
    for a in _axes_tuple(dp_axes(mesh)):
        n *= sizes[a]
    return batch % n == 0


def scalar_sharding(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def to_shardings(mesh, tree: PyTree) -> PyTree:
    """PartitionSpec tree -> NamedSharding tree on the given mesh."""
    return jax.tree.map(lambda p: NamedSharding(mesh, p), tree,
                        is_leaf=lambda x: isinstance(x, P))


def attn_head_shardable(cfg) -> bool:
    """Can attention KV heads shard the 16-way model axis?  When not,
    build_cell falls back to sequence-parallel attention."""
    return cfg.n_kv_heads % AXIS_SIZE[MODEL_AXIS] == 0


# ---------------------------------------------------------------------------
# Parameter PartitionSpecs
# ---------------------------------------------------------------------------


def _leaf_pspec(shape: Tuple[int, ...], stacked: bool, fsdp: bool) -> P:
    """Model-parallel one dim (last divisible, i.e. the fan-out/feature
    dim), FSDP another (first divisible, i.e. the fan-in dim).  The
    leading superblock-stack dim of scanned leaves is never sharded."""
    rank = len(shape)
    entries: list = [None] * rank
    off = 1 if stacked else 0
    mdim = None
    for i in reversed(range(off, rank)):
        if shape[i] and shape[i] % AXIS_SIZE[MODEL_AXIS] == 0:
            mdim = i
            entries[i] = MODEL_AXIS
            break
    if fsdp:
        fx = _FSDP["axes"]
        fsize = _axes_size(fx)
        for i in range(off, rank):
            if i != mdim and shape[i] and shape[i] % fsize == 0:
                entries[i] = fx
                break
    return P(*entries)


def param_pspecs(cfg, fsdp: bool) -> PyTree:
    """PartitionSpec tree congruent with ``backbone.param_specs(cfg)``."""
    from repro.models import backbone as B

    specs = B.param_specs(cfg)

    def leaf(path, s):
        keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        stacked = bool(keys) and keys[0] in ("blocks", "enc_blocks")
        return _leaf_pspec(tuple(s.shape), stacked, fsdp)

    return jax.tree_util.tree_map_with_path(leaf, specs)


# ---------------------------------------------------------------------------
# Batch / cache PartitionSpecs
# ---------------------------------------------------------------------------


def batch_pspecs(cfg, mesh, batch: int) -> Dict[str, P]:
    """Specs for the data batch (superset of keys; callers filter)."""
    bdim = dp_axes(mesh) if _dp_divides(mesh, batch) else None
    out = {"tokens": P(bdim, None), "labels": P(bdim, None)}
    if cfg.family == "audio":
        out["frames"] = P(bdim, None, None)
    if cfg.family == "vlm":
        out["context"] = P(bdim, None, None)
    return out


def cache_pspecs(cfg, mesh, batch: int) -> PyTree:
    """Specs congruent with ``backbone.cache_specs``: batch over the DP
    axes, KV-heads/head_dim over model; the seq/capacity dim (dynamic
    ring-writes) and the scanned superblock dim stay unsharded."""
    from repro.models import backbone as B

    specs = B.cache_specs(cfg, batch, 64)  # structure only; seq not sharded
    bdim = dp_axes(mesh) if _dp_divides(mesh, batch) else None

    def leaf(path, s):
        keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        stacked = bool(keys) and keys[0] == "blocks"
        shape = tuple(s.shape)
        rank = len(shape)
        off = 1 if stacked else 0  # off = batch dim index
        entries: list = [None] * rank
        if rank > off:
            entries[off] = bdim
        # model axis on the trailing head/feature dim (skip the seq dim
        # right after batch when another dim divides first).
        for i in reversed(range(off + 1, rank)):
            if shape[i] and shape[i] % AXIS_SIZE[MODEL_AXIS] == 0:
                entries[i] = MODEL_AXIS
                break
        return P(*entries)

    return jax.tree_util.tree_map_with_path(leaf, specs)


# ---------------------------------------------------------------------------
# Traced-model hooks (set per cell, consumed under jit)
# ---------------------------------------------------------------------------

_ACT: Dict[str, Optional[NamedSharding]] = {"sharding": None}
_SEQ: Dict[str, Optional[NamedSharding]] = {"q": None, "kv": None,
                                            "res": None}


def set_activation_sharding(sharding: Optional[NamedSharding]) -> None:
    _ACT["sharding"] = sharding


def constrain_activations(x: jax.Array) -> jax.Array:
    """Re-anchor batch-parallel (B, S, d) activations (embed output and
    residual stream); no-op when unset or rank-mismatched (decode's
    (B, 1, d) still matches — a None spec entry is fine at size 1)."""
    sh = _ACT["sharding"]
    if sh is None or len(sh.spec) != x.ndim:
        return x
    return jax.lax.with_sharding_constraint(x, sh)


def set_seq_parallel(q: Optional[NamedSharding],
                     kv: Optional[NamedSharding],
                     res: Optional[NamedSharding]) -> None:
    """Sequence-parallel attention for archs whose KV heads can't shard
    the model axis: Q stays sequence-sharded, K/V all-gather, the
    attention output re-anchors to the residual sharding."""
    _SEQ["q"], _SEQ["kv"], _SEQ["res"] = q, kv, res


def seq_parallel_on() -> bool:
    return _SEQ["q"] is not None


def seq_parallel(x: jax.Array, which: str) -> jax.Array:
    sh = _SEQ[which]
    if sh is None or len(sh.spec) != x.ndim:
        return x
    return jax.lax.with_sharding_constraint(x, sh)
