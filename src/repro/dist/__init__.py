"""Distribution layer: mesh construction + sharding policy.

``repro.dist.mesh`` owns every sharding decision the framework makes —
parameter/batch/cache PartitionSpecs, FSDP policy, activation and
sequence-parallel constraints — so models and launch code never spell
axis names locally (DESIGN.md §5).
"""
from repro.dist import mesh  # noqa: F401
