"""train_step builder: loss -> grads -> AdamW, with optional microbatch
gradient accumulation (scan) — the single jit'd program the dry-run lowers.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.model import Model
from repro.optim.adamw import AdamWConfig, update
from repro.train.state import TrainState

PyTree = Any


def build_train_step(model: Model, opt: AdamWConfig,
                     schedule: Callable[[jax.Array], jax.Array],
                     microbatches: int = 1,
                     grad_sync_dtype: Optional[str] = None,
                     param_shardings: Optional[PyTree] = None):
    """Returns train_step(state, batch) -> (state, metrics).

    grad_sync_dtype: dtype the per-microbatch gradients are cast to BEFORE
    the cross-replica reduction GSPMD inserts — bf16 halves the gradient
    all-reduce bytes (the dominant collective for the MoE archs, §Perf);
    accumulation stays f32.  None keeps f32 sync (bitwise baseline).

    param_shardings: when given (distributed runs), the f32 master params
    are cast to the compute dtype and RE-CONSTRAINED to their sharding
    before the loss — forcing the FSDP all-gathers to move bf16 instead of
    f32 (2x fewer param-AG bytes, §Perf).
    """
    sync_dt = jnp.dtype(grad_sync_dtype) if grad_sync_dtype else None

    def prep_params(params):
        if param_shardings is None:
            return params
        def cast(p, s):
            if p.dtype == jnp.float32:
                return jax.lax.with_sharding_constraint(
                    p.astype(model.compute_dtype), s)
            return p
        return jax.tree.map(cast, params, param_shardings)

    def loss_fn(params, batch):
        return model.loss(prep_params(params), batch)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]
                   ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        if microbatches > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches,
                                 *x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc_body(acc, mbatch):
                l, g = jax.value_and_grad(loss_fn)(state.params, mbatch)
                if sync_dt is not None:
                    g = jax.tree.map(lambda x: x.astype(sync_dt), g)
                if param_shardings is not None:
                    # Constrain per-microbatch grads to the (FSDP-sharded)
                    # param layout: GSPMD then REDUCE-SCATTERS each
                    # microbatch's partial grads (half the bytes of the
                    # all-reduce it inserts for a replicated accumulator),
                    # and the sharded sum feeds AdamW directly (§Perf).
                    g = jax.tree.map(jax.lax.with_sharding_constraint, g,
                                     param_shardings)
                acc_l, acc_g = acc
                return (acc_l + l,
                        jax.tree.map(lambda a, b_: a + b_.astype(a.dtype),
                                     acc_g, g)), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss, grads), _ = lax.scan(acc_body, (jnp.zeros(()), zero_g), mb)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
            if sync_dt is not None:
                grads = jax.tree.map(
                    lambda x: x.astype(sync_dt).astype(jnp.float32), grads)

        lr = schedule(state.step)
        new_p, new_m, new_v, gnorm = update(
            state.params, grads, state.mu, state.nu, state.step, lr, opt)
        new_state = TrainState(
            params=new_p, mu=new_m, nu=new_v,
            step=state.step + 1,
            data_seed=state.data_seed,
            # DERIVABLE by construction: PRNGKey(data_seed) folded with step
            # (matches core.reconstruct.rebuild_rng exactly).
            rng=jax.random.fold_in(jax.random.PRNGKey(state.data_seed),
                                   state.step + 1),
        )
        metrics = {"loss": loss, "lr": lr, "grad_norm": gnorm}
        return new_state, metrics

    return train_step
