"""Trainer: the host loop tying pipeline, train_step, and checkpoints.

Fault-tolerance contract (tested in tests/test_crash_restart.py):
* checkpoint every `ckpt_every` steps through the configured policy
  (fully / partly / partly+q8 / partly+drop), async by default;
* `crash()` drops ALL volatile state (python refs + device buffers);
* `resume()` restores from the latest valid checkpoint, reconstructs
  DERIVABLE state (pipeline cursor from (seed, step), rng), and continues —
  with the partly policy + persisted moments the continued loss trajectory
  is bit-identical to an uninterrupted run (asserted in tests).
Straggler/elastic posture (single-controller runtime): per-step deadline
watchdog — a step exceeding `deadline_s` marks the incarnation failed so
the launcher respawns from the last checkpoint (see launch/train.py);
restore accepts any target mesh (ckpt.manager restore-time re-shard).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.ckpt.manager import CheckpointManager
from repro.core import policy as pol
from repro.data.pipeline import Pipeline
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig, init_moments
from repro.optim.schedule import WarmupCosine
from repro.train.state import TrainState, new_state
from repro.train.step import build_train_step

PyTree = Any


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    policy: pol.PersistPolicy = pol.PARTLY_PERSISTENT
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 64
    microbatches: int = 1
    async_ckpt: bool = True
    deadline_s: float = 0.0      # 0 = watchdog off


class Trainer:
    def __init__(self, model: Model, opt: AdamWConfig, cfg: TrainerConfig,
                 shardings: Optional[PyTree] = None):
        self.model = model
        self.opt = opt
        self.cfg = cfg
        self.schedule = WarmupCosine(total_steps=max(cfg.steps, 10))
        self.pipeline = Pipeline(model.cfg, cfg.global_batch, cfg.seq_len,
                                 seed=cfg.seed)
        self.ckpt = CheckpointManager(cfg.ckpt_dir, cfg.policy)
        self._step_fn = jax.jit(build_train_step(
            model, opt, self.schedule, cfg.microbatches))
        self.state: Optional[TrainState] = None
        self.metrics_log: list = []
        self.shardings = shardings

    # ------------------------------------------------------------------
    def init(self) -> None:
        params = self.model.init_params(jax.random.PRNGKey(self.cfg.seed))
        mu, nu = init_moments(params, self.opt)
        self.state = new_state(params, mu, nu, self.cfg.seed)

    def state_spec(self) -> TrainState:
        params = jax.eval_shape(
            lambda: self.model.init_params(jax.random.PRNGKey(0)))
        mu = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape,
                                           np.dtype(self.opt.moment_dtype)),
            params)
        return TrainState(
            params=params, mu=mu, nu=mu,
            step=jax.ShapeDtypeStruct((), np.int32),
            data_seed=jax.ShapeDtypeStruct((), np.int32),
            rng=jax.ShapeDtypeStruct((2,), np.uint32),
        )

    # ------------------------------------------------------------------
    def run(self, steps: Optional[int] = None) -> Dict[str, float]:
        assert self.state is not None, "call init() or resume() first"
        steps = steps if steps is not None else self.cfg.steps
        start = int(jax.device_get(self.state.step))
        for s in range(start, start + steps):
            batch = self.pipeline.batch_at(s)
            t0 = time.perf_counter()
            self.state, metrics = self._step_fn(self.state, batch)
            metrics = {k: float(jax.device_get(v)) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            if self.cfg.deadline_s and dt > self.cfg.deadline_s:
                raise TimeoutError(
                    f"step {s} exceeded deadline ({dt:.1f}s) — respawn "
                    f"from checkpoint")
            metrics["step"] = s
            metrics["sec"] = dt
            self.metrics_log.append(metrics)
            if self.cfg.ckpt_every and (s + 1) % self.cfg.ckpt_every == 0:
                self.ckpt.save(self.state,
                               blocking=not self.cfg.async_ckpt)
        self.ckpt.wait()
        return self.metrics_log[-1] if self.metrics_log else {}

    # ------------------------------------------------------------------
    def crash(self) -> None:
        """Drop all volatile state (simulated preemption)."""
        self.ckpt.wait()
        self.state = None
        self.pipeline.step = -1
        self.pipeline.seed = -1

    def resume(self) -> int:
        """Restore from latest checkpoint; reconstruct DERIVABLE state."""
        assert self.ckpt.valid(), "no valid checkpoint to resume from"
        self.state = self.ckpt.restore(self.state_spec(), self.shardings)
        step = int(jax.device_get(self.state.step))
        seed = int(jax.device_get(self.state.data_seed))
        # DERIVABLE reconstruction: pipeline cursor from essential scalars
        self.pipeline.reconstruct_cursor(seed, step)
        return step
