"""TrainState pytree — the unit of persistence policy classification."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class TrainState(NamedTuple):
    """Field names align with repro.core.policy.DEFAULT_RULES:
    params/step/data_seed are ESSENTIAL, mu/nu APPROXIMABLE, rng DERIVABLE.
    """
    params: PyTree
    mu: PyTree
    nu: PyTree
    step: jax.Array          # scalar int32
    data_seed: jax.Array     # scalar int32 (with step => pipeline cursor)
    rng: jax.Array           # DERIVABLE: PRNGKey(data_seed) fold_in step

    def as_dict(self) -> Dict[str, Any]:
        return self._asdict()


def new_state(params: PyTree, mu: PyTree, nu: PyTree, seed: int) -> TrainState:
    return TrainState(
        params=params, mu=mu, nu=nu,
        step=jnp.zeros((), jnp.int32),
        data_seed=jnp.asarray(seed, jnp.int32),
        rng=jax.random.PRNGKey(seed),
    )
