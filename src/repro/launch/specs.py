"""ShapeDtypeStruct stand-ins + sharding assembly for every dry-run cell.

``input_specs(cfg, shape)`` returns weak-type-correct, shardable,
zero-allocation stand-ins for every model input of the cell:

* train cells   -> {tokens, labels[, frames|context]} for ``train_step``
* prefill cells -> the same request batch for ``prefill``
* decode cells  -> (cache, tokens(B,), pos) for ``serve_step`` — one new
  token against a KV cache of seq_len, per the assignment.

``build_cell`` assembles (fn, arg_specs, in_shardings) so launch/dryrun.py
can ``jax.jit(fn, in_shardings=...).lower(*specs).compile()``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.dist import mesh as dmesh
from repro.models.model import Model, build
from repro.optim.adamw import AdamWConfig
from repro.optim.schedule import WarmupCosine
from repro.train.state import TrainState
from repro.train.step import build_train_step

PyTree = Any

# Per-arch training knobs for the production mesh (memory-driven):
# microbatches splits the per-step batch to bound live activations
# (the saved-residual stack of the layer scan scales with per-microbatch
# tokens); "fsdp" forces ZeRO-3 param sharding on archs below the
# automatic >5B threshold whose replicated attention weights would
# otherwise blow the budget; the moment dtype drops to bf16 only where
# f32 moments cannot fit 16 GB HBM (llama4-400b: 400B * 12B / 256 chips
# = 18.8 GB > 16 GB even fully sharded — DESIGN.md §5).
TRAIN_KNOBS: Dict[str, Dict[str, Any]] = {
    "llama3.2-3b": {"microbatches": 2, "fsdp": True},
    "gemma2-9b": {"microbatches": 2},
    "gemma3-27b": {"microbatches": 8},
    "phi3-medium-14b": {"microbatches": 4},
    "llama-3.2-vision-90b": {"microbatches": 8},
    "whisper-large-v3": {"microbatches": 2},
    "dbrx-132b": {"microbatches": 4},
    # fsdp_pod=True was tried and REFUTED (§Perf log L4-5): spanning the
    # pod axis moves 3.6 TB of param all-gathers onto 6.25 GB/s DCN links
    # (collective 529 -> 636 s) while activations still exceed HBM.
    # llama4-400b with an f32 master + moments is a 1024-chip model on
    # v5e; both assigned meshes are reported over-budget honestly.
    "llama4-maverick-400b-a17b": {"microbatches": 8,
                                  "moment_dtype": "bfloat16"},
    "hymba-1.5b": {"microbatches": 8},
    "xlstm-1.3b": {"microbatches": 4},
}


def train_knobs(cfg: ArchConfig) -> Dict[str, Any]:
    return {"microbatches": 1, "moment_dtype": "float32", "fsdp": None,
            "fsdp_pod": False, **TRAIN_KNOBS.get(cfg.name, {})}


def input_specs(cfg: ArchConfig, shape: ShapeSpec,
                compute_dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """Data-batch stand-ins (train/prefill).  Decode adds cache/pos via
    decode_specs."""
    b, s = shape.global_batch, shape.seq_len
    if shape.is_decode:
        return {"tokens": jax.ShapeDtypeStruct((b,), jnp.int32)}
    model = build(cfg, compute_dtype=compute_dtype)
    spec = model.batch_spec(b, s)
    if shape.kind != "train":
        spec.pop("labels", None)
    return spec


def _sds(tree: PyTree, dtype=None) -> PyTree:
    def f(x):
        dt = dtype or x.dtype
        return jax.ShapeDtypeStruct(x.shape, dt)
    return jax.tree.map(f, tree)


def state_specs(model: Model, moment_dtype: str) -> TrainState:
    params = model.param_specs()
    mdt = np.dtype(moment_dtype)
    moments = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, mdt), params)
    return TrainState(
        params=params, mu=moments, nu=moments,
        step=jax.ShapeDtypeStruct((), jnp.int32),
        data_seed=jax.ShapeDtypeStruct((), jnp.int32),
        rng=jax.ShapeDtypeStruct((2,), jnp.uint32),
    )


@dataclasses.dataclass
class Cell:
    fn: Callable
    arg_specs: Tuple
    in_shardings: Tuple
    kind: str                  # train | prefill | decode
    n_tokens: int              # tokens processed per step (decode: B)
    training: bool
    fsdp: bool
    donate: Tuple[int, ...] = ()
    out_shardings: Any = None  # None = compiler-chosen


def build_cell(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
               fsdp: Optional[bool] = None,
               compute_dtype=jnp.bfloat16) -> Cell:
    from repro.models import moe as moe_mod

    model = build(cfg, compute_dtype=compute_dtype)
    knobs = train_knobs(cfg)
    if fsdp is None:
        fsdp = dmesh.use_fsdp(cfg)
        if shape.kind == "train" and knobs["fsdp"] is not None:
            fsdp = knobs["fsdp"]
    # FSDP spans the pod axis only where per-chip optimizer state demands
    # it (llama4-400b; see dist.mesh.set_fsdp_axes).
    if (shape.kind == "train" and knobs["fsdp_pod"]
            and "pod" in mesh.axis_names):
        dmesh.set_fsdp_axes(("pod", "data"))
    else:
        dmesh.set_fsdp_axes("data")
    # bf16 row-parallel reduces for distributed cells (§Perf).
    from repro.models import layers as L
    L.LOWP_ROW_REDUCE["on"] = True
    pps = dmesh.param_pspecs(cfg, fsdp)
    to_sh = lambda t: dmesh.to_shardings(mesh, t)
    scalar = dmesh.scalar_sharding(mesh)
    dp = dmesh.dp_axes(mesh)
    b, s = shape.global_batch, shape.seq_len
    batch_shardable = dmesh._dp_divides(mesh, b)
    bdim = dp if batch_shardable else None

    if cfg.moe is not None:
        # Expert-parallel constraint: dispatched (B, E, C, d) activations
        # shard experts over "model" (GSPMD inserts the token all-to-alls).
        moe_mod.set_sharding(
            dispatch=NamedSharding(mesh, P(bdim, "model", None, None)),
            out=NamedSharding(mesh, P(bdim, None, None)))
    else:
        moe_mod.set_sharding(None, None)
    # Seed batch-parallel activation propagation (critical under FSDP).
    dmesh.set_activation_sharding(
        NamedSharding(mesh, P(bdim, None, None)))
    # Sequence-parallel attention for archs whose heads can't shard over
    # the 16-way model axis (see dist.mesh.SEQ_PARALLEL).
    if (not dmesh.attn_head_shardable(cfg) and shape.kind != "decode"
            and cfg.family in ("dense", "moe", "vlm", "audio", "hybrid")):
        dmesh.set_seq_parallel(
            q=NamedSharding(mesh, P(bdim, "model", None)),
            kv=NamedSharding(mesh, P(bdim, None, None, None)),
            res=NamedSharding(mesh, P(bdim, None, None)))
    else:
        dmesh.set_seq_parallel(None, None, None)

    if shape.kind == "train":
        knobs = train_knobs(cfg)
        opt = AdamWConfig(moment_dtype=knobs["moment_dtype"])
        sched = WarmupCosine(total_steps=10000)
        step_fn = build_train_step(
            model, opt, sched,
            microbatches=knobs["microbatches"],
            grad_sync_dtype=knobs.get("grad_sync_dtype", "bfloat16"),
            param_shardings=to_sh(pps))
        st_specs = state_specs(model, knobs["moment_dtype"])
        batch = input_specs(cfg, shape, compute_dtype)
        st_sh = TrainState(
            params=to_sh(pps), mu=to_sh(pps), nu=to_sh(pps),
            step=scalar, data_seed=scalar, rng=scalar)
        b_sh = to_sh(dmesh.batch_pspecs(cfg, mesh, b))
        # keep labels sharding only for present keys
        b_sh = {k: v for k, v in b_sh.items() if k in batch}
        return Cell(step_fn, (st_specs, batch), (st_sh, b_sh), "train",
                    n_tokens=b * s, training=True, fsdp=fsdp,
                    donate=(0,))

    params = _sds(model.param_specs(), compute_dtype)  # bf16 serving weights
    p_sh = to_sh(pps)

    if shape.kind == "prefill":
        batch = input_specs(cfg, shape, compute_dtype)
        b_sh = {k: v for k, v in
                to_sh(dmesh.batch_pspecs(cfg, mesh, b)).items()
                if k in batch}

        def prefill_fn(p, bt):
            return model.prefill(p, bt, s_max=s)

        # The emitted KV cache must leave the step SHARDED (batch over
        # data, kv-heads/head_dim over model) — without an explicit
        # out_sharding the compiler's propagation leaves the 32k cache
        # closer to replicated and the cell overflows 16 GiB.
        return Cell(prefill_fn, (params, batch), (p_sh, b_sh), "prefill",
                    n_tokens=b * s, training=False, fsdp=fsdp,
                    out_shardings=(NamedSharding(mesh, P(bdim, "model")),
                                   to_sh(dmesh.cache_pspecs(cfg, mesh, b))))

    # decode: one token against a seq_len cache
    cache = model.cache_specs(b, s)
    c_sh = to_sh(dmesh.cache_pspecs(cfg, mesh, b))
    tok = jax.ShapeDtypeStruct((b,), jnp.int32)
    tok_sh = NamedSharding(mesh, P(bdim))
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def decode_fn(p, c, t, pz):
        return model.decode_step(p, c, t, pz)

    return Cell(decode_fn, (params, cache, tok, pos),
                (p_sh, c_sh, tok_sh, scalar), "decode",
                n_tokens=b, training=False, fsdp=fsdp, donate=(1,))
