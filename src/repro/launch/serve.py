"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Boots the ServingEngine (paged-KV DLL allocator + request hashmap, both
partly persistent), serves batched greedy decode for synthetic requests,
then demonstrates the crash/recover path: all device + volatile host
state is dropped and rebuilt from the persistent arena (token log replay
re-prefills every live request).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base, registry
from repro.models.model import build
from repro.serve.engine import EngineConfig, ServingEngine


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(registry.ARCHS))
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--s-max", type=int, default=64)
    ap.add_argument("--arena", default="/tmp/repro_serve_arena")
    ap.add_argument("--crash", action="store_true",
                    help="crash mid-serve and recover")
    args = ap.parse_args()

    cfg = base.reduced(registry.get(args.arch))
    model = build(cfg, compute_dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params,
                        EngineConfig(max_batch=args.requests,
                                     s_max=args.s_max,
                                     max_requests=4 * args.requests),
                        arena_path=args.arena)

    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        prompt = rng.integers(1, cfg.vocab, rng.integers(3, 9))
        eng.add_request(100 + rid, prompt.astype(np.int64))
        print(f"[serve] request {100 + rid}: prompt={prompt.tolist()}")

    for step in range(args.steps // 2):
        out = eng.step()
        print(f"[serve] step {step}: {out}")

    if args.crash:
        print("[serve] CRASH — dropping device caches + volatile tables")
        eng.crash()
        t = eng.recover()
        print(f"[serve] recovered in {t:.3f}s (hashmap reconstructed, "
              f"LRU chain rebuilt, KV re-prefilled from token log)")

    for step in range(args.steps // 2, args.steps):
        out = eng.step()
        print(f"[serve] step {step}: {out}")
    print(f"[serve] flush stats: {eng.arena.stats}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
