import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (including repro.*):
# jax locks the device count at first init, and the production dry-run
# needs 512 placeholder devices to build the 16x16 and 2x16x16 meshes.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (GSPMD partitions every op; uneven
    shardings / unsupported collectives fail here),
  * it fits per-device HBM (compiled.memory_analysis()),
  * and it yields the roofline terms (repro.roofline on the post-SPMD HLO
    + cost_analysis) recorded in EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b \
      --shape train_4k --mesh multi
  PYTHONPATH=src python -m repro.launch.dryrun --out results/dryrun.json
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax

from repro import roofline as rl
from repro.configs import base, registry
from repro.launch.mesh import POD_SIZE, make_production_mesh
from repro.launch.specs import build_cell


def run_cell(cfg, shape, mesh, multi_pod: bool) -> Dict[str, Any]:
    from repro.models import accounting

    t0 = time.perf_counter()
    cell = build_cell(cfg, shape, mesh)
    with mesh:
        kw = {}
        if cell.out_shardings is not None:
            kw["out_shardings"] = cell.out_shardings
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         donate_argnums=cell.donate, **kw)
        lowered = jitted.lower(*cell.arg_specs)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    n_dev = mesh.devices.size
    mem = rl.memory_stats(compiled)
    model_flops = accounting.model_flops(cfg, cell.n_tokens, cell.training)
    roof = rl.analyze(compiled, n_devices=n_dev,
                      pod_size=POD_SIZE if multi_pod else 1 << 30,
                      model_flops=model_flops)
    print(compiled.memory_analysis())

    return {
        "arch": cfg.name, "shape": shape.name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": cell.kind, "fsdp": cell.fsdp,
        "status": "ok",
        "n_devices": n_dev,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem,
        "terms": {
            "compute_s": roof.compute_s,
            "memory_s": roof.memory_s,
            "collective_s": roof.collective_s,
            "dominant": roof.dominant,
            "step_s": roof.step_seconds,
        },
        "flops": {
            "hlo_dot_flops_per_dev": roof.dot_flops,
            "model_flops_global": roof.model_flops,
            "useful_ratio": roof.useful_flops_ratio,
            "mfu_at_roofline": roof.mfu,
            "raw_cost_analysis_flops": roof.raw_cost_flops,
        },
        "bytes": {
            "hbm_per_dev": roof.hbm_bytes,
            "collective_ici": roof.coll_bytes,
            "collective_dcn": roof.coll_bytes_dcn,
            "raw_cost_analysis_bytes": roof.raw_cost_bytes,
        },
        "collective_ops": roof.coll_ops,
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--fail-fast", action="store_true")
    args = ap.parse_args()

    archs = list(registry.ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(base.SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results
            if r.get("status") == "ok"}

    n_fail = 0
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        mname = "2x16x16" if multi else "16x16"
        for arch in archs:
            cfg = registry.get(arch)
            for sname in shapes:
                shape = base.SHAPES[sname]
                key = (cfg.name, shape.name, mname)
                if key in done:
                    print(f"[skip-done] {key}")
                    continue
                ok, why = registry.cell_supported(cfg, shape)
                if not ok:
                    rec = {"arch": cfg.name, "shape": shape.name,
                           "mesh": mname, "status": why}
                    print(f"[{why}] {cfg.name} x {shape.name}")
                else:
                    print(f"[dryrun] {cfg.name} x {shape.name} x {mname} ...",
                          flush=True)
                    try:
                        rec = run_cell(cfg, shape, mesh, multi)
                        t = rec["terms"]
                        print(f"  ok: compile={rec['compile_s']:.1f}s "
                              f"hbm/dev={rec['memory']['total_hbm_bytes']/2**30:.2f}GiB "
                              f"compute={t['compute_s']*1e3:.2f}ms "
                              f"memory={t['memory_s']*1e3:.2f}ms "
                              f"coll={t['collective_s']*1e3:.2f}ms "
                              f"dom={t['dominant']}", flush=True)
                    except Exception as e:
                        n_fail += 1
                        rec = {"arch": cfg.name, "shape": shape.name,
                               "mesh": mname, "status": "FAIL",
                               "error": f"{type(e).__name__}: {e}"}
                        print(f"  FAIL {type(e).__name__}: {e}")
                        traceback.print_exc()
                        if args.fail_fast:
                            raise
                results = [r for r in results
                           if (r["arch"], r["shape"], r["mesh"]) != key]
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    print(f"\n{len(results)} cells recorded, {n_fail} failures "
          f"-> {args.out}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
