"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the Trainer end to end on the local device(s) with the configured
persistence policy, crash-sim hooks, and respawn-from-checkpoint —
the single-host harness for the fault-tolerance contract.  On real
hardware the same entry point runs per host under the cluster scheduler
(jax.distributed.initialize is a no-op on one process).

Fault-tolerance loop: the trainer runs in incarnations.  If a step
exceeds the straggler deadline or the process is told to crash (test
hook), the incarnation ends and the next one restores from the latest
valid checkpoint and continues — the paper's crash/reconstruct contract
at trainer scale.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.configs import base, registry
from repro.core import policy as pol
from repro.models.model import build
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig

POLICIES = {
    "full": pol.FULLY_PERSISTENT,
    "partly": pol.PARTLY_PERSISTENT,
    "partly-q8": pol.PARTLY_Q8,
    "partly-drop": pol.PARTLY_DROP,
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(registry.ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="use the reduced same-family config (CPU)")
    ap.add_argument("--full-size", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--policy", default="partly", choices=list(POLICIES))
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--deadline-s", type=float, default=0.0)
    ap.add_argument("--crash-at-step", type=int, default=-1,
                    help="inject a crash after this step (fault-tolerance "
                         "demo); the launcher respawns from checkpoint")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = registry.get(args.arch)
    if args.reduced:
        cfg = base.reduced(cfg)
    model = build(cfg, compute_dtype=jnp.float32
                  if jax.default_backend() == "cpu" else jnp.bfloat16)
    tc = TrainerConfig(
        steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir, policy=POLICIES[args.policy],
        seed=args.seed, global_batch=args.global_batch,
        seq_len=args.seq_len, microbatches=args.microbatches,
        deadline_s=args.deadline_s)
    trainer = Trainer(model, AdamWConfig(), tc)

    if args.resume and trainer.ckpt.valid():
        step = trainer.resume()
        print(f"[train] resumed incarnation at step {step}")
    else:
        trainer.init()
        print(f"[train] fresh start: {cfg.name} ({args.policy} persistence)")

    start = int(jax.device_get(trainer.state.step))
    end = args.steps
    while start < end:
        run_until = min(end, args.crash_at_step) \
            if start <= args.crash_at_step < end else end
        trainer.run(run_until - start)
        start = int(jax.device_get(trainer.state.step))
        if start == args.crash_at_step:
            print(f"[train] CRASH injected at step {start}; respawning...")
            trainer.crash()
            resumed = trainer.resume()
            print(f"[train] incarnation 2 restored at step {resumed} "
                  f"(reconstructed pipeline cursor + rng)")
            start = resumed
            args.crash_at_step = -1

    last = trainer.metrics_log[-1]
    rep = trainer.ckpt.last_report
    print(json.dumps({
        "final_step": last["step"], "final_loss": round(last["loss"], 4),
        "ckpt_bytes_written": rep.bytes_written if rep else 0,
        "ckpt_bytes_skipped_derivable":
            rep.bytes_skipped_derivable if rep else 0,
    }, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
