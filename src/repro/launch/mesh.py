"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (device count is locked at first jax init, and
smoke tests must see 1 CPU device while the dry-run sees 512 placeholders).
"""
from __future__ import annotations

import jax

SINGLE_POD = (16, 16)                 # 256 chips (v5e pod slice)
MULTI_POD = (2, 16, 16)               # 2 pods = 512 chips
POD_SIZE = 256


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh over the real local device (smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
