"""Backbone assembly: layer-type dispatch, superblock scan, Model API.

The stack is organized as ``n_super`` repetitions of the config's
``layer_pattern`` ("superblock") plus an unrolled remainder.  Superblock
parameters are stacked on a leading axis and consumed by one ``lax.scan``,
so HLO size is O(|pattern|), not O(n_layers) — a 62-layer gemma3 compiles
the same superblock body as a 6-layer toy.  Per-position layer types inside
the pattern are *static* (no runtime branching ⇒ exact cost_analysis FLOPs).

Modes:
  train   — full-sequence forward, no caches, remat-wrapped superblocks
  prefill — full-sequence forward, emits decode caches
  decode  — single-token step consuming/updating caches (scan carries the
            token activation; caches stream through scan xs/ys)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import xlstm as X

Array = jax.Array
PyTree = Any

# Remat policy applied to the superblock body in train mode.  "none" saves
# everything (no recompute), "full" saves nothing (max recompute, min HBM),
# "dots" saves matmul outputs with no batch dims.
REMAT = {"policy": "full"}


def _remat_wrap(fn):
    pol = REMAT["policy"]
    if pol == "none":
        return fn
    if pol == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def parse_tag(tag: str) -> Tuple[str, str]:
    base, _, var = tag.partition(":")
    return base, (var or "full")


# ---------------------------------------------------------------------------
# Parameter shape construction
# ---------------------------------------------------------------------------


def _attn_shapes(cfg: ArchConfig, cross: bool = False) -> Dict[str, Tuple[int, ...]]:
    d, h, k, e = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    out = {"wq": (d, h, e), "wk": (d, k, e), "wv": (d, k, e), "wo": (h, e, d)}
    if cfg.qk_norm and not cross:
        out["q_norm"] = (e,)
        out["k_norm"] = (e,)
    return out


def _mlp_shapes(d: int, f: int) -> Dict[str, Tuple[int, ...]]:
    return {"w_gate": (d, f), "w_up": (d, f), "w_down": (f, d)}


def _moe_shapes(cfg: ArchConfig) -> Dict[str, Tuple[int, ...]]:
    mc = cfg.moe
    d = cfg.d_model
    f = mc.expert_d_ff or cfg.d_ff
    out = {
        "router": (d, mc.n_experts),
        "w_gate": (mc.n_experts, d, f),
        "w_up": (mc.n_experts, d, f),
        "w_down": (mc.n_experts, f, d),
    }
    if mc.shared_expert:
        out.update({"s_gate": (d, f), "s_up": (d, f), "s_down": (f, d)})
    return out


def _mamba_shapes(cfg: ArchConfig) -> Dict[str, Tuple[int, ...]]:
    d = cfg.d_model
    di = cfg.ssm.expand * d
    n = cfg.ssm.state_dim
    dt_rank = max(1, d // 16)
    return {
        "in_proj": (d, 2 * di),
        "conv": (di, cfg.ssm.conv_width),
        "x_proj": (di, dt_rank + 2 * n),
        "dt_w": (dt_rank, di),
        "dt_bias": (di,),
        "a_log": (di, n),
        "d_skip": (di,),
    }


def layer_shapes(cfg: ArchConfig, tag: str) -> Dict[str, Any]:
    base, var = parse_tag(tag)
    d = cfg.d_model
    sh: Dict[str, Any] = {"ln1": (d,)}
    if base in ("dense", "attn", "moe"):
        if var == "cross" and cfg.family == "vlm":
            sh["xattn"] = _attn_shapes(cfg, cross=True)
            sh["xgate"] = ()
        else:
            sh["attn"] = _attn_shapes(cfg)
            if var == "cross":              # audio: self + cross
                sh["ln_x"] = (d,)
                sh["xattn"] = _attn_shapes(cfg, cross=True)
        sh["ln2"] = (d,)
        if base == "moe":
            sh["moe"] = _moe_shapes(cfg)
        else:
            sh["mlp"] = _mlp_shapes(d, cfg.d_ff)
    elif base == "hybrid":
        di = cfg.ssm.expand * d
        sh["attn"] = _attn_shapes(cfg)
        sh["mamba"] = _mamba_shapes(cfg)
        sh["norm_attn"] = (cfg.n_heads * cfg.resolved_head_dim,)
        sh["norm_mamba"] = (di,)
        sh["ln2"] = (d,)
        sh["mlp"] = _mlp_shapes(d, cfg.d_ff)
        # wo lives in sh["attn"]; hybrid projects the *combined* stream:
        sh["attn"] = {k: v for k, v in sh["attn"].items() if k != "wo"}
        sh["wo"] = (cfg.n_heads * cfg.resolved_head_dim, d)
        sh["w_mamba_out"] = (di, d)
    elif base == "mlstm":
        h = cfg.n_heads
        dv = cfg.resolved_head_dim
        dk = max(dv // 2, 8)
        sh.update({
            "wq": (d, h, dk), "wk": (d, h, dk), "wv": (d, h, dv),
            "w_if": (d, 2, h), "b_if": (2, h), "w_og": (d, h, dv),
            "out_norm": (h * dv,), "wo": (h, dv, d),
        })
    elif base == "slstm":
        h = cfg.n_heads
        dh = cfg.d_model // cfg.n_heads
        fx = int((cfg.xlstm.proj_factor if cfg.xlstm else 2.0) * d)
        sh.update({
            "w_in": (d, 4, h, dh), "b_in": (4, h, dh), "r": (4, h, dh, dh),
            "out_norm": (d,), "wo": (d, d), "ln2": (d,),
            "mlp": _mlp_shapes(d, fx),
        })
    else:
        raise ValueError(f"unknown layer tag {tag}")
    return sh


def _leaf_specs(tree, prefix_dims=()):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(tuple(prefix_dims) + tuple(s), jnp.float32),
        tree, is_leaf=lambda x: isinstance(x, tuple))


def param_specs(cfg: ArchConfig) -> PyTree:
    pattern, n_super, rem = cfg.pattern_plan()
    p: Dict[str, Any] = {
        "embed": jax.ShapeDtypeStruct((cfg.vocab_padded, cfg.d_model), jnp.float32),
        "final_norm": jax.ShapeDtypeStruct((cfg.d_model,), jnp.float32),
    }
    if n_super:
        p["blocks"] = {
            f"pos{i}": _leaf_specs(layer_shapes(cfg, t), (n_super,))
            for i, t in enumerate(pattern)
        }
    if rem:
        p["rem"] = {
            f"rem{i}": _leaf_specs(layer_shapes(cfg, t))
            for i, t in enumerate(rem)
        }
    if not cfg.tie_embeddings:
        p["lm_head"] = jax.ShapeDtypeStruct((cfg.d_model, cfg.vocab_padded), jnp.float32)
    if cfg.encoder_layers:
        p["enc_blocks"] = {
            "pos0": _leaf_specs(layer_shapes(cfg, "dense:bidir"),
                                (cfg.encoder_layers,))
        }
        p["enc_final_norm"] = jax.ShapeDtypeStruct((cfg.d_model,), jnp.float32)
    return p


def init_params(cfg: ArchConfig, rng: jax.Array) -> PyTree:
    """Materialize real parameters (smoke tests / examples only)."""
    specs = param_specs(cfg)
    leaves, treedef = jax.tree.flatten(specs)
    rngs = jax.random.split(rng, len(leaves))
    out = []
    for r, s in zip(rngs, leaves):
        fan_in = s.shape[0] if len(s.shape) > 1 else max(s.shape[-1], 1)
        scale = 0.02 if len(s.shape) <= 1 else min(0.02, (1.0 / fan_in) ** 0.5)
        if len(s.shape) == 0 or (len(s.shape) >= 1 and s.shape == ()):
            out.append(jnp.zeros(s.shape, s.dtype))
        elif len(s.shape) == 1:
            out.append(jnp.zeros(s.shape, s.dtype))  # norms/bias start at 0
        else:
            out.append(scale * jax.random.normal(r, s.shape, s.dtype))
    params = jax.tree.unflatten(treedef, out)
    params = _fix_special_inits(cfg, params)
    return params


def _fix_special_inits(cfg: ArchConfig, params: PyTree) -> PyTree:
    """SSM a_log / dt_bias need structured init for stability."""
    def fix(path, x):
        keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        if "a_log" in keys:
            n = x.shape[-1]
            base = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))
            return jnp.broadcast_to(base, x.shape)
        if "dt_bias" in keys:
            return jnp.full(x.shape, -2.0, x.dtype)  # softplus -> small dt
        if "d_skip" in keys:
            return jnp.ones(x.shape, x.dtype)
        return x
    return jax.tree_util.tree_map_with_path(fix, params)


# ---------------------------------------------------------------------------
# Cache shape construction (decode)
# ---------------------------------------------------------------------------


def _cache_shapes(cfg: ArchConfig, tag: str, batch: int, s_max: int,
                  dtype) -> Dict[str, jax.ShapeDtypeStruct]:
    base, var = parse_tag(tag)
    k, e = cfg.n_kv_heads, cfg.resolved_head_dim
    sh: Dict[str, Any] = {}

    def sds(shape, dt=dtype):
        return jax.ShapeDtypeStruct(shape, dt)

    if base in ("dense", "attn", "moe", "hybrid"):
        if var == "cross" and cfg.family == "vlm":
            ctx = cfg.context_seq
            sh["xk"] = sds((batch, ctx, k, e))
            sh["xv"] = sds((batch, ctx, k, e))
        else:
            cap = min(cfg.window, s_max) if var == "local" else s_max
            sh["k"] = sds((batch, cap, k, e))
            sh["v"] = sds((batch, cap, k, e))
            if var == "cross":   # audio self+cross
                sh["xk"] = sds((batch, cfg.encoder_seq, k, e))
                sh["xv"] = sds((batch, cfg.encoder_seq, k, e))
    if base == "hybrid":
        di = cfg.ssm.expand * cfg.d_model
        sh["ssm"] = sds((batch, di, cfg.ssm.state_dim), jnp.float32)
        sh["conv"] = sds((batch, cfg.ssm.conv_width - 1, di))
    if base == "mlstm":
        h, dv = cfg.n_heads, cfg.resolved_head_dim
        dk = max(dv // 2, 8)
        sh["c"] = sds((batch, h, dk, dv), jnp.float32)
        sh["n"] = sds((batch, h, dk), jnp.float32)
        sh["m"] = sds((batch, h), jnp.float32)
    if base == "slstm":
        h = cfg.n_heads
        dh = cfg.d_model // cfg.n_heads
        for name in ("c", "n", "h", "m"):
            sh[name] = sds((batch, h, dh), jnp.float32)
    return sh


def cache_specs(cfg: ArchConfig, batch: int, s_max: int,
                dtype=jnp.bfloat16) -> PyTree:
    pattern, n_super, rem = cfg.pattern_plan()
    out: Dict[str, Any] = {}
    if n_super:
        out["blocks"] = {
            f"pos{i}": jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((n_super,) + s.shape, s.dtype),
                _cache_shapes(cfg, t, batch, s_max, dtype))
            for i, t in enumerate(pattern)
        }
    if rem:
        out["rem"] = {
            f"rem{i}": _cache_shapes(cfg, t, batch, s_max, dtype)
            for i, t in enumerate(rem)
        }
    return out


def init_cache(cfg: ArchConfig, batch: int, s_max: int,
               dtype=jnp.bfloat16) -> PyTree:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_specs(cfg, batch, s_max, dtype))


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------


def _attn_params(p: Dict[str, Array]) -> L.AttnParams:
    return L.AttnParams(wq=p["wq"], wk=p["wk"], wv=p["wv"],
                        wo=p.get("wo"), q_norm=p.get("q_norm"),
                        k_norm=p.get("k_norm"))


def _self_attention_seq(cfg: ArchConfig, p, x, positions, *, causal, window):
    from repro.dist import mesh as dmesh

    sp = dmesh.seq_parallel_on()
    if sp:
        x = dmesh.seq_parallel(x, "q")          # (B, S/16, d) per device
    q, k, v = L.project_qkv(x, _attn_params(p), cfg.n_kv_heads,
                            positions=positions, theta=cfg.rope_theta)
    if sp:
        # causal attention needs the full KV prefix: gather K/V over the
        # model axis, keep Q sequence-sharded (one q-block => the score
        # tensor stays (B, K, G, S/16, S) per device).
        k = dmesh.seq_parallel(k, "kv")
        v = dmesh.seq_parallel(v, "kv")
    att = L.blockwise_attention(q, k, v, causal=causal, window=window,
                                softcap=cfg.attn_softcap,
                                q_block=(x.shape[1] if sp else 1024))
    return att, k, v


def _cross_attention_seq(cfg: ArchConfig, p, x, ctx):
    dt = x.dtype
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(dt),
                   preferred_element_type=jnp.float32).astype(dt)
    b, s, h, e = q.shape
    q = q.reshape(b, s, cfg.n_kv_heads, h // cfg.n_kv_heads, e)
    xk = jnp.einsum("bsd,dke->bske", ctx.astype(dt), p["wk"].astype(dt),
                    preferred_element_type=jnp.float32).astype(dt)
    xv = jnp.einsum("bsd,dke->bske", ctx.astype(dt), p["wv"].astype(dt),
                    preferred_element_type=jnp.float32).astype(dt)
    att = L.blockwise_attention(q, xk, xv, causal=False)
    return att, xk, xv


def _mamba_seq(cfg: ArchConfig, p, x, conv_tail, state0):
    """x: (B, S, d) -> (y (B,S,di->d is caller's job: returns (B,S,di)),
    new_conv_tail, new_state)."""
    di = cfg.ssm.expand * cfg.d_model
    n = cfg.ssm.state_dim
    dt_rank = max(1, cfg.d_model // 16)
    dt_ = x.dtype
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dt_),
                    preferred_element_type=jnp.float32).astype(dt_)
    xs, z = jnp.split(xz, 2, axis=-1)
    xc, new_tail = S.depthwise_conv(xs, p["conv"], conv_tail)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(dt_)
    proj = jnp.einsum("bsc,ce->bse", xc, p["x_proj"].astype(dt_),
                      preferred_element_type=jnp.float32)
    dt_low, bmat, cmat = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt_full = jax.nn.softplus(
        jnp.einsum("bsr,rc->bsc", dt_low, p["dt_w"].astype(jnp.float32))
        + p["dt_bias"].astype(jnp.float32))
    y, state = S.ssm_scan(xc, dt_full.astype(dt_), p["a_log"], bmat, cmat,
                          p["d_skip"], state0)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(dt_)
    return y, new_tail, state


def _mamba_step(cfg: ArchConfig, p, x_t, conv_tail, state):
    """x_t: (B, 1, d).  Single decode step."""
    n = cfg.ssm.state_dim
    dt_rank = max(1, cfg.d_model // 16)
    dt_ = x_t.dtype
    xz = jnp.einsum("bsd,de->bse", x_t, p["in_proj"].astype(dt_),
                    preferred_element_type=jnp.float32).astype(dt_)
    xs, z = jnp.split(xz, 2, axis=-1)
    # conv over (tail ++ x)
    full = jnp.concatenate([conv_tail, xs], axis=1)       # (B, cw, di)
    w = p["conv"].astype(jnp.float32)
    xc = jnp.sum(full.astype(jnp.float32) * w.T[None], axis=1, keepdims=True)
    xc = jax.nn.silu(xc).astype(dt_)
    new_tail = full[:, 1:]
    proj = jnp.einsum("bsc,ce->bse", xc, p["x_proj"].astype(dt_),
                      preferred_element_type=jnp.float32)
    dt_low, bmat, cmat = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt_full = jax.nn.softplus(
        jnp.einsum("bsr,rc->bsc", dt_low, p["dt_w"].astype(jnp.float32))
        + p["dt_bias"].astype(jnp.float32))
    y, state = S.ssm_step(xc[:, 0], dt_full[:, 0].astype(dt_), p["a_log"],
                          bmat[:, 0], cmat[:, 0], p["d_skip"], state)
    y = y[:, None] * jax.nn.silu(z.astype(jnp.float32)).astype(dt_)
    return y, new_tail, state


def _mlstm_proj(cfg, p, x):
    dt = x.dtype
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(dt),
                   preferred_element_type=jnp.float32).astype(dt)
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"].astype(dt),
                   preferred_element_type=jnp.float32).astype(dt)
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"].astype(dt),
                   preferred_element_type=jnp.float32).astype(dt)
    gates = jnp.einsum("bsd,dgh->bsgh", x, p["w_if"].astype(dt),
                       preferred_element_type=jnp.float32) + p["b_if"].astype(jnp.float32)
    og = jnp.einsum("bsd,dhe->bshe", x, p["w_og"].astype(dt),
                    preferred_element_type=jnp.float32)
    return q, k, v, gates[:, :, 0], gates[:, :, 1], og


def _seat_cache(k_all: Array, cap_total: int) -> Array:
    """Place the tail of prefill K/V (B, S, ...) into a fresh ring/linear
    cache of capacity cap_total, at the slots decode will expect
    (slot = abs_pos % cap_total)."""
    b, s = k_all.shape[:2]
    t = min(cap_total, s)
    tail = k_all[:, s - t:]
    slots = np.arange(s - t, s) % cap_total
    out = jnp.zeros((b, cap_total) + k_all.shape[2:], k_all.dtype)
    return out.at[:, slots].set(tail)


def apply_layer(cfg: ArchConfig, tag: str, p: Dict[str, Any], x: Array, *,
                mode: str, ctx: Optional[Array] = None,
                cache: Optional[Dict[str, Array]] = None,
                pos: Optional[Array] = None,
                s_max: Optional[int] = None) -> Tuple[Array, Optional[Dict]]:
    """Apply one layer.  Returns (x, new_cache)."""
    base, var = parse_tag(tag)
    b, s, d = x.shape
    s_max = s_max or s
    new_cache: Dict[str, Array] = {}
    rms = functools.partial(L.rms_norm, eps=cfg.norm_eps)

    if base in ("dense", "attn", "moe"):
        # ---- mixer ----
        if var == "cross" and cfg.family == "vlm":
            y = rms(x, p["ln1"])
            if mode == "decode":
                q = jnp.einsum("bsd,dhe->bshe", y, p["xattn"]["wq"].astype(y.dtype),
                               preferred_element_type=jnp.float32).astype(y.dtype)
                q = q.reshape(b, s, cfg.n_kv_heads, cfg.q_group, -1)
                ctx_pos = jnp.arange(cache["xk"].shape[1])
                att = L.decode_attention(q, cache["xk"], cache["xv"], ctx_pos,
                                         jnp.array(1 << 30))
                new_cache["xk"], new_cache["xv"] = cache["xk"], cache["xv"]
            else:
                att, xk, xv = _cross_attention_seq(cfg, p["xattn"], y, ctx)
                if mode == "prefill":
                    new_cache["xk"], new_cache["xv"] = xk, xv
            att = L.attn_out(att, p["xattn"]["wo"])
            x = x + jnp.tanh(p["xgate"].astype(jnp.float32)).astype(x.dtype) * att
        else:
            y = rms(x, p["ln1"])
            window = cfg.window if var == "local" else 0
            causal = var != "bidir"
            if mode == "decode":
                cap = cache["k"].shape[1]
                positions = pos[None] if pos.ndim == 0 else pos
                q, k_new, v_new = L.project_qkv(
                    y, _attn_params(p["attn"]), cfg.n_kv_heads,
                    positions=positions, theta=cfg.rope_theta)
                k_c = L.ring_write(cache["k"], k_new, pos, cap)
                v_c = L.ring_write(cache["v"], v_new, pos, cap)
                kv_pos = L.ring_slot_positions(pos, cap)
                att = L.decode_attention(q, k_c, v_c, kv_pos, pos,
                                         window=window,
                                         softcap=cfg.attn_softcap)
                new_cache["k"], new_cache["v"] = k_c, v_c
            else:
                positions = jnp.arange(s)
                att, k_all, v_all = _self_attention_seq(
                    cfg, p["attn"], y, positions, causal=causal, window=window)
                if mode == "prefill":
                    cap = min(cfg.window, s_max) if var == "local" else s_max
                    new_cache["k"] = _seat_cache(k_all, cap)
                    new_cache["v"] = _seat_cache(v_all, cap)
            att = L.attn_out(att, p["attn"]["wo"])
            if mode != "decode":
                from repro.dist import mesh as dmesh
                att = dmesh.seq_parallel(att, "res")
            x = x + att
            if var == "cross":           # audio decoder: self + cross
                y2 = rms(x, p["ln_x"])
                if mode == "decode":
                    q = jnp.einsum("bsd,dhe->bshe", y2,
                                   p["xattn"]["wq"].astype(y2.dtype),
                                   preferred_element_type=jnp.float32).astype(y2.dtype)
                    q = q.reshape(b, s, cfg.n_kv_heads, cfg.q_group, -1)
                    ctx_pos = jnp.arange(cache["xk"].shape[1])
                    att2 = L.decode_attention(q, cache["xk"], cache["xv"],
                                              ctx_pos, jnp.array(1 << 30))
                    new_cache["xk"], new_cache["xv"] = cache["xk"], cache["xv"]
                else:
                    att2, xk, xv = _cross_attention_seq(cfg, p["xattn"], y2, ctx)
                    if mode == "prefill":
                        new_cache["xk"], new_cache["xv"] = xk, xv
                x = x + L.attn_out(att2, p["xattn"]["wo"])
        # ---- ffn ----
        y = rms(x, p["ln2"])
        if base == "moe":
            mc = cfg.moe
            mp = M.MoEParams(router=p["moe"]["router"], w_gate=p["moe"]["w_gate"],
                             w_up=p["moe"]["w_up"], w_down=p["moe"]["w_down"],
                             s_gate=p["moe"].get("s_gate"),
                             s_up=p["moe"].get("s_up"),
                             s_down=p["moe"].get("s_down"))
            x = x + M.moe_ffn(y, mp, mc, cfg.act)
        else:
            x = x + L.gated_mlp(y, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                                p["mlp"]["w_down"], cfg.act)
        return x, (new_cache or None)

    if base == "hybrid":
        di = cfg.ssm.expand * d
        y = rms(x, p["ln1"])
        window = cfg.window if var == "local" else 0
        ap = _attn_params(p["attn"])
        if mode == "decode":
            cap = cache["k"].shape[1]
            positions = pos[None] if pos.ndim == 0 else pos
            q, k_new, v_new = L.project_qkv(y, ap, cfg.n_kv_heads,
                                            positions=positions,
                                            theta=cfg.rope_theta)
            k_c = L.ring_write(cache["k"], k_new, pos, cap)
            v_c = L.ring_write(cache["v"], v_new, pos, cap)
            kv_pos = L.ring_slot_positions(pos, cap)
            att = L.decode_attention(q, k_c, v_c, kv_pos, pos, window=window)
            new_cache["k"], new_cache["v"] = k_c, v_c
            m_out, new_tail, new_state = _mamba_step(cfg, p["mamba"], y,
                                                     cache["conv"],
                                                     cache["ssm"])
            new_cache["conv"], new_cache["ssm"] = new_tail, new_state
        else:
            from repro.dist import mesh as dmesh
            positions = jnp.arange(s)
            # Sequence-parallel attention branch (25H/5kv can't shard the
            # 16-way model axis); the mamba branch keeps batch-sharded y —
            # its d_inner is already model-parallel.
            y_att = dmesh.seq_parallel(y, "q")
            q, k_all, v_all = L.project_qkv(y_att, ap, cfg.n_kv_heads,
                                            positions=positions,
                                            theta=cfg.rope_theta)
            k_all = dmesh.seq_parallel(k_all, "kv")
            v_all = dmesh.seq_parallel(v_all, "kv")
            att = L.blockwise_attention(
                q, k_all, v_all, causal=True, window=window,
                q_block=(s if dmesh.seq_parallel_on() else 1024))
            state0 = jnp.zeros((b, di, cfg.ssm.state_dim), jnp.float32)
            m_out, new_tail, new_state = _mamba_seq(cfg, p["mamba"], y, None,
                                                    state0)
            if mode == "prefill":
                cap = min(cfg.window, s_max) if var == "local" else s_max
                new_cache["k"] = _seat_cache(k_all, cap)
                new_cache["v"] = _seat_cache(v_all, cap)
                new_cache["conv"], new_cache["ssm"] = new_tail, new_state
        a_flat = att.reshape(b, s, -1)
        a_mix = rms(a_flat, p["norm_attn"]) @ p["wo"].astype(x.dtype)
        if mode != "decode":
            from repro.dist import mesh as dmesh
            a_mix = dmesh.seq_parallel(a_mix, "res")
        mix = (a_mix
               + rms(m_out, p["norm_mamba"]) @ p["w_mamba_out"].astype(x.dtype))
        x = x + 0.5 * mix
        y = rms(x, p["ln2"])
        x = x + L.gated_mlp(y, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                            p["mlp"]["w_down"], cfg.act)
        return x, (new_cache or None)

    if base == "mlstm":
        y = rms(x, p["ln1"])
        q, k, v, i_pre, f_pre, og = _mlstm_proj(cfg, p, y)
        if mode == "decode":
            st = X.MLSTMState(cache["c"], cache["n"], cache["m"])
            yc, st2 = X.mlstm_step(q[:, 0], k[:, 0], v[:, 0],
                                   i_pre[:, 0], f_pre[:, 0], st)
            yc = yc[:, None]
            new_cache = {"c": st2.c, "n": st2.n, "m": st2.m}
        else:
            hh, dv = cfg.n_heads, cfg.resolved_head_dim
            dk = max(dv // 2, 8)
            st = X.mlstm_init_state(b, hh, dk, dv)
            chunk = cfg.xlstm.chunk if cfg.xlstm else 256
            yc, st2 = X.mlstm_chunkwise(q, k, v, i_pre, f_pre, st, chunk=chunk)
            if mode == "prefill":
                new_cache = {"c": st2.c, "n": st2.n, "m": st2.m}
        yc = yc * jax.nn.sigmoid(og).astype(yc.dtype)
        flat = yc.reshape(b, s, -1)
        flat = rms(flat, p["out_norm"])
        out = jnp.einsum("bshe,hed->bsd",
                         flat.reshape(b, s, cfg.n_heads, -1),
                         p["wo"].astype(x.dtype),
                         preferred_element_type=jnp.float32).astype(x.dtype)
        return x + out, (new_cache or None)

    if base == "slstm":
        y = rms(x, p["ln1"])
        pre = (jnp.einsum("bsd,dghe->bsghe", y, p["w_in"].astype(y.dtype),
                          preferred_element_type=jnp.float32)
               + p["b_in"].astype(jnp.float32)).astype(y.dtype)
        if mode == "decode":
            st = X.SLSTMState(cache["c"], cache["n"], cache["h"], cache["m"])
            h_out, st2 = X.slstm_step(pre[:, 0], p["r"], st)
            h_out = h_out[:, None]
            new_cache = {"c": st2.c, "n": st2.n, "h": st2.h, "m": st2.m}
        else:
            hh = cfg.n_heads
            dh = cfg.d_model // hh
            st = X.slstm_init_state(b, hh, dh)
            h_out, st2 = X.slstm_scan(pre, p["r"], st)
            if mode == "prefill":
                new_cache = {"c": st2.c, "n": st2.n, "h": st2.h, "m": st2.m}
        flat = h_out.reshape(b, s, d).astype(x.dtype)
        flat = rms(flat, p["out_norm"])
        x = x + (flat @ p["wo"].astype(x.dtype)).astype(x.dtype)
        y = rms(x, p["ln2"])
        x = x + L.gated_mlp(y, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                            p["mlp"]["w_down"], cfg.act)
        return x, (new_cache or None)

    raise ValueError(f"unknown layer base {base}")
