"""Core transformer layers: norms, RoPE, gated MLPs, blockwise attention.

Attention design notes (TPU adaptation):

* Prefill/train attention is *blockwise* with an online-softmax scan over KV
  blocks (the splash-attention pattern): memory is O(q_block * kv_block)
  instead of O(S^2).
* The causal schedule is **statically triangular**: a Python loop over query
  blocks, each scanning only its KV prefix.  This keeps compiled HLO FLOPs
  equal to the true triangular cost (no 2x masked-waste), which matters for
  honest roofline accounting at 32k prefill.
* Sliding-window layers slice the banded KV range per query block with a
  *static* slice (python ints), so local attention costs O(S*W) exactly.
* Decode uses direct softmax over the cache; sliding-window decode uses a
  ring buffer whose absolute slot positions are derived from `pos` (no
  stored position tensor needed).

All matmuls accumulate in f32 (`preferred_element_type`), activations are
bf16 by default.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: Array, gamma: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * lax.rsqrt(var + eps)
    return (y * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def layer_norm(x: Array, gamma: Array, beta: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> Array:
    """(head_dim//2,) inverse frequencies, f32."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """Rotate-half RoPE.  x: (..., S, ..., head_dim) with positions (..., S)
    broadcastable against x's sequence axis; here we require
    x: (B, S, N, D) [or (B, S, N, G, D)] and positions: (S,) or (B, S)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                     # (d/2,)
    pos = positions.astype(jnp.float32)
    ang = pos[..., None] * inv                     # (..., S, d/2)
    # Broadcast angles over head axes between S and D.
    extra = x.ndim - ang.ndim - 1
    for _ in range(extra):
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": functools.partial(jax.nn.gelu, approximate=True)}[name]


def gated_mlp(x: Array, w_gate: Array, w_up: Array, w_down: Array, act: str) -> Array:
    """x: (..., d).  w_gate/w_up: (d, f); w_down: (f, d)."""
    dt = x.dtype
    g = jnp.einsum("...d,df->...f", x, w_gate.astype(dt),
                   preferred_element_type=_row_reduce_dtype(dt))
    u = jnp.einsum("...d,df->...f", x, w_up.astype(dt),
                   preferred_element_type=_row_reduce_dtype(dt))
    h = (act_fn(act)(g) * u).astype(dt)
    return jnp.einsum("...f,fd->...d", h, w_down.astype(dt),
                      preferred_element_type=_row_reduce_dtype(dt)
                      ).astype(dt)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)

# §Perf: emit row-parallel matmul outputs (attn O-projection, MLP down-
# projection, MoE down-projection) at the compute dtype instead of f32.
# GSPMD inserts the cross-shard partial-sum all-reduce directly on the dot
# output, so a bf16 output halves the dominant TP activation-reduce bytes
# (gemma3 train: 37.6 s -> ~19 s collective).  MXU accumulation is f32
# internally either way; the cross-device add happens in bf16 (standard
# Megatron practice).  Off by default (bitwise-f32 baseline); enabled by
# launch/specs.build_cell for distributed cells.
LOWP_ROW_REDUCE = {"on": False}


def _row_reduce_dtype(dt):
    return dt if LOWP_ROW_REDUCE["on"] else jnp.float32


def _softcap(scores: Array, cap: float) -> Array:
    if cap and cap > 0.0:
        return cap * jnp.tanh(scores / cap)
    return scores


@dataclasses.dataclass(frozen=True)
class AttnParams:
    """Weight bundle for one attention mixer (arrays may be batched by a
    leading superblock dim before being sliced by scan)."""
    wq: Array        # (d, H, Dh)
    wk: Array        # (d, K, Dh)
    wv: Array        # (d, K, Dh)
    wo: Array        # (H, Dh, d)
    q_norm: Optional[Array] = None   # (Dh,) gemma3 qk-norm
    k_norm: Optional[Array] = None


def project_qkv(x: Array, p: AttnParams, n_kv: int, *, positions: Array,
                theta: float, qk_norm_eps: float = 1e-6,
                use_rope: bool = True) -> Tuple[Array, Array, Array]:
    """x: (B, S, d) -> q: (B, S, K, G, Dh); k, v: (B, S, K, Dh)."""
    dt = x.dtype
    pref = _row_reduce_dtype(dt)
    q = jnp.einsum("bsd,dhe->bshe", x, p.wq.astype(dt),
                   preferred_element_type=pref).astype(dt)
    k = jnp.einsum("bsd,dke->bske", x, p.wk.astype(dt),
                   preferred_element_type=pref).astype(dt)
    v = jnp.einsum("bsd,dke->bske", x, p.wv.astype(dt),
                   preferred_element_type=pref).astype(dt)
    if p.q_norm is not None:
        q = rms_norm(q, p.q_norm, qk_norm_eps)
        k = rms_norm(k, p.k_norm, qk_norm_eps)
    if use_rope:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    b, s, h, e = q.shape
    g = h // n_kv
    q = q.reshape(b, s, n_kv, g, e)
    return q, k, v


def _online_softmax_block(carry, q, k_blk, v_blk, mask, softcap):
    """One KV block of streaming attention.

    q: (B, K, G, Sq, Dh); k_blk/v_blk: (B, Skv, K, Dh);
    mask: (Sq, Skv) or None (True = attend); carry: (m, l, acc).
    """
    m_prev, l_prev, acc_prev = carry
    s = jnp.einsum("bkgqd,bjkd->bkgqj", q, k_blk,
                   preferred_element_type=jnp.float32)
    s = _softcap(s, softcap)
    if mask is not None:
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    # Re-scale previous accumulator.
    scale = jnp.exp(m_prev - m_new)
    # Guard fully-masked blocks: exp(NEG_INF - NEG_INF) would be 1.
    p = jnp.where(s > 0.5 * NEG_INF, jnp.exp(s - m_new[..., None]), 0.0)
    l_new = l_prev * scale + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bkgqj,bjkd->bkgqd", p.astype(v_blk.dtype), v_blk,
                    preferred_element_type=jnp.float32)
    acc_new = acc_prev * scale[..., None] + pv
    return m_new, l_new, acc_new


def _finish(m, l, acc, dtype):
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(dtype)  # (B, K, G, Sq, Dh)


def blockwise_attention(q: Array, k: Array, v: Array, *, causal: bool,
                        window: int = 0, softcap: float = 0.0,
                        q_block: int = 1024, kv_block: int = 1024,
                        scale: Optional[float] = None) -> Array:
    """Streaming-softmax attention.

    q: (B, S, K, G, Dh); k, v: (B, Skv, K, Dh).  Returns (B, S, K, G, Dh).

    causal=True  -> static triangular schedule over query blocks.
    window>0     -> additionally banded: query block i only reads the KV
                    slice [i*qb - window, (i+1)*qb)  (static slice).
    causal=False -> full bidirectional / cross attention.
    """
    b, s, n_kv, g, dh = q.shape
    skv = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    q = (q * scale).astype(q.dtype)

    qb = min(q_block, s)
    if s % qb:
        qb = s  # tiny/odd sequences: single block
    n_qb = s // qb
    # (B, K, G, S, Dh) layout for the inner loops.
    qt = q.transpose(0, 2, 3, 1, 4)

    out_blocks = []
    for i in range(n_qb):
        q_i = lax.slice_in_dim(qt, i * qb, (i + 1) * qb, axis=3)
        q_pos0 = i * qb
        if causal:
            lo = max(0, q_pos0 - window + 1) if window else 0
            lo = (lo // kv_block) * kv_block
            hi = min(skv, (i + 1) * qb)
        else:
            lo, hi = 0, skv
        k_i = lax.slice_in_dim(k, lo, hi, axis=1)
        v_i = lax.slice_in_dim(v, lo, hi, axis=1)
        span = hi - lo
        kb = min(kv_block, span)
        m0 = jnp.full((b, n_kv, g, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, n_kv, g, qb), jnp.float32)
        a0 = jnp.zeros((b, n_kv, g, qb, dh), jnp.float32)
        if span % kb == 0 and span // kb > 1:
            n_kb = span // kb
            ks = k_i.reshape(b, n_kb, kb, n_kv, dh).transpose(1, 0, 2, 3, 4)
            vs = v_i.reshape(b, n_kb, kb, n_kv, dh).transpose(1, 0, 2, 3, 4)
            jidx = jnp.arange(n_kb)

            def body(carry, xs):
                k_blk, v_blk, j = xs
                qpos = q_pos0 + jnp.arange(qb)
                kpos = lo + j * kb + jnp.arange(kb)
                mask = None
                if causal or window:
                    m = jnp.ones((qb, kb), bool)
                    if causal:
                        m &= qpos[:, None] >= kpos[None, :]
                    if window:
                        m &= qpos[:, None] - kpos[None, :] < window
                    mask = m
                return _online_softmax_block(carry, q_i, k_blk, v_blk, mask,
                                             softcap), None

            # Flash-attention-style backward: remat the KV-block body so the
            # (B, K, G, Sq, Skv) probability matrix and mask are NOT saved
            # as per-iteration scan residuals (25 GiB/layer at 4k train
            # otherwise) — backward recomputes them from the saved k/v
            # blocks.
            body = jax.checkpoint(body)
            (m, l, acc), _ = lax.scan(body, (m0, l0, a0), (ks, vs, jidx))
        else:
            qpos = q_pos0 + jnp.arange(qb)
            kpos = lo + jnp.arange(span)
            mask = None
            if causal or window:
                mm = jnp.ones((qb, span), bool)
                if causal:
                    mm &= qpos[:, None] >= kpos[None, :]
                if window:
                    mm &= qpos[:, None] - kpos[None, :] < window
                mask = mm
            m, l, acc = _online_softmax_block((m0, l0, a0), q_i, k_i, v_i,
                                              mask, softcap)
        out_blocks.append(_finish(m, l, acc, q.dtype))
    out = jnp.concatenate(out_blocks, axis=3) if n_qb > 1 else out_blocks[0]
    return out.transpose(0, 3, 1, 2, 4)  # (B, S, K, G, Dh)


def decode_attention(q: Array, k_cache: Array, v_cache: Array,
                     kv_positions: Array, pos: Array, *, window: int = 0,
                     softcap: float = 0.0,
                     scale: Optional[float] = None) -> Array:
    """Single-step attention against a cache.

    q: (B, 1, K, G, Dh); k_cache/v_cache: (B, C, K, Dh);
    kv_positions: (C,) absolute position held by each cache slot (−1 empty);
    pos: scalar current position.  Window masking uses absolute positions.
    """
    b, _, n_kv, g, dh = q.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    qs = (q[:, 0] * scale)  # (B, K, G, Dh)
    s = jnp.einsum("bkgd,bjkd->bkgj", qs, k_cache,
                   preferred_element_type=jnp.float32)
    s = _softcap(s, softcap)
    valid = (kv_positions >= 0) & (kv_positions <= pos)
    if window:
        valid &= kv_positions > pos - window
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgj,bjkd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out[:, None].astype(q.dtype)  # (B, 1, K, G, Dh)


def attn_out(attended: Array, wo: Array) -> Array:
    """attended: (B, S, K, G, Dh); wo: (H, Dh, d) -> (B, S, d)."""
    b, s, n_kv, g, dh = attended.shape
    a = attended.reshape(b, s, n_kv * g, dh)
    return jnp.einsum("bshe,hed->bsd", a, wo.astype(a.dtype),
                      preferred_element_type=_row_reduce_dtype(a.dtype)
                      ).astype(a.dtype)


# ---------------------------------------------------------------------------
# Ring-buffer cache helpers (sliding-window decode)
# ---------------------------------------------------------------------------


def ring_slot_positions(pos: Array, cap: int) -> Array:
    """Absolute position stored in each ring slot after writing `pos` at
    slot pos % cap.  Slot w holds the largest p <= pos with p % cap == w
    (or -1 if none)."""
    slots = jnp.arange(cap)
    p = pos - ((pos - slots) % cap)
    return jnp.where(p >= 0, p, -1)


def ring_write(cache: Array, value: Array, pos: Array, cap: int) -> Array:
    """cache: (B, cap, ...); value: (B, 1, ...) written at slot pos % cap."""
    slot = (pos % cap).astype(jnp.int32)
    return lax.dynamic_update_slice_in_dim(cache, value.astype(cache.dtype),
                                           slot, axis=1)
