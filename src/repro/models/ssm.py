"""Selective state-space (Mamba-style) sequence mixer.

Used by the hymba hybrid layers (parallel attention + mamba heads).

Prefill/train path: *chunked* associative scan — a sequential `lax.scan`
over chunks of the sequence, with a parallel `lax.associative_scan` inside
each chunk.  A fully parallel associative scan over the whole sequence
would materialize (B, S, d_inner, N) decay/state tensors (terabytes at
train_4k); chunking bounds live memory to (B, chunk, d_inner, N) while
keeping log-depth parallelism inside the chunk.

Decode path: single-step recurrence on the carried (B, d_inner, N) state
plus a (B, conv_w-1, d_inner) convolution tail.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array


def _ssm_combine(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a1 * a2, a2 * b1 + b2


def depthwise_conv(x: Array, w: Array, tail: Array | None = None) -> Tuple[Array, Array]:
    """Causal depthwise conv1d.

    x: (B, S, C); w: (C, K).  tail: (B, K-1, C) state from previous segment
    (zeros for a fresh sequence).  Returns (y, new_tail).
    """
    b, s, c = x.shape
    k = w.shape[1]
    if tail is None:
        tail = jnp.zeros((b, k - 1, c), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)            # (B, S+K-1, C)
    y = jnp.zeros((b, s, c), jnp.float32)
    for i in range(k):
        y = y + xp[:, i:i + s].astype(jnp.float32) * w[:, i].astype(jnp.float32)
    new_tail = xp[:, s:]                                # last K-1 inputs
    return y.astype(x.dtype), new_tail


def ssm_scan(x_in: Array, dt: Array, a_log: Array, bmat: Array, cmat: Array,
             d_skip: Array, state0: Array, *, chunk: int = 128
             ) -> Tuple[Array, Array]:
    """Selective scan.

    x_in:  (B, S, C)   post-conv activations (C = d_inner)
    dt:    (B, S, C)   positive step sizes (softplus already applied)
    a_log: (C, N)      log of -A (A = -exp(a_log))
    bmat:  (B, S, N)   input->state projection coefficients
    cmat:  (B, S, N)   state->output coefficients
    d_skip:(C,)        skip connection
    state0:(B, C, N)   initial state
    Returns (y (B, S, C) f32->x dtype, final_state (B, C, N) f32).
    """
    b, s, c = x_in.shape
    n = a_log.shape[1]
    ch = min(chunk, s)
    if s % ch:
        ch = s
    n_chunks = s // ch

    a = -jnp.exp(a_log.astype(jnp.float32))            # (C, N), negative

    def per_chunk(state, xs):
        xc, dtc, bc, cc = xs                           # (B, ch, ...)
        dtc = dtc.astype(jnp.float32)
        decay = jnp.exp(dtc[..., None] * a)            # (B, ch, C, N)
        inp = (dtc * xc.astype(jnp.float32))[..., None] * bc[:, :, None, :].astype(jnp.float32)
        # Parallel scan inside the chunk (time axis = 1).
        dec_s, inp_s = lax.associative_scan(_ssm_combine, (decay, inp), axis=1)
        # Fold in the carried state.
        states = dec_s * state[:, None] + inp_s        # (B, ch, C, N)
        y = jnp.einsum("btcn,btn->btc", states, cc.astype(jnp.float32))
        y = y + xc.astype(jnp.float32) * d_skip.astype(jnp.float32)
        return states[:, -1], y

    if n_chunks > 1:
        xs = tuple(
            t.reshape(b, n_chunks, ch, *t.shape[2:]).swapaxes(0, 1)
            for t in (x_in, dt, bmat, cmat))
        # Remat the chunk: the (B, ch, C, N) decay/state tensors (~5 x
        # 210 MB per chunk at hymba train_4k) are recomputed in backward
        # instead of stacked as residuals (§Perf, same policy as
        # blockwise_attention / mlstm_chunkwise).
        state_f, ys = lax.scan(jax.checkpoint(per_chunk),
                               state0.astype(jnp.float32), xs)
        y = ys.swapaxes(0, 1).reshape(b, s, c)
    else:
        state_f, y = per_chunk(state0.astype(jnp.float32), (x_in, dt, bmat, cmat))
    return y.astype(x_in.dtype), state_f


def ssm_step(x_t: Array, dt_t: Array, a_log: Array, b_t: Array, c_t: Array,
             d_skip: Array, state: Array) -> Tuple[Array, Array]:
    """One decode step.  x_t/dt_t: (B, C); b_t/c_t: (B, N); state: (B, C, N)."""
    a = -jnp.exp(a_log.astype(jnp.float32))
    dtf = dt_t.astype(jnp.float32)
    decay = jnp.exp(dtf[..., None] * a)                # (B, C, N)
    inp = (dtf * x_t.astype(jnp.float32))[..., None] * b_t[:, None, :].astype(jnp.float32)
    new_state = decay * state.astype(jnp.float32) + inp
    y = jnp.einsum("bcn,bn->bc", new_state, c_t.astype(jnp.float32))
    y = y + x_t.astype(jnp.float32) * d_skip.astype(jnp.float32)
    return y.astype(x_t.dtype), new_state
