"""Analytical parameter / useful-FLOP accounting.

Used for the §Roofline MODEL_FLOPS / HLO_FLOPs ratio.  Convention (documented
here, consumed by EXPERIMENTS.md):

* ``param_count`` is exact — it sums the leaves of the *implemented*
  parameter pytree (so padding, gates, norms are all included).
* ``MODEL_FLOPS = 6 * N * D`` for training (fwd 2ND + bwd 4ND) and
  ``2 * N * D`` for inference, where N excludes the input embedding table
  (a gather, not a matmul) but **includes** the LM head matmul once
  (Vp * d), tied or not, and for MoE counts only *active* expert
  parameters (top_k / n_experts of routed weights + shared experts).
* Attention O(S^2) score/value FLOPs are intentionally excluded from
  MODEL_FLOPS (the 6ND convention); they appear in HLO_FLOPs, so the
  reported ratio > 1 for long sequences is expected and is itself a useful
  signal (it quantifies quadratic-attention + remat overhead).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np

from repro.configs.base import ArchConfig


def _leaf_size(spec) -> int:
    return int(np.prod(spec.shape)) if spec.shape else 1


def param_count(cfg: ArchConfig) -> int:
    from repro.models import backbone as B

    specs = B.param_specs(cfg)
    return sum(_leaf_size(s) for s in jax.tree.leaves(specs))


def active_param_count(cfg: ArchConfig) -> int:
    """Parameters touched per token (MoE: routed experts scaled by k/E)."""
    from repro.models import backbone as B

    specs = B.param_specs(cfg)
    total = 0

    def visit(path, spec):
        nonlocal total
        keys = [str(getattr(k, "key", getattr(k, "name", "")))
                for k in path]
        size = _leaf_size(spec)
        if cfg.moe is not None and any(k in ("w_gate", "w_up", "w_down")
                                       for k in keys) and "moe" in keys:
            size = int(size * cfg.moe.top_k / cfg.moe.n_experts)
        total += size

    jax.tree_util.tree_map_with_path(visit, specs)
    return total


def matmul_param_count(cfg: ArchConfig, active: bool = True) -> int:
    """N for the 6ND formula: active params, minus the embedding gather,
    plus the head matmul if embeddings are tied (untied lm_head is already
    a parameter leaf)."""
    n = active_param_count(cfg) if active else param_count(cfg)
    n -= cfg.vocab_padded * cfg.d_model          # embedding gather
    if cfg.tie_embeddings:
        n += cfg.vocab_padded * cfg.d_model      # tied head matmul
    return n


def model_flops_per_token(cfg: ArchConfig, seq_len: int, training: bool) -> float:
    n = matmul_param_count(cfg, active=True)
    return (6.0 if training else 2.0) * n


def model_flops(cfg: ArchConfig, n_tokens: int, training: bool) -> float:
    return model_flops_per_token(cfg, 0, training) * n_tokens
