"""Model: config -> callable train/prefill/decode programs.

All stacks run as ``lax.scan`` over superblocks (see backbone.py).  The LM
loss is computed in *sequence chunks* so the (B, chunk, V) logits tensor —
not (B, S, V) — is the live working set (V is up to 262k).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import backbone as B
from repro.models.layers import rms_norm

Array = jax.Array
PyTree = Any


def _mask_padded_vocab(logits: Array, vocab: int) -> Array:
    vp = logits.shape[-1]
    if vp == vocab:
        return logits
    ids = lax.iota(jnp.int32, vp)
    return jnp.where(ids < vocab, logits, jnp.finfo(logits.dtype).min)


class Model:
    def __init__(self, cfg: ArchConfig, compute_dtype=jnp.bfloat16,
                 loss_chunk: int = 512):
        self.cfg = cfg
        self.compute_dtype = compute_dtype
        self.loss_chunk = loss_chunk

    # ---------------- parameters ----------------
    def param_specs(self) -> PyTree:
        return B.param_specs(self.cfg)

    def init_params(self, rng: jax.Array) -> PyTree:
        return B.init_params(self.cfg, rng)

    def cache_specs(self, batch: int, s_max: int) -> PyTree:
        return B.cache_specs(self.cfg, batch, s_max, self.compute_dtype)

    def init_cache(self, batch: int, s_max: int) -> PyTree:
        return B.init_cache(self.cfg, batch, s_max, self.compute_dtype)

    # ---------------- batch specs ----------------
    def batch_spec(self, batch: int, seq: int) -> Dict[str, jax.ShapeDtypeStruct]:
        cfg = self.cfg
        spec = {
            "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
            "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        }
        if cfg.family == "audio":
            spec["frames"] = jax.ShapeDtypeStruct(
                (batch, cfg.encoder_seq, cfg.d_model), self.compute_dtype)
        if cfg.family == "vlm":
            spec["context"] = jax.ShapeDtypeStruct(
                (batch, cfg.context_seq, cfg.d_model), self.compute_dtype)
        return spec

    # ---------------- forward pieces ----------------
    def _embed(self, params: PyTree, tokens: Array) -> Array:
        from repro.dist.mesh import constrain_activations

        e = params["embed"]
        x = jnp.take(e, tokens, axis=0).astype(self.compute_dtype)
        return constrain_activations(x)

    def _context(self, params: PyTree, batch: Dict[str, Array],
                 mode: str) -> Optional[Array]:
        cfg = self.cfg
        if cfg.family == "vlm":
            return batch["context"].astype(self.compute_dtype)
        if cfg.family == "audio" and mode != "decode":
            return self._encode(params, batch["frames"])
        return None

    def _encode(self, params: PyTree, frames: Array) -> Array:
        """Whisper-style encoder over precomputed frame embeddings (stub
        frontend)."""
        cfg = self.cfg
        x = frames.astype(self.compute_dtype)
        blocks = params["enc_blocks"]["pos0"]

        def body(carry, bp):
            y, _ = B.apply_layer(cfg, "dense:bidir", bp, carry, mode="train")
            return y, None

        body = self._maybe_remat_scan_body(body, "train")
        x, _ = lax.scan(body, x, blocks)
        return rms_norm(x, params["enc_final_norm"], cfg.norm_eps)

    def _maybe_remat_scan_body(self, body, mode):
        if mode != "train":
            return body
        pol = B.REMAT["policy"]
        if pol == "none":
            return body
        if pol == "dots":
            return jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        return jax.checkpoint(body)

    def _stack(self, params: PyTree, x: Array, ctx: Optional[Array],
               mode: str, cache: Optional[PyTree] = None,
               pos: Optional[Array] = None,
               s_max: Optional[int] = None) -> Tuple[Array, Optional[PyTree]]:
        cfg = self.cfg
        pattern, n_super, rem = cfg.pattern_plan()
        new_cache: Dict[str, Any] = {}

        if n_super:
            if mode == "train":
                def body(carry, bp):
                    y = carry
                    for i, tag in enumerate(pattern):
                        y, _ = B.apply_layer(cfg, tag, bp[f"pos{i}"], y,
                                             mode="train", ctx=ctx)
                    return y, None
                body = self._maybe_remat_scan_body(body, mode)
                x, _ = lax.scan(body, x, params["blocks"])
            elif mode == "prefill":
                def body(carry, bp):
                    y = carry
                    caches = {}
                    for i, tag in enumerate(pattern):
                        y, c = B.apply_layer(cfg, tag, bp[f"pos{i}"], y,
                                             mode="prefill", ctx=ctx,
                                             s_max=s_max)
                        caches[f"pos{i}"] = c
                    return y, caches
                x, blk_caches = lax.scan(body, x, params["blocks"])
                new_cache["blocks"] = blk_caches
            else:  # decode
                def body(carry, xs):
                    bp, bc = xs
                    y = carry
                    caches = {}
                    for i, tag in enumerate(pattern):
                        y, c = B.apply_layer(cfg, tag, bp[f"pos{i}"], y,
                                             mode="decode",
                                             cache=bc[f"pos{i}"], pos=pos)
                        caches[f"pos{i}"] = c
                    return y, caches
                x, blk_caches = lax.scan(body, x,
                                         (params["blocks"], cache["blocks"]))
                new_cache["blocks"] = blk_caches

        if rem:
            rem_caches = {}
            for i, tag in enumerate(rem):
                rp = params["rem"][f"rem{i}"]
                if mode == "decode":
                    x, c = B.apply_layer(cfg, tag, rp, x, mode="decode",
                                         cache=cache["rem"][f"rem{i}"],
                                         pos=pos)
                else:
                    x, c = B.apply_layer(cfg, tag, rp, x, mode=mode, ctx=ctx,
                                         s_max=s_max)
                rem_caches[f"rem{i}"] = c
            if mode == "prefill" or mode == "decode":
                new_cache["rem"] = rem_caches

        return x, (new_cache if new_cache else None)

    def _head(self, params: PyTree, x: Array) -> Array:
        """x: (..., d) -> logits (..., Vp) f32."""
        cfg = self.cfg
        if cfg.tie_embeddings:
            w = params["embed"].astype(self.compute_dtype)  # (Vp, d)
            logits = jnp.einsum("...d,vd->...v", x, w,
                                preferred_element_type=jnp.float32)
        else:
            w = params["lm_head"].astype(self.compute_dtype)
            logits = jnp.einsum("...d,dv->...v", x, w,
                                preferred_element_type=jnp.float32)
        if cfg.final_softcap:
            logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
        return logits

    # ---------------- public programs ----------------
    def loss(self, params: PyTree, batch: Dict[str, Array]) -> Array:
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        ctx = self._context(params, batch, "train")
        x = self._embed(params, tokens)
        x, _ = self._stack(params, x, ctx, "train")
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)

        b, s, d = x.shape
        chunk = min(self.loss_chunk, s)
        if s % chunk:
            chunk = s
        n_chunks = s // chunk

        def ce_chunk(x_c, y_c):
            logits = self._head(params, x_c)
            logits = _mask_padded_vocab(logits, cfg.vocab)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, y_c[..., None],
                                       axis=-1)[..., 0]
            return jnp.sum(lse - gold)

        if n_chunks == 1:
            total = ce_chunk(x, labels)
        else:
            xs = (x.reshape(b, n_chunks, chunk, d).swapaxes(0, 1),
                  labels.reshape(b, n_chunks, chunk).swapaxes(0, 1))

            def body(acc, xs_c):
                x_c, y_c = xs_c
                return acc + ce_chunk(x_c, y_c), None

            # Remat each chunk: backward recomputes the (B, chunk, V) logits
            # from x_c (one matmul) instead of saving them per chunk — at
            # V=128k..262k the saved logits would dominate HBM.
            body = jax.checkpoint(body)
            total, _ = lax.scan(body, jnp.zeros((), jnp.float32), xs)
        return total / (b * s)

    def prefill(self, params: PyTree, batch: Dict[str, Array],
                s_max: Optional[int] = None) -> Tuple[Array, PyTree]:
        """s_max: decode-cache capacity to allocate (>= tokens.shape[1];
        defaults to the prompt length)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        ctx = self._context(params, batch, "prefill")
        x = self._embed(params, tokens)
        x, kv = self._stack(params, x, ctx, "prefill", s_max=s_max)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._head(params, x[:, -1])
        return _mask_padded_vocab(logits, cfg.vocab), kv

    def decode_step(self, params: PyTree, cache: PyTree, tokens: Array,
                    pos: Array) -> Tuple[Array, PyTree]:
        """tokens: (B,) int32; pos: scalar int32 (position being written)."""
        cfg = self.cfg
        x = self._embed(params, tokens[:, None])
        x, kv = self._stack(params, x, None, "decode", cache=cache, pos=pos)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._head(params, x[:, 0])
        return _mask_padded_vocab(logits, cfg.vocab), kv


def build(cfg: ArchConfig, **kw) -> Model:
    return Model(cfg, **kw)
