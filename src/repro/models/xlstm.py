"""xLSTM sequence mixers: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM prefill/train uses the chunkwise-parallel formulation: intra-chunk
(triangular) attention in stabilized log-decay space + inter-chunk matrix
state recurrence, carried by `lax.scan` over chunks.  This is the standard
linear-time lowering of mLSTM (cf. flash-linear-attention); the exponential
input/forget gating with running stabilizer `m` follows the xLSTM paper.
Numerics note (DESIGN.md §2): the denominator uses
max(|q·n|, 1) after stabilization, matching the paper's normalizer bound.

sLSTM is inherently sequential (recurrent block-diagonal connections); the
per-step recurrent matvec runs inside `lax.scan` over time, while all input
projections are hoisted out of the scan.  sLSTM layers are batch-parallel
only (weights replicated) — see DESIGN.md §5.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array
NEG = -1e30


class MLSTMState(NamedTuple):
    c: Array   # (B, H, Dk, Dv) matrix memory (stabilized)
    n: Array   # (B, H, Dk) normalizer
    m: Array   # (B, H) running log stabilizer


def mlstm_init_state(b: int, h: int, dk: int, dv: int) -> MLSTMState:
    return MLSTMState(
        c=jnp.zeros((b, h, dk, dv), jnp.float32),
        n=jnp.zeros((b, h, dk), jnp.float32),
        m=jnp.full((b, h), 0.0, jnp.float32),
    )


def mlstm_chunkwise(q: Array, k: Array, v: Array, i_pre: Array, f_pre: Array,
                    state: MLSTMState, *, chunk: int = 256
                    ) -> Tuple[Array, MLSTMState]:
    """q,k: (B,S,H,Dk); v: (B,S,H,Dv); i_pre,f_pre: (B,S,H) gate
    pre-activations.  Returns (y (B,S,H,Dv), final state)."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    ch = min(chunk, s)
    if s % ch:
        ch = s
    n_chunks = s // ch
    scale = dk ** -0.5

    def per_chunk(carry: MLSTMState, xs):
        qc, kc, vc, ic, fc = xs               # (B, ch, H, ...)
        qc = qc.astype(jnp.float32) * scale
        kc = kc.astype(jnp.float32)
        vc32 = vc.astype(jnp.float32)
        lf = jax.nn.log_sigmoid(fc.astype(jnp.float32))   # (B, ch, H)
        li = ic.astype(jnp.float32)
        cum = jnp.cumsum(lf, axis=1)                       # inclusive
        # D[i, j] = cum_i - cum_j + li_j for j <= i (log decay paths).
        d = cum[:, :, None] - cum[:, None, :] + li[:, None, :, :]
        tri = jnp.tril(jnp.ones((ch, ch), bool))
        d = jnp.where(tri[None, :, :, None], d, NEG)       # (B, ch, ch, H)
        m_intra = jnp.max(d, axis=2)                       # (B, ch, H)
        m_inter = carry.m[:, None] + cum                   # (B, ch, H)
        m_i = jnp.maximum(m_intra, m_inter)
        # Intra-chunk (triangular) attention in stabilized space.
        sc = jnp.einsum("bihd,bjhd->bijh", qc, kc)
        w = sc * jnp.exp(d - m_i[:, :, None])              # (B, ch, ch, H)
        y_intra = jnp.einsum("bijh,bjhe->bihe", w, vc32)
        # Inter-chunk contribution from carried state.
        dec_q = jnp.exp(m_inter - m_i)                     # (B, ch, H)
        y_inter = jnp.einsum("bihd,bhde->bihe", qc, carry.c) * dec_q[..., None]
        n_prev_q = jnp.einsum("bihd,bhd->bih", qc, carry.n) * dec_q
        num = y_intra + y_inter                            # (B, ch, H, Dv)
        # Normalizer: sum_j w_ij == q_i . (sum_j exp(d_ij - m_i) k_j), i.e.
        # exactly q . n_intra, so no separate n_intra tensor is needed.
        den = jnp.sum(w, axis=2) + n_prev_q                # (B, ch, H)
        y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]
        # State update to end of chunk.
        m_end = jnp.maximum(carry.m + cum[:, -1], jnp.max(cum[:, -1:, :] - cum + li, axis=1))
        dec_c = jnp.exp(carry.m + cum[:, -1] - m_end)      # (B, H)
        dec_k = jnp.exp(cum[:, -1:, :] - cum + li - m_end[:, None])  # (B, ch, H)
        c_new = carry.c * dec_c[..., None, None] + jnp.einsum(
            "bjh,bjhd,bjhe->bhde", dec_k, kc, vc32)
        n_new = carry.n * dec_c[..., None] + jnp.einsum(
            "bjh,bjhd->bhd", dec_k, kc)
        return MLSTMState(c_new, n_new, m_end), y.astype(v.dtype)

    if n_chunks > 1:
        xs = tuple(
            t.reshape(b, n_chunks, ch, *t.shape[2:]).swapaxes(0, 1)
            for t in (q, k, v, i_pre, f_pre))
        # Remat the chunk body: the (B, ch, ch, H) decay/score tensors are
        # recomputed in the backward instead of being stacked as per-chunk
        # residuals (same flash-style policy as blockwise_attention).
        state_f, ys = lax.scan(jax.checkpoint(per_chunk), state, xs)
        y = ys.swapaxes(0, 1).reshape(b, s, h, dv)
    else:
        state_f, y = per_chunk(state, (q, k, v, i_pre, f_pre))
    return y, state_f


def mlstm_step(q: Array, k: Array, v: Array, i_pre: Array, f_pre: Array,
               state: MLSTMState) -> Tuple[Array, MLSTMState]:
    """One decode step.  q,k: (B,H,Dk); v: (B,H,Dv); gates (B,H)."""
    dk = q.shape[-1]
    qf = q.astype(jnp.float32) * dk ** -0.5
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    lf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    li = i_pre.astype(jnp.float32)
    m_new = jnp.maximum(lf + state.m, li)
    fg = jnp.exp(lf + state.m - m_new)
    ig = jnp.exp(li - m_new)
    c_new = state.c * fg[..., None, None] + ig[..., None, None] * (
        kf[..., :, None] * vf[..., None, :])
    n_new = state.n * fg[..., None] + ig[..., None] * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, c_new)
    den = jnp.einsum("bhd,bhd->bh", qf, n_new)
    y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return y.astype(v.dtype), MLSTMState(c_new, n_new, m_new)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


class SLSTMState(NamedTuple):
    c: Array   # (B, H, Dh)
    n: Array   # (B, H, Dh)
    h: Array   # (B, H, Dh)
    m: Array   # (B, H, Dh)


def slstm_init_state(b: int, h: int, dh: int) -> SLSTMState:
    z = jnp.zeros((b, h, dh), jnp.float32)
    return SLSTMState(z, z, z, z)


def _slstm_gates(state: SLSTMState, gates, rec) -> SLSTMState:
    """Gate math with the recurrent contribution precomputed (pure of r).
    gates: 4-tuple of (B, H, Dh) f32 pre-activations (z, i, f, o) — passed
    as SEPARATE leaves so their backward cotangents are direct tensors
    (slicing a packed (B, 4, H, Dh) here would make autodiff rebuild the
    packed gradient with pad+add chains whose mixed dtypes force XLA to
    convert the whole stacked scan buffer every timestep — measured
    1 GiB/step; §Perf).  rec: (4, B, H, Dh)."""
    zp = gates[0] + rec[0]
    ip = gates[1] + rec[1]
    fp = gates[2] + rec[2]
    op = gates[3] + rec[3]
    z = jnp.tanh(zp)
    m_new = jnp.maximum(fp + state.m, ip)
    ig = jnp.exp(ip - m_new)
    fg = jnp.exp(fp + state.m - m_new)
    c_new = fg * state.c + ig * z
    n_new = fg * state.n + ig
    h_new = jax.nn.sigmoid(op) * c_new / jnp.maximum(n_new, 1.0)
    return SLSTMState(c_new, n_new, h_new, m_new)


def _split_gates(pre):
    """(B, 4, H, Dh) any-dtype -> 4-tuple of (B, H, Dh) f32."""
    return tuple(pre[:, i].astype(jnp.float32) for i in range(4))


def _slstm_cell(state: SLSTMState, pre, r):
    """pre: (B, 4, H, Dh) gate pre-activations for this step (z, i, f, o);
    r: (4, H, Dh, Dh) recurrent block-diagonal weights."""
    rec = jnp.einsum("bhd,ghde->gbhe", state.h, r.astype(jnp.float32))
    return _slstm_gates(state, _split_gates(pre), rec)


@jax.custom_vjp
def slstm_scan(pre: Array, r: Array, state: SLSTMState
               ) -> Tuple[Array, SLSTMState]:
    """pre: (B, S, 4, H, Dh); r: (4, H, Dh, Dh).  Sequential over S.

    Custom VJP (§Perf hillclimb, xlstm train_4k): naive autodiff of the
    timestep scan accumulates the recurrent-weight gradient in the scan
    carry, which forces GSPMD to ALL-REDUCE the (4, H, Dh, Dh) gradient —
    and materialize the (B, 4, H, Dh, Dh) per-step outer products — at
    EVERY timestep (measured: 1.6e12 collective bytes, 33 s of the
    baseline's 40 s collective term).  The custom backward instead emits
    the per-step recurrent cotangents ``drec`` as stacked scan outputs and
    contracts them against the saved h-sequence in ONE post-scan einsum:
    one 16 MB all-reduce per layer instead of 49 152."""
    hs, _, state_f = _slstm_fwd_scan(pre, r, state)
    return hs, state_f


def _slstm_fwd_scan(pre, r, state):
    def body(st, pre_t):
        st2 = _slstm_cell(st, pre_t, r)
        return st2, st2
    state_f, states = lax.scan(body, state, pre.swapaxes(0, 1))
    hs = states.h.swapaxes(0, 1)            # (B, S, H, Dh)
    return hs, states, state_f


def _slstm_scan_fwd(pre, r, state):
    hs, states, state_f = _slstm_fwd_scan(pre, r, state)
    return (hs, state_f), (pre, r, state, states)


def _slstm_scan_bwd(res, cot):
    pre, r, state0, states = res
    dhs, dstate_f = cot
    s = pre.shape[1]

    # state BEFORE step t: shift the stacked states right by one.
    def shift(seq, init):
        return jnp.concatenate([init[None].astype(seq.dtype),
                                seq[:-1]], axis=0)
    prev = SLSTMState(*(shift(getattr(states, f), getattr(state0, f))
                        for f in ("c", "n", "h", "m")))

    def body(dstate, xs):
        pre_t, prev_t, dh_out_t = xs
        rec_t = jnp.einsum("bhd,ghde->gbhe", prev_t.h,
                           r.astype(jnp.float32))
        gates_t = _split_gates(pre_t)
        _, vjp = jax.vjp(_slstm_gates, prev_t, gates_t, rec_t)
        dstate = dstate._replace(h=dstate.h + dh_out_t)
        dprev, dgates_t, drec_t = vjp(dstate)
        # chain the recurrent matvec back into h_{t-1} (r part deferred)
        dh_extra = jnp.einsum("gbhe,ghde->bhd", drec_t,
                              r.astype(jnp.float32))
        dprev = SLSTMState(dprev.c, dprev.n, dprev.h + dh_extra, dprev.m)
        # Stack outputs at their final dtype — a mixed-dtype ys stack makes
        # XLA convert the WHOLE (S, ...) buffer every iteration (§Perf).
        dpre_t = jnp.stack(dgates_t, axis=1).astype(pre.dtype)
        return dprev, (dpre_t, drec_t.astype(jnp.bfloat16))

    xs = (pre.swapaxes(0, 1), prev, dhs.swapaxes(0, 1))
    dstate0, (dpre_s, drec_s) = lax.scan(
        body, SLSTMState(*dstate_f), xs, reverse=True)
    # ONE contraction for the recurrent weight gradient (replaces the
    # per-timestep all-reduce):
    dr = jnp.einsum("sgbhe,sbhd->ghde", drec_s.astype(jnp.float32),
                    prev.h)
    return dpre_s.swapaxes(0, 1), dr.astype(r.dtype), dstate0


slstm_scan.defvjp(_slstm_scan_fwd, _slstm_scan_bwd)


def slstm_step(pre_t: Array, r: Array, state: SLSTMState
               ) -> Tuple[Array, SLSTMState]:
    st2 = _slstm_cell(state, pre_t, r)
    return st2.h, st2
