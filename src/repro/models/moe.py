"""Token-choice top-k Mixture of Experts with capacity-bounded dispatch.

Dispatch strategy (TPU / GSPMD adaptation — see DESIGN.md §5):

* Tokens are processed in groups along the *sequence* axis via `lax.scan`
  (the batch axis stays data-sharded and parallel; the scanned axis is
  replicated, so no per-iteration collectives are induced by the scan
  itself).  Group scanning bounds the live dispatched-activation footprint
  to (B, E, C, d) per step — this is the memory knob that lets dbrx/llama4
  prefill fit HBM, and on real hardware lets the per-group all-to-alls
  overlap with expert compute.
* Within a group, dispatch is *sort-based* (not GShard one-hot einsum):
  argsort token->expert assignments, compute rank-in-expert by comparing
  sorted ids, scatter slot indices into an (E, C) table, gather tokens.
  This avoids materializing (g, E, C) one-hot tensors.
* Expert weights are sharded over the "model" mesh axis on the expert dim;
  GSPMD inserts the all-to-alls on the (B, E, C, d) dispatched activations.

Router: softmax over top-k logits (dbrx convention); optional always-on
shared expert (llama4).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import MoEConfig
from repro.models.layers import act_fn

Array = jax.Array


# Optional sharding constraints for the dispatched activations, set by the
# launcher (launch/specs.build_cell) before tracing distributed programs.
# GSPMD cannot infer the expert-parallel layout through the sort/scatter
# dispatch, so without an explicit constraint the expert FFN einsums get
# replicated over the model axis (verified: 16x the expected FLOPs in the
# dbrx dry-run).  Keys: "dispatch" -> sharding for (B, E, C, d) tensors,
# "out" -> sharding for (B, g, d) combined output.  None = no constraint
# (single-device smoke tests / examples).
SHARDING: dict = {"dispatch": None, "out": None}


def set_sharding(dispatch=None, out=None) -> None:
    SHARDING["dispatch"] = dispatch
    SHARDING["out"] = out


def _constrain(x: Array, key: str) -> Array:
    s = SHARDING.get(key)
    if s is not None:
        return jax.lax.with_sharding_constraint(x, s)
    return x


class MoEParams(NamedTuple):
    router: Array       # (d, E) f32
    w_gate: Array       # (E, d, f)
    w_up: Array         # (E, d, f)
    w_down: Array       # (E, f, d)
    # Optional shared expert (zeros-shaped-out when unused).
    s_gate: Array | None = None  # (d, f)
    s_up: Array | None = None
    s_down: Array | None = None


def capacity(group: int, cfg: MoEConfig) -> int:
    c = int(group * cfg.top_k * cfg.capacity_factor / cfg.n_experts + 0.999)
    return max(c, 1)


def _dispatch_indices(eids: Array, weights: Array, n_experts: int, cap: int
                      ) -> Tuple[Array, Array, Array]:
    """Build the (E*C) slot table for one token group.

    eids: (T, k) expert ids; weights: (T, k) router weights.
    Returns (slot_token (E*C,) int32 index into T*k flat assignments with
    T*k = overflow sentinel, slot_weight (E*C,), slot_valid (E*C,) bool).
    """
    t, k = eids.shape
    flat_e = eids.reshape(-1)                      # (T*k,)
    flat_w = weights.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)       # group by expert
    sorted_e = flat_e[order]
    # rank within expert = position - start offset of that expert
    counts = jnp.bincount(sorted_e, length=n_experts)
    starts = jnp.cumsum(counts) - counts           # exclusive prefix
    rank = jnp.arange(t * k) - starts[sorted_e]
    ok = rank < cap                                # capacity drop (overflow)
    slot = sorted_e * cap + rank.astype(jnp.int32)
    slot = jnp.where(ok, slot, n_experts * cap)    # spill to scratch slot
    slot_token = jnp.full((n_experts * cap + 1,), t * k, jnp.int32)
    slot_token = slot_token.at[slot].set(order.astype(jnp.int32),
                                         mode="drop")
    slot_token = slot_token[:-1]
    valid = slot_token < t * k
    safe = jnp.where(valid, slot_token, 0)
    slot_weight = jnp.where(valid, flat_w[safe], 0.0)
    return slot_token, slot_weight, valid


def _expert_ffn(xd: Array, p: MoEParams, act: str) -> Array:
    """xd: (B, E, C, d) -> (B, E, C, d)."""
    from repro.models.layers import _row_reduce_dtype
    dt = xd.dtype
    g = jnp.einsum("becd,edf->becf", xd, p.w_gate.astype(dt),
                   preferred_element_type=_row_reduce_dtype(dt))
    u = jnp.einsum("becd,edf->becf", xd, p.w_up.astype(dt),
                   preferred_element_type=_row_reduce_dtype(dt))
    h = (act_fn(act)(g) * u).astype(dt)
    from repro.models.layers import _row_reduce_dtype
    return jnp.einsum("becf,efd->becd", h, p.w_down.astype(dt),
                      preferred_element_type=_row_reduce_dtype(dt)
                      ).astype(dt)


def moe_group(x: Array, p: MoEParams, cfg: MoEConfig, act: str) -> Array:
    """Route one token group.  x: (B, g, d) -> (B, g, d)."""
    b, g, d = x.shape
    cap = capacity(g, cfg)
    logits = jnp.einsum("bgd,de->bge", x.astype(jnp.float32),
                        p.router.astype(jnp.float32))
    top_w, top_e = lax.top_k(logits, cfg.top_k)            # (B, g, k)
    top_w = jax.nn.softmax(top_w, axis=-1)

    def per_row(x_row, e_row, w_row):
        slot_tok, slot_w, valid = _dispatch_indices(
            e_row, w_row, cfg.n_experts, cap)
        tok = jnp.where(valid, slot_tok // cfg.top_k, 0)
        xd = x_row[tok] * valid[:, None].astype(x_row.dtype)   # (E*C, d)
        return xd.reshape(cfg.n_experts, cap, d), slot_tok, slot_w, valid

    xd, slot_tok, slot_w, valid = jax.vmap(per_row)(x, top_e, top_w)
    xd = _constrain(xd, "dispatch")     # all-to-all: tokens -> expert shards
    yd = _expert_ffn(xd, p, act)                           # (B, E, C, d)
    yd = _constrain(yd, "dispatch")     # all-to-all back before combine

    def per_row_combine(y_row, slot_tok_row, slot_w_row, valid_row):
        flat = y_row.reshape(cfg.n_experts * capacity(g, cfg), d)
        contrib = flat * (slot_w_row * valid_row)[:, None].astype(flat.dtype)
        tok = jnp.where(valid_row, slot_tok_row // cfg.top_k, g * cfg.top_k)
        out = jnp.zeros((g + 1, d), flat.dtype)
        out = out.at[jnp.minimum(tok, g)].add(contrib, mode="drop")
        return out[:g]

    y = jax.vmap(per_row_combine)(yd, slot_tok, slot_w, valid)
    y = _constrain(y, "out")
    if p.s_gate is not None:
        dt = x.dtype
        sg = jnp.einsum("bgd,df->bgf", x, p.s_gate.astype(dt),
                        preferred_element_type=jnp.float32)
        su = jnp.einsum("bgd,df->bgf", x, p.s_up.astype(dt),
                        preferred_element_type=jnp.float32)
        sh = (act_fn(act)(sg) * su).astype(dt)
        y = y + jnp.einsum("bgf,fd->bgd", sh, p.s_down.astype(dt),
                           preferred_element_type=jnp.float32).astype(dt)
    return y.astype(x.dtype)


def moe_ffn(x: Array, p: MoEParams, cfg: MoEConfig, act: str) -> Array:
    """x: (B, S, d).  Scans the sequence axis in groups of cfg.router_group."""
    b, s, d = x.shape
    g = min(cfg.router_group, s)
    if s % g:
        g = s
    n_groups = s // g
    if n_groups == 1:
        return moe_group(x, p, cfg, act)
    xs = x.reshape(b, n_groups, g, d).swapaxes(0, 1)       # (G, B, g, d)

    def body(_, xg):
        return None, moe_group(xg, p, cfg, act)

    _, ys = lax.scan(body, None, xs)
    return ys.swapaxes(0, 1).reshape(b, s, d)
