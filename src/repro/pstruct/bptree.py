"""Partly-persistent B+Tree (paper §IV-D).

Node layout mirrors the paper's Listing 2: one node = 256 B = 4 cache
lines (int32 row of 64 words):

  [0] num_keys  [1] is_leaf  [2:20] keys (18 x i32)
  [20:39] pointers (19 x i32: children for inner, record ids for leaves)
  [40] next (leaf chain)  [41] parent  [42:] pad

Records (the paper's 64 B ``struct record`` holding a 7-word Value) live in
a dense (cap, 8) int64 region — 1 line per record.

Persistence policy is the paper's exactly: both modes share one node
region; *partly* persists only rows with is_leaf=1 (+ records + header),
inner rows exist only as volatile redundancy; *fully* persists every dirty
node row — including the parent path on splits, which is where the
(1 - 1/n) * (t/(t-1)) flush saving comes from.

Simplifications vs the paper (identical across both modes, so the
fully-vs-partly comparison stays apples-to-apples; documented in
EXPERIMENTS.md): deletes remove keys from leaves and unlink emptied leaves
but do not rebalance inner nodes; splits fill to ORDER/2 (the paper's
insert-optimized minimum-bucket choice, §IV-D).

Reconstruction (paper §IV-D3): walk the persistent leaf chain (vectorized
binary lifting), then bulk-load inner levels by bucketing ORDER children
per parent — the paper's maximum-bucket-size choice, matching DCPMM 256 B
granularity.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import reconstruct as rec
from repro.core.arena import Arena, CorruptLineError, FlushStats
from repro.core.recovery import chain_method, chain_order
from repro.pstruct.dll import _salvage_bad_rows

ORDER = 19
MAX_KEYS = ORDER - 1           # 18
SPLIT_FILL = ORDER // 2        # 9..10 keys per split target
NULL = -1
VALUE_WORDS = 7

# Sharded-arena routing (DESIGN.md §7): node rows route by LEAF RANGE —
# block-cyclic runs of 16 node ids (sequentially allocated leaves land
# in runs, so a key-range scan's dirty leaves spread across shard files
# while adjacent leaf splits share one); records in 64-row ranges.
LEAF_RANGE = 16
REC_RANGE = 64

H_FLAG, H_ROOT, H_FIRST_LEAF, H_COUNT, H_FRESH_NODES, H_FRESH_RECS = range(6)

C_NK, C_LEAF = 0, 1
K0, K1 = 2, 20
P0, P1 = 20, 39
C_NEXT, C_PARENT = 40, 41


class BPTree:
    def __init__(self, arena: Arena, cap_nodes: int, cap_records: int,
                 mode: str = "partly", name: str = "bt",
                 chain_method: str = "auto"):
        assert mode in ("partly", "full")
        self.mode = mode
        self.arena = arena
        self.cap_nodes = cap_nodes
        self.cap_records = cap_records
        # leaf-chain ranking strategy (doubling vs contraction list
        # ranking, core.recovery.chain_method / DESIGN.md §8)
        self.chain_method = chain_method
        self.nodes = arena.regions.get(f"{name}.nodes") or arena.region(
            f"{name}.nodes", np.int32, (cap_nodes, 64),
            router=("seg", LEAF_RANGE))
        self.records = arena.regions.get(f"{name}.records") or arena.region(
            f"{name}.records", np.int64, (cap_records, 8),
            router=("seg", REC_RANGE))
        self.header = arena.regions.get(f"{name}.header") or arena.region(
            f"{name}.header", np.int64, (1, 8))
        self._free_nodes: List[int] = []
        self._free_recs: List[int] = []
        self.leaf_prev = np.full(cap_nodes, NULL, np.int32)  # volatile
        # keys lost to media corruption in the last salvage recovery
        # (best effort: readable from intact-but-unreachable leaf rows)
        self.quarantined: set = set()

    @staticmethod
    def layout(cap_nodes: int, cap_records: int, mode: str = "partly",
               name: str = "bt"):
        return {f"{name}.nodes": (np.int32, (cap_nodes, 64),
                                  ("seg", LEAF_RANGE)),
                f"{name}.records": (np.int64, (cap_records, 8),
                                    ("seg", REC_RANGE)),
                f"{name}.header": (np.int64, (1, 8))}

    # ---------------- allocation ----------------
    def _alloc_nodes(self, m: int) -> np.ndarray:
        hv = self.header.vol[0]
        ids = []
        take = min(len(self._free_nodes), m)
        if take:
            ids.extend(self._free_nodes[-take:])
            del self._free_nodes[-take:]
        need = m - take
        if need:
            f0 = int(hv[H_FRESH_NODES])
            if f0 + need > self.cap_nodes:
                raise MemoryError("bptree node arena exhausted")
            ids.extend(range(f0, f0 + need))
            hv[H_FRESH_NODES] = f0 + need
        arr = np.asarray(ids, np.int32)
        self.nodes.vol[arr] = 0
        self.nodes.vol[arr, C_NEXT] = NULL
        self.nodes.vol[arr, C_PARENT] = NULL
        return arr

    def _alloc_recs(self, m: int) -> np.ndarray:
        hv = self.header.vol[0]
        ids = []
        take = min(len(self._free_recs), m)
        if take:
            ids.extend(self._free_recs[-take:])
            del self._free_recs[-take:]
        need = m - take
        if need:
            f0 = int(hv[H_FRESH_RECS])
            if f0 + need > self.cap_records:
                raise MemoryError("bptree record arena exhausted")
            ids.extend(range(f0, f0 + need))
            hv[H_FRESH_RECS] = f0 + need
        return np.asarray(ids, np.int64)

    # ---------------- flush policy ----------------
    def _mark_nodes(self, dirty: np.ndarray) -> None:
        """Mark dirty node rows into the arena write set.  Partly mode
        persists only leaf rows — inner nodes are volatile redundancy."""
        dirty = np.unique(np.asarray(dirty, np.int64))
        if dirty.size == 0:
            return
        if self.mode == "partly":
            leaf = self.nodes.vol[dirty, C_LEAF] == 1
            dirty = dirty[leaf]
            if dirty.size == 0:
                return
        self.nodes.mark_rows(dirty)

    # ---------------- search ----------------
    def _descend(self, keys: np.ndarray) -> np.ndarray:
        """Leaf id for each key (vectorized level-synchronous descent)."""
        hv = self.header.vol[0]
        m = len(keys)
        cur = np.full(m, int(hv[H_ROOT]), np.int64)
        keys = keys.astype(np.int32)
        for _ in range(64):  # depth bound
            rows = self.nodes.vol[cur]
            inner = rows[:, C_LEAF] == 0
            if not inner.any():
                break
            r = rows[inner]
            nk = r[:, C_NK:C_NK + 1]
            keymat = r[:, K0:K1]
            valid = np.arange(MAX_KEYS)[None, :] < nk
            pos = ((keymat <= keys[inner, None]) & valid).sum(1)
            child = r[np.arange(len(r)), P0 + pos]
            nxt = cur.copy()
            nxt[inner] = child
            cur = nxt
        return cur

    def find_batch(self, keys: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        keys = np.asarray(keys, np.int64)
        hv = self.header.vol[0]
        if hv[H_FLAG] == 0 or hv[H_ROOT] == NULL:
            return (np.zeros(len(keys), bool),
                    np.zeros((len(keys), VALUE_WORDS), np.int64))
        leaves = self._descend(keys)
        rows = self.nodes.vol[leaves]
        nk = rows[:, C_NK:C_NK + 1]
        keymat = rows[:, K0:K1]
        valid = np.arange(MAX_KEYS)[None, :] < nk
        hit = (keymat == keys[:, None].astype(np.int32)) & valid
        ok = hit.any(1)
        slot = hit.argmax(1)
        rec = rows[np.arange(len(keys)), P0 + slot]
        vals = np.zeros((len(keys), VALUE_WORDS), np.int64)
        if ok.any():
            vals[ok] = self.records.vol[rec[ok], :VALUE_WORDS]
        return ok, vals

    # ---------------- insert ----------------
    def insert_batch(self, keys: np.ndarray, values: np.ndarray) -> None:
        with self.arena.epoch():
            self._insert_batch(keys, values)

    def _insert_batch(self, keys: np.ndarray, values: np.ndarray) -> None:
        keys = np.asarray(keys, np.int64)
        values = np.asarray(values, np.int64)
        # de-dup batch (keep last)
        _, last = np.unique(keys[::-1], return_index=True)
        keep = np.sort(len(keys) - 1 - last)
        keys, values = keys[keep], values[keep]
        hv = self.header.vol[0]

        if hv[H_FLAG] == 0 or hv[H_ROOT] == NULL:
            root = int(self._alloc_nodes(1)[0])
            self.nodes.vol[root, C_LEAF] = 1
            hv[H_ROOT] = root
            hv[H_FIRST_LEAF] = root
            hv[H_FLAG] = 1

        leaves = self._descend(keys)
        order = np.argsort(leaves, kind="stable")
        pending: List[Tuple[int, np.ndarray, np.ndarray]] = []
        i = 0
        while i < len(order):
            j = i
            leaf = leaves[order[i]]
            while j < len(order) and leaves[order[j]] == leaf:
                j += 1
            sel = order[i:j]
            pending.append((int(leaf), keys[sel], values[sel]))
            i = j

        # parent insertions accumulated per level
        promo: List[Tuple[int, int, int]] = []  # (left_node, sep_key, right_node)
        for leaf, ks, vs in pending:
            promo.extend(self._leaf_merge(leaf, ks, vs))
        # propagate splits upward
        while promo:
            promo = self._parent_insert(promo)
        self.header.mark_rows(np.array([0]))

    def _leaf_merge(self, leaf: int, ks: np.ndarray, vs: np.ndarray):
        hv = self.header.vol[0]
        row = self.nodes.vol[leaf]
        nk = int(row[C_NK])
        old_k = row[K0:K0 + nk].astype(np.int64)
        old_p = row[P0:P0 + nk].copy()
        ks32 = ks.astype(np.int32)
        # in-place updates for duplicates
        dup = np.isin(ks32, old_k.astype(np.int32))
        if dup.any():
            pos = np.searchsorted(old_k, ks[dup])
            recs = old_p[pos].astype(np.int64)
            self.records.vol[recs, :VALUE_WORDS] = vs[dup]
            self.records.mark_rows(recs)
        new_mask = ~dup
        if not new_mask.any():
            return []
        nks, nvs = ks[new_mask], vs[new_mask]
        f0 = int(hv[H_FRESH_RECS])
        recs = self._alloc_recs(len(nks))
        self.records.vol[recs, :VALUE_WORDS] = nvs
        # fresh-range record slots sit above the committed watermark, so
        # shadow mode flushes them home in place; free-list reuses may
        # have been freed by a still-uncommitted delete (live in the
        # committed image) and must route through the shadow remap
        fr = recs[recs >= f0]
        if fr.size:
            self.records.mark_rows(fr, fresh=True)
        rew = recs[recs < f0]
        if rew.size:
            self.records.mark_rows(rew)
        merged_k = np.concatenate([old_k, nks])
        merged_p = np.concatenate([old_p.astype(np.int64), recs])
        so = np.argsort(merged_k, kind="stable")
        merged_k, merged_p = merged_k[so], merged_p[so]
        hv[H_COUNT] += len(nks)
        if len(merged_k) <= MAX_KEYS:
            self._write_leaf(leaf, merged_k, merged_p)
            self._mark_nodes(np.array([leaf]))
            return []
        # split into chunks of SPLIT_FILL (last chunk takes remainder <= MAX)
        n = len(merged_k)
        cuts = list(range(SPLIT_FILL, n, SPLIT_FILL))
        if cuts and n - cuts[-1] < 2:
            cuts = cuts[:-1]
        chunks_k = np.split(merged_k, cuts)
        chunks_p = np.split(merged_p, cuts)
        n_new = len(chunks_k) - 1
        new_ids = self._alloc_nodes(n_new)
        self.nodes.vol[new_ids, C_LEAF] = 1
        old_next = int(row[C_NEXT])
        chain = [leaf] + new_ids.tolist()
        promos = []
        for idx, (nid, ck, cp) in enumerate(zip(chain, chunks_k, chunks_p)):
            self._write_leaf(nid, ck, cp)
            if idx > 0:
                promos.append((chain[idx - 1], int(ck[0]), nid))
        for a, b in zip(chain[:-1], chain[1:]):
            self.nodes.vol[a, C_NEXT] = b
            self.leaf_prev[b] = a
        self.nodes.vol[chain[-1], C_NEXT] = old_next
        if old_next != NULL:
            self.leaf_prev[old_next] = chain[-1]
        parent = int(row[C_PARENT])
        for nid in new_ids:
            self.nodes.vol[nid, C_PARENT] = parent
        self._mark_nodes(np.asarray(chain, np.int64))
        return promos

    def _write_leaf(self, nid: int, ks: np.ndarray, ps: np.ndarray) -> None:
        row = self.nodes.vol[nid]
        row[C_NK] = len(ks)
        row[K0:K1] = 0
        row[K0:K0 + len(ks)] = ks.astype(np.int32)
        row[P0:P1] = 0
        row[P0:P0 + len(ks)] = ps.astype(np.int32)

    def _parent_insert(self, promo: List[Tuple[int, int, int]]):
        """Insert (sep, right) pairs after `left` in their parents.  Returns
        next level's promotions."""
        hv = self.header.vol[0]
        dirty: List[int] = []
        by_parent: Dict[int, List[Tuple[int, int, int]]] = {}
        for left, sep, right in promo:
            parent = int(self.nodes.vol[left, C_PARENT])
            if parent == NULL:
                # splitting the root: create a new root holding just `left`
                # (0 separators); the (sep, right) pair is then inserted via
                # the regular path below.
                new_root = int(self._alloc_nodes(1)[0])
                r = self.nodes.vol[new_root]
                r[C_LEAF] = 0
                r[C_NK] = 0
                r[P0] = left
                self.nodes.vol[left, C_PARENT] = new_root
                hv[H_ROOT] = new_root
                dirty.append(new_root)
                parent = new_root
            # Set the right child's parent EAGERLY so later promotions in
            # this same pass (whose `left` is this `right`) resolve to the
            # correct parent.
            self.nodes.vol[right, C_PARENT] = parent
            if self.mode == "full":
                dirty.append(right)  # parent field is persistent
            by_parent.setdefault(parent, []).append((left, sep, right))
        next_promo: List[Tuple[int, int, int]] = []
        for parent, items in by_parent.items():
            row = self.nodes.vol[parent]
            nk = int(row[C_NK])
            keysv = row[K0:K0 + nk].astype(np.int64).tolist()
            ptrs = row[P0:P0 + nk + 1].astype(np.int64).tolist()
            for left, sep, right in items:
                at = ptrs.index(left) + 1
                keysv.insert(at - 1, sep)
                ptrs.insert(at, right)
            if len(keysv) <= MAX_KEYS:
                self._write_inner(parent, keysv, ptrs)
                dirty.append(parent)
                continue
            # split inner node into chunks of <= MAX_KEYS keys
            all_k, all_p = keysv, ptrs
            chunks: List[Tuple[List[int], List[int]]] = []
            seps: List[int] = []
            i = 0
            n = len(all_k)
            while True:
                take = min(SPLIT_FILL, n - i)
                if n - (i + take) == 0:
                    chunks.append((all_k[i:i + take], all_p[i:i + take + 1]))
                    break
                if n - (i + take + 1) < 1:  # leave >=1 key for the last chunk
                    take = n - i - 2
                chunks.append((all_k[i:i + take], all_p[i:i + take + 1]))
                seps.append(all_k[i + take])
                i += take + 1
            new_ids = self._alloc_nodes(len(chunks) - 1)
            node_ids = [parent] + new_ids.tolist()
            for nid, (ck, cp) in zip(node_ids, chunks):
                self._write_inner(nid, ck, cp)
                for c in cp:
                    self.nodes.vol[c, C_PARENT] = nid
                if self.mode == "full":
                    dirty.extend(int(c) for c in cp)
                dirty.append(nid)
            gp = int(self.nodes.vol[parent, C_PARENT])
            for nid in new_ids:
                self.nodes.vol[nid, C_PARENT] = gp
            for li, sep in enumerate(seps):
                next_promo.append((node_ids[li], sep, node_ids[li + 1]))
        self._mark_nodes(np.asarray(dirty, np.int64))
        return next_promo

    def _write_inner(self, nid: int, ks, ps) -> None:
        row = self.nodes.vol[nid]
        row[C_LEAF] = 0
        row[C_NK] = len(ks)
        row[K0:K1] = 0
        row[K0:K0 + len(ks)] = np.asarray(ks, np.int32)
        row[P0:P1] = 0
        row[P0:P0 + len(ps)] = np.asarray(ps, np.int32)

    # ---------------- delete ----------------
    def delete_batch(self, keys: np.ndarray) -> np.ndarray:
        with self.arena.epoch():
            return self._delete_batch(keys)

    def _delete_batch(self, keys: np.ndarray) -> np.ndarray:
        keys = np.asarray(keys, np.int64)
        hv = self.header.vol[0]
        if hv[H_FLAG] == 0 or hv[H_ROOT] == NULL:
            return np.zeros(len(keys), bool)
        leaves = self._descend(keys)
        ok = np.zeros(len(keys), bool)
        order = np.argsort(leaves, kind="stable")
        i = 0
        while i < len(order):
            j = i
            leaf = int(leaves[order[i]])
            while j < len(order) and leaves[order[j]] == leaf:
                j += 1
            sel = order[i:j]
            i = j
            row = self.nodes.vol[leaf]
            nk = int(row[C_NK])
            old_k = row[K0:K0 + nk].astype(np.int64)
            old_p = row[P0:P0 + nk].astype(np.int64)
            hit = np.isin(old_k, keys[sel])
            ok[sel] = np.isin(keys[sel], old_k)
            if not hit.any():
                continue
            self._free_recs.extend(old_p[hit].tolist())
            keep_k, keep_p = old_k[~hit], old_p[~hit]
            hv[H_COUNT] -= int(hit.sum())
            self._write_leaf(leaf, keep_k, keep_p)
            self._mark_nodes(np.array([leaf]))
            if len(keep_k) == 0:
                self._unlink_leaf(leaf)
        self.header.mark_rows(np.array([0]))
        return ok

    def _unlink_leaf(self, leaf: int) -> None:
        hv = self.header.vol[0]
        nxt = int(self.nodes.vol[leaf, C_NEXT])
        prv = int(self.leaf_prev[leaf])
        if prv != NULL:
            self.nodes.vol[prv, C_NEXT] = nxt
            self._mark_nodes(np.array([prv]))
        else:
            hv[H_FIRST_LEAF] = nxt
        if nxt != NULL:
            self.leaf_prev[nxt] = prv
        # detach from parent (recursively removing emptied inner nodes)
        self._remove_child(int(self.nodes.vol[leaf, C_PARENT]), leaf)
        self._free_nodes.append(leaf)

    def _remove_child(self, parent: int, child: int) -> None:
        hv = self.header.vol[0]
        if parent == NULL:
            if int(hv[H_ROOT]) == child:
                hv[H_ROOT] = NULL
                hv[H_FLAG] = 1  # initialized-but-empty
            return
        row = self.nodes.vol[parent]
        nk = int(row[C_NK])
        ptrs = row[P0:P0 + nk + 1].astype(np.int64).tolist()
        if child in ptrs:
            at = ptrs.index(child)
            keysv = row[K0:K0 + nk].astype(np.int64).tolist()
            del ptrs[at]
            if nk:
                del keysv[max(0, at - 1)]
            if not ptrs:
                self._remove_child(int(row[C_PARENT]), parent)
                self._free_nodes.append(parent)
                return
            self._write_inner(parent, keysv, ptrs)
            self._mark_nodes(np.array([parent]))

    # ---------------- traversal ----------------
    def leaves(self) -> np.ndarray:
        """Leaf ids in chain order via the shared vectorized primitive —
        the one place that knows how to enumerate the persistent NEXT
        chain (sliced at the committed fresh-water mark; empty for an
        empty tree)."""
        hv = self.header.vol[0]
        first = int(hv[H_FIRST_LEAF])
        if hv[H_FLAG] != 1 or first == NULL:
            return np.empty(0, np.int64)
        fresh = int(hv[H_FRESH_NODES])
        return chain_order(
            self.nodes.vol[:fresh, C_NEXT].astype(np.int64), first,
            method=self.chain_method)

    def keys_in_order(self) -> np.ndarray:
        """All keys in sorted (leaf-chain) order — one masked gather over
        the leaf rows, no per-leaf Python loop."""
        leaves = self.leaves()
        if leaves.size == 0:
            return np.empty(0, np.int64)
        rows = self.nodes.vol[leaves]
        nk = rows[:, C_NK]
        keymat = rows[:, K0:K1].astype(np.int64)
        valid = np.arange(MAX_KEYS)[None, :] < nk[:, None]
        return keymat[valid]

    def max_key(self) -> Optional[int]:
        """Largest key, read off the last non-empty leaf in O(chain
        enumeration) — no full key materialization."""
        leaves = self.leaves()
        if leaves.size == 0:
            return None
        nks = self.nodes.vol[leaves, C_NK]
        ne = np.nonzero(nks > 0)[0]
        if ne.size == 0:
            return None
        row = self.nodes.vol[leaves[ne[-1]]]
        return int(row[K0 + int(nks[ne[-1]]) - 1])

    # ---------------- crash / reconstruction ----------------
    def reconstruct(self) -> None:
        """Thin shim over the registered pure reconstructor — recovery
        paths route through core.recovery.RecoveryManager, which loads
        the regions once and times the stage."""
        self.header.load()
        self.nodes.load()
        self.records.load()
        rec.get("pstruct.bptree")(self)

    def _bulk_load_level(self, parents: np.ndarray, level: np.ndarray,
                         mins: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Write one inner level in a single vectorized pass: bucket ORDER
        children per parent, build all parent rows in one (P, 64) buffer,
        scatter children's parent pointers once."""
        n_parents = len(parents)
        n_level = len(level)
        kids = np.zeros((n_parents, ORDER), np.int64)
        kids.reshape(-1)[:n_level] = level
        kmins = np.zeros((n_parents, ORDER), np.int64)
        kmins.reshape(-1)[:n_level] = mins
        counts = np.minimum(ORDER, n_level - np.arange(n_parents) * ORDER)
        rowbuf = np.zeros((n_parents, 64), np.int32)
        rowbuf[:, C_NK] = (counts - 1).astype(np.int32)
        keymask = np.arange(MAX_KEYS)[None, :] < (counts - 1)[:, None]
        rowbuf[:, K0:K1] = np.where(keymask, kmins[:, 1:], 0).astype(np.int32)
        ptrmask = np.arange(ORDER)[None, :] < counts[:, None]
        rowbuf[:, P0:P0 + ORDER] = np.where(ptrmask, kids, 0).astype(np.int32)
        rowbuf[:, C_NEXT] = NULL
        rowbuf[:, C_PARENT] = NULL
        self.nodes.vol[parents] = rowbuf
        self.nodes.vol[level, C_PARENT] = np.repeat(
            parents.astype(np.int32), ORDER)[:n_level]
        return parents.astype(np.int64), kmins[:, 0]

    def _live_record_mask(self, leaves: np.ndarray) -> np.ndarray:
        """Records referenced by live leaves, one vectorized gather."""
        rec_live = np.zeros(self.cap_records, bool)
        if leaves.size:
            rows = self.nodes.vol[leaves]
            nk = rows[:, C_NK]
            recmat = rows[:, P0:P0 + MAX_KEYS].astype(np.int64)
            valid = np.arange(MAX_KEYS)[None, :] < nk[:, None]
            rec_live[recmat[valid]] = True
        return rec_live

    def _alloc_nodes_reconstruct(self, m: int, live: np.ndarray) -> np.ndarray:
        """Allocate inner nodes during rebuild from non-live slots."""
        free = np.nonzero(~live[:])[0][:m]
        if len(free) < m:
            raise MemoryError("bptree node arena exhausted during rebuild")
        live[free] = True
        arr = free.astype(np.int32)
        self.nodes.vol[arr] = 0
        self.nodes.vol[arr, C_NEXT] = NULL
        self.nodes.vol[arr, C_PARENT] = NULL
        hv = self.header.vol[0]
        hv[H_FRESH_NODES] = max(int(hv[H_FRESH_NODES]), int(arr.max()) + 1)
        return arr

    def _rebuild_volatile_only(self) -> None:
        """Fully-persistent mode: tree is complete in PM; rebuild leaf_prev
        and free lists."""
        hv = self.header.vol[0]
        fresh = int(hv[H_FRESH_NODES])
        self.leaf_prev[:] = NULL
        leaves = self.leaves()
        if leaves.size == 0:
            return
        self.leaf_prev[leaves[1:]] = leaves[:-1].astype(np.int32)
        live = np.zeros(self.cap_nodes, bool)
        live[leaves] = True
        cur = leaves
        while True:   # one round per tree LEVEL (O(log n) rounds)
            parents = np.unique(self.nodes.vol[cur, C_PARENT])
            parents = parents[parents != NULL]
            if parents.size == 0:
                break
            live[parents] = True
            cur = parents
        self._free_nodes = np.nonzero(~live[:fresh])[0].tolist()
        rec_live = self._live_record_mask(leaves)
        self._free_recs = np.nonzero(
            ~rec_live[:int(hv[H_FRESH_RECS])])[0].tolist()

    # ---------------- verification ----------------
    def check_invariants(self) -> None:
        """Leaf-chain order/sortedness/count — vectorized over the whole
        chain (one chain_order + masked matrix checks)."""
        hv = self.header.vol[0]
        if hv[H_FLAG] == 0 or hv[H_ROOT] == NULL:
            return
        leaves = self.leaves()
        if leaves.size == 0:
            assert int(hv[H_COUNT]) == 0, int(hv[H_COUNT])
            return
        rows = self.nodes.vol[leaves]
        assert (rows[:, C_LEAF] == 1).all(), "non-leaf on leaf chain"
        nk = rows[:, C_NK]
        keymat = rows[:, K0:K1].astype(np.int64)
        valid = np.arange(MAX_KEYS)[None, :] < nk[:, None]
        sorted_ok = (np.diff(keymat, axis=1) > 0) | ~valid[:, 1:]
        assert sorted_ok.all(), "leaf keys not sorted"
        ne = nk > 0
        firsts = keymat[ne, 0]
        lasts = keymat[ne, nk[ne] - 1]
        assert (firsts[1:] > lasts[:-1]).all(), "leaf chain out of order"
        total = int(nk.sum())
        assert total == int(hv[H_COUNT]), (total, int(hv[H_COUNT]))

    def flush_stats(self) -> FlushStats:
        return self.arena.stats


@rec.register("pstruct.bptree")
def _reconstruct_bptree(t: "BPTree") -> dict:
    """Pure rebuild (paper §IV-D3): enumerate leaves via the persistent
    NEXT chain (shared chain_order primitive — count derived by pointer
    doubling, cycle-checked), then bulk-load inner levels bucketing ORDER
    children per parent, one vectorized pass per level."""
    hv = t.header.vol[0]
    t.quarantined = set()
    if hv[H_FLAG] != 1:
        # uninitialized image recovers as an empty tree (§IV-D3 validity
        # check on the root node)
        hv[:] = 0
        hv[H_ROOT] = NULL
        hv[H_FIRST_LEAF] = NULL
        t.leaf_prev[:] = NULL
        t._free_nodes = []
        t._free_recs = []
        return {"mode": t.mode, "count": 0}
    salvage = bool(getattr(t.arena, "_salvage", False))
    bad_nodes = (_salvage_bad_rows(t.arena, t.nodes) if salvage
                 else np.empty(0, np.int64))
    bad_recs = (_salvage_bad_rows(t.arena, t.records) if salvage
                else np.empty(0, np.int64))
    bad_nodes = bad_nodes[bad_nodes < t.cap_nodes]
    bad_recs = bad_recs[bad_recs < t.cap_records]
    corrupt = int(bad_nodes.size + bad_recs.size)
    if t.mode == "full":
        if corrupt:
            # a fully-persistent tree has parent/child pointers woven
            # through every row — there is no committed-prefix remainder
            # to keep, so the whole stage quarantines
            raise CorruptLineError(
                t.nodes.name if bad_nodes.size else t.records.name,
                bad_nodes if bad_nodes.size else bad_recs,
                detail="fully-persistent tree: no salvageable remainder")
        t._rebuild_volatile_only()
        return {"mode": "full", "count": int(hv[H_COUNT])}
    detail = {"mode": "partly"}
    # 1. enumerate leaves via the persistent next chain
    if bad_nodes.size:
        # salvage: keep the maximal leaf-chain prefix that never touches
        # a corrupt row — everything downstream is unreachable without
        # trusting rotten bytes
        img = np.asarray(t.arena._pimage(t.nodes))
        badset = set(bad_nodes.tolist())
        fresh_n = int(hv[H_FRESH_NODES])
        seen: set = set()
        prefix: List[int] = []
        cur = int(hv[H_FIRST_LEAF])
        while 0 <= cur < fresh_n and cur not in badset and cur not in seen:
            seen.add(cur)
            prefix.append(cur)
            cur = int(img[cur, C_NEXT])
        leaves = np.asarray(prefix, np.int64)
        if leaves.size:
            t.nodes.vol[leaves[-1], C_NEXT] = NULL  # volatile chain cut
        # name the lost keys best-effort: intact-but-unreachable leaf
        # rows are readable even though the chain can no longer prove
        # them live (stale freed leaves over-quarantine only keys that
        # are absent anyway — refusal stays conservative); keys inside
        # the corrupt rows themselves are unreadable and stay anonymous
        for r in range(fresh_n):
            if r in seen or r in badset or img[r, C_LEAF] != 1:
                continue
            nk = min(int(img[r, C_NK]), MAX_KEYS)
            t.quarantined.update(int(k) for k in img[r, K0:K0 + nk])
    else:
        try:
            leaves = t.leaves()
        except (RuntimeError, ValueError) as e:
            if not salvage:
                raise
            raise CorruptLineError(t.nodes.name, np.empty(0, np.int64),
                                   detail=f"leaf chain rebuild: {e}") from e
    if leaves.size == 0:
        hv[H_ROOT] = NULL
        if corrupt:
            hv[H_FIRST_LEAF] = NULL
            hv[H_COUNT] = 0
            t.leaf_prev[:] = NULL
            live = np.zeros(t.cap_nodes, bool)
            live[bad_nodes] = True  # corrupt rows are never reusable
            t._free_nodes = np.nonzero(
                ~live[:int(hv[H_FRESH_NODES])])[0].tolist()
            rec_live = np.zeros(t.cap_records, bool)
            rec_live[bad_recs] = True
            t._free_recs = np.nonzero(
                ~rec_live[:int(hv[H_FRESH_RECS])])[0].tolist()
            detail.update(count=0, quarantined=True, degraded=True,
                          quarantined_rows=corrupt,
                          quarantined_keys=sorted(t.quarantined))
            return detail
        return {"mode": "partly", "count": 0}
    # 2. leaf prev (volatile redundancy)
    t.leaf_prev[:] = NULL
    t.leaf_prev[leaves[1:]] = leaves[:-1].astype(np.int32)
    # 2b. salvage: drop leaf slots whose record row is corrupt — the key
    #     is readable from the intact leaf, so it quarantines by name
    if bad_recs.size:
        badrec = np.zeros(t.cap_records, bool)
        badrec[bad_recs] = True
        for lf in leaves.tolist():
            row = t.nodes.vol[lf]
            nk = int(row[C_NK])
            ptrs = row[P0:P0 + nk].astype(np.int64)
            hit = badrec[ptrs]
            if not hit.any():
                continue
            t.quarantined.update(int(k) for k in row[K0:K0 + nk][hit])
            keep = ~hit
            kept = int(keep.sum())
            row[K0:K0 + kept] = row[K0:K0 + nk][keep]
            row[P0:P0 + kept] = ptrs[keep].astype(np.int32)
            row[K0 + kept:K0 + nk] = 0
            row[P0 + kept:P0 + nk] = 0
            row[C_NK] = kept
    if corrupt:
        rows = t.nodes.vol[leaves]
        nk = rows[:, C_NK]
        keymat = rows[:, K0:K1].astype(np.int64)
        valid = np.arange(MAX_KEYS)[None, :] < nk[:, None]
        t.quarantined -= set(keymat[valid].tolist())  # survivors aren't lost
        hv[H_COUNT] = int(nk.sum())
        detail.update(degraded=True, quarantined_rows=corrupt,
                      quarantined_keys=sorted(t.quarantined))
    # 3. bulk-load inner levels, bucket size = ORDER (paper §IV-D:
    #    maximum bucket -> fewest levels, matches 256B granularity);
    #    subtree minima are the separators, tracked per level
    level = leaves
    mins = t.nodes.vol[leaves, K0].astype(np.int64)
    # wipe any stale inner rows: everything not a live leaf is free
    live = np.zeros(t.cap_nodes, bool)
    live[level] = True
    live[bad_nodes] = True  # corrupt rows are never reusable
    while len(level) > 1:
        n_parents = (len(level) + ORDER - 1) // ORDER
        parents = t._alloc_nodes_reconstruct(n_parents, live)
        level, mins = t._bulk_load_level(parents, level, mins)
    root = int(level[0])
    t.nodes.vol[root, C_PARENT] = NULL
    hv[H_ROOT] = root
    # 4. free lists: records referenced by live leaves are live
    t._free_nodes = np.nonzero(~live[:int(hv[H_FRESH_NODES])])[0].tolist()
    rec_live = t._live_record_mask(leaves)
    rec_live[bad_recs] = True  # corrupt rows are never reusable
    t._free_recs = np.nonzero(
        ~rec_live[:int(hv[H_FRESH_RECS])])[0].tolist()
    detail.update(count=int(hv[H_COUNT]), leaves=int(leaves.size),
                  chain=chain_method(int(hv[H_FRESH_NODES]), None,
                                     getattr(t, "chain_method", "auto")))
    return detail
