"""Partly-persistent doubly linked list (paper §IV-C).

Array-backed (indices as pointers) so operations vectorize over batches —
the TPU-framework adaptation of the paper's single-threaded op loop
(DESIGN.md §2): framework call sites (the paged-KV LRU/free list) naturally
operate on batches of pages.

Layout mirrors the paper's Listing 1 exactly at the flush-unit level:

* partly persistent: one 64 B row per node = DATA (7 x i64 = 56 B) + NEXT
  (8 B).  PREV is volatile only.  Appending a node flushes 1 line.
* fully persistent: one 128 B row per node = DATA + NEXT + PREV + pad
  (the paper's 64-aligned struct with prev spilling to a second line).
  Appending flushes 2 lines, plus the successor's prev line on links.

Volatile redundancy (all DERIVABLE): PREV array, TAIL, free-slot list, and
an order ring (the list order materialized for O(1) batched head pops —
the LRU eviction path).

Reconstruction (paper §IV-C3, parallelized per §V-F's suggestion): binary
lifting over NEXT — jump tables next^(2^k); node-at-position for all
positions computed vectorized in O(N log N); PREV by one scatter; TAIL =
last; free slots = complement.  This is the TPU/vector-native equivalent of
the paper's sequential forward walk.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core import reconstruct as rec
from repro.core.arena import (Arena, CorruptLineError, FlushStats,
                              SNAP_SLOTS, SNAP_WORDS, snap_record_pack,
                              snap_record_parse, snapshot_enabled)
from repro.core.recovery import ChainSnapshot, chain_method, chain_order

NULL = -1
DATA_WORDS = 7

# Sharded-arena routing (DESIGN.md §7): node rows stripe block-cyclically
# in segments of 64 — appends fill a segment on one shard then roll to
# the next, so a batch's flush fans out across shard files while rows
# within a segment still coalesce lines.
SHARD_SEG = 64

# header slots
H_FLAG, H_HEAD, H_COUNT, H_TAIL, H_FREE_HEAD, H_FRESH = range(6)


class DoublyLinkedList:
    """mode: "partly" | "full"."""

    def __init__(self, arena: Arena, capacity: int, mode: str = "partly",
                 name: str = "dll", chain_method: str = "auto",
                 snapshot: Optional[bool] = None):
        assert mode in ("partly", "full")
        self.mode = mode
        self.capacity = capacity
        # chain-ranking strategy for every NEXT-chain walk (to_list and
        # the recovery reconstructor): "auto" flips from pointer
        # doubling to contraction list ranking at the cache crossover
        # (core.recovery.chain_method, DESIGN.md §8)
        self.chain_method = chain_method
        self.arena = arena
        row = 8 if mode == "partly" else 16
        self._row = row
        self.nodes = arena.regions.get(f"{name}.nodes") or arena.region(
            f"{name}.nodes", np.int64, (capacity, row),
            router=("seg", SHARD_SEG))
        self.header = arena.regions.get(f"{name}.header") or arena.region(
            f"{name}.header", np.int64, (1, 8))
        # volatile redundancy
        self.prev = np.full(capacity, NULL, np.int64)
        self._free: list[int] = []
        self._ring = np.empty(capacity * 2, np.int64)  # order ring
        self._r0 = 0
        self._r1 = 0
        # incremental order snapshots (DESIGN.md §10): a persisted mirror
        # of the order ring plus a 4-slot sealed-record ring, appended to
        # by a commit-time provider.  Degrades to OFF when the arena's
        # layout was finalized without the snapshot regions (an older
        # image, or REPRO_SNAPSHOT=0 at creation).
        snap_on = snapshot_enabled(snapshot)
        self.snapring = arena.regions.get(f"{name}.snapring")
        self.snaprec = arena.regions.get(f"{name}.snaprec")
        if snap_on and self.snapring is None and not arena._layout_final:
            self.snapring = arena.region(f"{name}.snapring", np.int64,
                                         (capacity * 2,),
                                         router=("seg", SHARD_SEG))
            self.snaprec = arena.region(f"{name}.snaprec", np.int64,
                                        (SNAP_SLOTS, SNAP_WORDS))
        self.snapshot = snap_on and self.snapring is not None
        if self.snapshot:
            self._snap_dirty = np.zeros(capacity * 2, bool)
            self._snap_seq = 0
            self._snap_resync = True   # first drain mirrors the window
            self._snap_last = None     # (r0, r1, count) at last emit
            arena.add_snapshot_provider(self._snap_emit)

    @staticmethod
    def layout(capacity: int, mode: str = "partly", name: str = "dll",
               snapshot: Optional[bool] = None):
        row = 8 if mode == "partly" else 16
        out = {f"{name}.nodes": (np.int64, (capacity, row),
                                 ("seg", SHARD_SEG)),
               f"{name}.header": (np.int64, (1, 8))}
        if snapshot_enabled(snapshot):
            out[f"{name}.snapring"] = (np.int64, (capacity * 2,),
                                       ("seg", SHARD_SEG))
            out[f"{name}.snaprec"] = (np.int64, (SNAP_SLOTS, SNAP_WORDS))
        return out

    # ------------- views over the node rows -------------
    @property
    def data(self) -> np.ndarray:
        # full-array view — on a paged arena this SPILLS the region;
        # batch consumers should use data_rows()
        return self.nodes.vol[:, :DATA_WORDS]

    @property
    def next(self) -> np.ndarray:
        return self.nodes.vol[:, DATA_WORDS]

    def data_rows(self, ids: np.ndarray) -> np.ndarray:
        """DATA words of the given node ids — block-routed on a paged
        arena (the ``.data`` property would materialize the region)."""
        return np.asarray(self.nodes.read_at(np.asarray(ids, np.int64),
                                             slice(0, DATA_WORDS)))

    def _next_col(self) -> np.ndarray:
        """NEXT column for a full chain walk: a paged nodes region reads
        the column through the block cache (residency stays bounded by
        eviction); resident regions return the live view."""
        n = self.nodes
        if getattr(n, "paged_active", False):
            return np.asarray(n.read_col(DATA_WORDS))
        return n.vol[:, DATA_WORDS]

    @property
    def head(self) -> int:
        return int(self.header.vol[0, H_HEAD])

    @property
    def tail(self) -> int:
        return int(self.header.vol[0, H_TAIL])

    @property
    def count(self) -> int:
        return int(self.header.vol[0, H_COUNT])

    # ------------- allocation -------------
    def _alloc(self, m: int) -> np.ndarray:
        ids = []
        take = min(len(self._free), m)
        if take:
            ids.extend(self._free[-take:])
            del self._free[-take:]
        fresh_needed = m - take
        fresh0 = int(self.header.vol[0, H_FRESH])
        if fresh_needed:
            if fresh0 + fresh_needed > self.capacity:
                raise MemoryError("dll arena exhausted")
            ids.extend(range(fresh0, fresh0 + fresh_needed))
            self.header.vol[0, H_FRESH] = fresh0 + fresh_needed
        return np.asarray(ids, np.int64)

    # ------------- operations -------------
    def append_batch(self, values: np.ndarray) -> np.ndarray:
        """Append m nodes at the tail.  values: (m, 7) int64.  Returns ids."""
        with self.arena.epoch():
            return self._append_batch(values)

    def _append_batch(self, values: np.ndarray) -> np.ndarray:
        m = len(values)
        fresh0 = int(self.header.vol[0, H_FRESH])
        ids = self._alloc(m)
        hv = self.header.vol[0]
        self.nodes.write_at(ids, slice(0, DATA_WORDS), values)
        # chain: old_tail -> ids[0] -> ids[1] ... -> NULL
        self.nodes.write_at(ids[:-1], DATA_WORDS, ids[1:])
        self.nodes.write_at(ids[-1:], DATA_WORDS, NULL)
        self.prev[ids[1:]] = ids[:-1]
        old_tail = int(hv[H_TAIL]) if hv[H_COUNT] > 0 else NULL
        if old_tail != NULL:
            self.nodes.write_at(np.asarray([old_tail]), DATA_WORDS, ids[0])
            self.prev[ids[0]] = old_tail
        else:
            hv[H_HEAD] = ids[0]
            self.prev[ids[0]] = NULL
        hv[H_TAIL] = ids[-1]
        hv[H_COUNT] += m
        hv[H_FLAG] = 1
        if self.mode == "full":
            self.nodes.write_at(ids[1:], DATA_WORDS + 1, ids[:-1])
            self.nodes.write_at(ids[:1], DATA_WORDS + 1, old_tail)
        # ring
        n = len(ids)
        if self._r1 + n > self._ring.size:
            self._compact_ring()
        self._ring[self._r1:self._r1 + n] = ids
        self._r1 += n
        if self.snapshot:
            self._snap_dirty[self._r1 - n:self._r1] = True
        # ---- mark dirty (flushed once at epoch close) ----
        # fresh-range ids sit above the committed fresh-water mark, so
        # their bytes are dead in the committed image: shadow mode may
        # flush them home in place (unreachable until the flip), while
        # free-list reuses and the old tail's pointer rewrite must route
        # through the shadow remap
        new = ids[ids >= fresh0]
        if new.size:
            self.nodes.mark_rows(new, fresh=True)
        reused = ids[ids < fresh0]
        dirty = reused if old_tail == NULL \
            else np.concatenate([[old_tail], reused])
        if dirty.size:
            self.nodes.mark_rows(dirty)
        self.header.mark_rows(np.array([0]))
        return ids

    def pop_front_batch(self, m: int) -> np.ndarray:
        """Remove the m oldest nodes (LRU eviction).  Returns their ids."""
        with self.arena.epoch():
            return self._pop_front_batch(m)

    def _pop_front_batch(self, m: int) -> np.ndarray:
        hv = self.header.vol[0]
        m = min(m, int(hv[H_COUNT]))
        if m == 0:
            return np.empty(0, np.int64)
        ids = self._ring_pop(m)
        new_head = self.nodes.read_one(int(ids[-1]), DATA_WORDS)
        hv[H_HEAD] = new_head
        hv[H_COUNT] -= m
        if new_head == NULL:
            hv[H_TAIL] = NULL
        else:
            self.prev[new_head] = NULL
        self._free.extend(ids.tolist())
        # partly: only the header changes persistently (the popped rows are
        # unreachable from HEAD, so their bytes are dead — zero row flushes).
        if self.mode == "full":
            # fully persistent must clear new_head's prev line
            if new_head != NULL:
                self.nodes.write_at(np.asarray([new_head]),
                                    DATA_WORDS + 1, NULL)
                self.nodes.mark_rows(np.array([new_head]))
        self.header.mark_rows(np.array([0]))
        return ids

    def delete_batch(self, ids: np.ndarray) -> None:
        """Unlink an arbitrary batch of node ids (vectorized rounds: each
        round unlinks ids whose predecessor is not itself being deleted).
        All rounds share one epoch: a predecessor rewritten in several
        rounds flushes once."""
        with self.arena.epoch():
            self._delete_batch(np.asarray(ids, np.int64))

    def _delete_batch(self, ids: np.ndarray) -> None:
        pending = set(ids.tolist())
        hv = self.header.vol[0]
        while pending:
            arr = np.fromiter(pending, np.int64)
            pred = self.prev[arr]
            ready = ~np.isin(pred, arr)
            batch = arr[ready]
            if batch.size == 0:  # adjacent chain; peel one end
                batch = arr[:1]
            nxt = np.asarray(self.nodes.read_at(batch, DATA_WORDS))
            prv = self.prev[batch]
            # batched column writes: within a round each node has a
            # DISTINCT predecessor and successor (a list node has one of
            # each, and nodes whose predecessor is also being deleted
            # wait for a later round), so the scatters are conflict-free
            link = prv != NULL
            if link.any():
                self.nodes.write_at(prv[link], DATA_WORDS, nxt[link])
            for i in np.nonzero(~link)[0]:
                hv[H_HEAD] = nxt[i]
            has_nx = nxt != NULL
            if has_nx.any():
                self.prev[nxt[has_nx]] = prv[has_nx]
                if self.mode == "full":
                    self.nodes.write_at(nxt[has_nx], DATA_WORDS + 1,
                                        prv[has_nx])
            for i in np.nonzero(~has_nx)[0]:
                hv[H_TAIL] = prv[i]
            dirty = [prv[link]]
            if self.mode == "full":
                dirty.append(nxt[has_nx])
            dirty = np.concatenate(dirty)
            hv[H_COUNT] -= batch.size
            self._free.extend(batch.tolist())
            pending.difference_update(batch.tolist())
            if dirty.size:
                self.nodes.mark_rows(dirty)
        self.header.mark_rows(np.array([0]))
        self._ring_invalidate(ids)

    # ------------- ring helpers -------------
    def _compact_ring(self) -> None:
        live = self._ring[self._r0:self._r1]
        self._ring[: live.size] = live
        self._r0, self._r1 = 0, live.size
        if self.snapshot:
            # every slot moved: the persisted mirror diverges wholesale
            self._snap_resync = True

    def _ring_pop(self, m: int) -> np.ndarray:
        out = np.empty(m, np.int64)
        got = 0
        while got < m:
            cand = self._ring[self._r0]
            self._r0 += 1
            if cand >= 0:
                out[got] = cand
                got += 1
        return out

    def _ring_invalidate(self, ids: np.ndarray) -> None:
        window = self._ring[self._r0:self._r1]
        mask = np.isin(window, ids)
        window[mask] = NULL
        if self.snapshot:
            self._snap_dirty[self._r0 + np.nonzero(mask)[0]] = True

    # ------------- traversal / verification -------------
    def to_list(self) -> np.ndarray:
        """Materialize list order from NEXT (the shared chain_order
        primitive — doubling or contraction per ``chain_method``, never
        a scalar walk)."""
        return chain_order(self._next_col(), self.head, self.count,
                           method=self.chain_method)

    def order(self) -> np.ndarray:
        """List order materialized from the volatile ring (no chain
        traversal at all): appends push at the back, pops consume the
        front, deletes punch NULL holes — the surviving window IS the
        list order.  Recovery consumers (the paged-KV allocator) read
        this right after reconstruction."""
        window = self._ring[self._r0:self._r1]
        return window[window != NULL].copy()

    # ------------- incremental order snapshots (DESIGN.md §10) -------
    def _snap_emit(self):
        """Commit-time snapshot provider: mirror the ring slots dirtied
        since the last commit and seal one record line naming the window
        and the generation this commit targets.  Slots never move
        between compactions (appends write fresh slots, deletes punch
        NULLs in place, pops only advance the record's r0), so the
        per-commit delta is a few lines regardless of list size.

        Idempotent: a flush with nothing newly dirty and an unchanged
        window emits nothing, so the writeset can drain providers at
        every epoch flush (not just commits) without a commit's own
        flush adding bytes beyond the preceding epoch's — the
        inter-shard commit-window byte-identity invariant."""
        out = []
        if self._snap_resync:
            self._snap_dirty[:] = False
            self._snap_dirty[self._r0:self._r1] = True
            self._snap_resync = False
        dirty = np.nonzero(self._snap_dirty)[0]
        state = (self._r0, self._r1, int(self.header.vol[0, H_COUNT]))
        if not dirty.size and state == self._snap_last:
            return out
        self._snap_last = state
        if dirty.size:
            self.snapring.vol[dirty] = self._ring[dirty]
            out.append((self.snapring, dirty))
            self._snap_dirty[:] = False
        seq = self._snap_seq
        self._snap_seq += 1
        slot = seq % SNAP_SLOTS
        self.snaprec.vol[slot] = snap_record_pack(
            self.arena.generation + 1, seq, self._r0, self._r1,
            int(self.header.vol[0, H_COUNT]))
        out.append((self.snaprec, np.asarray([slot], np.int64)))
        return out

    # ------------- crash / reconstruction -------------
    def reconstruct(self) -> None:
        """Rebuild all volatile redundancy from persistent fields only
        (paper §IV-C3).  Thin shim over the registered pure reconstructor
        — recovery paths route through core.recovery.RecoveryManager,
        which loads the regions once and times the stage."""
        self.header.load()
        self.nodes.load()
        if self.snapshot:
            self.snapring.load()
            self.snaprec.load()
        rec.get("pstruct.dll")(self)

    def flush_stats(self) -> FlushStats:
        return self.arena.stats


def _snap_records(snaprec) -> list:
    """Intact records in the persisted record ring, any order."""
    return [r for r in (snap_record_parse(snaprec.vol[s])
                        for s in range(SNAP_SLOTS)) if r is not None]


def _snap_resume(d) -> None:
    """Post-recovery provider state: resume the record sequence past
    every intact slot (so newest-by-seq selection keeps working across
    restarts) and re-mirror the whole window at the next commit (the
    rebuilt ring starts at slot 0, wherever the mirror's window was)."""
    recs = _snap_records(d.snaprec)
    d._snap_seq = (max(r[1] for r in recs) + 1) if recs else 0
    d._snap_dirty[:] = False
    d._snap_resync = True
    d._snap_last = None


def _snap_candidate(d, count: int) -> Optional[ChainSnapshot]:
    """Candidate order from the newest intact record whose generation is
    committed: the persisted window's live slots, plus a bounded local
    walk along NEXT past the snapshot tail (the suffix of appends the
    record predates), minus any front overhang (pops since the record).
    Every failure mode returns None — chain_order's verify-always pass
    is what makes adoption safe, this only has to be cheap."""
    committed = d.arena.header_generation()
    best = None
    for r in _snap_records(d.snaprec):
        if r[0] > committed:        # sealed by a generation that never
            continue                # committed (crash inside the window)
        if best is None or r[1] > best[1]:
            best = r
    if best is None:
        return None
    _, _, r0, r1, _, _ = best
    if not (0 <= r0 <= r1 <= d.snapring.shape[0]):
        return None
    window = d.snapring.vol[r0:r1]
    base = window[window != NULL]
    if base.size == 0 or ((base < 0) | (base >= d.capacity)).any():
        return None
    if getattr(d.nodes, "paged_active", False):
        # bounded scalar suffix walk: fault only the blocks it steps on
        def read_next(cur: int) -> int:
            return d.nodes.read_one(cur, DATA_WORDS)
    else:
        nxt = d.next

        def read_next(cur: int) -> int:
            return int(nxt[cur])
    suffix = []
    cur = int(base[-1])
    while len(suffix) < count:
        nx = read_next(cur)
        if nx < 0 or nx >= d.capacity:
            break
        suffix.append(nx)
        cur = nx
    cand = np.concatenate([base, np.asarray(suffix, np.int64)]) \
        if suffix else np.asarray(base, np.int64)
    if cand.size < count:
        return None
    return ChainSnapshot(cand[cand.size - count:], replayed=len(suffix))


def _gather_verify(nodes, head: int, count: int, cand: np.ndarray,
                   n: int) -> bool:
    """Exact mirror of recovery._snapshot_verify, but gathering NEXT of
    only the candidate rows through the block cache — the verify that
    makes snapshot adoption safe costs O(working set) faults instead of
    a full-column read on a paged arena."""
    if count is None or cand.size != count:
        return False
    if int(cand[0]) != int(head):
        return False
    if ((cand < 0) | (cand >= n)).any():
        return False
    if count > 1 and not np.array_equal(
            np.asarray(nodes.read_at(cand[:-1], DATA_WORDS)), cand[1:]):
        return False
    return True


def _salvage_bad_rows(arena, region) -> np.ndarray:
    """Rows of a structure's primary region failing their sidecar
    checksums (empty when the arena carries no integrity layer) —
    the shared salvage-mode probe (DESIGN.md §13)."""
    if not getattr(arena, "integrity", False):
        return np.empty(0, np.int64)
    return arena.verify_region(region)


@rec.register("pstruct.dll")
def _reconstruct_dll(d: "DoublyLinkedList") -> dict:
    """Pure rebuild of the DLL's volatile redundancy from its (already
    loaded) persistent fields: PREV by one scatter off the vectorized
    chain order, TAIL = last, free slots = complement, order ring =
    chain order (paper §IV-C3, parallelized per §V-F)."""
    hv = d.header.vol[0]
    if hv[H_FLAG] != 1:
        # Flag bit unset: nothing was ever flushed — recover as empty
        # (the paper's "safely initialized" check, §IV-C3).
        hv[:] = 0
        hv[H_HEAD] = NULL
        hv[H_TAIL] = NULL
    count = int(hv[H_COUNT])
    head = int(hv[H_HEAD])
    d.prev = np.full(d.capacity, NULL, np.int64)
    snap_on = getattr(d, "snapshot", False)
    if count == 0:
        hv[H_TAIL] = NULL
        hv[H_FRESH] = 0
        d._free = []
        d._r0 = d._r1 = 0
        if snap_on:
            _snap_resume(d)
        return {"mode": d.mode, "count": 0}
    # The committed COUNT bounds the walk: rows appended by a torn epoch
    # (data flushed, header not) stay unreachable.
    method = getattr(d, "chain_method", "auto")
    salvage = getattr(d.arena, "_salvage", False)
    bad = _salvage_bad_rows(d.arena, d.nodes) if salvage \
        else np.empty(0, np.int64)
    dropped = 0
    if bad.size:
        # salvage walk (DESIGN.md §13): corrupt rows terminate the
        # chain — the recovered list is the maximal committed prefix
        # whose every node verifies.  Reads the committed persistent
        # image directly (never through the block cache, whose fault
        # verification would reject whole blocks a corrupt neighbor
        # shares with healthy prefix rows).
        nxt = np.asarray(d.arena._pimage(d.nodes))[:, DATA_WORDS]
        badset = set(bad.tolist())
        seen: set = set()
        prefix: list[int] = []
        cur = head
        while (len(prefix) < count and 0 <= cur < d.capacity
               and cur not in badset and cur not in seen):
            prefix.append(cur)
            seen.add(cur)
            cur = int(nxt[cur])
        order = np.asarray(prefix, np.int64)
        dropped = count - int(order.size)
        snap = None
        if order.size == 0:
            hv[:] = 0
            hv[H_HEAD] = NULL
            hv[H_TAIL] = NULL
            d._free = []
            d._r0 = d._r1 = 0
            if snap_on:
                _snap_resume(d)
            return {"mode": d.mode, "count": 0, "quarantined": True,
                    "quarantined_rows": dropped}
        count = int(order.size)
        hv[H_COUNT] = count
    else:
        snap = _snap_candidate(d, count) if snap_on else None
        if getattr(d.nodes, "paged_active", False) and snap is not None \
                and _gather_verify(d.nodes, head, count, snap.candidate,
                                   d.capacity):
            # paged fast path: adopt the verified snapshot WITHOUT
            # touching the full NEXT column — recovery faults only the
            # candidate rows' blocks, so its cost tracks the working set
            snap.outcome = "snapshot"
            order = snap.candidate.astype(np.int64, copy=True)
        else:
            try:
                order = chain_order(d._next_col(), head, count,
                                    method=method, snapshot=snap)
            except (RuntimeError, ValueError) as e:
                if salvage:
                    # structurally impossible chain (cycle / short walk)
                    # with no sidecar to localize it: the whole
                    # structure is untrusted
                    raise CorruptLineError(
                        d.nodes.name, np.empty(0, np.int64),
                        detail=f"chain rebuild: {e}") from e
                raise
    d.prev[order[1:]] = order[:-1]
    hv[H_TAIL] = order[-1]
    live = np.zeros(d.capacity, bool)
    live[order] = True
    # quarantined rows are neither live nor reusable: keeping them out
    # of the free list stops a later insert from resurrecting rot
    if bad.size:
        live[bad[bad < d.capacity]] = True
    # Fresh-water mark: everything at/above the max live id is fresh.
    fresh = int(order.max()) + 1
    hv[H_FRESH] = fresh
    free = np.nonzero(~live[:fresh])[0]
    d._free = free.tolist()
    d._ring = np.empty(d.capacity * 2, np.int64)
    d._ring[:count] = order
    d._r0, d._r1 = 0, count
    if d.mode == "full":
        # pure-reconstructor PREV rebuild stays UNMARKED (derivable);
        # on a paged arena these rows pin their blocks dirty until a
        # later epoch flushes them — the documented full-mode cost
        d.nodes.write_at(order[1:], DATA_WORDS + 1, order[:-1])
        d.nodes.write_at(order[:1], DATA_WORDS + 1, NULL)
    detail = {"mode": d.mode, "count": count,
              "chain": chain_method(d.capacity, count, method)}
    if dropped:
        detail.update(degraded=True, quarantined_rows=dropped,
                      chain="salvage")
    if snap_on:
        # outcome: "snapshot" (seeded, suffix-only replay) or the full
        # fallback rank the verify pass forced; replayed = rows walked
        detail["chain"] = snap.outcome if snap is not None \
            else detail["chain"]
        detail["replayed"] = snap.replayed if snap is not None \
            and snap.outcome == "snapshot" else count
        _snap_resume(d)
    return detail


def order_from_next(nxt: np.ndarray, head: int, count: int) -> np.ndarray:
    """Back-compat alias for the shared primitive (core.recovery)."""
    return chain_order(nxt, head, count)
