"""Partly-persistent hashmap (paper §IV-E, AOSP-chaining layout).

Layout mirrors the paper's Listing 3 at flush-unit granularity:

* Entries live in a dense append-only slab (the paper's spatially-adjacent
  struct Entry file).  Partly persistent row = KEY (8 B) + VALUE (7 x 8 B)
  = 64 B = 1 line.  Fully persistent row additionally persists HASH + NEXT
  (2nd line; 128 B row).
* struct Hashmap: only SIZE is essential (one header line).  BUCKETCOUNT,
  the bucket array, chain links and cached hashes are all volatile
  redundancy (DERIVABLE).

Deletions in a dense slab: partly-persistent deletion writes a NULL key
tombstone into the entry row (1 line — the paper's "KEY is not NULL =>
valid entry" check) — the slab is compacted lazily on rehash.

Batched ops vectorize the chain walks: a probe advances *all* pending
lookups one link per round (rounds = max chain length, ~O(1/load-factor)).

Reconstruction (paper §IV-E3): scan the slab rows [0, fresh), drop NULL
keys, recompute hashes, re-derive bucket count from SIZE and the load
factor, and rebuild chains in slab order (the paper appends at chain tail,
preserving insertion order — we reproduce that with a grouped argsort).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core import reconstruct as rec
from repro.core.arena import (Arena, FlushStats, SNAP_SLOTS, SNAP_WORDS,
                              snap_record_pack, snap_record_parse,
                              snapshot_enabled)
from repro.core.recovery import chain_walk
from repro.pstruct.dll import _salvage_bad_rows

NULL = -1
KEY_NULL = np.int64(-(2 ** 62))  # tombstone / empty key sentinel
VALUE_WORDS = 7

H_FLAG, H_SIZE, H_FRESH, H_BUCKETS = range(4)


def hash64(keys: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer — cheap, good avalanche, vectorizable."""
    x = keys.astype(np.uint64)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    x = x ^ (x >> np.uint64(31))
    return x


class Hashmap:
    def __init__(self, arena: Arena, capacity: int, mode: str = "partly",
                 load_factor: float = 0.75, name: str = "hm",
                 chain_method: str = "auto",
                 snapshot: Optional[bool] = None):
        assert mode in ("partly", "full")
        self.mode = mode
        self.capacity = capacity
        self.load_factor = load_factor
        # bucket-chain walk strategy for the batched unlink ("auto"
        # keeps the level-synchronous walk for many short chains and
        # flips to contraction list ranking only for few chains over a
        # huge slab — core.recovery.chain_walk, DESIGN.md §8)
        self.chain_method = chain_method
        self.arena = arena
        row = 8 if mode == "partly" else 16
        self._row = row
        # Sharded routing (DESIGN.md §7): entry rows scatter by a hash
        # of their 64-row segment — the paper's bucket-hash dispersal
        # decoupled from insert order, so an append burst fans out
        # across shard files instead of serializing on the shard that
        # owns the slab frontier (segment-granular: loads block-copy).
        self.entries = arena.regions.get(f"{name}.entries") or arena.region(
            f"{name}.entries", np.int64, (capacity, row), router=("hash",))
        self.header = arena.regions.get(f"{name}.header") or arena.region(
            f"{name}.header", np.int64, (1, 8))
        n_max = _next_pow2(max(16, int(capacity / load_factor)))
        self.n_buckets_max = n_max
        # Fully-persistent mode keeps the bucket array itself in PM (the
        # paper's struct Hashmap stores BUCKETS persistently); partly mode
        # keeps it volatile only.
        self._pbuckets = None
        if mode == "full":
            self._pbuckets = arena.regions.get(f"{name}.buckets") or \
                arena.region(f"{name}.buckets", np.int64, (n_max, 1),
                             router=("seg", 64))
        self.n_buckets = _next_pow2(max(16, int(capacity / load_factor)))
        self.buckets = np.full(self.n_buckets, NULL, np.int64)  # volatile
        self.chain = np.full(capacity, NULL, np.int64)  # volatile next
        self.hashes = np.zeros(capacity, np.uint64)  # volatile cached hash
        # keys whose entry rows were dropped by salvage recovery
        # (DESIGN.md §13) — consumers refuse these instead of serving
        # reconstructed garbage
        self.quarantined: set = set()
        # incremental order snapshots (DESIGN.md §10): persisted mirrors
        # of the volatile bucket heads + chain links, plus a 4-slot
        # sealed-record ring — recovery adopts them after verification,
        # replacing the O(N log N) rebuild argsort with O(N) gathers
        snap_on = snapshot_enabled(snapshot)
        self.snapbkt = arena.regions.get(f"{name}.snapbkt")
        self.snapchain = arena.regions.get(f"{name}.snapchain")
        self.snaprec = arena.regions.get(f"{name}.snaprec")
        if snap_on and self.snapbkt is None and not arena._layout_final:
            self.snapbkt = arena.region(f"{name}.snapbkt", np.int64,
                                        (n_max,), router=("seg", 64))
            self.snapchain = arena.region(f"{name}.snapchain", np.int64,
                                          (capacity,), router=("hash",))
            self.snaprec = arena.region(f"{name}.snaprec", np.int64,
                                        (SNAP_SLOTS, SNAP_WORDS))
        self.snapshot = snap_on and self.snapbkt is not None
        if self.snapshot:
            self._snap_bkt_dirty = np.zeros(n_max, bool)
            self._snap_chain_dirty = np.zeros(capacity, bool)
            self._snap_seq = 0
            self._snap_resync = True
            self._snap_last = None     # (nb, fresh, size) at last emit
            arena.add_snapshot_provider(self._snap_emit)

    @staticmethod
    def layout(capacity: int, mode: str = "partly", name: str = "hm",
               load_factor: float = 0.75,
               snapshot: Optional[bool] = None):
        row = 8 if mode == "partly" else 16
        out = {f"{name}.entries": (np.int64, (capacity, row), ("hash",)),
               f"{name}.header": (np.int64, (1, 8))}
        n_max = _next_pow2(max(16, int(capacity / load_factor)))
        if mode == "full":
            out[f"{name}.buckets"] = (np.int64, (n_max, 1), ("seg", 64))
        if snapshot_enabled(snapshot):
            out[f"{name}.snapbkt"] = (np.int64, (n_max,), ("seg", 64))
            out[f"{name}.snapchain"] = (np.int64, (capacity,), ("hash",))
            out[f"{name}.snaprec"] = (np.int64, (SNAP_SLOTS, SNAP_WORDS))
        return out

    def _persist_buckets(self, bkts: np.ndarray) -> None:
        if self._pbuckets is not None and bkts.size:
            self._pbuckets.vol[bkts, 0] = self.buckets[bkts]
            self._pbuckets.mark_rows(bkts)

    # -------- views --------
    @property
    def keys(self) -> np.ndarray:
        return self.entries.vol[:, 0]

    @property
    def values(self) -> np.ndarray:
        return self.entries.vol[:, 1:1 + VALUE_WORDS]

    @property
    def size(self) -> int:
        return int(self.header.vol[0, H_SIZE])

    # -------- core probe (vectorized chain walk) --------
    def _find_slots(self, keys: np.ndarray) -> np.ndarray:
        """Slab index of each key (NULL if absent)."""
        h = hash64(keys)
        b = (h & np.uint64(self.n_buckets - 1)).astype(np.int64)
        cur = self.buckets[b]
        found = np.full(len(keys), NULL, np.int64)
        active = cur != NULL
        while active.any():
            idx = cur[active]
            hit = self.keys[idx] == keys[active]
            tgt = np.nonzero(active)[0]
            found[tgt[hit]] = idx[hit]
            nxt = self.chain[idx]
            cur[active] = np.where(hit, NULL, nxt)
            active = cur != NULL
        return found

    def find_batch(self, keys: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (present mask, values (m, 7))."""
        slots = self._find_slots(np.asarray(keys, np.int64))
        ok = slots != NULL
        vals = np.zeros((len(keys), VALUE_WORDS), np.int64)
        vals[ok] = self.values[np.where(ok, slots, 0)][ok]
        return ok, vals

    # -------- mutation --------
    def insert_batch(self, keys: np.ndarray, values: np.ndarray) -> None:
        """Insert-or-update.  keys: (m,); values: (m, 7)."""
        with self.arena.epoch():
            self._insert_batch(keys, values)

    def _insert_batch(self, keys: np.ndarray, values: np.ndarray) -> None:
        keys = np.asarray(keys, np.int64)
        values = np.asarray(values, np.int64)
        # de-dup within batch: keep the last occurrence
        _, last = np.unique(keys[::-1], return_index=True)
        keep = np.sort(len(keys) - 1 - last)
        keys, values = keys[keep], values[keep]
        slots = self._find_slots(keys)
        upd = slots != NULL
        hv = self.header.vol[0]
        if upd.any():
            s = slots[upd]
            self.entries.vol[s, 1:1 + VALUE_WORDS] = values[upd]
            self.entries.mark_rows(s)
        new_keys = keys[~upd]
        if len(new_keys):
            fresh0 = int(hv[H_FRESH])
            if fresh0 + len(new_keys) > self.capacity:
                raise MemoryError("hashmap slab exhausted")
            ids = np.arange(fresh0, fresh0 + len(new_keys), dtype=np.int64)
            hv[H_FRESH] = fresh0 + len(new_keys)
            self.entries.vol[ids, 0] = new_keys
            self.entries.vol[ids, 1:1 + VALUE_WORDS] = values[~upd]
            h = hash64(new_keys)
            self.hashes[ids] = h
            hv[H_SIZE] += len(new_keys)
            self._link(ids, h)
            if self.mode == "full":
                self.entries.vol[ids, 8] = h.astype(np.int64) >> np.int64(1)
                # chain pointers persisted too (set in _link)
            # new ids come off the fresh-range watermark, so their slab
            # bytes are dead in the committed image: shadow mode flushes
            # them home in place (unreachable until the flip); a
            # same-epoch update re-marks the row as a rewrite and the
            # writeset's rewrite-wins rule reroutes it through the remap
            self.entries.mark_rows(ids, fresh=True)
            if hv[H_SIZE] > self.load_factor * self.n_buckets:
                self._grow()
        hv[H_FLAG] = 1
        self.header.mark_rows(np.array([0]))

    def _link(self, ids: np.ndarray, h: np.ndarray) -> None:
        """Append ids to their bucket chains (chain-tail order, as the
        paper's reconstruction expects).  Vectorized by bucket grouping."""
        b = (h & np.uint64(self.n_buckets - 1)).astype(np.int64)
        order = np.argsort(b, kind="stable")
        bs, ids_s = b[order], ids[order]
        grp_start = np.concatenate([[True], bs[1:] != bs[:-1]])
        # head of each new group links after current chain tail
        tails = self._chain_tails(bs[grp_start])
        # intra-group chaining
        self.chain[ids_s[:-1]] = np.where(~grp_start[1:], ids_s[1:], NULL)
        self.chain[ids_s[-1]] = NULL
        heads = ids_s[grp_start]
        # tail linking, one scatter per case: empty buckets adopt the
        # group head; occupied buckets chain it after their tail
        empty = tails == NULL
        self.buckets[bs[grp_start][empty]] = heads[empty]
        self.chain[tails[~empty]] = heads[~empty]
        if self.snapshot:
            self._snap_chain_dirty[ids_s] = True
            self._snap_chain_dirty[tails[~empty]] = True
            self._snap_bkt_dirty[bs[grp_start][empty]] = True
        if self.mode == "full":
            self.entries.vol[ids_s, 9] = self.chain[ids_s]
            link_dirty = tails[~empty]
            if link_dirty.size:
                self.entries.vol[link_dirty, 9] = self.chain[link_dirty]
                self.entries.mark_rows(link_dirty)
            self._persist_buckets(bs[grp_start][empty])

    def _chain_tails(self, bkts: np.ndarray) -> np.ndarray:
        cur = self.buckets[bkts]
        tails = np.full(len(bkts), NULL, np.int64)
        active = cur != NULL
        while active.any():
            idx = cur[active]
            tails[np.nonzero(active)[0]] = idx
            cur[active] = self.chain[idx]
            active = cur != NULL
        return tails

    def remove_batch(self, keys: np.ndarray) -> np.ndarray:
        """Tombstone deletion.  Returns mask of keys that were present."""
        with self.arena.epoch():
            return self._remove_batch(keys)

    def _remove_batch(self, keys: np.ndarray) -> np.ndarray:
        keys = np.asarray(keys, np.int64)
        slots = self._find_slots(keys)
        ok = slots != NULL
        s = np.unique(slots[ok])
        if s.size == 0:
            self.header.mark_rows(np.array([0]))
            return ok
        hv = self.header.vol[0]
        # unlink from volatile chains (vectorized per chain via predecessor
        # search), write tombstone key persistently; chain fixes in full
        # mode are marked inside _unlink.
        self._unlink(s)
        self.entries.vol[s, 0] = KEY_NULL
        hv[H_SIZE] -= s.size
        self.entries.mark_rows(s)
        self.header.mark_rows(np.array([0]))
        return ok

    def _unlink(self, slots: np.ndarray) -> None:
        """Remove `slots` from their bucket chains, all buckets in
        parallel: materialize the affected chains with the shared
        chain_walk primitive, mask out the removed members, and relink
        the survivors (order preserved) with two scatters."""
        hs = self.hashes[slots]
        bkts = np.unique((hs & np.uint64(self.n_buckets - 1)).astype(np.int64))
        members = chain_walk(self.chain, self.buckets[bkts],
                             method=self.chain_method)
        if self.snapshot:
            self._snap_bkt_dirty[bkts] = True
            self._snap_chain_dirty[slots] = True
        if members.shape[1] == 0:
            self.chain[slots] = NULL
            return
        valid = members != NULL
        keep = valid & ~np.isin(members, slots)
        # compact survivors left (stable: chain order preserved)
        comp = np.take_along_axis(
            members, np.argsort(~keep, axis=1, kind="stable"), axis=1)
        cnt = keep.sum(1)
        old_heads = self.buckets[bkts]
        new_heads = np.where(cnt > 0, comp[:, 0], NULL)
        self.buckets[bkts] = new_heads
        # relink: comp[b, j] -> comp[b, j+1] for j+1 < cnt, last -> NULL
        chain_dirty = []
        if comp.shape[1] > 1:
            m = (np.arange(comp.shape[1] - 1)[None, :] + 1) < cnt[:, None]
            src, dst = comp[:, :-1][m], comp[:, 1:][m]
            changed = self.chain[src] != dst
            self.chain[src] = dst
            chain_dirty.append(src[changed])
            if self.snapshot:
                self._snap_chain_dirty[src[changed]] = True
        nz = np.nonzero(cnt > 0)[0]
        last = comp[nz, cnt[nz] - 1]
        last_changed = self.chain[last] != NULL
        self.chain[last] = NULL
        chain_dirty.append(last[last_changed])
        if self.snapshot:
            self._snap_chain_dirty[last[last_changed]] = True
        self.chain[slots] = NULL
        if self.mode == "full":
            dirty = np.unique(np.concatenate(chain_dirty)) \
                if chain_dirty else np.empty(0, np.int64)
            if dirty.size:
                self.entries.vol[dirty, 9] = self.chain[dirty]
                self.entries.mark_rows(dirty)
            self._persist_buckets(bkts[new_heads != old_heads])

    def _grow(self) -> None:
        if self.n_buckets >= self.n_buckets_max:
            return
        self.n_buckets *= 2
        self._rebuild_chains()
        if self.mode == "full":
            # A PM-resident rehash rewrites every chain pointer and the
            # whole bucket array — the full (expensive) flush, which is
            # exactly why the paper keeps this structure volatile.
            fresh = int(self.header.vol[0, H_FRESH])
            live = np.nonzero(self.keys[:fresh] != KEY_NULL)[0]
            self.entries.vol[live, 9] = self.chain[live]
            self.entries.mark_rows(live)
            self._pbuckets.vol[: self.n_buckets, 0] = \
                self.buckets[: self.n_buckets]
            self._pbuckets.mark_range(0, self.n_buckets)

    def _rebuild_chains(self) -> None:
        fresh = int(self.header.vol[0, H_FRESH])
        live = np.nonzero(self.keys[:fresh] != KEY_NULL)[0]
        self.buckets = np.full(self.n_buckets, NULL, np.int64)
        self.chain = np.full(self.capacity, NULL, np.int64)
        if self.snapshot:
            # every link potentially moved: re-mirror wholesale at the
            # next commit (grows are O(log N) rare, so this amortizes)
            self._snap_resync = True
        if live.size == 0:
            return
        h = self.hashes[live]
        b = (h & np.uint64(self.n_buckets - 1)).astype(np.int64)
        order = np.argsort(b, kind="stable")  # slab order within bucket
        bs, ls = b[order], live[order]
        grp_start = np.concatenate([[True], bs[1:] != bs[:-1]])
        self.buckets[bs[grp_start]] = ls[grp_start]
        self.chain[ls[:-1]] = np.where(~grp_start[1:], ls[1:], NULL)
        if ls.size:
            self.chain[ls[-1]] = NULL

    # -------- incremental order snapshots (DESIGN.md §10) --------
    def _snap_emit(self):
        """Commit-time provider: mirror the bucket heads and chain links
        dirtied since the last commit, then seal one record line naming
        (n_buckets, fresh, size) for the generation this commit
        targets.

        Idempotent: a flush with nothing newly dirty and unchanged
        (n_buckets, fresh, size) emits nothing — the writeset drains
        providers at every epoch flush, and a commit's own flush must
        not add bytes beyond the preceding epoch's (the inter-shard
        commit-window byte-identity invariant)."""
        out = []
        hv = self.header.vol[0]
        fresh = int(hv[H_FRESH])
        if self._snap_resync:
            self._snap_chain_dirty[:] = False
            self._snap_bkt_dirty[:] = False
            self._snap_chain_dirty[:fresh] = True
            self._snap_bkt_dirty[:self.n_buckets] = True
            self._snap_resync = False
        state = (self.n_buckets, fresh, int(hv[H_SIZE]))
        if state == self._snap_last and not self._snap_chain_dirty.any() \
                and not self._snap_bkt_dirty.any():
            return out
        self._snap_last = state
        cd = np.nonzero(self._snap_chain_dirty)[0]
        if cd.size:
            self.snapchain.vol[cd] = self.chain[cd]
            out.append((self.snapchain, cd))
            self._snap_chain_dirty[:] = False
        bd = np.nonzero(self._snap_bkt_dirty)[0]
        if bd.size:
            self.snapbkt.vol[bd] = self.buckets[bd]
            out.append((self.snapbkt, bd))
            self._snap_bkt_dirty[:] = False
        seq = self._snap_seq
        self._snap_seq += 1
        slot = seq % SNAP_SLOTS
        self.snaprec.vol[slot] = snap_record_pack(
            self.arena.generation + 1, seq, self.n_buckets, fresh,
            int(hv[H_SIZE]))
        out.append((self.snaprec, np.asarray([slot], np.int64)))
        return out

    # -------- crash / reconstruction --------
    def reconstruct(self) -> None:
        """Thin shim over the registered pure reconstructor — recovery
        paths route through core.recovery.RecoveryManager, which loads
        the regions once and times the stage."""
        self.header.load()
        self.entries.load()
        if self.snapshot:
            self.snapbkt.load()
            self.snapchain.load()
            self.snaprec.load()
        rec.get("pstruct.hashmap")(self)

    def check_against(self, ref: dict) -> bool:
        ks = np.fromiter(ref.keys(), np.int64, len(ref))
        ok, vals = self.find_batch(ks)
        if not ok.all() or self.size != len(ref):
            return False
        want = np.stack([ref[int(k)] for k in ks]) if len(ref) else vals
        return bool((vals == want).all())

    def flush_stats(self) -> FlushStats:
        return self.arena.stats


def _hm_snap_records(snaprec) -> list:
    return [r for r in (snap_record_parse(snaprec.vol[s])
                        for s in range(SNAP_SLOTS)) if r is not None]


def _hm_snap_resume(h: "Hashmap") -> None:
    recs = _hm_snap_records(h.snaprec)
    h._snap_seq = (max(r[1] for r in recs) + 1) if recs else 0
    h._snap_bkt_dirty[:] = False
    h._snap_chain_dirty[:] = False
    h._snap_resync = True
    h._snap_last = None


def _hm_snap_adopt(h: "Hashmap", fresh: int, idx: np.ndarray
                   ) -> Optional[int]:
    """Seed the bucket chains from the newest committed snapshot, link
    the suffix of slab rows younger than the record, VERIFY the result
    is a canonical chain assembly (every live row exactly once, in its
    hash bucket, ascending slab order — the invariant both _link and
    _rebuild_chains maintain), and scatter it into fresh volatile
    arrays.  The snapshot carries the PRE-CRASH bucket basis (rec_nb),
    so adoption also restores n_buckets — same logical map, no argsort
    and no immediate regrow churn.  Returns the replayed-suffix length
    on adoption, None on any mismatch (callers fall back to the full
    size-derived rebuild)."""
    committed = h.arena.header_generation()
    best = None
    for r in _hm_snap_records(h.snaprec):
        if r[0] > committed:
            continue
        if best is None or r[1] > best[1]:
            best = r
    if best is None:
        return None
    _, _, rec_nb, rec_fresh, _, _ = best
    if not (16 <= rec_nb <= h.n_buckets_max and rec_nb & (rec_nb - 1) == 0):
        return None
    if not 0 <= rec_fresh <= fresh:
        return None
    mask = np.uint64(rec_nb - 1)
    cand_bkt = np.array(h.snapbkt.vol[:rec_nb], np.int64).reshape(-1)
    cand_chain = np.array(h.snapchain.vol, np.int64).reshape(-1)
    # local-walk only the suffix: rows the record predates were appended
    # at their bucket's chain tail in ascending slab order — replay that
    sfx = idx[idx >= rec_fresh]
    if sfx.size:
        b = (hash64(h.keys[sfx]) & mask).astype(np.int64)
        order = np.argsort(b, kind="stable")
        bs, ids_s = b[order], sfx[order]
        grp_start = np.concatenate([[True], bs[1:] != bs[:-1]])
        # tails of the affected buckets, walked over the candidate
        # arrays (bounded: torn links can cycle, so cap the rounds)
        tb = bs[grp_start]
        cur = cand_bkt[tb]
        tails = np.full(tb.size, NULL, np.int64)
        for _ in range(fresh + 1):
            ok = (cur >= 0) & (cur < h.capacity) & (cur < rec_fresh)
            if not ok.any():
                break
            tails[ok] = cur[ok]
            nxt = cand_chain[np.where(ok, cur, 0)]
            cur = np.where(ok, nxt, NULL)
        else:
            return None                       # never terminated: cycle
        cand_chain[ids_s[:-1]] = np.where(~grp_start[1:], ids_s[1:], NULL)
        cand_chain[ids_s[-1]] = NULL
        heads = ids_s[grp_start]
        empty = tails == NULL
        cand_bkt[tb[empty]] = heads[empty]
        cand_chain[tails[~empty]] = heads[~empty]
    # verify-always: materialize every chain and check it IS the
    # canonical state (one O(N) walk — the saving over the O(N log N)
    # argsort is the point of the seed)
    try:
        members = chain_walk(cand_chain, cand_bkt,
                             method=h.chain_method)
    except RuntimeError:
        return None                           # cycle in a torn chain
    valid = members != NULL
    flat = members[valid]
    if flat.size != idx.size:
        return None
    if flat.size:
        if ((flat < 0) | (flat >= fresh)).any():
            return None
        if (h.keys[flat] == KEY_NULL).any():
            return None
        want_b = (hash64(h.keys[flat]) & mask).astype(np.int64)
        got_b = np.broadcast_to(
            np.arange(rec_nb)[:, None], members.shape)[valid]
        if not np.array_equal(want_b, got_b):
            return None
        # ascending slab order within each bucket row (rules out both
        # misordering and duplicates: a dupe must share a bucket)
        if members.shape[1] > 1:
            step = valid[:, 1:]
            if (members[:, 1:][step] <= members[:, :-1][step]).any():
                return None
    # adopt: restore the record's basis and scatter the verified chains
    h.n_buckets = int(rec_nb)
    h.buckets = np.full(h.n_buckets, NULL, np.int64)
    h.chain = np.full(h.capacity, NULL, np.int64)
    if members.shape[1]:
        h.buckets[valid[:, 0]] = members[valid[:, 0], 0]
        if members.shape[1] > 1:
            step = valid[:, 1:]
            h.chain[members[:, :-1][step]] = members[:, 1:][step]
    return int(sfx.size)


@rec.register("pstruct.hashmap")
def _reconstruct_hashmap(h: "Hashmap") -> dict:
    """Pure rebuild (paper §IV-E3): SIZE + dense (KEY, VALUE) rows ->
    full hashmap.  Scan the slab rows [0, fresh) in one vectorized pass,
    drop NULL keys, recompute hashes, re-derive the bucket count from
    SIZE and the load factor, and rebuild chains in slab order — seeded
    from the newest committed order snapshot when one verifies
    (DESIGN.md §10)."""
    hv = h.header.vol[0]
    if hv[H_FLAG] != 1:
        # uninitialized image recovers as an empty map (§IV-E3 validity
        # check on struct Hashmap)
        hv[:] = 0
    fresh = int(hv[H_FRESH])
    # salvage (DESIGN.md §13): entry rows failing their sidecar become
    # volatile tombstones — the map recovers every verifiable entry and
    # refuses the rest by key.  A corrupt VALUE word leaves the key
    # word intact, so the quarantine names the real key; a corrupt KEY
    # word degrades to row-level loss (the garbage key is recorded
    # best-effort, and the structure is flagged degraded either way).
    h.quarantined = set()
    dropped = 0
    if getattr(h.arena, "_salvage", False):
        bad = _salvage_bad_rows(h.arena, h.entries)
        bad = bad[bad < fresh]
        if bad.size:
            img = np.asarray(h.arena._pimage(h.entries))
            for r in bad.tolist():
                key = int(img[r, 0])
                if key != KEY_NULL:
                    h.quarantined.add(key)
            was_live = int((h.keys[bad] != KEY_NULL).sum())
            h.entries.vol[bad, 0] = KEY_NULL
            hv[H_SIZE] = max(0, int(hv[H_SIZE]) - was_live)
            dropped = int(bad.size)
    live = h.keys[:fresh] != KEY_NULL
    # SIZE -> derive bucket count (paper derives BUCKETCOUNT from SIZE)
    size = int(hv[H_SIZE])
    h.n_buckets = _next_pow2(max(16, int(size / h.load_factor) + 1))
    h.hashes = np.zeros(h.capacity, np.uint64)
    idx = np.nonzero(live)[0]
    h.hashes[idx] = hash64(h.keys[idx])
    detail = {"mode": h.mode, "size": size, "live": int(idx.size)}
    if dropped:
        detail.update(degraded=True, quarantined_rows=dropped,
                      quarantined_keys=sorted(h.quarantined))
    snap_on = getattr(h, "snapshot", False)
    # a salvaged map never adopts a snapshot (the mirrors may reference
    # quarantined rows) — rebuild from the tombstoned slab instead
    replayed = _hm_snap_adopt(h, fresh, idx) \
        if snap_on and not dropped else None
    if replayed is None:
        h._rebuild_chains()
    if snap_on:
        detail["chain"] = "snapshot" if replayed is not None else "rebuild"
        detail["replayed"] = replayed if replayed is not None \
            else int(idx.size)
        _hm_snap_resume(h)
    return detail


def _next_pow2(x: int) -> int:
    return 1 << (int(x - 1)).bit_length()
