from repro.pstruct.dll import DoublyLinkedList  # noqa: F401
from repro.pstruct.hashmap import Hashmap  # noqa: F401
from repro.pstruct.bptree import BPTree  # noqa: F401
