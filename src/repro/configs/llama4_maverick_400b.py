"""llama4-maverick-400b-a17b [moe] — 128 experts top-1, interleaved MoE.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1
[hf:meta-llama/Llama-4 family].  Maverick interleaves dense and MoE layers
(interleave_moe_layer_step=2); MoE layers have 128 routed experts (top-1)
plus one always-on shared expert, expert d_ff 8192; dense layers use
d_ff_mlp 16384.  Early-fusion multimodal attention is out of scope for the
LM backbone cells (text shapes only).  long_500k skipped: full attention.
"""
from repro.configs.base import DENSE, MOE, ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=16384,             # dense-layer FFN width
    vocab=202048,
    head_dim=128,
    layer_pattern=(DENSE, MOE),
    # router_group=4096 (one dispatch group per training sub-batch):
    # scanning smaller groups makes GSPMD all-reduce the accumulated
    # expert-weight gradients once PER GROUP — 4x the necessary collective
    # volume (§Perf hillclimb #2).  One group per sequence keeps dispatched
    # activations small ((B_loc, 8, 160, 5120) bf16 ~130 MB/device) while
    # reducing gradients once per microbatch.
    moe=MoEConfig(n_experts=128, top_k=1, capacity_factor=1.25,
                  expert_d_ff=8192, shared_expert=True, router_group=4096),
    rope_theta=500000.0,
    tie_embeddings=False,
)
