"""Registry of assigned architectures (``--arch <id>``)."""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.configs import base
from repro.configs.base import ArchConfig, ShapeSpec

from repro.configs.hymba_1_5b import CONFIG as HYMBA
from repro.configs.xlstm_1_3b import CONFIG as XLSTM
from repro.configs.llama3_2_3b import CONFIG as LLAMA32_3B
from repro.configs.gemma3_27b import CONFIG as GEMMA3_27B
from repro.configs.gemma2_9b import CONFIG as GEMMA2_9B
from repro.configs.phi3_medium_14b import CONFIG as PHI3_14B
from repro.configs.llama3_2_vision_90b import CONFIG as VISION_90B
from repro.configs.whisper_large_v3 import CONFIG as WHISPER_V3
from repro.configs.dbrx_132b import CONFIG as DBRX
from repro.configs.llama4_maverick_400b import CONFIG as LLAMA4_MAV

ARCHS: Dict[str, ArchConfig] = {
    c.name: c
    for c in (
        HYMBA, XLSTM, LLAMA32_3B, GEMMA3_27B, GEMMA2_9B,
        PHI3_14B, VISION_90B, WHISPER_V3, DBRX, LLAMA4_MAV,
    )
}

# Architectures whose sequence mixing is sub-quadratic end to end; only
# these run the long_500k cell (see DESIGN.md §4).
SUBQUADRATIC = ("hymba-1.5b", "xlstm-1.3b")


def get(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def cell_supported(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Is (arch x shape) runnable?  Returns (ok, reason_if_skipped)."""
    if shape.name == "long_500k" and cfg.name not in SUBQUADRATIC:
        return False, "SKIPPED(full-attention: O(L^2) at 512k)"
    return True, ""


def all_cells() -> List[Tuple[ArchConfig, ShapeSpec]]:
    """All 40 (arch x shape) cells, including ones recorded as skipped."""
    return [(cfg, s) for cfg in ARCHS.values() for s in base.ALL_SHAPES]
