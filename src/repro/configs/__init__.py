from repro.configs.base import (  # noqa: F401
    ALL_SHAPES,
    ArchConfig,
    MoEConfig,
    SSMConfig,
    ShapeSpec,
    SHAPES,
    XLSTMConfig,
    reduced,
)
from repro.configs.registry import ARCHS, all_cells, cell_supported, get  # noqa: F401
