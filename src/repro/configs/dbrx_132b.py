"""dbrx-132b [moe] — 16 experts top-4, fine-grained.

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352, MoE 16e top-4
[hf:databricks/dbrx-base].  Every layer is MoE.
long_500k skipped: full attention.
"""
from repro.configs.base import MOE, ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100352,
    head_dim=128,
    layer_pattern=(MOE,),
    # router_group=4096: one dispatch group per training sub-batch, so
    # expert-weight gradients reduce once per microbatch instead of once
    # per 1k-token group (§Perf hillclimb #2; same reasoning as llama4).
    moe=MoEConfig(n_experts=16, top_k=4, capacity_factor=1.25,
                  router_group=4096),
    rope_theta=500000.0,
    tie_embeddings=False,
)
