"""gemma3-27b [dense] — 5:1 local:global attention, 128k context.

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144
[hf:google/gemma-3 family].  Pattern: five sliding-window layers then one
global layer.  QK-norm, no attention softcap (gemma3 dropped it).
long_500k skipped: global layers are O(L^2).
"""
from repro.configs.base import ATTN, ATTN_LOCAL, DENSE, ArchConfig

CONFIG = ArchConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_ff=21504,
    vocab=262144,
    head_dim=128,
    layer_pattern=("dense:local",) * 5 + ("dense:full",),
    window=1024,
    qk_norm=True,
    rope_theta=1000000.0,
    act="gelu",
    tie_embeddings=True,
)
