"""llama-3.2-vision-90b [vlm] — cross-attention image layers.

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256
[hf:meta-llama/Llama-3.2-Vision family].  Every 5th layer cross-attends to
precomputed image-patch embeddings (the vision frontend is a STUB:
``input_specs`` supplies (B, n_patches, d_model) embeddings directly, per
the assignment).  long_500k skipped: full attention.
"""
from repro.configs.base import DENSE, ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    head_dim=128,
    layer_pattern=(DENSE,) * 4 + ("dense:cross",),
    context_seq=1600,  # image patch tokens (stub frontend)
    rope_theta=500000.0,
    tie_embeddings=False,
)
