"""hymba-1.5b [hybrid] — parallel attention + mamba heads per layer.

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16
[arXiv:2411.13676; hf].  Hymba uses sliding-window attention on most layers
with a few full-attention layers (first/middle/last per the paper); the
mamba heads run in parallel with the attention heads inside every layer.
Sub-quadratic ⇒ the long_500k cell runs for this arch.
"""
from repro.configs.base import ATTN, HYBRID, ArchConfig, SSMConfig

# Pattern of 8 positions tiled 4x over 32 layers: position 0 is a
# full-attention hybrid layer, positions 1..7 use sliding-window attention
# in the attention half of the hybrid head group.
CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    head_dim=64,
    layer_pattern=(HYBRID + ":full",) + (HYBRID + ":local",) * 7,
    window=1024,
    ssm=SSMConfig(state_dim=16, conv_width=4, expand=1),
    rope_theta=10000.0,
)
