"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks (xLSTM[7:1]).

48L d_model=2048 4H (GQA kv=4) d_ff=0 vocab=50304 [arXiv:2405.04517].
d_ff=0: xLSTM blocks carry their own up/down projections; there is no
separate transformer FFN.  Linear-time recurrence ⇒ long_500k runs.
"""
from repro.configs.base import MLSTM, SLSTM, ArchConfig, XLSTMConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    head_dim=512,
    layer_pattern=(MLSTM,) * 7 + (SLSTM,),
    xlstm=XLSTMConfig(chunk=256, proj_factor=2.0, slstm_every=8),
    tie_embeddings=False,
)
