"""gemma2-9b [dense] — alternating local/global attention, logit softcaps.

42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000 [arXiv:2408.00118].
Attention logit softcap 50.0, final LM logit softcap 30.0, window 4096.
long_500k skipped: global layers are O(L^2).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    d_ff=14336,
    vocab=256000,
    head_dim=256,
    layer_pattern=("dense:local", "dense:full"),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    rope_theta=10000.0,
    act="gelu",
    tie_embeddings=True,
)
