"""Architecture + shape configuration schema.

Every assigned architecture is expressed as an :class:`ArchConfig`.  The
backbone (``repro.models.backbone``) consumes the config's ``layer_pattern``
— a repeating "superblock" of layer types — so heterogeneous stacks
(local/global attention, dense/MoE interleave, mLSTM/sLSTM mixes,
self/cross attention) lower to a single ``lax.scan`` over stacked superblock
parameters with *static* per-position layer types.  This keeps the HLO size
O(pattern) instead of O(n_layers) and keeps cost_analysis FLOPs exact (no
runtime branches).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

# Layer-type tags understood by repro.models.backbone.
ATTN = "attn"              # causal self attention (full)
ATTN_LOCAL = "attn_local"  # causal self attention, sliding window
ATTN_BIDIR = "attn_bidir"  # bidirectional self attention (encoder)
ATTN_CROSS = "attn_cross"  # cross attention to a context sequence
HYBRID = "hybrid"          # parallel attention + mamba heads (hymba)
MLSTM = "mlstm"            # xLSTM matrix-memory block
SLSTM = "slstm"            # xLSTM scalar-memory block
MOE = "moe"                # MoE FFN layer (attn mixer + routed experts)
DENSE = "dense"            # plain attn mixer + dense FFN

RECURRENT_TYPES = (HYBRID, MLSTM, SLSTM)


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    expert_d_ff: int = 0          # 0 -> use ArchConfig.d_ff
    shared_expert: bool = False   # llama4-style always-on shared expert
    router_group: int = 1024      # tokens per dispatch group (scanned)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16           # N, per-channel SSM state
    conv_width: int = 4
    expand: int = 1               # d_inner = expand * d_model


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    # mLSTM / sLSTM block geometry (head_dim = d_model / n_heads).
    chunk: int = 256              # chunkwise-parallel chunk length (mLSTM)
    proj_factor: float = 2.0      # mLSTM up-projection factor
    slstm_every: int = 8          # 1 sLSTM per this many layers (7:1 mix)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    # Attention pattern.
    layer_pattern: Tuple[str, ...] = (DENSE,)
    window: int = 1024            # sliding window for ATTN_LOCAL layers
    rope_theta: float = 10000.0
    attn_softcap: float = 0.0     # gemma2-style tanh softcap on logits
    final_softcap: float = 0.0    # softcap on LM logits
    qk_norm: bool = False         # gemma3-style rmsnorm on q,k
    tie_embeddings: bool = True
    # Optional sub-configs.
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # Encoder (whisper) / multimodal context (vision) stubs.
    encoder_layers: int = 0       # >0 -> enc-dec model
    encoder_seq: int = 1500       # audio frames after conv stub
    context_seq: int = 0          # >0 -> cross-attn context length (vision)
    # Norm/activation choices.
    norm_eps: float = 1e-6
    act: str = "silu"             # silu -> SwiGLU; gelu -> GeGLU
    # Attention-free model?  (xLSTM has no conventional FFN when d_ff == 0.)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        """Embedding tables are padded to a multiple of 256 so the vocab
        dimension shards evenly over a 16-way model axis (standard practice;
        hymba's 32001 and whisper's 51866 are not otherwise divisible)."""
        return _round_up(self.vocab, 256)

    @property
    def q_group(self) -> int:
        assert self.n_heads % self.n_kv_heads == 0, self.name
        return self.n_heads // self.n_kv_heads

    def pattern_plan(self) -> Tuple[Tuple[str, ...], int, Tuple[str, ...]]:
        """(pattern, n_superblocks, remainder_layer_types)."""
        p = self.layer_pattern
        n_super = self.n_layers // len(p)
        rem = tuple(p[: self.n_layers % len(p)])
        return p, n_super, rem

    # ---- analytical parameter / FLOP accounting (for roofline ratios) ----
    def param_count(self) -> int:
        """Exact parameter count of the implemented model (padded vocab)."""
        from repro.models import accounting  # local import to avoid cycle

        return accounting.param_count(self)

    def model_flops_per_token(self, seq_len: int, training: bool) -> float:
        """6*N*D-style useful-FLOPs estimate (MoE: active params only)."""
        from repro.models import accounting

        return accounting.model_flops_per_token(self, seq_len, training)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

ALL_SHAPES: Tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


def reduced(cfg: ArchConfig) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests.

    Keeps the layer_pattern (one full superblock + remainder coverage), cuts
    width/heads/vocab/experts to toy sizes.
    """
    pattern = cfg.layer_pattern
    n_layers = min(cfg.n_layers, len(pattern) + 1)  # 1 superblock + 1 rem
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe, n_experts=min(4, cfg.moe.n_experts),
            top_k=min(2, cfg.moe.top_k), router_group=64, expert_d_ff=64)
    ssm = cfg.ssm
    if ssm is not None:
        ssm = dataclasses.replace(ssm, state_dim=4)
    xl = cfg.xlstm
    if xl is not None:
        xl = dataclasses.replace(xl, chunk=16, slstm_every=2)
    n_heads = min(cfg.n_heads, 4)
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    while n_heads % n_kv:
        n_kv -= 1
    return dataclasses.replace(
        cfg,
        n_layers=n_layers,
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab=256,
        window=8,
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_seq=16 if cfg.encoder_layers else cfg.encoder_seq,
        context_seq=16 if cfg.context_seq else 0,
        moe=moe,
        ssm=ssm,
        xlstm=xl,
    )
