"""whisper-large-v3 [audio] — encoder-decoder, conv frontend stub.

32L d_model=1280 20H (kv=20, i.e. MHA) d_ff=5120 vocab=51866
[arXiv:2212.04356].  32 encoder layers (bidirectional) + 32 decoder layers
(causal self-attn + cross-attn to encoder states).  The mel-spectrogram conv
frontend is a STUB: ``input_specs`` supplies (B, 1500, d_model) frame
embeddings.  long_500k skipped: decoder is full attention.  The decode shape
lowers the decoder serve_step with self-attn KV cache + precomputed
cross-attn KV.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,            # decoder layers; encoder_layers below
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    head_dim=64,
    layer_pattern=("dense:cross",),  # every decoder layer: self + cross
    encoder_layers=32,
    encoder_seq=1500,
    act="gelu",
    tie_embeddings=True,
)
